//! Standalone RIP validation — regenerates paper Table 4 + Figure 4
//! without touching the PJRT runtime (pure rust, runs in seconds).
//!
//!     cargo run --release --example rip_validation [-- --samples 1000]

use cosa::exp;
use cosa::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    exp::run("table4", &args)
}
