//! End-to-end driver (DESIGN.md §6): fine-tune the ~33M-parameter `e2e-lm`
//! transformer on the synthetic math-reasoning corpus with CoSA, log the
//! loss curve, and report decode-based exact-match — optionally against
//! LoRA for the paired comparison.  Results recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example finetune_math -- [--steps 200]
//!         [--method cosa|lora] [--compare] [--preset e2e-lm|small-lm]

use cosa::config::{RunConfig, Schedule, TrainConfig};
use cosa::data::Vocab;
use cosa::eval;
use cosa::runtime::executor::Runtime;
use cosa::runtime::Registry;
use cosa::train::{TaskData, Trainer};
use cosa::util::args::Args;

fn run_one(rt: &Runtime, reg: &Registry, preset: &str, method: &str,
           steps: usize, lr: f64) -> anyhow::Result<()> {
    let cfg = RunConfig {
        name: format!("e2e-math-{method}"),
        artifact: format!("{preset}_{method}"),
        task: "math".into(),
        train: TrainConfig {
            steps,
            lr,
            weight_decay: 0.01,
            clip_norm: 1.0,
            schedule: Schedule::CosineWarmup { warmup_frac: 0.05 },
            eval_every: (steps / 4).max(1),
            log_every: 10,
            grad_accum: 1,
        },
        out_dir: "runs/e2e".into(),
        ..RunConfig::default()
    };
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(rt, reg, cfg)?;
    let meta = trainer.train_exec.meta.clone();
    println!(
        "\n=== {method}: d={} L={} vocab={} | trainables {} ({} tensors) ===",
        meta.model.d_model, meta.model.n_layers, meta.model.vocab,
        meta.trainable_param_count(),
        meta.inputs_with_role("trainable").len()
    );
    trainer.run()?;
    let train_time = t0.elapsed().as_secs_f64();

    let (eval_loss, token_acc) = trainer.evaluate()?;
    // decode-based exact match on held-out problems (decode is ~2 eval
    // steps per generated token at e2e scale — keep n modest by default)
    let decode_n: usize = std::env::var("COSA_DECODE_N")
        .ok().and_then(|v| v.parse().ok()).unwrap_or(24);
    let em = match &trainer.data {
        TaskData::Lm(d) => {
            let n = decode_n.min(d.eval.len());
            let exs: Vec<&_> = d.eval[..n].iter().collect();
            let gen = eval::greedy_decode(&trainer.eval_exec, &trainer.state,
                                          &exs, 12)?;
            let v = Vocab::new(meta.model.vocab);
            eval::exact_match_int(&v, &exs, &gen)
        }
        _ => unreachable!(),
    };
    trainer.log.save_csv(&trainer.csv_path())?;
    trainer.save_checkpoint(&trainer.ckpt_path())?;
    println!(
        "{method}: loss {:.3} -> {:.3} | eval loss {eval_loss:.3} | token \
         acc {token_acc:.3} | exact-match {:.1}% | {:.1}s ({:.2} s/step)",
        trainer.log.first_loss(),
        trainer.log.recent_loss(10),
        100.0 * em,
        train_time,
        train_time / steps as f64
    );
    println!("loss curve: {}", trainer.csv_path().display());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.usize("steps", 200);
    let preset = args.str("preset", "e2e-lm");
    let lr = args.f64("lr", 1e-3);
    let rt = Runtime::cpu()?;
    let reg = Registry::open_default()?;

    if args.bool("compare") {
        for m in ["cosa", "lora"] {
            run_one(&rt, &reg, &preset, m, steps, lr)?;
        }
    } else {
        let method = args.str("method", "cosa");
        run_one(&rt, &reg, &preset, &method, steps, lr)?;
    }
    println!("\nfinetune_math OK");
    Ok(())
}
