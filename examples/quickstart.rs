//! Quickstart: load the tiny CoSA artifact, fine-tune on synthetic math
//! for a handful of steps, and evaluate.
//!
//!     make artifacts && cargo run --release --example quickstart

use cosa::config::{RunConfig, Schedule, TrainConfig};
use cosa::runtime::executor::Runtime;
use cosa::runtime::Registry;
use cosa::train::Trainer;

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig {
        name: "quickstart".into(),
        artifact: "tiny-lm_cosa".into(),
        task: "math".into(),
        train: TrainConfig {
            steps: 40,
            lr: 3e-3,
            weight_decay: 0.01,
            clip_norm: 1.0,
            schedule: Schedule::CosineWarmup { warmup_frac: 0.1 },
            eval_every: 20,
            log_every: 5,
            grad_accum: 1,
        },
        ..RunConfig::default()
    };

    let rt = Runtime::cpu()?;
    let reg = Registry::open_default()?;
    println!("platform: {} ({} devices)", rt.client.platform_name(),
             rt.client.device_count());

    let mut trainer = Trainer::new(&rt, &reg, cfg)?;
    let meta = trainer.train_exec.meta.clone();
    println!(
        "model: d={} layers={} | method={} (a={}, b={}) | {} trainable params",
        meta.model.d_model, meta.model.n_layers, meta.method.method,
        meta.method.a, meta.method.b, meta.trainable_param_count()
    );

    trainer.run()?;
    let (eval_loss, token_acc) = trainer.evaluate()?;
    let first = trainer.log.first_loss();
    let last = trainer.log.recent_loss(5);
    println!("\ntrain loss: {first:.3} -> {last:.3}");
    println!("eval: loss {eval_loss:.3}, token accuracy {token_acc:.3}");
    trainer.log.save_csv(&trainer.csv_path())?;
    trainer.save_checkpoint(&trainer.ckpt_path())?;
    println!("wrote {} and {}", trainer.csv_path().display(),
             trainer.ckpt_path().display());
    anyhow::ensure!(last < first, "loss did not decrease");
    println!("quickstart OK");
    Ok(())
}
