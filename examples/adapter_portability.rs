//! Adapter portability — the paper's storage claim (§4.1): after
//! training, only the core Y and a seed are stored; L and R regenerate
//! bit-identically, so a reloaded adapter reproduces the trained model's
//! outputs exactly.
//!
//! Flow: train → checkpoint (Y + seed) → fresh Trainer (re-inits
//! everything from seeds) → load checkpoint → verify eval losses and
//! logits match to the bit.

use cosa::config::{RunConfig, Schedule, TrainConfig};
use cosa::runtime::executor::Runtime;
use cosa::runtime::Registry;
use cosa::train::checkpoint::Checkpoint;
use cosa::train::Trainer;

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig {
        name: "portability".into(),
        artifact: "tiny-lm_cosa".into(),
        task: "math".into(),
        train: TrainConfig {
            steps: 25,
            lr: 3e-3,
            weight_decay: 0.01,
            clip_norm: 1.0,
            schedule: Schedule::Constant,
            eval_every: 0,
            log_every: 0,
            grad_accum: 1,
        },
        ..RunConfig::default()
    };
    let rt = Runtime::cpu()?;
    let reg = Registry::open_default()?;

    // 1. train and checkpoint
    let mut t1 = Trainer::new(&rt, &reg, cfg.clone())?;
    t1.run()?;
    let (loss1, metric1) = t1.evaluate()?;
    let path = std::path::Path::new("runs/portability.ckpt");
    t1.save_checkpoint(path)?;
    let ck = Checkpoint::load(path)?;
    let core_params: usize =
        ck.tensors.values().map(|(_, v)| v.len()).sum();
    println!("stored adapter: {} cores, {} params, {} bytes on disk \
              (+ seed {})",
             ck.tensors.len(), core_params,
             std::fs::metadata(path)?.len(), ck.adapter_seed);

    // 2. fresh trainer — same seeds, pristine state (Y = 0)
    let mut t2 = Trainer::new(&rt, &reg, cfg.clone())?;
    let (loss_pristine, _) = t2.evaluate()?;

    // 3. load the adapter: projections come from the seed, Y from disk
    t2.load_checkpoint(&ck)?;
    let (loss2, metric2) = t2.evaluate()?;

    println!("eval loss  trained {loss1:.6} | pristine {loss_pristine:.6} \
              | reloaded {loss2:.6}");
    println!("metric     trained {metric1:.6} | reloaded {metric2:.6}");
    anyhow::ensure!((loss1 - loss2).abs() < 1e-6,
                    "reloaded adapter diverges: {loss1} vs {loss2}");
    anyhow::ensure!((loss_pristine - loss1).abs() > 1e-4,
                    "training had no effect; portability check is vacuous");

    // 4. cross-check the regenerated projections against the live state
    let meta = &t2.train_exec.meta;
    let spec = meta
        .inputs_with_role("frozen")
        .into_iter()
        .find(|s| s.name.ends_with(".l"))
        .expect("cosa artifact has L projections")
        .clone();
    let live = t2.state.read(&spec.name)?;
    let regen = cosa::adapters::cosa::regen_l(
        ck.adapter_seed, &spec.name, spec.shape[0], spec.shape[1]);
    anyhow::ensure!(live == regen.data,
                    "L projection is not bit-identical after regen");
    println!("projection `{}` regenerated bit-identically from seed", spec.name);
    println!("adapter_portability OK");
    Ok(())
}
