//! One GLUE-sim task end-to-end across three methods — a fast taste of
//! the Table 2 comparison (full table: `cosa-repro exp table2`).
//!
//!     cargo run --release --example glue_sim [-- --task mrpc-sim --steps 60]

use cosa::exp::harness::{exp_train_cfg, method_lr, run_scored, LmScore};
use cosa::runtime::executor::Runtime;
use cosa::runtime::Registry;
use cosa::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let task = args.str("task", "mrpc-sim");
    let steps = args.usize("steps", 60);
    let preset = if task == "stsb-sim" { "small-reg" } else { "small-cls" };

    let rt = Runtime::cpu()?;
    let reg = Registry::open_default()?;
    println!("GLUE-sim task `{task}` ({} metric), {steps} steps\n",
             cosa::data::nlu::metric_for(&task));

    for method in ["lora", "vera", "cosa"] {
        let tcfg = exp_train_cfg(steps, method_lr(method, 2e-3));
        let r = run_scored(&rt, &reg, &format!("{preset}_{method}"),
                           &format!("nlu:{task}"), &tcfg, 0,
                           LmScore::ExactInt, 0)?;
        println!(
            "{method:8}  params {:>8}   loss {:.3} -> {:.3}   metric {:.2}",
            r.trainable_params, r.train_loss_first, r.train_loss_last,
            100.0 * r.metric
        );
    }
    println!("\nglue_sim OK");
    Ok(())
}
