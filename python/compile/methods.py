"""Adapter method definitions: parameter specs + forward application.

Implements CoSA plus every baseline the paper compares against, as pure
functions over a flat ``{name: array}`` parameter dict:

* ``full``     — full fine-tuning (whole trunk trainable)
* ``lora``     — ΔW = (α/r)·A B                       (Hu et al. 2022)
* ``pissa``    — LoRA graph; SVD-based init + residual W0 happen in rust
* ``dora``     — magnitude/direction decomposition     (Liu et al. 2024b)
* ``vera``     — shared frozen A/B + trainable scaling vectors (Kopiczko 2023)
* ``adalora``  — P·diag(λ⊙mask)·Q with a rust-driven rank-budget mask
* ``nola``     — linear combination of frozen random low-rank bases
* ``cosa``     — ΔW = α·L Y R via the fused Pallas kernel (the paper)

Naming convention (mirrored by rust/src/runtime/artifact.rs):
  trunk:    embed, pos, lyr{i}.{ln1.s,ln1.b,wq,wk,wv,wo,ln2.s,ln2.b,w1,w2},
            lnf.s, lnf.b, head.w[, head.b]
  adapters: adp.{i}.{site}.{tensor}      site ∈ {wq, wv, w1, w2}
  shared:   vera.{ni}x{no}.{a,b}, nola.{ni}x{no}.{abank,bbank}
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.cosa_kernel import cosa_adapter_3d

# Sites adapted by every PEFT method (attention q/v + both MLP projections),
# with (input_dim, output_dim) expressed in units of (d_model, d_ff).
ADAPTED_SITES = ["wq", "wv", "w1", "w2"]


def site_dims(site: str, d: int, ff: int):
    return {"wq": (d, d), "wv": (d, d), "w1": (d, ff), "w2": (ff, d)}[site]


class SpecBuilder:
    """Collects ordered (name, role, shape, dtype) input specs."""

    def __init__(self):
        self.entries = []  # list of dicts
        self._seen = set()

    def add(self, name, role, shape, dtype="f32"):
        if name in self._seen:
            return
        self._seen.add(name)
        self.entries.append(
            {"name": name, "role": role, "shape": list(shape), "dtype": dtype})

    def by_role(self, role):
        return [e for e in self.entries if e["role"] == role]


def build_param_specs(mcfg: dict, meth: dict) -> SpecBuilder:
    """Full input spec (trunk + adapters + batch) for one model × method."""
    sb = SpecBuilder()
    d, ff, v, t = mcfg["d_model"], mcfg["d_ff"], mcfg["vocab"], mcfg["max_seq"]
    nl, head, ncls, bsz = (mcfg["n_layers"], mcfg["head"],
                           mcfg["n_classes"], mcfg["batch"])
    method = meth["method"]
    trunk_role = "trainable" if method == "full" else "frozen"

    # --- trunk ---
    sb.add("embed", trunk_role, (v, d))
    sb.add("pos", trunk_role, (t, d))
    for i in range(nl):
        p = f"lyr{i}."
        sb.add(p + "ln1.s", trunk_role, (d,))
        sb.add(p + "ln1.b", trunk_role, (d,))
        for w in ["wq", "wk", "wv", "wo"]:
            sb.add(p + w, trunk_role, (d, d))
        sb.add(p + "ln2.s", trunk_role, (d,))
        sb.add(p + "ln2.b", trunk_role, (d,))
        sb.add(p + "w1", trunk_role, (d, ff))
        sb.add(p + "w2", trunk_role, (ff, d))
    sb.add("lnf.s", trunk_role, (d,))
    sb.add("lnf.b", trunk_role, (d,))
    # Classification/regression heads are always trained (PEFT convention);
    # the tied-ish LM head stays frozen unless full FT.
    head_role = "trainable" if (method == "full" or head != "lm") else "frozen"
    if head == "lm":
        sb.add("head.w", head_role, (d, v))
    else:
        sb.add("head.w", head_role, (d, ncls))
        sb.add("head.b", head_role, (ncls,))

    # --- adapters ---
    r, a, b, k = meth.get("r", 8), meth.get("a", 64), meth.get("b", 32), \
        meth.get("nola_k", 32)
    if method != "full":
        for i in range(nl):
            for s in ADAPTED_SITES:
                ni, no = site_dims(s, d, ff)
                p = f"adp.{i}.{s}."
                if method in ("lora", "pissa"):
                    sb.add(p + "a", "trainable", (ni, r))
                    sb.add(p + "b", "trainable", (r, no))
                elif method == "dora":
                    sb.add(p + "a", "trainable", (ni, r))
                    sb.add(p + "b", "trainable", (r, no))
                    sb.add(p + "mag", "trainable", (no,))
                elif method == "vera":
                    sb.add(f"vera.{ni}x{no}.a", "frozen", (ni, r))
                    sb.add(f"vera.{ni}x{no}.b", "frozen", (r, no))
                    sb.add(p + "dvec", "trainable", (r,))
                    sb.add(p + "bvec", "trainable", (no,))
                elif method == "adalora":
                    sb.add(p + "p", "trainable", (ni, r))
                    sb.add(p + "lam", "trainable", (r,))
                    sb.add(p + "q", "trainable", (r, no))
                    sb.add(p + "mask", "frozen", (r,))
                elif method == "nola":
                    sb.add(f"nola.{ni}x{no}.abank", "frozen", (k, ni, r))
                    sb.add(f"nola.{ni}x{no}.bbank", "frozen", (k, r, no))
                    sb.add(p + "ca", "trainable", (k,))
                    sb.add(p + "cb", "trainable", (k,))
                elif method == "cosa":
                    sb.add(p + "l", "frozen", (no, a))
                    sb.add(p + "r", "frozen", (b, ni))
                    sb.add(p + "y", "trainable", (a, b))
                else:
                    raise ValueError(f"unknown method {method}")

    # --- batch ---
    seq = mcfg["max_seq"]
    sb.add("inputs", "batch", (bsz, seq), "i32")
    sb.add("wmask", "batch", (bsz, seq))
    if head == "lm":
        sb.add("targets", "batch", (bsz, seq), "i32")
    elif head == "cls":
        sb.add("labels", "batch", (bsz,), "i32")
    else:
        sb.add("labels", "batch", (bsz,))
    return sb


def adapted_matmul(p: dict, meth: dict, layer: int, site: str,
                   x: jnp.ndarray) -> jnp.ndarray:
    """``x @ W_eff`` for one adapted site; x is (B, T, ni) → (B, T, no).

    Where the method permits, the update is applied on the *activation*
    path (never materializing ΔW) — for CoSA this is the fused L1 kernel.
    """
    method = meth["method"]
    w0 = p[f"lyr{layer}.{site}"]
    if method == "full":
        return x @ w0
    base = x @ w0
    pre = f"adp.{layer}.{site}."
    alpha, r = meth.get("alpha", 2.0), meth.get("r", 8)
    if method in ("lora", "pissa"):
        return base + (alpha / r) * ((x @ p[pre + "a"]) @ p[pre + "b"])
    if method == "dora":
        dirn = w0 + (alpha / r) * (p[pre + "a"] @ p[pre + "b"])
        col = jnp.sqrt(jnp.sum(dirn * dirn, axis=0, keepdims=True) + 1e-6)
        return x @ (p[pre + "mag"][None, :] * dirn / col)
    if method == "vera":
        ni, no = w0.shape
        av, bv = p[f"vera.{ni}x{no}.a"], p[f"vera.{ni}x{no}.b"]
        return base + alpha * (((x @ av) * p[pre + "dvec"]) @ bv) \
            * p[pre + "bvec"]
    if method == "adalora":
        lam = p[pre + "lam"] * p[pre + "mask"]
        return base + (alpha / r) * (((x @ p[pre + "p"]) * lam) @ p[pre + "q"])
    if method == "nola":
        ni, no = w0.shape
        am = jnp.einsum("k,kir->ir", p[pre + "ca"],
                        p[f"nola.{ni}x{no}.abank"])
        bm = jnp.einsum("k,kro->ro", p[pre + "cb"],
                        p[f"nola.{ni}x{no}.bbank"])
        return base + (alpha / r) * ((x @ am) @ bm)
    if method == "cosa":
        return base + cosa_adapter_3d(x, p[pre + "l"], p[pre + "r"],
                                      p[pre + "y"], scale=alpha)
    raise ValueError(f"unknown method {method}")
