"""AOT pipeline: lower every (preset × method × kind) step to HLO text.

Interchange format is HLO **text**, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per artifact ``<name>_<kind>``:
  artifacts/<name>_<kind>.hlo.txt   — the lowered module
  artifacts/<name>_<kind>.json      — ordered input/output specs + configs
plus one ``artifacts/manifest.json`` indexing everything for the rust L3.

Usage:  python -m compile.aot [--out-dir ../artifacts] [--jobs N]
        [--only SUBSTR]        (artifact-name filter, for iteration)
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from concurrent.futures import ProcessPoolExecutor

import jax

from . import model, presets

KINDS = ["train", "eval"]


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_one(job):
    """Lower one artifact; runs in a worker process."""
    name, preset, meth, kind, out_dir = job
    t0 = time.time()
    mcfg = presets.MODEL_PRESETS[preset]
    graph_method = presets.GRAPH_ALIAS.get(meth["method"], meth["method"])
    gmeth = dict(meth, method=graph_method)

    step = model.make_step(mcfg, gmeth, kind)
    specs = model.input_shapedtypes(mcfg, gmeth, kind)
    lowered = jax.jit(step).lower(*specs)
    text = to_hlo_text(lowered)

    ins, outs = model.io_spec(mcfg, gmeth, kind)
    meta = {
        "name": f"{name}_{kind}",
        "preset": preset,
        "kind": kind,
        "model": mcfg,
        "method": meth,
        "graph_method": graph_method,
        "inputs": ins,
        "outputs": outs,
    }
    base = os.path.join(out_dir, f"{name}_{kind}")
    with open(base + ".hlo.txt", "w") as f:
        f.write(text)
    with open(base + ".json", "w") as f:
        json.dump(meta, f, indent=1)
    return f"{name}_{kind}", len(text), time.time() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(legacy) ignored marker file")
    ap.add_argument("--jobs", type=int, default=min(8, os.cpu_count() or 1))
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    jobs = []
    entries = presets.artifact_set()
    for name, preset, meth in entries:
        for kind in KINDS:
            if args.only and args.only not in f"{name}_{kind}":
                continue
            jobs.append((name, preset, meth, kind, args.out_dir))

    print(f"lowering {len(jobs)} artifacts with {args.jobs} workers",
          file=sys.stderr)
    t0 = time.time()
    results = []
    if args.jobs <= 1:
        for j in jobs:
            results.append(lower_one(j))
            print(f"  {results[-1][0]}  {results[-1][1]} chars "
                  f"{results[-1][2]:.1f}s", file=sys.stderr)
    else:
        with ProcessPoolExecutor(max_workers=args.jobs) as pool:
            for res in pool.map(lower_one, jobs):
                results.append(res)
                print(f"  {res[0]}  {res[1]} chars {res[2]:.1f}s",
                      file=sys.stderr)

    manifest = {
        "artifacts": [r[0] for r in results],
        "entries": [
            {"name": name, "preset": preset, "method": meth,
             "kinds": KINDS}
            for name, preset, meth in entries
            if not args.only or any(args.only in f"{name}_{k}" for k in KINDS)
        ],
        "model_presets": presets.MODEL_PRESETS,
        "adapted_sites": ["wq", "wv", "w1", "w2"],
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"done: {len(results)} artifacts in {time.time() - t0:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
