"""§Perf analysis for L1/L2 (DESIGN.md / EXPERIMENTS.md §Perf).

L1 — Pallas kernel: VMEM working set + MXU utilization *estimates* per
block configuration (interpret=True wallclock is CPU-numpy, not a TPU
proxy; we optimize structure, not timing).

L2 — lowered HLO: op-census of each artifact (fusion opportunities,
redundant recompute check, graph size), the basis for the scan-vs-unroll
and donation decisions.

Usage:  python -m compile.perf_analysis [--artifacts ../artifacts]
"""

from __future__ import annotations

import argparse
import os
import re
from collections import Counter

from .kernels.cosa_kernel import mxu_utilization_estimate, vmem_bytes


def l1_report():
    print("== L1 (Pallas kernel): VMEM footprint / MXU utilization "
          "estimates ==")
    print(f"{'preset':<26} {'block':>6} {'VMEM':>10} {'MXU util':>9}")
    # (label, n, b, a, m) — the shipped adapter shapes
    shapes = [
        ("tiny  d=64   (a32,b16)", 64, 16, 32, 64),
        ("small d=128  (a64,b32)", 128, 32, 64, 128),
        ("small ff=256 (a64,b32)", 256, 32, 64, 128),
        ("e2e   d=512  (a128,b64)", 512, 64, 128, 512),
        ("e2e   ff=2048 in", 512, 64, 128, 2048),
        ("e2e   ff=2048 out", 2048, 64, 128, 512),
        ("paper d=4096 (a1024,b256)", 4096, 256, 1024, 4096),
    ]
    for label, n, b, a, m in shapes:
        for bm in (128,):
            v = vmem_bytes(bm, n, b, a, m)
            u = mxu_utilization_estimate(bm, n, b, a, m)
            flag = "" if v < 16 * 2**20 else "  EXCEEDS 16MiB"
            print(f"{label:<26} {bm:>6} {v/2**20:>9.2f}M {u:>9.2f}{flag}")
    print("\nblock-rows sweep at the e2e shape (n=512,b=64,a=128,m=512):")
    for bm in (32, 64, 128, 256, 512):
        v = vmem_bytes(bm, 512, 64, 128, 512)
        u = mxu_utilization_estimate(bm, 512, 64, 128, 512)
        print(f"  bm={bm:<4}  VMEM {v/2**20:6.2f}M   MXU-util {u:.2f}")


OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\],{}/ ]*?\s*"
                   r"([a-z][a-z0-9\-]*)\(")


def census(path):
    ops = Counter()
    with open(path) as f:
        for line in f:
            m = OP_RE.match(line)
            if m:
                ops[m.group(1)] += 1
    return ops


def l2_report(artifacts_dir):
    print("\n== L2 (lowered HLO): op census per artifact ==")
    interesting = ["tiny-lm_cosa_train", "small-lm_cosa_train",
                   "small-lm_lora_train", "small-lm_full_train",
                   "e2e-lm_cosa_train"]
    print(f"{'artifact':<24} {'total':>7} {'dot':>5} {'fusion':>7} "
          f"{'transpose':>9} {'reduce':>7} {'bytes':>9}")
    for name in interesting:
        path = os.path.join(artifacts_dir, f"{name}.hlo.txt")
        if not os.path.exists(path):
            continue
        ops = census(path)
        total = sum(ops.values())
        size = os.path.getsize(path)
        print(f"{name:<24} {total:>7} {ops.get('dot', 0):>5} "
              f"{ops.get('fusion', 0):>7} {ops.get('transpose', 0):>9} "
              f"{ops.get('reduce', 0):>7} {size:>9}")
    print("\nredundant-recompute check: dot count per layer should be "
          "~constant across methods modulo the adapter branch (3 dots for "
          "CoSA fwd, +3 bwd).")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()
    l1_report()
    l2_report(args.artifacts)


if __name__ == "__main__":
    main()
