"""L1: fused Pallas kernel for the CoSA adapter branch  o = L(Y(R·x)).

Hardware adaptation (paper targets CUDA GPUs; we target the TPU model that
Pallas exposes, validated on CPU via ``interpret=True``):

* The adapter chain ``x(n) → u=Rx(b) → v=Yu(a) → o=Lv(m)`` is fused into a
  single kernel so the intermediates ``u`` and ``v`` never round-trip
  through HBM — the paper's "never materialize ΔW (m×n)" memory argument
  carried through to activations.
* The grid tiles the flattened ``(B·T, n)`` activation rows; ``R``, ``Y``
  and ``L`` are pinned in VMEM for every row-tile (their BlockSpec index
  maps are constant), so each weight byte is read from HBM once per grid
  pass instead of once per row, raising arithmetic intensity to
  ``b(n+a) + am`` FLOPs per activation row.
* On a real MXU the three dots run as 128×128 bf16 systolic tiles; the
  default row tile (128) matches the MXU/VREG lane width.  VMEM footprint
  per tile is ``(bm·n + b·n + a·b + m·a + bm·m)·4`` bytes — see
  ``vmem_bytes`` below; presets keep it well under the 16 MiB budget.

``interpret=True`` is mandatory here: real TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute.  Numerics are
identical between the two paths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default number of activation rows processed by one grid step.  128 matches
# the MXU systolic tile; bench/perf notes in EXPERIMENTS.md §Perf.
DEFAULT_BLOCK_ROWS = 128


def vmem_bytes(block_rows: int, n: int, b: int, a: int, m: int,
               itemsize: int = 4) -> int:
    """Per-grid-step VMEM working set of the fused kernel, in bytes."""
    return itemsize * (block_rows * n    # x tile
                       + b * n           # R
                       + a * b           # Y
                       + m * a           # L
                       + block_rows * b  # u scratch
                       + block_rows * a  # v scratch
                       + block_rows * m) # o tile


def mxu_utilization_estimate(block_rows: int, n: int, b: int, a: int,
                             m: int) -> float:
    """Fraction of MXU-issue slots doing useful work for one row tile.

    Each of the three dots is padded to 128-multiples on the MXU; the
    estimate is useful-FLOPs / padded-FLOPs.  Used by DESIGN.md §Perf to
    pick (a, b) tile-friendly presets (multiples of 128 score 1.0).
    """
    def pad(v):
        return ((v + 127) // 128) * 128

    useful = block_rows * (2 * n * b + 2 * b * a + 2 * a * m)
    padded = pad(block_rows) * (2 * pad(n) * pad(b) + 2 * pad(b) * pad(a)
                                + 2 * pad(a) * pad(m))
    return useful / padded


def _cosa_kernel(x_ref, r_ref, y_ref, l_ref, o_ref):
    """One grid step: rows tile of x → rows tile of o.

    All three weight refs hold the full (small) matrices; only x/o are
    tiled.  Accumulation dtype is f32 regardless of input dtype.
    """
    x = x_ref[...]
    u = jnp.dot(x, r_ref[...].T, preferred_element_type=jnp.float32)
    v = jnp.dot(u, y_ref[...].T, preferred_element_type=jnp.float32)
    o = jnp.dot(v, l_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] = o.astype(o_ref.dtype)


def _cosa_kernel_mtiled(x_ref, r_ref, y_ref, l_ref, o_ref):
    """2-D grid variant: (row tile i, output-column tile j).

    §Perf L1 finding: at paper scale (m=n=4096, a=1024) the full L
    (m×a ≈ 16 MiB) blows the VMEM budget; tiling L's rows (the adapter's
    output dim m) brings the per-step working set under budget.  u and v
    are recomputed per j-tile — b·(n+a) FLOPs per row, negligible next to
    the a·m reconstruction — trading a little compute for HBM locality,
    the same trade the paper's threadblock scheme makes on GPUs.
    """
    x = x_ref[...]
    u = jnp.dot(x, r_ref[...].T, preferred_element_type=jnp.float32)
    v = jnp.dot(u, y_ref[...].T, preferred_element_type=jnp.float32)
    o = jnp.dot(v, l_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] = o.astype(o_ref.dtype)


def _pallas_forward(x, l, r, y, *, block_rows: int,
                    block_m: int | None = None) -> jnp.ndarray:
    """Invoke the fused kernel on ``(N, n)`` activations, padding N.

    ``block_m`` (optional) additionally tiles the output dimension m —
    required once ``m·a`` itself exceeds VMEM (paper-scale sites); see
    ``_cosa_kernel_mtiled``.
    """
    nrows, n = x.shape
    m, a = l.shape
    b, n2 = r.shape
    assert n == n2 and y.shape == (a, b), (x.shape, l.shape, r.shape, y.shape)

    bm = min(block_rows, max(8, nrows))
    padded = ((nrows + bm - 1) // bm) * bm
    if padded != nrows:
        x = jnp.pad(x, ((0, padded - nrows), (0, 0)))

    if block_m is None or block_m >= m:
        out = pl.pallas_call(
            _cosa_kernel,
            grid=(padded // bm,),
            in_specs=[
                pl.BlockSpec((bm, n), lambda i: (i, 0)),
                pl.BlockSpec((b, n), lambda i: (0, 0)),
                pl.BlockSpec((a, b), lambda i: (0, 0)),
                pl.BlockSpec((m, a), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((bm, m), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((padded, m), x.dtype),
            interpret=True,  # Mosaic custom-calls can't run on CPU PJRT
        )(x, r, y, l)
        return out[:nrows]

    # 2-D grid: pad m to a multiple of block_m and tile L's rows.
    bmm = block_m
    padded_m = ((m + bmm - 1) // bmm) * bmm
    l_p = jnp.pad(l, ((0, padded_m - m), (0, 0))) if padded_m != m else l
    out = pl.pallas_call(
        _cosa_kernel_mtiled,
        grid=(padded // bm, padded_m // bmm),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i, j: (i, 0)),
            pl.BlockSpec((b, n), lambda i, j: (0, 0)),
            pl.BlockSpec((a, b), lambda i, j: (0, 0)),
            pl.BlockSpec((bmm, a), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bmm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((padded, padded_m), x.dtype),
        interpret=True,
    )(x, r, y, l_p)
    return out[:nrows, :m]


def vmem_bytes_mtiled(block_rows: int, block_m: int, n: int, b: int,
                      a: int, itemsize: int = 4) -> int:
    """Working set of the m-tiled kernel (paper-scale path)."""
    return itemsize * (block_rows * n + b * n + a * b + block_m * a
                       + block_rows * b + block_rows * a
                       + block_rows * block_m)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def cosa_adapter(x, l, r, y, block_rows=DEFAULT_BLOCK_ROWS):
    """Fused CoSA adapter forward ``o = x Rᵀ Yᵀ Lᵀ`` with analytic VJP.

    The VJP implements the paper's Eq. 10: ``∇Y = (Lᵀ g)(R x)ᵀ`` and routes
    the activation cotangent ``∇x = ((g L) Y) R`` so gradients flow to
    earlier layers.  L and R are frozen — their cotangents are zero.
    """
    return _pallas_forward(x, l, r, y, block_rows=block_rows)


def _cosa_fwd(x, l, r, y, block_rows):
    return _pallas_forward(x, l, r, y, block_rows=block_rows), (x, l, r, y)


def _cosa_bwd(block_rows, res, g):
    x, l, r, y = res
    gv = g @ l                 # (N, a)
    u = x @ r.T                # (N, b) recomputed — cheaper than storing
    dy = gv.T @ u              # (a, b)  paper Eq. 10
    dx = (gv @ y) @ r          # (N, n)
    return dx, jnp.zeros_like(l), jnp.zeros_like(r), dy


cosa_adapter.defvjp(_cosa_fwd, _cosa_bwd)


def cosa_adapter_3d(x, l, r, y, scale: float = 1.0,
                    block_rows: int = DEFAULT_BLOCK_ROWS):
    """Apply the adapter to ``(B, T, n)`` activations, returning (B, T, m)."""
    bsz, t, n = x.shape
    out = cosa_adapter(x.reshape(bsz * t, n), l, r, y, block_rows)
    return scale * out.reshape(bsz, t, -1)
