"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here, written
with plain ``jnp`` ops only (no pallas, no custom_vjp).  pytest compares the
kernel output (and its VJP) against these oracles; hypothesis sweeps shapes
and dtypes.  These are also the semantic definition mirrored by the
rust-side property tests (``rust/src/adapters/cosa.rs``).
"""

from __future__ import annotations

import jax.numpy as jnp


def cosa_adapter_ref(x: jnp.ndarray, l: jnp.ndarray, r: jnp.ndarray,
                     y: jnp.ndarray) -> jnp.ndarray:
    """CoSA adapter branch  o = L (Y (R x))  in row-vector convention.

    Args:
      x: ``(N, n)`` activations (rows are flattened batch*time positions).
      l: ``(m, a)`` fixed Gaussian output projection.
      r: ``(b, n)`` fixed Gaussian input projection.
      y: ``(a, b)`` trainable core.

    Returns:
      ``(N, m)`` adapter output ``ΔW x`` with ``ΔW = L Y R``.
    """
    u = x @ r.T          # (N, b)   input compression
    v = u @ y.T          # (N, a)   core transformation
    return v @ l.T       # (N, m)   output reconstruction


def cosa_adapter_vjp_ref(x, l, r, y, g):
    """Analytic VJP of the adapter (paper Eq. 10 generalized to batches).

    Returns ``(dx, dY)`` — cotangents for the activation and the core.
    L and R are frozen so their cotangents are identically zero.
    """
    gv = g @ l           # (N, a)
    u = x @ r.T          # (N, b)
    dy = gv.T @ u        # (a, b)  == (L^T g)(R x)^T summed over rows
    dx = (gv @ y) @ r    # (N, n)
    return dx, dy


def lora_delta_ref(a: jnp.ndarray, b: jnp.ndarray, scale: float) -> jnp.ndarray:
    """LoRA update  ΔW = scale · A B  with A ``(in, r)``, B ``(r, out)``."""
    return scale * (a @ b)


def cosa_delta_ref(l: jnp.ndarray, y: jnp.ndarray, r: jnp.ndarray,
                   scale: float) -> jnp.ndarray:
    """Materialized CoSA update ΔW = scale · L Y R, shape ``(m, n)``.

    Only used by tests — the runtime never materializes ΔW (that is the
    point of the method); it applies the three matmuls to activations.
    """
    return scale * (l @ y @ r)
