"""L2: transformer forward/loss + AdamW train step for every PEFT method.

A pre-LN transformer (MHA + GELU MLP) with the adapter methods of
``methods.py`` applied to the q/v attention projections and both MLP
projections — the sites the paper adapts.  The same trunk serves three
heads: causal LM (``lm``), mean-pooled classification (``cls``) and scalar
regression (``reg``, STS-B analogue).

Everything here is *build-time only*: ``aot.py`` lowers ``make_step`` once
per (preset × method × kind) to HLO text; the rust L3 executes the
artifacts and owns schedules, data order, seeding and checkpoints.

Backward passes come from ``jax.grad`` — except the CoSA adapter branch,
whose VJP is the paper's analytic Eq. 10 inside ``kernels/cosa_kernel.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import methods
from .methods import adapted_matmul, build_param_specs

# AdamW constants (paper App. C; β2=0.999 everywhere but the full-FT
# MetaMath runs — rust selects clip/wd per config instead).
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def _layernorm(x, s, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * s + b


def _attention(p, meth, i, x, attn_bias, n_heads):
    bsz, t, d = x.shape
    hd = d // n_heads

    def split(h):
        return h.reshape(bsz, t, n_heads, hd).transpose(0, 2, 1, 3)

    q = split(adapted_matmul(p, meth, i, "wq", x))
    k = split(x @ p[f"lyr{i}.wk"])
    v = split(adapted_matmul(p, meth, i, "wv", x))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    scores = scores + attn_bias  # (B, 1, T, T) additive mask
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(bsz, t, d)
    return ctx @ p[f"lyr{i}.wo"]


def forward(p: dict, mcfg: dict, meth: dict, inputs, wmask):
    """Token ids (B, T) → logits: (B, T, V) for lm, (B, n_classes) else."""
    nl, nh, head = mcfg["n_layers"], mcfg["n_heads"], mcfg["head"]
    bsz, t = inputs.shape
    x = jnp.take(p["embed"], inputs, axis=0) + p["pos"][None, :t, :]

    # Additive attention bias: padding mask always; causal for the LM head.
    pad = (wmask[:, None, None, :] - 1.0) * 1e9  # 0 where valid, -1e9 where pad
    if head == "lm":
        causal = jnp.tril(jnp.ones((t, t), dtype=x.dtype))
        bias = pad + (causal[None, None, :, :] - 1.0) * 1e9
    else:
        bias = pad

    for i in range(nl):
        h = _layernorm(x, p[f"lyr{i}.ln1.s"], p[f"lyr{i}.ln1.b"])
        x = x + _attention(p, meth, i, h, bias, nh)
        h = _layernorm(x, p[f"lyr{i}.ln2.s"], p[f"lyr{i}.ln2.b"])
        h = jax.nn.gelu(adapted_matmul(p, meth, i, "w1", h))
        x = x + adapted_matmul(p, meth, i, "w2", h)

    x = _layernorm(x, p["lnf.s"], p["lnf.b"])
    if head == "lm":
        return x @ p["head.w"]
    pooled = jnp.sum(x * wmask[:, :, None], axis=1) \
        / jnp.maximum(jnp.sum(wmask, axis=1, keepdims=True), 1.0)
    out = pooled @ p["head.w"] + p["head.b"]
    return out


def loss_and_metrics(p, mcfg, meth, batch):
    """Returns (loss, accuracy, logits) for the preset's head type."""
    head = mcfg["head"]
    logits = forward(p, mcfg, meth, batch["inputs"], batch["wmask"])
    if head == "lm":
        tgt, w = batch["targets"], batch["wmask"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(jnp.sum(w), 1.0)
        loss = jnp.sum(nll * w) / denom
        acc = jnp.sum((jnp.argmax(logits, -1) == tgt) * w) / denom
    elif head == "cls":
        lab = batch["labels"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(jnp.take_along_axis(logp, lab[:, None], axis=-1))
        acc = jnp.mean((jnp.argmax(logits, -1) == lab).astype(jnp.float32))
    else:  # regression
        pred = logits[:, 0]
        loss = jnp.mean((pred - batch["labels"]) ** 2)
        acc = -loss  # placeholder; rust computes Pearson/Spearman from logits
    return loss, acc, logits


def _adamw(p, g, m, v, lr, wd, t):
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mh = m / (1.0 - ADAM_B1 ** t)
    vh = v / (1.0 - ADAM_B2 ** t)
    p = p - lr * (mh / (jnp.sqrt(vh) + ADAM_EPS) + wd * p)
    return p, m, v


# ---------------------------------------------------------------------------
# Flat-ABI step builders (the artifact boundary)
# ---------------------------------------------------------------------------

TRAIN_SCALARS = ["lr", "wd", "clip", "t"]


def io_spec(mcfg, meth, kind):
    """Ordered input/output spec dicts for one artifact (→ meta json)."""
    sb = build_param_specs(mcfg, meth)
    trainables = sb.by_role("trainable")
    frozen = sb.by_role("frozen")
    batch = sb.by_role("batch")
    inputs = []
    if kind == "train":
        inputs += [{"name": s, "role": "scalar", "shape": [], "dtype": "f32"}
                   for s in TRAIN_SCALARS]
    inputs += [dict(e, role="trainable") for e in trainables]
    if kind == "train":
        inputs += [dict(e, name="opt_m:" + e["name"], role="opt_m")
                   for e in trainables]
        inputs += [dict(e, name="opt_v:" + e["name"], role="opt_v")
                   for e in trainables]
    inputs += [dict(e, role="frozen") for e in frozen]
    inputs += batch

    head = mcfg["head"]
    if head == "lm":
        lshape = [mcfg["batch"], mcfg["max_seq"], mcfg["vocab"]]
    else:
        lshape = [mcfg["batch"], mcfg["n_classes"]]
    outputs = [{"name": "loss", "shape": [], "dtype": "f32"},
               {"name": "acc", "shape": [], "dtype": "f32"}]
    if kind == "train":
        outputs += [{"name": "new:" + e["name"], "shape": e["shape"],
                     "dtype": "f32"} for e in trainables]
        outputs += [{"name": "new_m:" + e["name"], "shape": e["shape"],
                     "dtype": "f32"} for e in trainables]
        outputs += [{"name": "new_v:" + e["name"], "shape": e["shape"],
                     "dtype": "f32"} for e in trainables]
    else:
        outputs += [{"name": "logits", "shape": lshape, "dtype": "f32"}]
    return inputs, outputs


def make_step(mcfg, meth, kind):
    """Build the flat-argument step function matching ``io_spec`` order."""
    sb = build_param_specs(mcfg, meth)
    tnames = [e["name"] for e in sb.by_role("trainable")]
    fnames = [e["name"] for e in sb.by_role("frozen")]
    bnames = [e["name"] for e in sb.by_role("batch")]
    nt, nf = len(tnames), len(fnames)

    def unpack(args, kind):
        i = 0
        sc = {}
        if kind == "train":
            for s in TRAIN_SCALARS:
                sc[s] = args[i]
                i += 1
        tr = dict(zip(tnames, args[i:i + nt])); i += nt
        m = v = None
        if kind == "train":
            m = dict(zip(tnames, args[i:i + nt])); i += nt
            v = dict(zip(tnames, args[i:i + nt])); i += nt
        fr = dict(zip(fnames, args[i:i + nf])); i += nf
        batch = dict(zip(bnames, args[i:]))
        return sc, tr, m, v, fr, batch

    if kind == "eval":
        def eval_step(*args):
            _, tr, _, _, fr, batch = unpack(args, "eval")
            loss, acc, logits = loss_and_metrics({**tr, **fr}, mcfg, meth,
                                                 batch)
            return (loss, acc, logits)
        return eval_step

    def train_step(*args):
        sc, tr, m, v, fr, batch = unpack(args, "train")

        def lossfn(tr):
            loss, acc, _ = loss_and_metrics({**tr, **fr}, mcfg, meth, batch)
            return loss, acc

        (loss, acc), grads = jax.value_and_grad(lossfn, has_aux=True)(tr)
        # Global-norm clipping (rust passes clip=1e9 to disable).
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()) + 1e-12)
        scale = jnp.minimum(1.0, sc["clip"] / gnorm)
        new_t, new_m, new_v = [], [], []
        for name in tnames:
            pn, mn, vn = _adamw(tr[name], grads[name] * scale, m[name],
                                v[name], sc["lr"], sc["wd"], sc["t"])
            new_t.append(pn); new_m.append(mn); new_v.append(vn)
        return tuple([loss, acc] + new_t + new_m + new_v)

    return train_step


def input_shapedtypes(mcfg, meth, kind):
    ins, _ = io_spec(mcfg, meth, kind)
    return [jax.ShapeDtypeStruct(tuple(e["shape"]), DTYPES[e["dtype"]])
            for e in ins]


# ---------------------------------------------------------------------------
# Test-only initialization (the runtime inits live in rust/src/adapters/)
# ---------------------------------------------------------------------------

def init_params(mcfg, meth, seed=0):
    """Random init of every spec'd tensor — used by pytest only."""
    sb = build_param_specs(mcfg, meth)
    key = jax.random.PRNGKey(seed)
    out = {}
    for e in sb.entries:
        if e["role"] == "batch":
            continue
        key, sub = jax.random.split(key)
        shape, name = tuple(e["shape"]), e["name"]
        if name.endswith((".y", ".b")) and name.startswith("adp.") \
                or name.endswith((".dvec", ".ca", ".cb", ".lam", ".mag")):
            # Zero-init the "last" adapter factor so ΔW = 0 at step 0
            # (the paper's requirement that training starts at W0).
            val = jnp.zeros(shape)
        elif name.endswith(".mask"):
            val = jnp.ones(shape)
        elif name.endswith((".s",)) and ("ln" in name):
            val = jnp.ones(shape)
        elif name.endswith(".b") and ("ln" in name or "head" in name):
            val = jnp.zeros(shape)
        else:
            fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
            val = jax.random.normal(sub, shape) / jnp.sqrt(float(fan_in))
        out[name] = val
    # DoRA magnitudes start at the column norms of W0 so W_eff == W0.
    if meth["method"] == "dora":
        for i in range(mcfg["n_layers"]):
            for s in methods.ADAPTED_SITES:
                w0 = out[f"lyr{i}.{s}"]
                out[f"adp.{i}.{s}.mag"] = jnp.sqrt(
                    jnp.sum(w0 * w0, axis=0) + 1e-6)
    return out


def init_batch(mcfg, seed=0):
    key = jax.random.PRNGKey(seed + 99)
    bsz, t, v = mcfg["batch"], mcfg["max_seq"], mcfg["vocab"]
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {
        "inputs": jax.random.randint(k1, (bsz, t), 0, v),
        "wmask": jnp.ones((bsz, t)),
    }
    if mcfg["head"] == "lm":
        batch["targets"] = jax.random.randint(k2, (bsz, t), 0, v)
    elif mcfg["head"] == "cls":
        batch["labels"] = jax.random.randint(k3, (bsz,), 0, mcfg["n_classes"])
    else:
        batch["labels"] = jax.random.normal(k3, (mcfg["batch"],))
    return batch
