"""AOT pipeline tests: spec/step consistency, manifest integrity, and the
HLO-text interchange invariants the rust runtime depends on."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, presets

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def artifacts_built():
    return os.path.exists(os.path.join(ARTIFACTS, "manifest.json"))


class TestHloText:
    def test_lowering_produces_parseable_hlo_text(self):
        mcfg = presets.MODEL_PRESETS["tiny-lm"]
        meth = presets.method_cfg("tiny-lm", "cosa")
        step = model.make_step(mcfg, meth, "eval")
        specs = model.input_shapedtypes(mcfg, meth, "eval")
        text = aot.to_hlo_text(jax.jit(step).lower(*specs))
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # jax>=0.5 emits 64-bit ids in serialized protos; text must not
        # (ids are reassigned by the parser) — just assert non-empty body.
        assert len(text) > 1000

    def test_graph_alias_pissa_lowers_lora_graph(self):
        assert presets.GRAPH_ALIAS["pissa"] == "lora"

    def test_train_and_eval_arity_match_iospec(self):
        mcfg = presets.MODEL_PRESETS["tiny-cls"]
        meth = presets.method_cfg("tiny-cls", "lora")
        for kind in ["train", "eval"]:
            ins, outs = model.io_spec(mcfg, meth, kind)
            specs = model.input_shapedtypes(mcfg, meth, kind)
            assert len(ins) == len(specs)
            roles = [e["role"] for e in ins]
            # role ordering contract relied on by the rust executor
            if kind == "train":
                assert roles[:4] == ["scalar"] * 4
            assert roles[-1] == "batch"


@pytest.mark.skipif(not artifacts_built(), reason="run `make artifacts`")
class TestManifest:
    def _manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_every_artifact_has_hlo_and_meta(self):
        man = self._manifest()
        assert len(man["artifacts"]) >= 60
        for name in man["artifacts"]:
            assert os.path.exists(os.path.join(ARTIFACTS, f"{name}.hlo.txt")), name
            assert os.path.exists(os.path.join(ARTIFACTS, f"{name}.json")), name

    def test_meta_specs_match_model_iospec(self):
        man = self._manifest()
        name = "tiny-lm_cosa_train"
        assert name in man["artifacts"]
        with open(os.path.join(ARTIFACTS, f"{name}.json")) as f:
            meta = json.load(f)
        ins, outs = model.io_spec(meta["model"], meta["method"], "train")
        assert meta["inputs"] == ins
        assert meta["outputs"] == outs

    def test_trainable_counts_match_paper_formula(self):
        """CoSA trainables = n_layers * 4 sites * a * b."""
        with open(os.path.join(ARTIFACTS, "small-lm_cosa_train.json")) as f:
            meta = json.load(f)
        tr = [e for e in meta["inputs"] if e["role"] == "trainable"]
        total = sum(int(np.prod(e["shape"])) for e in tr)
        m, mm = meta["method"], meta["model"]
        assert total == mm["n_layers"] * 4 * m["a"] * m["b"]

    def test_pissa_meta_keeps_method_but_aliases_graph(self):
        with open(os.path.join(ARTIFACTS, "small-lm_pissa_train.json")) as f:
            meta = json.load(f)
        assert meta["method"]["method"] == "pissa"
        assert meta["graph_method"] == "lora"


class TestStepNumerics:
    def test_train_step_reduces_loss_over_iterations(self):
        """The exact function rust executes must descend, in python too."""
        mcfg = presets.MODEL_PRESETS["tiny-lm"]
        meth = presets.method_cfg("tiny-lm", "cosa")
        step = jax.jit(model.make_step(mcfg, meth, "train"))
        from compile.methods import build_param_specs
        sb = build_param_specs(mcfg, meth)
        p = model.init_params(mcfg, meth, seed=11)
        batch = model.init_batch(mcfg, seed=11)
        tn = [e["name"] for e in sb.by_role("trainable")]
        fn = [e["name"] for e in sb.by_role("frozen")]
        bn = [e["name"] for e in sb.by_role("batch")]
        tr = [p[n] for n in tn]
        mstate = [jnp.zeros_like(x) for x in tr]
        vstate = [jnp.zeros_like(x) for x in tr]
        losses = []
        for t in range(1, 16):
            out = step(*([jnp.float32(5e-3), jnp.float32(0.0),
                          jnp.float32(1e9), jnp.float32(t)]
                         + tr + mstate + vstate
                         + [p[n] for n in fn] + [batch[n] for n in bn]))
            losses.append(float(out[0]))
            k = len(tr)
            tr = list(out[2:2 + k])
            mstate = list(out[2 + k:2 + 2 * k])
            vstate = list(out[2 + 2 * k:2 + 3 * k])
        assert losses[-1] < losses[0] * 0.9, losses

    def test_gradient_clipping_engages(self):
        """With a tiny clip norm the update is strictly smaller."""
        mcfg = presets.MODEL_PRESETS["tiny-lm"]
        meth = presets.method_cfg("tiny-lm", "cosa")
        step = jax.jit(model.make_step(mcfg, meth, "train"))
        from compile.methods import build_param_specs
        sb = build_param_specs(mcfg, meth)
        p = model.init_params(mcfg, meth, seed=12)
        batch = model.init_batch(mcfg, seed=12)
        tn = [e["name"] for e in sb.by_role("trainable")]
        fn = [e["name"] for e in sb.by_role("frozen")]
        bn = [e["name"] for e in sb.by_role("batch")]

        def one_step(clip):
            tr = [p[n] for n in tn]
            z = [jnp.zeros_like(x) for x in tr]
            out = step(*([jnp.float32(1e-2), jnp.float32(0.0),
                          jnp.float32(clip), jnp.float32(1.0)]
                         + tr + z + [jnp.zeros_like(x) for x in tr]
                         + [p[n] for n in fn] + [batch[n] for n in bn]))
            k = len(tr)
            delta = sum(float(jnp.sum((a - b) ** 2))
                        for a, b in zip(out[2:2 + k], tr))
            return delta

        assert one_step(1e-4) < one_step(1e9)
