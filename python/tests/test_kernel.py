"""L1 correctness: fused Pallas kernel vs the pure-jnp oracle.

Includes hypothesis sweeps over shapes and dtypes, VJP checks against the
paper's analytic gradient (Eq. 10), and numerical-gradient cross-checks.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.cosa_kernel import (cosa_adapter, cosa_adapter_3d,
                                         mxu_utilization_estimate,
                                         vmem_bytes)
from compile.kernels.ref import (cosa_adapter_ref, cosa_adapter_vjp_ref,
                                 cosa_delta_ref)


def _rand(key, *shapes):
    keys = jax.random.split(key, len(shapes))
    return [jax.random.normal(k, s) for k, s in zip(keys, shapes)]


class TestForward:
    def test_matches_ref_basic(self):
        x, l, r, y = _rand(jax.random.PRNGKey(0), (40, 24), (16, 12),
                           (8, 24), (12, 8))
        np.testing.assert_allclose(cosa_adapter(x, l, r, y),
                                   cosa_adapter_ref(x, l, r, y),
                                   rtol=1e-5, atol=1e-5)

    def test_matches_materialized_delta(self):
        """Activation-path kernel == x @ (L Y R)^T — the synthesis model."""
        x, l, r, y = _rand(jax.random.PRNGKey(1), (10, 6), (7, 5), (4, 6),
                           (5, 4))
        delta = cosa_delta_ref(l, y, r, 1.0)        # (m, n)
        np.testing.assert_allclose(cosa_adapter(x, l, r, y), x @ delta.T,
                                   rtol=1e-4, atol=1e-5)

    def test_zero_core_gives_zero(self):
        x, l, r, _ = _rand(jax.random.PRNGKey(2), (33, 16), (12, 8), (4, 16),
                           (8, 4))
        out = cosa_adapter(x, l, r, jnp.zeros((8, 4)))
        assert float(jnp.abs(out).max()) == 0.0

    def test_rows_not_multiple_of_block(self):
        """Padding path: N deliberately not divisible by block_rows."""
        x, l, r, y = _rand(jax.random.PRNGKey(3), (130, 24), (16, 12),
                           (8, 24), (12, 8))
        np.testing.assert_allclose(cosa_adapter(x, l, r, y, 64),
                                   cosa_adapter_ref(x, l, r, y),
                                   rtol=1e-5, atol=1e-5)

    def test_single_row(self):
        x, l, r, y = _rand(jax.random.PRNGKey(4), (1, 8), (6, 4), (3, 8),
                           (4, 3))
        np.testing.assert_allclose(cosa_adapter(x, l, r, y),
                                   cosa_adapter_ref(x, l, r, y),
                                   rtol=1e-5, atol=1e-5)

    def test_3d_wrapper_scale(self):
        x3, l, r, y = _rand(jax.random.PRNGKey(5), (2, 9, 16), (12, 8),
                            (4, 16), (8, 4))
        out = cosa_adapter_3d(x3, l, r, y, scale=2.5)
        ref = 2.5 * cosa_adapter_ref(x3.reshape(18, 16), l, r, y)
        np.testing.assert_allclose(out.reshape(18, 12), ref, rtol=1e-5,
                                   atol=1e-5)

    def test_jit_composes(self):
        x, l, r, y = _rand(jax.random.PRNGKey(6), (32, 16), (12, 8), (4, 16),
                           (8, 4))
        f = jax.jit(lambda x, y: cosa_adapter(x, l, r, y).sum())
        np.testing.assert_allclose(f(x, y),
                                   cosa_adapter_ref(x, l, r, y).sum(),
                                   rtol=1e-5)


class TestVJP:
    def test_matches_analytic_eq10(self):
        x, l, r, y, g = _rand(jax.random.PRNGKey(7), (21, 10), (9, 7), (5, 10),
                              (7, 5), (21, 9))
        f = lambda x, y: jnp.sum(cosa_adapter(x, l, r, y) * g)
        dx, dy = jax.grad(f, (0, 1))(x, y)
        dx_ref, dy_ref = cosa_adapter_vjp_ref(x, l, r, y, g)
        np.testing.assert_allclose(dx, dx_ref, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(dy, dy_ref, rtol=1e-5, atol=1e-5)

    def test_numerical_gradient_y(self):
        x, l, r, y = _rand(jax.random.PRNGKey(8), (5, 6), (4, 3), (2, 6),
                           (3, 2))
        f = lambda y: jnp.sum(jnp.sin(cosa_adapter(x, l, r, y)))
        g = jax.grad(f)(y)
        eps = 1e-3
        for i in range(3):
            for j in range(2):
                yp = y.at[i, j].add(eps)
                ym = y.at[i, j].add(-eps)
                num = (f(yp) - f(ym)) / (2 * eps)
                np.testing.assert_allclose(g[i, j], num, rtol=2e-2, atol=1e-3)

    def test_gradient_flows_through_x(self):
        """∇x must route to earlier layers: ((gL)Y)R."""
        x, l, r, y = _rand(jax.random.PRNGKey(9), (7, 6), (4, 3), (2, 6),
                           (3, 2))
        f = lambda x: jnp.sum(cosa_adapter(x, l, r, y) ** 2)
        g = jax.grad(f)(x)
        assert float(jnp.abs(g).max()) > 0.0


@settings(max_examples=25, deadline=None)
@given(
    nrows=st.integers(1, 200),
    n=st.integers(1, 48),
    b=st.integers(1, 24),
    a=st.integers(1, 24),
    m=st.integers(1, 48),
    block=st.sampled_from([8, 32, 128]),
)
def test_hypothesis_shapes(nrows, n, b, a, m, block):
    """Kernel == oracle across the shape lattice (incl. padding edges)."""
    key = jax.random.PRNGKey(nrows * 1000 + n * 100 + b * 10 + a)
    x, l, r, y = _rand(key, (nrows, n), (m, a), (b, n), (a, b))
    out = cosa_adapter(x, l, r, y, block)
    ref = cosa_adapter_ref(x, l, r, y)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(dtype=st.sampled_from(["float32", "bfloat16"]),
       nrows=st.integers(4, 64))
def test_hypothesis_dtypes(dtype, nrows):
    key = jax.random.PRNGKey(nrows)
    x, l, r, y = _rand(key, (nrows, 16), (12, 8), (4, 16), (8, 4))
    dt = jnp.dtype(dtype)
    out = cosa_adapter(x.astype(dt), l.astype(dt), r.astype(dt),
                       y.astype(dt))
    assert out.dtype == dt
    ref = cosa_adapter_ref(x, l, r, y)
    tol = 1e-4 if dtype == "float32" else 0.15
    np.testing.assert_allclose(out.astype(jnp.float32), ref, rtol=tol,
                               atol=tol * 8)


class TestMTiled:
    """The §Perf L1 m-tiled variant (paper-scale VMEM fix)."""

    def test_matches_ref_with_m_tiling(self):
        from compile.kernels.cosa_kernel import _pallas_forward
        x, l, r, y = _rand(jax.random.PRNGKey(20), (70, 48), (96, 24),
                           (12, 48), (24, 12))
        out = _pallas_forward(x, l, r, y, block_rows=32, block_m=32)
        np.testing.assert_allclose(out, cosa_adapter_ref(x, l, r, y),
                                   rtol=1e-4, atol=1e-4)

    def test_m_not_multiple_of_block(self):
        from compile.kernels.cosa_kernel import _pallas_forward
        x, l, r, y = _rand(jax.random.PRNGKey(21), (16, 20), (50, 8),
                           (4, 20), (8, 4))
        out = _pallas_forward(x, l, r, y, block_rows=8, block_m=16)
        np.testing.assert_allclose(out, cosa_adapter_ref(x, l, r, y),
                                   rtol=1e-4, atol=1e-4)

    def test_vmem_mtiled_fits_paper_scale(self):
        from compile.kernels.cosa_kernel import vmem_bytes_mtiled
        # paper site m=n=4096, (a,b)=(1024,256): full-L kernel needs
        # >16MiB; the m-tiled variant fits.
        assert vmem_bytes(128, 4096, 256, 1024, 4096) > 16 * 2**20
        assert vmem_bytes_mtiled(128, 512, 4096, 256, 1024) < 16 * 2**20


class TestPerfModel:
    def test_vmem_within_budget_for_presets(self):
        """Every shipped preset's working set fits a 16 MiB VMEM budget."""
        presets = [(128, 512, 64, 128, 512), (128, 2048, 64, 128, 512),
                   (128, 512, 64, 128, 2048)]
        for bm, n, b, a, m in presets:
            assert vmem_bytes(bm, n, b, a, m) < 16 * 2 ** 20

    def test_mxu_estimate_bounds(self):
        u = mxu_utilization_estimate(128, 512, 64, 128, 512)
        assert 0.0 < u <= 1.0
        # 128-aligned shapes achieve full-tile issue
        assert mxu_utilization_estimate(128, 512, 128, 128, 512) == 1.0
