"""L2 correctness: model forward/loss, adapter semantics, train-step descent."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, presets
from compile.methods import ADAPTED_SITES, build_param_specs

TINY_LM = presets.MODEL_PRESETS["tiny-lm"]
TINY_CLS = presets.MODEL_PRESETS["tiny-cls"]

PEFT_METHODS = ["lora", "dora", "vera", "adalora", "nola", "cosa"]


def _meth(method, preset="tiny-lm", **ov):
    return presets.method_cfg(preset, method, **ov)


class TestSpecs:
    @pytest.mark.parametrize("method", PEFT_METHODS + ["full"])
    def test_roles_partition(self, method):
        sb = build_param_specs(TINY_LM, _meth(method))
        roles = {e["role"] for e in sb.entries}
        assert roles <= {"trainable", "frozen", "batch"}
        names = [e["name"] for e in sb.entries]
        assert len(names) == len(set(names)), "duplicate spec names"

    def test_full_has_no_frozen_params(self):
        sb = build_param_specs(TINY_LM, _meth("full"))
        assert sb.by_role("frozen") == []

    def test_cosa_trainable_is_only_core_for_lm(self):
        sb = build_param_specs(TINY_LM, _meth("cosa"))
        tr = [e["name"] for e in sb.by_role("trainable")]
        assert all(t.endswith(".y") for t in tr)
        assert len(tr) == TINY_LM["n_layers"] * len(ADAPTED_SITES)

    def test_cls_head_is_trainable(self):
        sb = build_param_specs(TINY_CLS, _meth("cosa", "tiny-cls"))
        tr = [e["name"] for e in sb.by_role("trainable")]
        assert "head.w" in tr and "head.b" in tr

    def test_cosa_param_count_matches_paper_formula(self):
        """Trainable count == a·b per adapted site — independent of (m,n)."""
        meth = _meth("cosa")
        sb = build_param_specs(TINY_LM, meth)
        count = sum(int(np.prod(e["shape"])) for e in sb.by_role("trainable"))
        per_site = meth["a"] * meth["b"]
        assert count == per_site * TINY_LM["n_layers"] * len(ADAPTED_SITES)


class TestZeroInit:
    @pytest.mark.parametrize("method", PEFT_METHODS)
    def test_adapter_starts_at_base_model(self, method):
        """Paper requirement: model initially behaves as the pre-trained one."""
        meth = _meth(method)
        p = model.init_params(TINY_LM, meth, seed=3)
        batch = model.init_batch(TINY_LM, seed=3)
        base = model.forward(p, TINY_LM, _meth("full"), batch["inputs"],
                             batch["wmask"])
        adapted = model.forward(p, TINY_LM, meth, batch["inputs"],
                                batch["wmask"])
        np.testing.assert_allclose(adapted, base, rtol=1e-4, atol=1e-4)


class TestForward:
    def test_causal_masking(self):
        """LM logits at position i must not depend on tokens > i."""
        meth = _meth("cosa")
        p = model.init_params(TINY_LM, meth, seed=1)
        batch = model.init_batch(TINY_LM, seed=1)
        ids = batch["inputs"]
        logits1 = model.forward(p, TINY_LM, meth, ids, batch["wmask"])
        ids2 = ids.at[:, -1].set((ids[:, -1] + 7) % TINY_LM["vocab"])
        logits2 = model.forward(p, TINY_LM, meth, ids2, batch["wmask"])
        np.testing.assert_allclose(logits1[:, :-1], logits2[:, :-1],
                                   rtol=1e-4, atol=1e-4)

    def test_padding_mask_ignores_tokens_cls(self):
        meth = _meth("cosa", "tiny-cls")
        p = model.init_params(TINY_CLS, meth, seed=2)
        batch = model.init_batch(TINY_CLS, seed=2)
        wm = batch["wmask"].at[:, 16:].set(0.0)
        out1 = model.forward(p, TINY_CLS, meth, batch["inputs"], wm)
        ids2 = batch["inputs"].at[:, 16:].set(0)
        out2 = model.forward(p, TINY_CLS, meth, ids2, wm)
        np.testing.assert_allclose(out1, out2, rtol=1e-4, atol=1e-4)


class TestTrainStep:
    def _run_steps(self, mcfg, meth, nsteps=12, lr=5e-2):
        gmeth = dict(meth, method=presets.GRAPH_ALIAS.get(meth["method"],
                                                          meth["method"]))
        step = jax.jit(model.make_step(mcfg, gmeth, "train"))
        sb = build_param_specs(mcfg, gmeth)
        p = model.init_params(mcfg, gmeth, seed=5)
        batch = model.init_batch(mcfg, seed=5)
        tnames = [e["name"] for e in sb.by_role("trainable")]
        fnames = [e["name"] for e in sb.by_role("frozen")]
        bnames = [e["name"] for e in sb.by_role("batch")]
        tr = [p[n] for n in tnames]
        m = [jnp.zeros_like(v) for v in tr]
        v = [jnp.zeros_like(x) for x in tr]
        losses = []
        for t in range(1, nsteps + 1):
            args = ([jnp.float32(lr), jnp.float32(0.0), jnp.float32(1e9),
                     jnp.float32(t)] + tr + m + v
                    + [p[n] for n in fnames] + [batch[n] for n in bnames])
            out = step(*args)
            losses.append(float(out[0]))
            k = len(tr)
            tr = list(out[2:2 + k])
            m = list(out[2 + k:2 + 2 * k])
            v = list(out[2 + 2 * k:2 + 3 * k])
        return losses

    @pytest.mark.parametrize("method", ["cosa", "lora", "full"])
    def test_loss_decreases_lm(self, method):
        losses = self._run_steps(TINY_LM, _meth(method))
        assert losses[-1] < losses[0] * 0.98, losses

    @pytest.mark.parametrize("method", ["cosa", "vera", "dora"])
    def test_loss_decreases_cls(self, method):
        losses = self._run_steps(TINY_CLS, _meth(method, "tiny-cls"))
        assert losses[-1] < losses[0], losses

    def test_eval_step_matches_loss(self):
        """train and eval artifacts compute the same loss on the same state."""
        meth = _meth("cosa")
        mcfg = TINY_LM
        sb = build_param_specs(mcfg, meth)
        p = model.init_params(mcfg, meth, seed=7)
        batch = model.init_batch(mcfg, seed=7)
        estep = jax.jit(model.make_step(mcfg, meth, "eval"))
        tnames = [e["name"] for e in sb.by_role("trainable")]
        fnames = [e["name"] for e in sb.by_role("frozen")]
        bnames = [e["name"] for e in sb.by_role("batch")]
        out = estep(*([p[n] for n in tnames] + [p[n] for n in fnames]
                      + [batch[n] for n in bnames]))
        loss_direct, _, _ = model.loss_and_metrics(p, mcfg, meth, batch)
        np.testing.assert_allclose(float(out[0]), float(loss_direct),
                                   rtol=1e-5)
        assert out[2].shape == (mcfg["batch"], mcfg["max_seq"],
                                mcfg["vocab"])


class TestIoSpec:
    @pytest.mark.parametrize("kind", ["train", "eval"])
    def test_spec_matches_step_arity(self, kind):
        meth = _meth("cosa")
        ins, outs = model.io_spec(TINY_LM, meth, kind)
        specs = model.input_shapedtypes(TINY_LM, meth, kind)
        assert len(ins) == len(specs)
        step = model.make_step(TINY_LM, meth, kind)
        args = [jnp.zeros(s.shape, s.dtype) for s in specs]
        # set t=1 to avoid 0^0 in bias correction
        if kind == "train":
            args[3] = jnp.float32(1.0)
        out = step(*args)
        assert len(out) == len(outs)
        for o, spec in zip(out, outs):
            assert list(o.shape) == spec["shape"], spec["name"]
