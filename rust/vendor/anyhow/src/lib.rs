//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements exactly the surface the `cosa` workspace uses: a boxed-free
//! string-backed [`Error`], the [`Result`] alias, the `anyhow!` / `bail!` /
//! `ensure!` macros, and blanket `?`-conversion from any
//! `std::error::Error`.  Deliberately API-compatible so the path
//! dependency can be swapped for the real crates.io `anyhow` without
//! touching call sites.

use std::fmt;

/// String-backed error value (the real crate boxes the source error and
/// captures a backtrace; this shim keeps just the rendered message).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Blanket conversion powering `?` on std / vendored-crate error types.
// `Error` itself must NOT implement `std::error::Error`, or this impl
// would collide with the reflexive `From<T> for T` (same trick as the
// real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    fn fails(flag: bool) -> crate::Result<u32> {
        crate::ensure!(!flag, "flag was {}", flag);
        Ok(7)
    }

    #[test]
    fn macros_and_display() {
        let e = crate::anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        assert_eq!(format!("{e:#}"), "x = 3");
        assert_eq!(format!("{e:?}"), "x = 3");
        assert_eq!(fails(false).unwrap(), 7);
        assert_eq!(fails(true).unwrap_err().to_string(), "flag was true");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> crate::Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert_eq!(parse("41").unwrap(), 41);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn bail_early_returns() {
        fn f() -> crate::Result<()> {
            crate::bail!("stop");
        }
        assert_eq!(f().unwrap_err().to_string(), "stop");
    }
}
