//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The container this workspace builds in has no XLA toolchain, so this
//! crate provides a compile-time compatible subset of the `xla` API:
//!
//! * [`Literal`] is **fully functional host-side** — typed storage,
//!   round-trips, tuple decomposition — so the marshalling layer
//!   (`runtime/literal.rs`) and its tests behave exactly as with the real
//!   bindings.
//! * [`PjRtClient::cpu`] and [`PjRtClient::buffer_from_host_buffer`]
//!   succeed (buffers hold a host copy), but
//!   [`PjRtClient::compile`] returns an error: executing lowered HLO
//!   requires the real backend.  Every artifact-dependent code path in
//!   the workspace already skips cleanly when compilation is impossible.
//!
//! One deliberate extension over the upstream API:
//! [`Literal::read_f32_into`], which refills a caller-owned buffer without
//! allocating — the executor's train-step splice path uses it to keep
//! steady-state host allocations at zero.

use std::fmt;

#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes the workspace marshals (f32 tensors, i32 token ids).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn byte_size(&self) -> usize {
        4
    }
}

/// Types that can live in a [`Literal`].
pub trait ArrayElement: Copy {
    const TY: ElementType;
    fn to_le_bytes4(self) -> [u8; 4];
    fn from_le_bytes4(b: [u8; 4]) -> Self;
}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
    fn to_le_bytes4(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_le_bytes4(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
}

impl ArrayElement for i32 {
    const TY: ElementType = ElementType::S32;
    fn to_le_bytes4(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_le_bytes4(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
}

fn numel(dims: &[usize]) -> usize {
    dims.iter().product::<usize>()
}

/// Host tensor value: dtype + dims + little-endian bytes, or a tuple.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let want = numel(dims) * ty.byte_size();
        if data.len() != want {
            return Err(Error::new(format!(
                "literal data is {} bytes, shape {:?} wants {}",
                data.len(),
                dims,
                want
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.to_vec(),
            data: data.to_vec(),
            tuple: None,
        })
    }

    /// Scalar f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal {
            ty: ElementType::F32,
            dims: Vec::new(),
            data: v.to_le_bytes().to_vec(),
            tuple: None,
        }
    }

    /// Wrap component literals into a tuple literal.
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            ty: ElementType::F32,
            dims: Vec::new(),
            data: Vec::new(),
            tuple: Some(parts),
        }
    }

    pub fn element_count(&self) -> usize {
        numel(&self.dims)
    }

    fn check_type(&self, ty: ElementType) -> Result<()> {
        if self.tuple.is_some() {
            return Err(Error::new("literal is a tuple, not an array"));
        }
        if self.ty != ty {
            return Err(Error::new(format!(
                "literal is {:?}, requested {:?}",
                self.ty, ty
            )));
        }
        Ok(())
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        self.check_type(T::TY)?;
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| T::from_le_bytes4([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Refill `dst` from an f32 literal, reusing its capacity (extension
    /// over the upstream API; see crate docs).
    pub fn read_f32_into(&self, dst: &mut Vec<f32>) -> Result<()> {
        self.check_type(ElementType::F32)?;
        dst.clear();
        dst.reserve(self.element_count());
        for c in self.data.chunks_exact(4) {
            dst.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(())
    }

    pub fn get_first_element<T: ArrayElement>(&self) -> Result<T> {
        self.check_type(T::TY)?;
        if self.data.len() < 4 {
            return Err(Error::new("empty literal has no first element"));
        }
        Ok(T::from_le_bytes4([
            self.data[0],
            self.data[1],
            self.data[2],
            self.data[3],
        ]))
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        self.tuple
            .ok_or_else(|| Error::new("literal is not a tuple"))
    }
}

/// Parsed HLO module (text is retained verbatim; only the real backend
/// interprets it).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

#[derive(Clone, Debug)]
pub struct XlaComputation {
    _hlo: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _hlo: proto.text.clone() }
    }
}

/// Device buffer: in the stub, a host copy of the uploaded data.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// PJRT client handle.  Creation and uploads succeed; compilation needs
/// the real backend and errors with a clear message.
#[derive(Clone, Debug, Default)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(
            "PJRT execution is unavailable in this build: the `xla` \
             dependency is the vendored stub (rust/vendor/xla); link the \
             real bindings to run lowered artifacts",
        ))
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        if data.len() != numel(dims) {
            return Err(Error::new(format!(
                "host buffer has {} elements, shape {:?} wants {}",
                data.len(),
                dims,
                numel(dims)
            )));
        }
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes4());
        }
        Ok(PjRtBuffer {
            lit: Literal {
                ty: T::TY,
                dims: dims.to_vec(),
                data: bytes,
                tuple: None,
            },
        })
    }
}

/// Compiled executable handle.  Unreachable through the stub (compile
/// errors first), but the API surface exists so call sites type-check.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new("stub executable cannot run"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, -2.5, 0.0, 3.25];
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2],
            &bytes,
        )
        .unwrap();
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert!(lit.to_vec::<i32>().is_err(), "dtype mismatch must error");
    }

    #[test]
    fn read_into_reuses_capacity() {
        let lit = Literal::scalar(4.5);
        let mut dst = Vec::with_capacity(8);
        let cap = dst.capacity();
        lit.read_f32_into(&mut dst).unwrap();
        assert_eq!(dst, vec![4.5]);
        assert_eq!(dst.capacity(), cap);
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![Literal::scalar(1.0), Literal::scalar(2.0)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].get_first_element::<f32>().unwrap(), 2.0);
    }

    #[test]
    fn upload_validates_shape() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client
            .buffer_from_host_buffer(&[1.0f32, 2.0], &[3], None)
            .is_err());
        let buf = client
            .buffer_from_host_buffer(&[1i32, 2, 3], &[3], None)
            .unwrap();
        assert_eq!(buf.to_literal_sync().unwrap().to_vec::<i32>().unwrap(),
                   vec![1, 2, 3]);
    }

    #[test]
    fn compile_is_a_clear_stub_error() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto {
            text: "HloModule m".into(),
        });
        let err = client.compile(&comp).unwrap_err().to_string();
        assert!(err.contains("vendored stub"), "{err}");
    }
}
