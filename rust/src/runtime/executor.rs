//! Executor: compiled artifact + persistent model state + step dispatch.
//!
//! State is a name → **device buffer** map shared between the train and
//! eval executors of a run, executed via `execute_b`.  Weights live on
//! the device across steps: per step only the four schedule scalars and
//! the batch are uploaded, and only the updated trainables/moments are
//! spliced back.  (§Perf L3: the literal-based `execute` path re-uploads
//! every frozen tensor per call *and leaks the input device buffers* in
//! xla_rs.cc — at e2e scale that is 132 MB/step of growth; the
//! buffer-resident path removed both the copy and the leak.  See
//! EXPERIMENTS.md §Perf.)  The splice path refills each entry's host
//! mirror in place via `Literal::read_f32_into`, so steady-state steps
//! perform no host-side output allocations.

use std::collections::BTreeMap;
use std::path::Path;

use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient,
          PjRtLoadedExecutable, XlaComputation};

use crate::data::batcher::Batch;
use crate::runtime::artifact::ArtifactMeta;
use crate::runtime::literal::{scalar_f32, to_vec};

/// Process-wide PJRT client (CPU).
pub struct Runtime {
    pub client: PjRtClient,
}

impl Runtime {
    pub fn cpu() -> anyhow::Result<Runtime> {
        Ok(Runtime { client: PjRtClient::cpu()? })
    }

    /// Load + compile one artifact.
    pub fn load(&self, dir: &Path, artifact: &str)
                -> anyhow::Result<Executor> {
        let meta = ArtifactMeta::load(dir, artifact)?;
        let proto = HloModuleProto::from_text_file(
            meta.hlo_path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("bad path"))?,
        )?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executor {
            meta,
            exe,
            client: self.client.clone(),
            profile: Default::default(),
        })
    }
}

/// A state tensor: host mirror + device buffer.
///
/// Uploads go through `buffer_from_host_buffer` with
/// `kImmutableOnlyDuringCall` semantics — the CPU client copies the host
/// data *before returning*, so there is no async-transfer lifetime hazard
/// (`BufferFromHostLiteral` defers its copy to a worker thread and
/// use-after-frees if the source literal dies first — the crate's own
/// `execute` wrapper awaits readiness for that reason, at the price of
/// leaking every input buffer; see EXPERIMENTS.md §Perf).
pub struct Entry {
    /// Host mirror of the tensor (also serves `State::read`).
    pub data: Vec<f32>,
    pub buf: PjRtBuffer,
}

/// Shared model + optimizer state (name → device-resident entry).
pub struct State {
    pub tensors: BTreeMap<String, Entry>,
    /// AdamW step counter (t input; starts at 1 on the first step).
    pub step: u64,
    client: PjRtClient,
}

impl State {
    /// Initialize from host tensors (trainable + frozen) plus zeroed
    /// moments for every trainable of `meta`.  All tensors are uploaded
    /// to the device once here.
    pub fn init(client: &PjRtClient, meta: &ArtifactMeta,
                host: &BTreeMap<String, Vec<f32>>) -> anyhow::Result<State> {
        let up = |data: Vec<f32>, shape: &[usize]| -> anyhow::Result<Entry> {
            let buf = client.buffer_from_host_buffer(&data, shape, None)?;
            Ok(Entry { data, buf })
        };
        let mut tensors = BTreeMap::new();
        for spec in &meta.inputs {
            match spec.role.as_str() {
                "trainable" | "frozen" => {
                    let vals = host.get(&spec.name).ok_or_else(|| {
                        anyhow::anyhow!("initializer missing `{}`", spec.name)
                    })?;
                    anyhow::ensure!(
                        vals.len() == spec.numel(),
                        "`{}`: init has {} values, spec wants {:?}",
                        spec.name, vals.len(), spec.shape
                    );
                    tensors.insert(spec.name.clone(),
                                   up(vals.clone(), &spec.shape)?);
                }
                "opt_m" | "opt_v" => {
                    tensors.insert(spec.name.clone(),
                                   up(vec![0.0; spec.numel()], &spec.shape)?);
                }
                _ => {}
            }
        }
        Ok(State { tensors, step: 0, client: client.clone() })
    }

    /// Read one tensor back to the host (checkpointing, AdaLoRA masks…).
    pub fn read(&self, name: &str) -> anyhow::Result<Vec<f32>> {
        let e = self.tensors.get(name)
            .ok_or_else(|| anyhow::anyhow!("state missing `{name}`"))?;
        Ok(e.data.clone()) // host mirror always matches the device buffer
    }

    /// Overwrite one tensor from host values.
    pub fn write(&mut self, name: &str, shape: &[usize],
                 vals: &[f32]) -> anyhow::Result<()> {
        let data = vals.to_vec();
        let buf = self.client.buffer_from_host_buffer(&data, shape, None)?;
        self.tensors.insert(name.to_string(), Entry { data, buf });
        Ok(())
    }
}

/// Result of one train step.
#[derive(Clone, Copy, Debug)]
pub struct StepOut {
    pub loss: f32,
    pub acc: f32,
}

/// Result of one eval step.
pub struct EvalOut {
    pub loss: f32,
    pub acc: f32,
    pub logits: Vec<f32>,
    pub logits_shape: Vec<usize>,
}

/// Accumulated per-phase timings of the executor hot path (§Perf L3):
/// batch upload vs XLA execute vs output readback/splice.
#[derive(Default, Debug, Clone, Copy)]
pub struct PhaseTimes {
    pub marshal_ns: u64,
    pub execute_ns: u64,
    pub splice_ns: u64,
    pub steps: u64,
}

impl PhaseTimes {
    pub fn report(&self) -> String {
        let s = self.steps.max(1);
        format!(
            "per step: marshal {:.1}µs | execute {:.1}µs | splice {:.1}µs \
             (overhead {:.2}%)",
            self.marshal_ns as f64 / s as f64 / 1e3,
            self.execute_ns as f64 / s as f64 / 1e3,
            self.splice_ns as f64 / s as f64 / 1e3,
            100.0 * (self.marshal_ns + self.splice_ns) as f64
                / (self.marshal_ns + self.execute_ns + self.splice_ns)
                    .max(1) as f64
        )
    }
}

fn dbg_log(msg: &str) {
    if std::env::var("COSA_DBG").is_ok() {
        eprintln!("DBG: {msg}");
    }
}

pub struct Executor {
    pub meta: ArtifactMeta,
    exe: PjRtLoadedExecutable,
    client: PjRtClient,
    profile: std::cell::Cell<PhaseTimes>,
}

impl Executor {
    /// Upload one batch-role input as a device buffer.
    fn batch_buffer(&self, name: &str, shape: &[usize],
                    batch: &Batch) -> anyhow::Result<PjRtBuffer> {
        match name {
            "inputs" => self.upload_i32(&batch.ids, shape),
            "wmask" => Ok(self.client
                .buffer_from_host_buffer(&batch.wmask, shape, None)?),
            "targets" => {
                let t = batch.targets.as_ref()
                    .ok_or_else(|| anyhow::anyhow!("batch lacks targets"))?;
                self.upload_i32(t, shape)
            }
            "labels" => {
                if let Some(li) = &batch.labels_i {
                    self.upload_i32(li, shape)
                } else if let Some(lf) = &batch.labels_f {
                    Ok(self.client.buffer_from_host_buffer(lf, shape, None)?)
                } else {
                    anyhow::bail!("batch lacks labels")
                }
            }
            other => anyhow::bail!("unknown batch input `{other}`"),
        }
    }

    /// Reset and return accumulated phase timings.
    pub fn take_profile(&self) -> PhaseTimes {
        self.profile.replace(PhaseTimes::default())
    }

    /// Synchronous-copy upload of f32 host data.
    fn upload_f32(&self, data: Vec<f32>, shape: &[usize])
                  -> anyhow::Result<Entry> {
        let buf = self.client.buffer_from_host_buffer(&data, shape, None)?;
        Ok(Entry { data, buf })
    }

    /// Per-call i32 upload (batch ids/targets/labels); host data is
    /// copied before return, nothing to keep alive.
    fn upload_i32(&self, data: &[i32], shape: &[usize])
                  -> anyhow::Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, shape, None)?)
    }

    /// Assemble inputs in artifact order and execute via `execute_b`,
    /// returning the decomposed output tuple.
    fn run(&self, scalars: &BTreeMap<&str, f32>, state: &State,
           batch: &Batch) -> anyhow::Result<Vec<Literal>> {
        let t0 = std::time::Instant::now();
        // Two passes: first upload the per-call buffers (scalars + batch),
        // then assemble borrows in artifact order.
        enum Src {
            Owned(usize),
            State(usize), // index into meta.inputs → name lookup
        }
        let mut owned: Vec<PjRtBuffer> = Vec::new();
        // Host storage for per-call uploads: must outlive execute_b (the
        // CPU client may defer the H2D copy to a worker thread even under
        // kImmutableOnlyDuringCall — observed on xla_extension 0.5.1).
        let mut scalar_store: Vec<Box<[f32; 1]>> = Vec::new();
        let mut srcs: Vec<Src> = Vec::with_capacity(self.meta.inputs.len());
        for (idx, spec) in self.meta.inputs.iter().enumerate() {
            match spec.role.as_str() {
                "scalar" => {
                    let v = *scalars.get(spec.name.as_str()).ok_or_else(|| {
                        anyhow::anyhow!("missing scalar `{}`", spec.name)
                    })?;
                    scalar_store.push(Box::new([v]));
                    let data: &[f32] = scalar_store.last().unwrap().as_ref();
                    owned.push(self.client
                        .buffer_from_host_buffer(data, &[], None)?);
                    srcs.push(Src::Owned(owned.len() - 1));
                }
                "trainable" | "opt_m" | "opt_v" | "frozen" => {
                    anyhow::ensure!(
                        state.tensors.contains_key(&spec.name),
                        "state missing `{}`", spec.name
                    );
                    srcs.push(Src::State(idx));
                }
                "batch" => {
                    owned.push(self.batch_buffer(&spec.name, &spec.shape,
                                                 batch)?);
                    srcs.push(Src::Owned(owned.len() - 1));
                }
                other => anyhow::bail!("unknown input role `{other}`"),
            }
        }
        let mut args: Vec<&PjRtBuffer> =
            Vec::with_capacity(self.meta.inputs.len());
        for src in &srcs {
            match src {
                Src::Owned(i) => args.push(&owned[*i]),
                Src::State(i) => {
                    args.push(&state.tensors[&self.meta.inputs[*i].name].buf)
                }
            }
        }
        let t1 = std::time::Instant::now();
        dbg_log("inputs ready, executing");
        let result = self.exe.execute_b::<&PjRtBuffer>(&args)?;
        dbg_log("executed");
        let t2 = std::time::Instant::now();
        dbg_log("readback");
        let tuple = result[0][0].to_literal_sync()?;
        dbg_log("tuple read");
        let outs = tuple.to_tuple()?;
        let t3 = std::time::Instant::now();
        let mut p = self.profile.get();
        p.marshal_ns += (t1 - t0).as_nanos() as u64;
        p.execute_ns += (t2 - t1).as_nanos() as u64;
        p.splice_ns += (t3 - t2).as_nanos() as u64;
        p.steps += 1;
        self.profile.set(p);
        Ok(outs)
    }

    /// One optimizer step; splices updated trainables + moments into
    /// `state` and bumps the Adam step counter.
    pub fn train_step(&self, state: &mut State, lr: f32, wd: f32, clip: f32,
                      batch: &Batch) -> anyhow::Result<StepOut> {
        anyhow::ensure!(self.meta.kind == "train", "not a train artifact");
        state.step += 1;
        let scalars = BTreeMap::from([
            ("lr", lr),
            ("wd", wd),
            ("clip", clip),
            ("t", state.step as f32),
        ]);
        let outs = self.run(&scalars, state, batch)?;
        anyhow::ensure!(outs.len() == self.meta.outputs.len(),
                        "output arity mismatch");
        let loss = scalar_f32(&outs[0])?;
        let acc = scalar_f32(&outs[1])?;
        let t0 = std::time::Instant::now();
        for (spec, lit) in self.meta.outputs.iter().zip(outs).skip(2) {
            // output names: "new:<t>", "new_m:<t>", "new_v:<t>"
            let state_name = match spec.name.split_once(':') {
                Some(("new", t)) => t.to_string(),
                Some(("new_m", t)) => format!("opt_m:{t}"),
                Some(("new_v", t)) => format!("opt_v:{t}"),
                _ => anyhow::bail!("unexpected output `{}`", spec.name),
            };
            // Splice without per-step host allocations: refill the
            // existing entry's host mirror in place (its capacity is
            // already right after the first step) and re-upload.
            if let Some(entry) = state.tensors.get_mut(&state_name) {
                lit.read_f32_into(&mut entry.data)?;
                entry.buf = self.client.buffer_from_host_buffer(
                    &entry.data, &spec.shape, None)?;
            } else {
                let data = to_vec::<f32>(&lit)?;
                state.tensors.insert(state_name,
                                     self.upload_f32(data, &spec.shape)?);
            }
        }
        let mut p = self.profile.get();
        p.splice_ns += t0.elapsed().as_nanos() as u64;
        self.profile.set(p);
        Ok(StepOut { loss, acc })
    }

    /// Loss + logits on one batch (no state mutation).
    pub fn eval_step(&self, state: &State, batch: &Batch)
                     -> anyhow::Result<EvalOut> {
        anyhow::ensure!(self.meta.kind == "eval", "not an eval artifact");
        let outs = self.run(&BTreeMap::new(), state, batch)?;
        let loss = scalar_f32(&outs[0])?;
        let acc = scalar_f32(&outs[1])?;
        let logits = to_vec::<f32>(&outs[2])?;
        Ok(EvalOut {
            loss,
            acc,
            logits,
            logits_shape: self.meta.outputs[2].shape.clone(),
        })
    }
}
