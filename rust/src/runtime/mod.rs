//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! training hot path.  Python is never involved here — see DESIGN.md §3.

pub mod artifact;
pub mod executor;
pub mod literal;

pub use artifact::{ArtifactMeta, Registry, TensorSpec};
pub use executor::{Executor, Runtime, State};
