//! Artifact registry: parse `artifacts/manifest.json` and the per-artifact
//! `<name>.json` metadata emitted by `python/compile/aot.py`.
//!
//! The JSON is the ABI between L2 and L3: ordered input/output tensor
//! specs with roles, plus the model/method configs the specs were lowered
//! against.  Rust trusts the order, not name conventions.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One input or output tensor in artifact order.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    /// "scalar" | "trainable" | "opt_m" | "opt_v" | "frozen" | "batch"
    /// for inputs; outputs leave this empty.
    pub role: String,
    pub shape: Vec<usize>,
    /// "f32" | "i32"
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> anyhow::Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req("name")?.as_str().unwrap_or_default().to_string(),
            role: j.get("role").and_then(|r| r.as_str()).unwrap_or("")
                .to_string(),
            shape: j
                .req("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect(),
            dtype: j.get("dtype").and_then(|d| d.as_str()).unwrap_or("f32")
                .to_string(),
        })
    }
}

/// Model config mirrored from `presets.py`.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub head: String,
    pub n_classes: usize,
    pub batch: usize,
}

impl ModelMeta {
    fn from_json(j: &Json) -> anyhow::Result<ModelMeta> {
        let u = |k: &str| -> anyhow::Result<usize> {
            Ok(j.req(k)?.as_usize().unwrap_or(0))
        };
        Ok(ModelMeta {
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            d_ff: u("d_ff")?,
            max_seq: u("max_seq")?,
            head: j.req("head")?.as_str().unwrap_or("lm").to_string(),
            n_classes: u("n_classes")?,
            batch: u("batch")?,
        })
    }
}

/// Method config mirrored from `presets.py`.
#[derive(Clone, Debug)]
pub struct MethodMeta {
    pub method: String,
    pub r: usize,
    pub a: usize,
    pub b: usize,
    pub alpha: f64,
    pub nola_k: usize,
}

impl MethodMeta {
    fn from_json(j: &Json) -> anyhow::Result<MethodMeta> {
        Ok(MethodMeta {
            method: j.req("method")?.as_str().unwrap_or("").to_string(),
            r: j.get("r").and_then(|v| v.as_usize()).unwrap_or(8),
            a: j.get("a").and_then(|v| v.as_usize()).unwrap_or(64),
            b: j.get("b").and_then(|v| v.as_usize()).unwrap_or(32),
            alpha: j.get("alpha").and_then(|v| v.as_f64()).unwrap_or(2.0),
            nola_k: j.get("nola_k").and_then(|v| v.as_usize()).unwrap_or(32),
        })
    }
}

/// Parsed metadata for one lowered artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    pub preset: String,
    pub model: ModelMeta,
    pub method: MethodMeta,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub hlo_path: PathBuf,
}

impl ArtifactMeta {
    pub fn load(dir: &Path, artifact: &str) -> anyhow::Result<ArtifactMeta> {
        let meta_path = dir.join(format!("{artifact}.json"));
        let src = std::fs::read_to_string(&meta_path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts`?): {e}",
                meta_path.display()
            )
        })?;
        let j = Json::parse(&src)?;
        let inputs = j
            .req("inputs")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(TensorSpec::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let outputs = j
            .req("outputs")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(TensorSpec::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(ArtifactMeta {
            name: j.req("name")?.as_str().unwrap_or("").to_string(),
            kind: j.req("kind")?.as_str().unwrap_or("").to_string(),
            preset: j.req("preset")?.as_str().unwrap_or("").to_string(),
            model: ModelMeta::from_json(j.req("model")?)?,
            method: MethodMeta::from_json(j.req("method")?)?,
            inputs,
            outputs,
            hlo_path: dir.join(format!("{artifact}.hlo.txt")),
        })
    }

    /// Input specs with a given role, in artifact order.
    pub fn inputs_with_role(&self, role: &str) -> Vec<&TensorSpec> {
        self.inputs.iter().filter(|s| s.role == role).collect()
    }

    /// (name, shape) pairs for the initializer (trainable + frozen).
    pub fn init_specs(&self) -> Vec<(String, Vec<usize>)> {
        self.inputs
            .iter()
            .filter(|s| s.role == "trainable" || s.role == "frozen")
            .map(|s| (s.name.clone(), s.shape.clone()))
            .collect()
    }

    pub fn trainable_param_count(&self) -> usize {
        self.inputs_with_role("trainable").iter().map(|s| s.numel()).sum()
    }
}

/// The artifact directory + manifest.
#[derive(Debug)]
pub struct Registry {
    pub dir: PathBuf,
    pub artifacts: Vec<String>,
    pub entries: BTreeMap<String, Json>,
}

impl Registry {
    /// Open `artifacts/` (or `$COSA_ARTIFACTS`).
    pub fn open_default() -> anyhow::Result<Registry> {
        let dir = std::env::var("COSA_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Registry::open(Path::new(&dir))
    }

    pub fn open(dir: &Path) -> anyhow::Result<Registry> {
        let manifest = dir.join("manifest.json");
        let src = std::fs::read_to_string(&manifest).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts`): {e}",
                manifest.display()
            )
        })?;
        let j = Json::parse(&src)?;
        let artifacts = j
            .req("artifacts")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_str().map(str::to_string))
            .collect();
        let mut entries = BTreeMap::new();
        if let Some(arr) = j.get("entries").and_then(|e| e.as_arr()) {
            for e in arr {
                if let Some(name) = e.get("name").and_then(|n| n.as_str()) {
                    entries.insert(name.to_string(), e.clone());
                }
            }
        }
        Ok(Registry { dir: dir.to_path_buf(), artifacts, entries })
    }

    pub fn meta(&self, artifact: &str) -> anyhow::Result<ArtifactMeta> {
        ArtifactMeta::load(&self.dir, artifact)
    }

    pub fn has(&self, artifact: &str) -> bool {
        self.artifacts.iter().any(|a| a == artifact)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn registry_and_meta_parse() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let reg = Registry::open(&dir).unwrap();
        assert!(reg.has("tiny-lm_cosa_train"), "{:?}", reg.artifacts);
        let meta = reg.meta("tiny-lm_cosa_train").unwrap();
        assert_eq!(meta.kind, "train");
        assert_eq!(meta.model.d_model, 64);
        assert_eq!(meta.method.method, "cosa");
        assert!(meta.hlo_path.exists());

        // role partitioning: scalars first, batch last
        assert_eq!(meta.inputs[0].role, "scalar");
        assert_eq!(meta.inputs.last().unwrap().role, "batch");
        // train outputs = loss, acc + 3 tensors per trainable
        let nt = meta.inputs_with_role("trainable").len();
        assert_eq!(meta.outputs.len(), 2 + 3 * nt);
        // CoSA trainables are exactly the cores: n_layers × 4 sites
        assert_eq!(nt, meta.model.n_layers * 4);
        assert_eq!(meta.trainable_param_count(),
                   nt * meta.method.a * meta.method.b);
    }

    #[test]
    fn eval_meta_has_logits() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let meta = Registry::open(&dir).unwrap()
            .meta("tiny-lm_cosa_eval").unwrap();
        let last = meta.outputs.last().unwrap();
        assert_eq!(last.name, "logits");
        assert_eq!(last.shape, vec![8, 32, 256]);
    }

    #[test]
    fn missing_artifact_is_helpful_error() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let err = Registry::open(&dir).unwrap().meta("nope").unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
