//! Literal marshalling helpers: host tensors ↔ `xla::Literal`.

use xla::{ArrayElement, ElementType, Literal};

use crate::runtime::artifact::TensorSpec;

/// f32 tensor → Literal with the given dims.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> anyhow::Result<Literal> {
    debug_assert_eq!(shape.iter().product::<usize>().max(1), data.len());
    // SAFETY: reinterpreting &[f32] as &[u8] — u8 has alignment 1, the
    // byte length covers exactly the borrowed buffer (4 bytes per f32,
    // no padding), and the slice's lifetime is bounded by `data`.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                   data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        shape,
        bytes,
    )?)
}

/// i32 tensor → Literal.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> anyhow::Result<Literal> {
    debug_assert_eq!(shape.iter().product::<usize>().max(1), data.len());
    // SAFETY: same as `lit_f32` — &[i32] viewed as bytes, alignment 1,
    // exact length, lifetime bounded by the `data` borrow.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                   data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        shape,
        bytes,
    )?)
}

/// f32 scalar literal.
pub fn lit_scalar(v: f32) -> Literal {
    Literal::scalar(v)
}

/// Zero-filled literal for a spec (AdamW moment buffers).
pub fn lit_zeros(spec: &TensorSpec) -> anyhow::Result<Literal> {
    lit_f32(&spec.shape, &vec![0.0f32; spec.numel()])
}

/// Literal → host Vec<T>.
pub fn to_vec<T: ArrayElement>(lit: &Literal) -> anyhow::Result<Vec<T>> {
    Ok(lit.to_vec::<T>()?)
}

/// First element of a scalar f32 literal.
pub fn scalar_f32(lit: &Literal) -> anyhow::Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.0f32, -2.5, 3.25, 0.0, 7.0, 9.5];
        let lit = lit_f32(&[2, 3], &data).unwrap();
        assert_eq!(to_vec::<f32>(&lit).unwrap(), data);
        assert_eq!(lit.element_count(), 6);
    }

    #[test]
    fn i32_roundtrip() {
        let data = vec![1i32, -2, 3, 40];
        let lit = lit_i32(&[4], &data).unwrap();
        assert_eq!(to_vec::<i32>(&lit).unwrap(), data);
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = lit_scalar(2.5);
        assert_eq!(scalar_f32(&lit).unwrap(), 2.5);
    }

    #[test]
    fn zeros_match_spec() {
        let spec = TensorSpec {
            name: "y".into(),
            role: "trainable".into(),
            shape: vec![4, 3],
            dtype: "f32".into(),
        };
        let lit = lit_zeros(&spec).unwrap();
        assert_eq!(to_vec::<f32>(&lit).unwrap(), vec![0.0; 12]);
    }
}
