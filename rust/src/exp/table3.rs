//! Table 3: NLG comparison — math reasoning (GSM8K/MATH analogue) and
//! code generation (HumanEval/MBPP analogue) on the `small-lm` preset,
//! decode-based metrics (exact match / execution-checked pass@1).

use crate::adapters::costmodel::fmt_params;
use crate::exp::harness::{exp_train_cfg, method_lr, run_scored, LmScore};
use crate::exp::{print_header, print_row};
use crate::math::stats;
use crate::runtime::executor::Runtime;
use crate::runtime::Registry;
use crate::util::args::Args;

pub const METHODS: [&str; 5] = ["full", "lora", "adalora", "pissa", "cosa"];
const TASKS: [(&str, &str, LmScore); 2] = [
    ("math", "GSM8K-sim", LmScore::ExactInt),
    ("code", "HumanEval-sim", LmScore::PassAt1),
];

pub fn run(args: &Args) -> anyhow::Result<()> {
    let steps = args.usize("steps", 150);
    let seeds = args.usize("seeds", 2);
    let lr = args.f64("lr", 2e-3);
    let decode_n = args.usize("decode", 64);
    let methods: Vec<String> = match args.opt("methods") {
        Some(m) => m.split(',').map(str::to_string).collect(),
        None => METHODS.iter().map(|s| s.to_string()).collect(),
    };
    let rt = Runtime::cpu()?;
    let reg = Registry::open_default()?;

    println!("== Table 3 (NLG-sim): small-lm, {steps} steps, {seeds} seeds, \
              decode n={decode_n} ==\n");
    let widths = [9, 10, 16, 16, 8];
    print_header(&["METHOD", "PARAMS", "GSM8K-sim", "HumanEval-sim", "AVG"],
                 &widths);

    for method in &methods {
        let artifact = format!("small-lm_{method}");
        let tcfg = exp_train_cfg(steps, method_lr(method, lr));
        let mut cells = vec![method.clone(), String::new()];
        let mut means = Vec::new();
        let mut params = 0;
        for (task, _, score) in TASKS {
            let mut vals = Vec::new();
            for s in 0..seeds {
                let r = run_scored(&rt, &reg, &artifact, task, &tcfg,
                                   s as u64, score, decode_n)?;
                vals.push(100.0 * r.metric);
                params = r.trainable_params;
            }
            means.push(stats::mean(&vals));
            cells.push(stats::fmt_mean_std(&vals));
        }
        cells[1] = fmt_params(params);
        cells.push(format!("{:.2}", stats::mean(&means)));
        print_row(&cells, &widths);
    }
    println!("\nPaper shape (LLaMA-3.2-1B block): CoSA 28.10 avg with 29M \
              params vs PiSSA 27.75 @ 90M and LoRA 23.91 @ 90M — CoSA \
              matches/beats the LoRA family at ~1/3 the parameters.");
    Ok(())
}
