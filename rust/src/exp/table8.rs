//! Table 8: instruction tuning (MT-Bench substitute) — rubric-judge
//! scores (0–10) for LoRA vs PiSSA vs CoSA over 2 runs.

use crate::adapters::costmodel::fmt_params;
use crate::exp::harness::{exp_train_cfg, method_lr, run_scored, LmScore};
use crate::exp::{print_header, print_row};
use crate::math::stats;
use crate::runtime::executor::Runtime;
use crate::runtime::Registry;
use crate::util::args::Args;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let steps = args.usize("steps", 150);
    let decode_n = args.usize("decode", 48);
    let lr = args.f64("lr", 2e-3);
    let rt = Runtime::cpu()?;
    let reg = Registry::open_default()?;

    println!("== Table 8 (instruction tuning, rubric judge 0-10): \
              small-lm, {steps} steps ==\n");
    let widths = [9, 10, 10, 10, 10];
    print_header(&["METHOD", "PARAMS", "RUN 1", "RUN 2", "AVERAGE"],
                 &widths);
    for method in ["lora", "pissa", "cosa"] {
        let artifact = format!("small-lm_{method}");
        let tcfg = exp_train_cfg(steps, method_lr(method, lr));
        let mut scores = Vec::new();
        let mut params = 0;
        for s in 0..2 {
            let r = run_scored(&rt, &reg, &artifact, "instr", &tcfg, s,
                               LmScore::Judge, decode_n)?;
            scores.push(r.metric);
            params = r.trainable_params;
        }
        print_row(&[
            method.to_string(),
            fmt_params(params),
            format!("{:.2}", scores[0]),
            format!("{:.2}", scores[1]),
            format!("{:.2}", stats::mean(&scores)),
        ], &widths);
    }
    println!("\nPaper shape: CoSA 3.24 avg > PiSSA 2.69 > LoRA 1.88, with \
              ~1/3 the trainable parameters.");
    Ok(())
}
