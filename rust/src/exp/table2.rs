//! Table 2: NLU (GLUE-sim) comparison across methods.
//!
//! Six synthetic GLUE-analogue tasks × PEFT methods × seeds on the
//! `small-cls` / `small-reg` presets (DESIGN.md §2 substitution).  The
//! printed shape to compare against the paper's RoBERTa-base block:
//! CoSA best-or-second-best on average with fewer trainables than the
//! LoRA family.

use crate::adapters::costmodel::fmt_params;
use crate::data::nlu;
use crate::exp::harness::{exp_train_cfg, method_lr, run_scored, LmScore};
use crate::exp::{print_header, print_row};
use crate::math::stats;
use crate::runtime::executor::Runtime;
use crate::runtime::Registry;
use crate::util::args::Args;

pub const METHODS: [&str; 7] =
    ["full", "lora", "adalora", "pissa", "vera", "dora", "cosa"];

pub fn run(args: &Args) -> anyhow::Result<()> {
    let steps = args.usize("steps", 60);
    let seeds = args.usize("seeds", 2);
    let lr = args.f64("lr", 2e-3);
    let methods: Vec<String> = match args.opt("methods") {
        Some(m) => m.split(',').map(str::to_string).collect(),
        None => METHODS.iter().map(|s| s.to_string()).collect(),
    };
    let rt = Runtime::cpu()?;
    let reg = Registry::open_default()?;

    println!("== Table 2 (GLUE-sim): small preset, {steps} steps, \
              {seeds} seeds ==\n");
    let mut widths = vec![9usize, 10];
    widths.extend(std::iter::repeat(14).take(nlu::TASKS.len()));
    widths.push(8);
    let mut header = vec!["METHOD", "PARAMS"];
    header.extend(nlu::TASKS.iter().copied());
    header.push("AVG");
    print_header(&header, &widths);

    let mut best: (f64, String) = (f64::MIN, String::new());
    for method in &methods {
        let mut cells = vec![method.clone(), String::new()];
        let mut task_means = Vec::new();
        let mut params = 0usize;
        for task in nlu::TASKS {
            let preset =
                if task == "stsb-sim" { "small-reg" } else { "small-cls" };
            let artifact = format!("{preset}_{method}");
            let tcfg = exp_train_cfg(steps, method_lr(method, lr));
            let mut vals = Vec::new();
            for s in 0..seeds {
                let r = run_scored(&rt, &reg, &artifact,
                                   &format!("nlu:{task}"), &tcfg, s as u64,
                                   LmScore::ExactInt, 0)?;
                vals.push(100.0 * r.metric);
                params = r.trainable_params;
            }
            task_means.push(stats::mean(&vals));
            cells.push(stats::fmt_mean_std(&vals));
        }
        let avg = stats::mean(&task_means);
        cells[1] = fmt_params(params);
        cells.push(format!("{avg:.2}"));
        print_row(&cells, &widths);
        if avg > best.0 {
            best = (avg, method.clone());
        }
    }
    println!("\nBest average: {} ({:.2}).  Paper shape: CoSA best/2nd-best \
              avg (83.23 base / 86.82 large) with ~1.1x VeRA params and \
              ~0.3x DoRA params.", best.1, best.0);
    Ok(())
}
