//! Figure 3: parameter and memory efficiency across model scales.
//! (a) trainable params, (b) memory incl. optimizer states, (c) CoSA
//! params relative to LoRA — at Llama-3.2-1B / Qwen2-7B / Llama-3.1-8B
//! dimensions with the paper's r=128 and (a,b)=(1024,256).

use crate::adapters::costmodel::{fmt_mb, fmt_params, total_params,
                                 train_memory_bytes, Arch, CostCfg};
use crate::adapters::Method;
use crate::exp::{print_header, print_row};
use crate::util::args::Args;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let r = args.usize("rank", 128);
    let a = args.usize("a", 1024);
    let b = args.usize("b", 256);
    let c = CostCfg { r, a, b, nola_k: 1024, full_params: 0 };
    println!("== Figure 3: parameter & memory efficiency \
              (r={r}, a={a}, b={b}) ==\n");
    let widths = [14, 12, 12, 12, 12, 12, 10];
    print_header(&["MODEL", "LoRA", "PiSSA", "CoSA", "LoRA mem",
                   "CoSA mem", "CoSA/LoRA"], &widths);
    for arch in Arch::paper_models() {
        let lora = total_params(Method::LoRA, &arch, &c);
        let pissa = total_params(Method::PiSSA, &arch, &c);
        let cosa = total_params(Method::CoSA, &arch, &c);
        let lmem = train_memory_bytes(Method::LoRA, &arch, &c);
        let cmem = train_memory_bytes(Method::CoSA, &arch, &c);
        print_row(&[
            arch.name.to_string(),
            fmt_params(lora),
            fmt_params(pissa),
            fmt_params(cosa),
            fmt_mb(lmem),
            fmt_mb(cmem),
            format!("{:.1}%", 100.0 * cosa as f64 / lora as f64),
        ], &widths);
    }
    println!("\nPaper reference: 1B 90M/29M, 7B 323M/51M, 8B 336M/58M \
              (LoRA/CoSA); CoSA < 32.6% of LoRA everywhere; memory cut \
              >60% at 8B scale.");
    Ok(())
}
