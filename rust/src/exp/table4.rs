//! Table 4 + Figure 4: empirical RIP validation of the Kronecker
//! dictionary.  δ_s per (config × sparsity) with spread across matrix
//! draws (Table 4 / Fig 4a), theory-vs-empirical + conservative factor
//! (Fig 4b/4c), dictionary coherence vs the recovery threshold (Fig 4d).

use crate::exp::{print_header, print_row};
use crate::rip::coherence::{kron_coherence, recovery_threshold};
use crate::rip::estimator::{rip_constant_trials, RipSetup};
use crate::rip::theory::{kron_rip_bound, DEFAULT_C};
use crate::util::args::Args;

pub const CONFIGS: [(usize, usize); 4] =
    [(32, 8), (64, 16), (128, 32), (256, 64)];
pub const SPARSITIES: [usize; 3] = [5, 10, 20];

pub fn run(args: &Args) -> anyhow::Result<()> {
    let samples = args.usize("samples", 1000);
    let trials = args.usize("trials", 3);
    let seed = args.u64("seed", 42);

    println!("== Table 4 / Fig 4a: empirical RIP constants \
              (m=512, n=256, N={samples}, {trials} matrix draws) ==\n");
    let widths = [12, 12, 16, 16, 16];
    print_header(&["CONFIG", "COMPRESSION", "delta_5", "delta_10",
                   "delta_20"], &widths);
    let mut deltas = vec![vec![0.0f64; SPARSITIES.len()]; CONFIGS.len()];
    for (ci, (a, b)) in CONFIGS.iter().enumerate() {
        let setup = RipSetup::paper(*a, *b);
        let mut cells = vec![format!("({a},{b})"),
                             format!("{:.0}x", setup.compression_ratio())];
        for (si, s) in SPARSITIES.iter().enumerate() {
            let (mean, std, _) =
                rip_constant_trials(setup, *s, samples, trials, seed);
            deltas[ci][si] = mean;
            cells.push(format!("{mean:.3} ±{std:.3}"));
        }
        print_row(&cells, &widths);
    }
    println!("\nPaper reference: 0.082–0.166 across the grid, decreasing \
              in s, all << 0.5 stability threshold.");

    println!("\n== Fig 4b/4c: theoretical bound vs empirical \
              (C={DEFAULT_C}) ==\n");
    let w2 = [12, 10, 12, 12, 14];
    print_header(&["CONFIG", "s", "empirical", "theory", "theory/emp"],
                 &w2);
    for (ci, (a, b)) in CONFIGS.iter().enumerate() {
        for (si, s) in SPARSITIES.iter().enumerate() {
            let th = kron_rip_bound(*s, 512, 256, *a, *b, DEFAULT_C);
            print_row(&[
                format!("({a},{b})"),
                s.to_string(),
                format!("{:.3}", deltas[ci][si]),
                format!("{th:.3}"),
                format!("{:.2}x", th / deltas[ci][si].max(1e-9)),
            ], &w2);
        }
    }

    println!("\n== Fig 4d: dictionary coherence ==\n");
    let w3 = [12, 12, 12, 12, 22];
    print_header(&["CONFIG", "mu(Psi)", "mu(L)", "mu(R)",
                   "recovery bound 1/sqrt(20)"], &w3);
    for (a, b) in CONFIGS {
        let (mu, mul, mur) = kron_coherence(512, 256, a, b, seed);
        let thr = recovery_threshold(20);
        print_row(&[
            format!("({a},{b})"),
            format!("{mu:.3}"),
            format!("{mul:.3}"),
            format!("{mur:.3}"),
            format!("{:.3} ({})", thr, if mu < thr { "OK" } else { "VIOLATED" }),
        ], &w3);
    }
    println!("\nPaper reference: mu in 0.163–0.219, all below 0.224.");
    Ok(())
}
