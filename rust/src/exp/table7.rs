//! Table 7: wider PEFT baseline sweep (DoRA / VeRA / NoLA vs CoSA) on the
//! math-reasoning task — the App. D.2 complement of Table 3.

use crate::adapters::costmodel::fmt_params;
use crate::exp::harness::{exp_train_cfg, method_lr, run_scored, LmScore};
use crate::exp::{print_header, print_row};
use crate::math::stats;
use crate::runtime::executor::Runtime;
use crate::runtime::Registry;
use crate::util::args::Args;

pub const METHODS: [&str; 6] =
    ["lora", "pissa", "vera", "dora", "nola", "cosa"];

pub fn run(args: &Args) -> anyhow::Result<()> {
    let steps = args.usize("steps", 150);
    let seeds = args.usize("seeds", 2);
    let decode_n = args.usize("decode", 64);
    let lr = args.f64("lr", 2e-3);
    let rt = Runtime::cpu()?;
    let reg = Registry::open_default()?;

    println!("== Table 7 (PEFT baselines on math): small-lm, {steps} \
              steps, {seeds} seeds ==\n");
    let widths = [9, 10, 16, 12];
    print_header(&["METHOD", "PARAMS", "GSM8K-sim", "eval loss"], &widths);
    for method in METHODS {
        let artifact = format!("small-lm_{method}");
        let tcfg = exp_train_cfg(steps, method_lr(method, lr));
        let mut vals = Vec::new();
        let mut losses = Vec::new();
        let mut params = 0;
        for s in 0..seeds {
            let r = run_scored(&rt, &reg, &artifact, "math", &tcfg,
                               s as u64, LmScore::ExactInt, decode_n)?;
            vals.push(100.0 * r.metric);
            losses.push(r.eval_loss);
            params = r.trainable_params;
        }
        print_row(&[
            method.to_string(),
            fmt_params(params),
            stats::fmt_mean_std(&vals),
            format!("{:.3}", stats::mean(&losses)),
        ], &widths);
    }
    println!("\nPaper shape (Llama-3.1-8B): CoSA 77.18 GSM8K at 58M params \
              beats LoRA/DoRA/NoLA/VeRA; only PiSSA (336M) edges it.");
    Ok(())
}
