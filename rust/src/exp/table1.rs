//! Table 1: trainable parameters and training complexities per method —
//! asymptotic rows plus a concrete instantiation at paper NLG scale.

use crate::adapters::costmodel::{fmt_params, site_params, table1_row,
                                 CostCfg, Site};
use crate::adapters::Method;
use crate::exp::{print_header, print_row};
use crate::util::args::Args;

pub fn run(_args: &Args) -> anyhow::Result<()> {
    println!("== Table 1: trainable params and training complexities ==\n");
    let widths = [10, 12, 14, 10, 14];
    print_header(&["METHOD", "PARAMS", "OPT. STATE", "FWD/BWD", "STORAGE"],
                 &widths);
    let methods = [Method::LoRA, Method::PiSSA, Method::DoRA, Method::VeRA,
                   Method::CoSA];
    for m in methods {
        let (p, o, f, s) = table1_row(m);
        print_row(&[m.paper_name().to_string(), p.into(), o.into(),
                    f.into(), s.into()], &widths);
    }

    println!("\nConcrete instantiation (one 4096×4096 site, r=128, \
              (a,b)=(1024,256)):");
    let site = Site { n_in: 4096, n_out: 4096 };
    let c = CostCfg { r: 128, a: 1024, b: 256, nola_k: 1024,
                      full_params: 4096 * 4096 };
    print_header(&["METHOD", "PARAMS", "vs LoRA"], &[10, 12, 10]);
    let lora = site_params(Method::LoRA, site, &c) as f64;
    for m in [Method::Full, Method::LoRA, Method::PiSSA, Method::DoRA,
              Method::VeRA, Method::CoSA] {
        let p = site_params(m, site, &c);
        print_row(&[m.paper_name().to_string(), fmt_params(p),
                    format!("{:.2}x", p as f64 / lora)], &[10, 12, 10]);
    }
    println!("\nShape check (paper): CoSA ab=262144 = 0.25x LoRA's \
              (m+n)r=1048576 at this site; VeRA cheapest; DoRA > LoRA.");
    Ok(())
}
