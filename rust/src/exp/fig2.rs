//! Figure 2: performance across compression pairs (a, b) — the paper's
//! central ablation.  Sweeps the pre-lowered (a, b) grid on `tiny-lm`
//! with the math task and prints the heatmap plus the symmetric-pair
//! (a > b vs a < b) comparison the paper highlights.

use crate::exp::harness::{exp_train_cfg, run_scored, LmScore};
use crate::exp::{print_header, print_row};
use crate::runtime::executor::Runtime;
use crate::runtime::Registry;
use crate::util::args::Args;

/// The grid lowered by `presets.py` (symmetric diagonal + asymmetric
/// pairs mirroring the paper's ▲/▼ analysis).
pub const GRID: [(usize, usize); 8] = [
    (16, 16), (32, 32), (64, 64), (96, 96),
    (32, 96), (96, 32), (16, 64), (64, 16),
];

pub fn run(args: &Args) -> anyhow::Result<()> {
    let steps = args.usize("steps", 120);
    let decode_n = args.usize("decode", 64);
    let lr = args.f64("lr", 3e-3);
    let rt = Runtime::cpu()?;
    let reg = Registry::open_default()?;

    println!("== Figure 2: compression-pair (a,b) sweep \
              (tiny-lm, math, {steps} steps) ==\n");
    let widths = [12, 10, 12, 12];
    print_header(&["(a,b)", "PARAMS", "EXACT MATCH", "eval loss"], &widths);
    let mut scores = Vec::new();
    for (a, b) in GRID {
        let artifact = format!("tiny-lm_cosa-a{a}b{b}");
        let tcfg = exp_train_cfg(steps, lr);
        let r = run_scored(&rt, &reg, &artifact, "math", &tcfg, 0,
                           LmScore::ExactInt, decode_n)?;
        scores.push(((a, b), 100.0 * r.metric));
        print_row(&[
            format!("({a},{b})"),
            r.trainable_params.to_string(),
            format!("{:.1}%", 100.0 * r.metric),
            format!("{:.3}", r.eval_loss),
        ], &widths);
    }

    println!("\n-- symmetric-pair asymmetry (paper: enlarging a, the");
    println!("   input-side dim, beats enlarging b) --");
    for ((hi, lo), (lo2, hi2)) in [((96, 32), (32, 96)), ((64, 16), (16, 64))]
    {
        let s_a = scores.iter().find(|(c, _)| *c == (hi, lo)).unwrap().1;
        let s_b = scores.iter().find(|(c, _)| *c == (lo2, hi2)).unwrap().1;
        let mark = if s_a >= s_b { "▲ a>b wins" } else { "▼ a<b wins" };
        println!("  ({hi},{lo}) {s_a:.1}%  vs  ({lo2},{hi2}) {s_b:.1}%   {mark}");
    }
    println!("\nPaper shape: rapid rise from small (a,b), plateau at large; \
              (512,128) > (128,512) by 5.4pts at Llama-1B scale.");
    Ok(())
}
