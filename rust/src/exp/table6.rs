//! Table 6: arithmetic-reasoning task families (MultiArith/AddSub/AQuA/…
//! analogues) — CoSA vs LoRA across the seven synthetic math families.

use crate::adapters::costmodel::fmt_params;
use crate::data::mathgen::Family;
use crate::exp::harness::{exp_train_cfg, method_lr, run_scored, LmScore};
use crate::exp::{print_header, print_row};
use crate::math::stats;
use crate::runtime::executor::Runtime;
use crate::runtime::Registry;
use crate::util::args::Args;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let steps = args.usize("steps", 150);
    let decode_n = args.usize("decode", 48);
    let lr = args.f64("lr", 2e-3);
    let methods: Vec<String> = match args.opt("methods") {
        Some(m) => m.split(',').map(str::to_string).collect(),
        None => vec!["lora".into(), "dora".into(), "cosa".into()],
    };
    let rt = Runtime::cpu()?;
    let reg = Registry::open_default()?;

    println!("== Table 6 (arithmetic families): small-lm, {steps} steps ==\n");
    let mut widths = vec![9usize, 10];
    widths.extend(std::iter::repeat(11).take(Family::ALL.len()));
    widths.push(8);
    let mut header = vec!["METHOD".to_string(), "PARAMS".to_string()];
    header.extend(Family::ALL.iter().map(|f| f.name().to_string()));
    header.push("AVG".to_string());
    print_header(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
                 &widths);

    for method in &methods {
        let artifact = format!("small-lm_{method}");
        let tcfg = exp_train_cfg(steps, method_lr(method, lr));
        let mut cells = vec![method.clone(), String::new()];
        let mut means = Vec::new();
        let mut params = 0;
        for fam in Family::ALL {
            let task = format!("math:{}", fam.name().to_lowercase());
            let r = run_scored(&rt, &reg, &artifact, &task, &tcfg, 0,
                               LmScore::ExactInt, decode_n)?;
            means.push(100.0 * r.metric);
            params = r.trainable_params;
            cells.push(format!("{:.1}", 100.0 * r.metric));
        }
        cells[1] = fmt_params(params);
        cells.push(format!("{:.2}", stats::mean(&means)));
        print_row(&cells, &widths);
    }
    println!("\nPaper shape: CoSA 79.5 avg at 29.4M params vs LoRA 77.2 @ \
              56.2M and DoRA 77.5 @ 57M — competitive at the fewest \
              parameters.");
    Ok(())
}
