//! Appendix B.3: structure of trained Y cores — sparsity fraction,
//! effective rank at 95% spectral energy, Frobenius norms and the
//! fraction of layers with non-trivial learned structure.
//!
//! Trains a quick CoSA run (or loads `--ckpt`) and analyzes every core.

use crate::config::RunConfig;
use crate::exp::harness::exp_train_cfg;
use crate::exp::{print_header, print_row};
use crate::math::matrix::Matrix;
use crate::math::stats;
use crate::math::svd::jacobi_svd;
use crate::runtime::executor::Runtime;
use crate::runtime::Registry;
use crate::train::checkpoint::Checkpoint;
use crate::train::Trainer;
use crate::util::args::Args;

/// Effective rank: #singular values holding 95% of spectral energy.
pub fn effective_rank(m: &Matrix, energy: f64) -> usize {
    let (_, s, _) = jacobi_svd(m);
    let total: f64 = s.iter().map(|x| (*x as f64) * (*x as f64)).sum();
    if total <= 0.0 {
        return 0;
    }
    let mut acc = 0.0;
    for (i, v) in s.iter().enumerate() {
        acc += (*v as f64) * (*v as f64);
        if acc >= energy * total {
            return i + 1;
        }
    }
    s.len()
}

pub fn run(args: &Args) -> anyhow::Result<()> {
    let tensors: Vec<(String, Vec<usize>, Vec<f32>)> =
        if let Some(path) = args.opt("ckpt") {
            let ck = Checkpoint::load(std::path::Path::new(path))?;
            ck.tensors.into_iter().map(|(n, (s, v))| (n, s, v)).collect()
        } else {
            // quick training run to obtain non-trivial cores
            let steps = args.usize("steps", 80);
            let rt = Runtime::cpu()?;
            let reg = Registry::open_default()?;
            let cfg = RunConfig {
                name: "ystruct".into(),
                artifact: "small-lm_cosa".into(),
                task: "math".into(),
                train: exp_train_cfg(steps, 2e-3),
                ..RunConfig::default()
            };
            let mut tr = Trainer::new(&rt, &reg, cfg)?;
            tr.run()?;
            tr.train_exec
                .meta
                .inputs_with_role("trainable")
                .iter()
                .map(|s| {
                    Ok((s.name.clone(), s.shape.clone(),
                        tr.state.read(&s.name)?))
                })
                .collect::<anyhow::Result<Vec<_>>>()?
        };

    println!("== App. B.3: trained-Y structure ({} cores) ==\n",
             tensors.len());
    let mut sparsities = Vec::new();
    let mut ranks = Vec::new();
    let mut fronorms = Vec::new();
    let mut nontrivial = 0usize;
    for (_, shape, vals) in &tensors {
        if shape.len() != 2 {
            continue;
        }
        let m = Matrix::from_vec(shape[0], shape[1], vals.clone());
        let thresh = 1e-4f32;
        let frac_small = vals.iter().filter(|v| v.abs() < thresh).count()
            as f64 / vals.len() as f64;
        sparsities.push(frac_small);
        let fro = m.frobenius();
        fronorms.push(fro);
        if fro > 1e-6 {
            nontrivial += 1;
            ranks.push(effective_rank(&m, 0.95) as f64);
        }
    }
    let widths = [34, 16];
    print_header(&["STATISTIC", "VALUE"], &widths);
    print_row(&["cores analyzed".into(), tensors.len().to_string()],
              &widths);
    print_row(&["mean sparsity (<1e-4)".into(),
                format!("{:.1}%", 100.0 * stats::mean(&sparsities))],
              &widths);
    print_row(&["mean effective rank (95% energy)".into(),
                format!("{:.1}", stats::mean(&ranks))], &widths);
    print_row(&["mean Frobenius norm".into(),
                format!("{:.4}", stats::mean(&fronorms))], &widths);
    print_row(&["non-trivial cores".into(),
                format!("{}/{} ({:.1}%)", nontrivial, tensors.len(),
                        100.0 * nontrivial as f64
                            / tensors.len().max(1) as f64)],
              &widths);
    println!("\nPaper reference (RoBERTa-base CoLA, 128x128 cores): 31.2% \
              sparsity, effective rank ~63, Frobenius ~0.05, 98.7% \
              non-trivial.");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Pcg64;

    #[test]
    fn effective_rank_of_lowrank_matrix() {
        let mut rng = Pcg64::new(1);
        let u = Matrix::gaussian(20, 3, 1.0, &mut rng);
        let v = Matrix::gaussian(3, 16, 1.0, &mut rng);
        let m = u.matmul(&v);
        let r = effective_rank(&m, 0.95);
        assert!(r <= 3, "rank-3 matrix reported effective rank {r}");
        assert!(r >= 1);
    }

    #[test]
    fn effective_rank_zero_matrix() {
        assert_eq!(effective_rank(&Matrix::zeros(8, 8), 0.95), 0);
    }

    #[test]
    fn effective_rank_identity_is_full() {
        let m = Matrix::identity(6);
        assert!(effective_rank(&m, 0.95) >= 5);
    }
}
