//! Shared experiment harness: train (artifact × task × seed) bundles and
//! score them with the paper's metric for that benchmark.

use crate::config::{RunConfig, Schedule, TrainConfig};
use crate::data::{LmDataset, Vocab};
use crate::eval;
use crate::runtime::executor::Runtime;
use crate::runtime::Registry;
use crate::train::{TaskData, Trainer};

/// Decode-based LM scoring mode.
#[derive(Clone, Copy, Debug)]
pub enum LmScore {
    /// GSM8K/MATH style: integer exact match.
    ExactInt,
    /// HumanEval/MBPP style: execution-checked answer (same decode, the
    /// gold completion *is* the executed output).
    PassAt1,
    /// MT-Bench style rubric judge (0–10).
    Judge,
}

/// One scored training run.
pub struct Scored {
    pub train_loss_first: f64,
    pub train_loss_last: f64,
    pub eval_loss: f64,
    /// Task metric in [0,1] (or 0–10 for Judge).
    pub metric: f64,
    pub trainable_params: usize,
}

/// Train one run and compute its final metric.
pub fn run_scored(
    rt: &Runtime,
    reg: &Registry,
    artifact: &str,
    task: &str,
    tcfg: &TrainConfig,
    seed: u64,
    lm_score: LmScore,
    decode_n: usize,
) -> anyhow::Result<Scored> {
    let cfg = RunConfig {
        name: format!("{artifact}-{task}-s{seed}"),
        artifact: artifact.to_string(),
        task: task.to_string(),
        train: tcfg.clone(),
        base_seed: 42, // shared trunk across methods: paired comparison
        adapter_seed: 1000 + seed,
        data_seed: 7000 + seed,
        out_dir: "runs/exp".into(),
        ..RunConfig::default()
    };
    let mut trainer = Trainer::new(rt, reg, cfg)?;
    crate::debug!("exp run `{artifact}` on {}", crate::linalg::describe());
    trainer.run()?;
    let (eval_loss, fast_metric) = trainer.evaluate()?;
    let params = trainer.train_exec.meta.trainable_param_count();

    let metric = match &trainer.data {
        TaskData::Cls(_) => fast_metric,
        TaskData::Lm(d) => {
            score_lm(&trainer, d, lm_score, decode_n)?
        }
    };
    Ok(Scored {
        train_loss_first: trainer.log.first_loss(),
        train_loss_last: trainer.log.recent_loss(10),
        eval_loss,
        metric,
        trainable_params: params,
    })
}

fn score_lm(trainer: &Trainer, d: &LmDataset, mode: LmScore,
            decode_n: usize) -> anyhow::Result<f64> {
    let n = decode_n.min(d.eval.len());
    let exs: Vec<&_> = d.eval[..n].iter().collect();
    let gen = eval::greedy_decode(&trainer.eval_exec, &trainer.state, &exs,
                                  16)?;
    let v = Vocab::new(trainer.eval_exec.meta.model.vocab);
    Ok(match mode {
        LmScore::ExactInt | LmScore::PassAt1 =>
            eval::exact_match_int(&v, &exs, &gen),
        LmScore::Judge => eval::judge_score(&exs, &gen),
    })
}

/// Default train config for the table experiments (scaled-down analogue
/// of App. C; override via CLI flags).
pub fn exp_train_cfg(steps: usize, lr: f64) -> TrainConfig {
    TrainConfig {
        steps,
        lr,
        weight_decay: 0.01,
        clip_norm: 1.0,
        schedule: Schedule::CosineWarmup { warmup_frac: 0.06 },
        eval_every: 0, // experiments evaluate once at the end
        log_every: 0,
        grad_accum: 1,
    }
}

/// Per-method LR scaling: full FT needs a smaller step than adapter
/// methods (App. C uses 1e-5 vs 2e-5..4e-4); vector-parameterized methods
/// (VeRA) train hotter.
pub fn method_lr(method: &str, base: f64) -> f64 {
    match method {
        "full" => base * 0.1,
        "vera" => base * 10.0,
        "nola" => base * 10.0,
        _ => base,
    }
}
