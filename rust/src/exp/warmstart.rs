//! Transferability ablation (paper §4.1): "the same random projections
//! support many tasks with only Y retrained … enabling plug-and-play
//! reuse and warm-starts of Y between tasks."
//!
//! Protocol: train CoSA on task A (mixed arithmetic), then fine-tune on
//! task B (the held-out Expr3 family) either from scratch (Y = 0) or
//! warm-started from task A's core.  Because L/R are task-agnostic and
//! shared, the warm-started core should converge faster — the claim this
//! experiment checks.

use crate::config::RunConfig;
use crate::exp::harness::exp_train_cfg;
use crate::exp::{print_header, print_row};
use crate::runtime::executor::Runtime;
use crate::runtime::Registry;
use crate::train::Trainer;
use crate::util::args::Args;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let steps_a = args.usize("steps-a", 150);
    let steps_b = args.usize("steps-b", 60);
    let rt = Runtime::cpu()?;
    let reg = Registry::open_default()?;

    let mk_cfg = |name: &str, task: &str, steps: usize| RunConfig {
        name: name.into(),
        artifact: "small-lm_cosa".into(),
        task: task.into(),
        train: exp_train_cfg(steps, 2e-3),
        out_dir: "runs/warmstart".into(),
        ..RunConfig::default()
    };

    println!("== Warm-start transfer (paper §4.1 claim) ==\n");
    // Phase A: source task
    let mut source = Trainer::new(&rt, &reg,
                                  mk_cfg("ws-source", "math", steps_a))?;
    source.run()?;
    let ck_path = std::path::Path::new("runs/warmstart/source.ckpt");
    source.save_checkpoint(ck_path)?;
    println!("source task (mixed math): loss {:.3} -> {:.3}\n",
             source.log.first_loss(), source.log.recent_loss(10));

    // Phase B: target task, cold vs warm
    let mut results = Vec::new();
    for (label, warm) in [("cold (Y=0)", false), ("warm-start Y", true)] {
        let mut t = Trainer::new(&rt, &reg,
                                 mk_cfg(&format!("ws-{label}"),
                                        "math:expr3", steps_b))?;
        if warm {
            let ck = crate::train::checkpoint::Checkpoint::load(ck_path)?;
            t.load_checkpoint(&ck)?;
            t.state.step = 0; // fresh optimizer schedule on the new task
        }
        let (loss0, _) = t.evaluate()?;
        t.run()?;
        let (loss1, acc1) = t.evaluate()?;
        results.push((label.to_string(), loss0, loss1, acc1,
                      t.log.rows.iter().map(|r| r.2).collect::<Vec<f64>>()));
    }

    let widths = [16, 14, 14, 12];
    print_header(&["INIT", "eval loss t=0", "eval loss end", "token acc"],
                 &widths);
    for (label, l0, l1, acc, _) in &results {
        print_row(&[label.clone(), format!("{l0:.3}"), format!("{l1:.3}"),
                    format!("{acc:.3}")], &widths);
    }
    // steps to reach the cold run's final train loss
    let cold_final = results[0].4.last().copied().unwrap_or(f64::NAN);
    let warm_hits = results[1].4.iter().position(|l| *l <= cold_final);
    println!(
        "\nwarm-start reaches the cold run's final loss after {} / {} steps",
        warm_hits.map_or("never".into(), |s| s.to_string()),
        steps_b
    );
    println!("Expected shape: warm-started Y starts at lower eval loss on \
              the transfer task and reaches the cold baseline in fewer \
              steps (shared L/R coordinate system).");
    Ok(())
}
