//! Table 5: GLUE fine-tuning hyperparameters (App. C.1), encoded as the
//! presets our GLUE-sim runs key off.

use crate::config::presets::{table5, GLUE_AB, NLG_AB};
use crate::exp::{print_header, print_row};
use crate::util::args::Args;

pub fn run(_args: &Args) -> anyhow::Result<()> {
    println!("== Table 5: GLUE hyperparameters (paper App. C.1) ==\n");
    let widths = [8, 8, 8, 8, 10, 8, 8];
    print_header(&["METHOD", "MODEL", "TASK", "EPOCHS", "LR", "BATCH",
                   "ALPHA"], &widths);
    for r in table5() {
        print_row(&[
            r.method.to_string(),
            r.model.to_string(),
            r.task.to_string(),
            r.epochs.to_string(),
            format!("{:.0e}", r.lr),
            r.batch.to_string(),
            format!("{}", r.alpha),
        ], &widths);
    }
    println!("\nDefault compression dims: GLUE (a,b)={GLUE_AB:?}, \
              NLG (a,b)={NLG_AB:?}.");
    Ok(())
}
