//! Experiment registry: one module per paper table/figure.
//! `cosa-repro exp <id>` regenerates the corresponding rows/series.

pub mod fig2;
pub mod harness;
pub mod fig3;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;
pub mod table7;
pub mod table8;
pub mod warmstart;
pub mod ystruct;

use crate::util::args::Args;

pub const ALL: [&str; 12] = [
    "table1", "table2", "table3", "table4", "table5", "table6", "table7",
    "table8", "fig2", "fig3", "ystruct", "warmstart",
];

/// Dispatch one experiment id.
pub fn run(id: &str, args: &Args) -> anyhow::Result<()> {
    match id {
        "table1" => table1::run(args),
        "table2" => table2::run(args),
        "table3" => table3::run(args),
        "table4" | "fig4" => table4::run(args),
        "table5" => table5::run(args),
        "table6" => table6::run(args),
        "table7" => table7::run(args),
        "table8" => table8::run(args),
        "fig2" => fig2::run(args),
        "fig3" => fig3::run(args),
        "ystruct" => ystruct::run(args),
        "warmstart" => warmstart::run(args),
        other => anyhow::bail!("unknown experiment `{other}` (try one of {ALL:?})"),
    }
}

/// Shared pretty-printer: fixed-width table row.
pub fn print_row(cells: &[String], widths: &[usize]) {
    let mut line = String::new();
    for (c, w) in cells.iter().zip(widths) {
        line.push_str(&format!("{c:<w$}  ", w = w));
    }
    println!("{}", line.trim_end());
}

pub fn print_header(cells: &[&str], widths: &[usize]) {
    print_row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
              widths);
    let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
    println!("{}", "-".repeat(total));
}
