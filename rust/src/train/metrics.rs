//! Training metrics log: in-memory history + CSV export for loss curves
//! (the E2E example's deliverable in EXPERIMENTS.md).

use std::path::Path;

#[derive(Clone, Debug, Default)]
pub struct MetricsLog {
    /// (step, lr, loss, token/cls accuracy)
    pub rows: Vec<(usize, f64, f64, f64)>,
    /// (step, eval_loss, eval_metric)
    pub evals: Vec<(usize, f64, f64)>,
}

impl MetricsLog {
    pub fn push_train(&mut self, step: usize, lr: f64, loss: f64, acc: f64) {
        self.rows.push((step, lr, loss, acc));
    }

    pub fn push_eval(&mut self, step: usize, loss: f64, metric: f64) {
        self.evals.push((step, loss, metric));
    }

    /// Mean training loss over the last `k` logged steps.
    pub fn recent_loss(&self, k: usize) -> f64 {
        let tail = &self.rows[self.rows.len().saturating_sub(k)..];
        if tail.is_empty() {
            return f64::NAN;
        }
        tail.iter().map(|r| r.2).sum::<f64>() / tail.len() as f64
    }

    pub fn first_loss(&self) -> f64 {
        self.rows.first().map_or(f64::NAN, |r| r.2)
    }

    pub fn save_csv(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = String::from("step,lr,loss,acc\n");
        for (s, lr, l, a) in &self.rows {
            out.push_str(&format!("{s},{lr:.3e},{l:.6},{a:.6}\n"));
        }
        std::fs::write(path, out)?;
        if !self.evals.is_empty() {
            let mut ev = String::from("step,eval_loss,eval_metric\n");
            for (s, l, m) in &self.evals {
                ev.push_str(&format!("{s},{l:.6},{m:.6}\n"));
            }
            std::fs::write(path.with_extension("eval.csv"), ev)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recent_loss_window() {
        let mut m = MetricsLog::default();
        for i in 0..10 {
            m.push_train(i, 1e-3, 10.0 - i as f64, 0.0);
        }
        assert!((m.recent_loss(2) - 1.5).abs() < 1e-9);
        assert_eq!(m.first_loss(), 10.0);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut m = MetricsLog::default();
        m.push_train(1, 2e-5, 3.25, 0.5);
        m.push_eval(1, 3.0, 0.6);
        let path = std::env::temp_dir().join("cosa_metrics_test/t.csv");
        m.save_csv(&path).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.starts_with("step,lr,loss,acc\n"));
        assert!(s.contains("3.25"));
        assert!(path.with_extension("eval.csv").exists());
    }
}
