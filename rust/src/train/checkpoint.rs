//! Adapter checkpoints — the paper's storage format (§4.1/§4.2):
//! the trainable tensors plus the adapter seed; CoSA's fixed projections
//! are *not* stored, they regenerate from the seed at load time.
//!
//! File layout: `b"COSA"` magic, u32 header length, JSON header
//! (method cfg, seed, ordered tensor names + shapes), then raw
//! little-endian f32 blobs in header order.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::util::json::{obj, Json};

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub method: String,
    pub adapter_seed: u64,
    pub artifact: String,
    pub step: u64,
    /// name → (shape, values), insertion-ordered by name (BTreeMap).
    pub tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

const MAGIC: &[u8; 4] = b"COSA";

impl Checkpoint {
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let names: Vec<Json> = self
            .tensors
            .iter()
            .map(|(n, (shape, _))| {
                obj(vec![
                    ("name", Json::Str(n.clone())),
                    ("shape",
                     Json::Arr(shape.iter().map(|s| Json::from(*s)).collect())),
                ])
            })
            .collect();
        let header = obj(vec![
            ("method", Json::Str(self.method.clone())),
            ("adapter_seed", Json::from(self.adapter_seed as usize)),
            ("artifact", Json::Str(self.artifact.clone())),
            ("step", Json::from(self.step as usize)),
            ("tensors", Json::Arr(names)),
        ])
        .to_string();

        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, (_, vals)) in &self.tensors {
            for v in vals {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a COSA checkpoint");
        let mut len = [0u8; 4];
        f.read_exact(&mut len)?;
        let mut header = vec![0u8; u32::from_le_bytes(len) as usize];
        f.read_exact(&mut header)?;
        let j = Json::parse(std::str::from_utf8(&header)?)?;

        let mut tensors = BTreeMap::new();
        for t in j.req("tensors")?.as_arr().unwrap_or(&[]) {
            let name = t.req("name")?.as_str().unwrap_or("").to_string();
            let shape: Vec<usize> = t
                .req("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_usize())
                .collect();
            let n: usize = shape.iter().product::<usize>().max(1);
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            let vals: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(name, (shape, vals));
        }
        Ok(Checkpoint {
            method: j.req("method")?.as_str().unwrap_or("").to_string(),
            adapter_seed: j.req("adapter_seed")?.as_i64().unwrap_or(0) as u64,
            artifact: j.req("artifact")?.as_str().unwrap_or("").to_string(),
            step: j.req("step")?.as_i64().unwrap_or(0) as u64,
            tensors,
        })
    }

    /// Bytes on disk (Figure 3 storage accounting cross-check).
    pub fn size_bytes(&self) -> usize {
        let data: usize =
            self.tensors.values().map(|(_, v)| v.len() * 4).sum();
        data + 64 // magic + header order-of-magnitude
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut tensors = BTreeMap::new();
        tensors.insert("adp.0.wq.y".to_string(),
                       (vec![4, 2], vec![0.5f32; 8]));
        tensors.insert("adp.1.w1.y".to_string(),
                       (vec![2, 3], vec![-1.25f32, 0.0, 3.5, 7.0, 8.0, 9.0]));
        Checkpoint {
            method: "cosa".into(),
            adapter_seed: 1234,
            artifact: "tiny-lm_cosa".into(),
            step: 42,
            tensors,
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("adapter.cosa");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.method, "cosa");
        assert_eq!(back.adapter_seed, 1234);
        assert_eq!(back.step, 42);
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.tensors["adp.1.w1.y"].0, vec![2, 3]);
        assert_eq!(back.tensors["adp.1.w1.y"].1[3], 7.0);
        assert_eq!(back.tensors["adp.0.wq.y"].1, vec![0.5f32; 8]);
    }

    #[test]
    fn rejects_non_checkpoint() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn cosa_checkpoint_is_core_plus_seed_sized() {
        let ck = sample();
        let params: usize = ck.tensors.values().map(|(_, v)| v.len()).sum();
        assert!(ck.size_bytes() < params * 4 + 128,
                "no hidden projection storage");
    }
}
