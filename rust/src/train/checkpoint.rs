//! Adapter checkpoints — the paper's storage format (§4.1/§4.2):
//! the trainable tensors plus the adapter seed; CoSA's fixed projections
//! are *not* stored, they regenerate from the seed at load time.
//!
//! File layout: `b"COSA"` magic, u32 header length, JSON header
//! (format version, method cfg, seed, ordered tensor names + shapes,
//! optional site blocks), then raw little-endian f32 blobs in header
//! order.
//!
//! ## Format versions
//!
//! * **v1** (PR 0–3 era): no `version` key, no site metadata.  Tensors
//!   only — a serving registry has to guess which `*.y` tensor adapts
//!   which site.  Still loaded (as `version == 1`, `sites` empty); a
//!   1-site [`model::AdaptedModel`](crate::model::AdaptedModel) accepts
//!   such files unchanged.
//! * **v2**: `version: 2` plus a `sites` array — one
//!   `{name, m, n, a, b}` block per adapted site, where `name` is the
//!   tensor stem (`<name>.y` must exist with shape `[a, b]`; the
//!   projections regenerate from `<name>.l` / `<name>.r`).  One adapter
//!   name thus saves/loads **all** of its per-site cores.  Loaders
//!   reject corrupt site blocks (missing/mis-shaped core tensors,
//!   duplicate names) instead of serving from them.
//! * **v3** (current writer): each site block additionally carries a
//!   `method` tag (`"cosa"` / `"lora"` / `"rosa"`), and the tensors a
//!   block must describe depend on it — CoSA stores `<name>.y`
//!   `[a, b]`, LoRA `<name>.lora_b` `[m, r]` + `<name>.lora_a`
//!   `[r, n]`, RoSA those two plus `<name>.rosa_s` `[m, n]` (low-rank
//!   blocks record `a = b = r`).  An absent `method` key reads as
//!   `"cosa"`, which is exactly how v2 files load unchanged.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::util::json::{obj, Json};

/// One site block (v2+): the adapted weight is `m × n`, the core
/// `a × b` (low-rank methods record `a = b = r`), `name` is the tensor
/// stem its tensors derive from, and `method` (v3; `"cosa"` when the
/// key is absent) picks which tensors the stem must carry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CkptSite {
    pub name: String,
    pub m: usize,
    pub n: usize,
    pub a: usize,
    pub b: usize,
    pub method: String,
}

#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Format version this checkpoint was *loaded* from (1 for legacy
    /// files).  `save` always writes the current format
    /// ([`FORMAT_VERSION`]).
    pub version: u32,
    pub method: String,
    pub adapter_seed: u64,
    pub artifact: String,
    pub step: u64,
    /// Site blocks (v2+); empty for v1 files (and for site-less saves).
    pub sites: Vec<CkptSite>,
    /// name → (shape, values), insertion-ordered by name (BTreeMap).
    pub tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

const MAGIC: &[u8; 4] = b"COSA";

/// The format `save` writes.  Readers accept 1..=FORMAT_VERSION.
pub const FORMAT_VERSION: u32 = 3;

/// Element count of a shape.  The empty shape is a scalar (1 element,
/// the numpy convention); any zero dimension means zero elements.
fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl Checkpoint {
    /// The serialized JSON header — shared by `save` and `size_bytes` so
    /// storage accounting always matches the bytes actually written.
    ///
    /// `adapter_seed` is serialized as a decimal *string*: the JSON
    /// number path goes through f64, which corrupts seeds ≥ 2⁵³ — and a
    /// corrupted seed silently regenerates different L/R projections,
    /// the one thing §4.1 requires to be bit-stable.
    fn header_json(&self) -> String {
        let names: Vec<Json> = self
            .tensors
            .iter()
            .map(|(n, (shape, _))| {
                obj(vec![
                    ("name", Json::Str(n.clone())),
                    ("shape",
                     Json::Arr(shape.iter().map(|s| Json::from(*s)).collect())),
                ])
            })
            .collect();
        let mut fields = vec![
            ("version", Json::from(FORMAT_VERSION as usize)),
            ("method", Json::Str(self.method.clone())),
            ("adapter_seed", Json::Str(self.adapter_seed.to_string())),
            ("artifact", Json::Str(self.artifact.clone())),
            ("step", Json::from(self.step as usize)),
            ("tensors", Json::Arr(names)),
        ];
        if !self.sites.is_empty() {
            let sites: Vec<Json> = self
                .sites
                .iter()
                .map(|s| {
                    obj(vec![
                        ("name", Json::Str(s.name.clone())),
                        ("m", Json::from(s.m)),
                        ("n", Json::from(s.n)),
                        ("a", Json::from(s.a)),
                        ("b", Json::from(s.b)),
                        ("method", Json::Str(s.method.clone())),
                    ])
                })
                .collect();
            fields.push(("sites", Json::Arr(sites)));
        }
        obj(fields).to_string()
    }

    /// Every site block must describe the real tensors its method
    /// stores — names unique, dims nonzero, shapes agreeing with the
    /// block.  Run on both save (never write a corrupt block) and load
    /// (never serve from one).
    fn validate_sites(
        sites: &[CkptSite],
        tensors: &BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    ) -> anyhow::Result<()> {
        let want = |site: &str,
                    tname: String,
                    rows: usize,
                    cols: usize|
         -> anyhow::Result<()> {
            let Some((shape, _)) = tensors.get(&tname) else {
                anyhow::bail!(
                    "site `{site}` declares `{tname}` but it is missing"
                );
            };
            anyhow::ensure!(
                shape.as_slice() == [rows, cols],
                "site `{site}`: `{tname}` has shape {shape:?}, site block \
                 says [{rows}, {cols}]"
            );
            Ok(())
        };
        for (i, s) in sites.iter().enumerate() {
            anyhow::ensure!(
                !s.name.is_empty(),
                "site block {i} has an empty name"
            );
            anyhow::ensure!(
                s.m >= 1 && s.n >= 1 && s.a >= 1 && s.b >= 1,
                "site `{}`: every dim must be >= 1 (m {} n {} a {} b {})",
                s.name, s.m, s.n, s.a, s.b
            );
            if sites[..i].iter().any(|t| t.name == s.name) {
                anyhow::bail!("duplicate site block `{}`", s.name);
            }
            match s.method.as_str() {
                "cosa" => {
                    want(&s.name, format!("{}.y", s.name), s.a, s.b)?;
                }
                "lora" | "rosa" => {
                    // low-rank blocks record a = b = r
                    anyhow::ensure!(
                        s.a == s.b,
                        "site `{}`: {} blocks record a = b = rank, got \
                         a {} b {}",
                        s.name, s.method, s.a, s.b
                    );
                    let p = &s.method;
                    want(&s.name, format!("{}.{p}_b", s.name), s.m, s.a)?;
                    want(&s.name, format!("{}.{p}_a", s.name), s.b, s.n)?;
                    if s.method == "rosa" {
                        want(&s.name, format!("{}.rosa_s", s.name), s.m,
                             s.n)?;
                    }
                }
                other => anyhow::bail!(
                    "site `{}`: unknown method tag `{other}` (this binary \
                     knows cosa, lora, rosa)",
                    s.name
                ),
            }
        }
        Ok(())
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        // Shape/value agreement is checked before any bytes hit disk:
        // the blob section has no per-tensor framing, so a mismatched
        // tensor would silently misalign every blob after it on load.
        for (name, (shape, vals)) in &self.tensors {
            anyhow::ensure!(
                vals.len() == numel(shape),
                "tensor `{name}`: {} values for shape {shape:?} \
                 (expect {})",
                vals.len(), numel(shape)
            );
        }
        Self::validate_sites(&self.sites, &self.tensors)?;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let header = self.header_json();
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, (_, vals)) in &self.tensors {
            for v in vals {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        // Surface buffered-write failures (full disk) instead of letting
        // BufWriter's drop swallow them after reporting Ok.
        f.flush()?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a COSA checkpoint");
        let mut len = [0u8; 4];
        f.read_exact(&mut len)?;
        let mut header = vec![0u8; u32::from_le_bytes(len) as usize];
        f.read_exact(&mut header)?;
        let j = Json::parse(std::str::from_utf8(&header)?)?;

        let mut tensors = BTreeMap::new();
        for t in j.req("tensors")?.as_arr().unwrap_or(&[]) {
            let name = t.req("name")?.as_str().unwrap_or("").to_string();
            let shape: Vec<usize> = t
                .req("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_usize())
                .collect();
            // Exactly numel(shape) floats: a zero-element tensor (any 0
            // dim) owns zero blob bytes, matching what `save` wrote —
            // over-reading here would misalign every later tensor.
            let n: usize = numel(&shape);
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            let vals: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(name, (shape, vals));
        }
        // Decimal-string seed (current format), with a fallback for
        // pre-fix checkpoints that stored a JSON number.
        let seed_field = j.req("adapter_seed")?;
        let adapter_seed = match seed_field.as_str() {
            Some(s) => s.parse::<u64>().map_err(|e| {
                anyhow::anyhow!("bad adapter_seed `{s}`: {e}")
            })?,
            None => seed_field.as_i64().unwrap_or(0) as u64,
        };
        // Format version: absent = v1 (the pre-site era).  A file newer
        // than this binary is rejected rather than half-read.
        let version = match j.get("version") {
            Some(v) => v.as_i64().unwrap_or(0) as u32,
            None => 1,
        };
        anyhow::ensure!(
            (1..=FORMAT_VERSION).contains(&version),
            "checkpoint format v{version} is not supported (this binary \
             reads v1..=v{FORMAT_VERSION})"
        );
        let mut sites = Vec::new();
        if let Some(arr) = j.get("sites").and_then(|s| s.as_arr()) {
            for s in arr {
                sites.push(CkptSite {
                    name: s.req("name")?.as_str().unwrap_or("").to_string(),
                    m: s.req("m")?.as_usize().unwrap_or(0),
                    n: s.req("n")?.as_usize().unwrap_or(0),
                    a: s.req("a")?.as_usize().unwrap_or(0),
                    b: s.req("b")?.as_usize().unwrap_or(0),
                    // v2 blocks predate per-site methods: always CoSA
                    method: s
                        .get("method")
                        .and_then(|m| m.as_str())
                        .unwrap_or("cosa")
                        .to_string(),
                });
            }
        }
        // Corrupt site blocks (missing/mis-shaped cores, dup names) are
        // a load failure, not something to serve from.
        Self::validate_sites(&sites, &tensors)?;
        Ok(Checkpoint {
            version,
            method: j.req("method")?.as_str().unwrap_or("").to_string(),
            adapter_seed,
            artifact: j.req("artifact")?.as_str().unwrap_or("").to_string(),
            step: j.req("step")?.as_i64().unwrap_or(0) as u64,
            sites,
            tensors,
        })
    }

    /// Serving entry point: resolve a bare adapter `name` to a
    /// checkpoint file inside `dir`.  Tries `<name>`, `<name>.cosa`,
    /// `<name>.ckpt` in that order (the trainer writes `.ckpt`, the
    /// portability example `.cosa`), so registries can hot-load by the
    /// id requests carry instead of a filesystem path.  Because names
    /// may arrive from untrusted requests, anything that could escape
    /// `dir` (path separators, `..`) is rejected.
    pub fn load_by_name(dir: &Path, name: &str) -> anyhow::Result<Checkpoint> {
        anyhow::ensure!(
            !name.is_empty()
                && !name.contains('/')
                && !name.contains('\\')
                && !name.contains(".."),
            "adapter name `{name}` is not a bare name"
        );
        let candidates =
            [name.to_string(), format!("{name}.cosa"), format!("{name}.ckpt")];
        for cand in &candidates {
            let path = dir.join(cand);
            if path.is_file() {
                return Checkpoint::load(&path);
            }
        }
        anyhow::bail!(
            "no checkpoint for `{name}` in {} (tried {candidates:?})",
            dir.display()
        )
    }

    /// Bytes on disk (Figure 3 storage accounting cross-check): magic +
    /// length word + the actual serialized header + blobs.  The header
    /// grows linearly with tensor count, so a fixed fudge constant would
    /// understate multi-layer adapters.
    pub fn size_bytes(&self) -> usize {
        let data: usize =
            self.tensors.values().map(|(_, v)| v.len() * 4).sum();
        MAGIC.len() + 4 + self.header_json().len() + data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut tensors = BTreeMap::new();
        tensors.insert("adp.0.wq.y".to_string(),
                       (vec![4, 2], vec![0.5f32; 8]));
        tensors.insert("adp.1.w1.y".to_string(),
                       (vec![2, 3], vec![-1.25f32, 0.0, 3.5, 7.0, 8.0, 9.0]));
        Checkpoint {
            version: FORMAT_VERSION,
            method: "cosa".into(),
            adapter_seed: 1234,
            artifact: "tiny-lm_cosa".into(),
            step: 42,
            sites: Vec::new(),
            tensors,
        }
    }

    /// `sample()` with its two cores described by site blocks.
    fn sample_v2() -> Checkpoint {
        let mut ck = sample();
        ck.sites = vec![
            CkptSite {
                name: "adp.0.wq".into(),
                m: 16,
                n: 16,
                a: 4,
                b: 2,
                method: "cosa".into(),
            },
            CkptSite {
                name: "adp.1.w1".into(),
                m: 8,
                n: 12,
                a: 2,
                b: 3,
                method: "cosa".into(),
            },
        ];
        ck
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("adapter.cosa");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.version, FORMAT_VERSION);
        assert_eq!(back.method, "cosa");
        assert_eq!(back.adapter_seed, 1234);
        assert_eq!(back.step, 42);
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.tensors["adp.1.w1.y"].0, vec![2, 3]);
        assert_eq!(back.tensors["adp.1.w1.y"].1[3], 7.0);
        assert_eq!(back.tensors["adp.0.wq.y"].1, vec![0.5f32; 8]);
        assert!(back.sites.is_empty(), "site-less save stays site-less");
    }

    #[test]
    fn v2_sites_roundtrip_bit_identically() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("multisite.cosa");
        let ck = sample_v2();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.version, FORMAT_VERSION);
        assert_eq!(back.sites, ck.sites, "site blocks must round-trip");
        for (name, (shape, vals)) in &ck.tensors {
            assert_eq!(&back.tensors[name].0, shape);
            let got = &back.tensors[name].1;
            for (p, q) in vals.iter().zip(got) {
                assert_eq!(p.to_bits(), q.to_bits(),
                           "`{name}` values drifted");
            }
        }
    }

    #[test]
    fn v1_file_without_version_loads_as_v1() {
        // Hand-assemble a PR-3-era file: header has no `version` /
        // `sites` keys.  It must load with version == 1, empty sites,
        // and intact tensors.
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("legacy_v1.cosa");
        let header = concat!(
            r#"{"adapter_seed":"77","artifact":"tiny-lm_cosa","#,
            r#""method":"cosa","step":3,"#,
            r#""tensors":[{"name":"adp.0.wq.y","shape":[2,2]}]}"#,
        );
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"COSA");
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        for v in [1.0f32, -2.0, 3.0, -4.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.version, 1, "missing version key means v1");
        assert!(back.sites.is_empty());
        assert_eq!(back.adapter_seed, 77);
        assert_eq!(back.tensors["adp.0.wq.y"].1, vec![1.0, -2.0, 3.0, -4.0]);
    }

    #[test]
    fn v2_file_without_method_tags_loads_as_cosa() {
        // Hand-assemble a v2-era file: site blocks carry no `method`
        // key.  It must load with every block tagged "cosa".
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("legacy_v2.cosa");
        let header = concat!(
            r#"{"adapter_seed":"9","artifact":"tiny-lm_cosa","#,
            r#""method":"cosa","#,
            r#""sites":[{"a":2,"b":2,"m":4,"n":4,"name":"adp.0.wq"}],"#,
            r#""step":3,"#,
            r#""tensors":[{"name":"adp.0.wq.y","shape":[2,2]}],"#,
            r#""version":2}"#,
        );
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"COSA");
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        for v in [1.0f32, -2.0, 3.0, -4.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.version, 2);
        assert_eq!(back.sites.len(), 1);
        assert_eq!(back.sites[0].method, "cosa",
                   "absent method tag must read as cosa");
        assert_eq!(back.tensors["adp.0.wq.y"].1, vec![1.0, -2.0, 3.0, -4.0]);
    }

    #[test]
    fn v3_lora_and_rosa_site_blocks_roundtrip_and_validate() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("methods_v3.cosa");
        let mut tensors = BTreeMap::new();
        tensors.insert("s0.lora_b".to_string(),
                       (vec![4, 2], vec![0.5f32; 8]));
        tensors.insert("s0.lora_a".to_string(),
                       (vec![2, 6], vec![0.25f32; 12]));
        tensors.insert("s1.rosa_s".to_string(),
                       (vec![4, 6], vec![0.0f32; 24]));
        tensors.insert("s1.rosa_b".to_string(),
                       (vec![4, 2], vec![1.0f32; 8]));
        tensors.insert("s1.rosa_a".to_string(),
                       (vec![2, 6], vec![-1.0f32; 12]));
        let site = |name: &str, method: &str| CkptSite {
            name: name.into(),
            m: 4,
            n: 6,
            a: 2,
            b: 2,
            method: method.into(),
        };
        let ck = Checkpoint {
            version: FORMAT_VERSION,
            method: "lora".into(),
            adapter_seed: 11,
            artifact: "tiny-lm".into(),
            step: 0,
            sites: vec![site("s0", "lora"), site("s1", "rosa")],
            tensors,
        };
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.sites, ck.sites, "method tags must round-trip");

        // lora blocks must record a == b == rank
        let mut bad = ck.clone();
        bad.sites[0].b = 3;
        assert!(bad.save(&path).is_err(), "a != b must not save");

        // a rosa block without its sparse residual is corrupt
        let mut bad = ck.clone();
        bad.tensors.remove("s1.rosa_s");
        assert!(bad.save(&path).is_err(), "missing rosa_s must not save");

        // a lora block whose factor disagrees with the header is corrupt
        let mut bad = ck.clone();
        bad.tensors.insert("s0.lora_a".to_string(),
                           (vec![3, 6], vec![0.25f32; 18]));
        assert!(bad.save(&path).is_err(), "mis-shaped factor must not save");
    }

    #[test]
    fn corrupt_site_blocks_are_rejected() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad_sites.cosa");

        // save refuses: site block without its core tensor
        let mut ck = sample_v2();
        ck.sites.push(CkptSite {
            name: "ghost".into(),
            m: 4,
            n: 4,
            a: 2,
            b: 2,
            method: "cosa".into(),
        });
        assert!(ck.save(&path).is_err(), "missing `ghost.y` must not save");

        // save refuses: a method tag this binary doesn't know
        let mut ck = sample_v2();
        ck.sites[0].method = "qlora".into();
        assert!(ck.save(&path).is_err(), "unknown method must not save");

        // save refuses: block dims disagreeing with the core tensor
        let mut ck = sample_v2();
        ck.sites[0].a = 3;
        assert!(ck.save(&path).is_err(), "mis-shaped site must not save");

        // save refuses: duplicate site names
        let mut ck = sample_v2();
        let dup = ck.sites[0].clone();
        ck.sites.push(dup);
        assert!(ck.save(&path).is_err(), "duplicate site must not save");

        // load refuses a hand-corrupted header (block vs tensor shape),
        // even though every tensor individually parses
        let good = sample_v2();
        good.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6],
                                       bytes[7]]) as usize;
        let header = std::str::from_utf8(&bytes[8..8 + hlen]).unwrap();
        let bad_header = header.replacen(
            r#""a":4,"b":2"#, r#""a":2,"b":4"#, 1);
        assert_ne!(header, bad_header, "corruption must actually apply");
        let mut corrupted = Vec::new();
        corrupted.extend_from_slice(&bytes[..4]);
        corrupted
            .extend_from_slice(&(bad_header.len() as u32).to_le_bytes());
        corrupted.extend_from_slice(bad_header.as_bytes());
        corrupted.extend_from_slice(&bytes[8 + hlen..]);
        let bad_path = dir.join("bad_sites_corrupted.cosa");
        std::fs::write(&bad_path, &corrupted).unwrap();
        assert!(Checkpoint::load(&bad_path).is_err(),
                "mis-shaped site block must not load");
    }

    #[test]
    fn truncated_blob_section_is_rejected() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("truncated.cosa");
        let ck = sample_v2();
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // chop the last core's blob short
        std::fs::write(&path, &bytes[..bytes.len() - 6]).unwrap();
        assert!(Checkpoint::load(&path).is_err(),
                "truncated site core must not load");
    }

    #[test]
    fn future_format_versions_are_rejected() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("future.cosa");
        let ck = sample();
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6],
                                       bytes[7]]) as usize;
        let header = std::str::from_utf8(&bytes[8..8 + hlen]).unwrap();
        let bumped = header.replacen(
            &format!(r#""version":{FORMAT_VERSION}"#),
            r#""version":99"#,
            1,
        );
        assert_ne!(header, bumped);
        let mut out = Vec::new();
        out.extend_from_slice(&bytes[..4]);
        out.extend_from_slice(&(bumped.len() as u32).to_le_bytes());
        out.extend_from_slice(bumped.as_bytes());
        out.extend_from_slice(&bytes[8 + hlen..]);
        std::fs::write(&path, &out).unwrap();
        assert!(Checkpoint::load(&path).is_err(),
                "v99 must be rejected, not half-read");
    }

    #[test]
    fn load_by_name_resolves_suffixes() {
        let dir = std::env::temp_dir().join("cosa_ckpt_byname_test");
        std::fs::create_dir_all(&dir).unwrap();
        let ck = sample();
        ck.save(&dir.join("mathbot.cosa")).unwrap();
        let back = Checkpoint::load_by_name(&dir, "mathbot").unwrap();
        assert_eq!(back.adapter_seed, 1234);
        ck.save(&dir.join("explicit.bin")).unwrap();
        let back = Checkpoint::load_by_name(&dir, "explicit.bin").unwrap();
        assert_eq!(back.step, 42);
        assert!(Checkpoint::load_by_name(&dir, "missing").is_err());
        // request-carried ids must not escape the checkpoint dir
        for evil in ["../mathbot", "a/b", "a\\b", "..", "", "/etc/passwd"] {
            assert!(Checkpoint::load_by_name(&dir, evil).is_err(),
                    "`{evil}` must be rejected");
        }
    }

    #[test]
    fn rejects_non_checkpoint() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn cosa_checkpoint_is_core_plus_seed_sized() {
        let ck = sample();
        let params: usize = ck.tensors.values().map(|(_, v)| v.len()).sum();
        assert!(ck.size_bytes() < params * 4 + 512,
                "no hidden projection storage");
    }

    #[test]
    fn size_bytes_matches_bytes_on_disk() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sized.cosa");
        // many tensors so a fixed header fudge would visibly understate
        let mut ck = sample();
        for layer in 0..24 {
            ck.tensors.insert(format!("adp.{layer}.w_long_name.y"),
                              (vec![3, 5], vec![0.25f32; 15]));
        }
        ck.save(&path).unwrap();
        let on_disk = std::fs::metadata(&path).unwrap().len() as usize;
        assert_eq!(ck.size_bytes(), on_disk, "Fig 3 accounting drift");
    }

    #[test]
    fn zero_element_tensors_roundtrip_without_misalignment() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("zero_elem.cosa");
        let mut tensors = BTreeMap::new();
        // zero-element tensors sorted *before* a real one: any spurious
        // blob bytes for them would shift the real tensor's values
        tensors.insert("a.empty_rows.y".to_string(),
                       (vec![0, 5], Vec::new()));
        tensors.insert("b.empty_cols.y".to_string(),
                       (vec![3, 0], Vec::new()));
        tensors.insert("c.real.y".to_string(),
                       (vec![2, 2], vec![1.0f32, -2.0, 3.0, -4.0]));
        let ck = Checkpoint {
            version: FORMAT_VERSION,
            method: "cosa".into(),
            adapter_seed: 7,
            artifact: "tiny-lm_cosa".into(),
            step: 1,
            sites: Vec::new(),
            tensors,
        };
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.tensors["a.empty_rows.y"].0, vec![0, 5]);
        assert!(back.tensors["a.empty_rows.y"].1.is_empty());
        assert!(back.tensors["b.empty_cols.y"].1.is_empty());
        assert_eq!(back.tensors["c.real.y"].1,
                   vec![1.0f32, -2.0, 3.0, -4.0],
                   "blob misaligned by zero-element tensor");
    }

    #[test]
    fn adapter_seed_roundtrips_at_u64_max() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("big_seed.cosa");
        // seeds ≥ 2⁶³ corrupted under the old numeric (f64) round-trip
        for seed in [u64::MAX, 1u64 << 63, (1u64 << 53) + 1, 0] {
            let mut ck = sample();
            ck.adapter_seed = seed;
            ck.save(&path).unwrap();
            let back = Checkpoint::load(&path).unwrap();
            assert_eq!(back.adapter_seed, seed, "seed {seed} corrupted");
        }
    }

    #[test]
    fn save_rejects_shape_value_mismatch() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mismatch.cosa");
        let mut ck = sample();
        ck.tensors.insert("bad.y".to_string(), (vec![4, 4], vec![0.0; 3]));
        assert!(ck.save(&path).is_err(), "mismatched tensor must not save");
    }
}
