//! Adapter checkpoints — the paper's storage format (§4.1/§4.2):
//! the trainable tensors plus the adapter seed; CoSA's fixed projections
//! are *not* stored, they regenerate from the seed at load time.
//!
//! File layout: `b"COSA"` magic, u32 header length, JSON header
//! (method cfg, seed, ordered tensor names + shapes), then raw
//! little-endian f32 blobs in header order.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::util::json::{obj, Json};

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub method: String,
    pub adapter_seed: u64,
    pub artifact: String,
    pub step: u64,
    /// name → (shape, values), insertion-ordered by name (BTreeMap).
    pub tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

const MAGIC: &[u8; 4] = b"COSA";

/// Element count of a shape.  The empty shape is a scalar (1 element,
/// the numpy convention); any zero dimension means zero elements.
fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl Checkpoint {
    /// The serialized JSON header — shared by `save` and `size_bytes` so
    /// storage accounting always matches the bytes actually written.
    ///
    /// `adapter_seed` is serialized as a decimal *string*: the JSON
    /// number path goes through f64, which corrupts seeds ≥ 2⁵³ — and a
    /// corrupted seed silently regenerates different L/R projections,
    /// the one thing §4.1 requires to be bit-stable.
    fn header_json(&self) -> String {
        let names: Vec<Json> = self
            .tensors
            .iter()
            .map(|(n, (shape, _))| {
                obj(vec![
                    ("name", Json::Str(n.clone())),
                    ("shape",
                     Json::Arr(shape.iter().map(|s| Json::from(*s)).collect())),
                ])
            })
            .collect();
        obj(vec![
            ("method", Json::Str(self.method.clone())),
            ("adapter_seed", Json::Str(self.adapter_seed.to_string())),
            ("artifact", Json::Str(self.artifact.clone())),
            ("step", Json::from(self.step as usize)),
            ("tensors", Json::Arr(names)),
        ])
        .to_string()
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        // Shape/value agreement is checked before any bytes hit disk:
        // the blob section has no per-tensor framing, so a mismatched
        // tensor would silently misalign every blob after it on load.
        for (name, (shape, vals)) in &self.tensors {
            anyhow::ensure!(
                vals.len() == numel(shape),
                "tensor `{name}`: {} values for shape {shape:?} \
                 (expect {})",
                vals.len(), numel(shape)
            );
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let header = self.header_json();
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, (_, vals)) in &self.tensors {
            for v in vals {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        // Surface buffered-write failures (full disk) instead of letting
        // BufWriter's drop swallow them after reporting Ok.
        f.flush()?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a COSA checkpoint");
        let mut len = [0u8; 4];
        f.read_exact(&mut len)?;
        let mut header = vec![0u8; u32::from_le_bytes(len) as usize];
        f.read_exact(&mut header)?;
        let j = Json::parse(std::str::from_utf8(&header)?)?;

        let mut tensors = BTreeMap::new();
        for t in j.req("tensors")?.as_arr().unwrap_or(&[]) {
            let name = t.req("name")?.as_str().unwrap_or("").to_string();
            let shape: Vec<usize> = t
                .req("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_usize())
                .collect();
            // Exactly numel(shape) floats: a zero-element tensor (any 0
            // dim) owns zero blob bytes, matching what `save` wrote —
            // over-reading here would misalign every later tensor.
            let n: usize = numel(&shape);
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            let vals: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(name, (shape, vals));
        }
        // Decimal-string seed (current format), with a fallback for
        // pre-fix checkpoints that stored a JSON number.
        let seed_field = j.req("adapter_seed")?;
        let adapter_seed = match seed_field.as_str() {
            Some(s) => s.parse::<u64>().map_err(|e| {
                anyhow::anyhow!("bad adapter_seed `{s}`: {e}")
            })?,
            None => seed_field.as_i64().unwrap_or(0) as u64,
        };
        Ok(Checkpoint {
            method: j.req("method")?.as_str().unwrap_or("").to_string(),
            adapter_seed,
            artifact: j.req("artifact")?.as_str().unwrap_or("").to_string(),
            step: j.req("step")?.as_i64().unwrap_or(0) as u64,
            tensors,
        })
    }

    /// Serving entry point: resolve a bare adapter `name` to a
    /// checkpoint file inside `dir`.  Tries `<name>`, `<name>.cosa`,
    /// `<name>.ckpt` in that order (the trainer writes `.ckpt`, the
    /// portability example `.cosa`), so registries can hot-load by the
    /// id requests carry instead of a filesystem path.  Because names
    /// may arrive from untrusted requests, anything that could escape
    /// `dir` (path separators, `..`) is rejected.
    pub fn load_by_name(dir: &Path, name: &str) -> anyhow::Result<Checkpoint> {
        anyhow::ensure!(
            !name.is_empty()
                && !name.contains('/')
                && !name.contains('\\')
                && !name.contains(".."),
            "adapter name `{name}` is not a bare name"
        );
        let candidates =
            [name.to_string(), format!("{name}.cosa"), format!("{name}.ckpt")];
        for cand in &candidates {
            let path = dir.join(cand);
            if path.is_file() {
                return Checkpoint::load(&path);
            }
        }
        anyhow::bail!(
            "no checkpoint for `{name}` in {} (tried {candidates:?})",
            dir.display()
        )
    }

    /// Bytes on disk (Figure 3 storage accounting cross-check): magic +
    /// length word + the actual serialized header + blobs.  The header
    /// grows linearly with tensor count, so a fixed fudge constant would
    /// understate multi-layer adapters.
    pub fn size_bytes(&self) -> usize {
        let data: usize =
            self.tensors.values().map(|(_, v)| v.len() * 4).sum();
        MAGIC.len() + 4 + self.header_json().len() + data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut tensors = BTreeMap::new();
        tensors.insert("adp.0.wq.y".to_string(),
                       (vec![4, 2], vec![0.5f32; 8]));
        tensors.insert("adp.1.w1.y".to_string(),
                       (vec![2, 3], vec![-1.25f32, 0.0, 3.5, 7.0, 8.0, 9.0]));
        Checkpoint {
            method: "cosa".into(),
            adapter_seed: 1234,
            artifact: "tiny-lm_cosa".into(),
            step: 42,
            tensors,
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("adapter.cosa");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.method, "cosa");
        assert_eq!(back.adapter_seed, 1234);
        assert_eq!(back.step, 42);
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.tensors["adp.1.w1.y"].0, vec![2, 3]);
        assert_eq!(back.tensors["adp.1.w1.y"].1[3], 7.0);
        assert_eq!(back.tensors["adp.0.wq.y"].1, vec![0.5f32; 8]);
    }

    #[test]
    fn load_by_name_resolves_suffixes() {
        let dir = std::env::temp_dir().join("cosa_ckpt_byname_test");
        std::fs::create_dir_all(&dir).unwrap();
        let ck = sample();
        ck.save(&dir.join("mathbot.cosa")).unwrap();
        let back = Checkpoint::load_by_name(&dir, "mathbot").unwrap();
        assert_eq!(back.adapter_seed, 1234);
        ck.save(&dir.join("explicit.bin")).unwrap();
        let back = Checkpoint::load_by_name(&dir, "explicit.bin").unwrap();
        assert_eq!(back.step, 42);
        assert!(Checkpoint::load_by_name(&dir, "missing").is_err());
        // request-carried ids must not escape the checkpoint dir
        for evil in ["../mathbot", "a/b", "a\\b", "..", "", "/etc/passwd"] {
            assert!(Checkpoint::load_by_name(&dir, evil).is_err(),
                    "`{evil}` must be rejected");
        }
    }

    #[test]
    fn rejects_non_checkpoint() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn cosa_checkpoint_is_core_plus_seed_sized() {
        let ck = sample();
        let params: usize = ck.tensors.values().map(|(_, v)| v.len()).sum();
        assert!(ck.size_bytes() < params * 4 + 512,
                "no hidden projection storage");
    }

    #[test]
    fn size_bytes_matches_bytes_on_disk() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sized.cosa");
        // many tensors so a fixed header fudge would visibly understate
        let mut ck = sample();
        for layer in 0..24 {
            ck.tensors.insert(format!("adp.{layer}.w_long_name.y"),
                              (vec![3, 5], vec![0.25f32; 15]));
        }
        ck.save(&path).unwrap();
        let on_disk = std::fs::metadata(&path).unwrap().len() as usize;
        assert_eq!(ck.size_bytes(), on_disk, "Fig 3 accounting drift");
    }

    #[test]
    fn zero_element_tensors_roundtrip_without_misalignment() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("zero_elem.cosa");
        let mut tensors = BTreeMap::new();
        // zero-element tensors sorted *before* a real one: any spurious
        // blob bytes for them would shift the real tensor's values
        tensors.insert("a.empty_rows.y".to_string(),
                       (vec![0, 5], Vec::new()));
        tensors.insert("b.empty_cols.y".to_string(),
                       (vec![3, 0], Vec::new()));
        tensors.insert("c.real.y".to_string(),
                       (vec![2, 2], vec![1.0f32, -2.0, 3.0, -4.0]));
        let ck = Checkpoint {
            method: "cosa".into(),
            adapter_seed: 7,
            artifact: "tiny-lm_cosa".into(),
            step: 1,
            tensors,
        };
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.tensors["a.empty_rows.y"].0, vec![0, 5]);
        assert!(back.tensors["a.empty_rows.y"].1.is_empty());
        assert!(back.tensors["b.empty_cols.y"].1.is_empty());
        assert_eq!(back.tensors["c.real.y"].1,
                   vec![1.0f32, -2.0, 3.0, -4.0],
                   "blob misaligned by zero-element tensor");
    }

    #[test]
    fn adapter_seed_roundtrips_at_u64_max() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("big_seed.cosa");
        // seeds ≥ 2⁶³ corrupted under the old numeric (f64) round-trip
        for seed in [u64::MAX, 1u64 << 63, (1u64 << 53) + 1, 0] {
            let mut ck = sample();
            ck.adapter_seed = seed;
            ck.save(&path).unwrap();
            let back = Checkpoint::load(&path).unwrap();
            assert_eq!(back.adapter_seed, seed, "seed {seed} corrupted");
        }
    }

    #[test]
    fn save_rejects_shape_value_mismatch() {
        let dir = std::env::temp_dir().join("cosa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mismatch.cosa");
        let mut ck = sample();
        ck.tensors.insert("bad.y".to_string(), (vec![4, 4], vec![0.0; 3]));
        assert!(ck.save(&path).is_err(), "mismatched tensor must not save");
    }
}
