//! Learning-rate schedules (App. C: linear for GLUE, cosine for NLG).
//! The artifact takes lr as a scalar input, so schedules live entirely
//! in the coordinator.

use crate::config::Schedule;

/// LR at 0-based step `step` of `total` steps.
pub fn lr_at(schedule: Schedule, base_lr: f64, step: usize,
             total: usize) -> f64 {
    let total = total.max(1);
    let s = step.min(total) as f64;
    let t = total as f64;
    match schedule {
        Schedule::Constant => base_lr,
        Schedule::LinearWarmup { warmup_frac } => {
            let w = (warmup_frac * t).max(1.0);
            if s < w {
                base_lr * (s + 1.0) / w
            } else {
                base_lr * ((t - s) / (t - w).max(1.0)).max(0.0)
            }
        }
        Schedule::CosineWarmup { warmup_frac } => {
            let w = (warmup_frac * t).max(1.0);
            if s < w {
                base_lr * (s + 1.0) / w
            } else {
                let p = (s - w) / (t - w).max(1.0);
                base_lr * 0.5 * (1.0 + (std::f64::consts::PI * p).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn constant_is_constant() {
        for s in [0, 10, 199] {
            assert_eq!(lr_at(Schedule::Constant, 3e-4, s, 200), 3e-4);
        }
    }

    #[test]
    fn warmup_ramps_then_decays() {
        let sch = Schedule::LinearWarmup { warmup_frac: 0.1 };
        let lr0 = lr_at(sch, 1.0, 0, 100);
        assert!(lr0 < 0.2);
        let peak = lr_at(sch, 1.0, 9, 100);
        assert!((peak - 1.0).abs() < 1e-9);
        assert!(lr_at(sch, 1.0, 99, 100) < 0.05);
    }

    #[test]
    fn cosine_ends_near_zero_and_is_monotone_after_warmup() {
        let sch = Schedule::CosineWarmup { warmup_frac: 0.05 };
        let end = lr_at(sch, 1.0, 199, 200);
        assert!(end < 0.01, "{end}");
        prop::for_all("cosine monotone decay", 20, |rng| {
            let a = prop::int_in(rng, 10, 150);
            let b = a + prop::int_in(rng, 1, 40);
            assert!(lr_at(sch, 1.0, a, 200) >= lr_at(sch, 1.0, b, 200));
        });
    }

    #[test]
    fn never_negative() {
        for sch in [Schedule::Constant,
                    Schedule::LinearWarmup { warmup_frac: 0.06 },
                    Schedule::CosineWarmup { warmup_frac: 0.03 }] {
            for s in 0..250 {
                assert!(lr_at(sch, 2e-5, s, 200) >= 0.0);
            }
        }
    }
}
