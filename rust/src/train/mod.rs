//! Training loop: the L3 coordinator's core.  Owns schedules, data order,
//! grad-accum grouping, periodic eval, AdaLoRA's rank-budget schedule,
//! checkpointing and the metrics log.  The compute itself is one
//! AOT-compiled XLA train step per optimizer update; [`Trainer::new`]
//! pins the host-side `linalg` backend from the run config's `[compute]`
//! table before any initialization math runs.
//!
//! [`HostCosaStep`] is the host mirror of the XLA train step for the
//! CoSA core: forward + analytic VJP + update, with every intermediate
//! drawn from a `linalg::Workspace` so the steady-state step performs
//! zero matmul-output allocations (asserted in this module's tests and
//! measured by `benches/e2e_step.rs`).  The packed backend extends the
//! same contract to its B-panel packing scratch (thread-local pool), so
//! pinning `[compute] backend = "packed"` keeps the step allocation-free
//! too.

pub mod checkpoint;
pub mod metrics;
pub mod sched;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::adapters::cosa::{adapter_forward_into, adapter_vjp_y_into};
use crate::adapters::init::{init_state, MethodCfg};
use crate::adapters::Method;
use crate::config::RunConfig;
use crate::data::batcher::{cls_batch, lm_batch, Batcher};
use crate::data::{self, ClsDataset, LmDataset};
use crate::eval;
use crate::info;
use crate::linalg::{self, Workspace};
use crate::math::matrix::Matrix;
use crate::runtime::executor::{Executor, Runtime, State};
use crate::runtime::Registry;
use crate::train::checkpoint::Checkpoint;
use crate::train::metrics::MetricsLog;

/// Task data bound to the model's head type.
pub enum TaskData {
    Lm(LmDataset),
    Cls(ClsDataset),
}

/// A fully-wired training run.
pub struct Trainer {
    pub cfg: RunConfig,
    pub train_exec: Executor,
    pub eval_exec: Executor,
    pub state: State,
    pub data: TaskData,
    pub log: MetricsLog,
    batcher: Batcher,
}

impl Trainer {
    /// Wire a run: load artifacts, initialize state, generate data.
    pub fn new(rt: &Runtime, reg: &Registry, cfg: RunConfig)
               -> anyhow::Result<Trainer> {
        let train_exec = rt.load(&reg.dir, &format!("{}_train", cfg.artifact))?;
        let eval_exec = rt.load(&reg.dir, &format!("{}_eval", cfg.artifact))?;
        let meta = &train_exec.meta;

        // Pin the host compute backend before any init math runs.
        // Precedence: COSA_BACKEND env > [compute] config > preset hint.
        let compute = cfg.compute.resolved(&meta.preset);
        linalg::configure(&compute.backend, compute.threads)?;
        info!("compute backend: {}", linalg::describe());

        let mcfg = MethodCfg {
            method: Method::from_str(&meta.method.method)?,
            r: meta.method.r,
            a: meta.method.a,
            b: meta.method.b,
            alpha: meta.method.alpha as f32,
            nola_k: meta.method.nola_k,
        };
        let host = init_state(&meta.init_specs(), &mcfg, cfg.base_seed,
                              cfg.adapter_seed);
        let state = State::init(&rt.client, meta, &host)?;

        let bsz = meta.model.batch;
        let n_train = (cfg.train.steps * bsz * cfg.train.grad_accum)
            .clamp(512, 20_000);
        let data = match meta.model.head.as_str() {
            "lm" => TaskData::Lm(data::lm_task(
                &cfg.task, n_train, 128, meta.model.vocab,
                meta.model.max_seq, cfg.data_seed)?),
            _ => TaskData::Cls(data::cls_task(
                &cfg.task, n_train, 256, meta.model.vocab,
                meta.model.max_seq, cfg.data_seed)?),
        };
        let n = match &data {
            TaskData::Lm(d) => d.train.len(),
            TaskData::Cls(d) => d.train.len(),
        };
        let batcher = Batcher::new(n, bsz, cfg.data_seed);
        info!(
            "run `{}`: artifact={} method={} trainables={} params={}",
            cfg.name, cfg.artifact, meta.method.method,
            meta.inputs_with_role("trainable").len(),
            meta.trainable_param_count()
        );
        Ok(Trainer {
            cfg, train_exec, eval_exec, state, data,
            log: MetricsLog::default(), batcher,
        })
    }

    fn next_batch(&mut self) -> crate::data::batcher::Batch {
        let idx = self.batcher.next_indices();
        let m = &self.train_exec.meta.model;
        match &self.data {
            TaskData::Lm(d) => {
                let exs: Vec<&_> = idx.iter().map(|i| &d.train[*i]).collect();
                lm_batch(&exs, m.batch, m.max_seq)
            }
            TaskData::Cls(d) => {
                let exs: Vec<&_> = idx.iter().map(|i| &d.train[*i]).collect();
                cls_batch(&exs, m.batch, m.max_seq, m.head == "reg")
            }
        }
    }

    /// Periodic eval: (loss, metric).  For LM the fast metric is
    /// teacher-forced token accuracy; decode-based metrics are computed
    /// by the experiment harnesses at the end of a run.
    pub fn evaluate(&self) -> anyhow::Result<(f64, f64)> {
        match &self.data {
            TaskData::Lm(d) => eval::eval_lm(&self.eval_exec, &self.state, d),
            TaskData::Cls(d) => {
                eval::eval_cls(&self.eval_exec, &self.state, d)
            }
        }
    }

    /// AdaLoRA rank-budget schedule: cubic decay of the kept-rank
    /// fraction from 1.0 to 0.5 over the first 60% of training, pruning
    /// the smallest |λ| entries via the frozen mask inputs.
    fn adalora_mask_update(&mut self, step: usize) -> anyhow::Result<()> {
        let total = self.cfg.train.steps.max(1);
        let progress = (step as f64 / (0.6 * total as f64)).min(1.0);
        let keep_frac = 1.0 - 0.5 * (1.0 - (1.0 - progress).powi(3));
        let mask_names: Vec<String> = self.train_exec.meta
            .inputs_with_role("frozen")
            .iter()
            .filter(|s| s.name.ends_with(".mask"))
            .map(|s| s.name.clone())
            .collect();
        for mname in mask_names {
            let lam_name = mname.replace(".mask", ".lam");
            let lam = self.state.read(&lam_name)?;
            let r = lam.len();
            let keep = ((keep_frac * r as f64).round() as usize).clamp(1, r);
            let mut order: Vec<usize> = (0..r).collect();
            order.sort_by(|&i, &j| lam[j].abs().partial_cmp(&lam[i].abs())
                .unwrap());
            let mut mask = vec![0.0f32; r];
            for &i in order.iter().take(keep) {
                mask[i] = 1.0;
            }
            self.state.write(&mname, &[r], &mask)?;
        }
        Ok(())
    }

    /// Run the configured number of steps.  Returns the metrics log.
    pub fn run(&mut self) -> anyhow::Result<&MetricsLog> {
        let t = self.cfg.train.clone();
        let is_adalora = self.train_exec.meta.method.method == "adalora";
        for step in 0..t.steps {
            let lr = sched::lr_at(t.schedule, t.lr, step, t.steps);
            let mut loss_sum = 0.0;
            let mut acc_sum = 0.0;
            // grad-accum grouping: N micro-steps per logical step (each
            // micro-step is a full optimizer update at lr/N — see
            // DESIGN.md §6 deviation note).
            let micro = t.grad_accum.max(1);
            for _ in 0..micro {
                let batch = self.next_batch();
                let out = self.train_exec.train_step(
                    &mut self.state,
                    (lr / micro as f64) as f32,
                    t.weight_decay as f32,
                    t.clip_norm as f32,
                    &batch,
                )?;
                loss_sum += out.loss as f64;
                acc_sum += out.acc as f64;
            }
            let loss = loss_sum / micro as f64;
            self.log.push_train(step, lr, loss, acc_sum / micro as f64);
            if t.log_every > 0 && step % t.log_every == 0 {
                info!("step {step:5}  lr {lr:.3e}  loss {loss:.4}");
            }
            if is_adalora && step > 0 && step % 25 == 0 {
                self.adalora_mask_update(step)?;
            }
            if t.eval_every > 0 && (step + 1) % t.eval_every == 0 {
                let (el, em) = self.evaluate()?;
                info!("step {step:5}  eval_loss {el:.4}  metric {em:.4}");
                self.log.push_eval(step, el, em);
            }
        }
        Ok(&self.log)
    }

    /// Save the adapter checkpoint (trainables + adapter seed).  CoSA
    /// artifacts get method-tagged site blocks: every trainable
    /// `<stem>.y` whose
    /// frozen `<stem>.l` (m × a) and `<stem>.r` (b × n) companions are
    /// in the artifact is recorded as an adapted site, so one file
    /// carries the whole model's per-site cores and a multi-site
    /// registry can load them without guessing (other methods' tensor
    /// layouts don't match the pattern and save site-less, as before).
    pub fn save_checkpoint(&self, path: &Path) -> anyhow::Result<PathBuf> {
        use crate::train::checkpoint::{CkptSite, FORMAT_VERSION};
        let meta = &self.train_exec.meta;
        let mut tensors = BTreeMap::new();
        for spec in meta.inputs_with_role("trainable") {
            tensors.insert(spec.name.clone(),
                           (spec.shape.clone(), self.state.read(&spec.name)?));
        }
        let mut sites = Vec::new();
        for spec in meta.inputs_with_role("trainable") {
            let Some(stem) = spec.name.strip_suffix(".y") else { continue };
            if spec.shape.len() != 2 {
                continue;
            }
            let (a, b) = (spec.shape[0], spec.shape[1]);
            let find = |suffix: &str| {
                meta.inputs.iter().find(|t| {
                    t.role == "frozen"
                        && t.shape.len() == 2
                        && t.name == format!("{stem}{suffix}")
                })
            };
            let (Some(l), Some(r)) = (find(".l"), find(".r")) else {
                continue;
            };
            // L is m × a, R is b × n — skip anything inconsistent
            // rather than record a corrupt site block.
            if l.shape[1] != a || r.shape[0] != b {
                continue;
            }
            // The `.y` + frozen `.l`/`.r` pattern is CoSA's layout by
            // construction, so the site block carries that tag
            // regardless of the artifact's configured method string.
            sites.push(CkptSite {
                name: stem.to_string(),
                m: l.shape[0],
                n: r.shape[1],
                a,
                b,
                method: "cosa".to_string(),
            });
        }
        let ck = Checkpoint {
            version: FORMAT_VERSION,
            method: meta.method.method.clone(),
            adapter_seed: self.cfg.adapter_seed,
            artifact: self.cfg.artifact.clone(),
            step: self.state.step,
            sites,
            tensors,
        };
        ck.save(path)?;
        Ok(path.to_path_buf())
    }

    /// Restore trainables from a checkpoint (projections regenerate from
    /// the stored seed via the initializer — nothing else is persisted).
    pub fn load_checkpoint(&mut self, ck: &Checkpoint) -> anyhow::Result<()> {
        anyhow::ensure!(ck.artifact == self.cfg.artifact,
                        "checkpoint is for `{}`", ck.artifact);
        for (name, (shape, vals)) in &ck.tensors {
            self.state.write(name, shape, vals)?;
        }
        self.state.step = ck.step;
        Ok(())
    }

    /// Output path helpers.
    pub fn csv_path(&self) -> PathBuf {
        Path::new(&self.cfg.out_dir).join(format!("{}.csv", self.cfg.name))
    }
    pub fn ckpt_path(&self) -> PathBuf {
        Path::new(&self.cfg.out_dir).join(format!("{}.ckpt", self.cfg.name))
    }
}

/// Host mirror of the CoSA train step: fit the core `Y` to target
/// activations by gradient descent on ½·N⁻¹‖α·x Rᵀ Yᵀ Lᵀ − target‖²_F.
///
/// This is the compressed-sensing recovery loop in miniature (observe a
/// ΔW through the fixed dictionary, recover the sparse core), and the
/// reference workload for the workspace-arena contract: every
/// intermediate (u, v, o, residual, gL, dY) is drawn from the owned
/// [`Workspace`], so after the first step **no matmul output is
/// allocated** — `fresh_allocs()` is flat, which the tests below and
/// `benches/e2e_step.rs` both check.
pub struct HostCosaStep {
    pub l: Matrix,
    pub r: Matrix,
    pub y: Matrix,
    pub alpha: f32,
    ws: Workspace,
}

impl HostCosaStep {
    pub fn new(l: Matrix, r: Matrix, y: Matrix, alpha: f32) -> HostCosaStep {
        assert_eq!(l.cols, y.rows, "L (m×a) vs Y (a×b)");
        assert_eq!(y.cols, r.rows, "Y (a×b) vs R (b×n)");
        HostCosaStep { l, r, y, alpha, ws: Workspace::new() }
    }

    /// Workspace allocation counter (flat after warmup ⇒ zero-alloc).
    pub fn fresh_allocs(&self) -> usize {
        self.ws.fresh_allocs()
    }

    /// One SGD step toward `target` (N × m); returns the pre-update loss
    /// ½·N⁻¹‖o − target‖²_F.
    pub fn step(&mut self, x: &Matrix, target: &Matrix, lr: f32) -> f64 {
        assert_eq!((target.rows, target.cols), (x.rows, self.l.rows),
                   "target must be N×m (N = x rows, m = L rows)");
        let n_rows = x.rows.max(1);
        let inv_n = 1.0 / n_rows as f32;

        // forward into a workspace buffer: e = α·x Rᵀ Yᵀ Lᵀ
        let mut e = self.ws.take_matrix(x.rows, self.l.rows);
        adapter_forward_into(x, &self.l, &self.r, &self.y, self.alpha,
                             &mut self.ws, &mut e);
        // residual (in place) + loss
        let mut loss = 0.0f64;
        for (ev, tv) in e.data.iter_mut().zip(&target.data) {
            *ev -= tv;
            loss += (*ev as f64) * (*ev as f64);
        }
        loss *= 0.5 * inv_n as f64;

        // dY = α/N · (e L)ᵀ (x Rᵀ), all from the workspace
        let mut dy = self.ws.take_matrix(self.y.rows, self.y.cols);
        adapter_vjp_y_into(x, &self.l, &self.r, &e, self.alpha * inv_n,
                           &mut self.ws, &mut dy);
        linalg::axpy(-lr, &dy.data, &mut self.y.data);

        self.ws.recycle_matrix(dy);
        self.ws.recycle_matrix(e);
        loss
    }

    /// A step size with guaranteed descent for this quadratic: the
    /// smoothness constant is bounded by α²·‖L‖²_F·‖x Rᵀ‖²_F / N, so
    /// lr = 1/bound is always safe (if conservative).
    pub fn safe_lr(&self, x: &Matrix) -> f32 {
        let u = linalg::gemm_nt(x, &self.r);
        let bound = (self.alpha as f64).powi(2)
            * self.l.frobenius_sq()
            * u.frobenius_sq()
            / x.rows.max(1) as f64;
        if bound <= 1e-30 {
            1.0
        } else {
            (1.0 / bound) as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::cosa::{adapter_forward, regen_l, regen_r};
    use crate::math::rng::Pcg64;

    #[test]
    fn host_step_recovers_core_without_allocating() {
        let (m, n, a, b, rows) = (10, 12, 4, 3, 32);
        let mut rng = Pcg64::new(1);
        let l = regen_l(5, "host.l", m, a);
        let r = regen_r(5, "host.r", b, n);
        let x = Matrix::gaussian(rows, n, 1.0, &mut rng);

        // ground-truth sparse core and the activations it produces
        let mut y_star = Matrix::zeros(a, b);
        for pos in rng.sample_indices(a * b, 4) {
            y_star.data[pos] = rng.normal() as f32;
        }
        let alpha = 2.0f32;
        let target = adapter_forward(&x, &l, &r, &y_star, alpha);

        let mut step =
            HostCosaStep::new(l, r, Matrix::zeros(a, b), alpha);
        let lr = step.safe_lr(&x);
        assert!(lr > 0.0 && lr.is_finite());

        let first = step.step(&x, &target, lr); // warmup
        let warm_allocs = step.fresh_allocs();
        let mut prev = first;
        let mut last = first;
        for _ in 0..30 {
            last = step.step(&x, &target, lr);
            assert!(last.is_finite());
            assert!(last <= prev * (1.0 + 1e-4),
                    "descent violated: {prev} -> {last}");
            prev = last;
        }
        // numpy cross-check of this exact recovery: ratio < 0.2 across
        // seeds with the conservative lr; assert half as much slack
        assert!(last < first * 0.5,
                "no meaningful recovery: {first} -> {last}");
        assert_eq!(step.fresh_allocs(), warm_allocs,
                   "train step allocated after warmup");
    }

    #[test]
    fn host_step_zero_target_drives_loss_to_zero_direction() {
        // target == current output ⇒ zero gradient, loss 0, Y unchanged
        let (m, n, a, b, rows) = (6, 8, 3, 2, 8);
        let mut rng = Pcg64::new(2);
        let l = Matrix::gaussian(m, a, 0.5, &mut rng);
        let r = Matrix::gaussian(b, n, 0.5, &mut rng);
        let y = Matrix::gaussian(a, b, 0.5, &mut rng);
        let x = Matrix::gaussian(rows, n, 1.0, &mut rng);
        let target = adapter_forward(&x, &l, &r, &y, 1.0);
        let y_before = y.data.clone();
        let mut step = HostCosaStep::new(l, r, y, 1.0);
        let loss = step.step(&x, &target, 0.1);
        assert!(loss < 1e-9, "self-target loss {loss}");
        for (p, q) in step.y.data.iter().zip(&y_before) {
            assert!((p - q).abs() < 1e-5);
        }
    }
}
