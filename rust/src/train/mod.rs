//! Training loop: the L3 coordinator's core.  Owns schedules, data order,
//! grad-accum grouping, periodic eval, AdaLoRA's rank-budget schedule,
//! checkpointing and the metrics log.  The compute itself is one
//! AOT-compiled XLA train step per optimizer update.

pub mod checkpoint;
pub mod metrics;
pub mod sched;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::adapters::init::{init_state, MethodCfg};
use crate::adapters::Method;
use crate::config::RunConfig;
use crate::data::batcher::{cls_batch, lm_batch, Batcher};
use crate::data::{self, ClsDataset, LmDataset};
use crate::eval;
use crate::info;
use crate::runtime::executor::{Executor, Runtime, State};
use crate::runtime::Registry;
use crate::train::checkpoint::Checkpoint;
use crate::train::metrics::MetricsLog;

/// Task data bound to the model's head type.
pub enum TaskData {
    Lm(LmDataset),
    Cls(ClsDataset),
}

/// A fully-wired training run.
pub struct Trainer {
    pub cfg: RunConfig,
    pub train_exec: Executor,
    pub eval_exec: Executor,
    pub state: State,
    pub data: TaskData,
    pub log: MetricsLog,
    batcher: Batcher,
}

impl Trainer {
    /// Wire a run: load artifacts, initialize state, generate data.
    pub fn new(rt: &Runtime, reg: &Registry, cfg: RunConfig)
               -> anyhow::Result<Trainer> {
        let train_exec = rt.load(&reg.dir, &format!("{}_train", cfg.artifact))?;
        let eval_exec = rt.load(&reg.dir, &format!("{}_eval", cfg.artifact))?;
        let meta = &train_exec.meta;

        let mcfg = MethodCfg {
            method: Method::from_str(&meta.method.method)?,
            r: meta.method.r,
            a: meta.method.a,
            b: meta.method.b,
            alpha: meta.method.alpha as f32,
            nola_k: meta.method.nola_k,
        };
        let host = init_state(&meta.init_specs(), &mcfg, cfg.base_seed,
                              cfg.adapter_seed);
        let state = State::init(&rt.client, meta, &host)?;

        let bsz = meta.model.batch;
        let n_train = (cfg.train.steps * bsz * cfg.train.grad_accum)
            .clamp(512, 20_000);
        let data = match meta.model.head.as_str() {
            "lm" => TaskData::Lm(data::lm_task(
                &cfg.task, n_train, 128, meta.model.vocab,
                meta.model.max_seq, cfg.data_seed)?),
            _ => TaskData::Cls(data::cls_task(
                &cfg.task, n_train, 256, meta.model.vocab,
                meta.model.max_seq, cfg.data_seed)?),
        };
        let n = match &data {
            TaskData::Lm(d) => d.train.len(),
            TaskData::Cls(d) => d.train.len(),
        };
        let batcher = Batcher::new(n, bsz, cfg.data_seed);
        info!(
            "run `{}`: artifact={} method={} trainables={} params={}",
            cfg.name, cfg.artifact, meta.method.method,
            meta.inputs_with_role("trainable").len(),
            meta.trainable_param_count()
        );
        Ok(Trainer {
            cfg, train_exec, eval_exec, state, data,
            log: MetricsLog::default(), batcher,
        })
    }

    fn next_batch(&mut self) -> crate::data::batcher::Batch {
        let idx = self.batcher.next_indices();
        let m = &self.train_exec.meta.model;
        match &self.data {
            TaskData::Lm(d) => {
                let exs: Vec<&_> = idx.iter().map(|i| &d.train[*i]).collect();
                lm_batch(&exs, m.batch, m.max_seq)
            }
            TaskData::Cls(d) => {
                let exs: Vec<&_> = idx.iter().map(|i| &d.train[*i]).collect();
                cls_batch(&exs, m.batch, m.max_seq, m.head == "reg")
            }
        }
    }

    /// Periodic eval: (loss, metric).  For LM the fast metric is
    /// teacher-forced token accuracy; decode-based metrics are computed
    /// by the experiment harnesses at the end of a run.
    pub fn evaluate(&self) -> anyhow::Result<(f64, f64)> {
        match &self.data {
            TaskData::Lm(d) => eval::eval_lm(&self.eval_exec, &self.state, d),
            TaskData::Cls(d) => {
                eval::eval_cls(&self.eval_exec, &self.state, d)
            }
        }
    }

    /// AdaLoRA rank-budget schedule: cubic decay of the kept-rank
    /// fraction from 1.0 to 0.5 over the first 60% of training, pruning
    /// the smallest |λ| entries via the frozen mask inputs.
    fn adalora_mask_update(&mut self, step: usize) -> anyhow::Result<()> {
        let total = self.cfg.train.steps.max(1);
        let progress = (step as f64 / (0.6 * total as f64)).min(1.0);
        let keep_frac = 1.0 - 0.5 * (1.0 - (1.0 - progress).powi(3));
        let mask_names: Vec<String> = self.train_exec.meta
            .inputs_with_role("frozen")
            .iter()
            .filter(|s| s.name.ends_with(".mask"))
            .map(|s| s.name.clone())
            .collect();
        for mname in mask_names {
            let lam_name = mname.replace(".mask", ".lam");
            let lam = self.state.read(&lam_name)?;
            let r = lam.len();
            let keep = ((keep_frac * r as f64).round() as usize).clamp(1, r);
            let mut order: Vec<usize> = (0..r).collect();
            order.sort_by(|&i, &j| lam[j].abs().partial_cmp(&lam[i].abs())
                .unwrap());
            let mut mask = vec![0.0f32; r];
            for &i in order.iter().take(keep) {
                mask[i] = 1.0;
            }
            self.state.write(&mname, &[r], &mask)?;
        }
        Ok(())
    }

    /// Run the configured number of steps.  Returns the metrics log.
    pub fn run(&mut self) -> anyhow::Result<&MetricsLog> {
        let t = self.cfg.train.clone();
        let is_adalora = self.train_exec.meta.method.method == "adalora";
        for step in 0..t.steps {
            let lr = sched::lr_at(t.schedule, t.lr, step, t.steps);
            let mut loss_sum = 0.0;
            let mut acc_sum = 0.0;
            // grad-accum grouping: N micro-steps per logical step (each
            // micro-step is a full optimizer update at lr/N — see
            // DESIGN.md §6 deviation note).
            let micro = t.grad_accum.max(1);
            for _ in 0..micro {
                let batch = self.next_batch();
                let out = self.train_exec.train_step(
                    &mut self.state,
                    (lr / micro as f64) as f32,
                    t.weight_decay as f32,
                    t.clip_norm as f32,
                    &batch,
                )?;
                loss_sum += out.loss as f64;
                acc_sum += out.acc as f64;
            }
            let loss = loss_sum / micro as f64;
            self.log.push_train(step, lr, loss, acc_sum / micro as f64);
            if t.log_every > 0 && step % t.log_every == 0 {
                info!("step {step:5}  lr {lr:.3e}  loss {loss:.4}");
            }
            if is_adalora && step > 0 && step % 25 == 0 {
                self.adalora_mask_update(step)?;
            }
            if t.eval_every > 0 && (step + 1) % t.eval_every == 0 {
                let (el, em) = self.evaluate()?;
                info!("step {step:5}  eval_loss {el:.4}  metric {em:.4}");
                self.log.push_eval(step, el, em);
            }
        }
        Ok(&self.log)
    }

    /// Save the adapter checkpoint (trainables + adapter seed).
    pub fn save_checkpoint(&self, path: &Path) -> anyhow::Result<PathBuf> {
        let meta = &self.train_exec.meta;
        let mut tensors = BTreeMap::new();
        for spec in meta.inputs_with_role("trainable") {
            tensors.insert(spec.name.clone(),
                           (spec.shape.clone(), self.state.read(&spec.name)?));
        }
        let ck = Checkpoint {
            method: meta.method.method.clone(),
            adapter_seed: self.cfg.adapter_seed,
            artifact: self.cfg.artifact.clone(),
            step: self.state.step,
            tensors,
        };
        ck.save(path)?;
        Ok(path.to_path_buf())
    }

    /// Restore trainables from a checkpoint (projections regenerate from
    /// the stored seed via the initializer — nothing else is persisted).
    pub fn load_checkpoint(&mut self, ck: &Checkpoint) -> anyhow::Result<()> {
        anyhow::ensure!(ck.artifact == self.cfg.artifact,
                        "checkpoint is for `{}`", ck.artifact);
        for (name, (shape, vals)) in &ck.tensors {
            self.state.write(name, shape, vals)?;
        }
        self.state.step = ck.step;
        Ok(())
    }

    /// Output path helpers.
    pub fn csv_path(&self) -> PathBuf {
        Path::new(&self.cfg.out_dir).join(format!("{}.csv", self.cfg.name))
    }
    pub fn ckpt_path(&self) -> PathBuf {
        Path::new(&self.cfg.out_dir).join(format!("{}.ckpt", self.cfg.name))
    }
}
