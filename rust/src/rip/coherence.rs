//! Mutual coherence of the Kronecker dictionary (paper App. B.1/B.2).
//!
//! Columns of `Ψ = Rᵀ ⊗ L` are `r_j ⊗ l_i`, so inner products factorize:
//! `⟨ψ_{ij}, ψ_{i'j'}⟩ = (l_i·l_{i'})(r_j·r_{j'})` and, after column
//! normalization, the dictionary coherence is
//!
//! ```text
//! μ(Ψ) = max( μ(L), μ(Rᵀ), μ(L)·μ(Rᵀ) ) = max( μ(L), μ(Rᵀ) )
//! ```
//!
//! (factorization means we never materialize the mn × ab dictionary).
//! Recovery guarantee checked by Fig 4d: μ < 1/√(s_max).

use crate::math::rng::Pcg64;

/// Mutual coherence of a set of vectors (rows of `vecs`), i.e. the max
/// absolute cosine between distinct vectors.
pub fn mutual_coherence(vecs: &[Vec<f32>]) -> f64 {
    let norms: Vec<f64> = vecs
        .iter()
        .map(|v| v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt())
        .collect();
    let mut mu = 0.0f64;
    for i in 0..vecs.len() {
        for j in (i + 1)..vecs.len() {
            let dot: f64 = vecs[i]
                .iter()
                .zip(&vecs[j])
                .map(|(a, b)| *a as f64 * *b as f64)
                .sum();
            let denom = norms[i] * norms[j];
            if denom > 1e-12 {
                mu = mu.max((dot / denom).abs());
            }
        }
    }
    mu
}

/// Coherence of the CoSA dictionary for (m, n, a, b), via factorization.
/// Returns (μ_Ψ, μ_L, μ_R).
pub fn kron_coherence(m: usize, n: usize, a: usize, b: usize,
                      seed: u64) -> (f64, f64, f64) {
    let mut rng = Pcg64::derive(seed, "rip.projections");
    // identical draw order to estimator.rs so Table 4 / Fig 4 share (L, R)
    let lt: Vec<Vec<f32>> = (0..a).map(|_| rng.normal_vec(m, 1.0)).collect();
    let r: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec(n, 1.0)).collect();
    let mu_l = mutual_coherence(&lt);
    let mu_r = mutual_coherence(&r);
    (mu_l.max(mu_r), mu_l, mu_r)
}

/// The sparse-recovery guarantee threshold 1/√(s_max) (Fig 4d reference
/// line; the paper uses s_max = 20 → 0.224).
pub fn recovery_threshold(s_max: usize) -> f64 {
    1.0 / (s_max as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orthogonal_vectors_have_zero_coherence() {
        let vecs = vec![vec![1.0, 0.0, 0.0], vec![0.0, 2.0, 0.0],
                        vec![0.0, 0.0, -1.0]];
        assert!(mutual_coherence(&vecs) < 1e-12);
    }

    #[test]
    fn parallel_vectors_have_unit_coherence() {
        let vecs = vec![vec![1.0, 1.0], vec![2.0, 2.0]];
        assert!((mutual_coherence(&vecs) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn factorization_matches_explicit_kron() {
        // Tiny dims: materialize Ψ explicitly and compare coherences.
        let (m, n, a, b) = (10, 8, 3, 2);
        let mut rng = Pcg64::derive(3, "rip.projections");
        let lt: Vec<Vec<f32>> =
            (0..a).map(|_| rng.normal_vec(m, 1.0)).collect();
        let r: Vec<Vec<f32>> =
            (0..b).map(|_| rng.normal_vec(n, 1.0)).collect();
        // explicit columns ψ_{ij}[p*n + q] = L[p,i] * R[j,q]
        let mut cols = Vec::new();
        for i in 0..a {
            for j in 0..b {
                let mut col = vec![0.0f32; m * n];
                for p in 0..m {
                    for q in 0..n {
                        col[p * n + q] = lt[i][p] * r[j][q];
                    }
                }
                cols.push(col);
            }
        }
        let explicit = mutual_coherence(&cols);
        let (factored, _, _) = kron_coherence(m, n, a, b, 3);
        assert!(
            (explicit - factored).abs() < 1e-6,
            "explicit {explicit} vs factored {factored}"
        );
    }

    #[test]
    fn paper_scale_satisfies_recovery_guarantee() {
        // Fig 4d claim: all four configs sit below 1/√20 ≈ 0.224.
        for &(a, b) in &[(32, 8), (64, 16), (128, 32), (256, 64)] {
            let (mu, _, _) = kron_coherence(512, 256, a, b, 42);
            assert!(
                mu < recovery_threshold(20) * 1.15,
                "(a={a},b={b}) coherence {mu} too high"
            );
        }
    }

    #[test]
    fn threshold_value() {
        assert!((recovery_threshold(20) - 0.2236).abs() < 1e-3);
    }
}
