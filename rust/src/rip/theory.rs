//! Theoretical RIP bounds (paper Appendix A.2 / A.4, Theorem 4.1).
//!
//! Single Gaussian factor:  δ_s ≤ C·√(s·log(d)/k)  for a k×d (or d→k)
//! map with k "measurement" rows.  Kronecker composition (Duarte &
//! Baraniuk 2011):  1 + δ(Ψ₁⊗Ψ₂) ≤ (1 + δ(Ψ₁))(1 + δ(Ψ₂)).

/// δ bound for one Gaussian factor mapping R^d through k measurements.
/// `c` is the calibration constant of Appendix A.2 (absolute constant
/// folded from the union bound; Fig 4b/4c use the default below).
pub fn single_factor_bound(s: usize, d: usize, k: usize, c: f64) -> f64 {
    (c * (s as f64 * (d.max(2) as f64).ln() / k as f64).sqrt()).min(1.0)
}

/// Default calibration constant.  Chosen once so that the *moderate*
/// compression regime (8–32×) sits near theory/empirical ≈ 1 (paper
/// Fig 4c reports 0.35–1.18× there); not tuned per configuration.
pub const DEFAULT_C: f64 = 0.55;

/// Theoretical bound for the CoSA Kronecker dictionary Ψ = Rᵀ ⊗ L with
/// L: a→m and Rᵀ: b→n, via the composition rule.
pub fn kron_rip_bound(s: usize, m: usize, n: usize, a: usize, b: usize,
                      c: f64) -> f64 {
    let dl = single_factor_bound(s, a, m, c);
    let dr = single_factor_bound(s, b, n, c);
    ((1.0 + dl) * (1.0 + dr) - 1.0).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_shrinks_with_measurements() {
        let loose = single_factor_bound(10, 256, 64, 1.0);
        let tight = single_factor_bound(10, 256, 1024, 1.0);
        assert!(tight < loose);
    }

    #[test]
    fn bound_grows_with_sparsity() {
        assert!(single_factor_bound(20, 256, 512, 1.0)
            > single_factor_bound(5, 256, 512, 1.0));
    }

    #[test]
    fn kron_composition_dominates_factors() {
        let (s, m, n, a, b) = (10, 512, 256, 128, 32);
        let k = kron_rip_bound(s, m, n, a, b, 1.0);
        assert!(k >= single_factor_bound(s, a, m, 1.0));
        assert!(k >= single_factor_bound(s, b, n, 1.0));
        assert!(k <= 1.0);
    }

    #[test]
    fn paper_configs_stay_below_stability_threshold() {
        // Theorem 4.1's practical content: the paper-scale dictionaries
        // have bounded δ.  With the calibrated constant all four Table 4
        // configs stay under the 0.5 stability threshold for s ≤ 10.
        for &(a, b) in &[(32, 8), (64, 16), (128, 32), (256, 64)] {
            let d = kron_rip_bound(5, 512, 256, a, b, DEFAULT_C);
            assert!(d < 0.6, "(a={a},b={b}) bound {d}");
        }
    }

    #[test]
    fn saturates_at_one() {
        assert_eq!(single_factor_bound(10_000, 4096, 4, 1.0), 1.0);
        assert_eq!(kron_rip_bound(10_000, 4, 4, 4096, 4096, 1.0), 1.0);
    }
}
