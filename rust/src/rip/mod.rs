//! RIP measurement suite (paper §3.2, §4.1, Appendix A/B).
//!
//! Validates that CoSA's Kronecker dictionary `Ψ = Rᵀ ⊗ L` acts as a
//! near-isometry on sparse cores: Monte-Carlo estimation of the empirical
//! RIP constant δ_s (Appendix B, Eq. 26), mutual coherence of the
//! dictionary (App. B.2), and the theoretical bounds of Appendix A.2 —
//! everything behind Table 4 and Figure 4.

pub mod coherence;
pub mod estimator;
pub mod theory;

pub use coherence::kron_coherence;
pub use estimator::{rip_constant, RipEstimate, RipSetup};
pub use theory::{kron_rip_bound, single_factor_bound};
