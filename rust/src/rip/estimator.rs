//! Monte-Carlo RIP constant estimator (paper Appendix A.3 / B.1).
//!
//! For the Kronecker dictionary `Ψ = Rᵀ ⊗ L`, the isometry ratio of a
//! sparse core never materializes Ψ (mn × ab): using
//! `Ψ vec(Y) = vec(L Y R)` and the rank-one expansion
//!
//! ```text
//! ‖L Y R‖_F² = Σ_k Σ_l y_k y_l (l_{ik}·l_{il}) (r_{jk}·r_{jl})
//! ```
//!
//! each s-sparse sample needs only the Gram matrices `Gₗ = LᵀL` (a × a)
//! and `Gᵣ = R Rᵀ` (b × b): the column/row dot products are **hoisted out
//! of the sample loop** into two `linalg::gemm_nt` products per (L, R)
//! draw, dropping the per-sample cost from O(s²·(m+n)) to O(s²) lookups —
//! this is the L3 hot path behind Table 4 / Fig 4 and is benchmarked in
//! `rust/benches/rip_bench.rs`.

use crate::linalg;
use crate::math::matrix::Matrix;
use crate::math::rng::Pcg64;
use crate::math::stats;

/// Dimensions of one RIP experiment: ΔW is (m × n), core Y is (a × b).
#[derive(Clone, Copy, Debug)]
pub struct RipSetup {
    pub m: usize,
    pub n: usize,
    pub a: usize,
    pub b: usize,
}

impl RipSetup {
    /// The paper's proxy dimensions (App. B.1): m=512, n=256.
    pub fn paper(a: usize, b: usize) -> Self {
        RipSetup { m: 512, n: 256, a, b }
    }

    /// Compression ratio mn / ab as reported in Table 4.
    pub fn compression_ratio(&self) -> f64 {
        (self.m * self.n) as f64 / (self.a * self.b) as f64
    }
}

/// Result of one Monte-Carlo δ_s estimation.
#[derive(Clone, Debug)]
pub struct RipEstimate {
    pub setup: RipSetup,
    pub sparsity: usize,
    pub samples: usize,
    /// 95th percentile of |ratio − 1| (paper Eq. 26).
    pub delta: f64,
    /// Mean and std of |ratio − 1| across samples (diagnostics).
    pub mean_dev: f64,
    pub std_dev: f64,
    /// Raw isometry ratios (returned for Fig 4 histograms).
    pub ratios: Vec<f64>,
}

/// Stack row vectors into a Matrix (rows must share a length).
fn rows_to_matrix(rows: &[Vec<f32>]) -> Matrix {
    let cols = rows.first().map_or(0, |r| r.len());
    let mut m = Matrix::zeros(rows.len(), cols);
    for (i, row) in rows.iter().enumerate() {
        m.data[i * cols..(i + 1) * cols].copy_from_slice(row);
    }
    m
}

/// Gram matrix of a set of row vectors: `G = V Vᵀ`, via the backend's
/// transpose-free NT kernel.
fn gram_rows(rows: &[Vec<f32>]) -> Matrix {
    let v = rows_to_matrix(rows);
    linalg::gemm_nt(&v, &v)
}

/// Sample one s-sparse core and return its isometry ratio
/// ‖Ψα‖²/‖α‖² under the 1/√(mn)-normalized dictionary.
///
/// `gl` is the L-column Gram `LᵀL` (a × a), `gr` the R-row Gram `R Rᵀ`
/// (b × b) — both precomputed once per (L, R) draw by [`rip_constant`],
/// so each sample is O(s²) table lookups.
fn isometry_ratio(
    gl: &Matrix,
    gr: &Matrix,
    setup: &RipSetup,
    sparsity: usize,
    rng: &mut Pcg64,
) -> f64 {
    let ab = setup.a * setup.b;
    let s = sparsity.min(ab);
    // support: s distinct (i, j) positions in Y; values N(0, 1)
    let support = rng.sample_indices(ab, s);
    let vals: Vec<f64> = (0..s).map(|_| rng.normal()).collect();

    let mut num = 0.0f64;
    for k in 0..s {
        let (ik, jk) = (support[k] / setup.b, support[k] % setup.b);
        for l in 0..s {
            let (il, jl) = (support[l] / setup.b, support[l] % setup.b);
            num += vals[k] * vals[l]
                * gl.at(ik, il) as f64
                * gr.at(jk, jl) as f64;
        }
    }
    let denom: f64 = vals.iter().map(|v| v * v).sum();
    // Ψ ← Ψ / √(mn): entries of L,R are N(0,1); E‖LYR‖² = mn‖Y‖².
    num / denom / (setup.m * setup.n) as f64
}

/// Estimate δ_s = percentile₉₅{|ratio − 1|} over `samples` random s-sparse
/// cores against a fresh Gaussian (L, R) draw seeded by `seed`.
pub fn rip_constant(
    setup: RipSetup,
    sparsity: usize,
    samples: usize,
    seed: u64,
) -> RipEstimate {
    let mut rng = Pcg64::derive(seed, "rip.projections");
    // store Lᵀ so column dots are contiguous (draw order is part of the
    // seeded stream contract shared with `rip::coherence`)
    let lt: Vec<Vec<f32>> =
        (0..setup.a).map(|_| rng.normal_vec(setup.m, 1.0)).collect();
    let r: Vec<Vec<f32>> =
        (0..setup.b).map(|_| rng.normal_vec(setup.n, 1.0)).collect();
    // hoisted Gram matrices: two NT products, then O(s²) per sample
    let gl = gram_rows(&lt);
    let gr = gram_rows(&r);

    let mut sample_rng = Pcg64::derive(seed, "rip.samples");
    let mut ratios = Vec::with_capacity(samples);
    for _ in 0..samples {
        ratios.push(isometry_ratio(&gl, &gr, &setup, sparsity,
                                   &mut sample_rng));
    }
    let devs: Vec<f64> = ratios.iter().map(|r| (r - 1.0).abs()).collect();
    RipEstimate {
        setup,
        sparsity,
        samples,
        delta: stats::percentile(&devs, 95.0),
        mean_dev: stats::mean(&devs),
        std_dev: stats::std_dev(&devs),
        ratios,
    }
}

/// Direct isometry ratio for an explicitly materialized core:
/// `‖L·Y·R‖²_F / ‖Y‖²_F / (mn)` — the slow-path cross-check of the Gram
/// expansion (used by the suite's validation tests and Fig 4 sanity
/// lanes).  `Y` is an s-sparse core, so the first product goes through
/// the threaded sparse-left kernel (`linalg::sparse`): zero rows drop
/// out of the work list and large cross-checks scale across cores.
pub fn direct_isometry_ratio(l: &Matrix, r: &Matrix, y: &Matrix) -> f64 {
    let yr = linalg::sparse::gemm_sparse_left(y, r);
    let lyr = linalg::gemm(l, &yr);
    lyr.frobenius_sq() / y.frobenius_sq() / (l.rows * r.cols) as f64
}

/// Repeat `rip_constant` over `trials` independent (L, R) draws and return
/// (mean δ, std δ) — the ± column of Table 4.
pub fn rip_constant_trials(
    setup: RipSetup,
    sparsity: usize,
    samples: usize,
    trials: usize,
    seed: u64,
) -> (f64, f64, Vec<f64>) {
    let deltas: Vec<f64> = (0..trials)
        .map(|t| {
            rip_constant(setup, sparsity, samples, seed + 1000 * t as u64)
                .delta
        })
        .collect();
    (stats::mean(&deltas), stats::std_dev(&deltas), deltas)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_concentrate_around_one() {
        let est = rip_constant(RipSetup::paper(64, 16), 10, 300, 7);
        let mean = stats::mean(&est.ratios);
        assert!((mean - 1.0).abs() < 0.1, "mean ratio {mean}");
        assert!(est.delta < 0.5, "delta {} breaches stability", est.delta);
        assert!(est.delta > 0.01, "delta {} suspiciously tight", est.delta);
    }

    #[test]
    fn delta_decreases_with_sparsity_level() {
        // Random (non-adversarial) sparse cores concentrate better as s
        // grows — the paper's Table 4 trend.
        let s5 = rip_constant(RipSetup::paper(128, 32), 5, 400, 3).delta;
        let s20 = rip_constant(RipSetup::paper(128, 32), 20, 400, 3).delta;
        assert!(s20 < s5, "δ5={s5} δ20={s20}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = rip_constant(RipSetup::paper(32, 8), 5, 50, 11);
        let b = rip_constant(RipSetup::paper(32, 8), 5, 50, 11);
        assert_eq!(a.delta, b.delta);
        assert_eq!(a.ratios, b.ratios);
    }

    #[test]
    fn dense_core_matches_direct_computation() {
        // s = ab (fully dense core): cross-check the rank-one expansion
        // against the direct ‖LYR‖ computed with explicit matrices.
        let setup = RipSetup { m: 24, n: 16, a: 4, b: 3 };
        let mut rng = Pcg64::derive(5, "rip.projections");
        let lt: Vec<Vec<f32>> =
            (0..setup.a).map(|_| rng.normal_vec(setup.m, 1.0)).collect();
        let r: Vec<Vec<f32>> =
            (0..setup.b).map(|_| rng.normal_vec(setup.n, 1.0)).collect();
        let (gl, gr) = (gram_rows(&lt), gram_rows(&r));
        let mut srng = Pcg64::new(99);
        let ratio = isometry_ratio(&gl, &gr, &setup, 12, &mut srng);

        // rebuild the same support/values stream
        let mut srng2 = Pcg64::new(99);
        let support = srng2.sample_indices(12, 12);
        let vals: Vec<f64> = (0..12).map(|_| srng2.normal()).collect();
        let mut y = Matrix::zeros(setup.a, setup.b);
        for (k, pos) in support.iter().enumerate() {
            y.set(pos / setup.b, pos % setup.b, vals[k] as f32);
        }
        let mut l = Matrix::zeros(setup.m, setup.a);
        for (j, col) in lt.iter().enumerate() {
            for (i, v) in col.iter().enumerate() {
                l.set(i, j, *v);
            }
        }
        let mut rm = Matrix::zeros(setup.b, setup.n);
        for (i, row) in r.iter().enumerate() {
            for (j, v) in row.iter().enumerate() {
                rm.set(i, j, *v);
            }
        }
        let direct = direct_isometry_ratio(&l, &rm, &y);
        assert!(
            (ratio - direct).abs() / direct < 1e-3,
            "expansion {ratio} vs direct {direct}"
        );
    }

    #[test]
    fn trials_report_spread() {
        let (mean, std, deltas) =
            rip_constant_trials(RipSetup::paper(64, 16), 5, 100, 3, 21);
        assert_eq!(deltas.len(), 3);
        assert!(mean > 0.0 && std >= 0.0);
    }
}
