//! The method-agnostic [`Adapter`] trait — one serving contract for
//! CoSA and the paper's §4 baselines (RoSA, LoRA).
//!
//! The trait factors what the model/serve layers actually need from an
//! adapted site into five capabilities:
//!
//! * **forward** — [`Adapter::forward_into`] computes `o = ΔW_method(x)`
//!   for one site, with regenerable operands passed in (so residency
//!   stays the model layer's concern, not the method's);
//! * **VJP** — [`Adapter::vjp`] returns the trainable-tensor gradients
//!   (encode order) plus the activation gradient;
//! * **cost** — [`Adapter::param_count`] /
//!   [`Adapter::resident_bytes`] / [`Adapter::regen_bytes`] give the
//!   Figure-3-style accounting the wire API reports per adapter;
//! * **seed-regen description** — [`Adapter::regen_specs`] declares the
//!   tensors that regenerate from the seed instead of being stored
//!   ([`RegenSpec`]).  CoSA declares `[L, R]` per site — in exactly the
//!   order the pre-trait model peeked its cache, so the shared
//!   projection-cache key sequence (and therefore CoSA's bit-identical
//!   serving) is preserved by construction.  LoRA/RoSA declare nothing:
//!   their tensors are all resident;
//! * **checkpoint encode/decode** — [`Adapter::encode_tensors`] writes
//!   the site's stored tensors, [`decode_site`] rebuilds an adapter
//!   from a checkpoint's tensor map (format v3 carries the per-site
//!   method tag; v1/v2 files decode as CoSA).
//!
//! [`forward_grouped_into`] is the fused-batch dispatcher: the
//! scheduler's cross-adapter batches segment by (adapter, method), and
//! consecutive same-method segments execute as one grouped
//! block-diagonal sweep — the all-CoSA case takes the *identical*
//! grouped kernel path the pre-trait engine used (bit-identity is
//! pinned by acceptance tests), all-LoRA takes a two-sweep grouped
//! path, same-rank RoSA fuses its dense low-rank half through the same
//! two sweeps (the sparse residual stays per-segment), and anything
//! else (mixed low-rank ranks) falls back to per-segment
//! [`Adapter::forward_into`] calls, which the grouped kernels are
//! bit-identical to anyway.

use std::any::Any;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::adapters::cosa::{self, CosaAdapter};
use crate::adapters::lora::LoraAdapter;
use crate::adapters::rosa::RosaAdapter;
use crate::adapters::Method;
use crate::linalg::{self, QuantKind, QuantMat, Workspace};
use crate::math::matrix::Matrix;

/// One tensor that regenerates from the adapter seed instead of being
/// stored (the paper's §4.1 storage trick).  `(seed, name, rows, cols)`
/// doubles as the shared projection-cache key
/// ([`crate::model::CacheKey`]), and `regen` is the canonical generator
/// — for CoSA, [`cosa::regen_l`] / [`cosa::regen_r`], so a spec
/// materializes the same bits forever.
#[derive(Clone)]
pub struct RegenSpec {
    pub seed: u64,
    /// Tensor name (e.g. `adp.0.wq.l`) — embeds the site stem, so one
    /// shared cache never collides across sites or adapters.
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// Deterministic generator: `(seed, name, rows, cols) -> Matrix`.
    pub regen: fn(u64, &str, usize, usize) -> Matrix,
}

impl RegenSpec {
    /// The shared projection-cache key this spec materializes under.
    pub fn key(&self) -> (u64, String, usize, usize) {
        (self.seed, self.name.clone(), self.rows, self.cols)
    }

    /// Regenerate the tensor (deterministic per key).
    pub fn materialize(&self) -> Matrix {
        (self.regen)(self.seed, &self.name, self.rows, self.cols)
    }

    /// Bytes this tensor occupies when materialized (f32).
    pub fn bytes(&self) -> usize {
        self.rows * self.cols * std::mem::size_of::<f32>()
    }

    /// Bytes this tensor occupies when cache-resident under a storage
    /// kind — the quantity the projection-LRU ledger meters under
    /// `[serve] cache_quant` (payload plus int8 per-panel scales).
    pub fn bytes_as(&self, kind: QuantKind) -> usize {
        kind.bytes_for(self.rows, self.cols)
    }
}

impl std::fmt::Debug for RegenSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RegenSpec")
            .field("seed", &self.seed)
            .field("name", &self.name)
            .field("rows", &self.rows)
            .field("cols", &self.cols)
            .finish()
    }
}

/// One adapted site of one adapter, behind a method-agnostic contract
/// (see module docs).  Implementations: [`CosaAdapter`],
/// [`RosaAdapter`], [`LoraAdapter`].
pub trait Adapter: Send + Sync {
    /// Which PEFT method this site runs.
    fn method(&self) -> Method;

    /// Output width `m` of the adapted `m × n` site.
    fn out_dim(&self) -> usize;

    /// Input width `n` of the adapted `m × n` site.
    fn in_dim(&self) -> usize;

    /// Core dims recorded in the checkpoint site block: CoSA `(a, b)`,
    /// low-rank methods `(r, r)`.
    fn core_dims(&self) -> (usize, usize);

    /// Trainable parameters at this site.
    fn param_count(&self) -> usize;

    /// Bytes stored resident (checkpoint blob bytes + seed overhead).
    fn resident_bytes(&self) -> usize;

    /// Bytes of seed-regenerable operands (0 for fully-stored methods).
    fn regen_bytes(&self) -> usize;

    /// The seed-regenerable tensors, in the order `forward_into` /
    /// `vjp` expect them in `regen` — and the order the model layer
    /// resolves them against the shared projection cache.
    fn regen_specs(&self) -> Vec<RegenSpec>;

    /// `out = α · ΔW(x)` for a batch of row activations `x` (N × n),
    /// `out` (N × m).  `regen` holds the materialized
    /// [`Adapter::regen_specs`] tensors in declaration order, in
    /// whatever storage kind the cache resides them under (f32 payloads
    /// are served bit-identically to the unquantized engine).
    fn forward_into(
        &self,
        x: &Matrix,
        regen: &[Arc<QuantMat>],
        alpha: f32,
        ws: &mut Workspace,
        out: &mut Matrix,
    );

    /// Allocating convenience wrapper over [`Adapter::forward_into`].
    fn forward(
        &self,
        x: &Matrix,
        regen: &[Arc<QuantMat>],
        alpha: f32,
    ) -> Matrix {
        let mut ws = Workspace::new();
        let mut out = Matrix::zeros(x.rows, self.out_dim());
        self.forward_into(x, regen, alpha, &mut ws, &mut out);
        out
    }

    /// Backward pass: given upstream gradients `g = ∂L/∂o` (N × m),
    /// returns the trainable-tensor gradients (in
    /// [`Adapter::encode_tensors`] name order) and the activation
    /// gradient `dX` (N × n).
    fn vjp(
        &self,
        x: &Matrix,
        regen: &[Arc<QuantMat>],
        g: &Matrix,
        alpha: f32,
    ) -> (Vec<Matrix>, Matrix);

    /// Write this site's stored tensors into a checkpoint tensor map
    /// under the `site` stem (e.g. `{site}.y`, `{site}.lora_b`, ...).
    fn encode_tensors(
        &self,
        site: &str,
        out: &mut BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    );

    /// Concrete-type escape hatch for the grouped fast paths.
    fn as_any(&self) -> &dyn Any;
}

/// Look up `tensors[name]`, requiring shape `[rows, cols]`.
fn take_tensor(
    tensors: &BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    name: &str,
    rows: usize,
    cols: usize,
) -> anyhow::Result<Matrix> {
    let Some((shape, vals)) = tensors.get(name) else {
        anyhow::bail!("tensor `{name}` is missing");
    };
    anyhow::ensure!(
        shape.as_slice() == [rows, cols],
        "tensor `{name}` has shape {shape:?}, expected [{rows}, {cols}]"
    );
    anyhow::ensure!(
        vals.len() == rows * cols,
        "tensor `{name}`: {} values for shape [{rows}, {cols}]",
        vals.len()
    );
    Ok(Matrix::from_vec(rows, cols, vals.clone()))
}

/// Rebuild one site's adapter from a checkpoint tensor map.  The
/// inverse of [`Adapter::encode_tensors`]; the per-site method tag
/// comes from the v3 site block (v1/v2 files always decode as CoSA).
pub fn decode_site(
    method: Method,
    site: &str,
    m: usize,
    n: usize,
    seed: u64,
    tensors: &BTreeMap<String, (Vec<usize>, Vec<f32>)>,
) -> anyhow::Result<Arc<dyn Adapter>> {
    match method {
        Method::CoSA => {
            let yname = format!("{site}.y");
            let Some((shape, _)) = tensors.get(&yname) else {
                anyhow::bail!("site `{site}`: core `{yname}` is missing");
            };
            anyhow::ensure!(
                shape.len() == 2 && shape[0] >= 1 && shape[1] >= 1,
                "site `{site}`: core `{yname}` has shape {shape:?}"
            );
            let (a, b) = (shape[0], shape[1]);
            let y = take_tensor(tensors, &yname, a, b)?;
            Ok(Arc::new(CosaAdapter::new(
                seed,
                format!("{site}.l"),
                format!("{site}.r"),
                m,
                n,
                Arc::new(y),
            )))
        }
        Method::LoRA => {
            let bname = format!("{site}.lora_b");
            let Some((bshape, _)) = tensors.get(&bname) else {
                anyhow::bail!("site `{site}`: `{bname}` is missing");
            };
            anyhow::ensure!(
                bshape.len() == 2 && bshape[0] == m && bshape[1] >= 1,
                "site `{site}`: `{bname}` has shape {bshape:?}, expected \
                 [{m}, r]"
            );
            let r = bshape[1];
            let bm = take_tensor(tensors, &bname, m, r)?;
            let am = take_tensor(tensors, &format!("{site}.lora_a"), r, n)?;
            LoraAdapter::try_new(Arc::new(bm), Arc::new(am))
                .map(|ad| Arc::new(ad) as Arc<dyn Adapter>)
        }
        Method::RoSA => {
            let s = take_tensor(tensors, &format!("{site}.rosa_s"), m, n)?;
            let bname = format!("{site}.rosa_b");
            let Some((bshape, _)) = tensors.get(&bname) else {
                anyhow::bail!("site `{site}`: `{bname}` is missing");
            };
            anyhow::ensure!(
                bshape.len() == 2 && bshape[0] == m && bshape[1] >= 1,
                "site `{site}`: `{bname}` has shape {bshape:?}, expected \
                 [{m}, r]"
            );
            let r = bshape[1];
            let bm = take_tensor(tensors, &bname, m, r)?;
            let am = take_tensor(tensors, &format!("{site}.rosa_a"), r, n)?;
            RosaAdapter::try_new(Arc::new(s), Arc::new(bm), Arc::new(am))
                .map(|ad| Arc::new(ad) as Arc<dyn Adapter>)
        }
        other => anyhow::bail!(
            "method `{}` has no serving adapter implementation \
             (servable: cosa, rosa, lora)",
            other.name()
        ),
    }
}

/// Methods the serving engine can execute (a subset of the costmodel's
/// [`Method`] universe).
pub const SERVABLE_METHODS: [Method; 3] =
    [Method::CoSA, Method::RoSA, Method::LoRA];

/// Timing split of one grouped dispatch, for the telemetry layer
/// (`obs`): `copy_us` counts the mixed-method staging row copies,
/// `compute_us` the grouped kernel sweeps themselves.  Uniform-method
/// batches (the serving fast path) accrue only `compute_us`.
#[derive(Clone, Copy, Debug, Default)]
pub struct GroupedMarks {
    pub copy_us: u64,
    pub compute_us: u64,
}

/// Fused multi-adapter forward over one site: consecutive row segments
/// of `x` (`segs[g]` rows each) run against their own adapter + regen
/// set.  Dispatch is per maximal same-method run (see module docs);
/// every path is bit-identical to calling [`Adapter::forward_into`]
/// once per segment.
#[allow(clippy::too_many_arguments)]
pub fn forward_grouped_into(
    adapters: &[&dyn Adapter],
    regens: &[&[Arc<QuantMat>]],
    alphas: &[f32],
    x: &Matrix,
    segs: &[usize],
    ws: &mut Workspace,
    out: &mut Matrix,
) {
    forward_grouped_into_marked(
        adapters, regens, alphas, x, segs, ws, out, None,
    );
}

/// [`forward_grouped_into`] with an optional [`GroupedMarks`]
/// accumulator.  With `marks = None` (every non-traced caller) not a
/// single `Instant::now` is taken — the compute path is byte-for-byte
/// the untimed one.
#[allow(clippy::too_many_arguments)]
pub fn forward_grouped_into_marked(
    adapters: &[&dyn Adapter],
    regens: &[&[Arc<QuantMat>]],
    alphas: &[f32],
    x: &Matrix,
    segs: &[usize],
    ws: &mut Workspace,
    out: &mut Matrix,
    mut marks: Option<&mut GroupedMarks>,
) {
    assert!(
        adapters.len() == segs.len()
            && regens.len() == segs.len()
            && alphas.len() == segs.len(),
        "forward_grouped_into: operand/segment count mismatch"
    );
    if adapters.is_empty() {
        return;
    }
    let timed = marks.is_some();
    let mut copy_us = 0u64;
    let mut compute_us = 0u64;
    let total_segs = segs.len();
    let mut g0 = 0usize;
    let mut row0 = 0usize;
    while g0 < total_segs {
        let method = adapters[g0].method();
        let mut g1 = g0 + 1;
        while g1 < total_segs && adapters[g1].method() == method {
            g1 += 1;
        }
        let rows: usize = segs[g0..g1].iter().sum();
        if g0 == 0 && g1 == total_segs {
            // uniform-method batch: dispatch in place, no row copies —
            // the all-CoSA serving fast path is exactly this arm
            let t0 = timed.then(Instant::now);
            run_method_into(
                &adapters[g0..g1],
                &regens[g0..g1],
                &alphas[g0..g1],
                x,
                &segs[g0..g1],
                ws,
                out,
            );
            if let Some(t0) = t0 {
                compute_us += t0.elapsed().as_micros() as u64;
            }
        } else if rows > 0 {
            // mixed-method batch: copy the run's rows out, compute,
            // copy back (row-independent kernels make this exact)
            let n = adapters[g0].in_dim();
            let m = adapters[g0].out_dim();
            let t0 = timed.then(Instant::now);
            let mut xs = ws.take_matrix(rows, n);
            xs.data
                .copy_from_slice(&x.data[row0 * n..(row0 + rows) * n]);
            let mut os = ws.take_matrix(rows, m);
            if let Some(t0) = t0 {
                copy_us += t0.elapsed().as_micros() as u64;
            }
            let t1 = timed.then(Instant::now);
            run_method_into(
                &adapters[g0..g1],
                &regens[g0..g1],
                &alphas[g0..g1],
                &xs,
                &segs[g0..g1],
                ws,
                &mut os,
            );
            if let Some(t1) = t1 {
                compute_us += t1.elapsed().as_micros() as u64;
            }
            let t2 = timed.then(Instant::now);
            out.data[row0 * m..(row0 + rows) * m]
                .copy_from_slice(&os.data);
            ws.recycle_matrix(os);
            ws.recycle_matrix(xs);
            if let Some(t2) = t2 {
                copy_us += t2.elapsed().as_micros() as u64;
            }
        }
        row0 += rows;
        g0 = g1;
    }
    if let Some(m) = marks.as_deref_mut() {
        m.copy_us += copy_us;
        m.compute_us += compute_us;
    }
}

/// Grouped compute for one same-method run of segments.
fn run_method_into(
    adapters: &[&dyn Adapter],
    regens: &[&[Arc<QuantMat>]],
    alphas: &[f32],
    x: &Matrix,
    segs: &[usize],
    ws: &mut Workspace,
    out: &mut Matrix,
) {
    match adapters[0].method() {
        Method::CoSA => {
            // the pre-trait grouped kernel path — bit for bit when the
            // regens are f32, pack-fused quantized sweeps otherwise
            let ys: Vec<&Matrix> = adapters
                .iter()
                .map(|ad| {
                    ad.as_any()
                        .downcast_ref::<CosaAdapter>()
                        .expect("cosa-method segment must be a CosaAdapter")
                        .core()
                })
                .collect();
            let ls: Vec<&QuantMat> =
                regens.iter().map(|r| r[0].as_ref()).collect();
            let rs: Vec<&QuantMat> =
                regens.iter().map(|r| r[1].as_ref()).collect();
            cosa::adapter_forward_grouped_quant_into(
                x, &ls, &rs, &ys, alphas, segs, ws, out,
            );
        }
        Method::LoRA => {
            let las: Vec<&LoraAdapter> = adapters
                .iter()
                .map(|ad| {
                    ad.as_any()
                        .downcast_ref::<LoraAdapter>()
                        .expect("lora-method segment must be a LoraAdapter")
                })
                .collect();
            let rank = las[0].rank();
            if las.iter().all(|l| l.rank() == rank) {
                // two grouped NT sweeps: u = x·Aᵀ, out = u·Bᵀ, then the
                // per-segment α exactly as the single-adapter path
                // applies it (unconditional multiply ⇒ identical bits)
                let amats: Vec<&Matrix> =
                    las.iter().map(|l| l.a_ref()).collect();
                let bmats: Vec<&Matrix> =
                    las.iter().map(|l| l.b_ref()).collect();
                let mut u = ws.take_matrix(x.rows, rank);
                linalg::gemm_grouped_nt_into(x, &amats, segs, &mut u);
                linalg::gemm_grouped_nt_into(&u, &bmats, segs, out);
                let m = out.cols;
                let mut row = 0usize;
                for (g, &rows) in segs.iter().enumerate() {
                    for o in out.data[row * m..(row + rows) * m].iter_mut()
                    {
                        *o *= alphas[g];
                    }
                    row += rows;
                }
                ws.recycle_matrix(u);
            } else {
                run_per_segment(adapters, regens, alphas, x, segs, ws, out);
            }
        }
        Method::RoSA => {
            let ras: Vec<&RosaAdapter> = adapters
                .iter()
                .map(|ad| {
                    ad.as_any()
                        .downcast_ref::<RosaAdapter>()
                        .expect("rosa-method segment must be a RosaAdapter")
                })
                .collect();
            let rank = ras[0].rank();
            if ras.iter().all(|r| r.rank() == rank) {
                // dense low-rank half fused across segments — the same
                // two grouped NT sweeps the LoRA arm runs; the sparse
                // residual stays per-segment (sparse-left kernel, not
                // groupable) and α multiplies last, exactly the op
                // order `forward_into` uses ⇒ identical bits.
                let amats: Vec<&Matrix> =
                    ras.iter().map(|r| r.a_ref()).collect();
                let bmats: Vec<&Matrix> =
                    ras.iter().map(|r| r.b_ref()).collect();
                let mut u = ws.take_matrix(x.rows, rank);
                linalg::gemm_grouped_nt_into(x, &amats, segs, &mut u);
                linalg::gemm_grouped_nt_into(&u, &bmats, segs, out);
                ws.recycle_matrix(u);
                let n = x.cols;
                let m = out.cols;
                let mut row = 0usize;
                for (g, &rows) in segs.iter().enumerate() {
                    if rows == 0 {
                        continue;
                    }
                    let mut xs = ws.take_matrix(rows, n);
                    xs.data.copy_from_slice(
                        &x.data[row * n..(row + rows) * n],
                    );
                    let sx = linalg::sparse::gemm_sparse_left(
                        ras[g].sparse_ref(),
                        &xs.transpose(),
                    );
                    ws.recycle_matrix(xs);
                    for i in 0..rows {
                        let orow =
                            &mut out.data[(row + i) * m..(row + i + 1) * m];
                        for (j, o) in orow.iter_mut().enumerate() {
                            *o += sx.data[j * rows + i];
                        }
                    }
                    for o in out.data[row * m..(row + rows) * m].iter_mut()
                    {
                        *o *= alphas[g];
                    }
                    row += rows;
                }
            } else {
                run_per_segment(adapters, regens, alphas, x, segs, ws, out);
            }
        }
        _ => run_per_segment(adapters, regens, alphas, x, segs, ws, out),
    }
}

/// Per-segment fallback: each segment computes through its own
/// [`Adapter::forward_into`] on a row-slice copy (RoSA's sparse half,
/// mixed LoRA ranks).
fn run_per_segment(
    adapters: &[&dyn Adapter],
    regens: &[&[Arc<QuantMat>]],
    alphas: &[f32],
    x: &Matrix,
    segs: &[usize],
    ws: &mut Workspace,
    out: &mut Matrix,
) {
    let n = x.cols;
    let m = out.cols;
    let mut row = 0usize;
    for (g, &rows) in segs.iter().enumerate() {
        if rows == 0 {
            continue;
        }
        let mut xs = ws.take_matrix(rows, n);
        xs.data.copy_from_slice(&x.data[row * n..(row + rows) * n]);
        let mut os = ws.take_matrix(rows, m);
        adapters[g].forward_into(&xs, regens[g], alphas[g], ws, &mut os);
        out.data[row * m..(row + rows) * m].copy_from_slice(&os.data);
        ws.recycle_matrix(os);
        ws.recycle_matrix(xs);
        row += rows;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Pcg64;

    fn cosa_site(seed: u64, m: usize, n: usize) -> Arc<dyn Adapter> {
        let mut rng = Pcg64::derive(seed, "y");
        let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
        Arc::new(CosaAdapter::new(
            seed,
            "t.l".into(),
            "t.r".into(),
            m,
            n,
            Arc::new(y),
        ))
    }

    fn lora_site(seed: u64, m: usize, n: usize, r: usize) -> Arc<dyn Adapter>
    {
        let mut rng = Pcg64::derive(seed, "lora");
        let b = Matrix::gaussian(m, r, 0.5, &mut rng);
        let a = Matrix::gaussian(r, n, 0.5, &mut rng);
        Arc::new(LoraAdapter::try_new(Arc::new(b), Arc::new(a)).unwrap())
    }

    fn rosa_site(seed: u64, m: usize, n: usize, r: usize) -> Arc<dyn Adapter>
    {
        let mut rng = Pcg64::derive(seed, "rosa");
        let mut s = Matrix::gaussian(m, n, 0.5, &mut rng);
        for (i, v) in s.data.iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        let b = Matrix::gaussian(m, r, 0.5, &mut rng);
        let a = Matrix::gaussian(r, n, 0.5, &mut rng);
        RosaAdapter::try_new(Arc::new(s), Arc::new(b), Arc::new(a))
            .map(|ad| Arc::new(ad) as Arc<dyn Adapter>)
            .unwrap()
    }

    fn materialized(ad: &dyn Adapter) -> Vec<Arc<QuantMat>> {
        materialized_as(ad, QuantKind::F32)
    }

    fn materialized_as(
        ad: &dyn Adapter,
        kind: QuantKind,
    ) -> Vec<Arc<QuantMat>> {
        ad.regen_specs()
            .iter()
            .map(|s| {
                Arc::new(QuantMat::encode_owned(s.materialize(), kind))
            })
            .collect()
    }

    #[test]
    fn mixed_method_grouped_matches_per_segment_bitwise() {
        // A fused batch whose segments interleave all three methods:
        // the dispatcher's outputs must equal composed single-segment
        // forward_into calls bit for bit, regardless of run splits.
        let (m, n) = (12usize, 10usize);
        let sites: Vec<Arc<dyn Adapter>> = vec![
            cosa_site(1, m, n),
            cosa_site(2, m, n),
            lora_site(3, m, n, 3),
            rosa_site(4, m, n, 2),
            lora_site(5, m, n, 5), // different rank: per-seg fallback
            cosa_site(6, m, n),
        ];
        let segs = [2usize, 1, 3, 2, 1, 2];
        let alphas = [2.0f32, 0.5, 1.0, 1.5, 3.0, 0.25];
        let total: usize = segs.iter().sum();
        let mut rng = Pcg64::new(9);
        let x = Matrix::gaussian(total, n, 1.0, &mut rng);
        let regens: Vec<Vec<Arc<QuantMat>>> =
            sites.iter().map(|s| materialized(s.as_ref())).collect();

        let adapters: Vec<&dyn Adapter> =
            sites.iter().map(|s| s.as_ref()).collect();
        let regen_refs: Vec<&[Arc<QuantMat>]> =
            regens.iter().map(|r| r.as_slice()).collect();
        let mut ws = Workspace::new();
        let mut fused = Matrix::zeros(total, m);
        forward_grouped_into(
            &adapters, &regen_refs, &alphas, &x, &segs, &mut ws,
            &mut fused,
        );

        let mut row = 0usize;
        for (g, &rows) in segs.iter().enumerate() {
            let xs = Matrix::from_vec(
                rows,
                n,
                x.data[row * n..(row + rows) * n].to_vec(),
            );
            let mut o = Matrix::zeros(rows, m);
            adapters[g]
                .forward_into(&xs, &regens[g], alphas[g], &mut ws, &mut o);
            for (i, (p, q)) in fused.data[row * m..(row + rows) * m]
                .iter()
                .zip(&o.data)
                .enumerate()
            {
                assert_eq!(
                    p.to_bits(),
                    q.to_bits(),
                    "seg {g} elem {i}: {p} vs {q}"
                );
            }
            row += rows;
        }
    }

    #[test]
    fn grouped_with_quantized_regens_matches_per_segment_bitwise() {
        // The scenario-7 serving shape: an all-CoSA fused batch whose
        // cache residents are a mix of storage kinds.  The grouped
        // quantized sweeps must equal composed per-segment forward_into
        // calls (themselves the pack-fused quant route) bit for bit.
        let (m, n) = (12usize, 10usize);
        let sites: Vec<Arc<dyn Adapter>> =
            (0..4).map(|i| cosa_site(30 + i, m, n)).collect();
        let kinds = [QuantKind::Bf16, QuantKind::Int8, QuantKind::F32,
                     QuantKind::Bf16];
        let segs = [2usize, 0, 3, 1];
        let alphas = [2.0f32, 1.0, 0.5, 3.0];
        let total: usize = segs.iter().sum();
        let mut rng = Pcg64::new(41);
        let x = Matrix::gaussian(total, n, 1.0, &mut rng);
        let regens: Vec<Vec<Arc<QuantMat>>> = sites
            .iter()
            .zip(&kinds)
            .map(|(s, &kind)| materialized_as(s.as_ref(), kind))
            .collect();
        let adapters: Vec<&dyn Adapter> =
            sites.iter().map(|s| s.as_ref()).collect();
        let regen_refs: Vec<&[Arc<QuantMat>]> =
            regens.iter().map(|r| r.as_slice()).collect();
        let mut ws = Workspace::new();
        let mut fused = Matrix::zeros(total, m);
        forward_grouped_into(
            &adapters, &regen_refs, &alphas, &x, &segs, &mut ws,
            &mut fused,
        );
        let mut row = 0usize;
        for (g, &rows) in segs.iter().enumerate() {
            if rows == 0 {
                continue;
            }
            let xs = Matrix::from_vec(
                rows,
                n,
                x.data[row * n..(row + rows) * n].to_vec(),
            );
            let mut o = Matrix::zeros(rows, m);
            adapters[g]
                .forward_into(&xs, &regens[g], alphas[g], &mut ws, &mut o);
            for (i, (p, q)) in fused.data[row * m..(row + rows) * m]
                .iter()
                .zip(&o.data)
                .enumerate()
            {
                assert_eq!(
                    p.to_bits(),
                    q.to_bits(),
                    "{} seg {g} elem {i}: {p} vs {q}",
                    kinds[g].name()
                );
            }
            row += rows;
        }
    }

    #[test]
    fn regen_spec_bytes_as_counts_encoded_bytes() {
        let spec = RegenSpec {
            seed: 1,
            name: "s.l".into(),
            rows: 8,
            cols: 6,
            regen: cosa::regen_l,
        };
        assert_eq!(spec.bytes(), 8 * 6 * 4);
        assert_eq!(spec.bytes_as(QuantKind::F32), spec.bytes());
        assert_eq!(spec.bytes_as(QuantKind::Bf16), 8 * 6 * 2);
        assert_eq!(spec.bytes_as(QuantKind::Int8), 8 * 6 + 8 * 4);
    }

    #[test]
    fn decode_rejects_unservable_methods_and_missing_tensors() {
        let tensors = BTreeMap::new();
        for m in [Method::Full, Method::PiSSA, Method::DoRA] {
            assert!(decode_site(m, "s", 4, 4, 1, &tensors).is_err());
        }
        assert!(decode_site(Method::CoSA, "s", 4, 4, 1, &tensors).is_err());
        assert!(decode_site(Method::LoRA, "s", 4, 4, 1, &tensors).is_err());
        assert!(decode_site(Method::RoSA, "s", 4, 4, 1, &tensors).is_err());
    }

    #[test]
    fn encode_decode_roundtrips_every_method() {
        let (m, n) = (8usize, 6usize);
        let mut rng = Pcg64::new(4);
        let x = Matrix::gaussian(3, n, 1.0, &mut rng);
        // the CoSA site must carry the canonical `<site>.l` / `<site>.r`
        // projection names — decode derives them from the site stem, and
        // a round-trip with custom stems would regenerate different bits
        let mut yrng = Pcg64::derive(7, "y");
        let y = Matrix::gaussian(4, 3, 0.5, &mut yrng);
        let cosa: Arc<dyn Adapter> = Arc::new(CosaAdapter::new(
            7,
            "s0.l".into(),
            "s0.r".into(),
            m,
            n,
            Arc::new(y),
        ));
        for site in [cosa, lora_site(8, m, n, 2), rosa_site(9, m, n, 2)] {
            let mut tensors = BTreeMap::new();
            site.encode_tensors("s0", &mut tensors);
            let back =
                decode_site(site.method(), "s0", m, n, 7, &tensors).unwrap();
            assert_eq!(back.method(), site.method());
            assert_eq!(back.param_count(), site.param_count());
            let want =
                site.forward(&x, &materialized(site.as_ref()), 1.5);
            let got =
                back.forward(&x, &materialized(back.as_ref()), 1.5);
            for (p, q) in want.data.iter().zip(&got.data) {
                assert_eq!(
                    p.to_bits(),
                    q.to_bits(),
                    "{:?} decode drifted",
                    site.method()
                );
            }
        }
    }
}
