//! Adapter methods: CoSA and every baseline the paper evaluates.
//!
//! Five concerns live here:
//! * `init` — deterministic tensor initialization for every artifact input
//!   (synthetic "pretrained" trunks, Gaussian L/R projections, PiSSA SVD
//!   init, VeRA/NoLA shared banks, DoRA magnitudes);
//! * `traits` — the method-agnostic [`Adapter`] serving contract
//!   (forward / grouped forward / VJP / cost accounting / seed-regen
//!   description / checkpoint encode-decode) the model and serve layers
//!   program against;
//! * `cosa` — the host-side mirror of the adapter math plus the paper's
//!   seed-regeneration storage trick (store Y + seed, regenerate L and R),
//!   and [`cosa::CosaAdapter`], the trait impl over that math;
//! * `lora` / `rosa` — the §4 baseline impls served by the same engine:
//!   plain BA ([`lora::LoraAdapter`]) and sparse + low-rank
//!   ([`rosa::RosaAdapter`], sparse half on the threaded
//!   `linalg::sparse` kernel);
//! * `costmodel` — trainable-parameter and memory accounting against real
//!   LLM architectures (Table 1, Figure 3).

pub mod cosa;
pub mod costmodel;
pub mod init;
pub mod lora;
pub mod rosa;
pub mod traits;

pub use traits::{
    decode_site, forward_grouped_into, forward_grouped_into_marked,
    Adapter, GroupedMarks, RegenSpec, SERVABLE_METHODS,
};

/// The PEFT methods implemented across L2/L3.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Full,
    LoRA,
    PiSSA,
    DoRA,
    VeRA,
    AdaLoRA,
    NoLA,
    RoSA,
    CoSA,
}

impl Method {
    pub fn from_str(s: &str) -> anyhow::Result<Method> {
        Ok(match s {
            "full" => Method::Full,
            "lora" => Method::LoRA,
            "pissa" => Method::PiSSA,
            "dora" => Method::DoRA,
            "vera" => Method::VeRA,
            "adalora" => Method::AdaLoRA,
            "nola" => Method::NoLA,
            "rosa" => Method::RoSA,
            "cosa" => Method::CoSA,
            other => anyhow::bail!("unknown method `{other}`"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Full => "full",
            Method::LoRA => "lora",
            Method::PiSSA => "pissa",
            Method::DoRA => "dora",
            Method::VeRA => "vera",
            Method::AdaLoRA => "adalora",
            Method::NoLA => "nola",
            Method::RoSA => "rosa",
            Method::CoSA => "cosa",
        }
    }

    /// Display name matching the paper's tables.
    pub fn paper_name(&self) -> &'static str {
        match self {
            Method::Full => "Full FT",
            Method::LoRA => "LoRA",
            Method::PiSSA => "PiSSA",
            Method::DoRA => "DoRA",
            Method::VeRA => "VeRA",
            Method::AdaLoRA => "AdaLoRA",
            Method::NoLA => "NoLA",
            Method::RoSA => "RoSA",
            Method::CoSA => "CoSA",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_names() {
        for m in [Method::Full, Method::LoRA, Method::PiSSA, Method::DoRA,
                  Method::VeRA, Method::AdaLoRA, Method::NoLA, Method::RoSA,
                  Method::CoSA] {
            assert_eq!(Method::from_str(m.name()).unwrap(), m);
        }
        assert!(Method::from_str("qlora").is_err());
    }
}
