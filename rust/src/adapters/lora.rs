//! Plain LoRA — the `ΔW = B·A` low-rank baseline (Hu et al.), served
//! through the method-agnostic [`Adapter`] trait.
//!
//! The paper's §4 comparison pits CoSA against low-rank adaptation on
//! identical tasks; this impl is the serving-side half of that
//! comparison.  Unlike CoSA there is **no projection regeneration**:
//! both factors are trainable, both are stored, and
//! [`Adapter::regen_specs`] is empty — the projection cache never sees
//! a LoRA adapter.  Forward is two transpose-free NT products
//! (`o = α · x Aᵀ Bᵀ`), grouped-servable via the same block-diagonal
//! kernel sweeps CoSA batches use (see
//! [`crate::adapters::traits::forward_grouped_into`]).

use std::any::Any;
use std::collections::BTreeMap;
use std::sync::Arc;

use crate::adapters::traits::{Adapter, RegenSpec};
use crate::adapters::Method;
use crate::linalg::{self, QuantMat, Workspace};
use crate::math::matrix::Matrix;

/// One adapted `m × n` site under plain LoRA: `B` (m × r) and `A`
/// (r × n), both stored, both trainable.
pub struct LoraAdapter {
    b: Arc<Matrix>,
    a: Arc<Matrix>,
}

impl LoraAdapter {
    /// Validates the factor shapes agree on the rank.
    pub fn try_new(
        b: Arc<Matrix>,
        a: Arc<Matrix>,
    ) -> anyhow::Result<LoraAdapter> {
        anyhow::ensure!(
            b.cols == a.rows && b.cols >= 1,
            "lora factors disagree: B is {}x{}, A is {}x{}",
            b.rows, b.cols, a.rows, a.cols
        );
        anyhow::ensure!(
            b.rows >= 1 && a.cols >= 1,
            "lora site dims must be >= 1 (B {}x{}, A {}x{})",
            b.rows, b.cols, a.rows, a.cols
        );
        Ok(LoraAdapter { b, a })
    }

    pub fn rank(&self) -> usize {
        self.b.cols
    }

    pub fn b_ref(&self) -> &Matrix {
        &self.b
    }

    pub fn a_ref(&self) -> &Matrix {
        &self.a
    }
}

impl Adapter for LoraAdapter {
    fn method(&self) -> Method {
        Method::LoRA
    }

    fn out_dim(&self) -> usize {
        self.b.rows
    }

    fn in_dim(&self) -> usize {
        self.a.cols
    }

    fn core_dims(&self) -> (usize, usize) {
        (self.rank(), self.rank())
    }

    fn param_count(&self) -> usize {
        self.b.data.len() + self.a.data.len()
    }

    fn resident_bytes(&self) -> usize {
        (self.b.data.len() + self.a.data.len()) * 4
    }

    fn regen_bytes(&self) -> usize {
        0
    }

    /// Nothing regenerates — LoRA stores every tensor.
    fn regen_specs(&self) -> Vec<RegenSpec> {
        Vec::new()
    }

    /// `out = α · x Aᵀ Bᵀ` — two NT products through workspace
    /// intermediates, no transpose copies.
    fn forward_into(
        &self,
        x: &Matrix,
        _regen: &[Arc<QuantMat>],
        alpha: f32,
        ws: &mut Workspace,
        out: &mut Matrix,
    ) {
        let mut u = ws.take_matrix(x.rows, self.rank());
        linalg::gemm_nt_into(x, &self.a, &mut u);
        linalg::gemm_nt_into(&u, &self.b, out);
        out.scale(alpha);
        ws.recycle_matrix(u);
    }

    /// Gradients in encode order `[dB, dA]` plus `dX`:
    /// `dB = α · gᵀ (x Aᵀ)`, `dA = α · (g B)ᵀ x`, `dX = α · g B A`.
    fn vjp(
        &self,
        x: &Matrix,
        _regen: &[Arc<QuantMat>],
        g: &Matrix,
        alpha: f32,
    ) -> (Vec<Matrix>, Matrix) {
        let u = linalg::gemm_nt(x, &self.a); // x Aᵀ   (N × r)
        let mut db = linalg::gemm_tn(g, &u); // gᵀ(xAᵀ) (m × r)
        db.scale(alpha);
        let t = linalg::gemm(g, &self.b); //   g B    (N × r)
        let mut da = linalg::gemm_tn(&t, x); // (gB)ᵀx (r × n)
        da.scale(alpha);
        let mut dx = linalg::gemm(&t, &self.a); //    (N × n)
        dx.scale(alpha);
        (vec![db, da], dx)
    }

    fn encode_tensors(
        &self,
        site: &str,
        out: &mut BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    ) {
        out.insert(
            format!("{site}.lora_b"),
            (vec![self.b.rows, self.b.cols], self.b.data.clone()),
        );
        out.insert(
            format!("{site}.lora_a"),
            (vec![self.a.rows, self.a.cols], self.a.data.clone()),
        );
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Pcg64;

    fn sample(m: usize, n: usize, r: usize, seed: u64) -> LoraAdapter {
        let mut rng = Pcg64::derive(seed, "lora-test");
        let b = Matrix::gaussian(m, r, 0.5, &mut rng);
        let a = Matrix::gaussian(r, n, 0.5, &mut rng);
        LoraAdapter::try_new(Arc::new(b), Arc::new(a)).unwrap()
    }

    #[test]
    fn forward_matches_materialized_ba() {
        let (m, n, r, rows) = (10usize, 12usize, 3usize, 6usize);
        let ad = sample(m, n, r, 1);
        let mut rng = Pcg64::new(2);
        let x = Matrix::gaussian(rows, n, 1.0, &mut rng);
        let got = ad.forward(&x, &[], 1.5);
        // slow path: ΔW = B·A, o = α · x · ΔWᵀ
        let mut delta = linalg::gemm(ad.b_ref(), ad.a_ref());
        delta.scale(1.5);
        let want = x.matmul(&delta.transpose());
        for (p, q) in got.data.iter().zip(&want.data) {
            assert!((p - q).abs() < 1e-4, "{p} vs {q}");
        }
    }

    #[test]
    fn vjp_matches_finite_differences() {
        // Forward is linear in both factors, so central differences on
        // the scalar loss Σ o⊙g recover each gradient up to rounding.
        let (m, n, r, rows) = (6usize, 8usize, 3usize, 5usize);
        let ad = sample(m, n, r, 3);
        let mut rng = Pcg64::new(4);
        let x = Matrix::gaussian(rows, n, 1.0, &mut rng);
        let g = Matrix::gaussian(rows, m, 0.5, &mut rng);
        let alpha = 1.3f32;
        let loss = |bb: &Matrix, aa: &Matrix| -> f64 {
            let tmp = LoraAdapter::try_new(
                Arc::new(bb.clone()),
                Arc::new(aa.clone()),
            )
            .unwrap();
            let o = tmp.forward(&x, &[], alpha);
            o.data.iter().zip(&g.data)
                .map(|(ov, gv)| *ov as f64 * *gv as f64).sum()
        };
        let (grads, dx) = ad.vjp(&x, &[], &g, alpha);
        let (db, da) = (&grads[0], &grads[1]);
        let eps = 1e-2f32;
        for idx in [0usize, 3, m * r - 1] {
            let mut bp = ad.b_ref().clone();
            bp.data[idx] += eps;
            let mut bm = ad.b_ref().clone();
            bm.data[idx] -= eps;
            let fd = (loss(&bp, ad.a_ref()) - loss(&bm, ad.a_ref()))
                / (2.0 * eps as f64);
            assert!(
                (fd - db.data[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "dB[{idx}]: fd {fd} vs analytic {}", db.data[idx]
            );
        }
        for idx in [0usize, 5, r * n - 1] {
            let mut ap = ad.a_ref().clone();
            ap.data[idx] += eps;
            let mut am = ad.a_ref().clone();
            am.data[idx] -= eps;
            let fd = (loss(ad.b_ref(), &ap) - loss(ad.b_ref(), &am))
                / (2.0 * eps as f64);
            assert!(
                (fd - da.data[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "dA[{idx}]: fd {fd} vs analytic {}", da.data[idx]
            );
        }
        // dX against the materialized ΔW: dX = α · g · (B A)
        let delta = linalg::gemm(ad.b_ref(), ad.a_ref());
        let mut dx_ref = g.matmul(&delta);
        dx_ref.scale(alpha);
        for (p, q) in dx.data.iter().zip(&dx_ref.data) {
            assert!((p - q).abs() < 1e-3, "{p} vs {q}");
        }
    }

    #[test]
    fn grouped_forward_is_bit_identical_to_single_calls() {
        // Same-rank LoRA segments fuse through two grouped NT sweeps;
        // the result must equal composed forward_into calls bitwise.
        use crate::adapters::traits::forward_grouped_into;
        let (m, n, r) = (10usize, 12usize, 3usize);
        let ads: Vec<LoraAdapter> =
            (0..4).map(|i| sample(m, n, r, 10 + i)).collect();
        let segs = [2usize, 0, 3, 1];
        let alphas = [2.0f32, 1.0, 0.5, 3.0];
        let total: usize = segs.iter().sum();
        let mut rng = Pcg64::new(5);
        let x = Matrix::gaussian(total, n, 1.0, &mut rng);
        let refs: Vec<&dyn Adapter> =
            ads.iter().map(|a| a as &dyn Adapter).collect();
        let regens: Vec<&[Arc<QuantMat>]> =
            ads.iter().map(|_| &[] as &[Arc<QuantMat>]).collect();
        let mut ws = Workspace::new();
        let mut fused = Matrix::zeros(total, m);
        forward_grouped_into(&refs, &regens, &alphas, &x, &segs, &mut ws,
                             &mut fused);
        let mut row = 0usize;
        for (g, &rows) in segs.iter().enumerate() {
            if rows == 0 {
                continue;
            }
            let xs = Matrix::from_vec(
                rows, n, x.data[row * n..(row + rows) * n].to_vec());
            let mut o = Matrix::zeros(rows, m);
            ads[g].forward_into(&xs, &[], alphas[g], &mut ws, &mut o);
            for (p, q) in fused.data[row * m..(row + rows) * m]
                .iter()
                .zip(&o.data)
            {
                assert_eq!(p.to_bits(), q.to_bits(), "seg {g}: {p} vs {q}");
            }
            row += rows;
        }
    }

    #[test]
    fn accounting_and_shape_validation() {
        let ad = sample(10, 12, 3, 6);
        assert_eq!(ad.param_count(), 10 * 3 + 3 * 12);
        assert_eq!(ad.resident_bytes(), ad.param_count() * 4);
        assert_eq!(ad.regen_bytes(), 0);
        assert!(ad.regen_specs().is_empty());
        assert_eq!(ad.core_dims(), (3, 3));
        let b = Arc::new(Matrix::zeros(10, 3));
        let a = Arc::new(Matrix::zeros(4, 12));
        assert!(LoraAdapter::try_new(b, a).is_err(), "rank mismatch");
    }
}
