//! RoSA — robust sparse + low-rank adaptation (`ΔW = S + B·A`), served
//! through the method-agnostic [`Adapter`] trait.
//!
//! PAPERS.md names RoSA as the natural first baseline beyond plain
//! LoRA: a low-rank pair catches the dense drift, a sparse residual `S`
//! (fixed support, trained values) catches the outliers low-rank can't.
//! The sparse half of the forward runs on the threaded
//! [`linalg::sparse::gemm_sparse_left`] kernel — `x Sᵀ` computed as
//! `(S xᵀ)ᵀ` so `S` is the sparse *left* operand and zero rows of its
//! access pattern vanish wholesale.  `S` is carried as a dense matrix
//! whose zero entries are exactly `0.0` (the kernel's skip convention
//! and the checkpoint layout); the support mask is implicit in those
//! zeros and gradients are masked to it, so training never densifies
//! the residual.
//!
//! Like LoRA and unlike CoSA, nothing regenerates from a seed:
//! [`Adapter::regen_specs`] is empty and all three tensors are stored.

use std::any::Any;
use std::collections::BTreeMap;
use std::sync::Arc;

use crate::adapters::traits::{Adapter, RegenSpec};
use crate::adapters::Method;
use crate::linalg::{self, QuantMat, Workspace};
use crate::math::matrix::Matrix;

/// One adapted `m × n` site under RoSA: sparse residual `S` (m × n,
/// zeros exactly 0.0) plus low-rank factors `B` (m × r), `A` (r × n).
pub struct RosaAdapter {
    s: Arc<Matrix>,
    b: Arc<Matrix>,
    a: Arc<Matrix>,
    /// Nonzeros of `S` at construction — the trainable count of the
    /// sparse half (support is fixed).
    nnz: usize,
}

impl RosaAdapter {
    /// Validates that `S` spans the site and the factors agree on rank.
    pub fn try_new(
        s: Arc<Matrix>,
        b: Arc<Matrix>,
        a: Arc<Matrix>,
    ) -> anyhow::Result<RosaAdapter> {
        anyhow::ensure!(
            b.cols == a.rows && b.cols >= 1,
            "rosa factors disagree: B is {}x{}, A is {}x{}",
            b.rows, b.cols, a.rows, a.cols
        );
        anyhow::ensure!(
            s.rows == b.rows && s.cols == a.cols,
            "rosa sparse residual is {}x{}, low-rank half adapts {}x{}",
            s.rows, s.cols, b.rows, a.cols
        );
        anyhow::ensure!(
            s.rows >= 1 && s.cols >= 1,
            "rosa site dims must be >= 1 (S {}x{})",
            s.rows, s.cols
        );
        let nnz = s.data.iter().filter(|v| **v != 0.0).count();
        Ok(RosaAdapter { s, b, a, nnz })
    }

    pub fn rank(&self) -> usize {
        self.b.cols
    }

    /// Nonzeros of the sparse residual (fixed support).
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    pub fn sparse_ref(&self) -> &Matrix {
        &self.s
    }

    pub fn b_ref(&self) -> &Matrix {
        &self.b
    }

    pub fn a_ref(&self) -> &Matrix {
        &self.a
    }
}

impl Adapter for RosaAdapter {
    fn method(&self) -> Method {
        Method::RoSA
    }

    fn out_dim(&self) -> usize {
        self.s.rows
    }

    fn in_dim(&self) -> usize {
        self.s.cols
    }

    fn core_dims(&self) -> (usize, usize) {
        (self.rank(), self.rank())
    }

    /// Trained values: the sparse support plus both factors.
    fn param_count(&self) -> usize {
        self.nnz + self.b.data.len() + self.a.data.len()
    }

    /// Checkpoint bytes: `S` is stored dense-with-zeros (blob-format
    /// simplicity; the nnz savings are a format evolution, not a
    /// serving concern), plus both factors.
    fn resident_bytes(&self) -> usize {
        (self.s.data.len() + self.b.data.len() + self.a.data.len()) * 4
    }

    fn regen_bytes(&self) -> usize {
        0
    }

    /// Nothing regenerates — RoSA stores every tensor.
    fn regen_specs(&self) -> Vec<RegenSpec> {
        Vec::new()
    }

    /// `out = α · (x Sᵀ + x Aᵀ Bᵀ)`.  The low-rank half runs the two
    /// NT products into `out`; the sparse half computes `(S xᵀ)` on the
    /// sparse-left kernel and accumulates its transpose.
    fn forward_into(
        &self,
        x: &Matrix,
        _regen: &[Arc<QuantMat>],
        alpha: f32,
        ws: &mut Workspace,
        out: &mut Matrix,
    ) {
        let mut u = ws.take_matrix(x.rows, self.rank());
        linalg::gemm_nt_into(x, &self.a, &mut u);
        linalg::gemm_nt_into(&u, &self.b, out);
        ws.recycle_matrix(u);
        // sparse half: S (m × n) is the left operand of S · xᵀ
        let sx = linalg::sparse::gemm_sparse_left(&self.s, &x.transpose());
        let m = self.s.rows;
        let rows = x.rows;
        for i in 0..rows {
            let orow = &mut out.data[i * m..(i + 1) * m];
            for (j, o) in orow.iter_mut().enumerate() {
                *o += sx.data[j * rows + i];
            }
        }
        out.scale(alpha);
    }

    /// Gradients in encode order `[dS, dB, dA]` plus `dX`:
    /// `dS = α · gᵀ x` masked to the fixed support, `dB/dA` as LoRA,
    /// `dX = α · g (S + B A)`.
    fn vjp(
        &self,
        x: &Matrix,
        _regen: &[Arc<QuantMat>],
        g: &Matrix,
        alpha: f32,
    ) -> (Vec<Matrix>, Matrix) {
        let mut ds = linalg::gemm_tn(g, x); // gᵀ x    (m × n)
        ds.scale(alpha);
        for (dv, sv) in ds.data.iter_mut().zip(&self.s.data) {
            if *sv == 0.0 {
                *dv = 0.0; // fixed support: off-mask entries stay frozen
            }
        }
        let u = linalg::gemm_nt(x, &self.a); // x Aᵀ   (N × r)
        let mut db = linalg::gemm_tn(g, &u); // gᵀ(xAᵀ) (m × r)
        db.scale(alpha);
        let t = linalg::gemm(g, &self.b); //   g B     (N × r)
        let mut da = linalg::gemm_tn(&t, x); // (gB)ᵀx  (r × n)
        da.scale(alpha);
        let mut dx = linalg::gemm(&t, &self.a); //     (N × n)
        let gs = linalg::sparse::gemm_sparse_left(g, &self.s);
        // gs is g · S?  No: g (N × m) · S (m × n) — S is the *right*
        // operand, so run the dense-left product with g sparse-skipped;
        // g is dense, but gemm_sparse_left only elides exact zeros, so
        // the result still equals the dense product exactly.
        for (d, v) in dx.data.iter_mut().zip(&gs.data) {
            *d += v;
        }
        dx.scale(alpha);
        (vec![ds, db, da], dx)
    }

    fn encode_tensors(
        &self,
        site: &str,
        out: &mut BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    ) {
        out.insert(
            format!("{site}.rosa_s"),
            (vec![self.s.rows, self.s.cols], self.s.data.clone()),
        );
        out.insert(
            format!("{site}.rosa_b"),
            (vec![self.b.rows, self.b.cols], self.b.data.clone()),
        );
        out.insert(
            format!("{site}.rosa_a"),
            (vec![self.a.rows, self.a.cols], self.a.data.clone()),
        );
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Pcg64;

    /// ~1/3-dense sparse residual plus rank-r factors.
    fn sample(m: usize, n: usize, r: usize, seed: u64) -> RosaAdapter {
        let mut rng = Pcg64::derive(seed, "rosa-test");
        let mut s = Matrix::gaussian(m, n, 0.5, &mut rng);
        for (i, v) in s.data.iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        let b = Matrix::gaussian(m, r, 0.5, &mut rng);
        let a = Matrix::gaussian(r, n, 0.5, &mut rng);
        RosaAdapter::try_new(Arc::new(s), Arc::new(b), Arc::new(a)).unwrap()
    }

    fn materialized_delta(ad: &RosaAdapter, alpha: f32) -> Matrix {
        let mut d = linalg::gemm(ad.b_ref(), ad.a_ref());
        for (dv, sv) in d.data.iter_mut().zip(&ad.sparse_ref().data) {
            *dv += sv;
        }
        d.scale(alpha);
        d
    }

    #[test]
    fn forward_matches_materialized_s_plus_ba() {
        let (m, n, r, rows) = (10usize, 12usize, 3usize, 6usize);
        let ad = sample(m, n, r, 1);
        let mut rng = Pcg64::new(2);
        let x = Matrix::gaussian(rows, n, 1.0, &mut rng);
        let got = ad.forward(&x, &[], 1.5);
        let want = x.matmul(&materialized_delta(&ad, 1.5).transpose());
        for (p, q) in got.data.iter().zip(&want.data) {
            assert!((p - q).abs() < 1e-4, "{p} vs {q}");
        }
    }

    #[test]
    fn vjp_matches_finite_differences_and_respects_support() {
        let (m, n, r, rows) = (6usize, 8usize, 2usize, 5usize);
        let ad = sample(m, n, r, 3);
        let mut rng = Pcg64::new(4);
        let x = Matrix::gaussian(rows, n, 1.0, &mut rng);
        let g = Matrix::gaussian(rows, m, 0.5, &mut rng);
        let alpha = 1.3f32;
        let loss = |ss: &Matrix| -> f64 {
            let tmp = RosaAdapter::try_new(
                Arc::new(ss.clone()),
                Arc::new(ad.b_ref().clone()),
                Arc::new(ad.a_ref().clone()),
            )
            .unwrap();
            let o = tmp.forward(&x, &[], alpha);
            o.data.iter().zip(&g.data)
                .map(|(ov, gv)| *ov as f64 * *gv as f64).sum()
        };
        let (grads, dx) = ad.vjp(&x, &[], &g, alpha);
        let ds = &grads[0];
        // off-support entries are frozen; on-support entries match
        // central differences
        let eps = 1e-2f32;
        let mut checked_on = 0usize;
        for idx in 0..m * n {
            if ad.sparse_ref().data[idx] == 0.0 {
                assert_eq!(ds.data[idx], 0.0, "off-mask gradient leaked");
                continue;
            }
            if checked_on >= 4 {
                continue;
            }
            checked_on += 1;
            let mut sp = ad.sparse_ref().clone();
            sp.data[idx] += eps;
            let mut sm = ad.sparse_ref().clone();
            sm.data[idx] -= eps;
            let fd = (loss(&sp) - loss(&sm)) / (2.0 * eps as f64);
            assert!(
                (fd - ds.data[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "dS[{idx}]: fd {fd} vs analytic {}", ds.data[idx]
            );
        }
        assert!(checked_on >= 4, "sample() must leave a support to check");
        // dX against the materialized ΔW
        let dx_ref = g.matmul(&materialized_delta(&ad, alpha));
        for (p, q) in dx.data.iter().zip(&dx_ref.data) {
            assert!((p - q).abs() < 1e-3, "{p} vs {q}");
        }
    }

    #[test]
    fn grouped_forward_is_bit_identical_to_single_calls() {
        // Same-rank RoSA segments now take the grouped fast path (the
        // dense low-rank half fused across segments, the sparse
        // residual per-segment); the fused output must still equal
        // composed single calls bit for bit.
        use crate::adapters::traits::forward_grouped_into;
        let (m, n, r) = (10usize, 12usize, 2usize);
        let ads: Vec<RosaAdapter> =
            (0..3).map(|i| sample(m, n, r, 20 + i)).collect();
        let segs = [2usize, 3, 1];
        let alphas = [2.0f32, 0.5, 1.0];
        let total: usize = segs.iter().sum();
        let mut rng = Pcg64::new(6);
        let x = Matrix::gaussian(total, n, 1.0, &mut rng);
        let refs: Vec<&dyn Adapter> =
            ads.iter().map(|a| a as &dyn Adapter).collect();
        let regens: Vec<&[Arc<QuantMat>]> =
            ads.iter().map(|_| &[] as &[Arc<QuantMat>]).collect();
        let mut ws = Workspace::new();
        let mut fused = Matrix::zeros(total, m);
        forward_grouped_into(&refs, &regens, &alphas, &x, &segs, &mut ws,
                             &mut fused);
        let mut row = 0usize;
        for (g, &rows) in segs.iter().enumerate() {
            let xs = Matrix::from_vec(
                rows, n, x.data[row * n..(row + rows) * n].to_vec());
            let mut o = Matrix::zeros(rows, m);
            ads[g].forward_into(&xs, &[], alphas[g], &mut ws, &mut o);
            for (p, q) in fused.data[row * m..(row + rows) * m]
                .iter()
                .zip(&o.data)
            {
                assert_eq!(p.to_bits(), q.to_bits(), "seg {g}: {p} vs {q}");
            }
            row += rows;
        }
    }

    #[test]
    fn grouped_fast_path_handles_zero_segs_and_mixed_ranks() {
        // Two acceptance edges for the grouped fast path: zero-row
        // segments must be skipped exactly, and mixed ranks must fall
        // back to per-segment composition — both bit-identical to
        // composed single calls.
        use crate::adapters::traits::forward_grouped_into;
        let (m, n) = (9usize, 11usize);
        for ranks in [[2usize, 2, 2], [2, 3, 2]] {
            let ads: Vec<RosaAdapter> = ranks
                .iter()
                .enumerate()
                .map(|(i, &r)| sample(m, n, r, 40 + i as u64))
                .collect();
            let segs = [3usize, 0, 2];
            let alphas = [1.5f32, 1.0, 0.25];
            let total: usize = segs.iter().sum();
            let mut rng = Pcg64::new(7);
            let x = Matrix::gaussian(total, n, 1.0, &mut rng);
            let refs: Vec<&dyn Adapter> =
                ads.iter().map(|a| a as &dyn Adapter).collect();
            let regens: Vec<&[Arc<QuantMat>]> =
                ads.iter().map(|_| &[] as &[Arc<QuantMat>]).collect();
            let mut ws = Workspace::new();
            let mut fused = Matrix::zeros(total, m);
            forward_grouped_into(&refs, &regens, &alphas, &x, &segs,
                                 &mut ws, &mut fused);
            let mut row = 0usize;
            for (g, &rows) in segs.iter().enumerate() {
                if rows == 0 {
                    continue;
                }
                let xs = Matrix::from_vec(
                    rows, n, x.data[row * n..(row + rows) * n].to_vec());
                let mut o = Matrix::zeros(rows, m);
                ads[g].forward_into(&xs, &[], alphas[g], &mut ws, &mut o);
                for (p, q) in fused.data[row * m..(row + rows) * m]
                    .iter()
                    .zip(&o.data)
                {
                    assert_eq!(p.to_bits(), q.to_bits(),
                               "ranks {ranks:?} seg {g}: {p} vs {q}");
                }
                row += rows;
            }
        }
    }

    #[test]
    fn accounting_counts_support_not_zeros() {
        let (m, n, r) = (9usize, 9usize, 2usize);
        let ad = sample(m, n, r, 8);
        assert_eq!(
            ad.param_count(),
            ad.nnz() + (m + n) * r,
            "trainables = sparse support + both factors"
        );
        assert!(ad.nnz() < m * n, "sample must actually be sparse");
        assert_eq!(ad.resident_bytes(), (m * n + (m + n) * r) * 4);
        assert_eq!(ad.regen_bytes(), 0);
        assert!(ad.regen_specs().is_empty());
        // shape validation
        let s = Arc::new(Matrix::zeros(m, n));
        let b = Arc::new(Matrix::zeros(m + 1, r));
        let a = Arc::new(Matrix::zeros(r, n));
        assert!(RosaAdapter::try_new(s, b, a).is_err());
    }
}
