//! Parameter / memory cost model (paper Table 1 and Figure 3).
//!
//! Counts trainable parameters and optimizer-state memory for every
//! method against *real* LLM architectures (Llama-3.2-1B, Qwen2-7B,
//! Llama-3.1-8B) — this part of the paper's evaluation is exact
//! arithmetic, so the reproduction matches its numbers to the megabyte.

use crate::adapters::Method;

/// One adapted linear site: x(n_in) → z(n_out).
#[derive(Clone, Copy, Debug)]
pub struct Site {
    pub n_in: usize,
    pub n_out: usize,
}

/// A model architecture as a list of adapted sites (per layer × layers).
#[derive(Clone, Debug)]
pub struct Arch {
    pub name: &'static str,
    pub sites: Vec<Site>,
}

impl Arch {
    /// Decoder with GQA attention + gated MLP; adapters on
    /// q,k,v,o,gate,up,down — the seven sites the paper's NLG runs adapt.
    pub fn llama_style(name: &'static str, d: usize, kv: usize, ff: usize,
                       layers: usize) -> Arch {
        let per_layer = vec![
            Site { n_in: d, n_out: d },   // q
            Site { n_in: d, n_out: kv },  // k
            Site { n_in: d, n_out: kv },  // v
            Site { n_in: d, n_out: d },   // o
            Site { n_in: d, n_out: ff },  // gate
            Site { n_in: d, n_out: ff },  // up
            Site { n_in: ff, n_out: d },  // down
        ];
        let mut sites = Vec::new();
        for _ in 0..layers {
            sites.extend_from_slice(&per_layer);
        }
        Arch { name, sites }
    }

    /// The three scales of Figure 3.
    pub fn paper_models() -> Vec<Arch> {
        vec![
            // Llama-3.2-1B: d=2048, kv=512, ff=8192, 16 layers
            Arch::llama_style("Llama-3.2-1B", 2048, 512, 8192, 16),
            // Qwen2-7B: d=3584, kv=512, ff=18944, 28 layers
            Arch::llama_style("Qwen2-7B", 3584, 512, 18944, 28),
            // Llama-3.1-8B: d=4096, kv=1024, ff=14336, 32 layers
            Arch::llama_style("Llama-3.1-8B", 4096, 1024, 14336, 32),
        ]
    }
}

/// Hyperparameters entering the counts.
#[derive(Clone, Copy, Debug)]
pub struct CostCfg {
    pub r: usize,
    pub a: usize,
    pub b: usize,
    pub nola_k: usize,
    /// Full-model parameter count (Full FT rows in Table 2/3).
    pub full_params: usize,
}

/// Trainable parameters for one site under `method` (paper Table 1).
pub fn site_params(method: Method, s: Site, c: &CostCfg) -> usize {
    let (m, n) = (s.n_out, s.n_in); // paper convention: ΔW ∈ R^{m×n}
    match method {
        Method::Full => m * n,
        Method::LoRA | Method::PiSSA => (m + n) * c.r,
        Method::DoRA => (m + n) * c.r + m,
        // VeRA trains the two scaling vectors (r-dim d and m-dim b).
        Method::VeRA => c.r + m,
        // AdaLoRA's P/λ/Q at the initial rank.
        Method::AdaLoRA => (m + n + 1) * c.r,
        Method::NoLA => 2 * c.nola_k,
        // RoSA's low-rank half; its sparse half's nnz is a serving-time
        // knob, not part of this table's fixed (r, a, b) configuration.
        Method::RoSA => (m + n) * c.r,
        Method::CoSA => c.a * c.b,
    }
}

/// Total trainable parameters across an architecture.
pub fn total_params(method: Method, arch: &Arch, c: &CostCfg) -> usize {
    if method == Method::Full {
        return c.full_params;
    }
    arch.sites.iter().map(|s| site_params(method, *s, c)).sum()
}

/// Training memory for the adapter path in bytes: fp32 parameters +
/// AdamW first/second moments + one gradient buffer (4 tensors the size
/// of the trainables — the "≈3× optimizer state" of §4.2 plus params).
pub fn train_memory_bytes(method: Method, arch: &Arch, c: &CostCfg) -> usize {
    total_params(method, arch, c) * 4 * 4
}

/// Storage on disk: CoSA stores Y + a seed (projections regenerate);
/// every other method stores all trainables.
pub fn storage_bytes(method: Method, arch: &Arch, c: &CostCfg) -> usize {
    match method {
        Method::CoSA => total_params(method, arch, c) * 4 + 8,
        _ => total_params(method, arch, c) * 4,
    }
}

/// A serving [`ModelSpec`](crate::model::ModelSpec)'s sites as cost
/// sites (`n_in = n`, `n_out = m`) — the bridge between the serving
/// layer's shape contract and this module's Table 1 / Figure 3
/// arithmetic.
pub fn spec_sites(spec: &crate::model::ModelSpec) -> Vec<Site> {
    spec.sites
        .iter()
        .map(|s| Site { n_in: s.shape.n, n_out: s.shape.m })
        .collect()
}

/// Trainable parameters of one adapter across a whole served model:
/// `Σ a_s·b_s` over its sites.  Unlike [`total_params`] (which applies
/// one global `(a, b)` to every site), this honors the spec's per-site
/// heterogeneous core dims.
pub fn spec_params(spec: &crate::model::ModelSpec) -> usize {
    spec.core_params()
}

/// Storage on disk for one whole-model CoSA adapter: every per-site
/// core plus **one** seed — the multi-site generalization of the
/// paper's "Y plus a seed" (§4.1).  All N sites regenerate their
/// projections from the same 8 bytes, which is exactly why a model's
/// adapter set stays tiny (checkpoint v2 materializes this layout; its
/// header overhead is measured by `Checkpoint::size_bytes`, not here).
pub fn spec_storage_bytes(spec: &crate::model::ModelSpec) -> usize {
    spec_params(spec) * 4 + 8
}

/// Asymptotic complexity strings for Table 1.
pub fn table1_row(method: Method) -> (&'static str, &'static str,
                                      &'static str, &'static str) {
    match method {
        Method::LoRA | Method::PiSSA =>
            ("(m+n)r", "O((m+n)r)", "O(mn)", "O((m+n)r)"),
        Method::DoRA => ("(m+n)r+m", "O((m+n)r)", "O(mn)", "O((m+n)r)"),
        Method::VeRA => ("(m+n)", "O(m+n)", "O(mn)", "O(m+n)"),
        Method::CoSA => ("ab", "O(ab)", "O(mn)", "O(ab)"),
        Method::Full => ("mn", "O(mn)", "O(mn)", "O(mn)"),
        Method::AdaLoRA => ("(m+n+1)r", "O((m+n)r)", "O(mn)", "O((m+n)r)"),
        Method::NoLA => ("2k", "O(k)", "O(mn)", "O(k)"),
        Method::RoSA =>
            ("(m+n)r+nnz", "O((m+n)r+nnz)", "O(mn)", "O((m+n)r+nnz)"),
    }
}

pub fn fmt_params(p: usize) -> String {
    if p >= 1_000_000_000 {
        format!("{:.2}B", p as f64 / 1e9)
    } else if p >= 1_000_000 {
        format!("{:.1}M", p as f64 / 1e6)
    } else if p >= 1_000 {
        format!("{:.1}K", p as f64 / 1e3)
    } else {
        p.to_string()
    }
}

pub fn fmt_mb(bytes: usize) -> String {
    format!("{:.0}MB", bytes as f64 / (1024.0 * 1024.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_cfg() -> CostCfg {
        CostCfg { r: 128, a: 1024, b: 256, nola_k: 1024, full_params: 0 }
    }

    /// Figure 3a's exact numbers: LoRA vs CoSA trainable params.
    #[test]
    fn fig3_param_counts_match_paper() {
        let c = paper_cfg();
        let models = Arch::paper_models();
        let lora: Vec<usize> = models.iter()
            .map(|m| total_params(Method::LoRA, m, &c)).collect();
        let cosa: Vec<usize> = models.iter()
            .map(|m| total_params(Method::CoSA, m, &c)).collect();
        // Paper: 1B → 90M/29M, 7B → 323M/51M, 8B → 336M/58M.
        assert!((lora[0] as f64 / 1e6 - 90.0).abs() < 1.0, "{}", lora[0]);
        assert!((cosa[0] as f64 / 1e6 - 29.4).abs() < 0.5, "{}", cosa[0]);
        assert!((lora[1] as f64 / 1e6 - 323.0).abs() < 2.0, "{}", lora[1]);
        assert!((cosa[1] as f64 / 1e6 - 51.4).abs() < 0.5, "{}", cosa[1]);
        assert!((lora[2] as f64 / 1e6 - 335.5).abs() < 2.0, "{}", lora[2]);
        assert!((cosa[2] as f64 / 1e6 - 58.7).abs() < 0.5, "{}", cosa[2]);
    }

    /// Paper claim: "CoSA operates with less than 32.6% of the parameters
    /// [of LoRA] across all employed models".
    #[test]
    fn cosa_under_one_third_of_lora() {
        let c = paper_cfg();
        for m in Arch::paper_models() {
            let ratio = total_params(Method::CoSA, &m, &c) as f64
                / total_params(Method::LoRA, &m, &c) as f64;
            assert!(ratio < 0.326, "{}: {ratio}", m.name);
        }
    }

    #[test]
    fn cosa_memory_independent_of_width() {
        let c = paper_cfg();
        let narrow = Arch::llama_style("narrow", 1024, 256, 4096, 4);
        let wide = Arch::llama_style("wide", 8192, 2048, 28672, 4);
        assert_eq!(
            total_params(Method::CoSA, &narrow, &c),
            total_params(Method::CoSA, &wide, &c),
            "CoSA count must not depend on (m, n)"
        );
        assert!(total_params(Method::LoRA, &wide, &c)
            > total_params(Method::LoRA, &narrow, &c));
    }

    #[test]
    fn dora_costs_more_than_lora() {
        let c = paper_cfg();
        let m = &Arch::paper_models()[0];
        assert!(total_params(Method::DoRA, m, &c)
            > total_params(Method::LoRA, m, &c));
    }

    #[test]
    fn vera_is_cheapest_vector_method() {
        let c = paper_cfg();
        let m = &Arch::paper_models()[0];
        assert!(total_params(Method::VeRA, m, &c)
            < total_params(Method::CoSA, m, &c));
    }

    #[test]
    fn storage_includes_seed_only_for_cosa() {
        let c = paper_cfg();
        let m = &Arch::paper_models()[0];
        let p = total_params(Method::CoSA, m, &c);
        assert_eq!(storage_bytes(Method::CoSA, m, &c), p * 4 + 8);
    }

    #[test]
    fn model_spec_aggregation_matches_uniform_arch_math() {
        use crate::model::{ModelSpec, SiteShape, SiteSpec};
        // A homogeneous spec must agree with the Arch-based count for
        // the same dims, and the whole model still costs ONE seed.
        let shape = SiteShape { m: 64, n: 48 };
        let sites: Vec<SiteSpec> = (0..5)
            .map(|i| SiteSpec {
                name: format!("adp.{i}.wq"),
                shape,
                a: 16,
                b: 12,
            })
            .collect();
        let spec = ModelSpec::new("uniform", sites).unwrap();
        let arch = Arch {
            name: "uniform",
            sites: vec![Site { n_in: 48, n_out: 64 }; 5],
        };
        let c = CostCfg { r: 8, a: 16, b: 12, nola_k: 8, full_params: 0 };
        assert_eq!(spec_params(&spec), total_params(Method::CoSA, &arch, &c));
        assert_eq!(spec_storage_bytes(&spec), 5 * 16 * 12 * 4 + 8,
                   "N sites amortize a single 8-byte seed");
        assert_eq!(spec_sites(&spec).len(), 5);
        assert_eq!(spec_sites(&spec)[0].n_out, 64);
    }

    #[test]
    fn model_spec_aggregation_honors_per_site_heterogeneity() {
        use crate::model::{ModelSpec, SiteShape};
        let spec =
            ModelSpec::synthetic(4, SiteShape { m: 32, n: 32 }, 8, 6);
        // sites 0/2 are 8x6 cores, sites 1/3 are 4x3 (KaSA-style)
        assert_eq!(spec_params(&spec), 2 * 48 + 2 * 12);
        assert_eq!(spec_storage_bytes(&spec), (2 * 48 + 2 * 12) * 4 + 8);
    }
}
