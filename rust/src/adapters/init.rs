//! Deterministic initialization of every artifact input tensor.
//!
//! Two seeds, two concerns:
//! * `base_seed` — the synthetic "pretrained" trunk (DESIGN.md §2: stands
//!   in for RoBERTa/Llama/Qwen checkpoints; all methods see the *same*
//!   frozen W0 for a given seed, so method comparisons are paired).
//! * `adapter_seed` — the paper's stored adapter seed; CoSA's L/R (and
//!   VeRA/NoLA's shared banks) regenerate from it via `Pcg64::derive`.
//!
//! PiSSA is initialized here per the paper: randomized SVD of each W0,
//! principal factors into A/B, residual folded back into the trunk.

use std::collections::BTreeMap;

use crate::adapters::cosa;
use crate::adapters::Method;
use crate::linalg;
use crate::math::matrix::Matrix;
use crate::math::rng::Pcg64;
use crate::math::svd::randomized_svd;

/// Method hyperparameters mirrored from the artifact meta json.
#[derive(Clone, Copy, Debug)]
pub struct MethodCfg {
    pub method: Method,
    pub r: usize,
    pub a: usize,
    pub b: usize,
    pub alpha: f32,
    pub nola_k: usize,
}

/// Initialize all trainable + frozen tensors for the given specs
/// (`(name, shape)` pairs from the artifact meta, in meta order).
pub fn init_state(
    specs: &[(String, Vec<usize>)],
    meth: &MethodCfg,
    base_seed: u64,
    adapter_seed: u64,
) -> BTreeMap<String, Vec<f32>> {
    let mut out: BTreeMap<String, Vec<f32>> = BTreeMap::new();

    // Pass 1: trunk tensors (synthetic pretrained weights).
    for (name, shape) in specs {
        if is_adapter_tensor(name) {
            continue;
        }
        out.insert(name.clone(), init_trunk(name, shape, base_seed));
    }

    // Pass 2: adapter tensors (may reference trunk W0).
    for (name, shape) in specs {
        if !is_adapter_tensor(name) {
            continue;
        }
        let vals = init_adapter(name, shape, meth, adapter_seed, &out);
        out.insert(name.clone(), vals);
    }

    // Pass 3: PiSSA — SVD-initialize A/B and fold residuals into W0.
    if meth.method == Method::PiSSA {
        pissa_init(specs, meth, adapter_seed, &mut out);
    }
    out
}

fn is_adapter_tensor(name: &str) -> bool {
    name.starts_with("adp.") || name.starts_with("vera.")
        || name.starts_with("nola.")
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product::<usize>().max(1)
}

fn init_trunk(name: &str, shape: &[usize], seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::derive(seed, name);
    let n = numel(shape);
    if name.ends_with("ln1.s") || name.ends_with("ln2.s")
        || name.ends_with("lnf.s")
    {
        return vec![1.0; n];
    }
    if name.ends_with(".b") {
        // layernorm biases and head bias
        return vec![0.0; n];
    }
    if name == "pos" {
        return rng.normal_vec(n, 0.01);
    }
    if name == "embed" {
        let d = *shape.last().unwrap() as f64;
        return rng.normal_vec(n, 1.0 / d.sqrt());
    }
    // weight matrices: N(0, 1/√fan_in)
    let fan_in = shape[0].max(1) as f64;
    rng.normal_vec(n, 1.0 / fan_in.sqrt())
}

fn init_adapter(
    name: &str,
    shape: &[usize],
    meth: &MethodCfg,
    seed: u64,
    trunk: &BTreeMap<String, Vec<f32>>,
) -> Vec<f32> {
    let n = numel(shape);
    let mut rng = Pcg64::derive(seed, name);

    // --- zero-init tensors (ΔW = 0 at step 0) ---
    if ends_with_any(name, &[".y", ".dvec", ".ca", ".cb", ".lam"])
        || (name.starts_with("adp.") && name.ends_with(".b"))
        || (name.starts_with("adp.") && name.ends_with(".bvec"))
    {
        return vec![0.0; n];
    }
    if name.ends_with(".mask") {
        return vec![1.0; n]; // AdaLoRA rank mask starts fully open
    }
    if name.ends_with(".mag") {
        // DoRA magnitude = column norms of the frozen W0 at this site.
        let w0_name = site_w0_name(name);
        let w0 = &trunk[&w0_name];
        let cols = shape[0];
        let rows = w0.len() / cols;
        let m = Matrix::from_vec(rows, cols, w0.clone());
        return m.col_norms();
    }

    // --- CoSA fixed projections (norm-preserving scales) ---
    if name.starts_with("adp.") && name.ends_with(".l") {
        let (m, a) = (shape[0], shape[1]);
        return cosa::regen_l(seed, name, m, a).data;
    }
    if name.starts_with("adp.") && name.ends_with(".r") {
        let (b, nn) = (shape[0], shape[1]);
        return cosa::regen_r(seed, name, b, nn).data;
    }

    // --- shared frozen banks (VeRA / NoLA) + LoRA-family A factors ---
    if name.starts_with("vera.") || name.starts_with("nola.")
        || name.ends_with(".a") || name.ends_with(".p")
        || name.ends_with(".q")
    {
        let fan_in = shape[0].max(1) as f64;
        let _ = meth; // scales are shape-driven
        return rng.normal_vec(n, 1.0 / fan_in.sqrt());
    }
    panic!("no initializer for adapter tensor `{name}` ({shape:?})");
}

fn ends_with_any(name: &str, suffixes: &[&str]) -> bool {
    suffixes.iter().any(|s| name.ends_with(s))
}

/// "adp.3.wq.mag" → "lyr3.wq"
fn site_w0_name(adapter_name: &str) -> String {
    let parts: Vec<&str> = adapter_name.split('.').collect();
    format!("lyr{}.{}", parts[1], parts[2])
}

/// PiSSA (Meng et al. 2024): A,B from the principal SVD factors of W0 so
/// that (α/r)·A·B equals the top-r component; residual replaces W0.
fn pissa_init(
    specs: &[(String, Vec<usize>)],
    meth: &MethodCfg,
    seed: u64,
    state: &mut BTreeMap<String, Vec<f32>>,
) {
    let scale = meth.alpha / meth.r as f32;
    for (name, shape) in specs {
        if !(name.starts_with("adp.") && name.ends_with(".a")) {
            continue;
        }
        let w0_name = site_w0_name(name);
        let b_name = name.strip_suffix(".a").unwrap().to_string() + ".b";
        let (ni, r) = (shape[0], shape[1]);
        let w0_vals = state[&w0_name].clone();
        let no = w0_vals.len() / ni;
        let w0 = Matrix::from_vec(ni, no, w0_vals);

        let mut rng = Pcg64::derive(seed, name);
        let svd = randomized_svd(&w0, r, 4, &mut rng);
        // A = U·√S / √scale, B = √S·Vᵀ / √scale  ⇒ scale·A·B = U S Vᵀ
        let mut a = Matrix::zeros(ni, r);
        let mut b = Matrix::zeros(r, no);
        let s_norm = scale.max(1e-12).sqrt();
        for k in 0..r.min(svd.s.len()) {
            let sq = svd.s[k].max(0.0).sqrt();
            for i in 0..ni {
                a.set(i, k, svd.u.at(i, k) * sq / s_norm);
            }
            for j in 0..no {
                b.set(k, j, svd.vt.at(k, j) * sq / s_norm);
            }
        }
        // residual: W0 ← W0 − scale·A·B (backend gemm; A·B is the one
        // O(n·r·n) product of the init path)
        let mut delta = linalg::gemm(&a, &b);
        delta.scale(scale);
        let resid = w0.sub(&delta);
        state.insert(w0_name, resid.data);
        state.insert(name.clone(), a.data);
        state.insert(b_name, b.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(method: Method) -> MethodCfg {
        MethodCfg { method, r: 4, a: 16, b: 8, alpha: 2.0, nola_k: 8 }
    }

    fn lora_specs() -> Vec<(String, Vec<usize>)> {
        vec![
            ("embed".into(), vec![64, 16]),
            ("lyr0.ln1.s".into(), vec![16]),
            ("lyr0.ln1.b".into(), vec![16]),
            ("lyr0.wq".into(), vec![16, 16]),
            ("adp.0.wq.a".into(), vec![16, 4]),
            ("adp.0.wq.b".into(), vec![4, 16]),
        ]
    }

    #[test]
    fn trunk_deterministic_and_method_independent() {
        let s1 = init_state(&lora_specs(), &cfg(Method::LoRA), 5, 9);
        let s2 = init_state(&lora_specs(), &cfg(Method::LoRA), 5, 10);
        assert_eq!(s1["lyr0.wq"], s2["lyr0.wq"],
                   "trunk must depend only on base_seed");
        let s3 = init_state(&lora_specs(), &cfg(Method::LoRA), 6, 9);
        assert_ne!(s1["lyr0.wq"], s3["lyr0.wq"]);
    }

    #[test]
    fn layernorm_scales_are_one() {
        let s = init_state(&lora_specs(), &cfg(Method::LoRA), 5, 9);
        assert!(s["lyr0.ln1.s"].iter().all(|v| *v == 1.0));
        assert!(s["lyr0.ln1.b"].iter().all(|v| *v == 0.0));
    }

    #[test]
    fn lora_b_zero_a_random() {
        let s = init_state(&lora_specs(), &cfg(Method::LoRA), 5, 9);
        assert!(s["adp.0.wq.b"].iter().all(|v| *v == 0.0));
        assert!(s["adp.0.wq.a"].iter().any(|v| *v != 0.0));
    }

    #[test]
    fn cosa_projections_match_regen() {
        let specs = vec![
            ("lyr0.wq".into(), vec![16, 16]),
            ("adp.0.wq.l".into(), vec![16, 16]),
            ("adp.0.wq.r".into(), vec![8, 16]),
            ("adp.0.wq.y".into(), vec![16, 8]),
        ];
        let s = init_state(&specs, &cfg(Method::CoSA), 5, 9);
        assert_eq!(s["adp.0.wq.l"], cosa::regen_l(9, "adp.0.wq.l", 16, 16).data);
        assert_eq!(s["adp.0.wq.r"], cosa::regen_r(9, "adp.0.wq.r", 8, 16).data);
        assert!(s["adp.0.wq.y"].iter().all(|v| *v == 0.0));
    }

    #[test]
    fn dora_magnitude_equals_w0_col_norms() {
        let specs = vec![
            ("lyr0.wq".into(), vec![16, 16]),
            ("adp.0.wq.a".into(), vec![16, 4]),
            ("adp.0.wq.b".into(), vec![4, 16]),
            ("adp.0.wq.mag".into(), vec![16]),
        ];
        let s = init_state(&specs, &cfg(Method::DoRA), 5, 9);
        let w0 = Matrix::from_vec(16, 16, s["lyr0.wq"].clone());
        let norms = w0.col_norms();
        for (a, b) in s["adp.0.wq.mag"].iter().zip(&norms) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn pissa_base_plus_delta_reconstructs_w0() {
        let specs = vec![
            ("lyr0.wq".into(), vec![16, 16]),
            ("adp.0.wq.a".into(), vec![16, 4]),
            ("adp.0.wq.b".into(), vec![4, 16]),
        ];
        let c = cfg(Method::PiSSA);
        let pristine = init_trunk("lyr0.wq", &[16, 16], 5);
        let s = init_state(&specs, &c, 5, 9);
        let resid = Matrix::from_vec(16, 16, s["lyr0.wq"].clone());
        let a = Matrix::from_vec(16, 4, s["adp.0.wq.a"].clone());
        let b = Matrix::from_vec(4, 16, s["adp.0.wq.b"].clone());
        let mut delta = a.matmul(&b);
        delta.scale(c.alpha / c.r as f32);
        let rec = resid.add(&delta);
        let w0 = Matrix::from_vec(16, 16, pristine);
        let err = rec.sub(&w0).frobenius() / w0.frobenius();
        assert!(err < 1e-3, "pissa reconstruction err {err}");
        // and the principal component actually lives in A·B
        assert!(delta.frobenius() > 0.1 * w0.frobenius());
    }

    #[test]
    fn adalora_mask_open_lam_zero() {
        let specs = vec![
            ("lyr0.wq".into(), vec![16, 16]),
            ("adp.0.wq.p".into(), vec![16, 4]),
            ("adp.0.wq.lam".into(), vec![4]),
            ("adp.0.wq.q".into(), vec![4, 16]),
            ("adp.0.wq.mask".into(), vec![4]),
        ];
        let s = init_state(&specs, &cfg(Method::AdaLoRA), 5, 9);
        assert!(s["adp.0.wq.mask"].iter().all(|v| *v == 1.0));
        assert!(s["adp.0.wq.lam"].iter().all(|v| *v == 0.0));
    }
}
