//! Host-side CoSA adapter math + the paper's seed-regeneration storage.
//!
//! The deployable adapter artifact is *only* the core Y plus an RNG seed
//! (§4.1: "only the compact matrix Y needs to be stored … together with a
//! random seed for regenerating L and R").  `regen_l` / `regen_r` are the
//! canonical generators — the runtime initializer (`init.rs`), the
//! checkpoint loader and the portability example all call them, so a
//! stored adapter reproduces bit-identical projections forever.

use crate::math::matrix::Matrix;
use crate::math::rng::Pcg64;

/// Scale of the output projection L (m × a): entries N(0, 1/m) make
/// E‖Lv‖² = ‖v‖² — norm-preserving reconstruction.
pub fn l_sigma(m: usize) -> f64 {
    1.0 / (m as f64).sqrt()
}

/// Scale of the input projection R (b × n): entries N(0, 1/b) make
/// E‖Rx‖² = ‖x‖² — norm-preserving compression (JL-style rows).
pub fn r_sigma(b: usize) -> f64 {
    1.0 / (b as f64).sqrt()
}

/// Regenerate the fixed L projection for tensor `name` (e.g.
/// "adp.3.wq.l") from the adapter seed.  Deterministic per (seed, name).
pub fn regen_l(seed: u64, name: &str, m: usize, a: usize) -> Matrix {
    let mut rng = Pcg64::derive(seed, name);
    Matrix::gaussian(m, a, l_sigma(m), &mut rng)
}

/// Regenerate the fixed R projection (see `regen_l`).
pub fn regen_r(seed: u64, name: &str, b: usize, n: usize) -> Matrix {
    let mut rng = Pcg64::derive(seed, name);
    Matrix::gaussian(b, n, r_sigma(b), &mut rng)
}

/// Host-side adapter forward on a batch of row activations
/// (mirror of the Pallas kernel; used by tests and the portability check):
/// `o = α · x Rᵀ Yᵀ Lᵀ` for x (N × n).
pub fn adapter_forward(x: &Matrix, l: &Matrix, r: &Matrix, y: &Matrix,
                       alpha: f32) -> Matrix {
    let u = x.matmul(&r.transpose());
    let v = u.matmul(&y.transpose());
    let mut o = v.matmul(&l.transpose());
    o.scale(alpha);
    o
}

/// Materialized ΔW = α·L Y R (tests only — O(mn), the thing CoSA avoids).
pub fn materialize_delta(l: &Matrix, y: &Matrix, r: &Matrix,
                         alpha: f32) -> Matrix {
    let mut d = l.matmul(y).matmul(r);
    d.scale(alpha);
    d
}

/// Trainable-parameter count for one adapted site — the paper's headline
/// `ab`, independent of the site's (m, n).
pub fn param_count(a: usize, b: usize) -> usize {
    a * b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn regen_is_deterministic_and_name_scoped() {
        let l1 = regen_l(7, "adp.0.wq.l", 16, 8);
        let l2 = regen_l(7, "adp.0.wq.l", 16, 8);
        assert_eq!(l1, l2);
        assert_ne!(regen_l(7, "adp.0.wv.l", 16, 8), l1);
        assert_ne!(regen_l(8, "adp.0.wq.l", 16, 8), l1);
    }

    #[test]
    fn projections_are_norm_preserving_in_expectation() {
        let mut rng = Pcg64::new(3);
        let r = regen_r(1, "adp.0.wq.r", 48, 256);
        let x = Matrix::gaussian(64, 256, 1.0, &mut rng);
        let u = x.matmul(&r.transpose());
        let ratio = u.frobenius_sq() / x.frobenius_sq();
        assert!((ratio - 1.0).abs() < 0.25, "R ratio {ratio}");

        let l = regen_l(1, "adp.0.wq.l", 256, 48);
        let v = Matrix::gaussian(64, 48, 1.0, &mut rng);
        let o = v.matmul(&l.transpose());
        let ratio = o.frobenius_sq() / v.frobenius_sq();
        assert!((ratio - 1.0).abs() < 0.25, "L ratio {ratio}");
    }

    #[test]
    fn forward_matches_materialized_delta() {
        prop::for_all("x·ΔWᵀ == adapter(x)", 10, |rng| {
            let (nn, b, a, m, rows) = (
                prop::int_in(rng, 2, 10),
                prop::int_in(rng, 1, 6),
                prop::int_in(rng, 1, 6),
                prop::int_in(rng, 2, 10),
                prop::int_in(rng, 1, 12),
            );
            let x = Matrix::gaussian(rows, nn, 1.0, rng);
            let l = Matrix::gaussian(m, a, 1.0, rng);
            let r = Matrix::gaussian(b, nn, 1.0, rng);
            let y = Matrix::gaussian(a, b, 1.0, rng);
            let fast = adapter_forward(&x, &l, &r, &y, 1.5);
            let slow = x.matmul(&materialize_delta(&l, &y, &r, 1.5).transpose());
            for (p, q) in fast.data.iter().zip(&slow.data) {
                assert!((p - q).abs() < 1e-3, "{p} vs {q}");
            }
        });
    }

    #[test]
    fn zero_core_is_identity_update() {
        let l = regen_l(0, "l", 8, 4);
        let r = regen_r(0, "r", 3, 6);
        let y = Matrix::zeros(4, 3);
        let x = Matrix::gaussian(5, 6, 1.0, &mut Pcg64::new(1));
        let o = adapter_forward(&x, &l, &r, &y, 2.0);
        assert!(o.frobenius() == 0.0);
    }

    #[test]
    fn param_count_independent_of_layer_dims() {
        assert_eq!(param_count(1024, 256), 262_144);
        // same count regardless of whether the site is 2048×2048 or
        // 8192×2048 — the paper's Table 1 property.
    }
}
