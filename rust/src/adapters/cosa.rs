//! Host-side CoSA adapter math + the paper's seed-regeneration storage.
//!
//! The deployable adapter artifact is *only* the core Y plus an RNG seed
//! (§4.1: "only the compact matrix Y needs to be stored … together with a
//! random seed for regenerating L and R").  `regen_l` / `regen_r` are the
//! canonical generators — the runtime initializer (`init.rs`), the
//! checkpoint loader and the portability example all call them, so a
//! stored adapter reproduces bit-identical projections forever.

use std::any::Any;
use std::collections::BTreeMap;
use std::sync::Arc;

use crate::adapters::traits::{Adapter, RegenSpec};
use crate::adapters::Method;
use crate::linalg::{self, QuantMat, Workspace};
use crate::math::matrix::Matrix;
use crate::math::rng::Pcg64;

/// Scale of the output projection L (m × a): entries N(0, 1/m) make
/// E‖Lv‖² = ‖v‖² — norm-preserving reconstruction.
pub fn l_sigma(m: usize) -> f64 {
    1.0 / (m as f64).sqrt()
}

/// Scale of the input projection R (b × n): entries N(0, 1/b) make
/// E‖Rx‖² = ‖x‖² — norm-preserving compression (JL-style rows).
pub fn r_sigma(b: usize) -> f64 {
    1.0 / (b as f64).sqrt()
}

/// Regenerate the fixed L projection for tensor `name` (e.g.
/// "adp.3.wq.l") from the adapter seed.  Deterministic per (seed, name).
pub fn regen_l(seed: u64, name: &str, m: usize, a: usize) -> Matrix {
    let mut rng = Pcg64::derive(seed, name);
    Matrix::gaussian(m, a, l_sigma(m), &mut rng)
}

/// Regenerate the fixed R projection (see `regen_l`).
pub fn regen_r(seed: u64, name: &str, b: usize, n: usize) -> Matrix {
    let mut rng = Pcg64::derive(seed, name);
    Matrix::gaussian(b, n, r_sigma(b), &mut rng)
}

/// Host-side adapter forward on a batch of row activations
/// (mirror of the Pallas kernel; used by tests and the portability check):
/// `o = α · x Rᵀ Yᵀ Lᵀ` for x (N × n).  The three products use the
/// `linalg` transpose-free NT kernels — no `Rᵀ/Yᵀ/Lᵀ` copies are formed.
pub fn adapter_forward(x: &Matrix, l: &Matrix, r: &Matrix, y: &Matrix,
                       alpha: f32) -> Matrix {
    let u = linalg::gemm_nt(x, r); // x Rᵀ         (N × b)
    let v = linalg::gemm_nt(&u, y); // (x Rᵀ) Yᵀ   (N × a)
    let mut o = linalg::gemm_nt(&v, l); // … Lᵀ    (N × m)
    o.scale(alpha);
    o
}

/// Allocation-free forward: intermediates come from `ws`, the result is
/// written into `out` (N × m).  After the first call with a given shape
/// set, no allocations occur (see `Workspace` docs).
pub fn adapter_forward_into(x: &Matrix, l: &Matrix, r: &Matrix, y: &Matrix,
                            alpha: f32, ws: &mut Workspace,
                            out: &mut Matrix) {
    let mut u = ws.take_matrix(x.rows, r.rows);
    linalg::gemm_nt_into(x, r, &mut u);
    let mut v = ws.take_matrix(x.rows, y.rows);
    linalg::gemm_nt_into(&u, y, &mut v);
    linalg::gemm_nt_into(&v, l, out);
    out.scale(alpha);
    ws.recycle_matrix(v);
    ws.recycle_matrix(u);
}

/// [`adapter_forward_into`] with cache-resident projections in
/// whatever storage kind the model layer installed them under
/// ([`QuantMat`]).  F32 payloads take the unquantized path unchanged —
/// the default `cache_quant = "f32"` policy is bit-identical to the
/// pre-quant engine by construction.  Encoded payloads run the
/// pack-fused quantized NT products ([`linalg::gemm_nt_quant_into`]),
/// so no full-size f32 dequant buffer ever materializes.
pub fn adapter_forward_quant_into(x: &Matrix, l: &QuantMat, r: &QuantMat,
                                  y: &Matrix, alpha: f32,
                                  ws: &mut Workspace, out: &mut Matrix) {
    if let (Some(lf), Some(rf)) = (l.as_f32(), r.as_f32()) {
        adapter_forward_into(x, lf, rf, y, alpha, ws, out);
        return;
    }
    let mut u = ws.take_matrix(x.rows, r.rows());
    linalg::gemm_nt_quant_into(x, r, &mut u);
    let mut v = ws.take_matrix(x.rows, y.rows);
    linalg::gemm_nt_into(&u, y, &mut v);
    linalg::gemm_nt_quant_into(&v, l, out);
    out.scale(alpha);
    ws.recycle_matrix(v);
    ws.recycle_matrix(u);
}

/// Grouped multi-adapter forward: consecutive row segments of `x`
/// (`segs[g]` rows each) run against their own `(ls[g], rs[g], ys[g],
/// alphas[g])` operand set in three grouped block-diagonal NT sweeps
/// ([`linalg::gemm_grouped_nt_into`]) — one thread fan-out per product
/// for the whole group instead of one per adapter.  All segments must
/// share the site shape (m × n) and core dims (a × b): the serving
/// invariant, one model spec × many adapters.  Bit-identical to
/// calling [`adapter_forward_into`] once per segment (the grouped
/// kernel computes each output row from only its own activation row).
#[allow(clippy::too_many_arguments)]
pub fn adapter_forward_grouped_into(
    x: &Matrix,
    ls: &[&Matrix],
    rs: &[&Matrix],
    ys: &[&Matrix],
    alphas: &[f32],
    segs: &[usize],
    ws: &mut Workspace,
    out: &mut Matrix,
) {
    assert!(
        ls.len() == segs.len()
            && rs.len() == segs.len()
            && ys.len() == segs.len()
            && alphas.len() == segs.len(),
        "adapter_forward_grouped_into: operand/segment count mismatch"
    );
    let b = rs.first().map_or(0, |r| r.rows);
    let a = ys.first().map_or(0, |y| y.rows);
    let mut u = ws.take_matrix(x.rows, b);
    linalg::gemm_grouped_nt_into(x, rs, segs, &mut u);
    let mut v = ws.take_matrix(x.rows, a);
    linalg::gemm_grouped_nt_into(&u, ys, segs, &mut v);
    linalg::gemm_grouped_nt_into(&v, ls, segs, out);
    // per-segment α, applied exactly like `Matrix::scale` does in the
    // per-adapter path (unconditional multiply ⇒ identical bits)
    let m = out.cols;
    let mut row = 0usize;
    for (g, &rows) in segs.iter().enumerate() {
        for o in out.data[row * m..(row + rows) * m].iter_mut() {
            *o *= alphas[g];
        }
        row += rows;
    }
    ws.recycle_matrix(v);
    ws.recycle_matrix(u);
}

/// [`adapter_forward_grouped_into`] with quantized cache-resident
/// projections.  All-F32 groups take the existing fused f32 sweep bit
/// for bit; otherwise the two projection products run the grouped
/// quantized sweeps ([`linalg::gemm_grouped_nt_quant_into`]) — still
/// bit-identical to calling [`adapter_forward_quant_into`] once per
/// segment, because each grouped sweep is bit-identical to its
/// per-segment composition and the α ordering is unchanged.
#[allow(clippy::too_many_arguments)]
pub fn adapter_forward_grouped_quant_into(
    x: &Matrix,
    ls: &[&QuantMat],
    rs: &[&QuantMat],
    ys: &[&Matrix],
    alphas: &[f32],
    segs: &[usize],
    ws: &mut Workspace,
    out: &mut Matrix,
) {
    assert!(
        ls.len() == segs.len()
            && rs.len() == segs.len()
            && ys.len() == segs.len()
            && alphas.len() == segs.len(),
        "adapter_forward_grouped_quant_into: operand/segment count \
         mismatch"
    );
    if ls.iter().chain(rs.iter()).all(|q| q.as_f32().is_some()) {
        let lf: Vec<&Matrix> = ls
            .iter()
            .map(|q| q.as_f32().expect("checked f32").as_ref())
            .collect();
        let rf: Vec<&Matrix> = rs
            .iter()
            .map(|q| q.as_f32().expect("checked f32").as_ref())
            .collect();
        adapter_forward_grouped_into(x, &lf, &rf, ys, alphas, segs, ws,
                                     out);
        return;
    }
    let b = rs.first().map_or(0, |r| r.rows());
    let a = ys.first().map_or(0, |y| y.rows);
    let mut u = ws.take_matrix(x.rows, b);
    linalg::gemm_grouped_nt_quant_into(x, rs, segs, &mut u);
    let mut v = ws.take_matrix(x.rows, a);
    linalg::gemm_grouped_nt_into(&u, ys, segs, &mut v);
    linalg::gemm_grouped_nt_quant_into(&v, ls, segs, out);
    // per-segment α, applied exactly like the per-adapter path does
    let m = out.cols;
    let mut row = 0usize;
    for (g, &rows) in segs.iter().enumerate() {
        for o in out.data[row * m..(row + rows) * m].iter_mut() {
            *o *= alphas[g];
        }
        row += rows;
    }
    ws.recycle_matrix(v);
    ws.recycle_matrix(u);
}

/// Analytic VJP of the adapter forward (host mirror of the Pallas
/// kernel's Eq. 10 backward): given upstream gradients `g = ∂L/∂o`
/// (N × m), returns
///
/// * `dY = α · (g L)ᵀ (x Rᵀ)`  — (a × b), the only trainable gradient;
/// * `dX = α · g L Y R`        — (N × n), the activation gradient.
pub fn adapter_vjp(x: &Matrix, l: &Matrix, r: &Matrix, y: &Matrix,
                   g: &Matrix, alpha: f32) -> (Matrix, Matrix) {
    let u = linalg::gemm_nt(x, r); // x Rᵀ   (N × b)
    let t = linalg::gemm(g, l); //    g L    (N × a)
    let mut dy = linalg::gemm_tn(&t, &u); // (a × b)
    dy.scale(alpha);
    let ty = linalg::gemm(&t, y); //  g L Y  (N × b)
    let mut dx = linalg::gemm(&ty, r); //    (N × n)
    dx.scale(alpha);
    (dy, dx)
}

/// Allocation-free core gradient: writes `dY = α·(g L)ᵀ(x Rᵀ)` into
/// `dy_out` using workspace intermediates only.
pub fn adapter_vjp_y_into(x: &Matrix, l: &Matrix, r: &Matrix, g: &Matrix,
                          alpha: f32, ws: &mut Workspace,
                          dy_out: &mut Matrix) {
    let mut u = ws.take_matrix(x.rows, r.rows);
    linalg::gemm_nt_into(x, r, &mut u);
    let mut t = ws.take_matrix(g.rows, l.cols);
    linalg::gemm_into(g, l, &mut t);
    linalg::gemm_tn_into(&t, &u, dy_out);
    dy_out.scale(alpha);
    ws.recycle_matrix(t);
    ws.recycle_matrix(u);
}

/// Materialized ΔW = α·L Y R (tests only — O(mn), the thing CoSA avoids).
/// The association is chosen by FLOP count: `(L·Y)·R` when `a > b` at
/// large n (the paper's NLG shape — the old grouping, ~3× cheaper
/// there), else `L·(Y·R)` where the sparse core Y is the left operand
/// and the dedicated sparse-left kernel from `linalg::sparse` applies —
/// threaded over its precomputed nonzero-row index above the FLOP
/// threshold, so large materializations scale across cores.
pub fn materialize_delta(l: &Matrix, y: &Matrix, r: &Matrix,
                         alpha: f32) -> Matrix {
    let (m, a, b, n) = (l.rows, y.rows, y.cols, r.cols);
    let cost_ly_first = m * a * b + m * b * n;
    let cost_yr_first = a * b * n + m * a * n;
    let mut d = if cost_yr_first <= cost_ly_first {
        let yr = linalg::sparse::gemm_sparse_left(y, r);
        linalg::gemm(l, &yr)
    } else {
        linalg::gemm(&linalg::gemm(l, y), r)
    };
    d.scale(alpha);
    d
}

/// Trainable-parameter count for one adapted site — the paper's headline
/// `ab`, independent of the site's (m, n).
pub fn param_count(a: usize, b: usize) -> usize {
    a * b
}

/// The [`Adapter`] impl over this module's free-function math: one
/// adapted `m × n` site storing only the core `Y` (a × b) plus the
/// seed/tensor-name description that regenerates `L` and `R`.  Every
/// trait entry point delegates to the free functions above, so serving
/// through the trait is bit-identical to the pre-trait engine.
pub struct CosaAdapter {
    seed: u64,
    l_name: String,
    r_name: String,
    m: usize,
    n: usize,
    y: Arc<Matrix>,
}

impl CosaAdapter {
    /// `y` is the trained core (a × b); `l_name` / `r_name` are the
    /// projection tensor names the seed regenerates under (canonical:
    /// `<site>.l` / `<site>.r`).
    pub fn new(
        seed: u64,
        l_name: String,
        r_name: String,
        m: usize,
        n: usize,
        y: Arc<Matrix>,
    ) -> CosaAdapter {
        CosaAdapter { seed, l_name, r_name, m, n, y }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn l_name(&self) -> &str {
        &self.l_name
    }

    pub fn r_name(&self) -> &str {
        &self.r_name
    }

    /// The trained core Y (a × b).
    pub fn core(&self) -> &Matrix {
        &self.y
    }

    pub fn core_arc(&self) -> Arc<Matrix> {
        self.y.clone()
    }
}

impl Adapter for CosaAdapter {
    fn method(&self) -> Method {
        Method::CoSA
    }

    fn out_dim(&self) -> usize {
        self.m
    }

    fn in_dim(&self) -> usize {
        self.n
    }

    fn core_dims(&self) -> (usize, usize) {
        (self.y.rows, self.y.cols)
    }

    fn param_count(&self) -> usize {
        param_count(self.y.rows, self.y.cols)
    }

    fn resident_bytes(&self) -> usize {
        // the §4.1 artifact: the core plus 8 bytes of seed
        self.y.data.len() * 4 + 8
    }

    fn regen_bytes(&self) -> usize {
        self.regen_specs().iter().map(RegenSpec::bytes).sum()
    }

    /// `[L, R]` — in exactly the order the model layer has always
    /// resolved the shared projection cache (peek L then R per site),
    /// so the trait refactor preserves the cache-key sequence.
    fn regen_specs(&self) -> Vec<RegenSpec> {
        vec![
            RegenSpec {
                seed: self.seed,
                name: self.l_name.clone(),
                rows: self.m,
                cols: self.y.rows,
                regen: regen_l,
            },
            RegenSpec {
                seed: self.seed,
                name: self.r_name.clone(),
                rows: self.y.cols,
                cols: self.n,
                regen: regen_r,
            },
        ]
    }

    fn forward_into(
        &self,
        x: &Matrix,
        regen: &[Arc<QuantMat>],
        alpha: f32,
        ws: &mut Workspace,
        out: &mut Matrix,
    ) {
        adapter_forward_quant_into(x, &regen[0], &regen[1], &self.y,
                                   alpha, ws, out);
    }

    fn vjp(
        &self,
        x: &Matrix,
        regen: &[Arc<QuantMat>],
        g: &Matrix,
        alpha: f32,
    ) -> (Vec<Matrix>, Matrix) {
        // training-only path: dequantize once (serving never comes
        // through here, and f32 payloads borrow without a copy)
        let l_owned;
        let l: &Matrix = match regen[0].as_f32() {
            Some(m) => m,
            None => {
                l_owned = regen[0].to_matrix();
                &l_owned
            }
        };
        let r_owned;
        let r: &Matrix = match regen[1].as_f32() {
            Some(m) => m,
            None => {
                r_owned = regen[1].to_matrix();
                &r_owned
            }
        };
        let (dy, dx) = adapter_vjp(x, l, r, &self.y, g, alpha);
        (vec![dy], dx)
    }

    fn encode_tensors(
        &self,
        site: &str,
        out: &mut BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    ) {
        out.insert(
            format!("{site}.y"),
            (vec![self.y.rows, self.y.cols], self.y.data.clone()),
        );
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn regen_is_deterministic_and_name_scoped() {
        let l1 = regen_l(7, "adp.0.wq.l", 16, 8);
        let l2 = regen_l(7, "adp.0.wq.l", 16, 8);
        assert_eq!(l1, l2);
        assert_ne!(regen_l(7, "adp.0.wv.l", 16, 8), l1);
        assert_ne!(regen_l(8, "adp.0.wq.l", 16, 8), l1);
    }

    #[test]
    fn projections_are_norm_preserving_in_expectation() {
        let mut rng = Pcg64::new(3);
        let r = regen_r(1, "adp.0.wq.r", 48, 256);
        let x = Matrix::gaussian(64, 256, 1.0, &mut rng);
        let u = x.matmul(&r.transpose());
        let ratio = u.frobenius_sq() / x.frobenius_sq();
        assert!((ratio - 1.0).abs() < 0.25, "R ratio {ratio}");

        let l = regen_l(1, "adp.0.wq.l", 256, 48);
        let v = Matrix::gaussian(64, 48, 1.0, &mut rng);
        let o = v.matmul(&l.transpose());
        let ratio = o.frobenius_sq() / v.frobenius_sq();
        assert!((ratio - 1.0).abs() < 0.25, "L ratio {ratio}");
    }

    #[test]
    fn forward_matches_materialized_delta() {
        prop::for_all("x·ΔWᵀ == adapter(x)", 10, |rng| {
            let (nn, b, a, m, rows) = (
                prop::int_in(rng, 2, 10),
                prop::int_in(rng, 1, 6),
                prop::int_in(rng, 1, 6),
                prop::int_in(rng, 2, 10),
                prop::int_in(rng, 1, 12),
            );
            let x = Matrix::gaussian(rows, nn, 1.0, rng);
            let l = Matrix::gaussian(m, a, 1.0, rng);
            let r = Matrix::gaussian(b, nn, 1.0, rng);
            let y = Matrix::gaussian(a, b, 1.0, rng);
            let fast = adapter_forward(&x, &l, &r, &y, 1.5);
            let slow = x.matmul(&materialize_delta(&l, &y, &r, 1.5).transpose());
            for (p, q) in fast.data.iter().zip(&slow.data) {
                assert!((p - q).abs() < 1e-3, "{p} vs {q}");
            }
        });
    }

    #[test]
    fn forward_into_matches_allocating_forward() {
        let mut rng = Pcg64::new(6);
        let (m, nn, a, b, rows) = (10, 12, 4, 3, 8);
        let x = Matrix::gaussian(rows, nn, 1.0, &mut rng);
        let l = Matrix::gaussian(m, a, 1.0, &mut rng);
        let r = Matrix::gaussian(b, nn, 1.0, &mut rng);
        let y = Matrix::gaussian(a, b, 1.0, &mut rng);
        let want = adapter_forward(&x, &l, &r, &y, 1.5);

        let mut ws = crate::linalg::Workspace::new();
        let mut out = Matrix::zeros(rows, m);
        adapter_forward_into(&x, &l, &r, &y, 1.5, &mut ws, &mut out);
        for (p, q) in out.data.iter().zip(&want.data) {
            assert!((p - q).abs() < 1e-5, "{p} vs {q}");
        }

        // steady state: repeated calls never allocate again
        let warm = ws.fresh_allocs();
        for _ in 0..5 {
            adapter_forward_into(&x, &l, &r, &y, 1.5, &mut ws, &mut out);
        }
        assert_eq!(ws.fresh_allocs(), warm);
    }

    #[test]
    fn grouped_forward_is_bit_identical_to_per_adapter_forwards() {
        let mut rng = Pcg64::new(12);
        let (m, nn, a, b) = (10usize, 12usize, 4usize, 3usize);
        let segs = [3usize, 1, 0, 5, 2];
        let alphas = [2.0f32, 0.5, 1.0, 1.5, 3.0];
        let total: usize = segs.iter().sum();
        let x = Matrix::gaussian(total, nn, 1.0, &mut rng);
        let ls: Vec<Matrix> = segs
            .iter()
            .map(|_| Matrix::gaussian(m, a, 1.0, &mut rng))
            .collect();
        let rs: Vec<Matrix> = segs
            .iter()
            .map(|_| Matrix::gaussian(b, nn, 1.0, &mut rng))
            .collect();
        let ys: Vec<Matrix> = segs
            .iter()
            .map(|_| Matrix::gaussian(a, b, 1.0, &mut rng))
            .collect();
        let (lr, rr, yr): (Vec<&Matrix>, Vec<&Matrix>, Vec<&Matrix>) = (
            ls.iter().collect(),
            rs.iter().collect(),
            ys.iter().collect(),
        );
        let mut ws = crate::linalg::Workspace::new();
        let mut fused = Matrix::zeros(total, m);
        adapter_forward_grouped_into(&x, &lr, &rr, &yr, &alphas, &segs,
                                     &mut ws, &mut fused);
        let mut row = 0usize;
        for (g, &rows) in segs.iter().enumerate() {
            if rows == 0 {
                continue;
            }
            let xs = Matrix::from_vec(
                rows, nn, x.data[row * nn..(row + rows) * nn].to_vec());
            let mut o = Matrix::zeros(rows, m);
            adapter_forward_into(&xs, &ls[g], &rs[g], &ys[g], alphas[g],
                                 &mut ws, &mut o);
            for (i, (p, q)) in fused.data[row * m..(row + rows) * m]
                .iter()
                .zip(&o.data)
                .enumerate()
            {
                assert_eq!(p.to_bits(), q.to_bits(),
                           "seg {g} elem {i}: {p} vs {q}");
            }
            row += rows;
        }
    }

    #[test]
    fn vjp_matches_finite_differences() {
        // The forward is linear in Y, so central differences on the
        // scalar loss Σ o⊙g recover dY exactly up to f32 rounding.
        let mut rng = Pcg64::new(7);
        let (m, nn, a, b, rows) = (6, 8, 3, 4, 5);
        let x = Matrix::gaussian(rows, nn, 1.0, &mut rng);
        let l = Matrix::gaussian(m, a, 0.5, &mut rng);
        let r = Matrix::gaussian(b, nn, 0.5, &mut rng);
        let y = Matrix::gaussian(a, b, 0.5, &mut rng);
        let g = Matrix::gaussian(rows, m, 0.5, &mut rng);
        let alpha = 1.3f32;
        let loss = |yy: &Matrix| -> f64 {
            let o = adapter_forward(&x, &l, &r, yy, alpha);
            o.data.iter().zip(&g.data)
                .map(|(ov, gv)| *ov as f64 * *gv as f64).sum()
        };
        let (dy, dx) = adapter_vjp(&x, &l, &r, &y, &g, alpha);
        let eps = 1e-2f32;
        for idx in [0usize, 3, 7, a * b - 1] {
            let mut yp = y.clone();
            yp.data[idx] += eps;
            let mut ym = y.clone();
            ym.data[idx] -= eps;
            let fd = (loss(&yp) - loss(&ym)) / (2.0 * eps as f64);
            assert!(
                (fd - dy.data[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "dY[{idx}]: fd {fd} vs analytic {}", dy.data[idx]
            );
        }
        // dX via the materialized ΔW: dX = g · ΔW with ΔW = α·L Y R.
        let delta = materialize_delta(&l, &y, &r, alpha);
        let dx_ref = g.matmul(&delta);
        for (p, q) in dx.data.iter().zip(&dx_ref.data) {
            assert!((p - q).abs() < 1e-3, "{p} vs {q}");
        }

        // workspace variant agrees with the allocating one
        let mut ws = crate::linalg::Workspace::new();
        let mut dy2 = Matrix::zeros(a, b);
        adapter_vjp_y_into(&x, &l, &r, &g, alpha, &mut ws, &mut dy2);
        for (p, q) in dy2.data.iter().zip(&dy.data) {
            assert!((p - q).abs() < 1e-4, "{p} vs {q}");
        }
    }

    #[test]
    fn zero_core_is_identity_update() {
        let l = regen_l(0, "l", 8, 4);
        let r = regen_r(0, "r", 3, 6);
        let y = Matrix::zeros(4, 3);
        let x = Matrix::gaussian(5, 6, 1.0, &mut Pcg64::new(1));
        let o = adapter_forward(&x, &l, &r, &y, 2.0);
        assert!(o.frobenius() == 0.0);
    }

    #[test]
    fn param_count_independent_of_layer_dims() {
        assert_eq!(param_count(1024, 256), 262_144);
        // same count regardless of whether the site is 2048×2048 or
        // 8192×2048 — the paper's Table 1 property.
    }

    #[test]
    fn trait_impl_is_bit_identical_to_free_functions() {
        // The acceptance anchor at the adapter level: CosaAdapter's
        // trait entry points must reproduce the free-function math bit
        // for bit, and its regen specs must rebuild the exact cache
        // keys (seed, tensor name, dims) the pre-trait model used.
        let mut rng = Pcg64::new(21);
        let (m, nn, a, b, rows) = (12usize, 10usize, 4usize, 3usize, 5);
        let y = Matrix::gaussian(a, b, 0.5, &mut rng);
        let ad = CosaAdapter::new(
            7,
            "adp.0.wq.l".into(),
            "adp.0.wq.r".into(),
            m,
            nn,
            Arc::new(y.clone()),
        );
        let specs = ad.regen_specs();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].key(), (7, "adp.0.wq.l".to_string(), m, a));
        assert_eq!(specs[1].key(), (7, "adp.0.wq.r".to_string(), b, nn));
        let l = specs[0].materialize();
        let r = specs[1].materialize();
        assert_eq!(l, regen_l(7, "adp.0.wq.l", m, a));
        assert_eq!(r, regen_r(7, "adp.0.wq.r", b, nn));

        let x = Matrix::gaussian(rows, nn, 1.0, &mut rng);
        let want = adapter_forward(&x, &l, &r, &y, 2.0);
        let regen = vec![
            Arc::new(QuantMat::encode_owned(l.clone(),
                                            crate::linalg::QuantKind::F32)),
            Arc::new(QuantMat::encode_owned(r.clone(),
                                            crate::linalg::QuantKind::F32)),
        ];
        let got = ad.forward(&x, &regen, 2.0);
        for (p, q) in want.data.iter().zip(&got.data) {
            assert_eq!(p.to_bits(), q.to_bits(), "trait forward drifted");
        }

        let g = Matrix::gaussian(rows, m, 0.5, &mut rng);
        let (want_dy, want_dx) = adapter_vjp(&x, &l, &r, &y, &g, 2.0);
        let (grads, dx) = ad.vjp(&x, &regen, &g, 2.0);
        assert_eq!(grads.len(), 1);
        assert_eq!(grads[0], want_dy);
        assert_eq!(dx, want_dx);

        assert_eq!(ad.param_count(), a * b);
        assert_eq!(ad.resident_bytes(), a * b * 4 + 8);
        assert_eq!(ad.regen_bytes(), (m * a + b * nn) * 4);
        assert_eq!(ad.core_dims(), (a, b));
    }

    #[test]
    fn quant_forward_is_bit_identical_to_quant_gemm_composition() {
        // Forward-level acceptance for the quantized route: the trait
        // forward with encoded regens must equal the hand-composed
        // quantized GEMM sequence (regen → quantize → pack-fused
        // product) bit for bit — same entries, same α ordering.  The
        // GEMM-level test in linalg pins each quantized product against
        // its dequantize-reference composition, so transitively the
        // forward matches the regen-then-quantize-then-dequantize
        // reference too.
        use crate::linalg::QuantKind;
        let mut rng = Pcg64::new(33);
        let (m, nn, a, b, rows) = (18usize, 22usize, 5usize, 4usize, 6);
        let y = Matrix::gaussian(a, b, 0.5, &mut rng);
        let ad = CosaAdapter::new(
            9,
            "q.0.wq.l".into(),
            "q.0.wq.r".into(),
            m,
            nn,
            Arc::new(y.clone()),
        );
        let specs = ad.regen_specs();
        let l = specs[0].materialize();
        let r = specs[1].materialize();
        let x = Matrix::gaussian(rows, nn, 1.0, &mut rng);
        let f32_out = adapter_forward(&x, &l, &r, &y, 1.5);
        for (kind, tol) in
            [(QuantKind::Bf16, 0.05f64), (QuantKind::Int8, 0.15f64)]
        {
            let ql = Arc::new(QuantMat::encode(&l, kind));
            let qr = Arc::new(QuantMat::encode(&r, kind));
            let got =
                ad.forward(&x, &[ql.clone(), qr.clone()], 1.5);
            // hand-composed reference over the same quant GEMM entries
            let mut u = Matrix::zeros(rows, b);
            linalg::gemm_nt_quant_into(&x, &qr, &mut u);
            let mut v = Matrix::zeros(rows, a);
            linalg::gemm_nt_into(&u, &y, &mut v);
            let mut want = Matrix::zeros(rows, m);
            linalg::gemm_nt_quant_into(&v, &ql, &mut want);
            want.scale(1.5);
            for (i, (p, q)) in
                want.data.iter().zip(&got.data).enumerate()
            {
                assert_eq!(p.to_bits(), q.to_bits(),
                           "{} elem {i}: {p} vs {q}", kind.name());
            }
            // accuracy vs the unquantized forward stays inside the
            // codec budget (the scenario-7 gate property, in-unit)
            let num = (got.sub(&f32_out)).frobenius() as f64;
            let den = (f32_out.frobenius() as f64).max(1e-12);
            assert!(num / den < tol, "{}: rel err {}", kind.name(),
                    num / den);
        }
    }
}
