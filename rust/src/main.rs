//! `cosa-repro` — launcher CLI for the CoSA reproduction framework.
//!
//! Subcommands:
//!   train       --config <toml> [--steps N]       run one fine-tuning job
//!   eval        --ckpt <path> --task <id>         score a stored adapter
//!   exp         <table1|table2|...|fig2|fig3|...> regenerate a paper table
//!   rip         [--samples N] [--trials K]        RIP validation (Table 4)
//!   params      [--rank R --a A --b B]            cost model (Fig 3)
//!   serve       [--port P --preload-dir D ...]    HTTP/JSON serving gateway
//!   serve-bench [--adapters N --requests N ...]   multi-adapter serving bench
//!   list                                          available artifacts
//!
//! Examples:
//!   cosa-repro exp table4
//!   cosa-repro train --config configs/quickstart.toml
//!   cosa-repro exp table2 --steps 60 --seeds 2
//!   cosa-repro serve --port 7080 --preload-dir runs/adapters
//!   cosa-repro serve-bench --adapters 64 --zipf 1.1 --requests 2048

use cosa::config::RunConfig;
use cosa::runtime::executor::Runtime;
use cosa::runtime::Registry;
use cosa::train::Trainer;
use cosa::util::args::Args;
use cosa::{exp, info};

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> anyhow::Result<()> {
    match args.subcommand.as_str() {
        "train" => cmd_train(args),
        "eval" => cmd_eval(args),
        "exp" => {
            let id = args
                .positional
                .first()
                .ok_or_else(|| anyhow::anyhow!(
                    "usage: cosa-repro exp <id>; ids: {:?}", exp::ALL))?;
            exp::run(id, args)
        }
        "rip" => exp::run("table4", args),
        "params" => exp::run("fig3", args),
        "serve" => cmd_serve(args),
        "serve-bench" => cmd_serve_bench(args),
        "list" => cmd_list(),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand `{other}`\n{HELP}"),
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let mut cfg = match args.opt("config") {
        Some(path) => RunConfig::load(path)?,
        None => RunConfig::default(),
    };
    if let Some(a) = args.opt("artifact") {
        cfg.artifact = a.to_string();
    }
    if let Some(t) = args.opt("task") {
        cfg.task = t.to_string();
    }
    if let Some(s) = args.opt("steps") {
        cfg.train.steps = s.parse()?;
    }
    if let Some(lr) = args.opt("lr") {
        cfg.train.lr = lr.parse()?;
    }
    if let Some(b) = args.opt("backend") {
        cosa::linalg::Kind::parse(b)?; // validate before the run starts
        cfg.compute.backend = b.to_string();
    }
    if let Some(t) = args.opt("threads") {
        cfg.compute.threads = t.parse()?;
    }
    let rt = Runtime::cpu()?;
    let reg = Registry::open_default()?;
    let mut trainer = Trainer::new(&rt, &reg, cfg)?;
    trainer.run()?;
    let (eloss, metric) = trainer.evaluate()?;
    trainer.log.save_csv(&trainer.csv_path())?;
    trainer.save_checkpoint(&trainer.ckpt_path())?;
    info!("final eval: loss {eloss:.4} metric {metric:.4}");
    info!("loss curve: {}", trainer.csv_path().display());
    info!("adapter checkpoint: {}", trainer.ckpt_path().display());
    Ok(())
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    use cosa::train::checkpoint::Checkpoint;
    let path = args
        .opt("ckpt")
        .ok_or_else(|| anyhow::anyhow!("--ckpt <path> required"))?;
    let ck = Checkpoint::load(std::path::Path::new(path))?;
    let cfg = RunConfig {
        artifact: ck.artifact.clone(),
        task: args.str("task", "math"),
        adapter_seed: ck.adapter_seed,
        train: cosa::config::TrainConfig { steps: 0,
            ..cosa::config::TrainConfig::default() },
        ..RunConfig::default()
    };
    let rt = Runtime::cpu()?;
    let reg = Registry::open_default()?;
    let mut trainer = Trainer::new(&rt, &reg, cfg)?;
    trainer.load_checkpoint(&ck)?;
    let (eloss, metric) = trainer.evaluate()?;
    println!("checkpoint {path}: eval loss {eloss:.4}  metric {metric:.4}");
    Ok(())
}

/// `serve`: run the HTTP/1.1 + JSON gateway over the multi-adapter
/// serving engine in the foreground.  The served `ModelSpec` comes
/// from the `[model]` table, engine knobs from `[serve]`, transport
/// knobs from `[wire]`, telemetry knobs from `[obs]` — each
/// env-overridable (`COSA_MODEL_*`, `COSA_SERVE_*`, `COSA_WIRE_*`,
/// `COSA_OBS_*`) with CLI flags taking highest precedence.  `[serve]
/// preload_dir` warm-loads every checkpoint in the directory before
/// the listener opens.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use cosa::model::AdaptedModel;
    use cosa::wire::Gateway;

    let cfg = match args.opt("config") {
        Some(path) => RunConfig::load(path)?,
        None => RunConfig::default(),
    };
    let mut serve = cfg.serve.env_overridden();
    if let Some(v) = args.opt("batch") {
        serve.max_batch = v.parse()?;
        anyhow::ensure!(serve.max_batch >= 1, "--batch must be >= 1");
    }
    if let Some(v) = args.opt("wait-us") {
        serve.max_wait_us = v.parse()?;
    }
    if let Some(v) = args.opt("workers") {
        serve.workers = v.parse()?;
    }
    if let Some(v) = args.opt("cache-mb") {
        serve.cache_mb = v.parse()?;
        anyhow::ensure!(serve.cache_mb >= 0.0, "--cache-mb must be >= 0");
    }
    if let Some(v) = args.opt("preload-dir") {
        serve.preload_dir = v.to_string();
    }
    let mut wire = cfg.wire.env_overridden();
    if let Some(v) = args.opt("host") {
        wire.host = v.to_string();
    }
    if let Some(v) = args.opt("port") {
        wire.port = v.parse()?;
    }
    if let Some(v) = args.opt("http-workers") {
        wire.http_workers = v.parse()?;
    }
    let mut obs = cfg.obs.env_overridden();
    if args.bool("no-obs") {
        obs.enabled = false;
    }
    if let Some(v) = args.opt("obs-slow-ms") {
        obs.slow_ms = v.parse()?;
        anyhow::ensure!(obs.slow_ms >= 1, "--obs-slow-ms must be >= 1");
    }
    let model_cfg = cfg.model.env_overridden();
    let spec = model_cfg.to_spec(&cfg.name)?;
    let model = AdaptedModel::new(spec, serve.cache_budget_bytes())?;
    let gateway = Gateway::start_obs(model, &serve, &wire, &obs)?;
    info!(
        "gateway up on http://{} — POST /v1/forward, \
         POST /v1/adapters/{{name}}/load, DELETE /v1/adapters/{{name}}, \
         GET /v1/stats, GET /v1/adapters, GET /metrics, \
         GET /v1/debug/slow, GET /healthz (Ctrl-C to stop)",
        gateway.addr()
    );
    if obs.enabled {
        info!(
            "obs: tracing on — slow watermark {} ms, slow ring {}, \
             {} recent exemplars",
            obs.slow_ms, obs.slow_ring, obs.exemplars
        );
    } else {
        info!("obs: tracing off (--no-obs / [obs] enabled = false)");
    }
    // Foreground server: park until killed (no signal handling in a
    // zero-dependency std build; the OS reclaims the sockets).
    loop {
        std::thread::park();
    }
}

/// `serve-bench`: drive the multi-adapter serving engine under
/// synthetic Zipf workloads and write the `serving` (single-site),
/// `serving_model` (whole adapted model), and opt-in `serving_wire` /
/// `serving_tail` (fused vs per-adapter batching) / `serving_methods`
/// (cross-method adapter-zoo table) / `serving_quant` (f32 vs bf16 vs
/// int8 cache codecs at one thrashing LRU budget) / `serving_obs`
/// (traced vs untraced throughput on one identical stream) sections of
/// the canonical `BENCH_linalg.json`.  Knob precedence, highest
/// first: CLI flags,
/// `COSA_SERVE_*` / `COSA_MODEL_*` env, `[serve]` / `[model]` config
/// tables.  The preset worker hint (`ServeConfig::resolved`) is
/// deliberately NOT applied: it describes serving a *model preset's*
/// site, while this bench runs its own synthetic shapes — pinning
/// workers to the tiny-preset hint here would silently bench
/// single-worker and diverge from what `cargo bench --bench
/// serve_bench` (CI) measures.
fn cmd_serve_bench(args: &Args) -> anyhow::Result<()> {
    use cosa::serve::bench::{
        run, run_model, ModelBenchOpts, ServeBenchOpts,
    };
    use cosa::serve::SiteShape;
    use cosa::util::json::Json;

    let cfg = match args.opt("config") {
        Some(path) => RunConfig::load(path)?,
        None => RunConfig::default(),
    };
    let mut serve = cfg.serve.env_overridden();
    if let Some(v) = args.opt("batch") {
        serve.max_batch = v.parse()?;
        anyhow::ensure!(serve.max_batch >= 1, "--batch must be >= 1");
    }
    if let Some(v) = args.opt("wait-us") {
        serve.max_wait_us = v.parse()?;
    }
    if let Some(v) = args.opt("workers") {
        serve.workers = v.parse()?;
    }
    if let Some(v) = args.opt("cache-mb") {
        serve.cache_mb = v.parse()?;
        anyhow::ensure!(serve.cache_mb >= 0.0, "--cache-mb must be >= 0");
    }
    let defaults = ServeBenchOpts::default();
    let opts = ServeBenchOpts {
        adapters: args.usize("adapters", defaults.adapters),
        requests: args.usize("requests", defaults.requests),
        zipf: args.f64("zipf", defaults.zipf),
        rate: args.f64("rate", defaults.rate),
        site: SiteShape {
            m: args.usize("site-m", defaults.site.m),
            n: args.usize("site-n", defaults.site.n),
        },
        core_a: args.usize("core-a", defaults.core_a),
        core_b: args.usize("core-b", defaults.core_b),
        seed: args.u64("seed", defaults.seed),
        cfg: serve.clone(),
    };
    let report = run(&opts)?;
    report.print();
    cosa::util::bench::write_bench_json("serving",
                                        Json::Arr(vec![report.to_json()]));

    // Whole-model scenario (the system's default shape): every request
    // exercises every site of a [model]-described spec.  --skip-model
    // keeps single-site explorations cheap.
    if !args.bool("skip-model") {
        let mut model_cfg = cfg.model.env_overridden();
        if let Some(v) = args.opt("sites") {
            model_cfg.sites = v.parse()?;
            anyhow::ensure!(model_cfg.sites >= 1, "--sites must be >= 1");
            // an explicit count asks for the synthetic preset
            model_cfg.sites_spec.clear();
        }
        let mdefaults = ModelBenchOpts::default();
        let model_serve = cosa::config::ServeConfig {
            // model cache pressure is its own knob — the single-site
            // default (64 MiB) would make the shared-vs-per-site
            // comparison an everything-resident no-op
            cache_mb: args.f64("model-cache-mb", mdefaults.cfg.cache_mb),
            ..serve.clone()
        };
        anyhow::ensure!(model_serve.cache_mb >= 0.0,
                        "--model-cache-mb must be >= 0");
        let mopts = ModelBenchOpts {
            spec: model_cfg.to_spec("serve-bench")?,
            adapters: args.usize("adapters", mdefaults.adapters),
            requests: args.usize("model-requests", mdefaults.requests),
            zipf: args.f64("zipf", mdefaults.zipf),
            seed: args.u64("seed", mdefaults.seed),
            cfg: model_serve,
        };
        let mreport = run_model(&mopts)?;
        mreport.print();
        cosa::util::bench::write_bench_json(
            "serving_model", Json::Arr(vec![mreport.to_json()]));
    }

    // Wire scenario (opt-in: --wire): the same single-site workload
    // through a loopback HTTP gateway vs the in-process engine at
    // equal concurrency -> `serving_wire` section.
    if args.bool("wire") {
        use cosa::wire::bench::{run_wire, WireBenchOpts};
        let wdefaults = WireBenchOpts::default();
        let wopts = WireBenchOpts {
            adapters: args.usize("adapters", wdefaults.adapters),
            requests: args.usize("wire-requests", wdefaults.requests),
            clients: args.usize("wire-clients", wdefaults.clients),
            zipf: args.f64("zipf", wdefaults.zipf),
            site: SiteShape {
                m: args.usize("site-m", wdefaults.site.m),
                n: args.usize("site-n", wdefaults.site.n),
            },
            core_a: args.usize("core-a", wdefaults.core_a),
            core_b: args.usize("core-b", wdefaults.core_b),
            seed: args.u64("seed", wdefaults.seed),
            serve: serve.clone(),
            wire: cosa::config::WireConfig {
                port: 0,
                ..cfg.wire.env_overridden()
            },
        };
        anyhow::ensure!(wopts.clients >= 1, "--wire-clients must be >= 1");
        let wreport = run_wire(&wopts)?;
        wreport.print();
        cosa::util::bench::write_bench_json(
            "serving_wire", Json::Arr(vec![wreport.to_json()]));
    }

    // Tail scenario (opt-in: --tail): the heavy-tail fused-batching
    // acceptance workload — the identical Zipf s=1.0 stream over a
    // 512-adapter fleet through a fused server and a `fused = false`
    // per-adapter-segment server -> `serving_tail` section.  The fleet
    // shape has its own flags (the default IS the acceptance
    // scenario); engine knobs reuse the scenario-1 CLI/env overrides.
    if args.bool("tail") {
        use cosa::serve::bench::{run_tail, TailBenchOpts};
        let tdefaults = TailBenchOpts::default();
        let topts = TailBenchOpts {
            adapters: args.usize("tail-adapters", tdefaults.adapters),
            requests: args.usize("tail-requests", tdefaults.requests),
            zipf: args.f64("tail-zipf", tdefaults.zipf),
            seed: args.u64("seed", tdefaults.seed),
            cfg: cosa::config::ServeConfig {
                workers: serve.workers,
                ..tdefaults.cfg.clone()
            },
            ..tdefaults
        };
        anyhow::ensure!(topts.adapters >= 1,
                        "--tail-adapters must be >= 1");
        let treport = run_tail(&topts)?;
        treport.print();
        cosa::util::bench::write_bench_json(
            "serving_tail", Json::Arr(vec![treport.to_json()]));
    }

    // Methods scenario (opt-in: --methods): the adapter-zoo
    // cross-method comparison — CoSA, RoSA, and LoRA fleets side by
    // side in one mixed-method model, per-method Zipf streams plus a
    // method-interleaved mixed stream -> `serving_methods` section
    // (one row per method + the mixed row).  The model shape reuses
    // the `[model]` spec; engine knobs reuse the scenario-1 CLI/env
    // overrides.
    if args.bool("methods") {
        use cosa::serve::bench::{run_methods, MethodsBenchOpts};
        let medefaults = MethodsBenchOpts::default();
        let mut model_cfg = cfg.model.env_overridden();
        if let Some(v) = args.opt("sites") {
            model_cfg.sites = v.parse()?;
            anyhow::ensure!(model_cfg.sites >= 1, "--sites must be >= 1");
            model_cfg.sites_spec.clear();
        }
        let meopts = MethodsBenchOpts {
            spec: model_cfg.to_spec("serve-bench")?,
            adapters_per_method: args.usize(
                "methods-adapters",
                medefaults.adapters_per_method,
            ),
            requests: args
                .usize("methods-requests", medefaults.requests),
            zipf: args.f64("zipf", medefaults.zipf),
            seed: args.u64("seed", medefaults.seed),
            cfg: cosa::config::ServeConfig {
                cache_mb: medefaults.cfg.cache_mb,
                ..serve.clone()
            },
        };
        anyhow::ensure!(meopts.adapters_per_method >= 1,
                        "--methods-adapters must be >= 1");
        let mereport = run_methods(&meopts)?;
        mereport.print();
        cosa::util::bench::write_bench_json(
            "serving_methods", Json::Arr(mereport.to_json_rows()));
    }

    // Quant scenario (opt-in: --quant): the same whole-model Zipf
    // workload served three times — f32, bf16, int8 cache codecs — at
    // one deliberately thrashing LRU byte budget, measuring effective
    // cache capacity (resident-tensor ratio vs f32), hit rates, and
    // the machine-independent output RMSE each codec pays ->
    // `serving_quant` section (one row per codec).  The fleet shape
    // has its own flags (the default IS the acceptance scenario:
    // 24 sites x 64 adapters); engine knobs reuse the scenario-1
    // CLI/env overrides except the cache budget, which stays at the
    // scenario's thrashing default unless --quant-cache-mb overrides.
    if args.bool("quant") {
        use cosa::serve::bench::{run_quant, QuantBenchOpts};
        let qdefaults = QuantBenchOpts::default();
        let qopts = QuantBenchOpts {
            adapters: args.usize("quant-adapters", qdefaults.adapters),
            requests: args.usize("quant-requests", qdefaults.requests),
            zipf: args.f64("quant-zipf", qdefaults.zipf),
            seed: args.u64("seed", qdefaults.seed),
            cfg: cosa::config::ServeConfig {
                workers: serve.workers,
                cache_mb: args
                    .f64("quant-cache-mb", qdefaults.cfg.cache_mb),
                ..qdefaults.cfg.clone()
            },
            ..qdefaults
        };
        anyhow::ensure!(qopts.adapters >= 1,
                        "--quant-adapters must be >= 1");
        anyhow::ensure!(qopts.cfg.cache_mb > 0.0,
                        "--quant-cache-mb must be > 0");
        let qreport = run_quant(&qopts)?;
        qreport.print();
        cosa::util::bench::write_bench_json(
            "serving_quant", Json::Arr(qreport.to_json_rows()));
    }

    // Obs scenario (opt-in: --obs): the telemetry-overhead acceptance
    // workload — the identical single-site Zipf stream through a
    // tracing-disabled server and a fully traced one in interleaved
    // passes -> `serving_obs` section.  CI gates
    // `traced_vs_untraced >= 0.95` (tracing must cost under 5%
    // throughput).  Engine knobs reuse the scenario-1 CLI/env
    // overrides' worker count; the rest of the shape IS the
    // single-site acceptance scenario unless overridden.
    if args.bool("obs") {
        use cosa::serve::bench::{run_obs, ObsBenchOpts};
        let odefaults = ObsBenchOpts::default();
        let oopts = ObsBenchOpts {
            adapters: args.usize("obs-adapters", odefaults.adapters),
            requests: args.usize("obs-requests", odefaults.requests),
            zipf: args.f64("zipf", odefaults.zipf),
            site: SiteShape {
                m: args.usize("site-m", odefaults.site.m),
                n: args.usize("site-n", odefaults.site.n),
            },
            core_a: args.usize("core-a", odefaults.core_a),
            core_b: args.usize("core-b", odefaults.core_b),
            seed: args.u64("seed", odefaults.seed),
            passes: args.usize("obs-passes", odefaults.passes),
            cfg: cosa::config::ServeConfig {
                workers: serve.workers,
                ..odefaults.cfg.clone()
            },
        };
        anyhow::ensure!(oopts.adapters >= 1,
                        "--obs-adapters must be >= 1");
        anyhow::ensure!(oopts.passes >= 1, "--obs-passes must be >= 1");
        let oreport = run_obs(&oopts)?;
        oreport.print();
        cosa::util::bench::write_bench_json(
            "serving_obs", Json::Arr(vec![oreport.to_json()]));
    }
    Ok(())
}

fn cmd_list() -> anyhow::Result<()> {
    let reg = Registry::open_default()?;
    println!("{} artifacts in {}:", reg.artifacts.len(), reg.dir.display());
    for a in &reg.artifacts {
        println!("  {a}");
    }
    Ok(())
}

const HELP: &str = "\
cosa-repro — CoSA (Compressed Sensing-Based Adaptation) reproduction

USAGE: cosa-repro <subcommand> [flags]

  train   --config <toml> | --artifact <name> --task <id> [--steps N --lr F]
          [--backend auto|reference|tiled|packed --threads N]
          host linalg backend (auto resolves to packed; env
          COSA_BACKEND / COSA_THREADS / COSA_SIMD=scalar override)
  eval    --ckpt <path> [--task <id>]
  exp     <id>         one of: table1 table2 table3 table4 table5 table6
                       table7 table8 fig2 fig3 ystruct
  rip     [--samples N --trials K --seed S]     alias for `exp table4`
  params  [--rank R --a A --b B]                alias for `exp fig3`
  serve   [--config <toml> --host H --port P --http-workers N]
          [--preload-dir D --batch N --wait-us U --workers N
           --cache-mb F] [--no-obs --obs-slow-ms MS]
          run the HTTP/1.1 + streaming-JSON gateway over the serving
          engine in the foreground: POST /v1/forward,
          POST /v1/adapters/{name}/load, DELETE /v1/adapters/{name},
          GET /v1/adapters, GET /v1/stats, GET /metrics (Prometheus
          text), GET /v1/debug/slow (slowest traces), GET /healthz.
          [wire]/[serve]/[model]/[obs] config tables and
          COSA_WIRE_*/COSA_SERVE_*/COSA_MODEL_*/COSA_OBS_* env provide
          the defaults; --preload-dir warm-loads every checkpoint in a
          directory before the listener opens; --no-obs disables
          request tracing, --obs-slow-ms sets the slow-request WARN
          watermark
  serve-bench  [--adapters N --requests N --zipf S --rate RPS]
          [--batch N --wait-us U --workers N --cache-mb F]
          [--site-m M --site-n N --core-a A --core-b B --seed S]
          [--sites N --model-requests N --model-cache-mb F]
          [--skip-model] [--wire --wire-requests N --wire-clients N]
          [--tail --tail-adapters N --tail-requests N --tail-zipf S]
          [--methods --methods-adapters N --methods-requests N]
          [--quant --quant-adapters N --quant-requests N --quant-zipf S
           --quant-cache-mb F]
          [--obs --obs-adapters N --obs-requests N --obs-passes N]
          multi-adapter serving benchmarks: the single-site scenario
          (batched scheduler vs sequential per-request forward ->
          `serving` section of BENCH_linalg.json) plus the whole-model
          scenario (N sites x M adapters, shared projection LRU vs
          per-site-partitioned caches -> `serving_model` section).
          [serve]/[model] config tables and COSA_SERVE_*/COSA_MODEL_*
          env provide the defaults; --skip-model runs only the
          single-site scenario; --wire adds the loopback HTTP gateway
          scenario (closed-loop clients vs the in-process engine ->
          `serving_wire` section); --tail adds the heavy-tail fused
          cross-adapter batching scenario (fused vs per-adapter
          batching on an identical Zipf s=1.0 stream ->
          `serving_tail` section); --methods adds the adapter-zoo
          cross-method table (CoSA vs RoSA vs LoRA fleets plus a
          mixed-method stream in one engine ->
          `serving_methods` section); --quant adds the quantized-cache
          codec comparison (f32 vs bf16 vs int8 residents at one
          thrashing LRU budget: effective-capacity ratio, hit rates,
          output RMSE vs f32 -> `serving_quant` section); --obs adds
          the telemetry-overhead scenario (traced vs untraced server
          on one identical stream -> `serving_obs` section)
  list    show artifacts (build with `make artifacts`)
";
