//! Reference backend: the seed's single-threaded i-k-j matmul, kept as
//! the semantic baseline every other backend is property-tested against.
//!
//! Differences from the original `Matrix::matmul`: the per-element
//! `a == 0.0` skip branch is gone (it penalized every dense product to
//! help only sparse cores — those now use `linalg::sparse`), and the
//! transpose variants accumulate directly from the untransposed operands
//! instead of materializing `Aᵀ`/`Bᵀ` first.  Accumulation order per
//! output element (ascending k) is identical to the original, so results
//! match the seed bit-for-bit on the dense path.

use crate::linalg::{shape_nn, shape_nt, shape_tn, Backend};
use crate::math::matrix::Matrix;

/// Plain-loop backend; allocation-free kernels, no blocking, no threads.
pub struct Reference;

impl Backend for Reference {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn gemm_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix) {
        shape_nn(a, b, out);
        let (m, k, c) = (a.rows, a.cols, b.cols);
        out.data.fill(0.0);
        for i in 0..m {
            let arow = &a.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * c..(i + 1) * c];
            for (kk, av) in arow.iter().enumerate() {
                let brow = &b.data[kk * c..(kk + 1) * c];
                for (o, bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }

    fn gemm_nt_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix) {
        shape_nt(a, b, out);
        let (m, k, n) = (a.rows, a.cols, b.rows);
        for i in 0..m {
            let arow = &a.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = &b.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (x, y) in arow.iter().zip(brow) {
                    acc += x * y;
                }
                *o = acc;
            }
        }
    }

    fn gemm_tn_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix) {
        shape_tn(a, b, out);
        let (k, mo, n) = (a.rows, a.cols, b.cols);
        out.data.fill(0.0);
        for kk in 0..k {
            let arow = &a.data[kk * mo..(kk + 1) * mo];
            let brow = &b.data[kk * n..(kk + 1) * n];
            for (i, av) in arow.iter().enumerate() {
                let orow = &mut out.data[i * n..(i + 1) * n];
                for (o, bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
}
