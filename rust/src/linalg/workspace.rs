//! Reusable buffer arena for allocation-free hot loops.
//!
//! ## Contract
//!
//! * [`Workspace::take`] returns a **zeroed** `Vec<f32>` of the requested
//!   length, reusing a pooled allocation whenever one with sufficient
//!   capacity exists ([`Workspace::take_scratch`] is the non-zeroing
//!   variant for callers that overwrite every element, e.g. panel
//!   packing); [`Workspace::recycle`] returns a buffer to the
//!   pool.  With a fixed set of shapes per iteration (the training-step
//!   case), every `take` after the first iteration is a reuse — the
//!   [`Workspace::fresh_allocs`] counter stops moving, which is exactly
//!   what the zero-allocation tests and benches assert.
//! * Buffers are plain `Vec<f32>`; wrap/unwrap them as matrices with
//!   [`Workspace::take_matrix`] / [`Workspace::recycle_matrix`].
//! * The pool is bounded ([`MAX_POOLED`]); recycling beyond the bound
//!   drops the smallest pooled buffer instead of growing without limit.

use crate::math::matrix::Matrix;

/// Maximum number of buffers retained in the pool.
const MAX_POOLED: usize = 64;

/// A pool of reusable f32 buffers (see module docs for the contract).
#[derive(Default)]
pub struct Workspace {
    pool: Vec<Vec<f32>>,
    fresh_allocs: usize,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Number of times `take` had to allocate instead of reusing a
    /// pooled buffer.  Flat across iterations ⇒ the loop is
    /// allocation-free after warmup.
    pub fn fresh_allocs(&self) -> usize {
        self.fresh_allocs
    }

    /// Buffers currently pooled (diagnostic).
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }

    /// Best-fit pooled buffer with capacity ≥ `len` (smallest sufficient
    /// capacity, so a repeating request sequence reaches a deterministic
    /// steady-state assignment and stays allocation-free).
    fn take_pooled(&mut self, len: usize) -> Option<Vec<f32>> {
        let best = self
            .pool
            .iter()
            .enumerate()
            .filter(|(_, b)| b.capacity() >= len)
            .min_by_key(|(_, b)| b.capacity())
            .map(|(i, _)| i);
        best.map(|i| self.pool.swap_remove(i))
    }

    /// A zeroed buffer of length `len`, reusing pooled capacity if any
    /// buffer is large enough.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        if let Some(mut buf) = self.take_pooled(len) {
            buf.clear();
            buf.resize(len, 0.0);
            return buf;
        }
        self.fresh_allocs += 1;
        vec![0.0; len]
    }

    /// A length-`len` buffer with **unspecified contents** (stale values
    /// from a previous use), for callers that overwrite every element
    /// anyway — panel packing uses this to skip `take`'s O(len) zeroing
    /// pass on the GEMM hot path.
    pub fn take_scratch(&mut self, len: usize) -> Vec<f32> {
        if let Some(mut buf) = self.take_pooled(len) {
            if buf.len() >= len {
                buf.truncate(len);
            } else {
                buf.resize(len, 0.0); // only the grown tail is written
            }
            return buf;
        }
        self.fresh_allocs += 1;
        vec![0.0; len]
    }

    /// Return a buffer to the pool.  When the pool is full the smallest
    /// allocation is kept out: the incoming buffer replaces the smallest
    /// pooled one only if it is strictly larger, otherwise it is dropped
    /// — so large recurring buffers are never evicted by small ones.
    pub fn recycle(&mut self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        if self.pool.len() >= MAX_POOLED {
            let smallest = self
                .pool
                .iter()
                .enumerate()
                .min_by_key(|(_, b)| b.capacity())
                .map(|(i, b)| (i, b.capacity()));
            match smallest {
                Some((i, cap)) if cap < buf.capacity() => {
                    self.pool.swap_remove(i);
                }
                _ => return, // incoming is no larger — drop it instead
            }
        }
        self.pool.push(buf);
    }

    /// A zeroed `rows × cols` matrix backed by a pooled buffer.
    pub fn take_matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take(rows * cols))
    }

    /// Return a matrix's backing buffer to the pool.
    pub fn recycle_matrix(&mut self, m: Matrix) {
        self.recycle(m.data);
    }
}
