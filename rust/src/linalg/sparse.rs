//! Dedicated sparse-dense product for sparse cores.
//!
//! The seed's dense matmul carried a per-element `a == 0.0` skip on its
//! innermost hot loop — a branch paid by *every* dense product to speed
//! up the rare case of a sparse left operand.  That branch now lives
//! here, as an explicit kernel for products whose **left** operand is a
//! sparse core (CoSA's trained Y, whose structure Appendix B.3 measures
//! at ~30% zeros, and the exactly-s-sparse cores of the RIP suite):
//! zero rows of the access pattern are skipped wholesale, so cost scales
//! with the number of nonzeros instead of `m·k·n`.

use crate::linalg::shape_nn;
use crate::math::matrix::Matrix;

/// `a · b` where `a` is sparse (entries exactly 0.0 are skipped).
/// Skipping only elides `+= 0.0 * x` terms, so for finite inputs the
/// result equals the dense product exactly.
pub fn gemm_sparse_left(a: &Matrix, b: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(a.rows, b.cols);
    gemm_sparse_left_into(a, b, &mut out);
    out
}

/// In-place variant of [`gemm_sparse_left`]; fully overwrites `out`.
pub fn gemm_sparse_left_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    shape_nn(a, b, out);
    let (m, k, c) = (a.rows, a.cols, b.cols);
    out.data.fill(0.0);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let orow = &mut out.data[i * c..(i + 1) * c];
        for (kk, av) in arow.iter().enumerate() {
            if *av == 0.0 {
                continue; // sparse core: skip zero entries of the pattern
            }
            let brow = &b.data[kk * c..(kk + 1) * c];
            for (o, bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// Fraction of exactly-zero entries (diagnostic for kernel selection).
pub fn zero_fraction(m: &Matrix) -> f64 {
    if m.data.is_empty() {
        return 0.0;
    }
    m.data.iter().filter(|v| **v == 0.0).count() as f64 / m.data.len() as f64
}
