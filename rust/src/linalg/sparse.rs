//! Dedicated sparse-dense product for sparse cores.
//!
//! The seed's dense matmul carried a per-element `a == 0.0` skip on its
//! innermost hot loop — a branch paid by *every* dense product to speed
//! up the rare case of a sparse left operand.  That branch now lives
//! here, as an explicit kernel for products whose **left** operand is a
//! sparse core (CoSA's trained Y, whose structure Appendix B.3 measures
//! at ~30% zeros, and the exactly-s-sparse cores of the RIP suite):
//! zero rows of the access pattern are skipped wholesale, so cost scales
//! with the number of nonzeros instead of `m·k·n`.
//!
//! ## Threading
//!
//! Above the shared FLOP threshold (`tiled::DEFAULT_MIN_PAR_FLOPS`,
//! counted in *nonzero* multiply-adds) the kernel precomputes a
//! CSR-style nonzero index — per-row (column, value) entries plus row
//! offsets — and fans the output rows across scoped threads exactly like
//! the dense backends.  The index costs one O(m·k) scan, threads own
//! disjoint output rows (deterministic for any thread count: per-row
//! accumulation order is the index order, which is ascending k), and
//! all-zero rows vanish from the work list entirely.  This is what lets
//! the RIP suite's materialized cross-checks and
//! `adapters::cosa::materialize_delta` scale across cores.  The serial
//! small-product path is unchanged and allocation-free.

use crate::linalg::shape_nn;
use crate::linalg::tiled::{parallel_rows, plan_threads, DEFAULT_MIN_PAR_FLOPS};
use crate::math::matrix::Matrix;

/// `a · b` where `a` is sparse (entries exactly 0.0 are skipped).
/// Skipping only elides `+= 0.0 * x` terms, so for finite inputs the
/// result equals the dense product exactly.
pub fn gemm_sparse_left(a: &Matrix, b: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(a.rows, b.cols);
    gemm_sparse_left_into(a, b, &mut out);
    out
}

/// In-place variant of [`gemm_sparse_left`]; fully overwrites `out`.
/// Threads above the FLOP threshold using the process-wide thread
/// setting (`COSA_THREADS` / `[compute] threads`; 0 = auto).
pub fn gemm_sparse_left_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    let threads = crate::linalg::current().1;
    sparse_left_run(a, b, out, threads, DEFAULT_MIN_PAR_FLOPS);
}

/// Worker with explicit thread/threshold knobs (tests force the
/// threaded path through this).
pub(crate) fn sparse_left_run(a: &Matrix, b: &Matrix, out: &mut Matrix,
                              threads: usize, min_par_flops: usize) {
    shape_nn(a, b, out);
    let (m, k, c) = (a.rows, a.cols, b.cols);
    out.data.fill(0.0);
    if m == 0 || k == 0 || c == 0 {
        return;
    }
    // Cheap gate first: if even the *dense* muladd bound stays serial,
    // skip the nnz-count scan entirely — small products keep the
    // original single-pass, allocation-free path.
    if plan_threads(threads, min_par_flops, m, m * k * c) <= 1 {
        serial_skip(a, b, out, m, k, c);
        return;
    }
    let nnz = a.data.iter().filter(|v| **v != 0.0).count();
    let nt = plan_threads(threads, min_par_flops, m, nnz * c);
    if nt <= 1 {
        serial_skip(a, b, out, m, k, c);
        return;
    }

    // CSR-style nonzero index: entries[row_ptr[i]..row_ptr[i+1]] are the
    // (col, val) pairs of row i in ascending-k order.  Built per call —
    // the O(nnz) build is amortized against the O(nnz·c) kernel (c is
    // ≥ hundreds on every threaded-size call site), and the (u32, f32)
    // entries don't fit the f32 Workspace pools.
    let mut row_ptr = Vec::with_capacity(m + 1);
    let mut entries: Vec<(u32, f32)> = Vec::with_capacity(nnz);
    row_ptr.push(0usize);
    for i in 0..m {
        for (kk, av) in a.data[i * k..(i + 1) * k].iter().enumerate() {
            if *av != 0.0 {
                entries.push((kk as u32, *av));
            }
        }
        row_ptr.push(entries.len());
    }

    let bd = &b.data;
    let (rp, en) = (&row_ptr, &entries);
    parallel_rows(&mut out.data, m, c, nt, |row0, chunk| {
        for (i, orow) in chunk.chunks_mut(c).enumerate() {
            let row = row0 + i;
            for &(kk, av) in &en[rp[row]..rp[row + 1]] {
                let brow = &bd[kk as usize * c..(kk as usize + 1) * c];
                for (o, bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    });
}

/// The original serial per-entry skip loop (allocation-free).
fn serial_skip(a: &Matrix, b: &Matrix, out: &mut Matrix, m: usize,
               k: usize, c: usize) {
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let orow = &mut out.data[i * c..(i + 1) * c];
        for (kk, av) in arow.iter().enumerate() {
            if *av == 0.0 {
                continue; // sparse core: skip zero entries of the pattern
            }
            let brow = &b.data[kk * c..(kk + 1) * c];
            for (o, bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// Fraction of exactly-zero entries (diagnostic for kernel selection).
pub fn zero_fraction(m: &Matrix) -> f64 {
    if m.data.is_empty() {
        return 0.0;
    }
    m.data.iter().filter(|v| **v == 0.0).count() as f64 / m.data.len() as f64
}
