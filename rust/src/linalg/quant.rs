//! Quantized storage codecs for cache-resident operands (bf16 / int8).
//!
//! The serving layer's scaling currency is adapter capacity per GiB:
//! the shared projection LRU holds seed-regenerated L/R panels, and
//! every byte saved per panel multiplies how many adapters stay hot.
//! This module provides the two storage codecs and the container type
//! ([`QuantMat`]) the cache holds:
//!
//! * **bf16** — f32 with the low 16 mantissa bits dropped, rounded to
//!   nearest-even ([`f32_to_bf16`]).  2 bytes/element, ~3 decimal
//!   digits of precision, exact for the exponent range of f32.  The
//!   codec is total: ±inf is preserved and NaN stays NaN (the payload
//!   is quieted so truncation cannot turn a signalling pattern into
//!   an infinity).
//! * **int8 + per-panel scales** — one f32 scale per matrix *row*
//!   (`scale = amax/127` over the row's finite entries), elements
//!   stored as `round(x/scale)` clamped to ±127.  1 byte/element plus
//!   4 bytes per row.  Non-finite policy: NaN encodes to 0, ±inf
//!   saturates to ±127 (both decode to finite values — the codec is a
//!   *storage* format for regenerable data, not an IEEE round-trip).
//!
//! Decoding is **fused into the packed backend's pack step**
//! ([`super::pack`]): [`QuantMat::dequantize_row_into`] up-converts one
//! contiguous source row into a caller buffer (pool scratch in the hot
//! path), the pack scatters it into NR-wide strips, and the untouched
//! f32 micro-kernels in [`super::packed`] consume the result.  No
//! full-size f32 image of a quantized operand ever materializes on the
//! serve path.  The row up-convert follows the repo's SIMD idiom: one
//! `#[inline(always)]` portable body, an AVX2 `#[target_feature]`
//! clone on x86_64 (bf16→f32 is a shift+widen, int8 is widen+scale —
//! both auto-vectorize under wide registers), dispatched once per call
//! via [`super::simd::level`].

use std::sync::Arc;

use crate::linalg::simd;
use crate::math::matrix::Matrix;

// ---------------------------------------------------------------- bf16

/// f32 → bf16 bits, round-to-nearest-even.  Total: ±inf maps to the
/// bf16 infinities; NaN keeps its sign/exponent and gets the top
/// mantissa bit forced so the result is a quiet NaN even when every
/// surviving payload bit would otherwise be zero.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    // RNE: add 0x7fff plus the parity of the keep-bit; ties (exactly
    // 0x8000 below) round toward the even truncation.  Cannot overflow:
    // NaN is handled above and inf + 0x8000 stays below 2^32.
    let round = 0x7fff + ((bits >> 16) & 1);
    ((bits + round) >> 16) as u16
}

/// bf16 bits → f32 (exact: shift+widen, every bf16 value is an f32).
#[inline(always)]
pub fn bf16_to_f32(u: u16) -> f32 {
    f32::from_bits((u as u32) << 16)
}

// ---------------------------------------------------------------- int8

/// Encode one panel (matrix row): returns the scale.  `amax` scans
/// finite entries only, so one NaN cannot zero a panel and an inf
/// cannot blow the scale up to non-finite.
fn encode_int8_row(src: &[f32], q: &mut [i8]) -> f32 {
    let mut amax = 0.0f32;
    for &v in src {
        let a = v.abs();
        if a.is_finite() && a > amax {
            amax = a;
        }
    }
    let scale = if amax == 0.0 { 0.0 } else { amax / 127.0 };
    let inv = if scale == 0.0 { 0.0 } else { 1.0 / scale };
    for (dst, &v) in q.iter_mut().zip(src) {
        *dst = if v.is_nan() {
            0
        } else {
            // finite values land in [-127, 127] by construction of
            // `inv`; the clamp catches ±inf (→ ±127) and keeps the
            // symmetric range (a bare cast would saturate -inf to
            // -128).  An all-zero panel's inf·0 = NaN clamps to NaN
            // and casts to 0.
            (v * inv).round().clamp(-127.0, 127.0) as i8
        };
    }
    scale
}

// -------------------------------------------- row up-convert (SIMD)

#[inline(always)]
fn bf16_row_body(src: &[u16], out: &mut [f32]) {
    for (o, &u) in out.iter_mut().zip(src) {
        *o = bf16_to_f32(u);
    }
}

#[inline(always)]
fn int8_row_body(src: &[i8], scale: f32, out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(src) {
        *o = v as f32 * scale;
    }
}

// SAFETY: callers must guarantee avx2 support — upheld at every call
// site by dispatching only when `simd::level()` probes Avx2Fma.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn bf16_row_avx2(src: &[u16], out: &mut [f32]) {
    bf16_row_body(src, out);
}

// SAFETY: callers must guarantee avx2 support — upheld at every call
// site by dispatching only when `simd::level()` probes Avx2Fma.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn int8_row_avx2(src: &[i8], scale: f32, out: &mut [f32]) {
    int8_row_body(src, scale, out);
}

fn bf16_row(src: &[u16], out: &mut [f32]) {
    match simd::level() {
        #[cfg(target_arch = "x86_64")]
        simd::Level::Avx2Fma => unsafe {
            // SAFETY: level() returned Avx2Fma ⇒ CPU has avx2.
            bf16_row_avx2(src, out)
        },
        _ => bf16_row_body(src, out),
    }
}

fn int8_row(src: &[i8], scale: f32, out: &mut [f32]) {
    match simd::level() {
        #[cfg(target_arch = "x86_64")]
        simd::Level::Avx2Fma => unsafe {
            // SAFETY: level() returned Avx2Fma ⇒ CPU has avx2.
            int8_row_avx2(src, scale, out)
        },
        _ => int8_row_body(src, scale, out),
    }
}

// ------------------------------------------------------------- policy

/// Storage codec selector for cache-resident operands.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantKind {
    /// Uncompressed f32 — the bit-identical default.
    F32,
    /// bf16, truncation rounded to nearest-even (2 bytes/element).
    Bf16,
    /// int8 with one f32 scale per row panel (1 byte/element + 4/row).
    Int8,
}

impl QuantKind {
    pub fn parse(s: &str) -> anyhow::Result<QuantKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "none" => QuantKind::F32,
            "bf16" | "bfloat16" => QuantKind::Bf16,
            "int8" | "i8" => QuantKind::Int8,
            other => anyhow::bail!(
                "unknown cache quantization `{other}` (f32|bf16|int8)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            QuantKind::F32 => "f32",
            QuantKind::Bf16 => "bf16",
            QuantKind::Int8 => "int8",
        }
    }

    /// Payload bytes of a `rows×cols` matrix stored under this codec
    /// (the cache ledger counts exactly this).
    pub fn bytes_for(&self, rows: usize, cols: usize) -> usize {
        match self {
            QuantKind::F32 => rows * cols * 4,
            QuantKind::Bf16 => rows * cols * 2,
            QuantKind::Int8 => rows * cols + rows * 4,
        }
    }
}

// ---------------------------------------------------------- container

enum Payload {
    F32(Arc<Matrix>),
    Bf16(Vec<u16>),
    Int8 { q: Vec<i8>, scales: Vec<f32> },
}

/// A row-major matrix stored under one of the [`QuantKind`] codecs.
/// The F32 variant wraps the source `Arc<Matrix>` without copying, so
/// the default policy has zero encode cost and bit-identical reads.
pub struct QuantMat {
    rows: usize,
    cols: usize,
    payload: Payload,
}

impl QuantMat {
    /// Encode a borrowed matrix (F32 clones the data into a fresh Arc).
    pub fn encode(m: &Matrix, kind: QuantKind) -> QuantMat {
        match kind {
            QuantKind::F32 => QuantMat::from_arc(Arc::new(m.clone())),
            _ => QuantMat::encode_parts(m.rows, m.cols, &m.data, kind),
        }
    }

    /// Encode an owned matrix — the F32 path wraps without copying.
    pub fn encode_owned(m: Matrix, kind: QuantKind) -> QuantMat {
        match kind {
            QuantKind::F32 => QuantMat::from_arc(Arc::new(m)),
            _ => QuantMat::encode_parts(m.rows, m.cols, &m.data, kind),
        }
    }

    /// Wrap an already-shared matrix as an uncompressed resident.
    pub fn from_arc(m: Arc<Matrix>) -> QuantMat {
        QuantMat { rows: m.rows, cols: m.cols, payload: Payload::F32(m) }
    }

    fn encode_parts(rows: usize, cols: usize, data: &[f32],
                    kind: QuantKind) -> QuantMat {
        let payload = match kind {
            QuantKind::F32 => {
                Payload::F32(Arc::new(Matrix::from_vec(rows, cols,
                                                       data.to_vec())))
            }
            QuantKind::Bf16 => {
                Payload::Bf16(data.iter().map(|&v| f32_to_bf16(v))
                                  .collect())
            }
            QuantKind::Int8 => {
                let mut q = vec![0i8; rows * cols];
                let mut scales = vec![0.0f32; rows];
                for r in 0..rows {
                    scales[r] = encode_int8_row(
                        &data[r * cols..(r + 1) * cols],
                        &mut q[r * cols..(r + 1) * cols],
                    );
                }
                Payload::Int8 { q, scales }
            }
        };
        QuantMat { rows, cols, payload }
    }

    pub fn kind(&self) -> QuantKind {
        match &self.payload {
            Payload::F32(_) => QuantKind::F32,
            Payload::Bf16(_) => QuantKind::Bf16,
            Payload::Int8 { .. } => QuantKind::Int8,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Resident payload bytes (what the cache ledger charges).
    pub fn bytes(&self) -> usize {
        self.kind().bytes_for(self.rows, self.cols)
    }

    /// The uncompressed matrix, when this resident is stored as f32 —
    /// the fast paths key on this to stay bit-identical to the
    /// pre-quantization serving pipeline.
    pub fn as_f32(&self) -> Option<&Arc<Matrix>> {
        match &self.payload {
            Payload::F32(m) => Some(m),
            _ => None,
        }
    }

    /// Up-convert one row into `out[..cols]` (the pack-fusion entry:
    /// contiguous reads, SIMD-dispatched, no allocation).
    pub fn dequantize_row_into(&self, row: usize, out: &mut [f32]) {
        let n = self.cols;
        let dst = &mut out[..n];
        match &self.payload {
            Payload::F32(m) => {
                dst.copy_from_slice(&m.data[row * n..(row + 1) * n]);
            }
            Payload::Bf16(d) => {
                bf16_row(&d[row * n..(row + 1) * n], dst);
            }
            Payload::Int8 { q, scales } => {
                int8_row(&q[row * n..(row + 1) * n], scales[row], dst);
            }
        }
    }

    /// Full decode to a fresh `rows×cols` matrix (slow path: VJP /
    /// non-packed backends / tests — never the packed serve path).
    pub fn to_matrix(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            self.dequantize_row_into(
                r, &mut out.data[r * self.cols..(r + 1) * self.cols]);
        }
        out
    }

    /// Full decode to the transposed `cols×rows` matrix.  The quant
    /// acceptance tests use this to build the reference composition:
    /// an NT product with quantized B equals an NN product against the
    /// decoded transpose, and the packed backend computes exactly that
    /// (same pack image, same micro-kernel) — bit-identically.
    pub fn to_matrix_transposed(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        let mut rowbuf = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            self.dequantize_row_into(r, &mut rowbuf);
            for (c, &v) in rowbuf.iter().enumerate() {
                out.data[c * self.rows + r] = v;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Pcg64;
    use crate::util::prop;

    fn roundtrip_bf16(x: f32) -> f32 {
        bf16_to_f32(f32_to_bf16(x))
    }

    #[test]
    fn bf16_exact_on_representable_values() {
        // Values whose low 16 mantissa bits are zero round-trip exactly.
        for x in [0.0f32, -0.0, 1.0, -1.0, 2.0, 0.5, -0.375, 256.0,
                  1.5e38, -1.5e-38] {
            let y = roundtrip_bf16(x);
            assert_eq!(x.to_bits(), y.to_bits(), "{x} -> {y}");
        }
    }

    #[test]
    fn bf16_rounds_ties_to_even() {
        // Construct a mantissa exactly halfway between two bf16
        // neighbours: keep-bit even ⇒ truncate, keep-bit odd ⇒ round up.
        let even = f32::from_bits(0x3f80_8000); // keep bits ...0, tie
        assert_eq!(f32_to_bf16(even), 0x3f80, "tie at even truncates");
        let odd = f32::from_bits(0x3f81_8000); // keep bits ...1, tie
        assert_eq!(f32_to_bf16(odd), 0x3f82, "tie at odd rounds up");
        // And a value just above the tie always rounds up.
        let above = f32::from_bits(0x3f80_8001);
        assert_eq!(f32_to_bf16(above), 0x3f81);
        let below = f32::from_bits(0x3f80_7fff);
        assert_eq!(f32_to_bf16(below), 0x3f80);
    }

    #[test]
    fn bf16_nonfinite_policy() {
        assert_eq!(roundtrip_bf16(f32::INFINITY), f32::INFINITY);
        assert_eq!(roundtrip_bf16(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(roundtrip_bf16(f32::NAN).is_nan());
        // A NaN whose payload lives entirely in the dropped bits must
        // stay NaN (the encoder quiets the surviving mantissa).
        let sneaky = f32::from_bits(0x7f80_0001);
        assert!(sneaky.is_nan());
        assert!(roundtrip_bf16(sneaky).is_nan());
        // Rounding can (correctly) overflow the largest finite into inf.
        let near_max = f32::from_bits(0x7f7f_ffff);
        assert_eq!(roundtrip_bf16(near_max), f32::INFINITY);
    }

    #[test]
    fn bf16_subnormals_keep_sign_and_magnitude_order() {
        // f32 subnormals all collapse into bf16's subnormal range; the
        // codec must stay total and monotone there.
        let tiny = f32::from_bits(1); // smallest positive subnormal
        let r = roundtrip_bf16(tiny);
        assert!(r >= 0.0 && r <= 2.0 * tiny.max(f32::MIN_POSITIVE));
        let a = f32::from_bits(0x0001_0000);
        let b = f32::from_bits(0x0002_0000);
        assert!(roundtrip_bf16(a) <= roundtrip_bf16(b));
        assert_eq!(roundtrip_bf16(-0.0f32).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn bf16_roundtrip_relative_error_bound() {
        // 8 mantissa bits ⇒ relative error ≤ 2^-8 = 1/256 for normals.
        prop::for_all("bf16 rel err <= 2^-8", 50, |rng| {
            for _ in 0..64 {
                let x = (rng.normal() as f32) * 10.0;
                let y = roundtrip_bf16(x);
                if x != 0.0 {
                    assert!(((x - y) / x).abs() <= 1.0 / 256.0,
                            "{x} -> {y}");
                }
            }
        });
    }

    #[test]
    fn int8_roundtrip_error_bounded_by_half_step() {
        prop::for_all("int8 err <= scale/2", 30, |rng| {
            let n = prop::int_in(rng, 1, 64);
            let src: Vec<f32> =
                (0..n).map(|_| rng.normal() as f32 * 3.0).collect();
            let mut q = vec![0i8; n];
            let scale = encode_int8_row(&src, &mut q);
            for (&v, &qv) in src.iter().zip(&q) {
                let dec = qv as f32 * scale;
                assert!((v - dec).abs() <= scale * 0.5 + 1e-12,
                        "{v} -> {dec} (scale {scale})");
            }
        });
    }

    #[test]
    fn int8_all_zero_panel_has_zero_scale() {
        let src = [0.0f32; 16];
        let mut q = [1i8; 16];
        let scale = encode_int8_row(&src, &mut q);
        assert_eq!(scale, 0.0);
        assert!(q.iter().all(|&v| v == 0));
    }

    #[test]
    fn int8_outlier_dominates_panel_scale() {
        // One large entry sets the scale; small entries collapse toward
        // zero but the outlier itself is represented near-exactly.
        let mut src = [1e-3f32; 32];
        src[7] = 127.0;
        let mut q = [0i8; 32];
        let scale = encode_int8_row(&src, &mut q);
        assert!((scale - 1.0).abs() < 1e-6);
        assert_eq!(q[7], 127);
        assert!(q.iter().enumerate().all(|(i, &v)| i == 7 || v == 0));
    }

    #[test]
    fn int8_nonfinite_policy() {
        let src = [1.0f32, f32::NAN, f32::INFINITY, f32::NEG_INFINITY];
        let mut q = [0i8; 4];
        let scale = encode_int8_row(&src, &mut q);
        assert!((scale - 1.0 / 127.0).abs() < 1e-9, "amax over finite");
        assert_eq!(q, [127, 0, 127, -127]);
        // All-nonfinite panel: zero scale, NaN→0, inf casts saturate
        // through the zero scale to a stored value that decodes to 0.
        let src = [f32::NAN, f32::INFINITY];
        let mut q = [9i8; 2];
        let scale = encode_int8_row(&src, &mut q);
        assert_eq!(scale, 0.0);
        assert!(q.iter().all(|&v| v as f32 * scale == 0.0));
    }

    #[test]
    fn kind_parse_and_bytes() {
        assert_eq!(QuantKind::parse("f32").unwrap(), QuantKind::F32);
        assert_eq!(QuantKind::parse("BF16").unwrap(), QuantKind::Bf16);
        assert_eq!(QuantKind::parse("int8").unwrap(), QuantKind::Int8);
        assert!(QuantKind::parse("fp4").is_err());
        assert_eq!(QuantKind::F32.bytes_for(3, 5), 60);
        assert_eq!(QuantKind::Bf16.bytes_for(3, 5), 30);
        assert_eq!(QuantKind::Int8.bytes_for(3, 5), 15 + 12);
    }

    #[test]
    fn quantmat_f32_wraps_without_copy_and_reads_exact() {
        let mut rng = Pcg64::new(5);
        let m = Arc::new(Matrix::gaussian(7, 9, 1.0, &mut rng));
        let qm = QuantMat::from_arc(Arc::clone(&m));
        assert_eq!(qm.kind(), QuantKind::F32);
        assert!(Arc::ptr_eq(qm.as_f32().unwrap(), &m));
        assert_eq!(qm.bytes(), 7 * 9 * 4);
        let dec = qm.to_matrix();
        assert_eq!(dec.data, m.data);
    }

    #[test]
    fn quantmat_row_decode_matches_full_decode_and_transpose() {
        let mut rng = Pcg64::new(6);
        let m = Matrix::gaussian(11, 13, 2.0, &mut rng);
        for kind in [QuantKind::F32, QuantKind::Bf16, QuantKind::Int8] {
            let qm = QuantMat::encode(&m, kind);
            assert_eq!(qm.kind(), kind);
            assert_eq!((qm.rows(), qm.cols()), (11, 13));
            assert_eq!(qm.bytes(), kind.bytes_for(11, 13));
            let full = qm.to_matrix();
            let mut row = vec![0.0f32; 13];
            for r in 0..11 {
                qm.dequantize_row_into(r, &mut row);
                assert_eq!(&full.data[r * 13..(r + 1) * 13], &row[..],
                           "{} row {r}", kind.name());
            }
            let t = qm.to_matrix_transposed();
            for r in 0..11 {
                for c in 0..13 {
                    assert_eq!(full.at(r, c).to_bits(),
                               t.at(c, r).to_bits());
                }
            }
        }
    }

    #[test]
    fn quantmat_decode_error_within_codec_budget() {
        let mut rng = Pcg64::new(7);
        let m = Matrix::gaussian(16, 24, 1.0, &mut rng);
        let amax_rows: Vec<f32> = (0..16)
            .map(|r| m.row(r).iter().fold(0.0f32, |a, v| a.max(v.abs())))
            .collect();
        let bf = QuantMat::encode(&m, QuantKind::Bf16).to_matrix();
        for (x, y) in m.data.iter().zip(&bf.data) {
            assert!((x - y).abs() <= x.abs() / 256.0 + 1e-12);
        }
        let i8m = QuantMat::encode(&m, QuantKind::Int8).to_matrix();
        for r in 0..16 {
            let half_step = amax_rows[r] / 127.0 * 0.5;
            for c in 0..24 {
                assert!((m.at(r, c) - i8m.at(r, c)).abs()
                            <= half_step + 1e-12,
                        "[{r},{c}]");
            }
        }
    }
}
