//! Tiled backend: cache-blocked micro-kernels with row-parallelism.
//!
//! Three ingredients over [`super::Reference`]:
//!
//! * **k-blocking** (`KC` rows of B per pass) so the streamed B panel
//!   stays cache-resident across all output rows instead of being
//!   re-fetched from memory once per row;
//! * an **8-lane unrolled dot product** for the `A·Bᵀ` kernel (eight
//!   independent accumulator chains, the shape compilers auto-vectorize);
//! * **row-parallelism** via `std::thread::scope` once a product exceeds
//!   [`Tiled::min_par_flops`] multiply-adds; each thread owns a disjoint
//!   chunk of output rows, so no synchronization is needed and — because
//!   per-row accumulation order never depends on the thread partition —
//!   results are identical for every thread count.
//!
//! The NN and TN kernels accumulate in the same ascending-k order as the
//! reference backend (bitwise-identical results); the NT kernel's
//! unrolled dot reassociates the sum, agreeing elementwise within
//! standard f32 tolerance (property-tested at 1e-4 in `linalg::tests`).
//!
//! This backend is deliberately kept as-is: it is the mid-tier baseline
//! that [`super::Packed`] (packed panels + explicit SIMD) is benchmarked
//! against, and the regression anchor in `BENCH_baseline.json`.  Its
//! threading helpers ([`plan_threads`], [`parallel_rows`]) are shared by
//! the packed and sparse kernels.

use crate::linalg::{shape_nn, shape_nt, shape_tn, Backend};
use crate::math::matrix::Matrix;

/// B-panel height for the k-blocked NN kernel (256 rows × 4 B × a few KiB
/// of columns keeps the panel in L2 at paper-scale widths).
const KC: usize = 256;
/// B-row block for the NT kernel (64 rows of B reused across all A rows).
const NT_JB: usize = 64;

/// Products below this many multiply-adds run single-threaded — thread
/// spawn latency (~tens of µs) dwarfs the kernel at small sizes.
pub const DEFAULT_MIN_PAR_FLOPS: usize = 1 << 22;

/// Upper bound for auto-detected worker threads.
const MAX_AUTO_THREADS: usize = 8;

/// Cache-blocked, optionally threaded backend.
pub struct Tiled {
    /// Worker thread count; 0 = auto (`available_parallelism`, capped).
    pub threads: usize,
    /// Multiply-add threshold below which the kernels stay serial.
    pub min_par_flops: usize,
}

impl Tiled {
    pub fn new(threads: usize) -> Tiled {
        Tiled { threads, min_par_flops: DEFAULT_MIN_PAR_FLOPS }
    }

    fn thread_count(&self, rows: usize, muladds: usize) -> usize {
        plan_threads(self.threads, self.min_par_flops, rows, muladds)
    }
}

/// Worker-thread count for a product of `muladds` multiply-adds over
/// `rows` output rows — shared by `Tiled`, `Packed` and the sparse-left
/// kernel so every backend applies the same serial threshold and
/// auto-detection cap.
pub(crate) fn plan_threads(threads: usize, min_par_flops: usize,
                           rows: usize, muladds: usize) -> usize {
    if muladds < min_par_flops || rows == 0 {
        return 1;
    }
    let t = if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(MAX_AUTO_THREADS)
    } else {
        threads
    };
    t.clamp(1, rows)
}

/// Run `f(first_row, row_chunk)` over disjoint chunks of `rows` output
/// rows (each `cols` wide), on `nthreads` scoped threads.
pub(crate) fn parallel_rows<F>(out: &mut [f32], rows: usize, cols: usize,
                               nthreads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if nthreads <= 1 || rows == 0 || cols == 0 {
        f(0, out);
        return;
    }
    let rows_per = rows.div_ceil(nthreads);
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(rows_per * cols).enumerate() {
            let f = &f;
            s.spawn(move || f(ci * rows_per, chunk));
        }
    });
}

/// Serial k-blocked NN kernel on raw slices: `out = a · b` where `a` is
/// `rows×k` (a row-contiguous horizontal slice of A) and `b` is `k×c`.
fn nn_block(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, k: usize,
            c: usize) {
    out.fill(0.0);
    let mut kb = 0;
    while kb < k {
        let kend = (kb + KC).min(k);
        for i in 0..rows {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * c..(i + 1) * c];
            for kk in kb..kend {
                let av = arow[kk];
                let brow = &b[kk * c..(kk + 1) * c];
                for (o, bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        kb = kend;
    }
}

/// 8-lane unrolled dot product (independent chains → SIMD-friendly).
fn dot8(x: &[f32], y: &[f32]) -> f32 {
    let xc = x.chunks_exact(8);
    let yc = y.chunks_exact(8);
    let xr = xc.remainder();
    let yr = yc.remainder();
    let mut acc = [0.0f32; 8];
    for (cx, cy) in xc.zip(yc) {
        for t in 0..8 {
            acc[t] += cx[t] * cy[t];
        }
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5]))
        + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    for (xv, yv) in xr.iter().zip(yr) {
        s += xv * yv;
    }
    s
}

/// Serial NT kernel: `out = a · bᵀ`, `a` rows×k, `b` n×k, blocked so each
/// `NT_JB`-row panel of `b` is reused across every row of `a`.
fn nt_block(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, k: usize,
            n: usize) {
    let mut jb = 0;
    while jb < n {
        let jend = (jb + NT_JB).min(n);
        for i in 0..rows {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in jb..jend {
                orow[j] = dot8(arow, &b[j * k..(j + 1) * k]);
            }
        }
        jb = jend;
    }
}

/// Serial TN kernel for output rows `[i0, i0+rows)`: `out = aᵀ · b` where
/// `a` is k×mo (full matrix — TN reads A columns, which are strided) and
/// `b` is k×n.
fn tn_block(a: &[f32], b: &[f32], out: &mut [f32], i0: usize, rows: usize,
            mo: usize, k: usize, n: usize) {
    out.fill(0.0);
    for kk in 0..k {
        let arow = &a[kk * mo..(kk + 1) * mo];
        let brow = &b[kk * n..(kk + 1) * n];
        for i in 0..rows {
            let av = arow[i0 + i];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

impl Backend for Tiled {
    fn name(&self) -> &'static str {
        "tiled"
    }

    fn gemm_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix) {
        shape_nn(a, b, out);
        let (m, k, c) = (a.rows, a.cols, b.cols);
        if m == 0 || c == 0 {
            return;
        }
        if k == 0 {
            out.data.fill(0.0);
            return;
        }
        let nt = self.thread_count(m, m * k * c);
        let (ad, bd) = (&a.data, &b.data);
        parallel_rows(&mut out.data, m, c, nt, |row0, chunk| {
            let rows_here = chunk.len() / c;
            nn_block(&ad[row0 * k..(row0 + rows_here) * k], bd, chunk,
                     rows_here, k, c);
        });
    }

    fn gemm_nt_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix) {
        shape_nt(a, b, out);
        let (m, k, n) = (a.rows, a.cols, b.rows);
        if m == 0 || n == 0 {
            return;
        }
        let nt = self.thread_count(m, m * k.max(1) * n);
        let (ad, bd) = (&a.data, &b.data);
        parallel_rows(&mut out.data, m, n, nt, |row0, chunk| {
            let rows_here = chunk.len() / n;
            nt_block(&ad[row0 * k..(row0 + rows_here) * k], bd, chunk,
                     rows_here, k, n);
        });
    }

    fn gemm_tn_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix) {
        shape_tn(a, b, out);
        let (k, mo, n) = (a.rows, a.cols, b.cols);
        if mo == 0 || n == 0 {
            return;
        }
        if k == 0 {
            out.data.fill(0.0);
            return;
        }
        let nt = self.thread_count(mo, mo * k * n);
        let (ad, bd) = (&a.data, &b.data);
        parallel_rows(&mut out.data, mo, n, nt, |row0, chunk| {
            let rows_here = chunk.len() / n;
            tn_block(ad, bd, chunk, row0, rows_here, mo, k, n);
        });
    }
}
