//! `linalg` — the host-side compute layer.
//!
//! Every dense product in the framework (adapter forward/VJP, PiSSA's
//! randomized SVD, the RIP estimator's Gram matrices, the experiment
//! harnesses and benches) routes through the [`Backend`] trait defined
//! here instead of hand-rolled loops, so the compute substrate can be
//! swapped, measured and scaled in one place.
//!
//! ## Backends
//!
//! * [`Reference`] — the seed's single-threaded i-k-j loops, minus the
//!   per-element sparse-skip branch; the semantic baseline.
//! * [`Tiled`] — cache-blocked micro-kernels with unrolled dot products
//!   and `std::thread::scope` row-parallelism above a FLOP threshold.
//!   Results are deterministic for a given shape regardless of thread
//!   count (threads own disjoint output rows; per-row accumulation order
//!   is fixed).  Kept unchanged as the mid-tier benchmark baseline.
//! * [`Packed`] — packed-panel micro-kernel GEMM: B is packed into
//!   NR-column strips ([`pack`], buffers from a thread-local
//!   [`Workspace`] pool so packing is allocation-free after warmup), the
//!   NN/TN kernels hold an MR×NR register block across KC-deep k-blocks
//!   (TN additionally packs A — a one-time blocked transpose — and then
//!   runs the NN kernel on contiguous rows), and every hot body runs at
//!   a runtime-selected SIMD level ([`simd`]: AVX2+FMA clone on capable
//!   x86_64, portable auto-vectorized body elsewhere; `COSA_SIMD=scalar`
//!   forces the portable body).  Also overrides the grouped
//!   block-diagonal NT entry ([`Backend::gemm_grouped_nt_into`]) with a
//!   single fused thread fan-out over all segments.
//!
//! Sparse cores use the dedicated [`sparse`] kernels instead of a branch
//! inside the dense path; the sparse-left kernel threads above the same
//! FLOP threshold via a precomputed nonzero-row index.
//!
//! ## Selection rules
//!
//! The process-wide backend is chosen in this order:
//!
//! 1. environment override: `COSA_BACKEND=auto|reference|tiled|packed`
//!    and `COSA_THREADS=<n>` (read once, first use);
//! 2. the last [`set_backend`] / [`configure`] call — the trainer applies
//!    the run config's `[compute]` table (see `config::ComputeConfig`)
//!    here;
//! 3. default `auto`, which resolves to [`Packed`] with auto threads
//!    (small products stay serial via the FLOP threshold, so `auto` is
//!    safe at every size).
//!
//! ## Transpose-free variants
//!
//! [`gemm_nt`] (`A·Bᵀ`) and [`gemm_tn`] (`Aᵀ·B`) read the untransposed
//! operands directly — call sites no longer materialize a transposed
//! copy before multiplying.
//!
//! ## Workspace arena
//!
//! [`Workspace`] pools output buffers for the `*_into` kernel variants;
//! see its module docs for the reuse contract.  The training-step hot
//! loops (`adapters::cosa::adapter_forward_into`, `train::HostCosaStep`)
//! perform zero matmul-output allocations after their first iteration.
//!
//! ## Quantized operands
//!
//! [`quant`] provides bf16 / int8 storage codecs ([`QuantMat`]) for
//! cache-resident operands; the packed backend consumes them through
//! quantized-source pack variants ([`pack`]) that fuse the SIMD
//! up-convert into the pack pass, so the f32 micro-kernels are
//! untouched and no full-size dequantized image materializes
//! ([`Packed::gemm_nt_quant_into`] and the grouped variant).

pub mod pack;
pub mod packed;
pub mod quant;
pub mod reference;
pub mod simd;
pub mod sparse;
pub mod tiled;
mod workspace;

pub use packed::Packed;
pub use quant::{QuantKind, QuantMat};
pub use reference::Reference;
pub use tiled::Tiled;
pub use workspace::Workspace;

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::math::matrix::Matrix;

/// A dense-compute implementation.  The `*_into` kernels fully overwrite
/// `out` (no accumulate-into semantics) and must be allocation-free.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// `out = a · b` — a (m×k), b (k×n), out (m×n).
    fn gemm_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix);
    /// `out = a · bᵀ` — a (m×k), b (n×k), out (m×n).
    fn gemm_nt_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix);
    /// `out = aᵀ · b` — a (k×m), b (k×n), out (m×n).
    fn gemm_tn_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix);
    /// Grouped (block-diagonal) NT: consecutive row segments of `a`
    /// (`segs[g]` rows each, summing to `a.rows`) each multiply their
    /// own `bs[g]` (`n×k`), writing the matching rows of `out` (m×n) —
    /// `out[seg g] = a[seg g] · bs[g]ᵀ`.  Must be **bit-identical** to
    /// calling [`Backend::gemm_nt_into`] once per segment; the serving
    /// layer relies on that to fuse same-site rows from different
    /// adapters into one dispatch.  This default composes exactly that
    /// way (allocating per-segment temporaries — correct, not fast);
    /// [`Packed`] overrides it with a fused single-fan-out sweep.
    fn gemm_grouped_nt_into(&self, a: &Matrix, bs: &[&Matrix],
                            segs: &[usize], out: &mut Matrix) {
        shape_grouped_nt(a, bs, segs, out);
        let (k, n) = (a.cols, out.cols);
        let mut row = 0usize;
        for (g, &rows) in segs.iter().enumerate() {
            if rows == 0 {
                continue;
            }
            let asub = Matrix::from_vec(
                rows, k, a.data[row * k..(row + rows) * k].to_vec());
            let mut osub = Matrix::zeros(rows, n);
            self.gemm_nt_into(&asub, bs[g], &mut osub);
            out.data[row * n..(row + rows) * n]
                .copy_from_slice(&osub.data);
            row += rows;
        }
    }
    /// `y += alpha · x` (serial default shared by every backend — the
    /// compiler auto-vectorizes this shape; override only to specialize).
    fn axpy(&self, alpha: f32, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len(), "axpy length mismatch");
        for (yv, xv) in y.iter_mut().zip(x) {
            *yv += alpha * xv;
        }
    }

    fn gemm(&self, a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows, b.cols);
        self.gemm_into(a, b, &mut out);
        out
    }
    fn gemm_nt(&self, a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows, b.rows);
        self.gemm_nt_into(a, b, &mut out);
        out
    }
    fn gemm_tn(&self, a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.cols, b.cols);
        self.gemm_tn_into(a, b, &mut out);
        out
    }
}

pub(crate) fn shape_nn(a: &Matrix, b: &Matrix, out: &Matrix) {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch: ({}x{})·({}x{})",
               a.rows, a.cols, b.rows, b.cols);
    assert_eq!((out.rows, out.cols), (a.rows, b.cols),
               "gemm out shape: have {}x{}, want {}x{}",
               out.rows, out.cols, a.rows, b.cols);
}

pub(crate) fn shape_nt(a: &Matrix, b: &Matrix, out: &Matrix) {
    assert_eq!(a.cols, b.cols, "gemm_nt shape mismatch: ({}x{})·({}x{})ᵀ",
               a.rows, a.cols, b.rows, b.cols);
    assert_eq!((out.rows, out.cols), (a.rows, b.rows),
               "gemm_nt out shape: have {}x{}, want {}x{}",
               out.rows, out.cols, a.rows, b.rows);
}

pub(crate) fn shape_tn(a: &Matrix, b: &Matrix, out: &Matrix) {
    assert_eq!(a.rows, b.rows, "gemm_tn shape mismatch: ({}x{})ᵀ·({}x{})",
               a.rows, a.cols, b.rows, b.cols);
    assert_eq!((out.rows, out.cols), (a.cols, b.cols),
               "gemm_tn out shape: have {}x{}, want {}x{}",
               out.rows, out.cols, a.cols, b.cols);
}

pub(crate) fn shape_grouped_nt(a: &Matrix, bs: &[&Matrix],
                               segs: &[usize], out: &Matrix) {
    assert_eq!(bs.len(), segs.len(),
               "gemm_grouped_nt: {} B operands vs {} segments",
               bs.len(), segs.len());
    let total: usize = segs.iter().sum();
    assert_eq!(total, a.rows,
               "gemm_grouped_nt: segments cover {total} rows, a has {}",
               a.rows);
    assert_eq!(out.rows, a.rows,
               "gemm_grouped_nt out rows: have {}, want {}",
               out.rows, a.rows);
    for (g, b) in bs.iter().enumerate() {
        assert_eq!(b.cols, a.cols,
                   "gemm_grouped_nt segment {g}: ({}x{})·({}x{})ᵀ",
                   a.rows, a.cols, b.rows, b.cols);
        assert_eq!(b.rows, out.cols,
                   "gemm_grouped_nt segment {g}: b has {} rows, out has \
                    {} cols",
                   b.rows, out.cols);
    }
}

/// Backend selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Resolve to the best general-purpose backend (currently `Packed`).
    Auto,
    Reference,
    Tiled,
    Packed,
}

impl Kind {
    pub fn parse(s: &str) -> anyhow::Result<Kind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "auto" => Kind::Auto,
            "reference" | "ref" => Kind::Reference,
            "tiled" => Kind::Tiled,
            "packed" => Kind::Packed,
            other => anyhow::bail!(
                "unknown linalg backend `{other}` \
                 (auto|reference|tiled|packed)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Kind::Auto => "auto",
            Kind::Reference => "reference",
            Kind::Tiled => "tiled",
            Kind::Packed => "packed",
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            Kind::Auto => 0,
            Kind::Reference => 1,
            Kind::Tiled => 2,
            Kind::Packed => 3,
        }
    }

    fn from_u8(v: u8) -> Kind {
        match v {
            1 => Kind::Reference,
            2 => Kind::Tiled,
            3 => Kind::Packed,
            _ => Kind::Auto,
        }
    }
}

static KIND: AtomicU8 = AtomicU8::new(0); // Kind::Auto
static THREADS: AtomicUsize = AtomicUsize::new(0); // 0 = auto

/// Environment override, read once at first use (see module docs).
fn env_override() -> &'static (Option<Kind>, Option<usize>) {
    static ENV: OnceLock<(Option<Kind>, Option<usize>)> = OnceLock::new();
    ENV.get_or_init(|| {
        let kind = std::env::var("COSA_BACKEND").ok().and_then(|s| {
            match Kind::parse(&s) {
                Ok(k) => Some(k),
                Err(e) => {
                    eprintln!("warning: ignoring COSA_BACKEND: {e}");
                    None
                }
            }
        });
        let threads = std::env::var("COSA_THREADS").ok().and_then(|s| {
            match s.parse() {
                Ok(t) => Some(t),
                Err(_) => {
                    eprintln!(
                        "warning: ignoring COSA_THREADS=`{s}` (not a \
                         non-negative integer)"
                    );
                    None
                }
            }
        });
        (kind, threads)
    })
}

/// Set the process-wide backend (env vars still take precedence).
pub fn set_backend(kind: Kind, threads: usize) {
    KIND.store(kind.to_u8(), Ordering::Relaxed);
    THREADS.store(threads, Ordering::Relaxed);
}

/// Config-string entry point used by the trainer / CLI.
pub fn configure(backend: &str, threads: usize) -> anyhow::Result<()> {
    set_backend(Kind::parse(backend)?, threads);
    Ok(())
}

/// The effective (kind, threads) after the env override.
pub fn current() -> (Kind, usize) {
    let (ek, et) = env_override();
    let kind = ek.unwrap_or_else(|| Kind::from_u8(KIND.load(Ordering::Relaxed)));
    let threads = et.unwrap_or_else(|| THREADS.load(Ordering::Relaxed));
    (kind, threads)
}

/// The concrete backend `Auto` resolves to right now — the single place
/// that mapping lives (dispatch, `describe` and the benches all use it).
pub fn resolved_kind() -> Kind {
    match current().0 {
        Kind::Reference => Kind::Reference,
        Kind::Tiled => Kind::Tiled,
        _ => Kind::Packed,
    }
}

/// Human-readable description of the active backend.
pub fn describe() -> String {
    let (kind, threads) = current();
    let t = if threads == 0 {
        "auto".to_string()
    } else {
        threads.to_string()
    };
    format!("{} (selector={}, threads={t}, simd={})",
            resolved_kind().name(), kind.name(), simd::level().name())
}

fn dispatch<R>(f: impl FnOnce(&dyn Backend) -> R) -> R {
    let threads = current().1;
    match resolved_kind() {
        Kind::Reference => f(&Reference),
        Kind::Tiled => f(&Tiled::new(threads)),
        _ => f(&Packed::new(threads)),
    }
}

/// `a · b` on the active backend.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    dispatch(|bk| bk.gemm(a, b))
}

/// `a · bᵀ` on the active backend (no transpose materialized).
pub fn gemm_nt(a: &Matrix, b: &Matrix) -> Matrix {
    dispatch(|bk| bk.gemm_nt(a, b))
}

/// `aᵀ · b` on the active backend (no transpose materialized).
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    dispatch(|bk| bk.gemm_tn(a, b))
}

/// In-place `out = a · b` on the active backend.
pub fn gemm_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    dispatch(|bk| bk.gemm_into(a, b, out))
}

/// In-place `out = a · bᵀ` on the active backend.
pub fn gemm_nt_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    dispatch(|bk| bk.gemm_nt_into(a, b, out))
}

/// In-place `out = aᵀ · b` on the active backend.
pub fn gemm_tn_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    dispatch(|bk| bk.gemm_tn_into(a, b, out))
}

/// Grouped block-diagonal NT on the active backend (see
/// [`Backend::gemm_grouped_nt_into`]): row segment `g` of `a`
/// multiplies `bs[g]ᵀ` into the matching rows of `out`.
pub fn gemm_grouped_nt_into(a: &Matrix, bs: &[&Matrix], segs: &[usize],
                            out: &mut Matrix) {
    dispatch(|bk| bk.gemm_grouped_nt_into(a, bs, segs, out))
}

/// `y += alpha · x` on the active backend.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    dispatch(|bk| bk.axpy(alpha, x, y))
}

/// In-place `out = a · bᵀ` with a quantized B operand.  The packed
/// backend (which `auto` resolves to — the serving configuration)
/// consumes the encoded payload through its pack-fused up-convert
/// ([`Packed::gemm_nt_quant_into`]); the reference/tiled backends
/// decode to a transient f32 matrix first, a correctness-only fallback
/// for debug runs.
pub fn gemm_nt_quant_into(a: &Matrix, b: &QuantMat, out: &mut Matrix) {
    let threads = current().1;
    match resolved_kind() {
        Kind::Reference | Kind::Tiled => match b.as_f32() {
            Some(bm) => gemm_nt_into(a, bm, out),
            None => {
                let bm = b.to_matrix();
                gemm_nt_into(a, &bm, out);
            }
        },
        _ => Packed::new(threads).gemm_nt_quant_into(a, b, out),
    }
}

/// Grouped block-diagonal NT with quantized B operands (see
/// [`gemm_grouped_nt_into`]); bit-identical to calling
/// [`gemm_nt_quant_into`] once per segment.
pub fn gemm_grouped_nt_quant_into(a: &Matrix, bs: &[&QuantMat],
                                  segs: &[usize], out: &mut Matrix) {
    let threads = current().1;
    match resolved_kind() {
        Kind::Reference | Kind::Tiled => {
            let decoded: Vec<Matrix> =
                bs.iter().map(|q| q.to_matrix()).collect();
            let refs: Vec<&Matrix> = decoded.iter().collect();
            gemm_grouped_nt_into(a, &refs, segs, out);
        }
        _ => Packed::new(threads)
            .gemm_grouped_nt_quant_into(a, bs, segs, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Pcg64;
    use crate::util::prop;

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32, ctx: &str) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{ctx}: shape");
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert!(
                (x - y).abs() <= tol,
                "{ctx}: element {i}: {x} vs {y}"
            );
        }
    }

    /// A Tiled instance forced onto the threaded path even at tiny sizes,
    /// so the chunking logic is exercised by small property tests.
    fn forced_parallel() -> Tiled {
        Tiled { threads: 4, min_par_flops: 1 }
    }

    /// Same for the packed backend: threads plus packing at tiny sizes.
    fn forced_parallel_packed() -> Packed {
        Packed { threads: 4, min_par_flops: 1 }
    }

    #[test]
    fn tiled_matches_reference_all_kernels() {
        prop::for_all("tiled == reference (nn/nt/tn)", 25, |rng| {
            let m = prop::int_in(rng, 1, 20);
            let k = prop::int_in(rng, 1, 24);
            let n = prop::int_in(rng, 1, 20);
            let a = Matrix::gaussian(m, k, 1.0, rng);
            let b = Matrix::gaussian(k, n, 1.0, rng);
            let bt = Matrix::gaussian(n, k, 1.0, rng);
            let at = Matrix::gaussian(k, m, 1.0, rng);
            for tiled in [Tiled::new(1), forced_parallel()] {
                assert_close(&tiled.gemm(&a, &b), &Reference.gemm(&a, &b),
                             1e-4, "nn");
                assert_close(&tiled.gemm_nt(&a, &bt),
                             &Reference.gemm_nt(&a, &bt), 1e-4, "nt");
                assert_close(&tiled.gemm_tn(&at, &b),
                             &Reference.gemm_tn(&at, &b), 1e-4, "tn");
            }
        });
    }

    #[test]
    fn transpose_free_variants_match_materialized_transpose() {
        prop::for_all("nt/tn == transpose+gemm", 25, |rng| {
            let m = prop::int_in(rng, 1, 16);
            let k = prop::int_in(rng, 1, 40);
            let n = prop::int_in(rng, 1, 16);
            let a = Matrix::gaussian(m, k, 1.0, rng);
            let bt = Matrix::gaussian(n, k, 1.0, rng);
            let at = Matrix::gaussian(k, m, 1.0, rng);
            let b = Matrix::gaussian(k, n, 1.0, rng);
            for bk in [&Reference as &dyn Backend, &Tiled::new(1),
                       &forced_parallel()] {
                assert_close(&bk.gemm_nt(&a, &bt),
                             &Reference.gemm(&a, &bt.transpose()), 1e-4,
                             "nt vs Bᵀ");
                assert_close(&bk.gemm_tn(&at, &b),
                             &Reference.gemm(&at.transpose(), &b), 1e-4,
                             "tn vs Aᵀ");
            }
        });
    }

    #[test]
    fn edge_shapes_one_row_one_col_empty() {
        let mut rng = Pcg64::new(9);
        // (1×n)·(n×1), (n×1)·(1×n), and every zero-dimension combination.
        let cases = [(1, 7, 1), (7, 1, 7), (1, 1, 1), (0, 5, 3), (3, 0, 4),
                     (4, 5, 0), (0, 0, 0)];
        for (m, k, n) in cases {
            let a = Matrix::gaussian(m, k, 1.0, &mut rng);
            let b = Matrix::gaussian(k, n, 1.0, &mut rng);
            let bt = Matrix::gaussian(n, k, 1.0, &mut rng);
            let at = Matrix::gaussian(k, m, 1.0, &mut rng);
            for bk in [&Reference as &dyn Backend, &Tiled::new(1),
                       &forced_parallel(), &Packed::new(1),
                       &forced_parallel_packed()] {
                let c = bk.gemm(&a, &b);
                assert_eq!((c.rows, c.cols), (m, n), "nn {m}x{k}x{n}");
                assert_close(&c, &Reference.gemm(&a, &b), 1e-5, "edge nn");
                assert_close(&bk.gemm_nt(&a, &bt),
                             &Reference.gemm_nt(&a, &bt), 1e-5, "edge nt");
                assert_close(&bk.gemm_tn(&at, &b),
                             &Reference.gemm_tn(&at, &b), 1e-5, "edge tn");
            }
            if k == 0 {
                // inner dimension 0 ⇒ exact zeros
                for bk in [&Tiled::new(1) as &dyn Backend, &Packed::new(1)] {
                    assert!(bk.gemm(&a, &b).data.iter().all(|v| *v == 0.0));
                }
            }
        }
    }

    #[test]
    fn packed_matches_reference_all_kernels() {
        // Dims up to 41 cross every remainder boundary of the packed
        // kernels: the 8-lane SIMD width, the MR=4 row block and the
        // NR=16 panel strip.
        prop::for_all("packed == reference (nn/nt/tn)", 30, |rng| {
            let m = prop::int_in(rng, 1, 41);
            let k = prop::int_in(rng, 1, 41);
            let n = prop::int_in(rng, 1, 41);
            let a = Matrix::gaussian(m, k, 1.0, rng);
            let b = Matrix::gaussian(k, n, 1.0, rng);
            let bt = Matrix::gaussian(n, k, 1.0, rng);
            let at = Matrix::gaussian(k, m, 1.0, rng);
            for packed in [Packed::new(1), forced_parallel_packed()] {
                assert_close(&packed.gemm(&a, &b), &Reference.gemm(&a, &b),
                             1e-4, "packed nn");
                assert_close(&packed.gemm_nt(&a, &bt),
                             &Reference.gemm_nt(&a, &bt), 1e-4, "packed nt");
                assert_close(&packed.gemm_tn(&at, &b),
                             &Reference.gemm_tn(&at, &b), 1e-4, "packed tn");
            }
        });
    }

    #[test]
    fn packed_remainder_boundaries_exact() {
        // Deterministic sweep across the exact block boundaries (±1):
        // SIMD width 8, MR=4, NR=16 — the shapes where an off-by-one in
        // the padding/remainder logic would bite.
        let mut rng = Pcg64::new(21);
        let dims = [1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33];
        for &m in &[3usize, 4, 5, 17] {
            for &k in &dims {
                for &n in &dims {
                    let a = Matrix::gaussian(m, k, 1.0, &mut rng);
                    let b = Matrix::gaussian(k, n, 1.0, &mut rng);
                    let bt = Matrix::gaussian(n, k, 1.0, &mut rng);
                    let ctx = format!("{m}x{k}x{n}");
                    assert_close(&Packed::new(1).gemm(&a, &b),
                                 &Reference.gemm(&a, &b), 1e-4,
                                 &format!("rem nn {ctx}"));
                    assert_close(&Packed::new(1).gemm_nt(&a, &bt),
                                 &Reference.gemm_nt(&a, &bt), 1e-4,
                                 &format!("rem nt {ctx}"));
                }
            }
        }
    }

    #[test]
    fn packed_crosses_kc_block_boundary() {
        // k around KC=256 (and 2×KC±1) exercises the multi-k-block
        // accumulation path of nn_body (which TN also runs, on the
        // transpose-packed A) — the path every paper shape (k ≥ 512)
        // runs but the small property dims never reach.
        let mut rng = Pcg64::new(29);
        for k in [255usize, 256, 257, 511, 513] {
            let (m, n) = (5, 19);
            let a = Matrix::gaussian(m, k, 1.0, &mut rng);
            let b = Matrix::gaussian(k, n, 1.0, &mut rng);
            let bt = Matrix::gaussian(n, k, 1.0, &mut rng);
            let at = Matrix::gaussian(k, m, 1.0, &mut rng);
            // tolerance scales with k: f32 dots of N(0,1) terms drift a
            // few ulps per hundred adds under reassociation/fusion
            let tol = 1e-4 * (k as f32 / 64.0).max(1.0);
            let ctx = format!("kc {m}x{k}x{n}");
            for packed in [Packed::new(1), forced_parallel_packed()] {
                assert_close(&packed.gemm(&a, &b), &Reference.gemm(&a, &b),
                             tol, &format!("{ctx} nn"));
                assert_close(&packed.gemm_nt(&a, &bt),
                             &Reference.gemm_nt(&a, &bt), tol,
                             &format!("{ctx} nt"));
                assert_close(&packed.gemm_tn(&at, &b),
                             &Reference.gemm_tn(&at, &b), tol,
                             &format!("{ctx} tn"));
            }
        }
    }

    #[test]
    fn grouped_nt_is_bit_identical_to_per_segment_calls() {
        // The fused-batching acceptance property: grouped output ==
        // composing today's per-adapter NT batches, to the bit, on both
        // the serial and the forced-parallel packed paths.  Layouts
        // cross chunk boundaries and include zero-length segments and
        // single-row tails (the Zipf-tail serving shape).
        let mut rng = Pcg64::new(31);
        let layouts: [&[usize]; 5] = [&[4], &[1, 1, 1, 1, 1], &[3, 0, 5],
                                      &[0, 0, 2], &[7, 1, 4, 9]];
        for segs in layouts {
            let m: usize = segs.iter().sum();
            let (k, n) = (13, 11);
            let a = Matrix::gaussian(m, k, 1.0, &mut rng);
            let bs: Vec<Matrix> = segs
                .iter()
                .map(|_| Matrix::gaussian(n, k, 1.0, &mut rng))
                .collect();
            let brefs: Vec<&Matrix> = bs.iter().collect();
            for packed in [Packed::new(1), forced_parallel_packed()] {
                let mut fused = Matrix::zeros(m, n);
                packed.gemm_grouped_nt_into(&a, &brefs, segs, &mut fused);
                let mut composed = Matrix::zeros(m, n);
                let mut row = 0;
                for (g, &rows) in segs.iter().enumerate() {
                    if rows == 0 {
                        continue;
                    }
                    let asub = Matrix::from_vec(
                        rows, k, a.data[row * k..(row + rows) * k].to_vec());
                    let mut osub = Matrix::zeros(rows, n);
                    packed.gemm_nt_into(&asub, &bs[g], &mut osub);
                    composed.data[row * n..(row + rows) * n]
                        .copy_from_slice(&osub.data);
                    row += rows;
                }
                for (i, (x, y)) in
                    fused.data.iter().zip(&composed.data).enumerate()
                {
                    assert_eq!(x.to_bits(), y.to_bits(),
                               "segs {segs:?} elem {i}: {x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn column_parallel_fanout_is_bit_identical_to_serial() {
        // Wide-short shapes (rows ≪ columns) take the column fan-out
        // under forced threading; every element must match the serial
        // kernel to the bit for nn/nt/tn (the fan-out only changes
        // which thread computes an element, never how).
        let mut rng = Pcg64::new(37);
        for &m in &[1usize, 2, 4, 5, 7] {
            for &(k, n) in &[(8usize, 200usize), (13, 65), (32, 129),
                             (9, 17)] {
                let a = Matrix::gaussian(m, k, 1.0, &mut rng);
                let b = Matrix::gaussian(k, n, 1.0, &mut rng);
                let bt = Matrix::gaussian(n, k, 1.0, &mut rng);
                let at = Matrix::gaussian(k, m, 1.0, &mut rng);
                let serial = Packed::new(1);
                let forced = forced_parallel_packed();
                let ctx = format!("{m}x{k}x{n}");
                for (want, have, tag) in [
                    (serial.gemm(&a, &b), forced.gemm(&a, &b), "nn"),
                    (serial.gemm_nt(&a, &bt), forced.gemm_nt(&a, &bt),
                     "nt"),
                    (serial.gemm_tn(&at, &b), forced.gemm_tn(&at, &b),
                     "tn"),
                ] {
                    for (i, (x, y)) in
                        want.data.iter().zip(&have.data).enumerate()
                    {
                        assert_eq!(x.to_bits(), y.to_bits(),
                                   "{ctx} {tag} elem {i}: {x} vs {y}");
                    }
                }
            }
        }
    }

    #[test]
    fn quant_nt_f32_payload_is_bit_identical_to_plain_nt() {
        // The default cache_quant="f32" policy must not perturb a
        // single bit of the existing serving math.
        let mut rng = Pcg64::new(51);
        let a = Matrix::gaussian(6, 24, 1.0, &mut rng);
        let b = Matrix::gaussian(19, 24, 1.0, &mut rng);
        let qb = QuantMat::from_arc(std::sync::Arc::new(b.clone()));
        for packed in [Packed::new(1), forced_parallel_packed()] {
            let mut want = Matrix::zeros(6, 19);
            packed.gemm_nt_into(&a, &b, &mut want);
            let mut have = Matrix::zeros(6, 19);
            packed.gemm_nt_quant_into(&a, &qb, &mut have);
            for (x, y) in want.data.iter().zip(&have.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn quant_nt_is_bit_identical_to_decode_reference_composition() {
        // The tentpole acceptance property at the GEMM level: the
        // pack-fused quantized product equals the quantize-then-
        // dequantize reference composition (an NN product against the
        // decoded transpose) to the bit, serial and threaded — the
        // pack images are identical and the micro-kernel is shared.
        let mut rng = Pcg64::new(53);
        let dims = [1usize, 3, 5, 15, 16, 17, 33];
        for &n in &dims {
            for &k in &[1usize, 9, 24, 40] {
                let a = Matrix::gaussian(5, k, 1.0, &mut rng);
                let b = Matrix::gaussian(n, k, 1.0, &mut rng);
                for kind in [quant::QuantKind::Bf16,
                             quant::QuantKind::Int8] {
                    let qb = QuantMat::encode(&b, kind);
                    let bt = qb.to_matrix_transposed(); // k×n decoded
                    for packed in [Packed::new(1),
                                   forced_parallel_packed()] {
                        let mut want = Matrix::zeros(5, n);
                        packed.gemm_into(&a, &bt, &mut want);
                        let mut have = Matrix::from_vec(
                            5, n, vec![8.0; 5 * n]);
                        packed.gemm_nt_quant_into(&a, &qb, &mut have);
                        for (i, (x, y)) in want
                            .data
                            .iter()
                            .zip(&have.data)
                            .enumerate()
                        {
                            assert_eq!(
                                x.to_bits(), y.to_bits(),
                                "{} {n}x{k} elem {i}: {x} vs {y}",
                                kind.name());
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn quant_nt_error_vs_f32_within_codec_tolerance() {
        // Accuracy (not bit) bound vs the unquantized product: bf16
        // carries ~2^-8 relative error per element, int8 per-panel
        // half-steps; both land well under these loose output bounds.
        let mut rng = Pcg64::new(57);
        let a = Matrix::gaussian(8, 48, 1.0, &mut rng);
        let b = Matrix::gaussian(21, 48, 1.0, &mut rng);
        let packed = Packed::new(1);
        let mut exact = Matrix::zeros(8, 21);
        packed.gemm_nt_into(&a, &b, &mut exact);
        for (kind, tol) in [(quant::QuantKind::Bf16, 0.05f64),
                            (quant::QuantKind::Int8, 0.15f64)] {
            let qb = QuantMat::encode(&b, kind);
            let mut got = Matrix::zeros(8, 21);
            packed.gemm_nt_quant_into(&a, &qb, &mut got);
            let num = got.sub(&exact).frobenius();
            let den = exact.frobenius().max(1e-12);
            assert!(num / den < tol, "{}: rel RMSE {}", kind.name(),
                    num / den);
        }
    }

    #[test]
    fn grouped_quant_matches_per_segment_calls_bitwise() {
        // Mixed-kind groups: every segment of the grouped sweep must
        // equal its standalone gemm_nt_quant_into to the bit (and the
        // all-F32 group must equal the fused f32 grouped sweep).
        let mut rng = Pcg64::new(59);
        let segs: &[usize] = &[3, 0, 5, 1];
        let m: usize = segs.iter().sum();
        let (k, n) = (13, 19);
        let a = Matrix::gaussian(m, k, 1.0, &mut rng);
        let kinds = [quant::QuantKind::F32, quant::QuantKind::Bf16,
                     quant::QuantKind::Int8, quant::QuantKind::Bf16];
        let bs: Vec<QuantMat> = kinds
            .iter()
            .map(|&kind| {
                QuantMat::encode(&Matrix::gaussian(n, k, 1.0, &mut rng),
                                 kind)
            })
            .collect();
        let brefs: Vec<&QuantMat> = bs.iter().collect();
        for packed in [Packed::new(1), forced_parallel_packed()] {
            let mut fused = Matrix::from_vec(m, n, vec![4.0; m * n]);
            packed.gemm_grouped_nt_quant_into(&a, &brefs, segs,
                                              &mut fused);
            let mut row = 0;
            for (g, &rows) in segs.iter().enumerate() {
                if rows == 0 {
                    continue;
                }
                let asub = Matrix::from_vec(
                    rows, k, a.data[row * k..(row + rows) * k].to_vec());
                let mut osub = Matrix::zeros(rows, n);
                packed.gemm_nt_quant_into(&asub, &bs[g], &mut osub);
                for (i, (x, y)) in fused.data
                    [row * n..(row + rows) * n]
                    .iter()
                    .zip(&osub.data)
                    .enumerate()
                {
                    assert_eq!(x.to_bits(), y.to_bits(),
                               "seg {g} elem {i}");
                }
                row += rows;
            }
        }
        // All-F32 groups ride the fused f32 sweep bit-identically.
        let f32s: Vec<QuantMat> = (0..segs.len())
            .map(|_| {
                QuantMat::encode(&Matrix::gaussian(n, k, 1.0, &mut rng),
                                 quant::QuantKind::F32)
            })
            .collect();
        let fq: Vec<&QuantMat> = f32s.iter().collect();
        let fm: Vec<&Matrix> = f32s
            .iter()
            .map(|q| q.as_f32().unwrap().as_ref())
            .collect();
        let packed = forced_parallel_packed();
        let mut want = Matrix::zeros(m, n);
        packed.gemm_grouped_nt_into(&a, &fm, segs, &mut want);
        let mut have = Matrix::zeros(m, n);
        packed.gemm_grouped_nt_quant_into(&a, &fq, segs, &mut have);
        for (x, y) in want.data.iter().zip(&have.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn grouped_nt_matches_reference_on_every_backend() {
        prop::for_all("grouped nt == composed nt", 15, |rng| {
            let g = prop::int_in(rng, 1, 4);
            let segs: Vec<usize> =
                (0..g).map(|_| prop::int_in(rng, 0, 6)).collect();
            let m: usize = segs.iter().sum();
            let k = prop::int_in(rng, 1, 12);
            let n = prop::int_in(rng, 1, 10);
            let a = Matrix::gaussian(m, k, 1.0, rng);
            let bs: Vec<Matrix> = segs
                .iter()
                .map(|_| Matrix::gaussian(n, k, 1.0, rng))
                .collect();
            let brefs: Vec<&Matrix> = bs.iter().collect();
            let mut want = Matrix::zeros(m, n);
            let mut row = 0;
            for (gi, &rows) in segs.iter().enumerate() {
                for i in 0..rows {
                    for j in 0..n {
                        let mut s = 0.0f32;
                        for kk in 0..k {
                            s += a.data[(row + i) * k + kk]
                                * bs[gi].data[j * k + kk];
                        }
                        want.data[(row + i) * n + j] = s;
                    }
                }
                row += rows;
            }
            for bk in [&Reference as &dyn Backend, &Tiled::new(1),
                       &forced_parallel(), &Packed::new(1),
                       &forced_parallel_packed()] {
                // stale output: every live row must be overwritten
                let mut out = Matrix::from_vec(m, n, vec![9.0; m * n]);
                bk.gemm_grouped_nt_into(&a, &brefs, &segs, &mut out);
                assert_close(&out, &want, 1e-4, "grouped nt");
            }
            let mut out = Matrix::zeros(m, n);
            gemm_grouped_nt_into(&a, &brefs, &segs, &mut out);
            assert_close(&out, &want, 1e-4, "grouped nt dispatch");
        });
    }

    #[test]
    fn panel_packing_is_allocation_free_after_warmup() {
        let mut rng = Pcg64::new(13);
        let bk = Packed::new(1);
        let a = Matrix::gaussian(23, 37, 1.0, &mut rng);
        let b = Matrix::gaussian(37, 29, 1.0, &mut rng);
        let at = Matrix::gaussian(37, 23, 1.0, &mut rng);
        let mut out = Matrix::zeros(23, 29);
        let run = |bk: &Packed, out: &mut Matrix| {
            bk.gemm_into(&a, &b, out);
            bk.gemm_tn_into(&at, &b, out);
        };
        run(&bk, &mut out); // warmup packs both operand shapes
        let warm = pack::pool_fresh_allocs();
        assert!(warm >= 1, "packing should have drawn from the pool");
        for _ in 0..10 {
            run(&bk, &mut out);
        }
        assert_eq!(pack::pool_fresh_allocs(), warm,
                   "steady-state panel packing must not allocate");
    }

    #[test]
    fn sparse_threaded_path_matches_dense() {
        let mut rng = Pcg64::new(17);
        // include all-zero rows (skipped wholesale by the row index)
        let mut y = Matrix::zeros(9, 11);
        for pos in rng.sample_indices(4 * 11, 13) {
            y.data[pos] = rng.normal() as f32; // rows 0..4 only
        }
        let b = Matrix::gaussian(11, 15, 1.0, &mut rng);
        let dense = Reference.gemm(&y, &b);
        // forced-threaded run (min_par_flops = 1)
        let mut out = Matrix::zeros(9, 15);
        sparse::sparse_left_run(&y, &b, &mut out, 4, 1);
        assert_close(&out, &dense, 1e-6, "threaded sparse vs dense");
        // serial path on the same operands
        let mut out2 = Matrix::zeros(9, 15);
        sparse::sparse_left_run(&y, &b, &mut out2, 1, usize::MAX);
        assert_close(&out2, &dense, 1e-6, "serial sparse vs dense");
    }

    #[test]
    fn into_variants_overwrite_stale_output() {
        let mut rng = Pcg64::new(4);
        let a = Matrix::gaussian(5, 6, 1.0, &mut rng);
        let b = Matrix::gaussian(6, 4, 1.0, &mut rng);
        let want = Reference.gemm(&a, &b);
        for bk in [&Reference as &dyn Backend, &forced_parallel(),
                   &Packed::new(1), &forced_parallel_packed()] {
            let mut out = Matrix::from_vec(5, 4, vec![7.5; 20]);
            bk.gemm_into(&a, &b, &mut out);
            assert_close(&out, &want, 1e-5, "stale nn");
        }
    }

    #[test]
    fn sparse_kernel_matches_dense_and_skips_zeros() {
        let mut rng = Pcg64::new(11);
        let mut y = Matrix::zeros(6, 8);
        for pos in rng.sample_indices(48, 9) {
            y.data[pos] = rng.normal() as f32;
        }
        let b = Matrix::gaussian(8, 10, 1.0, &mut rng);
        let dense = Reference.gemm(&y, &b);
        let sp = sparse::gemm_sparse_left(&y, &b);
        assert_close(&sp, &dense, 1e-6, "sparse vs dense");
        assert!(sparse::zero_fraction(&y) > 0.5);
        assert_eq!(sparse::zero_fraction(&Matrix::zeros(0, 0)), 0.0);
    }

    #[test]
    fn axpy_accumulates() {
        let x = vec![1.0f32, -2.0, 3.0];
        let mut y = vec![10.0f32, 10.0, 10.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, vec![10.5, 9.0, 11.5]);
    }

    #[test]
    fn workspace_is_allocation_free_after_warmup() {
        let mut ws = Workspace::new();
        let mut rng = Pcg64::new(2);
        let a = Matrix::gaussian(9, 7, 1.0, &mut rng);
        let b = Matrix::gaussian(7, 5, 1.0, &mut rng);
        let mut run = |ws: &mut Workspace| {
            let mut u = ws.take_matrix(9, 5);
            gemm_into(&a, &b, &mut u);
            let mut v = ws.take_matrix(5, 5);
            gemm_tn_into(&u, &u, &mut v);
            ws.recycle_matrix(u);
            ws.recycle_matrix(v);
        };
        run(&mut ws); // warmup
        let warm = ws.fresh_allocs();
        assert!(warm >= 1);
        for _ in 0..10 {
            run(&mut ws);
        }
        assert_eq!(ws.fresh_allocs(), warm, "steady state must not allocate");
        // and buffers come back zeroed
        let buf = ws.take(45);
        assert!(buf.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn selection_parses_and_dispatches() {
        assert_eq!(Kind::parse("tiled").unwrap(), Kind::Tiled);
        assert_eq!(Kind::parse("auto").unwrap(), Kind::Auto);
        assert_eq!(Kind::parse("REF").unwrap(), Kind::Reference);
        assert_eq!(Kind::parse("packed").unwrap(), Kind::Packed);
        assert!(Kind::parse("cuda").is_err());
        assert_eq!(Kind::from_u8(Kind::Reference.to_u8()), Kind::Reference);
        assert_eq!(Kind::from_u8(Kind::Tiled.to_u8()), Kind::Tiled);
        assert_eq!(Kind::from_u8(Kind::Packed.to_u8()), Kind::Packed);
        // NOTE: the global backend is deliberately NOT mutated here —
        // tests run in parallel and every other numeric test dispatches
        // through it.  Instead check that whatever is active agrees with
        // the reference baseline, which covers the dispatch plumbing.
        let mut rng = Pcg64::new(3);
        let a = Matrix::gaussian(4, 6, 1.0, &mut rng);
        let b = Matrix::gaussian(6, 3, 1.0, &mut rng);
        assert_close(&gemm(&a, &b), &Reference.gemm(&a, &b), 1e-5,
                     "global dispatch nn");
        let bt = Matrix::gaussian(3, 6, 1.0, &mut rng);
        assert_close(&gemm_nt(&a, &bt), &Reference.gemm_nt(&a, &bt), 1e-5,
                     "global dispatch nt");
        assert!(describe().contains(resolved_kind().name()), "{}",
                describe());
        assert!(describe().contains("simd="), "{}", describe());
    }
}
