// lint: hot-path
//! Portable wide-lane SIMD primitives for the packed backend.
//!
//! `std::simd` is nightly-only, so the vector type here is a plain
//! `[f32; 8]` wrapper ([`F32x8`]) whose per-lane loops LLVM reliably
//! turns into vector instructions — *provided* the enclosing function is
//! compiled with wide registers enabled.  That is what the runtime
//! dispatch below is for:
//!
//! * on `x86_64`, the hot kernels in `linalg::packed` exist twice — a
//!   portable body and an `#[target_feature(enable = "avx2", "fma")]`
//!   clone — and [`level`] picks the wide one at runtime when the CPU
//!   reports AVX2+FMA (`is_x86_feature_detected!`), independent of the
//!   build's baseline target (plain `x86-64` only guarantees SSE2);
//! * everywhere else (and under `COSA_SIMD=scalar`) the portable body
//!   runs and auto-vectorizes to whatever the build target allows
//!   (e.g. NEON on aarch64).
//!
//! The `FMA` const parameter on [`F32x8::fma`] selects between
//! `mul_add` (fused, one instruction when the `fma` feature is active)
//! and separate multiply+add: calling `f32::mul_add` without hardware
//! FMA falls back to a libm call, which is catastrophically slow, so the
//! scalar body must *not* use it.  Fusion changes results by less than
//! the property-test tolerance (it removes an intermediate rounding).

use std::sync::OnceLock;

/// Lane width every kernel is written against.
pub const LANES: usize = 8;

/// Runtime-selected instruction level for the packed kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// Portable body, build-target auto-vectorization only.
    Scalar,
    /// x86_64 AVX2 + FMA clone of the kernel body.
    Avx2Fma,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Avx2Fma => "avx2+fma",
        }
    }
}

fn detect() -> Level {
    if let Ok(v) = std::env::var("COSA_SIMD") {
        match v.to_ascii_lowercase().as_str() {
            "scalar" => return Level::Scalar,
            "auto" | "" => {}
            other => eprintln!(
                "warning: ignoring COSA_SIMD=`{other}` (scalar|auto)"
            ),
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return Level::Avx2Fma;
        }
    }
    Level::Scalar
}

/// The instruction level the packed kernels run at (cached; honors the
/// `COSA_SIMD=scalar|auto` override, read once at first use).
pub fn level() -> Level {
    static LEVEL: OnceLock<Level> = OnceLock::new();
    *LEVEL.get_or_init(detect)
}

/// Eight f32 lanes.  All methods are `#[inline(always)]` so they fold
/// into the (possibly `target_feature`-annotated) kernel bodies and
/// vectorize with that body's instruction set.
#[derive(Clone, Copy, Debug)]
pub struct F32x8(pub [f32; 8]);

impl F32x8 {
    pub const ZERO: F32x8 = F32x8([0.0; 8]);

    /// Load 8 lanes from the front of `s` (panics if `s.len() < 8`).
    #[inline(always)]
    pub fn load(s: &[f32]) -> F32x8 {
        let mut v = [0.0f32; 8];
        v.copy_from_slice(&s[..8]);
        F32x8(v)
    }

    #[inline(always)]
    pub fn splat(x: f32) -> F32x8 {
        F32x8([x; 8])
    }

    /// `self + a·b` per lane; fused when `FMA` (see module docs).
    #[inline(always)]
    pub fn fma<const FMA: bool>(self, a: F32x8, b: F32x8) -> F32x8 {
        let mut o = self.0;
        for t in 0..8 {
            o[t] = if FMA {
                a.0[t].mul_add(b.0[t], o[t])
            } else {
                o[t] + a.0[t] * b.0[t]
            };
        }
        F32x8(o)
    }

    /// `out[t] += self[t]` for the first 8 elements of `out`.
    #[inline(always)]
    pub fn accumulate_into(self, out: &mut [f32]) {
        for (o, v) in out[..8].iter_mut().zip(&self.0) {
            *o += *v;
        }
    }

    /// Pairwise horizontal sum (same reduction tree as the old `dot8`).
    #[inline(always)]
    pub fn hsum(self) -> f32 {
        let v = self.0;
        ((v[0] + v[4]) + (v[1] + v[5])) + ((v[2] + v[6]) + (v[3] + v[7]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_roundtrip_and_reduce() {
        let x = F32x8::load(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        assert_eq!(x.hsum(), 36.0);
        let y = F32x8::splat(2.0);
        assert_eq!(F32x8::ZERO.fma::<false>(x, y).hsum(), 72.0);
        assert_eq!(F32x8::ZERO.fma::<true>(x, y).hsum(), 72.0);
        let mut out = [1.0f32; 8];
        F32x8::splat(0.5).accumulate_into(&mut out);
        assert!(out.iter().all(|v| *v == 1.5));
    }

    #[test]
    fn level_is_cached_and_named() {
        let l = level();
        assert_eq!(l, level(), "level must be stable across calls");
        assert!(!l.name().is_empty());
    }
}
