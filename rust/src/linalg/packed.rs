// lint: hot-path
//! Packed backend: register-blocked micro-kernels over packed B panels
//! with runtime-selected wide-lane SIMD.
//!
//! What it adds over [`super::Tiled`]:
//!
//! * **Packed B panels** ([`super::pack`]): NN/TN stream B through
//!   [`NR`]-column strips packed contiguously in k, so the micro-kernel
//!   reads one dense 16-float line per k-step instead of striding across
//!   B's full row; strips are zero-padded, keeping the kernel branch-free
//!   at the column remainder.  Pack buffers come from a thread-local
//!   [`Workspace`](super::Workspace) pool — no fresh allocations after
//!   warmup.
//! * **Packed A for TN** ([`super::pack::pack_a_tn`]): the TN entry
//!   point transposes A once (blocked, on the dispatching thread) and
//!   then runs the NN micro-kernel on contiguous rows — the strided
//!   per-strip A-column reads of the old dedicated TN body are gone,
//!   and accumulation order (ascending k per KC-block) is unchanged,
//!   so results stay bit-identical.
//! * **Grouped (block-diagonal) NT** (`gemm_grouped_nt_into`): one
//!   activation batch against K per-segment B operands in a single
//!   thread fan-out — consecutive row segments of A each multiply
//!   their own B.  Because the NT kernel computes every output row
//!   from only its own A row, the fused sweep is bit-identical to K
//!   independent `gemm_nt_into` calls; it exists so the serving layer
//!   can fuse same-site rows from *different* adapters into one
//!   dispatch (one `plan_threads`, one scoped-thread spawn for the
//!   whole group instead of per adapter).
//! * **A register-blocked micro-kernel**: [`MR`]×[`NR`] outputs (4 rows ×
//!   two 8-lanes) accumulate entirely in registers across a [`KC`]-deep
//!   k-block before touching `out` — 8 independent accumulator vectors,
//!   one broadcast and two panel loads per (row, k) step.
//! * **Explicit SIMD with runtime dispatch** ([`super::simd`]): every hot
//!   body is compiled twice on x86_64 (portable + AVX2/FMA clone) and the
//!   level is chosen once per process at runtime; the portable body
//!   auto-vectorizes for the build target elsewhere.  The NT kernel
//!   replaces the old unrolled `dot8` with 8-lane loads and 4-way B-row
//!   blocking (each A-row load feeds four dot products).
//! * **Row- or column-parallelism**: the default fan-out is `Tiled`'s
//!   (scoped threads, disjoint output rows) — but row threading clamps
//!   to the row count, so wide-short outputs (a 4×3072 site product)
//!   used to run on 4 threads no matter how many cores exist.  When
//!   splitting the *column* dimension yields strictly more workers
//!   ([`run_nn`]/[`run_nt`]), each thread now runs the unchanged
//!   kernel over its own strip-aligned column block into a pool slab
//!   and the dispatcher scatters rows back — per-element arithmetic
//!   (and hence bits) is identical to the serial kernel because every
//!   output element's accumulation order never depends on which
//!   column block computes it.  Packing still happens once on the
//!   dispatching thread; workers share the panel read-only.
//! * **Quantized-source entries** ([`Packed::gemm_nt_quant_into`],
//!   [`Packed::gemm_grouped_nt_quant_into`]): bf16/int8 cache residents
//!   ([`super::quant::QuantMat`]) multiply through a pack-fused decode
//!   ([`super::pack::pack_b_nt_quant`]) — an NT product with quantized
//!   B becomes the NN micro-kernel over the decoded transpose's pack
//!   image, so the f32 kernels stay untouched and no full-size f32
//!   dequant buffer materializes.  F32 payloads delegate to the plain
//!   NT path, keeping the default serving pipeline bit-identical.
//!
//! Accumulation order per output element is ascending k within each
//! KC-block and blocks are added in order — a reassociation of the
//! reference fold, elementwise within the 1e-4 property tolerance
//! (`fma` fusion removes one rounding per multiply-add; see
//! `linalg::tests`).

use crate::linalg::pack::{self, NR};
use crate::linalg::quant::QuantMat;
use crate::linalg::simd::{self, F32x8};
use crate::linalg::tiled::{parallel_rows, plan_threads, DEFAULT_MIN_PAR_FLOPS};
use crate::linalg::{
    shape_grouped_nt, shape_nn, shape_nt, shape_tn, Backend,
};
use crate::math::matrix::Matrix;

/// Micro-kernel height (output rows held in registers).
pub const MR: usize = 4;
/// k-block depth: MR×KC of A (4 KiB) and KC×NR of packed B (16 KiB)
/// stay L1-resident under the accumulator pass.
const KC: usize = 256;
/// B-row block for the NT kernel (panel reused across all A rows).
const NT_JB: usize = 64;
/// B rows processed per A-row load in the NT inner kernel.
const NT_RB: usize = 4;

/// Packed micro-kernel backend (see module docs).
pub struct Packed {
    /// Worker thread count; 0 = auto (`available_parallelism`, capped).
    pub threads: usize,
    /// Multiply-add threshold below which the kernels stay serial.
    pub min_par_flops: usize,
}

impl Packed {
    pub fn new(threads: usize) -> Packed {
        Packed { threads, min_par_flops: DEFAULT_MIN_PAR_FLOPS }
    }
}

// ---------------------------------------------------------------------
// Kernel bodies.  Each is written once, generic over `FMA`, marked
// `#[inline(always)]` so it folds into the `#[target_feature]` clones
// below and vectorizes with their instruction set (see `simd` docs).
// There is no dedicated TN body: `gemm_tn_into` transposes A via
// `pack::pack_a_tn` and runs `nn_body` on the contiguous result.
// ---------------------------------------------------------------------

/// Accumulator spill: `out[i0..i0+mr) × [j0..j0+jw) += acc`.
#[inline(always)]
fn store_acc(
    acc: &[[F32x8; 2]; MR],
    out: &mut [f32],
    i0: usize,
    mr: usize,
    j0: usize,
    jw: usize,
    n: usize,
) {
    for r in 0..mr {
        let orow = &mut out[(i0 + r) * n + j0..(i0 + r) * n + j0 + jw];
        if jw == NR {
            acc[r][0].accumulate_into(&mut orow[..8]);
            acc[r][1].accumulate_into(&mut orow[8..16]);
        } else {
            let mut flat = [0.0f32; NR];
            flat[..8].copy_from_slice(&acc[r][0].0);
            flat[8..].copy_from_slice(&acc[r][1].0);
            for (o, v) in orow.iter_mut().zip(flat.iter()) {
                *o += *v;
            }
        }
    }
}

/// NN: `out = a · B` where `a` is `rows×k` (row-contiguous chunk) and B
/// is pre-packed `k×n`.
#[inline(always)]
fn nn_body<const FMA: bool>(
    a: &[f32],
    packed: &[f32],
    out: &mut [f32],
    rows: usize,
    k: usize,
    n: usize,
) {
    out.fill(0.0);
    let strips = n.div_ceil(NR);
    let mut kb = 0;
    while kb < k {
        let kend = (kb + KC).min(k);
        for s in 0..strips {
            let j0 = s * NR;
            let jw = NR.min(n - j0);
            let panel = &packed[(s * k + kb) * NR..(s * k + kend) * NR];
            let mut i0 = 0;
            while i0 < rows {
                let mr = MR.min(rows - i0);
                // A-row base offsets; bottom-edge padding lanes re-read
                // the block's first row (their results are discarded).
                let mut base = [0usize; MR];
                for (r, bo) in base.iter_mut().enumerate() {
                    *bo = (i0 + r.min(mr - 1)) * k;
                }
                let mut acc = [[F32x8::ZERO; 2]; MR];
                let mut p = 0;
                for kk in kb..kend {
                    let b0 = F32x8::load(&panel[p..p + 8]);
                    let b1 = F32x8::load(&panel[p + 8..p + 16]);
                    p += NR;
                    for r in 0..MR {
                        let av = F32x8::splat(a[base[r] + kk]);
                        acc[r][0] = acc[r][0].fma::<FMA>(av, b0);
                        acc[r][1] = acc[r][1].fma::<FMA>(av, b1);
                    }
                }
                store_acc(&acc, out, i0, mr, j0, jw, n);
                i0 += MR;
            }
        }
        kb = kend;
    }
}

/// 8-lane dot product (the SIMD successor of the old `dot8`).
#[inline(always)]
fn dot_body<const FMA: bool>(x: &[f32], y: &[f32]) -> f32 {
    let k = x.len().min(y.len());
    let mut acc = F32x8::ZERO;
    let mut kk = 0;
    while kk + 8 <= k {
        acc = acc
            .fma::<FMA>(F32x8::load(&x[kk..kk + 8]),
                        F32x8::load(&y[kk..kk + 8]));
        kk += 8;
    }
    let mut s = acc.hsum();
    for q in kk..k {
        s += x[q] * y[q];
    }
    s
}

/// NT: `out = a · bᵀ`, `a` rows×k (chunk), `b` n×k.  NT_JB-row B panels
/// are reused across all A rows; inside, each A-row load feeds NT_RB
/// independent dot accumulators.
#[inline(always)]
fn nt_body<const FMA: bool>(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: usize,
    k: usize,
    n: usize,
) {
    let mut jb = 0;
    while jb < n {
        let jend = (jb + NT_JB).min(n);
        for i in 0..rows {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            let mut j = jb;
            while j + NT_RB <= jend {
                let b0 = &b[j * k..(j + 1) * k];
                let b1 = &b[(j + 1) * k..(j + 2) * k];
                let b2 = &b[(j + 2) * k..(j + 3) * k];
                let b3 = &b[(j + 3) * k..(j + 4) * k];
                let mut acc = [F32x8::ZERO; NT_RB];
                let mut kk = 0;
                while kk + 8 <= k {
                    let av = F32x8::load(&arow[kk..kk + 8]);
                    acc[0] = acc[0]
                        .fma::<FMA>(av, F32x8::load(&b0[kk..kk + 8]));
                    acc[1] = acc[1]
                        .fma::<FMA>(av, F32x8::load(&b1[kk..kk + 8]));
                    acc[2] = acc[2]
                        .fma::<FMA>(av, F32x8::load(&b2[kk..kk + 8]));
                    acc[3] = acc[3]
                        .fma::<FMA>(av, F32x8::load(&b3[kk..kk + 8]));
                    kk += 8;
                }
                let mut sums =
                    [acc[0].hsum(), acc[1].hsum(), acc[2].hsum(),
                     acc[3].hsum()];
                for q in kk..k {
                    let av = arow[q];
                    sums[0] += av * b0[q];
                    sums[1] += av * b1[q];
                    sums[2] += av * b2[q];
                    sums[3] += av * b3[q];
                }
                orow[j..j + NT_RB].copy_from_slice(&sums);
                j += NT_RB;
            }
            while j < jend {
                orow[j] = dot_body::<FMA>(arow, &b[j * k..(j + 1) * k]);
                j += 1;
            }
        }
        jb = jend;
    }
}

// ---------------------------------------------------------------------
// Runtime dispatch: portable entry + AVX2/FMA clones (x86_64 only).
// The clones are `unsafe fn` because `#[target_feature]` requires the
// caller to guarantee the CPU supports the features — guaranteed here
// by `simd::level()`'s `is_x86_feature_detected!` probe.
// ---------------------------------------------------------------------

// SAFETY: callers must guarantee avx2+fma support — upheld at every
// call site by dispatching only when `simd::level()` probes Avx2Fma.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
unsafe fn nn_avx2fma(a: &[f32], packed: &[f32], out: &mut [f32],
                     rows: usize, k: usize, n: usize) {
    nn_body::<true>(a, packed, out, rows, k, n);
}

// SAFETY: callers must guarantee avx2+fma support — upheld at every
// call site by dispatching only when `simd::level()` probes Avx2Fma.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
unsafe fn nt_avx2fma(a: &[f32], b: &[f32], out: &mut [f32], rows: usize,
                     k: usize, n: usize) {
    nt_body::<true>(a, b, out, rows, k, n);
}

fn nn_kernel(a: &[f32], packed: &[f32], out: &mut [f32], rows: usize,
             k: usize, n: usize) {
    match simd::level() {
        #[cfg(target_arch = "x86_64")]
        simd::Level::Avx2Fma => unsafe {
            // SAFETY: level() returned Avx2Fma ⇒ CPU has avx2+fma.
            nn_avx2fma(a, packed, out, rows, k, n)
        },
        _ => nn_body::<false>(a, packed, out, rows, k, n),
    }
}

fn nt_kernel(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, k: usize,
             n: usize) {
    match simd::level() {
        #[cfg(target_arch = "x86_64")]
        simd::Level::Avx2Fma => unsafe {
            // SAFETY: level() returned Avx2Fma ⇒ CPU has avx2+fma.
            nt_avx2fma(a, b, out, rows, k, n)
        },
        _ => nt_body::<false>(a, b, out, rows, k, n),
    }
}

// ---------------------------------------------------------------------
// Fan-out planning: row-parallel by default, column-parallel for
// wide-short outputs.  Row threading clamps to `rows`, so a 4×3072
// product runs ≤4 threads however many cores exist; when a column
// split plans strictly more workers, each worker runs the *unchanged*
// kernel over its own column block — NN blocks are strip-aligned so a
// packed sub-range is itself a valid pack image; NT blocks are row
// ranges of B.  Workers write m×jw slabs drawn from the pack pool and
// the dispatcher scatters rows back into `out`.  Every output element
// is produced by the same kernel arithmetic on the same operand bytes
// regardless of the split, so results are bit-identical to the serial
// kernel (and therefore to the row-parallel fan-out).
// ---------------------------------------------------------------------

/// NN sweep `out(m×n) = a(m×k) · B` over a pre-packed `k×n` image,
/// choosing the fan-out (see above).  Also the engine of the TN and
/// quantized-NT entries, which reduce to NN over a packed operand.
fn run_nn(ad: &[f32], packed: &[f32], od: &mut [f32], m: usize, k: usize,
          n: usize, threads: usize, min_par_flops: usize) {
    let flops = m * k.max(1) * n;
    let nt = plan_threads(threads, min_par_flops, m, flops);
    let strips = n.div_ceil(NR);
    let ntc = plan_threads(threads, min_par_flops, strips, flops);
    if ntc <= nt {
        parallel_rows(od, m, n, nt, |row0, chunk| {
            let rows_here = chunk.len() / n;
            nn_kernel(&ad[row0 * k..(row0 + rows_here) * k], packed,
                      chunk, rows_here, k, n);
        });
        return;
    }
    // Column fan-out: cb columns per block, strip-aligned so each
    // block's packed sub-range is a self-contained pack image.
    let cb = strips.div_ceil(ntc) * NR;
    let nblocks = n.div_ceil(cb);
    pack::with_scratch(m * cb * nblocks, |slab| {
        parallel_rows(slab, nblocks, m * cb, nblocks, |blk, chunk| {
            let j0 = blk * cb;
            let jw = cb.min(n - j0);
            let s0 = j0 / NR;
            let sw = jw.div_ceil(NR);
            nn_kernel(ad, &packed[s0 * k * NR..(s0 + sw) * k * NR],
                      &mut chunk[..m * jw], m, k, jw);
        });
        for blk in 0..nblocks {
            let j0 = blk * cb;
            let jw = cb.min(n - j0);
            let chunk = &slab[blk * m * cb..blk * m * cb + m * jw];
            for i in 0..m {
                od[i * n + j0..i * n + j0 + jw]
                    .copy_from_slice(&chunk[i * jw..(i + 1) * jw]);
            }
        }
    });
}

/// NT sweep `out(rows×n) = a(rows×k) · b(n×k)ᵀ` choosing the fan-out
/// (column blocks are B-row ranges; see the planning comment above).
fn run_nt(ad: &[f32], bd: &[f32], od: &mut [f32], rows: usize, k: usize,
          n: usize, threads: usize, min_par_flops: usize) {
    let flops = rows * k.max(1) * n;
    let nt = plan_threads(threads, min_par_flops, rows, flops);
    let ntc = plan_threads(threads, min_par_flops, n, flops);
    if ntc <= nt {
        parallel_rows(od, rows, n, nt, |row0, chunk| {
            let rows_here = chunk.len() / n;
            nt_kernel(&ad[row0 * k..(row0 + rows_here) * k], bd, chunk,
                      rows_here, k, n);
        });
        return;
    }
    let cb = n.div_ceil(ntc);
    let nblocks = n.div_ceil(cb);
    pack::with_scratch(rows * cb * nblocks, |slab| {
        parallel_rows(slab, nblocks, rows * cb, nblocks, |blk, chunk| {
            let j0 = blk * cb;
            let jw = cb.min(n - j0);
            nt_kernel(ad, &bd[j0 * k..(j0 + jw) * k],
                      &mut chunk[..rows * jw], rows, k, jw);
        });
        for blk in 0..nblocks {
            let j0 = blk * cb;
            let jw = cb.min(n - j0);
            let chunk = &slab[blk * rows * cb..blk * rows * cb + rows * jw];
            for i in 0..rows {
                od[i * n + j0..i * n + j0 + jw]
                    .copy_from_slice(&chunk[i * jw..(i + 1) * jw]);
            }
        }
    });
}

impl Backend for Packed {
    fn name(&self) -> &'static str {
        "packed"
    }

    fn gemm_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix) {
        shape_nn(a, b, out);
        let (m, k, c) = (a.rows, a.cols, b.cols);
        if m == 0 || c == 0 {
            return;
        }
        if k == 0 {
            out.data.fill(0.0);
            return;
        }
        let (ad, bd) = (&a.data, &b.data);
        let od = &mut out.data;
        pack::with_packed_b(bd, k, c, |packed| {
            run_nn(ad, packed, od, m, k, c, self.threads,
                   self.min_par_flops);
        });
    }

    fn gemm_nt_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix) {
        shape_nt(a, b, out);
        let (m, k, n) = (a.rows, a.cols, b.rows);
        if m == 0 || n == 0 {
            return;
        }
        run_nt(&a.data, &b.data, &mut out.data, m, k, n, self.threads,
               self.min_par_flops);
    }

    fn gemm_tn_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix) {
        shape_tn(a, b, out);
        let (k, mo, n) = (a.rows, a.cols, b.cols);
        if mo == 0 || n == 0 {
            return;
        }
        if k == 0 {
            out.data.fill(0.0);
            return;
        }
        let (ad, bd) = (&a.data, &b.data);
        let od = &mut out.data;
        pack::with_packed_b(bd, k, n, |packed| {
            // Transpose A once into row-major mo×k; aᵀ·B on strided
            // columns becomes A'·B on contiguous rows — the NN kernel
            // verbatim, with identical accumulation order.
            pack::with_packed_a_tn(ad, k, mo, |at| {
                run_nn(at, packed, od, mo, k, n, self.threads,
                       self.min_par_flops);
            });
        });
    }

    fn gemm_grouped_nt_into(&self, a: &Matrix, bs: &[&Matrix],
                            segs: &[usize], out: &mut Matrix) {
        shape_grouped_nt(a, bs, segs, out);
        let (m, k) = (a.rows, a.cols);
        let n = out.cols;
        if m == 0 || n == 0 {
            return;
        }
        let nt = plan_threads(self.threads, self.min_par_flops, m,
                              m * k.max(1) * n);
        let mut starts = Vec::with_capacity(segs.len());
        let mut acc = 0usize;
        for &s in segs {
            starts.push(acc);
            acc += s;
        }
        let ad = &a.data;
        // One fan-out for the whole group.  Each chunk walks the
        // segments it overlaps; the NT kernel computes every output
        // row from only its own A row, so splitting a segment across
        // chunks (or fusing many segments into one sweep) is
        // bit-identical to per-segment gemm_nt_into calls.
        parallel_rows(&mut out.data, m, n, nt, |row0, chunk| {
            let rows_here = chunk.len() / n;
            let end = row0 + rows_here;
            let mut seg = starts.partition_point(|&s| s <= row0) - 1;
            let mut r = row0;
            while r < end {
                let seg_end = starts[seg] + segs[seg];
                if seg_end <= r {
                    seg += 1; // skip zero-length segments
                    continue;
                }
                let take = seg_end.min(end) - r;
                let co = (r - row0) * n;
                nt_kernel(&ad[r * k..(r + take) * k], &bs[seg].data,
                          &mut chunk[co..co + take * n], take, k, n);
                r += take;
            }
        });
    }
}

// ---------------------------------------------------------------------
// Quantized-source entries.  These live on `Packed` (not the Backend
// trait): quantized residents are a packed-backend feature — the pack
// step is where the up-convert fuses — and callers hold a concrete
// `Packed` on the serve path.  Other backends go through
// `QuantMat::to_matrix` at the call site (correctness-only fallback).
// ---------------------------------------------------------------------

impl Packed {
    /// `out = a · bᵀ` where `b` is a quantized `n×k` resident.
    ///
    /// * F32 payload → delegates to [`Backend::gemm_nt_into`] on the
    ///   wrapped matrix: the default `cache_quant = "f32"` policy is
    ///   bit-identical to the pre-quantization serving path.
    /// * bf16/int8 → the product is computed as `a · decode(b)ᵀ` via
    ///   the NN micro-kernel over a pack-fused decode
    ///   ([`pack::pack_b_nt_quant`]).  The pack image is bit-identical
    ///   to packing the decoded transpose, so the result matches the
    ///   regen→quantize→dequantize reference composition (an NN
    ///   product against [`QuantMat::to_matrix_transposed`]) to the
    ///   bit, at every thread count.
    pub fn gemm_nt_quant_into(&self, a: &Matrix, b: &QuantMat,
                              out: &mut Matrix) {
        assert_eq!(a.cols, b.cols(),
                   "gemm_nt_quant shape mismatch: ({}x{})·({}x{})ᵀ",
                   a.rows, a.cols, b.rows(), b.cols());
        assert_eq!((out.rows, out.cols), (a.rows, b.rows()),
                   "gemm_nt_quant out shape: have {}x{}, want {}x{}",
                   out.rows, out.cols, a.rows, b.rows());
        if let Some(bm) = b.as_f32() {
            self.gemm_nt_into(a, bm, out);
            return;
        }
        let (m, k, n) = (a.rows, a.cols, b.rows());
        if m == 0 || n == 0 {
            return;
        }
        if k == 0 {
            out.data.fill(0.0);
            return;
        }
        let ad = &a.data;
        let od = &mut out.data;
        pack::with_packed_b_nt_quant(b, |packed| {
            run_nn(ad, packed, od, m, k, n, self.threads,
                   self.min_par_flops);
        });
    }

    /// Grouped (block-diagonal) NT over quantized per-segment
    /// residents: row segment `g` of `a` multiplies `bs[g]ᵀ` into the
    /// matching rows of `out`.  An all-F32 group takes the fused
    /// [`Backend::gemm_grouped_nt_into`] sweep verbatim (bit-identical
    /// to the pre-quantization grouped path); otherwise segments run
    /// one at a time — quantized ones through the pack-fused NN route,
    /// F32 ones through the NT kernel — each bit-identical to its
    /// single-call [`Packed::gemm_nt_quant_into`] counterpart.  Pack
    /// scratch is pool-recycled across segments, so a steady-state
    /// grouped sweep stays allocation-free after warmup.
    pub fn gemm_grouped_nt_quant_into(&self, a: &Matrix,
                                      bs: &[&QuantMat], segs: &[usize],
                                      out: &mut Matrix) {
        assert_eq!(bs.len(), segs.len(),
                   "gemm_grouped_nt_quant: {} B operands vs {} segments",
                   bs.len(), segs.len());
        let total: usize = segs.iter().sum();
        assert_eq!(total, a.rows,
                   "gemm_grouped_nt_quant: segments cover {total} rows, \
                    a has {}",
                   a.rows);
        assert_eq!(out.rows, a.rows,
                   "gemm_grouped_nt_quant out rows: have {}, want {}",
                   out.rows, a.rows);
        let (k, n) = (a.cols, out.cols);
        for (g, b) in bs.iter().enumerate() {
            assert_eq!(b.cols(), k,
                       "gemm_grouped_nt_quant segment {g}: \
                        ({}x{k})·({}x{})ᵀ",
                       a.rows, b.rows(), b.cols());
            assert_eq!(b.rows(), n,
                       "gemm_grouped_nt_quant segment {g}: b has {} \
                        rows, out has {n} cols",
                       b.rows());
        }
        if bs.iter().all(|b| b.as_f32().is_some()) {
            let mut refs: Vec<&Matrix> = Vec::with_capacity(bs.len());
            for b in bs {
                if let Some(m) = b.as_f32() {
                    refs.push(m);
                }
            }
            self.gemm_grouped_nt_into(a, &refs, segs, out);
            return;
        }
        if n == 0 {
            return;
        }
        let mut row = 0usize;
        for (g, &rows) in segs.iter().enumerate() {
            if rows == 0 {
                continue;
            }
            let asub = &a.data[row * k..(row + rows) * k];
            let osub = &mut out.data[row * n..(row + rows) * n];
            match bs[g].as_f32() {
                Some(bm) => {
                    run_nt(asub, &bm.data, osub, rows, k, n,
                           self.threads, self.min_par_flops);
                }
                None if k == 0 => osub.fill(0.0),
                None => {
                    pack::with_packed_b_nt_quant(bs[g], |packed| {
                        run_nn(asub, packed, osub, rows, k, n,
                               self.threads, self.min_par_flops);
                    });
                }
            }
            row += rows;
        }
    }
}
