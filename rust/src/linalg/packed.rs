// lint: hot-path
//! Packed backend: register-blocked micro-kernels over packed B panels
//! with runtime-selected wide-lane SIMD.
//!
//! What it adds over [`super::Tiled`]:
//!
//! * **Packed B panels** ([`super::pack`]): NN/TN stream B through
//!   [`NR`]-column strips packed contiguously in k, so the micro-kernel
//!   reads one dense 16-float line per k-step instead of striding across
//!   B's full row; strips are zero-padded, keeping the kernel branch-free
//!   at the column remainder.  Pack buffers come from a thread-local
//!   [`Workspace`](super::Workspace) pool — no fresh allocations after
//!   warmup.
//! * **Packed A for TN** ([`super::pack::pack_a_tn`]): the TN entry
//!   point transposes A once (blocked, on the dispatching thread) and
//!   then runs the NN micro-kernel on contiguous rows — the strided
//!   per-strip A-column reads of the old dedicated TN body are gone,
//!   and accumulation order (ascending k per KC-block) is unchanged,
//!   so results stay bit-identical.
//! * **Grouped (block-diagonal) NT** (`gemm_grouped_nt_into`): one
//!   activation batch against K per-segment B operands in a single
//!   thread fan-out — consecutive row segments of A each multiply
//!   their own B.  Because the NT kernel computes every output row
//!   from only its own A row, the fused sweep is bit-identical to K
//!   independent `gemm_nt_into` calls; it exists so the serving layer
//!   can fuse same-site rows from *different* adapters into one
//!   dispatch (one `plan_threads`, one scoped-thread spawn for the
//!   whole group instead of per adapter).
//! * **A register-blocked micro-kernel**: [`MR`]×[`NR`] outputs (4 rows ×
//!   two 8-lanes) accumulate entirely in registers across a [`KC`]-deep
//!   k-block before touching `out` — 8 independent accumulator vectors,
//!   one broadcast and two panel loads per (row, k) step.
//! * **Explicit SIMD with runtime dispatch** ([`super::simd`]): every hot
//!   body is compiled twice on x86_64 (portable + AVX2/FMA clone) and the
//!   level is chosen once per process at runtime; the portable body
//!   auto-vectorizes for the build target elsewhere.  The NT kernel
//!   replaces the old unrolled `dot8` with 8-lane loads and 4-way B-row
//!   blocking (each A-row load feeds four dot products).
//! * **Row-parallelism** identical to `Tiled` (scoped threads, disjoint
//!   output rows, deterministic per thread count); packing happens once
//!   on the dispatching thread, workers share the panel read-only.
//!
//! Accumulation order per output element is ascending k within each
//! KC-block and blocks are added in order — a reassociation of the
//! reference fold, elementwise within the 1e-4 property tolerance
//! (`fma` fusion removes one rounding per multiply-add; see
//! `linalg::tests`).

use crate::linalg::pack::{self, NR};
use crate::linalg::simd::{self, F32x8};
use crate::linalg::tiled::{parallel_rows, plan_threads, DEFAULT_MIN_PAR_FLOPS};
use crate::linalg::{
    shape_grouped_nt, shape_nn, shape_nt, shape_tn, Backend,
};
use crate::math::matrix::Matrix;

/// Micro-kernel height (output rows held in registers).
pub const MR: usize = 4;
/// k-block depth: MR×KC of A (4 KiB) and KC×NR of packed B (16 KiB)
/// stay L1-resident under the accumulator pass.
const KC: usize = 256;
/// B-row block for the NT kernel (panel reused across all A rows).
const NT_JB: usize = 64;
/// B rows processed per A-row load in the NT inner kernel.
const NT_RB: usize = 4;

/// Packed micro-kernel backend (see module docs).
pub struct Packed {
    /// Worker thread count; 0 = auto (`available_parallelism`, capped).
    pub threads: usize,
    /// Multiply-add threshold below which the kernels stay serial.
    pub min_par_flops: usize,
}

impl Packed {
    pub fn new(threads: usize) -> Packed {
        Packed { threads, min_par_flops: DEFAULT_MIN_PAR_FLOPS }
    }
}

// ---------------------------------------------------------------------
// Kernel bodies.  Each is written once, generic over `FMA`, marked
// `#[inline(always)]` so it folds into the `#[target_feature]` clones
// below and vectorizes with their instruction set (see `simd` docs).
// There is no dedicated TN body: `gemm_tn_into` transposes A via
// `pack::pack_a_tn` and runs `nn_body` on the contiguous result.
// ---------------------------------------------------------------------

/// Accumulator spill: `out[i0..i0+mr) × [j0..j0+jw) += acc`.
#[inline(always)]
fn store_acc(
    acc: &[[F32x8; 2]; MR],
    out: &mut [f32],
    i0: usize,
    mr: usize,
    j0: usize,
    jw: usize,
    n: usize,
) {
    for r in 0..mr {
        let orow = &mut out[(i0 + r) * n + j0..(i0 + r) * n + j0 + jw];
        if jw == NR {
            acc[r][0].accumulate_into(&mut orow[..8]);
            acc[r][1].accumulate_into(&mut orow[8..16]);
        } else {
            let mut flat = [0.0f32; NR];
            flat[..8].copy_from_slice(&acc[r][0].0);
            flat[8..].copy_from_slice(&acc[r][1].0);
            for (o, v) in orow.iter_mut().zip(flat.iter()) {
                *o += *v;
            }
        }
    }
}

/// NN: `out = a · B` where `a` is `rows×k` (row-contiguous chunk) and B
/// is pre-packed `k×n`.
#[inline(always)]
fn nn_body<const FMA: bool>(
    a: &[f32],
    packed: &[f32],
    out: &mut [f32],
    rows: usize,
    k: usize,
    n: usize,
) {
    out.fill(0.0);
    let strips = n.div_ceil(NR);
    let mut kb = 0;
    while kb < k {
        let kend = (kb + KC).min(k);
        for s in 0..strips {
            let j0 = s * NR;
            let jw = NR.min(n - j0);
            let panel = &packed[(s * k + kb) * NR..(s * k + kend) * NR];
            let mut i0 = 0;
            while i0 < rows {
                let mr = MR.min(rows - i0);
                // A-row base offsets; bottom-edge padding lanes re-read
                // the block's first row (their results are discarded).
                let mut base = [0usize; MR];
                for (r, bo) in base.iter_mut().enumerate() {
                    *bo = (i0 + r.min(mr - 1)) * k;
                }
                let mut acc = [[F32x8::ZERO; 2]; MR];
                let mut p = 0;
                for kk in kb..kend {
                    let b0 = F32x8::load(&panel[p..p + 8]);
                    let b1 = F32x8::load(&panel[p + 8..p + 16]);
                    p += NR;
                    for r in 0..MR {
                        let av = F32x8::splat(a[base[r] + kk]);
                        acc[r][0] = acc[r][0].fma::<FMA>(av, b0);
                        acc[r][1] = acc[r][1].fma::<FMA>(av, b1);
                    }
                }
                store_acc(&acc, out, i0, mr, j0, jw, n);
                i0 += MR;
            }
        }
        kb = kend;
    }
}

/// 8-lane dot product (the SIMD successor of the old `dot8`).
#[inline(always)]
fn dot_body<const FMA: bool>(x: &[f32], y: &[f32]) -> f32 {
    let k = x.len().min(y.len());
    let mut acc = F32x8::ZERO;
    let mut kk = 0;
    while kk + 8 <= k {
        acc = acc
            .fma::<FMA>(F32x8::load(&x[kk..kk + 8]),
                        F32x8::load(&y[kk..kk + 8]));
        kk += 8;
    }
    let mut s = acc.hsum();
    for q in kk..k {
        s += x[q] * y[q];
    }
    s
}

/// NT: `out = a · bᵀ`, `a` rows×k (chunk), `b` n×k.  NT_JB-row B panels
/// are reused across all A rows; inside, each A-row load feeds NT_RB
/// independent dot accumulators.
#[inline(always)]
fn nt_body<const FMA: bool>(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: usize,
    k: usize,
    n: usize,
) {
    let mut jb = 0;
    while jb < n {
        let jend = (jb + NT_JB).min(n);
        for i in 0..rows {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            let mut j = jb;
            while j + NT_RB <= jend {
                let b0 = &b[j * k..(j + 1) * k];
                let b1 = &b[(j + 1) * k..(j + 2) * k];
                let b2 = &b[(j + 2) * k..(j + 3) * k];
                let b3 = &b[(j + 3) * k..(j + 4) * k];
                let mut acc = [F32x8::ZERO; NT_RB];
                let mut kk = 0;
                while kk + 8 <= k {
                    let av = F32x8::load(&arow[kk..kk + 8]);
                    acc[0] = acc[0]
                        .fma::<FMA>(av, F32x8::load(&b0[kk..kk + 8]));
                    acc[1] = acc[1]
                        .fma::<FMA>(av, F32x8::load(&b1[kk..kk + 8]));
                    acc[2] = acc[2]
                        .fma::<FMA>(av, F32x8::load(&b2[kk..kk + 8]));
                    acc[3] = acc[3]
                        .fma::<FMA>(av, F32x8::load(&b3[kk..kk + 8]));
                    kk += 8;
                }
                let mut sums =
                    [acc[0].hsum(), acc[1].hsum(), acc[2].hsum(),
                     acc[3].hsum()];
                for q in kk..k {
                    let av = arow[q];
                    sums[0] += av * b0[q];
                    sums[1] += av * b1[q];
                    sums[2] += av * b2[q];
                    sums[3] += av * b3[q];
                }
                orow[j..j + NT_RB].copy_from_slice(&sums);
                j += NT_RB;
            }
            while j < jend {
                orow[j] = dot_body::<FMA>(arow, &b[j * k..(j + 1) * k]);
                j += 1;
            }
        }
        jb = jend;
    }
}

// ---------------------------------------------------------------------
// Runtime dispatch: portable entry + AVX2/FMA clones (x86_64 only).
// The clones are `unsafe fn` because `#[target_feature]` requires the
// caller to guarantee the CPU supports the features — guaranteed here
// by `simd::level()`'s `is_x86_feature_detected!` probe.
// ---------------------------------------------------------------------

// SAFETY: callers must guarantee avx2+fma support — upheld at every
// call site by dispatching only when `simd::level()` probes Avx2Fma.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
unsafe fn nn_avx2fma(a: &[f32], packed: &[f32], out: &mut [f32],
                     rows: usize, k: usize, n: usize) {
    nn_body::<true>(a, packed, out, rows, k, n);
}

// SAFETY: callers must guarantee avx2+fma support — upheld at every
// call site by dispatching only when `simd::level()` probes Avx2Fma.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
unsafe fn nt_avx2fma(a: &[f32], b: &[f32], out: &mut [f32], rows: usize,
                     k: usize, n: usize) {
    nt_body::<true>(a, b, out, rows, k, n);
}

fn nn_kernel(a: &[f32], packed: &[f32], out: &mut [f32], rows: usize,
             k: usize, n: usize) {
    match simd::level() {
        #[cfg(target_arch = "x86_64")]
        simd::Level::Avx2Fma => unsafe {
            // SAFETY: level() returned Avx2Fma ⇒ CPU has avx2+fma.
            nn_avx2fma(a, packed, out, rows, k, n)
        },
        _ => nn_body::<false>(a, packed, out, rows, k, n),
    }
}

fn nt_kernel(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, k: usize,
             n: usize) {
    match simd::level() {
        #[cfg(target_arch = "x86_64")]
        simd::Level::Avx2Fma => unsafe {
            // SAFETY: level() returned Avx2Fma ⇒ CPU has avx2+fma.
            nt_avx2fma(a, b, out, rows, k, n)
        },
        _ => nt_body::<false>(a, b, out, rows, k, n),
    }
}

impl Backend for Packed {
    fn name(&self) -> &'static str {
        "packed"
    }

    fn gemm_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix) {
        shape_nn(a, b, out);
        let (m, k, c) = (a.rows, a.cols, b.cols);
        if m == 0 || c == 0 {
            return;
        }
        if k == 0 {
            out.data.fill(0.0);
            return;
        }
        let nt = plan_threads(self.threads, self.min_par_flops, m, m * k * c);
        let (ad, bd) = (&a.data, &b.data);
        let od = &mut out.data;
        pack::with_packed_b(bd, k, c, |packed| {
            parallel_rows(od, m, c, nt, |row0, chunk| {
                let rows_here = chunk.len() / c;
                nn_kernel(&ad[row0 * k..(row0 + rows_here) * k], packed,
                          chunk, rows_here, k, c);
            });
        });
    }

    fn gemm_nt_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix) {
        shape_nt(a, b, out);
        let (m, k, n) = (a.rows, a.cols, b.rows);
        if m == 0 || n == 0 {
            return;
        }
        let nt = plan_threads(self.threads, self.min_par_flops, m,
                              m * k.max(1) * n);
        let (ad, bd) = (&a.data, &b.data);
        parallel_rows(&mut out.data, m, n, nt, |row0, chunk| {
            let rows_here = chunk.len() / n;
            nt_kernel(&ad[row0 * k..(row0 + rows_here) * k], bd, chunk,
                      rows_here, k, n);
        });
    }

    fn gemm_tn_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix) {
        shape_tn(a, b, out);
        let (k, mo, n) = (a.rows, a.cols, b.cols);
        if mo == 0 || n == 0 {
            return;
        }
        if k == 0 {
            out.data.fill(0.0);
            return;
        }
        let nt = plan_threads(self.threads, self.min_par_flops, mo,
                              mo * k * n);
        let (ad, bd) = (&a.data, &b.data);
        let od = &mut out.data;
        pack::with_packed_b(bd, k, n, |packed| {
            // Transpose A once into row-major mo×k; aᵀ·B on strided
            // columns becomes A'·B on contiguous rows — the NN kernel
            // verbatim, with identical accumulation order.
            pack::with_packed_a_tn(ad, k, mo, |at| {
                parallel_rows(od, mo, n, nt, |row0, chunk| {
                    let rows_here = chunk.len() / n;
                    nn_kernel(&at[row0 * k..(row0 + rows_here) * k],
                              packed, chunk, rows_here, k, n);
                });
            });
        });
    }

    fn gemm_grouped_nt_into(&self, a: &Matrix, bs: &[&Matrix],
                            segs: &[usize], out: &mut Matrix) {
        shape_grouped_nt(a, bs, segs, out);
        let (m, k) = (a.rows, a.cols);
        let n = out.cols;
        if m == 0 || n == 0 {
            return;
        }
        let nt = plan_threads(self.threads, self.min_par_flops, m,
                              m * k.max(1) * n);
        let mut starts = Vec::with_capacity(segs.len());
        let mut acc = 0usize;
        for &s in segs {
            starts.push(acc);
            acc += s;
        }
        let ad = &a.data;
        // One fan-out for the whole group.  Each chunk walks the
        // segments it overlaps; the NT kernel computes every output
        // row from only its own A row, so splitting a segment across
        // chunks (or fusing many segments into one sweep) is
        // bit-identical to per-segment gemm_nt_into calls.
        parallel_rows(&mut out.data, m, n, nt, |row0, chunk| {
            let rows_here = chunk.len() / n;
            let end = row0 + rows_here;
            let mut seg = starts.partition_point(|&s| s <= row0) - 1;
            let mut r = row0;
            while r < end {
                let seg_end = starts[seg] + segs[seg];
                if seg_end <= r {
                    seg += 1; // skip zero-length segments
                    continue;
                }
                let take = seg_end.min(end) - r;
                let co = (r - row0) * n;
                nt_kernel(&ad[r * k..(r + take) * k], &bs[seg].data,
                          &mut chunk[co..co + take * n], take, k, n);
                r += take;
            }
        });
    }
}
