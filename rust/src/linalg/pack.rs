// lint: hot-path
//! Operand packing for the packed micro-kernel backend.
//!
//! The NN/TN micro-kernels in [`super::packed`] read B through
//! [`NR`]-column strips laid out contiguously in k: strip `s` holds
//! columns `[s·NR, s·NR + NR)` of B as `k` consecutive NR-wide rows,
//! zero-padded on the right edge.  One pack pass rewrites the whole
//! `k×n` operand; the micro-kernel then streams each strip linearly
//! (one cache line every other k-step) instead of striding across B's
//! full row width, and the zero padding lets the kernel stay branch-free
//! at the column remainder.
//!
//! The TN kernel additionally packs its A operand ([`pack_a_tn`]): a
//! `k×mo` A is transposed once into a row-major `mo×k` image, after
//! which `aᵀ·B` is exactly `A'·B` on contiguous rows and the whole TN
//! entry point reuses the NN micro-kernel.  The old TN body read an
//! A *column* per output row — `mo`-strided loads repeated for every
//! NR-column strip of B — while the one-time blocked transpose touches
//! each A element once and every kernel read after it is dense.
//!
//! ## Quantized sources
//!
//! The bf16/int8 cache residents ([`crate::linalg::quant::QuantMat`])
//! are consumed through pack variants that fuse the SIMD up-convert
//! into the pack pass: [`pack_b_nt_quant`] reads a quantized NT
//! operand (`n×k`) row by row — each row decoded contiguously into a
//! pool scratch line, then scattered into the strip lanes of the
//! standard NN layout — and [`pack_a_tn_quant`] decodes block-rows
//! before the blocked transpose.  Both produce images bit-identical to
//! packing the decoded matrix, so the downstream f32 micro-kernels and
//! their accumulation order are untouched, and no full-size f32 image
//! of a quantized operand ever materializes.
//!
//! ## Allocation contract
//!
//! Pack buffers come from a **thread-local [`Workspace`] pool**, so a
//! steady-state loop of packed products performs no fresh allocations
//! after its first iteration — the same arena contract the `*_into`
//! kernels make for outputs, extended to the packing scratch.  The pool
//! is thread-local because only the dispatching thread packs (worker
//! threads of a parallel product share the packed panel read-only);
//! [`pool_fresh_allocs`] exposes the counter the steady-state test
//! asserts on.

use std::cell::RefCell;

use crate::linalg::quant::QuantMat;
use crate::linalg::Workspace;

/// Strip width (columns) — two 8-lane registers per micro-kernel row.
pub const NR: usize = 16;

thread_local! {
    static PACK_POOL: RefCell<Workspace> = RefCell::new(Workspace::new());
}

/// Fresh allocations performed by this thread's pack pool (flat across
/// iterations ⇒ packing is allocation-free after warmup).
pub fn pool_fresh_allocs() -> usize {
    PACK_POOL.with(|ws| ws.borrow().fresh_allocs())
}

/// Length of the packed image of a `k×n` operand.
pub fn packed_len(k: usize, n: usize) -> usize {
    n.div_ceil(NR) * k * NR
}

/// Pack row-major `b` (`k×n`) into NR-column strips (see module docs).
/// `packed` must hold at least [`packed_len`]`(k, n)` elements.
pub fn pack_b(b: &[f32], k: usize, n: usize, packed: &mut [f32]) {
    let strips = n.div_ceil(NR);
    assert!(packed.len() >= strips * k * NR, "pack buffer too small");
    for s in 0..strips {
        let j0 = s * NR;
        let jw = NR.min(n - j0);
        for kk in 0..k {
            let dst = &mut packed[(s * k + kk) * NR..(s * k + kk + 1) * NR];
            dst[..jw].copy_from_slice(&b[kk * n + j0..kk * n + j0 + jw]);
            // right-edge padding — REQUIRED: buffers arrive with stale
            // contents (scratch draw), the kernel multiplies these lanes
            for d in dst[jw..].iter_mut() {
                *d = 0.0;
            }
        }
    }
}

/// Run `f` against the packed image of `b`, drawing and returning the
/// buffer from the thread-local pool.  The borrow is released before
/// `f` runs, so nested packed products are fine.
pub fn with_packed_b<R>(
    b: &[f32],
    k: usize,
    n: usize,
    f: impl FnOnce(&[f32]) -> R,
) -> R {
    // Scratch (non-zeroed) draw: pack_b writes every element of the
    // packed image, padding included, so take's zeroing pass would be a
    // redundant full memset on the GEMM hot path.
    let mut buf =
        PACK_POOL.with(|ws| ws.borrow_mut().take_scratch(packed_len(k, n)));
    pack_b(b, k, n, &mut buf);
    let r = f(&buf);
    PACK_POOL.with(|ws| ws.borrow_mut().recycle(buf));
    r
}

/// Transpose row-major `a` (`k×mo`) into row-major `at` (`mo×k`) so the
/// TN kernel can run the NN micro-kernel on contiguous rows.  Blocked
/// 32×32 so both the source rows and the destination rows stay
/// cache-resident across a block.  `at` must hold at least `k·mo`
/// elements; every element is written (scratch draws are fine).
pub fn pack_a_tn(a: &[f32], k: usize, mo: usize, at: &mut [f32]) {
    assert!(at.len() >= k * mo, "pack buffer too small");
    const TB: usize = 32;
    let mut i0 = 0;
    while i0 < k {
        let iend = (i0 + TB).min(k);
        let mut j0 = 0;
        while j0 < mo {
            let jend = (j0 + TB).min(mo);
            for i in i0..iend {
                for j in j0..jend {
                    at[j * k + i] = a[i * mo + j];
                }
            }
            j0 = jend;
        }
        i0 = iend;
    }
}

/// Run `f` against the transposed image of `a` (`k×mo` → row-major
/// `mo×k`), drawing and returning the buffer from the thread-local
/// pool.  Nests inside [`with_packed_b`] (the borrow is released
/// before `f` runs).
pub fn with_packed_a_tn<R>(
    a: &[f32],
    k: usize,
    mo: usize,
    f: impl FnOnce(&[f32]) -> R,
) -> R {
    // Scratch draw: pack_a_tn writes all k·mo elements.
    let mut buf = PACK_POOL.with(|ws| ws.borrow_mut().take_scratch(k * mo));
    pack_a_tn(a, k, mo, &mut buf);
    let r = f(&buf);
    PACK_POOL.with(|ws| ws.borrow_mut().recycle(buf));
    r
}

/// Pack a **quantized NT operand** `b` (`n×k`: each row is one dot
/// operand) into the standard NN strip layout of its transpose (`k×n`),
/// decoding on the fly: row `j` is up-converted contiguously into a
/// pool scratch line (SIMD — see `quant`), then scattered into lane
/// `j−j0` of strip `j0/NR`.  The image is bit-identical to
/// `pack_b(decode(b)ᵀ)`, so the NN micro-kernel consumes it verbatim.
pub fn pack_b_nt_quant(b: &QuantMat, packed: &mut [f32]) {
    let (n, k) = (b.rows(), b.cols());
    let strips = n.div_ceil(NR);
    assert!(packed.len() >= strips * k * NR, "pack buffer too small");
    let mut rowbuf = PACK_POOL.with(|ws| ws.borrow_mut().take_scratch(k));
    for s in 0..strips {
        let j0 = s * NR;
        let jw = NR.min(n - j0);
        let strip = &mut packed[s * k * NR..(s + 1) * k * NR];
        for lane in 0..jw {
            b.dequantize_row_into(j0 + lane, &mut rowbuf);
            for (kk, &v) in rowbuf[..k].iter().enumerate() {
                strip[kk * NR + lane] = v;
            }
        }
        // right-edge padding — REQUIRED: buffers arrive with stale
        // contents (scratch draw), the kernel multiplies these lanes
        for lane in jw..NR {
            for kk in 0..k {
                strip[kk * NR + lane] = 0.0;
            }
        }
    }
    PACK_POOL.with(|ws| ws.borrow_mut().recycle(rowbuf));
}

/// Run `f` against the packed image of a quantized NT operand
/// (see [`pack_b_nt_quant`]); buffer from the thread-local pool.
pub fn with_packed_b_nt_quant<R>(
    b: &QuantMat,
    f: impl FnOnce(&[f32]) -> R,
) -> R {
    let (n, k) = (b.rows(), b.cols());
    // Scratch draw: pack_b_nt_quant writes every element, pad included.
    let mut buf =
        PACK_POOL.with(|ws| ws.borrow_mut().take_scratch(packed_len(k, n)));
    pack_b_nt_quant(b, &mut buf);
    let r = f(&buf);
    PACK_POOL.with(|ws| ws.borrow_mut().recycle(buf));
    r
}

/// Quantized-source variant of [`pack_a_tn`]: decode `a` (`k×mo`) a
/// block of `TB` rows at a time into pool scratch (contiguous SIMD
/// up-convert), then run the same blocked transpose into `at`
/// (`mo×k`).  Bit-identical to `pack_a_tn(decode(a))` without ever
/// holding more than `TB` decoded rows.
pub fn pack_a_tn_quant(a: &QuantMat, at: &mut [f32]) {
    let (k, mo) = (a.rows(), a.cols());
    assert!(at.len() >= k * mo, "pack buffer too small");
    const TB: usize = 32;
    let mut block =
        PACK_POOL.with(|ws| ws.borrow_mut().take_scratch(TB * mo));
    let mut i0 = 0;
    while i0 < k {
        let iend = (i0 + TB).min(k);
        for i in i0..iend {
            a.dequantize_row_into(
                i, &mut block[(i - i0) * mo..(i - i0) * mo + mo]);
        }
        let mut j0 = 0;
        while j0 < mo {
            let jend = (j0 + TB).min(mo);
            for i in i0..iend {
                for j in j0..jend {
                    at[j * k + i] = block[(i - i0) * mo + j];
                }
            }
            j0 = jend;
        }
        i0 = iend;
    }
    PACK_POOL.with(|ws| ws.borrow_mut().recycle(block));
}

/// Run `f` against the transposed image of a quantized TN operand
/// (see [`pack_a_tn_quant`]); buffer from the thread-local pool.
pub fn with_packed_a_tn_quant<R>(
    a: &QuantMat,
    f: impl FnOnce(&[f32]) -> R,
) -> R {
    // Scratch draw: pack_a_tn_quant writes all k·mo elements.
    let mut buf = PACK_POOL
        .with(|ws| ws.borrow_mut().take_scratch(a.rows() * a.cols()));
    pack_a_tn_quant(a, &mut buf);
    let r = f(&buf);
    PACK_POOL.with(|ws| ws.borrow_mut().recycle(buf));
    r
}

/// Run `f` on a pool-backed scratch slice of `len` **unspecified**
/// elements (callers must overwrite whatever they read).  The packed
/// backend's column fan-out uses this for its per-thread output slabs.
pub fn with_scratch<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    let mut buf = PACK_POOL.with(|ws| ws.borrow_mut().take_scratch(len));
    let r = f(&mut buf);
    PACK_POOL.with(|ws| ws.borrow_mut().recycle(buf));
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packs_strips_with_zero_padding() {
        // 3×5 matrix, NR=16 ⇒ one strip, 11 padded columns per k-row
        let b: Vec<f32> = (0..15).map(|v| v as f32 + 1.0).collect();
        let mut packed = vec![7.0f32; packed_len(3, 5)];
        pack_b(&b, 3, 5, &mut packed);
        for kk in 0..3 {
            let row = &packed[kk * NR..(kk + 1) * NR];
            assert_eq!(&row[..5], &b[kk * 5..kk * 5 + 5], "k-row {kk}");
            assert!(row[5..].iter().all(|v| *v == 0.0), "padding {kk}");
        }
    }

    #[test]
    fn multi_strip_layout_is_contiguous_in_k() {
        // 2×20 ⇒ two strips; strip 1 holds columns 16..20
        let b: Vec<f32> = (0..40).map(|v| v as f32).collect();
        let mut packed = vec![0.0f32; packed_len(2, 20)];
        pack_b(&b, 2, 20, &mut packed);
        let s1 = &packed[2 * NR..]; // strip 1: k rows of NR
        assert_eq!(&s1[..4], &b[16..20]);
        assert_eq!(&s1[NR..NR + 4], &b[36..40]);
    }

    #[test]
    fn a_transpose_pack_is_exact_at_odd_shapes() {
        // shapes crossing the 32-block boundary in both dimensions
        for (k, mo) in [(1usize, 1usize), (3, 5), (31, 33), (40, 64),
                        (65, 7)] {
            let a: Vec<f32> = (0..k * mo).map(|v| v as f32).collect();
            let mut at = vec![-1.0f32; k * mo];
            pack_a_tn(&a, k, mo, &mut at);
            for i in 0..k {
                for j in 0..mo {
                    assert_eq!(at[j * k + i], a[i * mo + j],
                               "({k}x{mo}) at [{i},{j}]");
                }
            }
        }
    }

    #[test]
    fn a_transpose_pool_reuses_buffers_after_warmup() {
        let a = vec![2.0f32; 24 * 24];
        with_packed_a_tn(&a, 24, 24, |at| assert_eq!(at.len(), 24 * 24));
        let warm = pool_fresh_allocs();
        for _ in 0..8 {
            with_packed_a_tn(&a, 24, 24, |at| {
                assert_eq!(at[0], 2.0);
            });
        }
        assert_eq!(pool_fresh_allocs(), warm, "steady-state pack allocated");
    }

    #[test]
    fn pool_reuses_buffers_after_warmup() {
        let b = vec![1.0f32; 24 * 24];
        with_packed_b(&b, 24, 24, |p| assert_eq!(p.len(), packed_len(24, 24)));
        let warm = pool_fresh_allocs();
        for _ in 0..8 {
            with_packed_b(&b, 24, 24, |p| {
                assert_eq!(p[0], 1.0);
            });
        }
        assert_eq!(pool_fresh_allocs(), warm, "steady-state pack allocated");
    }

    #[test]
    fn quant_nt_pack_image_matches_pack_b_of_decoded_transpose() {
        use crate::linalg::quant::{QuantKind, QuantMat};
        use crate::math::matrix::Matrix;
        use crate::math::rng::Pcg64;
        let mut rng = Pcg64::new(41);
        // shapes crossing the NR strip boundary and the odd-k edge
        for (n, k) in [(1usize, 1usize), (5, 3), (16, 8), (17, 9),
                       (33, 40)] {
            let b = Matrix::gaussian(n, k, 1.0, &mut rng);
            for kind in [QuantKind::F32, QuantKind::Bf16, QuantKind::Int8]
            {
                let qm = QuantMat::encode(&b, kind);
                let mut img = vec![7.0f32; packed_len(k, n)];
                pack_b_nt_quant(&qm, &mut img);
                // reference: decode, transpose, pack with the f32 path
                let bt = qm.to_matrix_transposed(); // k×n
                let mut want = vec![9.0f32; packed_len(k, n)];
                pack_b(&bt.data, k, n, &mut want);
                for (i, (x, y)) in img.iter().zip(&want).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(),
                               "{} ({n}x{k}) packed[{i}]: {x} vs {y}",
                               kind.name());
                }
            }
        }
    }

    #[test]
    fn quant_a_tn_pack_matches_f32_pack_of_decoded() {
        use crate::linalg::quant::{QuantKind, QuantMat};
        use crate::math::matrix::Matrix;
        use crate::math::rng::Pcg64;
        let mut rng = Pcg64::new(43);
        // shapes crossing the 32-row decode/transpose block
        for (k, mo) in [(1usize, 1usize), (3, 5), (31, 33), (40, 64)] {
            let a = Matrix::gaussian(k, mo, 1.0, &mut rng);
            for kind in [QuantKind::F32, QuantKind::Bf16, QuantKind::Int8]
            {
                let qm = QuantMat::encode(&a, kind);
                let mut at = vec![-1.0f32; k * mo];
                pack_a_tn_quant(&qm, &mut at);
                let dec = qm.to_matrix();
                let mut want = vec![-2.0f32; k * mo];
                pack_a_tn(&dec.data, k, mo, &mut want);
                for (i, (x, y)) in at.iter().zip(&want).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(),
                               "{} ({k}x{mo}) at[{i}]", kind.name());
                }
            }
        }
    }

    #[test]
    fn quant_pack_pool_reuses_buffers_after_warmup() {
        use crate::linalg::quant::{QuantKind, QuantMat};
        use crate::math::matrix::Matrix;
        use crate::math::rng::Pcg64;
        let mut rng = Pcg64::new(47);
        let qm = QuantMat::encode(&Matrix::gaussian(24, 24, 1.0, &mut rng),
                                  QuantKind::Bf16);
        with_packed_b_nt_quant(&qm, |p| {
            assert_eq!(p.len(), packed_len(24, 24));
        });
        with_packed_a_tn_quant(&qm, |at| assert_eq!(at.len(), 24 * 24));
        let warm = pool_fresh_allocs();
        for _ in 0..8 {
            with_packed_b_nt_quant(&qm, |p| assert!(p[0].is_finite()));
            with_packed_a_tn_quant(&qm, |at| assert!(at[0].is_finite()));
        }
        assert_eq!(pool_fresh_allocs(), warm, "steady-state pack allocated");
    }
}
