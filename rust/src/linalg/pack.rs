// lint: hot-path
//! Operand packing for the packed micro-kernel backend.
//!
//! The NN/TN micro-kernels in [`super::packed`] read B through
//! [`NR`]-column strips laid out contiguously in k: strip `s` holds
//! columns `[s·NR, s·NR + NR)` of B as `k` consecutive NR-wide rows,
//! zero-padded on the right edge.  One pack pass rewrites the whole
//! `k×n` operand; the micro-kernel then streams each strip linearly
//! (one cache line every other k-step) instead of striding across B's
//! full row width, and the zero padding lets the kernel stay branch-free
//! at the column remainder.
//!
//! The TN kernel additionally packs its A operand ([`pack_a_tn`]): a
//! `k×mo` A is transposed once into a row-major `mo×k` image, after
//! which `aᵀ·B` is exactly `A'·B` on contiguous rows and the whole TN
//! entry point reuses the NN micro-kernel.  The old TN body read an
//! A *column* per output row — `mo`-strided loads repeated for every
//! NR-column strip of B — while the one-time blocked transpose touches
//! each A element once and every kernel read after it is dense.
//!
//! ## Allocation contract
//!
//! Pack buffers come from a **thread-local [`Workspace`] pool**, so a
//! steady-state loop of packed products performs no fresh allocations
//! after its first iteration — the same arena contract the `*_into`
//! kernels make for outputs, extended to the packing scratch.  The pool
//! is thread-local because only the dispatching thread packs (worker
//! threads of a parallel product share the packed panel read-only);
//! [`pool_fresh_allocs`] exposes the counter the steady-state test
//! asserts on.

use std::cell::RefCell;

use crate::linalg::Workspace;

/// Strip width (columns) — two 8-lane registers per micro-kernel row.
pub const NR: usize = 16;

thread_local! {
    static PACK_POOL: RefCell<Workspace> = RefCell::new(Workspace::new());
}

/// Fresh allocations performed by this thread's pack pool (flat across
/// iterations ⇒ packing is allocation-free after warmup).
pub fn pool_fresh_allocs() -> usize {
    PACK_POOL.with(|ws| ws.borrow().fresh_allocs())
}

/// Length of the packed image of a `k×n` operand.
pub fn packed_len(k: usize, n: usize) -> usize {
    n.div_ceil(NR) * k * NR
}

/// Pack row-major `b` (`k×n`) into NR-column strips (see module docs).
/// `packed` must hold at least [`packed_len`]`(k, n)` elements.
pub fn pack_b(b: &[f32], k: usize, n: usize, packed: &mut [f32]) {
    let strips = n.div_ceil(NR);
    assert!(packed.len() >= strips * k * NR, "pack buffer too small");
    for s in 0..strips {
        let j0 = s * NR;
        let jw = NR.min(n - j0);
        for kk in 0..k {
            let dst = &mut packed[(s * k + kk) * NR..(s * k + kk + 1) * NR];
            dst[..jw].copy_from_slice(&b[kk * n + j0..kk * n + j0 + jw]);
            // right-edge padding — REQUIRED: buffers arrive with stale
            // contents (scratch draw), the kernel multiplies these lanes
            for d in dst[jw..].iter_mut() {
                *d = 0.0;
            }
        }
    }
}

/// Run `f` against the packed image of `b`, drawing and returning the
/// buffer from the thread-local pool.  The borrow is released before
/// `f` runs, so nested packed products are fine.
pub fn with_packed_b<R>(
    b: &[f32],
    k: usize,
    n: usize,
    f: impl FnOnce(&[f32]) -> R,
) -> R {
    // Scratch (non-zeroed) draw: pack_b writes every element of the
    // packed image, padding included, so take's zeroing pass would be a
    // redundant full memset on the GEMM hot path.
    let mut buf =
        PACK_POOL.with(|ws| ws.borrow_mut().take_scratch(packed_len(k, n)));
    pack_b(b, k, n, &mut buf);
    let r = f(&buf);
    PACK_POOL.with(|ws| ws.borrow_mut().recycle(buf));
    r
}

/// Transpose row-major `a` (`k×mo`) into row-major `at` (`mo×k`) so the
/// TN kernel can run the NN micro-kernel on contiguous rows.  Blocked
/// 32×32 so both the source rows and the destination rows stay
/// cache-resident across a block.  `at` must hold at least `k·mo`
/// elements; every element is written (scratch draws are fine).
pub fn pack_a_tn(a: &[f32], k: usize, mo: usize, at: &mut [f32]) {
    assert!(at.len() >= k * mo, "pack buffer too small");
    const TB: usize = 32;
    let mut i0 = 0;
    while i0 < k {
        let iend = (i0 + TB).min(k);
        let mut j0 = 0;
        while j0 < mo {
            let jend = (j0 + TB).min(mo);
            for i in i0..iend {
                for j in j0..jend {
                    at[j * k + i] = a[i * mo + j];
                }
            }
            j0 = jend;
        }
        i0 = iend;
    }
}

/// Run `f` against the transposed image of `a` (`k×mo` → row-major
/// `mo×k`), drawing and returning the buffer from the thread-local
/// pool.  Nests inside [`with_packed_b`] (the borrow is released
/// before `f` runs).
pub fn with_packed_a_tn<R>(
    a: &[f32],
    k: usize,
    mo: usize,
    f: impl FnOnce(&[f32]) -> R,
) -> R {
    // Scratch draw: pack_a_tn writes all k·mo elements.
    let mut buf = PACK_POOL.with(|ws| ws.borrow_mut().take_scratch(k * mo));
    pack_a_tn(a, k, mo, &mut buf);
    let r = f(&buf);
    PACK_POOL.with(|ws| ws.borrow_mut().recycle(buf));
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packs_strips_with_zero_padding() {
        // 3×5 matrix, NR=16 ⇒ one strip, 11 padded columns per k-row
        let b: Vec<f32> = (0..15).map(|v| v as f32 + 1.0).collect();
        let mut packed = vec![7.0f32; packed_len(3, 5)];
        pack_b(&b, 3, 5, &mut packed);
        for kk in 0..3 {
            let row = &packed[kk * NR..(kk + 1) * NR];
            assert_eq!(&row[..5], &b[kk * 5..kk * 5 + 5], "k-row {kk}");
            assert!(row[5..].iter().all(|v| *v == 0.0), "padding {kk}");
        }
    }

    #[test]
    fn multi_strip_layout_is_contiguous_in_k() {
        // 2×20 ⇒ two strips; strip 1 holds columns 16..20
        let b: Vec<f32> = (0..40).map(|v| v as f32).collect();
        let mut packed = vec![0.0f32; packed_len(2, 20)];
        pack_b(&b, 2, 20, &mut packed);
        let s1 = &packed[2 * NR..]; // strip 1: k rows of NR
        assert_eq!(&s1[..4], &b[16..20]);
        assert_eq!(&s1[NR..NR + 4], &b[36..40]);
    }

    #[test]
    fn a_transpose_pack_is_exact_at_odd_shapes() {
        // shapes crossing the 32-block boundary in both dimensions
        for (k, mo) in [(1usize, 1usize), (3, 5), (31, 33), (40, 64),
                        (65, 7)] {
            let a: Vec<f32> = (0..k * mo).map(|v| v as f32).collect();
            let mut at = vec![-1.0f32; k * mo];
            pack_a_tn(&a, k, mo, &mut at);
            for i in 0..k {
                for j in 0..mo {
                    assert_eq!(at[j * k + i], a[i * mo + j],
                               "({k}x{mo}) at [{i},{j}]");
                }
            }
        }
    }

    #[test]
    fn a_transpose_pool_reuses_buffers_after_warmup() {
        let a = vec![2.0f32; 24 * 24];
        with_packed_a_tn(&a, 24, 24, |at| assert_eq!(at.len(), 24 * 24));
        let warm = pool_fresh_allocs();
        for _ in 0..8 {
            with_packed_a_tn(&a, 24, 24, |at| {
                assert_eq!(at[0], 2.0);
            });
        }
        assert_eq!(pool_fresh_allocs(), warm, "steady-state pack allocated");
    }

    #[test]
    fn pool_reuses_buffers_after_warmup() {
        let b = vec![1.0f32; 24 * 24];
        with_packed_b(&b, 24, 24, |p| assert_eq!(p.len(), packed_len(24, 24)));
        let warm = pool_fresh_allocs();
        for _ in 0..8 {
            with_packed_b(&b, 24, 24, |p| {
                assert_eq!(p[0], 1.0);
            });
        }
        assert_eq!(pool_fresh_allocs(), warm, "steady-state pack allocated");
    }
}
