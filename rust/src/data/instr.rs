//! Instruction-following corpus + deterministic rubric judge
//! (WizardLM-Evol-Instruct → MT-Bench substitute, Table 8).
//!
//! Instructions are symbolic ("repeat token k times", "sort digits",
//! "reverse sequence", "count token"); the judge scores a response 0–10
//! from explicit rubric constraints (content, length, format) instead of
//! GPT-4 — same comparison harness, pluggable scorer.

use crate::data::tokenizer::{Vocab, BOS, EOS, SEP};
use crate::data::{LmDataset, LmExample};
use crate::math::rng::Pcg64;

/// Instruction kinds (word-token markers 30..=33).
const K_REPEAT: usize = 30;
const K_SORT: usize = 31;
const K_REVERSE: usize = 32;
const K_COUNT: usize = 33;

/// Build one instruction example and its gold completion.
fn gen_one(v: &Vocab, rng: &mut Pcg64) -> LmExample {
    let kind = rng.below(4);
    let mut prompt = vec![BOS];
    let mut completion: Vec<u32> = Vec::new();
    match kind {
        0 => {
            // repeat token w k times
            let w = v.word(60 + rng.below(20));
            let k = 1 + rng.below(5);
            prompt.push(v.word(K_REPEAT));
            prompt.extend(v.encode_int(k as i64));
            prompt.push(w);
            completion.extend(std::iter::repeat(w).take(k));
        }
        1 => {
            // sort digits ascending
            let n = 3 + rng.below(4);
            let mut ds: Vec<u32> = (0..n).map(|_| rng.below(10) as u32).collect();
            prompt.push(v.word(K_SORT));
            for d in &ds {
                prompt.push(v.digit(*d));
            }
            ds.sort_unstable();
            for d in ds {
                completion.push(v.digit(d));
            }
        }
        2 => {
            // reverse a word sequence
            let n = 3 + rng.below(4);
            let ws: Vec<u32> = (0..n).map(|_| v.word(60 + rng.below(20))).collect();
            prompt.push(v.word(K_REVERSE));
            prompt.extend(&ws);
            completion.extend(ws.iter().rev());
        }
        _ => {
            // count occurrences of token w
            let w = v.word(60 + rng.below(5));
            let n = 4 + rng.below(6);
            let mut count = 0i64;
            prompt.push(v.word(K_COUNT));
            prompt.push(w);
            prompt.push(SEP);
            for _ in 0..n {
                let t = v.word(60 + rng.below(5));
                if t == w {
                    count += 1;
                }
                prompt.push(t);
            }
            completion.extend(v.encode_int(count));
        }
    }
    prompt.push(SEP);
    completion.push(EOS);
    LmExample { prompt, completion }
}

pub fn generate(n_train: usize, n_eval: usize, vocab: usize, max_seq: usize,
                seed: u64) -> LmDataset {
    let v = Vocab::new(vocab);
    let mut tr = Pcg64::derive(seed, "instr.train");
    let mut ev = Pcg64::derive(seed, "instr.eval");
    let gen = |rng: &mut Pcg64, n: usize| {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let e = gen_one(&v, rng);
            if e.prompt.len() + e.completion.len() <= max_seq {
                out.push(e);
            }
        }
        out
    };
    LmDataset { train: gen(&mut tr, n_train), eval: gen(&mut ev, n_eval) }
}

/// Deterministic rubric judge: score a generated response 0–10 against
/// the gold completion.  60% content overlap (order-aware), 20% length
/// discipline, 20% clean termination — an explicit stand-in for the
/// paper's GPT-4 judge.
pub fn judge(gold: &[u32], generated: &[u32]) -> f64 {
    let strip = |xs: &[u32]| -> Vec<u32> {
        xs.iter().copied().take_while(|t| *t != EOS).collect()
    };
    let g = strip(gold);
    let r = strip(generated);
    if g.is_empty() {
        return 0.0;
    }
    // order-aware overlap: longest common prefix + positional matches
    let pos_match = g.iter().zip(&r).filter(|(a, b)| a == b).count() as f64
        / g.len() as f64;
    let len_score = {
        let diff = (g.len() as f64 - r.len() as f64).abs() / g.len() as f64;
        (1.0 - diff).max(0.0)
    };
    let term_score = if generated.contains(&EOS) { 1.0 } else { 0.0 };
    10.0 * (0.6 * pos_match + 0.2 * len_score + 0.2 * term_score)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_response_scores_ten() {
        let d = generate(20, 0, 256, 48, 1);
        for e in &d.train {
            let s = judge(&e.completion, &e.completion);
            assert!((s - 10.0).abs() < 1e-9, "{s}");
        }
    }

    #[test]
    fn empty_response_scores_low() {
        let d = generate(5, 0, 256, 48, 2);
        for e in &d.train {
            assert!(judge(&e.completion, &[]) <= 2.1);
        }
    }

    #[test]
    fn partial_beats_garbage() {
        let v = Vocab::new(256);
        let gold: Vec<u32> = {
            let mut g = v.encode_int(123);
            g.push(EOS);
            g
        };
        let mut half = gold.clone();
        half[2] = v.word(9); // corrupt one digit but terminate properly
        let garbage = vec![v.word(1), v.word(2), v.word(3)];
        assert!(judge(&gold, &half) > judge(&gold, &garbage));
    }

    #[test]
    fn examples_fit_and_terminate() {
        let d = generate(50, 20, 256, 40, 3);
        for e in d.train.iter().chain(&d.eval) {
            assert!(e.prompt.len() + e.completion.len() <= 40);
            assert_eq!(*e.completion.last().unwrap(), EOS);
            assert_eq!(*e.prompt.last().unwrap(), SEP);
        }
    }
}
