//! Fixed symbolic vocabulary shared by all synthetic tasks.
//!
//! Layout (stable — artifacts bake the vocab size, not the table):
//!   0..=3    PAD, BOS, EOS, SEP
//!   4..=13   digits 0–9
//!   14..=21  operators + - * ( ) = , →
//!   22..     generic word tokens `w{i}` up to the model's vocab size

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const SEP: u32 = 3;
const DIGIT0: u32 = 4;
const OPS_BASE: u32 = 14;
pub const WORD_BASE: u32 = 22;

const OPS: [char; 8] = ['+', '-', '*', '(', ')', '=', ',', '>'];

/// Vocabulary view bound to a model preset's vocab size.
#[derive(Clone, Copy, Debug)]
pub struct Vocab {
    pub size: usize,
}

impl Vocab {
    pub fn new(size: usize) -> Vocab {
        assert!(size >= 64, "vocab too small for the symbolic table");
        Vocab { size }
    }

    pub fn digit(&self, d: u32) -> u32 {
        debug_assert!(d < 10);
        DIGIT0 + d
    }

    pub fn op(&self, c: char) -> u32 {
        let idx = OPS.iter().position(|o| *o == c)
            .unwrap_or_else(|| panic!("unknown op `{c}`"));
        OPS_BASE + idx as u32
    }

    /// Generic word token; wraps into the available word range.
    pub fn word(&self, i: usize) -> u32 {
        let nwords = self.size as u32 - WORD_BASE;
        WORD_BASE + (i as u32 % nwords)
    }

    pub fn n_words(&self) -> usize {
        self.size - WORD_BASE as usize
    }

    /// Encode a non-negative integer as digit tokens (decimal).
    pub fn encode_int(&self, v: i64) -> Vec<u32> {
        let mut out = Vec::new();
        if v < 0 {
            out.push(self.op('-'));
        }
        for c in v.abs().to_string().chars() {
            out.push(self.digit(c.to_digit(10).unwrap()));
        }
        out
    }

    /// Decode a digit-token run back to an integer; `None` if the slice
    /// contains no digits before EOS/SEP.
    pub fn decode_int(&self, toks: &[u32]) -> Option<i64> {
        let mut s = String::new();
        let mut neg = false;
        for &t in toks {
            if t == EOS || t == SEP || t == PAD {
                break;
            }
            if t == self.op('-') && s.is_empty() {
                neg = true;
            } else if (DIGIT0..DIGIT0 + 10).contains(&t) {
                s.push(char::from_digit(t - DIGIT0, 10).unwrap());
            } else if !s.is_empty() {
                break;
            }
        }
        if s.is_empty() {
            return None;
        }
        s.parse::<i64>().ok().map(|v| if neg { -v } else { v })
    }

    /// Human-readable rendering for debugging / EXPERIMENTS.md excerpts.
    pub fn render(&self, toks: &[u32]) -> String {
        let mut out = String::new();
        for &t in toks {
            let s = match t {
                PAD => "·".into(),
                BOS => "<s>".into(),
                EOS => "</s>".into(),
                SEP => "|".into(),
                t if (DIGIT0..DIGIT0 + 10).contains(&t) =>
                    (t - DIGIT0).to_string(),
                t if (OPS_BASE..OPS_BASE + 8).contains(&t) =>
                    OPS[(t - OPS_BASE) as usize].to_string(),
                t => format!("w{}", t - WORD_BASE),
            };
            out.push_str(&s);
            out.push(' ');
        }
        out.trim_end().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_roundtrip() {
        let v = Vocab::new(256);
        for x in [0i64, 7, 42, 999, 12345, -38] {
            let enc = v.encode_int(x);
            assert_eq!(v.decode_int(&enc), Some(x), "{x}");
        }
    }

    #[test]
    fn decode_stops_at_separator() {
        let v = Vocab::new(256);
        let mut toks = v.encode_int(57);
        toks.push(EOS);
        toks.extend(v.encode_int(99));
        assert_eq!(v.decode_int(&toks), Some(57));
    }

    #[test]
    fn decode_rejects_wordish_prefix() {
        let v = Vocab::new(256);
        assert_eq!(v.decode_int(&[v.word(5), EOS]), None);
    }

    #[test]
    fn words_stay_in_vocab() {
        let v = Vocab::new(256);
        for i in 0..10_000 {
            assert!((v.word(i) as usize) < v.size);
            assert!(v.word(i) >= WORD_BASE);
        }
    }

    #[test]
    fn render_readable() {
        let v = Vocab::new(256);
        let mut t = vec![BOS];
        t.extend(v.encode_int(12));
        t.push(v.op('+'));
        t.extend(v.encode_int(3));
        t.push(v.op('='));
        assert_eq!(v.render(&t), "<s> 1 2 + 3 =");
    }
}
