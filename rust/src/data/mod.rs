//! Synthetic data pipeline (DESIGN.md §2 substitutions).
//!
//! The paper's gated datasets (GLUE, MetaMathQA, Code-Feedback,
//! WizardLM) are replaced by synthetic generators with matched *shape*:
//! same task types, same metric machinery, same fine-tuning pipeline.
//! Every generator is deterministic in its seed.

pub mod batcher;
pub mod codegen;
pub mod instr;
pub mod mathgen;
pub mod nlu;
pub mod tokenizer;

pub use batcher::{Batch, Batcher};
pub use tokenizer::Vocab;

/// One language-modeling example: loss is taken on the completion only.
#[derive(Clone, Debug)]
pub struct LmExample {
    pub prompt: Vec<u32>,
    pub completion: Vec<u32>,
}

/// One classification / regression example.
#[derive(Clone, Debug)]
pub struct ClsExample {
    pub tokens: Vec<u32>,
    /// Class index for cls heads; scaled score for reg heads.
    pub label: f32,
}

/// A generated dataset split.
#[derive(Clone, Debug, Default)]
pub struct LmDataset {
    pub train: Vec<LmExample>,
    pub eval: Vec<LmExample>,
}

#[derive(Clone, Debug, Default)]
pub struct ClsDataset {
    pub train: Vec<ClsExample>,
    pub eval: Vec<ClsExample>,
    /// Metric selector: "acc" | "f1" | "mcc" | "pearson_spearman".
    pub metric: &'static str,
}

/// Resolve a task id (from RunConfig.task) to an LM dataset.
pub fn lm_task(task: &str, n_train: usize, n_eval: usize, vocab: usize,
               max_seq: usize, seed: u64) -> anyhow::Result<LmDataset> {
    match task {
        "math" => Ok(mathgen::generate(mathgen::Family::Mixed, n_train,
                                       n_eval, max_seq, seed)),
        "code" => Ok(codegen::generate(n_train, n_eval, max_seq, seed)),
        "instr" => Ok(instr::generate(n_train, n_eval, vocab, max_seq, seed)),
        f if f.starts_with("math:") => {
            let fam = mathgen::Family::from_str(&f[5..])?;
            Ok(mathgen::generate(fam, n_train, n_eval, max_seq, seed))
        }
        other => anyhow::bail!("unknown lm task `{other}`"),
    }
}

/// Resolve a task id to a classification/regression dataset.
pub fn cls_task(task: &str, n_train: usize, n_eval: usize, vocab: usize,
                max_seq: usize, seed: u64) -> anyhow::Result<ClsDataset> {
    let name = task.strip_prefix("nlu:").unwrap_or(task);
    nlu::generate(name, n_train, n_eval, vocab, max_seq, seed)
}
