//! Batching: padding, loss-mask construction, epoch shuffling and
//! wrap-around fill so every batch matches the artifact's static (B, T).
//!
//! LM batches implement completion-only loss: `targets[i] = ids[i+1]` and
//! the weight mask selects exactly the positions that predict completion
//! tokens (the prompt is context, not loss).

use crate::data::tokenizer::PAD;
use crate::data::{ClsExample, LmExample};
use crate::math::rng::Pcg64;

/// A materialized batch in artifact input layout (row-major B×T).
#[derive(Clone, Debug)]
pub struct Batch {
    pub bsz: usize,
    pub seq: usize,
    pub ids: Vec<i32>,
    pub wmask: Vec<f32>,
    /// LM next-token targets (None for cls/reg).
    pub targets: Option<Vec<i32>>,
    /// cls labels (i32) or reg labels (f32).
    pub labels_i: Option<Vec<i32>>,
    pub labels_f: Option<Vec<f32>>,
    /// Number of genuine (non-wraparound-fill) examples in this batch.
    pub valid: usize,
}

/// Build one LM batch from `examples` (≤ bsz; wraps if fewer).
pub fn lm_batch(examples: &[&LmExample], bsz: usize, seq: usize) -> Batch {
    assert!(!examples.is_empty());
    let mut ids = vec![PAD as i32; bsz * seq];
    let mut targets = vec![PAD as i32; bsz * seq];
    let mut wmask = vec![0.0f32; bsz * seq];
    for bi in 0..bsz {
        let e = examples[bi % examples.len()];
        let full: Vec<u32> = e.prompt.iter().chain(&e.completion).copied()
            .collect();
        let len = full.len().min(seq);
        for t in 0..len {
            ids[bi * seq + t] = full[t] as i32;
        }
        // position i predicts token i+1; mask on completion predictions
        let comp_start = e.prompt.len().min(seq);
        for t in 0..len.saturating_sub(1) {
            targets[bi * seq + t] = full[t + 1] as i32;
            if t + 1 >= comp_start {
                wmask[bi * seq + t] = 1.0;
            }
        }
    }
    Batch {
        bsz,
        seq,
        ids,
        wmask,
        targets: Some(targets),
        labels_i: None,
        labels_f: None,
        valid: examples.len().min(bsz),
    }
}

/// Build one classification/regression batch.
pub fn cls_batch(examples: &[&ClsExample], bsz: usize, seq: usize,
                 regression: bool) -> Batch {
    assert!(!examples.is_empty());
    let mut ids = vec![PAD as i32; bsz * seq];
    let mut wmask = vec![0.0f32; bsz * seq];
    let mut li = vec![0i32; bsz];
    let mut lf = vec![0f32; bsz];
    for bi in 0..bsz {
        let e = examples[bi % examples.len()];
        let len = e.tokens.len().min(seq);
        for t in 0..len {
            ids[bi * seq + t] = e.tokens[t] as i32;
            wmask[bi * seq + t] = 1.0;
        }
        li[bi] = e.label as i32;
        lf[bi] = e.label;
    }
    Batch {
        bsz,
        seq,
        ids,
        wmask,
        targets: None,
        labels_i: if regression { None } else { Some(li) },
        labels_f: if regression { Some(lf) } else { None },
        valid: examples.len().min(bsz),
    }
}

/// Epoch-shuffling index iterator over a dataset of `n` examples.
/// Grad-accum grouping: `chunk = bsz * grad_accum` examples are drawn
/// per logical step, split into `grad_accum` device batches.
pub struct Batcher {
    n: usize,
    bsz: usize,
    order: Vec<usize>,
    cursor: usize,
    epoch: u64,
    seed: u64,
}

impl Batcher {
    pub fn new(n: usize, bsz: usize, seed: u64) -> Batcher {
        assert!(n > 0 && bsz > 0);
        let mut b = Batcher { n, bsz, order: (0..n).collect(), cursor: 0,
                              epoch: 0, seed };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        let mut rng = Pcg64::derive(self.seed, &format!("epoch.{}", self.epoch));
        self.order = (0..self.n).collect();
        rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Next `bsz` example indices, rolling over epochs as needed.
    pub fn next_indices(&mut self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.bsz);
        while out.len() < self.bsz {
            if self.cursor >= self.n {
                self.epoch += 1;
                self.reshuffle();
            }
            out.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        out
    }
}

/// Sequential eval batching: yields index windows covering [0, n) once;
/// the final window wraps but reports `valid < bsz`.
pub fn eval_windows(n: usize, bsz: usize) -> Vec<(Vec<usize>, usize)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < n {
        let valid = bsz.min(n - i);
        let idx: Vec<usize> = (0..bsz).map(|k| (i + k) % n).collect();
        out.push((idx, valid));
        i += bsz;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer::{BOS, EOS, SEP};
    use crate::util::prop;

    fn ex(plen: usize, clen: usize) -> LmExample {
        LmExample {
            prompt: std::iter::once(BOS)
                .chain((0..plen - 1).map(|i| 30 + i as u32)).collect(),
            completion: (0..clen - 1).map(|i| 60 + i as u32)
                .chain(std::iter::once(EOS)).collect(),
        }
    }

    #[test]
    fn lm_mask_covers_exactly_completion_predictions() {
        let e = ex(5, 3);
        let b = lm_batch(&[&e], 1, 16);
        let wm = &b.wmask[..16];
        // positions 4..=6 predict tokens 5..=7 (the 3 completion tokens)
        let active: Vec<usize> = (0..16).filter(|i| wm[*i] > 0.0).collect();
        assert_eq!(active, vec![4, 5, 6]);
        let t = b.targets.as_ref().unwrap();
        assert_eq!(t[4], 60);
        assert_eq!(t[6], EOS as i32);
    }

    #[test]
    fn lm_truncation_is_safe() {
        let e = ex(10, 10);
        let b = lm_batch(&[&e], 2, 8); // shorter than the example
        assert_eq!(b.ids.len(), 16);
        // no mask bit can point past the sequence
        for i in 0..16 {
            if b.wmask[i] > 0.0 {
                assert!(i % 8 < 7);
            }
        }
    }

    #[test]
    fn cls_batch_padding_and_labels() {
        let e1 = ClsExample { tokens: vec![BOS, 30, 31, SEP, 40], label: 1.0 };
        let e2 = ClsExample { tokens: vec![BOS, 32], label: 0.0 };
        let b = cls_batch(&[&e1, &e2], 4, 8, false);
        assert_eq!(b.valid, 2);
        assert_eq!(b.labels_i.as_ref().unwrap()[..2], [1, 0]);
        // wraparound fill repeats examples
        assert_eq!(b.labels_i.as_ref().unwrap()[2], 1);
        assert_eq!(b.wmask[8 + 2], 0.0, "padding after short example");
        assert_eq!(b.wmask[8 + 1], 1.0);
    }

    #[test]
    fn batcher_visits_every_example_each_epoch() {
        prop::for_all("batcher partition", 20, |rng| {
            let n = prop::int_in(rng, 1, 40);
            let bsz = prop::int_in(rng, 1, 8);
            let mut b = Batcher::new(n, bsz, 9);
            let steps_per_epoch = n.div_ceil(bsz);
            let mut seen = vec![0usize; n];
            for _ in 0..steps_per_epoch {
                for i in b.next_indices() {
                    seen[i] += 1;
                }
            }
            // each example seen at least once, at most twice (epoch roll)
            assert!(seen.iter().all(|c| *c >= 1 || bsz > n));
            assert!(seen.iter().all(|c| *c <= 2));
        });
    }

    #[test]
    fn batcher_epochs_reshuffle_differently() {
        let mut b = Batcher::new(32, 8, 1);
        let e0: Vec<usize> = (0..4).flat_map(|_| b.next_indices()).collect();
        let e1: Vec<usize> = (0..4).flat_map(|_| b.next_indices()).collect();
        assert_ne!(e0, e1);
        let mut s0 = e0.clone();
        s0.sort_unstable();
        assert_eq!(s0, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn eval_windows_cover_once() {
        let ws = eval_windows(10, 4);
        assert_eq!(ws.len(), 3);
        assert_eq!(ws[2].1, 2, "last window has 2 valid");
        let mut all: Vec<usize> = ws.iter()
            .flat_map(|(idx, v)| idx[..*v].to_vec()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
    }
}
