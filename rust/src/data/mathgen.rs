//! Arithmetic-reasoning corpus generator (MetaMathQA → GSM8K/MATH
//! substitute, plus the seven Table 6 task families).
//!
//! Examples are `prompt | completion` LM pairs:
//!   `<s> 1 2 + ( 3 * 4 ) = | 2 4 </s>`
//! with loss masked to the completion.  Greedy decode + integer
//! exact-match gives the GSM8K-style accuracy.

use crate::data::tokenizer::{Vocab, BOS, EOS, SEP};
use crate::data::{LmDataset, LmExample};
use crate::math::rng::Pcg64;

/// Task families mirroring the paper's Table 6 benchmark list.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Family {
    /// a+b (AddSub analogue)
    AddSub,
    /// a*b (single products)
    Mul,
    /// a+b-c etc., 3 operands (MultiArith analogue)
    MultiArith,
    /// one unknown: a + x = c, answer x (SingleEq analogue)
    SingleEq,
    /// two-step word-problem shape: (a+b)*c (SVAMP/MAWPS analogue)
    TwoStep,
    /// parenthesized 3-op expressions (AQuA/MATH analogue — hardest)
    Expr3,
    /// comparison: max of three numbers (GSM8K-lite reasoning)
    Max3,
    /// uniform mixture of all families (the "MetaMath" training mix)
    Mixed,
}

impl Family {
    pub fn from_str(s: &str) -> anyhow::Result<Family> {
        Ok(match s {
            "addsub" => Family::AddSub,
            "mul" => Family::Mul,
            "multiarith" => Family::MultiArith,
            "singleeq" => Family::SingleEq,
            "twostep" => Family::TwoStep,
            "expr3" => Family::Expr3,
            "max3" => Family::Max3,
            "mixed" => Family::Mixed,
            other => anyhow::bail!("unknown math family `{other}`"),
        })
    }

    pub const ALL: [Family; 7] = [
        Family::AddSub, Family::Mul, Family::MultiArith, Family::SingleEq,
        Family::TwoStep, Family::Expr3, Family::Max3,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Family::AddSub => "AddSub",
            Family::Mul => "Mul",
            Family::MultiArith => "MultiArith",
            Family::SingleEq => "SingleEq",
            Family::TwoStep => "TwoStep",
            Family::Expr3 => "Expr3",
            Family::Max3 => "Max3",
            Family::Mixed => "Mixed",
        }
    }
}

/// One generated problem: prompt tokens (after BOS, before SEP) and the
/// integer answer.
fn sample_problem(fam: Family, v: &Vocab, rng: &mut Pcg64)
                  -> (Vec<u32>, i64) {
    let fam = if fam == Family::Mixed {
        Family::ALL[rng.below(Family::ALL.len())]
    } else {
        fam
    };
    let small = |rng: &mut Pcg64| rng.below(10) as i64;
    let mid = |rng: &mut Pcg64| rng.below(50) as i64;
    let mut t = Vec::new();
    let ans;
    match fam {
        Family::AddSub => {
            let (a, b) = (mid(rng), mid(rng));
            let plus = rng.below(2) == 0;
            t.extend(v.encode_int(a));
            t.push(v.op(if plus { '+' } else { '-' }));
            t.extend(v.encode_int(b));
            ans = if plus { a + b } else { a - b };
        }
        Family::Mul => {
            let (a, b) = (small(rng), small(rng));
            t.extend(v.encode_int(a));
            t.push(v.op('*'));
            t.extend(v.encode_int(b));
            ans = a * b;
        }
        Family::MultiArith => {
            let (a, b, c) = (mid(rng), mid(rng), mid(rng));
            t.extend(v.encode_int(a));
            t.push(v.op('+'));
            t.extend(v.encode_int(b));
            t.push(v.op('-'));
            t.extend(v.encode_int(c));
            ans = a + b - c;
        }
        Family::SingleEq => {
            // a + x = c   → answer x
            let (a, x) = (mid(rng), mid(rng));
            let c = a + x;
            t.extend(v.encode_int(a));
            t.push(v.op('+'));
            t.push(v.word(0)); // the unknown symbol
            t.push(v.op('='));
            t.extend(v.encode_int(c));
            ans = x;
        }
        Family::TwoStep => {
            let (a, b, c) = (small(rng), small(rng), small(rng));
            t.push(v.op('('));
            t.extend(v.encode_int(a));
            t.push(v.op('+'));
            t.extend(v.encode_int(b));
            t.push(v.op(')'));
            t.push(v.op('*'));
            t.extend(v.encode_int(c));
            ans = (a + b) * c;
        }
        Family::Expr3 => {
            let (a, b, c, d) = (small(rng), small(rng), small(rng), small(rng));
            t.extend(v.encode_int(a));
            t.push(v.op('*'));
            t.extend(v.encode_int(b));
            t.push(v.op('+'));
            t.push(v.op('('));
            t.extend(v.encode_int(c));
            t.push(v.op('-'));
            t.extend(v.encode_int(d));
            t.push(v.op(')'));
            ans = a * b + (c - d);
        }
        Family::Max3 => {
            let (a, b, c) = (mid(rng), mid(rng), mid(rng));
            t.push(v.word(1)); // "max" marker
            t.extend(v.encode_int(a));
            t.push(v.op(','));
            t.extend(v.encode_int(b));
            t.push(v.op(','));
            t.extend(v.encode_int(c));
            ans = a.max(b).max(c);
        }
        Family::Mixed => unreachable!(),
    }
    (t, ans)
}

/// Build one LM example `[BOS prompt SEP] [answer EOS]`.
pub fn make_example(fam: Family, v: &Vocab, rng: &mut Pcg64) -> LmExample {
    let (body, ans) = sample_problem(fam, v, rng);
    let mut prompt = vec![BOS];
    prompt.extend(body);
    prompt.push(SEP);
    let mut completion = v.encode_int(ans);
    completion.push(EOS);
    LmExample { prompt, completion }
}

/// Generate a train/eval split (disjoint RNG streams; eval problems are
/// unseen with high probability given the combinatorial space).
pub fn generate(fam: Family, n_train: usize, n_eval: usize, max_seq: usize,
                seed: u64) -> LmDataset {
    // Vocab only needs the symbolic table; 64 is the floor.
    let v = Vocab::new(64);
    let mut tr_rng = Pcg64::derive(seed, "math.train");
    let mut ev_rng = Pcg64::derive(seed, "math.eval");
    let fits = |e: &LmExample| e.prompt.len() + e.completion.len() <= max_seq;
    let gen = |rng: &mut Pcg64, n: usize| {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let e = make_example(fam, &v, rng);
            if fits(&e) {
                out.push(e);
            }
        }
        out
    };
    LmDataset { train: gen(&mut tr_rng, n_train), eval: gen(&mut ev_rng, n_eval) }
}

/// Ground-truth answer for an example (re-parse of the completion).
pub fn gold_answer(v: &Vocab, e: &LmExample) -> Option<i64> {
    v.decode_int(&e.completion)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn answers_are_consistent() {
        // The completion must decode back to an integer for every family.
        let v = Vocab::new(64);
        prop::for_all("math answers decode", 50, |rng| {
            for fam in Family::ALL {
                let e = make_example(fam, &v, rng);
                assert!(gold_answer(&v, &e).is_some(), "{fam:?}");
                assert_eq!(*e.prompt.first().unwrap(), BOS);
                assert_eq!(*e.prompt.last().unwrap(), SEP);
                assert_eq!(*e.completion.last().unwrap(), EOS);
            }
        });
    }

    #[test]
    fn twostep_matches_arithmetic() {
        let v = Vocab::new(64);
        let mut rng = Pcg64::new(5);
        for _ in 0..50 {
            let (toks, ans) = sample_problem(Family::TwoStep, &v, &mut rng);
            // parse (a+b)*c back out of the tokens
            let rendered = v.render(&toks).replace(' ', "");
            let inner: Vec<i64> = rendered
                .trim_start_matches('(')
                .split(|c| "()+*".contains(c))
                .filter(|s| !s.is_empty())
                .map(|s| s.parse().unwrap())
                .collect();
            assert_eq!((inner[0] + inner[1]) * inner[2], ans, "{rendered}");
        }
    }

    #[test]
    fn deterministic_and_split_disjoint_streams() {
        let d1 = generate(Family::Mixed, 20, 10, 32, 9);
        let d2 = generate(Family::Mixed, 20, 10, 32, 9);
        assert_eq!(d1.train.len(), 20);
        assert_eq!(d1.eval.len(), 10);
        for (a, b) in d1.train.iter().zip(&d2.train) {
            assert_eq!(a.prompt, b.prompt);
        }
        // train and eval streams differ
        assert_ne!(d1.train[0].prompt, d1.eval[0].prompt);
    }

    #[test]
    fn respects_max_seq() {
        let d = generate(Family::Mixed, 100, 0, 20, 3);
        assert!(d.train.iter()
            .all(|e| e.prompt.len() + e.completion.len() <= 20));
    }
}
