//! GLUE-simulacrum NLU task suite (Table 2 substitute; DESIGN.md §2).
//!
//! Six synthetic sequence tasks with the *same output types and metrics*
//! as the paper's GLUE selection:
//!
//! | id        | GLUE analogue | task shape                       | metric |
//! |-----------|---------------|----------------------------------|--------|
//! | sst2-sim  | SST-2         | lexicon sentiment majority       | acc    |
//! | mrpc-sim  | MRPC          | paraphrase detection (pair)      | F1     |
//! | cola-sim  | CoLA          | grammar-pattern acceptability    | MCC    |
//! | qnli-sim  | QNLI          | question-answer entailment (pair)| acc    |
//! | rte-sim   | RTE           | premise-hypothesis entailment    | acc    |
//! | stsb-sim  | STS-B         | token-overlap similarity (0–5)   | P/S    |
//!
//! Difficulty is tuned via distractor noise so methods separate without
//! saturating — the property the table comparison needs.

use crate::data::tokenizer::{Vocab, BOS, SEP};
use crate::data::{ClsDataset, ClsExample};
use crate::math::rng::Pcg64;

pub const TASKS: [&str; 6] =
    ["sst2-sim", "mrpc-sim", "cola-sim", "qnli-sim", "rte-sim", "stsb-sim"];

/// Paper metric label for each task (Table 2 caption).
pub fn metric_for(task: &str) -> &'static str {
    match task {
        "mrpc-sim" => "f1",
        "cola-sim" => "mcc",
        "stsb-sim" => "pearson_spearman",
        _ => "acc",
    }
}

fn sentence(v: &Vocab, rng: &mut Pcg64, len: usize, pool: usize,
            offset: usize) -> Vec<u32> {
    (0..len).map(|_| v.word(offset + rng.below(pool))).collect()
}

/// Word-pool layout: [0,50) positive lexicon, [50,100) negative lexicon,
/// [100,150) neutral filler, [150,170) question keys, [170,190) answers.
const POS0: usize = 0;
const NEG0: usize = 50;
const NEUT0: usize = 100;
const QKEY0: usize = 150;
const ANS0: usize = 170;

fn gen_example(task: &str, v: &Vocab, rng: &mut Pcg64, max_seq: usize)
               -> ClsExample {
    let body = max_seq.saturating_sub(4).max(8);
    let mut toks = vec![BOS];
    let label: f32;
    match task {
        "sst2-sim" => {
            // sentiment = which lexicon dominates; 70/30 mix with filler
            let positive = rng.below(2) == 1;
            let n = body.min(16);
            for _ in 0..n {
                let roll = rng.below(10);
                let w = if roll < 5 {
                    let base = if positive { POS0 } else { NEG0 };
                    base + rng.below(50)
                } else if roll < 7 {
                    let base = if positive { NEG0 } else { POS0 };
                    base + rng.below(50)
                } else {
                    NEUT0 + rng.below(50)
                };
                toks.push(v.word(w));
            }
            label = positive as u32 as f32;
        }
        "mrpc-sim" => {
            let n = (body / 2 - 1).min(10).max(3);
            let s1 = sentence(v, rng, n, 50, NEUT0);
            let paraphrase = rng.below(2) == 1;
            let mut s2 = if paraphrase {
                let mut s = s1.clone();
                rng.shuffle(&mut s);
                // light lexical substitution noise
                if !s.is_empty() {
                    let i = rng.below(s.len());
                    s[i] = v.word(NEUT0 + rng.below(50));
                }
                s
            } else {
                sentence(v, rng, n, 50, NEUT0)
            };
            toks.extend(&s1);
            toks.push(SEP);
            toks.append(&mut s2);
            label = paraphrase as u32 as f32;
        }
        "cola-sim" => {
            // "grammar": alternating determiner/noun pattern
            // acceptable = strict alternation w(even) w(odd) w(even)...
            let n = body.min(12).max(4);
            let acceptable = rng.below(2) == 1;
            for i in 0..n {
                let parity = i % 2;
                let ok = acceptable || rng.below(4) != 0;
                let p = if ok { parity } else { 1 - parity };
                toks.push(v.word(NEUT0 + p * 25 + rng.below(25)));
            }
            label = acceptable as u32 as f32;
        }
        "qnli-sim" => {
            // question: key token k; entail iff sentence contains ANS(k)
            let k = rng.below(20);
            let entail = rng.below(2) == 1;
            toks.push(v.word(QKEY0 + k));
            toks.push(SEP);
            let n = (body - 3).min(12).max(4);
            let mut sent = sentence(v, rng, n, 50, NEUT0);
            if entail {
                let i = rng.below(sent.len());
                sent[i] = v.word(ANS0 + k);
            } else if rng.below(2) == 0 {
                // distractor: answer to a *different* question
                let i = rng.below(sent.len());
                sent[i] = v.word(ANS0 + (k + 1 + rng.below(19)) % 20);
            }
            toks.extend(sent);
            label = entail as u32 as f32;
        }
        "rte-sim" => {
            // hypothesis ⊆ premise → entail; novel token → not
            let n = (body / 2).min(10).max(4);
            let premise = sentence(v, rng, n, 60, NEUT0);
            let entail = rng.below(2) == 1;
            let hn = (n / 2).max(2);
            let mut hyp: Vec<u32> = (0..hn)
                .map(|_| premise[rng.below(premise.len())])
                .collect();
            if !entail {
                let i = rng.below(hyp.len());
                hyp[i] = v.word(NEUT0 + 60 + rng.below(30));
            }
            toks.extend(premise);
            toks.push(SEP);
            toks.extend(hyp);
            label = entail as u32 as f32;
        }
        "stsb-sim" => {
            // similarity = |shared| / n scaled to 0..5 with noise
            let n = (body / 2).min(10).max(4);
            let s1 = sentence(v, rng, n, 80, NEUT0);
            let shared = rng.below(n + 1);
            let mut s2: Vec<u32> = s1[..shared].to_vec();
            while s2.len() < n {
                s2.push(v.word(NEUT0 + 80 + rng.below(40)));
            }
            rng.shuffle(&mut s2);
            toks.extend(&s1);
            toks.push(SEP);
            toks.extend(&s2);
            label = 5.0 * shared as f32 / n as f32;
        }
        other => panic!("unknown nlu task `{other}`"),
    }
    ClsExample { tokens: toks, label }
}

/// Generate a train/eval split for one task id.
pub fn generate(task: &str, n_train: usize, n_eval: usize, vocab: usize,
                max_seq: usize, seed: u64) -> anyhow::Result<ClsDataset> {
    if !TASKS.contains(&task) {
        anyhow::bail!("unknown nlu task `{task}` (expected one of {TASKS:?})");
    }
    let v = Vocab::new(vocab);
    let mut tr = Pcg64::derive(seed, &format!("nlu.{task}.train"));
    let mut ev = Pcg64::derive(seed, &format!("nlu.{task}.eval"));
    let gen = |rng: &mut Pcg64, n: usize| {
        (0..n).map(|_| gen_example(task, &v, rng, max_seq)).collect()
    };
    Ok(ClsDataset {
        train: gen(&mut tr, n_train),
        eval: gen(&mut ev, n_eval),
        metric: metric_for(task),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate() {
        for t in TASKS {
            let d = generate(t, 50, 20, 512, 48, 1).unwrap();
            assert_eq!(d.train.len(), 50);
            assert_eq!(d.eval.len(), 20);
            assert!(d.train.iter().all(|e| e.tokens.len() <= 48));
            assert!(d.train.iter().all(|e| e.tokens[0] == BOS));
        }
    }

    #[test]
    fn labels_balanced_for_binary_tasks() {
        for t in ["sst2-sim", "mrpc-sim", "qnli-sim", "rte-sim"] {
            let d = generate(t, 400, 0, 512, 48, 2).unwrap();
            let pos = d.train.iter().filter(|e| e.label > 0.5).count();
            assert!((120..=280).contains(&pos), "{t}: {pos}/400");
        }
    }

    #[test]
    fn stsb_labels_in_range() {
        let d = generate("stsb-sim", 200, 0, 512, 48, 3).unwrap();
        assert!(d.train.iter().all(|e| (0.0..=5.0).contains(&e.label)));
        // non-degenerate spread
        let lo = d.train.iter().filter(|e| e.label < 1.5).count();
        let hi = d.train.iter().filter(|e| e.label > 3.5).count();
        assert!(lo > 10 && hi > 10);
    }

    #[test]
    fn qnli_is_learnable_signal() {
        // entailment examples must actually contain the paired answer
        let v = Vocab::new(512);
        let mut rng = Pcg64::new(4);
        for _ in 0..100 {
            let e = gen_example("qnli-sim", &v, &mut rng, 48);
            let key = e.tokens[1]; // token after BOS
            let k = key - v.word(QKEY0);
            let ans = v.word(ANS0 + k as usize);
            let contains = e.tokens[3..].contains(&ans);
            if e.label > 0.5 {
                assert!(contains);
            } else {
                assert!(!contains);
            }
        }
    }

    #[test]
    fn metric_mapping_matches_paper() {
        assert_eq!(metric_for("cola-sim"), "mcc");
        assert_eq!(metric_for("mrpc-sim"), "f1");
        assert_eq!(metric_for("stsb-sim"), "pearson_spearman");
        assert_eq!(metric_for("sst2-sim"), "acc");
    }

    #[test]
    fn rejects_unknown_task() {
        assert!(generate("wnli-sim", 1, 1, 512, 48, 0).is_err());
    }
}
