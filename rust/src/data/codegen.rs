//! Stack-program corpus (Code-Feedback → HumanEval/MBPP substitute).
//!
//! A tiny stack VM is the "programming language"; training examples ask
//! the model to *execute* a program (predict its output), and the
//! pass@1-style metric re-runs the reference interpreter and checks the
//! decoded answer — i.e. an execution-checked correctness rate, the same
//! shape as HumanEval's pass@1.
//!
//! Program syntax (token stream):  `Pk` push literal k, `+` add top two,
//! `*` multiply, `D` dup, `S` swap.  Output = final top of stack.

use crate::data::tokenizer::{Vocab, BOS, EOS, SEP};
use crate::data::{LmDataset, LmExample};
use crate::math::rng::Pcg64;

/// VM operations.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Op {
    Push(i64),
    Add,
    Mul,
    Dup,
    Swap,
}

/// Reference interpreter — also used by the pass@1 checker.
pub fn execute(prog: &[Op]) -> Option<i64> {
    let mut stack: Vec<i64> = Vec::new();
    for op in prog {
        match op {
            Op::Push(k) => stack.push(*k),
            Op::Add => {
                let (a, b) = (stack.pop()?, stack.pop()?);
                stack.push(a.checked_add(b)?);
            }
            Op::Mul => {
                let (a, b) = (stack.pop()?, stack.pop()?);
                stack.push(a.checked_mul(b)?);
            }
            Op::Dup => {
                let a = *stack.last()?;
                stack.push(a);
            }
            Op::Swap => {
                let n = stack.len();
                if n < 2 {
                    return None;
                }
                stack.swap(n - 1, n - 2);
            }
        }
    }
    stack.last().copied()
}

/// Word-token ids for the non-push ops (offsets in the word table).
const W_ADD: usize = 10;
const W_MUL: usize = 11;
const W_DUP: usize = 12;
const W_SWAP: usize = 13;

pub fn encode_program(prog: &[Op], v: &Vocab) -> Vec<u32> {
    let mut out = Vec::new();
    for op in prog {
        match op {
            Op::Push(k) => {
                out.push(v.word(20)); // "push" marker
                out.extend(v.encode_int(*k));
            }
            Op::Add => out.push(v.word(W_ADD)),
            Op::Mul => out.push(v.word(W_MUL)),
            Op::Dup => out.push(v.word(W_DUP)),
            Op::Swap => out.push(v.word(W_SWAP)),
        }
    }
    out
}

/// Sample a random well-formed program (never underflows, bounded values).
pub fn sample_program(len: usize, rng: &mut Pcg64) -> Vec<Op> {
    let mut prog = vec![Op::Push(rng.below(9) as i64 + 1)];
    let mut depth = 1usize;
    while prog.len() < len {
        let choice = rng.below(5);
        let op = match choice {
            0 => {
                depth += 1;
                Op::Push(rng.below(9) as i64 + 1)
            }
            1 if depth >= 2 => {
                depth -= 1;
                Op::Add
            }
            2 if depth >= 2 => {
                depth -= 1;
                Op::Mul
            }
            3 if depth >= 1 && depth < 4 => {
                depth += 1;
                Op::Dup
            }
            4 if depth >= 2 => Op::Swap,
            _ => {
                depth += 1;
                Op::Push(rng.below(9) as i64 + 1)
            }
        };
        prog.push(op);
    }
    prog
}

/// One LM example: `[BOS program SEP] [output EOS]`.
pub fn make_example(v: &Vocab, rng: &mut Pcg64, max_len: usize)
                    -> (LmExample, Vec<Op>) {
    loop {
        let plen = 2 + rng.below(max_len.saturating_sub(1).max(1));
        let prog = sample_program(plen, rng);
        if let Some(out) = execute(&prog) {
            if out.abs() < 10_000 {
                let mut prompt = vec![BOS];
                prompt.extend(encode_program(&prog, v));
                prompt.push(SEP);
                let mut completion = v.encode_int(out);
                completion.push(EOS);
                return (LmExample { prompt, completion }, prog);
            }
        }
    }
}

pub fn generate(n_train: usize, n_eval: usize, max_seq: usize,
                seed: u64) -> LmDataset {
    let v = Vocab::new(64);
    let mut tr = Pcg64::derive(seed, "code.train");
    let mut ev = Pcg64::derive(seed, "code.eval");
    let gen = |rng: &mut Pcg64, n: usize| {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let (e, _) = make_example(&v, rng, 6);
            if e.prompt.len() + e.completion.len() <= max_seq {
                out.push(e);
            }
        }
        out
    };
    LmDataset { train: gen(&mut tr, n_train), eval: gen(&mut ev, n_eval) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn interpreter_known_programs() {
        use Op::*;
        assert_eq!(execute(&[Push(2), Push(3), Add]), Some(5));
        assert_eq!(execute(&[Push(2), Push(3), Mul]), Some(6));
        assert_eq!(execute(&[Push(2), Dup, Mul]), Some(4));
        assert_eq!(execute(&[Push(2), Push(5), Swap]), Some(2));
        assert_eq!(execute(&[Add]), None, "underflow must be None");
    }

    #[test]
    fn sampled_programs_always_execute() {
        prop::for_all("programs well-formed", 100, |rng| {
            let p = sample_program(prop::int_in(rng, 1, 10), rng);
            assert!(execute(&p).is_some(), "{p:?}");
        });
    }

    #[test]
    fn example_answer_matches_interpreter() {
        let v = Vocab::new(64);
        prop::for_all("completion == execute(prog)", 50, |rng| {
            let (e, prog) = make_example(&v, rng, 5);
            let decoded = v.decode_int(&e.completion).unwrap();
            assert_eq!(decoded, execute(&prog).unwrap());
        });
    }

    #[test]
    fn deterministic_generation() {
        let a = generate(10, 5, 48, 3);
        let b = generate(10, 5, 48, 3);
        for (x, y) in a.train.iter().zip(&b.train) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.completion, y.completion);
        }
    }
}
