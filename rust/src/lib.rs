//! # cosa-repro — CoSA: Compressed Sensing-Based Adaptation of LLMs
//!
//! Full-system reproduction of the CoSA paper (Wei et al., 2026) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the fine-tuning framework: config system,
//!   launcher, synthetic data pipeline, PJRT runtime, training loop,
//!   adapter management (including the paper's Y-plus-seed storage format),
//!   RIP validation suite and every paper table/figure as a regenerable
//!   experiment.
//! * **L2 (`python/compile/model.py`)** — the transformer + 7 PEFT methods,
//!   lowered once to HLO text artifacts (`make artifacts`).
//! * **L1 (`python/compile/kernels/cosa_kernel.py`)** — the fused Pallas
//!   adapter kernel `o = L(Y(Rx))` with the paper's analytic VJP (Eq. 10).
//!
//! Python never runs on the training path: the rust binary is
//! self-contained once `artifacts/` is built.

pub mod adapters;
pub mod config;
pub mod data;
pub mod eval;
pub mod exp;
pub mod math;
pub mod rip;
pub mod runtime;
pub mod train;
pub mod util;

/// Crate-wide result type (anyhow-backed, like the rest of the stack).
pub type Result<T> = anyhow::Result<T>;
