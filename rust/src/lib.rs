//! # cosa-repro — CoSA: Compressed Sensing-Based Adaptation of LLMs
//!
//! Full-system reproduction of the CoSA paper (Wei et al., 2026) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the fine-tuning framework: config system,
//!   launcher, synthetic data pipeline, PJRT runtime, training loop,
//!   adapter management (including the paper's Y-plus-seed storage format),
//!   RIP validation suite and every paper table/figure as a regenerable
//!   experiment.
//! * **L2 (`python/compile/model.py`)** — the transformer + 7 PEFT methods,
//!   lowered once to HLO text artifacts (`make artifacts`).
//! * **L1 (`python/compile/kernels/cosa_kernel.py`)** — the fused Pallas
//!   adapter kernel `o = L(Y(Rx))` with the paper's analytic VJP (Eq. 10).
//!
//! Python never runs on the training path: the rust binary is
//! self-contained once `artifacts/` is built.
//!
//! ## Host compute layer (`linalg`)
//!
//! Every host-side dense product — adapter forward/VJP mirrors, PiSSA's
//! randomized SVD, the RIP estimator's Gram matrices, the experiment
//! harnesses and the benches — goes through the [`linalg`] backend
//! layer: a [`linalg::Backend`] trait with a `Reference` baseline, a
//! cache-blocked row-parallel `Tiled` implementation, and the default
//! `Packed` backend (packed B panels + register-blocked micro-kernels +
//! runtime-dispatched wide-lane SIMD), transpose-free `gemm_nt` /
//! `gemm_tn` kernels, dedicated sparse-core products (`linalg::sparse`,
//! threaded over a precomputed nonzero-row index) and a reusable
//! [`linalg::Workspace`] arena that keeps training-step hot loops —
//! including panel packing — allocation-free after warmup.
//! Selection is config-driven (`[compute]` in run configs, preset hints
//! in `config::presets`) with `COSA_BACKEND` / `COSA_THREADS` /
//! `COSA_SIMD` env overrides — see the `linalg` module docs for the
//! exact rules.
//!
//! ## Adapted models and multi-adapter serving (`model`, `serve`)
//!
//! The paper's §4.1 deployment story — an adapter is only the compact
//! core plus a seed that regenerates its projections — scales to *many
//! adapters across every adapted site of a base model*.  The [`model`]
//! layer defines the shape contract ([`model::ModelSpec`]: ordered
//! named sites with per-site core dims) and [`model::AdaptedModel`]
//! (N sites, many named adapters, one shared byte-budgeted projection
//! LRU).  The [`serve`] subsystem builds on it: checkpoints loaded by
//! name (v2 files carry all per-site cores under one adapter name),
//! hot load/evict with bit-identical re-materialization, a batched
//! request scheduler (whole multi-site requests batched per adapter
//! under a max-batch/max-wait policy with per-request deadlines and
//! cancellation, on a Workspace-backed worker pool with pooled output
//! buffers) and the `serve-bench` workload driver whose `serving` and
//! `serving_model` report sections CI gates.  Knobs live in the
//! `[serve]` and `[model]` config tables (`config::ServeConfig`,
//! `config::ModelConfig`) with `COSA_SERVE_*` / `COSA_MODEL_*` env
//! overrides.
//!
//! ## Network edge (`wire`)
//!
//! The [`wire`] subsystem is the production ingress over the serve
//! scheduler, built entirely on `std` (the workspace is offline): a
//! strict streaming JSON codec with precise `f32` round-trips, a
//! minimal HTTP/1.1 server (bounded accept/worker model, keep-alive,
//! `Content-Length` framing, timeouts), the `/v1/forward`,
//! `/v1/adapters/{name}/load` + `DELETE`, `/v1/stats` and `/healthz`
//! endpoints, and a gateway that warm pre-loads checkpoint
//! directories, sheds with `429 + Retry-After` under queue or
//! projection-LRU pressure, and drains in-flight tickets on shutdown.
//! The `serve` CLI subcommand runs it; `serve-bench --wire` measures
//! it (`serving_wire` report section, CI-gated).
//!
//! ## Telemetry (`obs`)
//!
//! The [`obs`] subsystem is the serving stack's first-class telemetry
//! layer: per-request stage-timing spans ([`obs::Trace`]) carried by
//! the scheduler ticket through parse → admission → queue →
//! batch_assemble → cache_plan → pack → gemm → reply, aggregated into
//! per-stage log₂-µs histograms keyed by request class and adapter
//! method; a hand-rolled Prometheus text-format exposition at
//! `GET /metrics`; and a lock-striped slow-request ring behind
//! `GET /v1/debug/slow`.  Knobs live in the `[obs]` config table with
//! `COSA_OBS_*` env overrides; `serve-bench --obs` (scenario 8) gates
//! traced throughput ≥ 0.95× untraced.
//!
//! ## Offline builds
//!
//! The workspace compiles with no network: `anyhow` and `xla` resolve to
//! vendored path crates under `rust/vendor/` (the `xla` stub executes
//! nothing — artifact-dependent tests and tools skip cleanly, exactly as
//! they do when `artifacts/` has not been built).

pub mod adapters;
pub mod config;
pub mod data;
pub mod eval;
pub mod exp;
pub mod linalg;
pub mod math;
pub mod model;
pub mod obs;
pub mod rip;
pub mod runtime;
pub mod serve;
pub mod train;
pub mod util;
pub mod wire;

/// Crate-wide result type (anyhow-backed, like the rest of the stack).
pub type Result<T> = anyhow::Result<T>;
