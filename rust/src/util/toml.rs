//! TOML-subset parser for run configs (`configs/*.toml`).
//!
//! Supports: `[section]` / `[section.sub]` tables, `key = value` with
//! string / integer / float / bool / flat-array values, `#` comments.
//! That subset covers every config this framework ships; anything fancier
//! fails loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            TomlValue::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed config: dotted-path key → value (e.g. `train.lr`).
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(src: &str) -> anyhow::Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut prefix = String::new();
        for (ln, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: bad table", ln + 1))?
                    .trim();
                if name.is_empty() {
                    anyhow::bail!("line {}: empty table name", ln + 1);
                }
                prefix = format!("{name}.");
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected `=`", ln + 1))?;
            let key = line[..eq].trim();
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", ln + 1))?;
            doc.entries.insert(format!("{prefix}{key}"), val);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }
    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> anyhow::Result<TomlValue> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("unterminated array"))?;
        let mut vals = Vec::new();
        for part in split_top(inner) {
            let p = part.trim();
            if !p.is_empty() {
                vals.push(parse_value(p)?);
            }
        }
        return Ok(TomlValue::Arr(vals));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    anyhow::bail!("cannot parse value `{s}`")
}

/// Split on commas not inside quotes (flat arrays only).
fn split_top(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
# run config
name = "glue-mrpc"   # inline comment
[train]
lr = 3e-5
epochs = 30
clip = 1.0
use_cosine = true
[method]
method = "cosa"
ab = [128, 56]
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "glue-mrpc");
        assert_eq!(doc.f64_or("train.lr", 0.0), 3e-5);
        assert_eq!(doc.i64_or("train.epochs", 0), 30);
        assert!(doc.bool_or("train.use_cosine", false));
        let ab = doc.get("method.ab").unwrap();
        match ab {
            TomlValue::Arr(v) => {
                assert_eq!(v[0].as_i64(), Some(128));
                assert_eq!(v[1].as_i64(), Some(56));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse("tag = \"a#b\"").unwrap();
        assert_eq!(doc.str_or("tag", ""), "a#b");
    }

    #[test]
    fn errors_are_line_numbered() {
        let err = TomlDoc::parse("x 5").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn defaults_apply() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.f64_or("train.lr", 1e-4), 1e-4);
    }
}
