//! Leveled stderr logging with wall-clock timestamps relative to start.
//!
//! `COSA_LOG=debug|info|warn|error` selects verbosity (default
//! `info`).  `COSA_LOG_FORMAT=json` switches every line to a single
//! JSON object (`{"t":…,"level":…,"msg":…}` plus `"req"` when a
//! request trace is in scope) with `wire::json`-style string escaping
//! — the text format stays the default for humans at a terminal.
//!
//! Request-path call sites that hold an `obs::Trace` log through
//! [`log_req`] so the request id lands on the line (text format:
//! `[… WRN req 00000000000000a3] …`).

use std::sync::OnceLock;
use std::time::Instant;

#[derive(PartialEq, PartialOrd, Clone, Copy, Debug)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static START: OnceLock<Instant> = OnceLock::new();
static LEVEL: OnceLock<Level> = OnceLock::new();
static JSON: OnceLock<bool> = OnceLock::new();

fn level() -> Level {
    *LEVEL.get_or_init(|| match std::env::var("COSA_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    })
}

fn json_format() -> bool {
    *JSON.get_or_init(|| {
        matches!(
            std::env::var("COSA_LOG_FORMAT").as_deref(),
            Ok("json")
        )
    })
}

/// JSON string escaping (the `wire::json::JsonWriter` rules: control
/// characters, quote and backslash; `util` stays independent of
/// `wire` so the escaper is local).
fn push_json_escaped(out: &mut String, s: &str) {
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

pub fn log(lvl: Level, msg: &str) {
    log_req(lvl, None, msg);
}

/// Log with an optional request id (from the in-scope `obs::Trace`).
pub fn log_req(lvl: Level, req: Option<u64>, msg: &str) {
    if lvl < level() {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    if json_format() {
        let level_name = match lvl {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        };
        let mut line = String::with_capacity(msg.len() + 48);
        line.push_str(&format!("{{\"t\":{t:.2},\"level\":\""));
        line.push_str(level_name);
        line.push('"');
        if let Some(id) = req {
            line.push_str(&format!(",\"req\":\"{id:016x}\""));
        }
        line.push_str(",\"msg\":\"");
        push_json_escaped(&mut line, msg);
        line.push_str("\"}");
        eprintln!("{line}");
        return;
    }
    let tag = match lvl {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    match req {
        Some(id) => eprintln!("[{t:8.2}s {tag} req {id:016x}] {msg}"),
        None => eprintln!("[{t:8.2}s {tag}] {msg}"),
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log(
        $crate::util::logging::Level::Info, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log(
        $crate::util::logging::Level::Debug, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::logging::log(
        $crate::util::logging::Level::Warn, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::logging::log(
        $crate::util::logging::Level::Error, &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert!(Level::Warn < Level::Error);
    }

    #[test]
    fn log_does_not_panic() {
        log(Level::Info, "hello from test");
        log_req(Level::Error, Some(0xa3), "with request id");
        crate::info!("macro path {}", 42);
        crate::error!("error macro path {}", 42);
    }

    #[test]
    fn json_escaping_covers_specials() {
        let mut s = String::new();
        push_json_escaped(&mut s, "a\"b\\c\nd\te\u{1}");
        assert_eq!(s, "a\\\"b\\\\c\\nd\\te\\u0001");
    }
}
