//! Leveled stderr logging with wall-clock timestamps relative to start.
//!
//! `COSA_LOG=debug|info|warn` selects verbosity (default `info`).

use std::sync::OnceLock;
use std::time::Instant;

#[derive(PartialEq, PartialOrd, Clone, Copy, Debug)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
}

static START: OnceLock<Instant> = OnceLock::new();
static LEVEL: OnceLock<Level> = OnceLock::new();

fn level() -> Level {
    *LEVEL.get_or_init(|| match std::env::var("COSA_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        _ => Level::Info,
    })
}

pub fn log(lvl: Level, msg: &str) {
    if lvl < level() {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match lvl {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
    };
    eprintln!("[{t:8.2}s {tag}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log(
        $crate::util::logging::Level::Info, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log(
        $crate::util::logging::Level::Debug, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::logging::log(
        $crate::util::logging::Level::Warn, &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
    }

    #[test]
    fn log_does_not_panic() {
        log(Level::Info, "hello from test");
        crate::info!("macro path {}", 42);
    }
}
