//! Self-built substrates that would normally come from crates.io — the
//! offline registry only carries the `xla` crate's closure, so the JSON
//! codec, TOML-subset config reader, CLI parser, property-test harness and
//! bench harness are implemented here (DESIGN.md S16/S17).

pub mod args;
pub mod bench;
pub mod json;
pub mod logging;
pub mod prop;
pub mod toml;
