//! Minimal JSON parser / writer (serde is unavailable offline).
//!
//! Covers the full JSON grammar; numbers are kept as `f64` plus an `i64`
//! fast path.  Used for `artifacts/*.json` metadata, checkpoints manifests
//! and experiment reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access: `j.get("inputs")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `get` that errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key `{key}`"))
    }

    pub fn parse(s: &str) -> anyhow::Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            anyhow::bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Compact serialization (stable key order — Obj is a BTreeMap).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}

/// Convenience constructor for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of json"))
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek()? != c {
            anyhow::bail!(
                "expected `{}` at byte {}, found `{}`",
                c as char,
                self.i,
                self.b[self.i] as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number `{s}` at byte {start}: {e}")
        })?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-decode multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let s =
                            std::str::from_utf8(&self.b[start..start + len])?;
                        out.push_str(s);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => anyhow::bail!("bad array sep `{}` at {}", c as char, self.i),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => anyhow::bail!("bad object sep `{}` at {}", c as char, self.i),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b >= 0xf0 {
        4
    } else if b >= 0xe0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(),
                   Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap(),
            &Json::Str("x".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"inputs":[{"dtype":"f32","name":"lr","shape":[]},{"name":"y","shape":[32,16]}],"kind":"train","n":42}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""δ_s ΔW""#).unwrap();
        assert_eq!(j, Json::Str("δ_s ΔW".into()));
        let round = Json::parse(&j.to_string()).unwrap();
        assert_eq!(round, j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" {\n \"a\" : [ 1 , 2 ] }\n").unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 2);
    }
}
