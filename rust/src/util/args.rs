//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `cosa-repro <subcommand> [positional…] [--flag[=| ]value…]`.
//! Bare `--flag` with no value is a boolean switch.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.flags.insert(flag.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(flag.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn bool(&self, key: &str) -> bool {
        self.flags.get(key).is_some_and(|v| v == "true" || v == "1")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --config configs/e2e.toml --steps=200 --verbose");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.str("config", ""), "configs/e2e.toml");
        assert_eq!(a.usize("steps", 0), 200);
        assert!(a.bool("verbose"));
    }

    #[test]
    fn positional_args() {
        let a = parse("exp table2 --seeds 3");
        assert_eq!(a.subcommand, "exp");
        assert_eq!(a.positional, vec!["table2"]);
        assert_eq!(a.usize("seeds", 1), 3);
    }

    #[test]
    fn space_separated_value() {
        let a = parse("rip --samples 500");
        assert_eq!(a.usize("samples", 0), 500);
    }

    #[test]
    fn trailing_boolean_flag() {
        let a = parse("bench --quick");
        assert!(a.bool("quick"));
    }

    #[test]
    fn negative_number_value() {
        // a value starting with '-' but not '--' is still a value
        let a = parse("train --offset -5");
        assert_eq!(a.str("offset", ""), "-5");
    }
}
