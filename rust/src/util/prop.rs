//! Mini property-testing harness (proptest is unavailable offline).
//!
//! Deterministic, seeded case generation with shrink-free minimal
//! reporting: on failure the failing case index and seed are printed so
//! the case can be replayed exactly.  Used by the invariant tests across
//! data/, train/, math/ and rip/.

use crate::math::rng::Pcg64;

/// Run `cases` random trials of `f`, feeding a seeded RNG.
/// Panics with the trial seed on the first failure.
pub fn for_all<F: FnMut(&mut Pcg64)>(name: &str, cases: usize, mut f: F) {
    for case in 0..cases {
        let seed = 0xC05A_0000 + case as u64;
        let mut rng = Pcg64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || f(&mut rng),
        ));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "panic".into());
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Uniform integer in `[lo, hi]` (inclusive).
pub fn int_in(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
    lo + (rng.next_u64() as usize) % (hi - lo + 1)
}

/// Random f32 vector with entries in [-scale, scale].
pub fn vec_f32(rng: &mut Pcg64, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| (rng.uniform() as f32 * 2.0 - 1.0) * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut n = 0;
        for_all("count", 25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn reports_failure_with_seed() {
        for_all("fails", 10, |rng| {
            assert!(int_in(rng, 0, 4) < 5); // passes
            assert!(int_in(rng, 5, 9) < 7, "too big"); // eventually fails
        });
    }

    #[test]
    fn int_in_bounds() {
        for_all("bounds", 50, |rng| {
            let v = int_in(rng, 3, 17);
            assert!((3..=17).contains(&v));
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        for_all("det-a", 5, |rng| a.push(rng.next_u64()));
        for_all("det-b", 5, |rng| b.push(rng.next_u64()));
        assert_eq!(a, b, "same per-case seeds must give same streams");
    }
}
