//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + timed iterations with mean / p50 / p99 reporting and a simple
//! throughput helper.  `cargo bench` binaries (`rust/benches/*.rs`, all
//! `harness = false`) drive this.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.min_ns),
        );
    }

    pub fn throughput(&self, items: f64, unit: &str) {
        let per_sec = items / (self.mean_ns * 1e-9);
        println!("{:<44} {:>26.1} {unit}/s", "", per_sec);
    }

    /// Compute rate for a kernel of `flops` floating-point operations
    /// per iteration (flops / mean-ns happens to be GFLOP/s exactly).
    pub fn gflops(&self, flops: f64) -> f64 {
        flops / self.mean_ns
    }

    /// Print the GFLOP/s line under the standard report row.
    pub fn report_gflops(&self, flops: f64) {
        println!("{:<44} {:>24.2} GFLOP/s", "", self.gflops(flops));
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` with automatic iteration-count calibration (~`budget_ms` total).
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    // warmup + calibrate
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((budget_ms as f64 * 1e6 / once) as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: samples[samples.len() / 2],
        p99_ns: samples[(samples.len() * 99) / 100],
        min_ns: samples[0],
    };
    res.report();
    res
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Merge one bench section into a machine-readable JSON report at
/// `path`, preserving other benches' sections (so `adapter_fwd` and
/// `e2e_step` can both write `BENCH_linalg.json`).
pub fn write_bench_json_at(path: &std::path::Path, section: &str,
                           entries: crate::util::json::Json) {
    use crate::util::json::Json;
    let mut root = match std::fs::read_to_string(path) {
        Ok(src) => match Json::parse(&src) {
            Ok(j) => j.as_obj().cloned().unwrap_or_default(),
            Err(e) => {
                eprintln!(
                    "warning: existing {} is not valid JSON ({e}); \
                     starting a fresh report — prior sections are lost",
                    path.display()
                );
                Default::default()
            }
        },
        Err(_) => Default::default(), // no existing report
    };
    root.insert(section.to_string(), entries);
    if let Err(e) = std::fs::write(path, Json::Obj(root).to_string()) {
        eprintln!("warning: cannot write {}: {e}", path.display());
    } else {
        println!("wrote section `{section}` to {}", path.display());
    }
}

/// Canonical location of the machine-readable bench report: the repo
/// root (found by walking up from the CWD to the first directory
/// holding `.git` or `BENCH_baseline.json`), falling back to the CWD.
/// `cargo bench` runs binaries with CWD at the *package* root (`rust/`),
/// which used to scatter reports across `rust/BENCH_linalg.json` and
/// the repo root depending on how the bench was launched; every writer
/// now resolves this single path, so CI and `tools/bench_regression.py`
/// read one file.
pub fn bench_report_path() -> std::path::PathBuf {
    let mut dir = std::env::current_dir()
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    loop {
        if dir.join(".git").exists() || dir.join("BENCH_baseline.json").exists()
        {
            return dir.join("BENCH_linalg.json");
        }
        if !dir.pop() {
            return std::path::PathBuf::from("BENCH_linalg.json");
        }
    }
}

/// `write_bench_json_at` against the canonical repo-root report (see
/// [`bench_report_path`]).
pub fn write_bench_json(section: &str, entries: crate::util::json::Json) {
    write_bench_json_at(&bench_report_path(), section, entries);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders_percentiles() {
        let r = bench("noop", 5, || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 3);
        assert!(r.p50_ns <= r.p99_ns);
        assert!(r.min_ns <= r.mean_ns * 1.5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with('s'));
    }

    #[test]
    fn gflops_units() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1_000_000.0, // 1 ms
            p50_ns: 0.0,
            p99_ns: 0.0,
            min_ns: 0.0,
        };
        // 2 GFLOP in 1 ms = 2000 GFLOP/s
        assert!((r.gflops(2e9) - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn bench_report_path_is_singular_and_named() {
        let p = bench_report_path();
        assert!(p.ends_with("BENCH_linalg.json"), "{}", p.display());
        // From anywhere inside the repo the path must resolve to the
        // repo root (the dir holding BENCH_baseline.json / .git), not
        // to the package dir cargo runs benches from.
        if let Some(parent) = p.parent() {
            if parent.as_os_str().is_empty() {
                return; // fallback path (no repo markers) — fine
            }
            assert!(
                parent.join(".git").exists()
                    || parent.join("BENCH_baseline.json").exists(),
                "not a repo root: {}",
                parent.display()
            );
        }
    }

    #[test]
    fn bench_json_sections_merge() {
        use crate::util::json::{obj, Json};
        let dir = std::env::temp_dir().join("cosa_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_linalg.json");
        let _ = std::fs::remove_file(&path);
        write_bench_json_at(&path, "a",
                            obj(vec![("v", Json::from(1usize))]));
        write_bench_json_at(&path, "b",
                            obj(vec![("v", Json::from(2usize))]));
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap())
            .unwrap();
        assert_eq!(j.get("a").unwrap().get("v").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("b").unwrap().get("v").unwrap().as_i64(), Some(2));
    }
}
