//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + timed iterations with mean / p50 / p99 reporting and a simple
//! throughput helper.  `cargo bench` binaries (`rust/benches/*.rs`, all
//! `harness = false`) drive this.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.min_ns),
        );
    }

    pub fn throughput(&self, items: f64, unit: &str) {
        let per_sec = items / (self.mean_ns * 1e-9);
        println!("{:<44} {:>26.1} {unit}/s", "", per_sec);
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` with automatic iteration-count calibration (~`budget_ms` total).
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    // warmup + calibrate
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((budget_ms as f64 * 1e6 / once) as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: samples[samples.len() / 2],
        p99_ns: samples[(samples.len() * 99) / 100],
        min_ns: samples[0],
    };
    res.report();
    res
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders_percentiles() {
        let r = bench("noop", 5, || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 3);
        assert!(r.p50_ns <= r.p99_ns);
        assert!(r.min_ns <= r.mean_ns * 1.5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with('s'));
    }
}
