// lint: allow-file(panic) — bench driver, not a request path: a panic aborts the measurement run loudly instead of producing a silently wrong report.
//! Synthetic open-loop serving workloads — the drivers behind the
//! `serve-bench` CLI subcommand and `benches/serve_bench.rs`.
//!
//! Three scenario families:
//!
//! * [`run`] — the PR-3 single-site workload (`serving` report
//!   section): one site, many adapters, Zipf-skewed popularity.  Each
//!   run measures the same request sequence **sequentially** (one
//!   allocating forward per request on the caller thread — the
//!   no-engine baseline) and **batched** (through the
//!   [`Server`](super::Server) scheduler); the throughput ratio is the
//!   CI acceptance gate (batched >= 1.5x sequential at 64 adapters).
//! * [`run_model`] — the multi-site workload (`serving_model`
//!   section): a whole [`ModelSpec`] (e.g. 24 heterogeneous sites) × N
//!   adapters, Zipf over adapters, every request touching every site.
//!   Besides sequential-vs-batched it measures the **shared-cache vs
//!   per-site-cache** claim: the same request sequence driven through
//!   one `AdaptedModel` (one LRU budget arbitrating all sites) versus
//!   through per-site single-site models splitting the same budget
//!   evenly.  CI gates `shared_vs_persite` — a shared budget must not
//!   lose to static partitioning (it amortizes residency across
//!   heterogeneous sites; the paper's seed-regenerable projections are
//!   what make the cache cheap to refill at all).
//! * [`run_methods`] — the cross-method comparison table (the
//!   `serving_methods` section): one mixed-method model (24 sites ×
//!   N adapters *per method*: CoSA, RoSA, LoRA — the paper's baseline
//!   set) serving per-method Zipf streams plus a mixed stream whose
//!   fused batches interleave all three methods.  One row per method
//!   and one `mixed` row, each with its own
//!   sequential-vs-batched ratio (CI gates every row's
//!   `batched_vs_sequential`) and the per-adapter
//!   param/resident/regen-byte accounting the methods differ on.
//! * [`run_tail`] — the tail-heavy fused-batching workload
//!   (`serving_tail` section): 24 sites × 512 adapters at Zipf s=1.0,
//!   where most adapters see a handful of requests.  The identical
//!   request stream runs through a **fused** server (cross-adapter
//!   rows share grouped block-diagonal GEMM batches) and a
//!   `fused = false` server that emulates the old per-adapter-segment
//!   batching; CI gates `fused_vs_per_adapter >= 1.5`
//!   machine-independently (two walls of the same binary on the same
//!   box).
//! * [`run_quant`] — the quantized-cache workload (`serving_quant`
//!   section): the model-bench spec at a deliberately **thrashing**
//!   LRU budget, one identical Zipf stream re-driven under each cache
//!   codec (f32, bf16, int8 — `[serve] cache_quant`).  Per codec it
//!   reports the end-of-run resident tensor count (the
//!   effective-capacity measure: bf16 fits ~2x the tensors of f32 in
//!   the same bytes, int8 ~3-4x), the hit rate over the measured
//!   stream, and the relative output RMSE against the f32 pass.  CI
//!   gates `capacity_vs_f32 >= 1.8` for bf16 and a per-codec RMSE
//!   bound — both machine-independent (deterministic sequential drive;
//!   the capacity and hit counters are exact integers).
//!
//! * [`run_obs`] — the telemetry-overhead workload (`serving_obs`
//!   section): the single-site acceptance shape driven through two
//!   servers of the same binary — one with tracing disabled, one with
//!   a live [`obs::Registry`](crate::obs::Registry) stamping every
//!   stage of every request — in interleaved passes (min wall per
//!   variant).  CI gates `traced_vs_untraced >= 0.95`: full tracing
//!   must cost under 5% throughput.  The traced pass also reports
//!   per-stage p99s and the slow-ring capture count, so the report
//!   doubles as a smoke check that the spans actually populate.
//!
//! Reported per scenario: wall-clock throughput, p50/p95/p99 request
//! latency (submit -> worker completion), mean batch occupancy,
//! projection-cache statistics, and (for models) the
//! `adapters::costmodel` storage aggregation.  `to_json` emits rows
//! for the canonical `BENCH_linalg.json`, which
//! `tools/bench_regression.py` gates against `BENCH_baseline.json`.

use std::time::{Duration, Instant};

use crate::adapters::{costmodel, Method};
use crate::config::ServeConfig;
use crate::linalg::QuantKind;
use crate::math::matrix::Matrix;
use crate::math::rng::Pcg64;
use crate::model::{AdaptedModel, CacheStats, ModelSpec, SiteShape};
use crate::model::CoreInput;
use crate::serve::scheduler::{Server, Ticket};
use crate::util::bench::black_box;
use crate::util::json::{obj, Json};

/// Single-site workload description.  `rate = 0` means open-loop
/// firehose: every request is enqueued as fast as `submit` allows (the
/// throughput measurement); a positive rate paces arrivals at `rate`
/// requests/sec.
#[derive(Clone, Debug)]
pub struct ServeBenchOpts {
    pub adapters: usize,
    pub requests: usize,
    /// Zipf skew exponent for adapter popularity (1.1 = acceptance).
    pub zipf: f64,
    /// Arrival rate in requests/sec; 0 = firehose.
    pub rate: f64,
    pub site: SiteShape,
    /// Core dims shared by every synthetic adapter.
    pub core_a: usize,
    pub core_b: usize,
    pub seed: u64,
    pub cfg: ServeConfig,
}

impl Default for ServeBenchOpts {
    fn default() -> Self {
        ServeBenchOpts {
            adapters: 64,
            requests: 2048,
            zipf: 1.1,
            rate: 0.0,
            site: SiteShape { m: 256, n: 256 },
            core_a: 64,
            core_b: 48,
            seed: 11,
            cfg: ServeConfig::default(),
        }
    }
}

/// One measured single-site scenario (a `serving` bench row).
#[derive(Clone, Debug)]
pub struct ServeBenchReport {
    pub opts: ServeBenchOpts,
    /// Workers the server actually spawned (after auto resolution).
    pub workers: usize,
    pub seq_wall_s: f64,
    pub batched_wall_s: f64,
    pub seq_throughput_rps: f64,
    pub throughput_rps: f64,
    /// The acceptance metric: batched / sequential throughput.
    pub batched_vs_sequential: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_batch_rows: f64,
    pub cache: CacheStats,
}

impl ServeBenchReport {
    pub fn to_json(&self) -> Json {
        let o = &self.opts;
        obj(vec![
            ("adapters", o.adapters.into()),
            ("requests", o.requests.into()),
            ("zipf", o.zipf.into()),
            ("rate_rps", o.rate.into()),
            ("site_m", o.site.m.into()),
            ("site_n", o.site.n.into()),
            ("core_a", o.core_a.into()),
            ("core_b", o.core_b.into()),
            ("max_batch", o.cfg.max_batch.into()),
            ("max_wait_us", (o.cfg.max_wait_us as usize).into()),
            ("workers", self.workers.into()),
            ("cache_mb", o.cfg.cache_mb.into()),
            ("seq_wall_s", self.seq_wall_s.into()),
            ("batched_wall_s", self.batched_wall_s.into()),
            ("seq_throughput_rps", self.seq_throughput_rps.into()),
            ("throughput_rps", self.throughput_rps.into()),
            ("batched_vs_sequential", self.batched_vs_sequential.into()),
            ("mean_ms", self.mean_ms.into()),
            ("p50_ms", self.p50_ms.into()),
            ("p95_ms", self.p95_ms.into()),
            ("p99_ms", self.p99_ms.into()),
            ("mean_batch_rows", self.mean_batch_rows.into()),
            ("cache_hits", (self.cache.hits as usize).into()),
            ("cache_misses", (self.cache.misses as usize).into()),
            ("cache_evictions", (self.cache.evictions as usize).into()),
        ])
    }

    pub fn print(&self) {
        let o = &self.opts;
        println!(
            "serve[{} adapters, zipf {:.2}, {} reqs, batch<= {}, \
             wait {}us, {} workers]",
            o.adapters, o.zipf, o.requests, o.cfg.max_batch,
            o.cfg.max_wait_us, self.workers
        );
        println!(
            "  sequential  {:>10.0} req/s   ({:.3} s wall)",
            self.seq_throughput_rps, self.seq_wall_s
        );
        println!(
            "  batched     {:>10.0} req/s   ({:.3} s wall)  => {:.2}x",
            self.throughput_rps, self.batched_wall_s,
            self.batched_vs_sequential
        );
        println!(
            "  latency ms  mean {:.3}  p50 {:.3}  p95 {:.3}  p99 {:.3}",
            self.mean_ms, self.p50_ms, self.p95_ms, self.p99_ms
        );
        println!(
            "  mean batch rows {:.2}   cache hits {} misses {} \
             evictions {}",
            self.mean_batch_rows, self.cache.hits, self.cache.misses,
            self.cache.evictions
        );
    }
}

/// Zipf-over-ranks sampler: item `i` has weight `1 / (i+1)^s`.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(items: usize, s: f64) -> Zipf {
        assert!(items > 0, "zipf over zero items");
        let mut cdf = Vec::with_capacity(items);
        let mut acc = 0.0f64;
        for i in 0..items {
            acc += 1.0 / ((i + 1) as f64).powf(s);
            cdf.push(acc);
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let total = *self.cdf.last().unwrap();
        let u = rng.uniform() * total;
        // first index whose cumulative weight exceeds u
        self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1)
    }
}

/// Nearest-rank percentile: the smallest sample with at least `p` of
/// the distribution at or below it (`ceil(n*p)` ranks, 1-based — so
/// p50 of [a, b] is `a`, and p99 of 100 samples is rank 99, not the
/// single worst outlier).
pub(crate) fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let rank = ((sorted_ms.len() as f64) * p).ceil() as usize;
    sorted_ms[rank.saturating_sub(1).min(sorted_ms.len() - 1)]
}

/// Rows of pre-generated activations the request loops cycle through
/// (so input generation never dominates the measurement).
pub(crate) const X_POOL: usize = 32;

/// Build the synthetic single-site registry the single-site scenarios
/// (and the wire bench) serve: `adapters` distinct-seeded adapters
/// with sparse-ish cores and per-adapter projection stems.  The build
/// is deterministic in `seed`, so two calls produce bit-identical
/// registries — the wire bench relies on that to compare an
/// in-process engine against a gateway-served copy.
pub(crate) fn synthetic_registry(
    adapters: usize,
    site: SiteShape,
    core_a: usize,
    core_b: usize,
    seed: u64,
    cache_budget_bytes: usize,
) -> anyhow::Result<(AdaptedModel, Vec<String>)> {
    let mut registry = AdaptedModel::single_site(
        "bench", site, core_a, core_b, cache_budget_bytes,
    );
    let mut rng = Pcg64::new(seed);
    let mut names = Vec::with_capacity(adapters);
    for i in 0..adapters {
        let name = format!("adp{i:03}");
        let aseed = seed.wrapping_add(1 + i as u64);
        let y = Matrix::gaussian(core_a, core_b, 0.02, &mut rng);
        registry.insert(
            &name,
            aseed,
            2.0,
            vec![CoreInput::new(
                &format!("{name}.l"),
                &format!("{name}.r"),
                y,
            )],
        )?;
        names.push(name);
    }
    Ok((registry, names))
}

/// Run one single-site scenario (see module docs).  `opts.cfg` is taken
/// as final — apply `env_overridden()` / preset resolution at the call
/// site.
pub fn run(opts: &ServeBenchOpts) -> anyhow::Result<ServeBenchReport> {
    anyhow::ensure!(opts.adapters > 0, "need at least one adapter");
    anyhow::ensure!(opts.requests > 0, "need at least one request");
    anyhow::ensure!(
        opts.site.m >= 1 && opts.site.n >= 1,
        "site must be at least 1x1 (got {}x{})",
        opts.site.m,
        opts.site.n
    );
    anyhow::ensure!(
        opts.core_a >= 1 && opts.core_b >= 1,
        "core must be at least 1x1 (got {}x{})",
        opts.core_a,
        opts.core_b
    );
    let n = opts.site.n;
    // The workload stream is distinct from the registry-construction
    // stream (`synthetic_registry` starts its own `Pcg64::new(seed)`),
    // so the request pattern never re-reads the raw u64s behind the
    // adapter weights.
    let mut rng = Pcg64::with_stream(opts.seed, 1);

    // Registry of synthetic adapters: distinct seeds, shared site/core
    // shape, sparse-ish cores (the trained-Y regime).  Per-adapter
    // tensor stems keep every adapter's projections distinct in the
    // shared cache even across equal seeds.
    let budget = opts.cfg.cache_budget_bytes();
    let (mut registry, names) = synthetic_registry(
        opts.adapters,
        opts.site,
        opts.core_a,
        opts.core_b,
        opts.seed,
        budget,
    )?;

    // Zipf-skewed request sequence + a small pool of activation rows.
    let zipf = Zipf::new(opts.adapters, opts.zipf);
    let seq: Vec<usize> =
        (0..opts.requests).map(|_| zipf.sample(&mut rng)).collect();
    let pool: Vec<Vec<f32>> = (0..X_POOL)
        .map(|_| rng.normal_vec(n, 1.0))
        .collect();

    // Warm every adapter's projections once so the two timed passes see
    // the same cache state (regeneration cost is measured by
    // `benches/adapter_fwd.rs`, not here).
    for name in &names {
        let x = Matrix::from_vec(1, n, pool[0].clone());
        black_box(registry.forward_one(name, &x)?);
    }

    // -- sequential baseline: one single-row forward per request --
    let t0 = Instant::now();
    for (j, &idx) in seq.iter().enumerate() {
        let x = Matrix::from_vec(1, n, pool[j % X_POOL].clone());
        let o = registry.forward_one(&names[idx], &x)?;
        black_box(o.data[0]);
    }
    let seq_wall_s = t0.elapsed().as_secs_f64();

    // -- batched: the same sequence through the scheduler --
    registry.reset_cache_stats();
    let server = Server::new(registry, &opts.cfg);
    let workers = server.worker_count();
    let model_arc = server.model();
    let interval = if opts.rate > 0.0 {
        Some(Duration::from_secs_f64(1.0 / opts.rate))
    } else {
        None
    };
    let t0 = Instant::now();
    let mut tickets: Vec<Ticket> = Vec::with_capacity(opts.requests);
    for (j, &idx) in seq.iter().enumerate() {
        if let Some(dt) = interval {
            let target = t0 + dt.mul_f64(j as f64);
            let now = Instant::now();
            if target > now {
                std::thread::sleep(target - now);
            }
        }
        tickets
            .push(server.submit_row(&names[idx], pool[j % X_POOL].clone())?);
    }
    let mut lat_ms: Vec<f64> = Vec::with_capacity(opts.requests);
    for t in tickets {
        let submitted = t.submitted;
        let resp = t.wait()?;
        black_box(resp.output()[0]);
        lat_ms.push(
            resp.done.duration_since(submitted).as_secs_f64() * 1e3,
        );
    }
    let batched_wall_s = t0.elapsed().as_secs_f64();
    let (batches, rows) = server.batch_stats();
    drop(server);
    let cache = {
        let reg = model_arc.lock().unwrap_or_else(|p| p.into_inner());
        reg.cache_stats()
    };

    lat_ms.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let mean_ms = lat_ms.iter().sum::<f64>() / lat_ms.len() as f64;
    let seq_tp = opts.requests as f64 / seq_wall_s.max(1e-9);
    let tp = opts.requests as f64 / batched_wall_s.max(1e-9);
    Ok(ServeBenchReport {
        opts: opts.clone(),
        workers,
        seq_wall_s,
        batched_wall_s,
        seq_throughput_rps: seq_tp,
        throughput_rps: tp,
        batched_vs_sequential: tp / seq_tp.max(1e-9),
        mean_ms,
        p50_ms: percentile(&lat_ms, 0.50),
        p95_ms: percentile(&lat_ms, 0.95),
        p99_ms: percentile(&lat_ms, 0.99),
        mean_batch_rows: rows as f64 / (batches as f64).max(1.0),
        cache,
    })
}

/// Multi-site workload description (always firehose — the model
/// scenario measures engine + cache behavior, not pacing).
#[derive(Clone, Debug)]
pub struct ModelBenchOpts {
    pub spec: ModelSpec,
    pub adapters: usize,
    pub requests: usize,
    pub zipf: f64,
    pub seed: u64,
    pub cfg: ServeConfig,
}

impl Default for ModelBenchOpts {
    fn default() -> Self {
        // The acceptance scenario: 24 heterogeneous sites × 64
        // adapters.  The cache budget is deliberately *under* the total
        // projection working set (~12 MiB at these dims) so the
        // shared-vs-per-site comparison measures residency arbitration,
        // not an everything-fits no-op.
        ModelBenchOpts {
            spec: ModelSpec::synthetic(
                24, SiteShape { m: 96, n: 96 }, 16, 12),
            adapters: 64,
            requests: 512,
            zipf: 1.1,
            seed: 11,
            cfg: ServeConfig { cache_mb: 8.0, ..ServeConfig::default() },
        }
    }
}

/// One measured multi-site scenario (a `serving_model` bench row).
/// A "request" here is one whole-model forward: every site of the
/// adapter, so `throughput_rps` counts model-requests, not site-matmuls.
#[derive(Clone, Debug)]
pub struct ModelBenchReport {
    pub opts: ModelBenchOpts,
    pub workers: usize,
    /// Per-adapter trainable params across the model (Σ a·b).
    pub core_params: usize,
    /// Per-adapter storage bytes (cores + one seed —
    /// `costmodel::spec_storage_bytes`).
    pub adapter_bytes: usize,
    pub seq_wall_s: f64,
    pub persite_wall_s: f64,
    pub batched_wall_s: f64,
    pub seq_throughput_rps: f64,
    pub persite_throughput_rps: f64,
    pub throughput_rps: f64,
    pub batched_vs_sequential: f64,
    /// Shared-LRU sequential throughput / per-site-partitioned caches
    /// sequential throughput (the machine-independent CI gate).
    pub shared_vs_persite: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_batch_rows: f64,
    pub cache: CacheStats,
}

impl ModelBenchReport {
    pub fn to_json(&self) -> Json {
        let o = &self.opts;
        obj(vec![
            ("sites", o.spec.len().into()),
            ("adapters", o.adapters.into()),
            ("requests", o.requests.into()),
            ("zipf", o.zipf.into()),
            ("rate_rps", Json::Num(0.0)),
            ("core_params", self.core_params.into()),
            ("adapter_bytes", self.adapter_bytes.into()),
            ("max_batch", o.cfg.max_batch.into()),
            ("max_wait_us", (o.cfg.max_wait_us as usize).into()),
            ("workers", self.workers.into()),
            ("cache_mb", o.cfg.cache_mb.into()),
            ("seq_wall_s", self.seq_wall_s.into()),
            ("persite_wall_s", self.persite_wall_s.into()),
            ("batched_wall_s", self.batched_wall_s.into()),
            ("seq_throughput_rps", self.seq_throughput_rps.into()),
            ("persite_throughput_rps", self.persite_throughput_rps.into()),
            ("throughput_rps", self.throughput_rps.into()),
            ("batched_vs_sequential", self.batched_vs_sequential.into()),
            ("shared_vs_persite", self.shared_vs_persite.into()),
            ("mean_ms", self.mean_ms.into()),
            ("p50_ms", self.p50_ms.into()),
            ("p95_ms", self.p95_ms.into()),
            ("p99_ms", self.p99_ms.into()),
            ("mean_batch_rows", self.mean_batch_rows.into()),
            ("cache_hits", (self.cache.hits as usize).into()),
            ("cache_misses", (self.cache.misses as usize).into()),
            ("cache_evictions", (self.cache.evictions as usize).into()),
        ])
    }

    pub fn print(&self) {
        let o = &self.opts;
        println!(
            "serve-model[{} sites x {} adapters, zipf {:.2}, {} reqs, \
             batch<= {}, {} workers, cache {:.1} MiB]",
            o.spec.len(), o.adapters, o.zipf, o.requests,
            o.cfg.max_batch, self.workers, o.cfg.cache_mb
        );
        println!(
            "  adapter: {} core params, {} bytes on disk (cores + seed)",
            self.core_params, self.adapter_bytes
        );
        println!(
            "  sequential (shared LRU)    {:>9.0} req/s  ({:.3} s wall)",
            self.seq_throughput_rps, self.seq_wall_s
        );
        println!(
            "  sequential (per-site LRU)  {:>9.0} req/s  ({:.3} s wall)  \
             shared/persite {:.2}x",
            self.persite_throughput_rps, self.persite_wall_s,
            self.shared_vs_persite
        );
        println!(
            "  batched                    {:>9.0} req/s  ({:.3} s wall)  \
             => {:.2}x sequential",
            self.throughput_rps, self.batched_wall_s,
            self.batched_vs_sequential
        );
        println!(
            "  latency ms  mean {:.3}  p50 {:.3}  p95 {:.3}  p99 {:.3}",
            self.mean_ms, self.p50_ms, self.p95_ms, self.p99_ms
        );
        println!(
            "  mean batch rows {:.2}   cache hits {} misses {} \
             evictions {}",
            self.mean_batch_rows, self.cache.hits, self.cache.misses,
            self.cache.evictions
        );
    }
}

/// Run one multi-site scenario (see module docs).  `opts.cfg` is taken
/// as final, exactly like [`run`].
pub fn run_model(opts: &ModelBenchOpts) -> anyhow::Result<ModelBenchReport> {
    anyhow::ensure!(opts.adapters > 0, "need at least one adapter");
    anyhow::ensure!(opts.requests > 0, "need at least one request");
    opts.spec.validate()?;
    let spec = &opts.spec;
    let n_sites = spec.len();
    let budget = opts.cfg.cache_budget_bytes();
    let mut rng = Pcg64::new(opts.seed);

    // One core set per adapter, shared verbatim between the shared-LRU
    // model and the per-site baseline models so both serve identical
    // math.
    let mut names = Vec::with_capacity(opts.adapters);
    let mut cores: Vec<Vec<Matrix>> = Vec::with_capacity(opts.adapters);
    for i in 0..opts.adapters {
        names.push(format!("adp{i:03}"));
        cores.push(
            spec.sites
                .iter()
                .map(|s| Matrix::gaussian(s.a, s.b, 0.02, &mut rng))
                .collect(),
        );
    }
    let seed_of = |i: usize| opts.seed.wrapping_add(1 + i as u64);

    let mut shared = AdaptedModel::new(spec.clone(), budget)?;
    for (i, name) in names.iter().enumerate() {
        shared.insert_synthetic(name, seed_of(i), 2.0, cores[i].clone())?;
    }
    // Per-site baseline: one single-site model per site, the same
    // total byte budget statically partitioned.
    let mut persite: Vec<AdaptedModel> = Vec::with_capacity(n_sites);
    for (s_idx, site) in spec.sites.iter().enumerate() {
        let one = ModelSpec::new(&site.name, vec![site.clone()])?;
        let mut m = AdaptedModel::new(one, budget / n_sites.max(1))?;
        for (i, name) in names.iter().enumerate() {
            m.insert_synthetic(name, seed_of(i), 2.0,
                               vec![cores[i][s_idx].clone()])?;
        }
        persite.push(m);
    }

    // Zipf request sequence + per-site activation row pools.
    let zipf = Zipf::new(opts.adapters, opts.zipf);
    let seq: Vec<usize> =
        (0..opts.requests).map(|_| zipf.sample(&mut rng)).collect();
    let xs_pool: Vec<Vec<Matrix>> = (0..X_POOL)
        .map(|_| {
            spec.sites
                .iter()
                .map(|s| {
                    Matrix::from_vec(1, s.shape.n,
                                     rng.normal_vec(s.shape.n, 1.0))
                })
                .collect()
        })
        .collect();

    // Warm both variants identically (every adapter once) so the timed
    // passes start from the same steady cache state.
    for name in &names {
        black_box(shared.forward(name, &xs_pool[0])?);
        for (s, m) in persite.iter_mut().enumerate() {
            black_box(m.forward_one(name, &xs_pool[0][s])?);
        }
    }

    // -- sequential, shared LRU --
    let t0 = Instant::now();
    for (j, &idx) in seq.iter().enumerate() {
        let outs = shared.forward(&names[idx], &xs_pool[j % X_POOL])?;
        black_box(outs[0].data[0]);
    }
    let seq_wall_s = t0.elapsed().as_secs_f64();

    // -- sequential, per-site partitioned LRUs --
    let t0 = Instant::now();
    for (j, &idx) in seq.iter().enumerate() {
        for (s, m) in persite.iter_mut().enumerate() {
            let o = m.forward_one(&names[idx], &xs_pool[j % X_POOL][s])?;
            black_box(o.data[0]);
        }
    }
    let persite_wall_s = t0.elapsed().as_secs_f64();
    drop(persite);

    // -- batched: the same sequence through the scheduler --
    shared.reset_cache_stats();
    let server = Server::new(shared, &opts.cfg);
    let workers = server.worker_count();
    let model_arc = server.model();
    let t0 = Instant::now();
    let mut tickets: Vec<Ticket> = Vec::with_capacity(opts.requests);
    for (j, &idx) in seq.iter().enumerate() {
        let xs: Vec<Vec<f32>> = xs_pool[j % X_POOL]
            .iter()
            .map(|m| m.data.clone())
            .collect();
        tickets.push(server.submit(&names[idx], xs)?);
    }
    let mut lat_ms: Vec<f64> = Vec::with_capacity(opts.requests);
    for t in tickets {
        let submitted = t.submitted;
        let resp = t.wait()?;
        black_box(resp.output()[0]);
        lat_ms.push(
            resp.done.duration_since(submitted).as_secs_f64() * 1e3,
        );
    }
    let batched_wall_s = t0.elapsed().as_secs_f64();
    let (batches, rows) = server.batch_stats();
    drop(server);
    let cache = {
        let m = model_arc.lock().unwrap_or_else(|p| p.into_inner());
        m.cache_stats()
    };

    lat_ms.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let mean_ms = lat_ms.iter().sum::<f64>() / lat_ms.len() as f64;
    let reqs = opts.requests as f64;
    let seq_tp = reqs / seq_wall_s.max(1e-9);
    let persite_tp = reqs / persite_wall_s.max(1e-9);
    let tp = reqs / batched_wall_s.max(1e-9);
    Ok(ModelBenchReport {
        opts: opts.clone(),
        workers,
        core_params: spec.core_params(),
        adapter_bytes: costmodel::spec_storage_bytes(spec),
        seq_wall_s,
        persite_wall_s,
        batched_wall_s,
        seq_throughput_rps: seq_tp,
        persite_throughput_rps: persite_tp,
        throughput_rps: tp,
        batched_vs_sequential: tp / seq_tp.max(1e-9),
        shared_vs_persite: seq_tp / persite_tp.max(1e-9),
        mean_ms,
        p50_ms: percentile(&lat_ms, 0.50),
        p95_ms: percentile(&lat_ms, 0.95),
        p99_ms: percentile(&lat_ms, 0.99),
        mean_batch_rows: rows as f64 / (batches as f64).max(1.0),
        cache,
    })
}

/// Tail-heavy fused-batching workload description (always firehose).
/// The scenario this measures: a long Zipf tail of adapters, where the
/// old per-adapter batcher degenerates to single-row batches (a tail
/// adapter rarely has a queue-mate of its own id) while the fused
/// batcher boards rows from *different* adapters into one grouped
/// block-diagonal GEMM sweep.
#[derive(Clone, Debug)]
pub struct TailBenchOpts {
    pub spec: ModelSpec,
    pub adapters: usize,
    pub requests: usize,
    pub zipf: f64,
    pub seed: u64,
    /// `cfg.fused` is overridden per measured variant (true for the
    /// fused pass, false for the per-adapter-segment baseline).
    pub cfg: ServeConfig,
}

impl Default for TailBenchOpts {
    fn default() -> Self {
        // The acceptance scenario: 24 heterogeneous sites × 512
        // adapters at Zipf s=1.0 — a heavy tail where most adapters
        // see a handful of requests.  The cache holds the whole
        // projection working set (~130 MiB), so the comparison
        // isolates batching policy rather than cache behavior.
        TailBenchOpts {
            spec: ModelSpec::synthetic(
                24, SiteShape { m: 96, n: 96 }, 16, 12),
            adapters: 512,
            requests: 2048,
            zipf: 1.0,
            seed: 17,
            cfg: ServeConfig {
                cache_mb: 256.0,
                max_batch: 32,
                max_wait_us: 500,
                ..ServeConfig::default()
            },
        }
    }
}

/// One measured tail scenario (a `serving_tail` bench row).
#[derive(Clone, Debug)]
pub struct TailBenchReport {
    pub opts: TailBenchOpts,
    pub workers: usize,
    pub fused_wall_s: f64,
    pub per_adapter_wall_s: f64,
    /// Fused throughput (model-requests/sec).
    pub throughput_rps: f64,
    pub per_adapter_throughput_rps: f64,
    /// The acceptance metric: fused / per-adapter throughput on the
    /// identical request stream.
    pub fused_vs_per_adapter: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub per_adapter_p99_ms: f64,
    pub mean_batch_rows: f64,
    pub per_adapter_mean_batch_rows: f64,
    pub cache: CacheStats,
}

impl TailBenchReport {
    pub fn to_json(&self) -> Json {
        let o = &self.opts;
        obj(vec![
            ("sites", o.spec.len().into()),
            ("adapters", o.adapters.into()),
            ("requests", o.requests.into()),
            ("zipf", o.zipf.into()),
            ("max_batch", o.cfg.max_batch.into()),
            ("max_wait_us", (o.cfg.max_wait_us as usize).into()),
            ("workers", self.workers.into()),
            ("cache_mb", o.cfg.cache_mb.into()),
            ("fused_wall_s", self.fused_wall_s.into()),
            ("per_adapter_wall_s", self.per_adapter_wall_s.into()),
            ("throughput_rps", self.throughput_rps.into()),
            (
                "per_adapter_throughput_rps",
                self.per_adapter_throughput_rps.into(),
            ),
            ("fused_vs_per_adapter", self.fused_vs_per_adapter.into()),
            ("mean_ms", self.mean_ms.into()),
            ("p50_ms", self.p50_ms.into()),
            ("p95_ms", self.p95_ms.into()),
            ("p99_ms", self.p99_ms.into()),
            ("per_adapter_p99_ms", self.per_adapter_p99_ms.into()),
            ("mean_batch_rows", self.mean_batch_rows.into()),
            (
                "per_adapter_mean_batch_rows",
                self.per_adapter_mean_batch_rows.into(),
            ),
            ("cache_hits", (self.cache.hits as usize).into()),
            ("cache_misses", (self.cache.misses as usize).into()),
            ("cache_evictions", (self.cache.evictions as usize).into()),
        ])
    }

    pub fn print(&self) {
        let o = &self.opts;
        println!(
            "serve-tail[{} sites x {} adapters, zipf {:.2}, {} reqs, \
             batch<= {}, {} workers, cache {:.0} MiB]",
            o.spec.len(), o.adapters, o.zipf, o.requests,
            o.cfg.max_batch, self.workers, o.cfg.cache_mb
        );
        println!(
            "  per-adapter  {:>9.0} req/s  ({:.3} s wall)  p99 {:.3} ms  \
             mean batch rows {:.2}",
            self.per_adapter_throughput_rps, self.per_adapter_wall_s,
            self.per_adapter_p99_ms, self.per_adapter_mean_batch_rows
        );
        println!(
            "  fused        {:>9.0} req/s  ({:.3} s wall)  p99 {:.3} ms  \
             mean batch rows {:.2}  => {:.2}x",
            self.throughput_rps, self.fused_wall_s, self.p99_ms,
            self.mean_batch_rows, self.fused_vs_per_adapter
        );
        println!(
            "  fused latency ms  mean {:.3}  p50 {:.3}  p95 {:.3}  \
             p99 {:.3}",
            self.mean_ms, self.p50_ms, self.p95_ms, self.p99_ms
        );
    }
}

/// Submit the whole Zipf sequence to `server` firehose-style and wait
/// every ticket out.  Returns (wall seconds, sorted latencies ms,
/// mean batch rows).
fn drive_tail(
    server: &Server,
    names: &[String],
    seq: &[usize],
    xs_pool: &[Vec<Matrix>],
) -> anyhow::Result<(f64, Vec<f64>, f64)> {
    let t0 = Instant::now();
    let mut tickets: Vec<Ticket> = Vec::with_capacity(seq.len());
    for (j, &idx) in seq.iter().enumerate() {
        let xs: Vec<Vec<f32>> = xs_pool[j % X_POOL]
            .iter()
            .map(|m| m.data.clone())
            .collect();
        tickets.push(server.submit(&names[idx], xs)?);
    }
    let mut lat_ms: Vec<f64> = Vec::with_capacity(seq.len());
    for t in tickets {
        let submitted = t.submitted;
        let resp = t.wait()?;
        black_box(resp.output()[0]);
        lat_ms.push(
            resp.done.duration_since(submitted).as_secs_f64() * 1e3,
        );
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let (batches, rows) = server.batch_stats();
    lat_ms.sort_by(|x, y| x.partial_cmp(y).unwrap());
    Ok((wall_s, lat_ms, rows as f64 / (batches as f64).max(1.0)))
}

/// Run one tail-heavy scenario: the identical Zipf request stream
/// through a fused server and a per-adapter-segment (`fused = false`)
/// server over two identically built models.  `opts.cfg` is taken as
/// final except for `fused`, which this function owns.
pub fn run_tail(opts: &TailBenchOpts) -> anyhow::Result<TailBenchReport> {
    anyhow::ensure!(opts.adapters > 0, "need at least one adapter");
    anyhow::ensure!(opts.requests > 0, "need at least one request");
    opts.spec.validate()?;
    let spec = &opts.spec;
    let budget = opts.cfg.cache_budget_bytes();
    let seed_of = |i: usize| opts.seed.wrapping_add(1 + i as u64);
    let names: Vec<String> =
        (0..opts.adapters).map(|i| format!("adp{i:03}")).collect();

    // Both variants serve bit-identically built models; the build is
    // deterministic in `opts.seed`.
    let build = || -> anyhow::Result<AdaptedModel> {
        let mut rng = Pcg64::new(opts.seed);
        let mut m = AdaptedModel::new(spec.clone(), budget)?;
        for (i, name) in names.iter().enumerate() {
            let cores: Vec<Matrix> = spec
                .sites
                .iter()
                .map(|s| Matrix::gaussian(s.a, s.b, 0.02, &mut rng))
                .collect();
            m.insert_synthetic(name, seed_of(i), 2.0, cores)?;
        }
        Ok(m)
    };

    // Shared Zipf sequence + activation pool, from a stream distinct
    // from the model build.
    let mut rng = Pcg64::with_stream(opts.seed, 1);
    let zipf = Zipf::new(opts.adapters, opts.zipf);
    let seq: Vec<usize> =
        (0..opts.requests).map(|_| zipf.sample(&mut rng)).collect();
    let xs_pool: Vec<Vec<Matrix>> = (0..X_POOL)
        .map(|_| {
            spec.sites
                .iter()
                .map(|s| {
                    Matrix::from_vec(1, s.shape.n,
                                     rng.normal_vec(s.shape.n, 1.0))
                })
                .collect()
        })
        .collect();

    let mut walls = [0.0f64; 2]; // [per-adapter, fused]
    let mut lats: [Vec<f64>; 2] = [Vec::new(), Vec::new()];
    let mut mean_rows = [0.0f64; 2];
    let mut workers = 0usize;
    let mut cache = CacheStats::default();
    for (slot, fused) in [(0usize, false), (1usize, true)] {
        let mut model = build()?;
        // Warm every adapter once so both passes start from the same
        // fully resident cache state.
        for name in &names {
            black_box(model.forward(name, &xs_pool[0])?);
        }
        model.reset_cache_stats();
        let cfg = ServeConfig { fused, ..opts.cfg.clone() };
        let server = Server::new(model, &cfg);
        workers = server.worker_count();
        let model_arc = server.model();
        let (wall, lat, rows) =
            drive_tail(&server, &names, &seq, &xs_pool)?;
        walls[slot] = wall;
        lats[slot] = lat;
        mean_rows[slot] = rows;
        drop(server);
        if fused {
            let m = model_arc.lock().unwrap_or_else(|p| p.into_inner());
            cache = m.cache_stats();
        }
    }

    let reqs = opts.requests as f64;
    let per_tp = reqs / walls[0].max(1e-9);
    let fused_tp = reqs / walls[1].max(1e-9);
    let fused_lat = &lats[1];
    let mean_ms =
        fused_lat.iter().sum::<f64>() / (fused_lat.len() as f64).max(1.0);
    Ok(TailBenchReport {
        opts: opts.clone(),
        workers,
        fused_wall_s: walls[1],
        per_adapter_wall_s: walls[0],
        throughput_rps: fused_tp,
        per_adapter_throughput_rps: per_tp,
        fused_vs_per_adapter: fused_tp / per_tp.max(1e-9),
        mean_ms,
        p50_ms: percentile(fused_lat, 0.50),
        p95_ms: percentile(fused_lat, 0.95),
        p99_ms: percentile(fused_lat, 0.99),
        per_adapter_p99_ms: percentile(&lats[0], 0.99),
        mean_batch_rows: mean_rows[1],
        per_adapter_mean_batch_rows: mean_rows[0],
        cache,
    })
}

/// Cross-method comparison workload description (always firehose).
/// One model holds `adapters_per_method` adapters of *each* servable
/// method; the scenario measures every method under the same engine
/// plus a mixed stream whose fused batches interleave methods.
#[derive(Clone, Debug)]
pub struct MethodsBenchOpts {
    pub spec: ModelSpec,
    /// Adapters inserted per servable method (CoSA, RoSA, LoRA).
    pub adapters_per_method: usize,
    /// Requests per measured stream (each per-method stream and the
    /// mixed stream submit this many whole-model requests).
    pub requests: usize,
    pub zipf: f64,
    pub seed: u64,
    pub cfg: ServeConfig,
}

impl Default for MethodsBenchOpts {
    fn default() -> Self {
        // The acceptance scenario: the 24-site model-bench spec, a
        // small fleet per method.  The cache holds CoSA's whole
        // projection working set — the comparison isolates each
        // method's compute path, not residency arbitration (that is
        // `run_model`'s job).
        MethodsBenchOpts {
            spec: ModelSpec::synthetic(
                24, SiteShape { m: 96, n: 96 }, 16, 12),
            adapters_per_method: 8,
            requests: 256,
            zipf: 1.1,
            seed: 13,
            cfg: ServeConfig { cache_mb: 64.0, ..ServeConfig::default() },
        }
    }
}

/// One measured stream of the cross-method scenario (a
/// `serving_methods` bench row): one servable method's Zipf stream,
/// or the `mixed` stream spanning every adapter of every method.
#[derive(Clone, Debug)]
pub struct MethodBenchRow {
    /// `"cosa"` / `"rosa"` / `"lora"` / `"mixed"`.
    pub label: String,
    pub adapters: usize,
    pub requests: usize,
    /// Whole-model trainable params of one adapter of this method
    /// (summed over every adapter for the mixed row).
    pub param_count: usize,
    /// Bytes the method must keep resident per adapter (mixed: sum).
    pub resident_bytes: usize,
    /// Bytes the method re-derives from seeds per adapter (mixed: sum).
    pub regen_bytes: usize,
    pub seq_wall_s: f64,
    pub batched_wall_s: f64,
    pub seq_throughput_rps: f64,
    pub throughput_rps: f64,
    /// The per-row acceptance metric: batched / sequential throughput
    /// on this stream.
    pub batched_vs_sequential: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_batch_rows: f64,
}

/// The full cross-method report: one row per servable method plus the
/// mixed row, all served by one engine instance.
#[derive(Clone, Debug)]
pub struct MethodsBenchReport {
    pub opts: MethodsBenchOpts,
    pub workers: usize,
    pub rows: Vec<MethodBenchRow>,
    pub cache: CacheStats,
}

impl MethodsBenchReport {
    /// One self-contained JSON object per row — the `serving_methods`
    /// section is their array, mirroring the other serving sections.
    pub fn to_json_rows(&self) -> Vec<Json> {
        let o = &self.opts;
        self.rows
            .iter()
            .map(|r| {
                obj(vec![
                    ("method", Json::Str(r.label.clone())),
                    ("sites", o.spec.len().into()),
                    ("adapters", r.adapters.into()),
                    ("requests", r.requests.into()),
                    ("zipf", o.zipf.into()),
                    ("max_batch", o.cfg.max_batch.into()),
                    ("workers", self.workers.into()),
                    ("cache_mb", o.cfg.cache_mb.into()),
                    ("param_count", r.param_count.into()),
                    ("resident_bytes", r.resident_bytes.into()),
                    ("regen_bytes", r.regen_bytes.into()),
                    ("seq_wall_s", r.seq_wall_s.into()),
                    ("batched_wall_s", r.batched_wall_s.into()),
                    ("seq_throughput_rps", r.seq_throughput_rps.into()),
                    ("throughput_rps", r.throughput_rps.into()),
                    (
                        "batched_vs_sequential",
                        r.batched_vs_sequential.into(),
                    ),
                    ("p50_ms", r.p50_ms.into()),
                    ("p99_ms", r.p99_ms.into()),
                    ("mean_batch_rows", r.mean_batch_rows.into()),
                ])
            })
            .collect()
    }

    pub fn print(&self) {
        let o = &self.opts;
        println!(
            "serve-methods[{} sites x {} adapters/method, zipf {:.2}, \
             {} reqs/stream, batch<= {}, {} workers]",
            o.spec.len(), o.adapters_per_method, o.zipf, o.requests,
            o.cfg.max_batch, self.workers
        );
        for r in &self.rows {
            println!(
                "  {:<5} seq {:>9.0} req/s  batched {:>9.0} req/s  \
                 => {:.2}x   p99 {:.3} ms   {} params \
                 ({} resident B, {} regen B)",
                r.label, r.seq_throughput_rps, r.throughput_rps,
                r.batched_vs_sequential, r.p99_ms, r.param_count,
                r.resident_bytes, r.regen_bytes
            );
        }
        println!(
            "  cache hits {} misses {} evictions {}",
            self.cache.hits, self.cache.misses, self.cache.evictions
        );
    }
}

/// Submit one stream (indices into `names`) firehose-style and wait
/// every ticket out.  Returns (wall seconds, sorted latencies ms).
fn drive_stream(
    server: &Server,
    names: &[&str],
    seq: &[usize],
    xs_pool: &[Vec<Matrix>],
) -> anyhow::Result<(f64, Vec<f64>)> {
    let t0 = Instant::now();
    let mut tickets: Vec<Ticket> = Vec::with_capacity(seq.len());
    for (j, &idx) in seq.iter().enumerate() {
        let xs: Vec<Vec<f32>> = xs_pool[j % X_POOL]
            .iter()
            .map(|m| m.data.clone())
            .collect();
        tickets.push(server.submit(names[idx], xs)?);
    }
    let mut lat_ms: Vec<f64> = Vec::with_capacity(seq.len());
    for t in tickets {
        let submitted = t.submitted;
        let resp = t.wait()?;
        black_box(resp.output()[0]);
        lat_ms.push(
            resp.done.duration_since(submitted).as_secs_f64() * 1e3,
        );
    }
    let wall_s = t0.elapsed().as_secs_f64();
    lat_ms.sort_by(|x, y| x.partial_cmp(y).unwrap());
    Ok((wall_s, lat_ms))
}

/// Run the cross-method comparison (see module docs): per-method Zipf
/// streams plus a mixed stream, sequential and batched, all against
/// one mixed-method model.  `opts.cfg` is taken as final, exactly like
/// [`run`].
pub fn run_methods(
    opts: &MethodsBenchOpts,
) -> anyhow::Result<MethodsBenchReport> {
    anyhow::ensure!(
        opts.adapters_per_method > 0,
        "need at least one adapter per method"
    );
    anyhow::ensure!(opts.requests > 0, "need at least one request");
    opts.spec.validate()?;
    let spec = &opts.spec;
    let budget = opts.cfg.cache_budget_bytes();
    let methods = [Method::CoSA, Method::RoSA, Method::LoRA];
    let apm = opts.adapters_per_method;

    // One model carries every method's fleet side by side — the point
    // of the trait layer.  Synthetic adapters are deterministic in
    // (seed, name), so the build reproduces bit-identically.
    let mut model = AdaptedModel::new(spec.clone(), budget)?;
    let mut names: Vec<String> = Vec::with_capacity(methods.len() * apm);
    for (k, &method) in methods.iter().enumerate() {
        for i in 0..apm {
            let name = format!("{}{i:03}", method.name());
            let aseed =
                opts.seed.wrapping_add(1 + (k * apm + i) as u64);
            model.insert_synthetic_method(&name, aseed, 2.0, method)?;
            names.push(name);
        }
    }
    // Per-adapter accounting, read off the first adapter of each
    // method (every adapter of a method shares its shape here).
    let accounting: Vec<(usize, usize, usize)> = (0..methods.len())
        .map(|k| {
            let a = model.get(&names[k * apm]).unwrap();
            (a.param_count(), a.resident_bytes(), a.regen_bytes())
        })
        .collect();
    let totals = (
        accounting.iter().map(|a| a.0).sum::<usize>() * apm,
        accounting.iter().map(|a| a.1).sum::<usize>() * apm,
        accounting.iter().map(|a| a.2).sum::<usize>() * apm,
    );

    // Streams: one Zipf sequence per method (indices into that
    // method's block of `names`) and one mixed sequence over the whole
    // fleet, all from a stream distinct from the model build.
    let mut rng = Pcg64::with_stream(opts.seed, 1);
    let zipf_m = Zipf::new(apm, opts.zipf);
    let per_seq: Vec<Vec<usize>> = (0..methods.len())
        .map(|k| {
            (0..opts.requests)
                .map(|_| k * apm + zipf_m.sample(&mut rng))
                .collect()
        })
        .collect();
    let zipf_all = Zipf::new(methods.len() * apm, opts.zipf);
    let mixed_seq: Vec<usize> = (0..opts.requests)
        .map(|_| zipf_all.sample(&mut rng))
        .collect();
    let xs_pool: Vec<Vec<Matrix>> = (0..X_POOL)
        .map(|_| {
            spec.sites
                .iter()
                .map(|s| {
                    Matrix::from_vec(1, s.shape.n,
                                     rng.normal_vec(s.shape.n, 1.0))
                })
                .collect()
        })
        .collect();

    // Warm every adapter once: all timed passes start from the same
    // resident state (CoSA projections cached; RoSA/LoRA carry their
    // tensors and never touch the cache).
    for name in &names {
        black_box(model.forward(name, &xs_pool[0])?);
    }

    // -- sequential passes: per-method streams, then mixed --
    let streams: Vec<&[usize]> = per_seq
        .iter()
        .map(Vec::as_slice)
        .chain(std::iter::once(mixed_seq.as_slice()))
        .collect();
    let mut seq_walls = Vec::with_capacity(streams.len());
    for seq in &streams {
        let t0 = Instant::now();
        for (j, &idx) in seq.iter().enumerate() {
            let outs =
                model.forward(&names[idx], &xs_pool[j % X_POOL])?;
            black_box(outs[0].data[0]);
        }
        seq_walls.push(t0.elapsed().as_secs_f64());
    }

    // -- batched passes: the same streams through one server --
    model.reset_cache_stats();
    let server = Server::new(model, &opts.cfg);
    let workers = server.worker_count();
    let model_arc = server.model();
    let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
    let mut rows = Vec::with_capacity(streams.len());
    for (s_idx, seq) in streams.iter().enumerate() {
        let (b0, r0) = server.batch_stats();
        let (wall, lat) =
            drive_stream(&server, &name_refs, seq, &xs_pool)?;
        let (b1, r1) = server.batch_stats();
        let reqs = seq.len() as f64;
        let seq_tp = reqs / seq_walls[s_idx].max(1e-9);
        let tp = reqs / wall.max(1e-9);
        let (label, adapters, acct) = if s_idx < methods.len() {
            (
                methods[s_idx].name().to_string(),
                apm,
                accounting[s_idx],
            )
        } else {
            ("mixed".to_string(), methods.len() * apm, totals)
        };
        rows.push(MethodBenchRow {
            label,
            adapters,
            requests: seq.len(),
            param_count: acct.0,
            resident_bytes: acct.1,
            regen_bytes: acct.2,
            seq_wall_s: seq_walls[s_idx],
            batched_wall_s: wall,
            seq_throughput_rps: seq_tp,
            throughput_rps: tp,
            batched_vs_sequential: tp / seq_tp.max(1e-9),
            p50_ms: percentile(&lat, 0.50),
            p99_ms: percentile(&lat, 0.99),
            mean_batch_rows: (r1 - r0) as f64
                / ((b1 - b0) as f64).max(1.0),
        });
    }
    drop(server);
    let cache = {
        let m = model_arc.lock().unwrap_or_else(|p| p.into_inner());
        m.cache_stats()
    };
    Ok(MethodsBenchReport { opts: opts.clone(), workers, rows, cache })
}

/// Quantized-cache workload description (sequential drive — the
/// scenario measures residency capacity and output accuracy per cache
/// codec; scheduler throughput is `run_model`'s job).
#[derive(Clone, Debug)]
pub struct QuantBenchOpts {
    pub spec: ModelSpec,
    pub adapters: usize,
    pub requests: usize,
    pub zipf: f64,
    pub seed: u64,
    /// `cache_mb` should sit well under the f32 projection working set
    /// so the LRU actually thrashes; `cache_quant` is overridden per
    /// measured codec by the driver.
    pub cfg: ServeConfig,
}

impl Default for QuantBenchOpts {
    fn default() -> Self {
        // The acceptance scenario: the 24-site × 64-adapter model-bench
        // shape with an LRU budget ~4x under its ~12 MiB f32 projection
        // working set, so codec choice directly moves the resident
        // tensor population (and with it the hit rate).
        QuantBenchOpts {
            spec: ModelSpec::synthetic(
                24, SiteShape { m: 96, n: 96 }, 16, 12),
            adapters: 64,
            requests: 512,
            zipf: 1.1,
            seed: 19,
            cfg: ServeConfig { cache_mb: 3.0, ..ServeConfig::default() },
        }
    }
}

/// One measured codec of the quantized-cache scenario (a
/// `serving_quant` bench row).
#[derive(Clone, Debug)]
pub struct QuantBenchRow {
    /// `"f32"` / `"bf16"` / `"int8"`.
    pub kind: String,
    /// Hit fraction over the measured stream's cache lookups.
    pub hit_rate: f64,
    pub hit_rate_vs_f32: f64,
    /// Projections resident at end of drive (exact integer —
    /// deterministic for a fixed stream).
    pub resident_tensors: usize,
    /// The acceptance metric: resident tensors / the f32 pass's
    /// resident tensors at the identical byte budget.
    pub capacity_vs_f32: f64,
    pub resident_bytes: usize,
    /// Relative output RMSE vs the f32 pass over every element of
    /// every request (0 for the f32 row itself).
    pub rmse_vs_f32: f64,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub cache: CacheStats,
}

/// The full quantized-cache report: one row per codec, f32 first.
#[derive(Clone, Debug)]
pub struct QuantBenchReport {
    pub opts: QuantBenchOpts,
    pub rows: Vec<QuantBenchRow>,
}

impl QuantBenchReport {
    /// One self-contained JSON object per codec — the `serving_quant`
    /// section is their array, mirroring `serving_methods`.
    pub fn to_json_rows(&self) -> Vec<Json> {
        let o = &self.opts;
        self.rows
            .iter()
            .map(|r| {
                obj(vec![
                    ("kind", Json::Str(r.kind.clone())),
                    ("sites", o.spec.len().into()),
                    ("adapters", o.adapters.into()),
                    ("requests", o.requests.into()),
                    ("zipf", o.zipf.into()),
                    ("cache_mb", o.cfg.cache_mb.into()),
                    ("hit_rate", r.hit_rate.into()),
                    ("hit_rate_vs_f32", r.hit_rate_vs_f32.into()),
                    ("resident_tensors", r.resident_tensors.into()),
                    ("capacity_vs_f32", r.capacity_vs_f32.into()),
                    ("resident_bytes", r.resident_bytes.into()),
                    ("rmse_vs_f32", r.rmse_vs_f32.into()),
                    ("wall_s", r.wall_s.into()),
                    ("throughput_rps", r.throughput_rps.into()),
                    ("cache_hits", (r.cache.hits as usize).into()),
                    ("cache_misses", (r.cache.misses as usize).into()),
                    (
                        "cache_evictions",
                        (r.cache.evictions as usize).into(),
                    ),
                ])
            })
            .collect()
    }

    pub fn print(&self) {
        let o = &self.opts;
        println!(
            "serve-quant[{} sites x {} adapters, zipf {:.2}, {} reqs, \
             cache {:.1} MiB]",
            o.spec.len(), o.adapters, o.zipf, o.requests, o.cfg.cache_mb
        );
        for r in &self.rows {
            println!(
                "  {:<4} resident {:>5} tensors ({:>8} B)  \
                 capacity {:.2}x  hit rate {:.3} ({:.2}x)  \
                 rmse {:.2e}  {:>7.0} req/s",
                r.kind, r.resident_tensors, r.resident_bytes,
                r.capacity_vs_f32, r.hit_rate, r.hit_rate_vs_f32,
                r.rmse_vs_f32, r.throughput_rps
            );
        }
    }
}

/// Run the quantized-cache scenario (see module docs): the identical
/// Zipf stream driven sequentially through three identically built
/// models whose caches store f32, bf16 and int8 residents at one byte
/// budget.  `opts.cfg` is taken as final except `cache_quant`, which
/// this function owns.
pub fn run_quant(opts: &QuantBenchOpts) -> anyhow::Result<QuantBenchReport> {
    anyhow::ensure!(opts.adapters > 0, "need at least one adapter");
    anyhow::ensure!(opts.requests > 0, "need at least one request");
    opts.spec.validate()?;
    let spec = &opts.spec;
    let budget = opts.cfg.cache_budget_bytes();
    let seed_of = |i: usize| opts.seed.wrapping_add(1 + i as u64);
    let names: Vec<String> =
        (0..opts.adapters).map(|i| format!("adp{i:03}")).collect();

    // Every codec serves an identically built model (deterministic in
    // `opts.seed`), so the only variable is resident storage.
    let build = || -> anyhow::Result<AdaptedModel> {
        let mut rng = Pcg64::new(opts.seed);
        let mut m = AdaptedModel::new(spec.clone(), budget)?;
        for (i, name) in names.iter().enumerate() {
            let cores: Vec<Matrix> = spec
                .sites
                .iter()
                .map(|s| Matrix::gaussian(s.a, s.b, 0.02, &mut rng))
                .collect();
            m.insert_synthetic(name, seed_of(i), 2.0, cores)?;
        }
        Ok(m)
    };

    // Shared Zipf stream + activation pool, distinct from the build
    // stream.
    let mut rng = Pcg64::with_stream(opts.seed, 1);
    let zipf = Zipf::new(opts.adapters, opts.zipf);
    let seq: Vec<usize> =
        (0..opts.requests).map(|_| zipf.sample(&mut rng)).collect();
    let xs_pool: Vec<Vec<Matrix>> = (0..X_POOL)
        .map(|_| {
            spec.sites
                .iter()
                .map(|s| {
                    Matrix::from_vec(1, s.shape.n,
                                     rng.normal_vec(s.shape.n, 1.0))
                })
                .collect()
        })
        .collect();

    let kinds = [QuantKind::F32, QuantKind::Bf16, QuantKind::Int8];
    // The f32 pass's outputs, flattened per request, for the RMSE
    // comparison (regeneration is deterministic, so these do not
    // depend on cache state).
    let mut f32_out: Vec<Vec<f32>> = Vec::new();
    let mut rows: Vec<QuantBenchRow> = Vec::with_capacity(kinds.len());
    for kind in kinds {
        let mut model = build()?;
        model.set_cache_quant(kind);
        // Warm every adapter once so the measured stream starts from a
        // steady (already thrashing) cache, not a cold one — the same
        // warm order for every codec.
        for name in &names {
            black_box(model.forward(name, &xs_pool[0])?);
        }
        model.reset_cache_stats();
        let mut sq_diff = 0.0f64;
        let mut sq_ref = 0.0f64;
        let t0 = Instant::now();
        for (j, &idx) in seq.iter().enumerate() {
            let outs = model.forward(&names[idx], &xs_pool[j % X_POOL])?;
            black_box(outs[0].data[0]);
            if kind == QuantKind::F32 {
                let mut flat = Vec::new();
                for o in &outs {
                    flat.extend_from_slice(&o.data);
                }
                f32_out.push(flat);
            } else {
                let want = &f32_out[j];
                let mut k = 0usize;
                for o in &outs {
                    for &v in &o.data {
                        let d = v as f64 - want[k] as f64;
                        sq_diff += d * d;
                        sq_ref += want[k] as f64 * want[k] as f64;
                        k += 1;
                    }
                }
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let cache = model.cache_stats();
        let lookups = (cache.hits + cache.misses) as f64;
        rows.push(QuantBenchRow {
            kind: kind.name().to_string(),
            hit_rate: cache.hits as f64 / lookups.max(1.0),
            hit_rate_vs_f32: 0.0, // filled once the f32 row exists
            resident_tensors: model.cache_resident_count(),
            capacity_vs_f32: 0.0, // filled once the f32 row exists
            resident_bytes: model.cache_bytes(),
            rmse_vs_f32: if kind == QuantKind::F32 {
                0.0
            } else {
                (sq_diff / sq_ref.max(1e-300)).sqrt()
            },
            wall_s,
            throughput_rps: opts.requests as f64 / wall_s.max(1e-9),
            cache,
        });
    }
    let base_hits = rows[0].hit_rate.max(1e-9);
    let base_resident = rows[0].resident_tensors.max(1) as f64;
    for r in rows.iter_mut() {
        r.hit_rate_vs_f32 = r.hit_rate / base_hits;
        r.capacity_vs_f32 = r.resident_tensors as f64 / base_resident;
    }
    Ok(QuantBenchReport { opts: opts.clone(), rows })
}

/// Telemetry-overhead workload description (always firehose — the
/// question is the per-request cost of stamping spans, not pacing).
#[derive(Clone, Debug)]
pub struct ObsBenchOpts {
    pub adapters: usize,
    pub requests: usize,
    pub zipf: f64,
    pub site: SiteShape,
    pub core_a: usize,
    pub core_b: usize,
    pub seed: u64,
    /// Interleaved measurement passes per variant; the min wall wins
    /// (both variants see the same ambient noise, so the ratio of
    /// minima is the stable machine-independent number).
    pub passes: usize,
    pub cfg: ServeConfig,
}

impl Default for ObsBenchOpts {
    fn default() -> Self {
        // The serving acceptance shape, so the overhead number is
        // measured on the workload the other gates already pin.
        ObsBenchOpts {
            adapters: 64,
            requests: 2048,
            zipf: 1.1,
            site: SiteShape { m: 256, n: 256 },
            core_a: 64,
            core_b: 48,
            seed: 11,
            passes: 3,
            cfg: ServeConfig::default(),
        }
    }
}

/// The telemetry-overhead report (the `serving_obs` bench row).
#[derive(Clone, Debug)]
pub struct ObsBenchReport {
    pub opts: ObsBenchOpts,
    pub workers: usize,
    pub untraced_wall_s: f64,
    pub traced_wall_s: f64,
    pub untraced_throughput_rps: f64,
    pub traced_throughput_rps: f64,
    /// The acceptance metric: traced / untraced throughput (>= 0.95).
    pub traced_vs_untraced: f64,
    /// Entries resident in the slow ring after the traced passes — a
    /// liveness check that spans actually populated.
    pub slow_captured: usize,
    /// Merged per-stage p99s (µs, log₂-bucket upper edges) over every
    /// traced request, indexed by [`obs::Stage`](crate::obs::Stage).
    pub stage_p99_us: [u64; crate::obs::STAGE_COUNT],
}

impl ObsBenchReport {
    pub fn to_json(&self) -> Json {
        let o = &self.opts;
        let mut kv: Vec<(&str, Json)> = vec![
            ("adapters", o.adapters.into()),
            ("requests", o.requests.into()),
            ("zipf", o.zipf.into()),
            ("site_m", o.site.m.into()),
            ("site_n", o.site.n.into()),
            ("core_a", o.core_a.into()),
            ("core_b", o.core_b.into()),
            ("passes", o.passes.into()),
            ("workers", self.workers.into()),
            ("untraced_wall_s", self.untraced_wall_s.into()),
            ("traced_wall_s", self.traced_wall_s.into()),
            (
                "untraced_throughput_rps",
                self.untraced_throughput_rps.into(),
            ),
            (
                "traced_throughput_rps",
                self.traced_throughput_rps.into(),
            ),
            ("traced_vs_untraced", self.traced_vs_untraced.into()),
            ("slow_captured", self.slow_captured.into()),
        ];
        for s in crate::obs::Stage::ALL {
            kv.push((
                match s {
                    crate::obs::Stage::Parse => "p99_us_parse",
                    crate::obs::Stage::Admission => "p99_us_admission",
                    crate::obs::Stage::Queue => "p99_us_queue",
                    crate::obs::Stage::BatchAssemble => {
                        "p99_us_batch_assemble"
                    }
                    crate::obs::Stage::CachePlan => "p99_us_cache_plan",
                    crate::obs::Stage::Pack => "p99_us_pack",
                    crate::obs::Stage::Gemm => "p99_us_gemm",
                    crate::obs::Stage::Reply => "p99_us_reply",
                },
                (self.stage_p99_us[s.idx()] as usize).into(),
            ));
        }
        obj(kv)
    }

    pub fn print(&self) {
        let o = &self.opts;
        println!(
            "serve-obs[{} adapters, zipf {:.2}, {} reqs x {} passes, \
             {} workers]",
            o.adapters, o.zipf, o.requests, o.passes, self.workers
        );
        println!(
            "  untraced    {:>10.0} req/s   ({:.3} s wall)",
            self.untraced_throughput_rps, self.untraced_wall_s
        );
        println!(
            "  traced      {:>10.0} req/s   ({:.3} s wall)  => {:.3}x",
            self.traced_throughput_rps, self.traced_wall_s,
            self.traced_vs_untraced
        );
        print!("  stage p99 us ");
        for s in crate::obs::Stage::ALL {
            print!(" {}={}", s.name(), self.stage_p99_us[s.idx()]);
        }
        println!("   slow ring {}", self.slow_captured);
    }
}

/// Run the telemetry-overhead scenario (see module docs): two
/// identically built single-site servers — tracing disabled vs a live
/// registry — each driven through the identical Zipf stream in
/// `passes` interleaved rounds.  The reported wall per variant is the
/// minimum over its rounds.
pub fn run_obs(opts: &ObsBenchOpts) -> anyhow::Result<ObsBenchReport> {
    anyhow::ensure!(opts.adapters > 0, "need at least one adapter");
    anyhow::ensure!(opts.requests > 0, "need at least one request");
    anyhow::ensure!(opts.passes > 0, "need at least one pass");
    let n = opts.site.n;
    let budget = opts.cfg.cache_budget_bytes();
    let mut rng = Pcg64::with_stream(opts.seed, 1);
    let zipf = Zipf::new(opts.adapters, opts.zipf);
    let seq: Vec<usize> =
        (0..opts.requests).map(|_| zipf.sample(&mut rng)).collect();
    let pool: Vec<Vec<f32>> =
        (0..X_POOL).map(|_| rng.normal_vec(n, 1.0)).collect();

    // Two bit-identical registries (synthetic_registry is
    // deterministic in the seed), warmed the same way, so the only
    // variable between the variants is the telemetry layer.
    let build_warm = || -> anyhow::Result<AdaptedModel> {
        let (mut registry, names) = synthetic_registry(
            opts.adapters,
            opts.site,
            opts.core_a,
            opts.core_b,
            opts.seed,
            budget,
        )?;
        for name in &names {
            let x = Matrix::from_vec(1, n, pool[0].clone());
            black_box(registry.forward_one(name, &x)?);
        }
        registry.reset_cache_stats();
        Ok(registry)
    };
    let names: Vec<String> =
        (0..opts.adapters).map(|i| format!("adp{i:03}")).collect();
    let untraced = Server::new(build_warm()?, &opts.cfg);
    let reg = crate::obs::Registry::new(&crate::config::ObsConfig::default());
    let traced =
        Server::with_obs(build_warm()?, &opts.cfg, reg.clone());
    let workers = untraced.worker_count();

    let drive = |server: &Server| -> anyhow::Result<f64> {
        let t0 = Instant::now();
        let mut tickets: Vec<Ticket> =
            Vec::with_capacity(opts.requests);
        for (j, &idx) in seq.iter().enumerate() {
            tickets.push(server.submit_row(
                &names[idx],
                pool[j % X_POOL].clone(),
            )?);
        }
        for t in tickets {
            let resp = t.wait()?;
            black_box(resp.output()[0]);
        }
        Ok(t0.elapsed().as_secs_f64())
    };

    let mut untraced_wall_s = f64::INFINITY;
    let mut traced_wall_s = f64::INFINITY;
    for _ in 0..opts.passes {
        untraced_wall_s = untraced_wall_s.min(drive(&untraced)?);
        traced_wall_s = traced_wall_s.min(drive(&traced)?);
    }
    drop(untraced);
    drop(traced);

    let mut stage_p99_us = [0u64; crate::obs::STAGE_COUNT];
    for s in crate::obs::Stage::ALL {
        stage_p99_us[s.idx()] =
            reg.merged_stage_snapshot(s).p99_us();
    }
    let untraced_tp =
        opts.requests as f64 / untraced_wall_s.max(1e-9);
    let traced_tp = opts.requests as f64 / traced_wall_s.max(1e-9);
    Ok(ObsBenchReport {
        opts: opts.clone(),
        workers,
        untraced_wall_s,
        traced_wall_s,
        untraced_throughput_rps: untraced_tp,
        traced_throughput_rps: traced_tp,
        traced_vs_untraced: traced_tp / untraced_tp.max(1e-9),
        slow_captured: reg.slow_snapshot().len(),
        stage_p99_us,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let z = Zipf::new(16, 1.1);
        let mut rng = Pcg64::new(9);
        let mut counts = [0usize; 16];
        for _ in 0..4000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts.iter().sum::<usize>() == 4000);
        assert!(
            counts[0] > counts[8] && counts[0] > counts[15],
            "rank 0 must dominate: {counts:?}"
        );
    }

    #[test]
    fn percentile_is_nearest_rank() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[1.0, 2.0], 0.50), 1.0,
                   "p50 of two samples is the lower median");
        let d: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        assert_eq!(percentile(&d, 0.99), 99.0, "p99 is rank 99, not max");
        assert_eq!(percentile(&d, 0.50), 50.0);
        assert_eq!(percentile(&d, 1.0), 100.0);
    }

    #[test]
    fn smoke_scenario_reports_consistent_numbers() {
        let opts = ServeBenchOpts {
            adapters: 3,
            requests: 48,
            zipf: 1.1,
            rate: 0.0,
            site: SiteShape { m: 16, n: 12 },
            core_a: 4,
            core_b: 3,
            seed: 5,
            cfg: ServeConfig {
                cache_mb: 4.0,
                max_batch: 4,
                max_wait_us: 300,
                workers: 2,
                ..ServeConfig::default()
            },
        };
        let rep = run(&opts).unwrap();
        assert!(rep.throughput_rps > 0.0);
        assert!(rep.seq_throughput_rps > 0.0);
        assert!(rep.batched_vs_sequential > 0.0);
        assert!(rep.p50_ms <= rep.p95_ms && rep.p95_ms <= rep.p99_ms);
        assert!(rep.mean_batch_rows >= 1.0);
        assert!(rep.workers >= 1);
        // every request was batched exactly once somewhere
        let j = rep.to_json();
        assert_eq!(j.get("requests").unwrap().as_usize(), Some(48));
        assert!(j.get("batched_vs_sequential").unwrap().as_f64().is_some());
    }

    #[test]
    fn tail_smoke_scenario_reports_consistent_numbers() {
        let opts = TailBenchOpts {
            spec: ModelSpec::synthetic(
                3, SiteShape { m: 16, n: 12 }, 4, 3),
            adapters: 6,
            requests: 48,
            zipf: 1.0,
            seed: 5,
            cfg: ServeConfig {
                cache_mb: 4.0,
                max_batch: 8,
                max_wait_us: 300,
                workers: 2,
                ..ServeConfig::default()
            },
        };
        let rep = run_tail(&opts).unwrap();
        assert!(rep.throughput_rps > 0.0);
        assert!(rep.per_adapter_throughput_rps > 0.0);
        assert!(rep.fused_vs_per_adapter > 0.0);
        assert!(rep.p50_ms <= rep.p95_ms && rep.p95_ms <= rep.p99_ms);
        assert!(rep.mean_batch_rows >= 1.0);
        assert!(rep.per_adapter_mean_batch_rows >= 1.0);
        let j = rep.to_json();
        assert_eq!(j.get("sites").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("adapters").unwrap().as_usize(), Some(6));
        assert_eq!(j.get("zipf").unwrap().as_f64(), Some(1.0));
        assert!(j.get("fused_vs_per_adapter").unwrap().as_f64().is_some());
    }

    #[test]
    fn methods_smoke_scenario_covers_every_method_and_mixed() {
        let opts = MethodsBenchOpts {
            spec: ModelSpec::synthetic(
                3, SiteShape { m: 16, n: 12 }, 4, 3),
            adapters_per_method: 2,
            requests: 24,
            zipf: 1.1,
            seed: 5,
            cfg: ServeConfig {
                cache_mb: 4.0,
                max_batch: 4,
                max_wait_us: 300,
                workers: 2,
                ..ServeConfig::default()
            },
        };
        let rep = run_methods(&opts).unwrap();
        let labels: Vec<&str> =
            rep.rows.iter().map(|r| r.label.as_str()).collect();
        assert_eq!(labels, ["cosa", "rosa", "lora", "mixed"]);
        for r in &rep.rows {
            assert!(r.throughput_rps > 0.0, "{}: dead batched", r.label);
            assert!(r.seq_throughput_rps > 0.0, "{}: dead seq", r.label);
            assert!(r.batched_vs_sequential > 0.0);
            assert!(r.param_count > 0 && r.resident_bytes > 0);
        }
        let by = |l: &str| {
            rep.rows.iter().find(|r| r.label == l).unwrap()
        };
        // The accounting the methods differ on: CoSA stores cores and
        // regenerates projections; LoRA/RoSA store everything.
        assert!(by("cosa").regen_bytes > 0, "cosa regenerates L/R");
        assert_eq!(by("lora").regen_bytes, 0);
        assert_eq!(by("rosa").regen_bytes, 0);
        assert!(by("rosa").param_count > by("lora").param_count,
                "rosa adds a sparse component on top of BA");
        assert!(by("lora").param_count > by("cosa").param_count,
                "cosa's core is smaller than full BA factors");
        // mixed row aggregates the whole fleet
        assert_eq!(by("mixed").adapters, 6);
        assert_eq!(
            by("mixed").param_count,
            2 * (by("cosa").param_count + by("rosa").param_count
                + by("lora").param_count)
        );
        let js = rep.to_json_rows();
        assert_eq!(js.len(), 4);
        assert_eq!(js[3].get("method").unwrap().as_str(), Some("mixed"));
        assert!(js[0]
            .get("batched_vs_sequential")
            .unwrap()
            .as_f64()
            .is_some());
    }

    #[test]
    fn quant_smoke_scenario_multiplies_capacity_within_error_budget() {
        // Tiny thrashing scenario (~8 KiB f32 working set, ~2.6 KiB
        // budget): cheaper codecs must keep measurably more tensors
        // resident at the same byte budget, and the output error must
        // stay inside each codec's budget.  All counters here are
        // deterministic in the seed.
        let opts = QuantBenchOpts {
            spec: ModelSpec::synthetic(
                3, SiteShape { m: 16, n: 12 }, 4, 3),
            adapters: 8,
            requests: 48,
            zipf: 1.0,
            seed: 5,
            cfg: ServeConfig {
                cache_mb: 0.0025,
                ..ServeConfig::default()
            },
        };
        let rep = run_quant(&opts).unwrap();
        let kinds: Vec<&str> =
            rep.rows.iter().map(|r| r.kind.as_str()).collect();
        assert_eq!(kinds, ["f32", "bf16", "int8"]);
        let f32r = &rep.rows[0];
        assert_eq!(f32r.rmse_vs_f32, 0.0);
        assert_eq!(f32r.capacity_vs_f32, 1.0);
        assert_eq!(f32r.hit_rate_vs_f32, 1.0);
        assert!(f32r.cache.evictions > 0, "scenario must thrash");
        for r in &rep.rows {
            assert!(r.hit_rate > 0.0 && r.hit_rate < 1.0,
                    "{}: hit rate {} not thrashing", r.kind, r.hit_rate);
            assert!(r.resident_tensors > 0);
            assert!(r.resident_bytes > 0);
            assert!(r.throughput_rps > 0.0);
        }
        let bf16 = &rep.rows[1];
        let int8 = &rep.rows[2];
        assert!(bf16.capacity_vs_f32 > 1.5,
                "bf16 capacity {:.2}", bf16.capacity_vs_f32);
        assert!(int8.capacity_vs_f32 > 1.5,
                "int8 capacity {:.2}", int8.capacity_vs_f32);
        assert!(bf16.hit_rate > f32r.hit_rate,
                "more resident tensors must hit more: bf16 {} vs f32 {}",
                bf16.hit_rate, f32r.hit_rate);
        assert!(bf16.rmse_vs_f32 > 0.0 && bf16.rmse_vs_f32 < 0.02,
                "bf16 rmse {}", bf16.rmse_vs_f32);
        assert!(int8.rmse_vs_f32 > 0.0 && int8.rmse_vs_f32 < 0.1,
                "int8 rmse {}", int8.rmse_vs_f32);
        let js = rep.to_json_rows();
        assert_eq!(js.len(), 3);
        assert_eq!(js[1].get("kind").unwrap().as_str(), Some("bf16"));
        assert!(js[1].get("capacity_vs_f32").unwrap().as_f64().is_some());
        assert!(js[2].get("rmse_vs_f32").unwrap().as_f64().is_some());
    }

    #[test]
    fn obs_smoke_scenario_traces_without_breaking_the_drive() {
        let opts = ObsBenchOpts {
            adapters: 3,
            requests: 48,
            zipf: 1.1,
            site: SiteShape { m: 16, n: 12 },
            core_a: 4,
            core_b: 3,
            seed: 5,
            passes: 2,
            cfg: ServeConfig {
                cache_mb: 4.0,
                max_batch: 4,
                max_wait_us: 300,
                workers: 2,
                ..ServeConfig::default()
            },
        };
        let rep = run_obs(&opts).unwrap();
        assert!(rep.untraced_throughput_rps > 0.0);
        assert!(rep.traced_throughput_rps > 0.0);
        assert!(rep.traced_vs_untraced > 0.0);
        // The traced server stamped real spans: some stage must show
        // a non-zero p99 (sub-µs stages legitimately round to 0) and
        // the slow ring must hold entries.
        assert!(
            rep.stage_p99_us.iter().any(|&v| v > 0),
            "all stage p99s zero: {:?}",
            rep.stage_p99_us
        );
        assert!(rep.slow_captured > 0);
        let j = rep.to_json();
        assert_eq!(j.get("requests").unwrap().as_usize(), Some(48));
        assert!(j.get("traced_vs_untraced").unwrap().as_f64().is_some());
        assert!(j.get("p99_us_gemm").unwrap().as_usize().is_some());
    }

    #[test]
    fn model_smoke_scenario_reports_consistent_numbers() {
        let opts = ModelBenchOpts {
            spec: ModelSpec::synthetic(
                4, SiteShape { m: 16, n: 12 }, 4, 3),
            adapters: 3,
            requests: 24,
            zipf: 1.1,
            seed: 5,
            cfg: ServeConfig {
                cache_mb: 1.0,
                max_batch: 4,
                max_wait_us: 300,
                workers: 2,
                ..ServeConfig::default()
            },
        };
        let rep = run_model(&opts).unwrap();
        assert!(rep.throughput_rps > 0.0);
        assert!(rep.seq_throughput_rps > 0.0);
        assert!(rep.persite_throughput_rps > 0.0);
        assert!(rep.shared_vs_persite > 0.0);
        assert!(rep.p50_ms <= rep.p95_ms && rep.p95_ms <= rep.p99_ms);
        // heterogeneous synthetic spec: 2 full + 2 half cores
        assert_eq!(rep.core_params, 2 * 12 + 2 * 2);
        assert_eq!(rep.adapter_bytes, rep.core_params * 4 + 8,
                   "whole-model artifact is cores + one seed");
        let j = rep.to_json();
        assert_eq!(j.get("sites").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("adapters").unwrap().as_usize(), Some(3));
        assert!(j.get("shared_vs_persite").unwrap().as_f64().is_some());
    }
}
