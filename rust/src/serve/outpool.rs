// lint: hot-path
//! Cross-worker recycling of batch **output** buffers.
//!
//! A batch's outputs outlive the worker that computed them — every
//! ticket of the batch holds them via `Arc` — so they cannot come from
//! a worker's private `Workspace` (which would hand the buffer to the
//! next batch while readers still hold rows).  PR 3 simply allocated a
//! fresh output matrix per batch; at high batch rates that is an
//! allocator round-trip per batch per site.  [`OutputPool`] closes the
//! loop: workers take [`PooledOut`] buffers, and when the *last* ticket
//! of a batch drops its `Arc`, the buffer's `Drop` impl returns it to
//! the shared pool — whichever thread that happens on (hence
//! "cross-worker": worker A's buffer is routinely recycled by a caller
//! thread and re-taken by worker B).
//!
//! The pool holds plain `Vec<f32>`s behind a `Mutex`, best-fit by
//! capacity like `linalg::Workspace`, bounded by [`MAX_POOLED`].  If
//! the pool itself is gone (server shut down while tickets are still
//! alive) the buffer just drops — `PooledOut` only holds a `Weak`.

use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::math::matrix::Matrix;

/// Maximum buffers retained; beyond it the smallest pooled buffer is
/// displaced only by a strictly larger incoming one (Workspace's rule).
const MAX_POOLED: usize = 256;

/// Shared pool of batch-output buffers (see module docs).
#[derive(Default)]
pub struct OutputPool {
    bufs: Mutex<Vec<Vec<f32>>>,
    allocs: AtomicU64,
    reuses: AtomicU64,
}

impl OutputPool {
    /// The pool is always shared (workers take, ticket drops recycle),
    /// so the constructor hands out an `Arc` directly.
    pub fn shared() -> Arc<OutputPool> {
        Arc::new(OutputPool::default())
    }

    /// `(fresh allocations, pool reuses)` so far — flat `allocs` across
    /// a steady stream of batches is the recycling proof the tests and
    /// benches assert.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.allocs.load(Ordering::Relaxed),
            self.reuses.load(Ordering::Relaxed),
        )
    }

    /// Buffers currently pooled (diagnostic).
    pub fn pooled(&self) -> usize {
        self.bufs.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    /// A zeroed `rows × cols` output backed by a pooled buffer when one
    /// with sufficient capacity exists.  (The gemm kernels fully
    /// overwrite their output, but zeroing keeps the contract identical
    /// to the `Matrix::zeros` path this replaces — stale floats can
    /// never leak to a caller even on an error path.)
    pub fn take(self: &Arc<Self>, rows: usize, cols: usize) -> PooledOut {
        let len = rows * cols;
        let reused = {
            let mut bufs =
                self.bufs.lock().unwrap_or_else(|p| p.into_inner());
            let best = bufs
                .iter()
                .enumerate()
                .filter(|(_, b)| b.capacity() >= len)
                .min_by_key(|(_, b)| b.capacity())
                .map(|(i, _)| i);
            best.map(|i| bufs.swap_remove(i))
        };
        let data = match reused {
            Some(mut buf) => {
                self.reuses.fetch_add(1, Ordering::Relaxed);
                buf.clear();
                buf.resize(len, 0.0);
                buf
            }
            None => {
                self.allocs.fetch_add(1, Ordering::Relaxed);
                // lint: allow(alloc) — pool-miss cold path: the whole point of the pool is that this arm stops running at steady state (the `allocs` counter is the proof the tests assert).
                vec![0.0; len]
            }
        };
        PooledOut {
            mat: Some(Matrix::from_vec(rows, cols, data)),
            pool: Arc::downgrade(self),
        }
    }

    fn recycle(&self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut bufs = self.bufs.lock().unwrap_or_else(|p| p.into_inner());
        if bufs.len() >= MAX_POOLED {
            let smallest = bufs
                .iter()
                .enumerate()
                .min_by_key(|(_, b)| b.capacity())
                .map(|(i, b)| (i, b.capacity()));
            match smallest {
                Some((i, cap)) if cap < buf.capacity() => {
                    bufs.swap_remove(i);
                }
                _ => return, // incoming is no larger — drop it instead
            }
        }
        bufs.push(buf);
    }
}

/// A batch output matrix on loan from an [`OutputPool`]; returns its
/// buffer to the pool when the last holder drops it.
pub struct PooledOut {
    mat: Option<Matrix>,
    pool: Weak<OutputPool>,
}

impl PooledOut {
    /// Mutable access for the worker filling the batch (before the
    /// buffer is `Arc`-shared with tickets).
    pub(crate) fn matrix_mut(&mut self) -> &mut Matrix {
        // lint: allow(panic) — `mat` is Some from construction until Drop::drop; no API hands out the None state.
        self.mat.as_mut().expect("PooledOut holds its matrix until drop")
    }
}

impl Deref for PooledOut {
    type Target = Matrix;
    fn deref(&self) -> &Matrix {
        // lint: allow(panic) — Deref cannot return Result; `mat` is Some until Drop::drop as above.
        self.mat.as_ref().expect("PooledOut holds its matrix until drop")
    }
}

impl Drop for PooledOut {
    fn drop(&mut self) {
        if let (Some(m), Some(pool)) = (self.mat.take(), self.pool.upgrade())
        {
            pool.recycle(m.data);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_state_reuses_instead_of_allocating() {
        let pool = OutputPool::shared();
        for i in 0..10 {
            let out = pool.take(4, 8);
            assert_eq!((out.rows, out.cols), (4, 8));
            assert!(out.data.iter().all(|v| *v == 0.0), "must hand zeros");
            drop(out); // recycles
            let (allocs, reuses) = pool.stats();
            assert_eq!(allocs, 1, "iteration {i} allocated again");
            assert_eq!(reuses, i as u64);
        }
    }

    #[test]
    fn best_fit_and_heterogeneous_shapes() {
        let pool = OutputPool::shared();
        let big = pool.take(16, 16);
        let small = pool.take(2, 2);
        drop(big);
        drop(small);
        // best-fit: a 9-float request skips the 4-float buffer and
        // reuses the 256-float one (smallest sufficient capacity)
        let mid = pool.take(3, 3);
        let (allocs, _) = pool.stats();
        assert_eq!(allocs, 2, "mid-size fits inside the big buffer");
        drop(mid);
        // both original capacities are still pooled (4 and 256)
        assert_eq!(pool.pooled(), 2);
    }

    #[test]
    fn pool_death_is_harmless_for_live_outputs() {
        let pool = OutputPool::shared();
        let out = pool.take(2, 2);
        drop(pool); // server gone, ticket still holds the output
        assert_eq!(out.data.len(), 4);
        drop(out); // Weak upgrade fails; buffer just drops
    }

    #[test]
    fn zeroes_recycled_buffers() {
        let pool = OutputPool::shared();
        let mut out = pool.take(2, 2);
        out.matrix_mut().data.fill(7.5);
        drop(out);
        let out = pool.take(2, 2);
        assert!(out.data.iter().all(|v| *v == 0.0), "stale floats leaked");
    }
}
