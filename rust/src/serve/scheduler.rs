//! Request scheduler: queue -> per-adapter batches -> worker pool.
//!
//! ## Data flow
//!
//! ```text
//! submit() --ingress--> batcher --batches--> workers --reply--> Ticket
//! ```
//!
//! * **submit** accepts one whole-model request — one activation row
//!   per site of the served [`AdaptedModel`], in spec order — and
//!   returns a [`Ticket`] the caller blocks on.  Single-site models
//!   keep the PR-3 ergonomics via [`Server::submit_row`].  Requests may
//!   carry a **deadline** ([`Server::submit_with_deadline`]): an
//!   expired request is answered with a timeout error instead of
//!   occupying compute in a batch, and the batcher flushes its group
//!   early so the timeout answer arrives near the deadline rather than
//!   at `max_wait`.  A [`Ticket::cancel_handle`] drops the request the
//!   same way from any thread; cancelled requests are flushed by a
//!   bounded batcher sweep (`CANCEL_SWEEP`), so the "cancelled" answer
//!   also never waits out a long `max_wait`.
//! * The **batcher** thread drains the ingress queue and groups pending
//!   requests **by adapter id** — a batch never mixes adapters.  A
//!   group flushes when it reaches `max_batch` rows or when a member
//!   reaches its effective wait bound (`min(arrival + max_wait,
//!   deadline)`).
//! * **Workers** (count resolved through the same `plan_threads` helper
//!   the compute backends share) pull whole batches, take one
//!   [`AdaptedModel::plan`] under a brief model lock — cache *misses*
//!   for **every cold site of the request** are described by that one
//!   call and regenerated outside the lock, then installed under a
//!   second brief lock — so a cold or thrashing projection cache never
//!   serializes the pool.  The worker then assembles one batch matrix
//!   per site in worker-owned [`Workspace`] buffers and runs one
//!   `adapter_forward_into` per site.  The matmul hot path is
//!   allocation-free at steady state (the Workspace contract), and the
//!   per-site batch *outputs* come from the shared
//!   [`OutputPool`](super::outpool::OutputPool) — recycled across
//!   workers when the last ticket of a batch drops them — so a batch
//!   allocates nothing after warmup, end to end.
//!
//! Batching is what buys multi-adapter throughput: a single-row forward
//! re-reads the whole per-site `L`/`R`/`Y` working set per request,
//! while a k-row batch amortizes that traffic k ways across **all
//! sites at once** (`benches/serve_bench.rs` measures both the
//! single-site and the multi-site scenario; CI gates them).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::adapters::cosa::{adapter_forward_into, regen_l, regen_r};
use crate::config::ServeConfig;
use crate::linalg::tiled::plan_threads;
use crate::linalg::Workspace;
use crate::math::matrix::Matrix;
use crate::model::AdaptedModel;

use super::outpool::{OutputPool, PooledOut};

/// One answered request.  `outs` holds the whole batch's per-site
/// output matrices, shared by every ticket of the batch; `row` is this
/// request's row in each of them.
pub struct Response {
    pub outs: Arc<Vec<PooledOut>>,
    pub row: usize,
    /// Adapter id the batch ran under (every row of `outs` used it).
    pub adapter: Arc<str>,
    /// Rows in the batch this request rode in.
    pub batch_rows: usize,
    /// When the worker finished the batch (latency = `done` - submit).
    pub done: Instant,
}

impl Response {
    /// Adapted sites in this response (the model's site count).
    pub fn sites(&self) -> usize {
        self.outs.len()
    }

    /// This request's output row at `site` (width `m_site`).
    pub fn site_output(&self, site: usize) -> &[f32] {
        self.outs[site].row(self.row)
    }

    /// Site-0 output row — the whole answer for single-site models.
    pub fn output(&self) -> &[f32] {
        self.site_output(0)
    }
}

type Reply = Result<Response, String>;

/// Cancels one in-flight request from any thread (cloneable; survives
/// the ticket moving into `wait`).  A cancelled request is dropped from
/// its batch at flush time and answered with a "cancelled" error.
#[derive(Clone)]
pub struct CancelHandle(Arc<AtomicBool>);

impl CancelHandle {
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Handle for one in-flight request; `wait` blocks for the answer.
pub struct Ticket {
    rx: Receiver<Reply>,
    /// When the request entered the queue (set by `submit`).
    pub submitted: Instant,
    cancel: CancelHandle,
}

impl Ticket {
    pub fn wait(self) -> anyhow::Result<Response> {
        match self.rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(msg)) => Err(anyhow::anyhow!(msg)),
            Err(_) => Err(anyhow::anyhow!(
                "server shut down before answering the request"
            )),
        }
    }

    /// Mark this request cancelled (see [`CancelHandle`]).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// A clonable handle for cancelling after the ticket moves away.
    pub fn cancel_handle(&self) -> CancelHandle {
        self.cancel.clone()
    }
}

/// Decrements the in-flight gauge exactly once, whenever its request
/// leaves the engine — answered by a worker, dropped with a batch on a
/// teardown race, or never sent at all.  Tying the decrement to `Drop`
/// (instead of sprinkling it over every reply path) is what keeps the
/// [`SchedulerStats::queue_depth`] gauge exact: a `Request` is dropped
/// exactly once, no matter which path answered it.
struct InflightGuard(Arc<ServerStats>);

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

struct Request {
    adapter: Arc<str>,
    /// One activation row per site, spec order.
    xs: Vec<Vec<f32>>,
    reply: Sender<Reply>,
    at: Instant,
    /// Absolute expiry; `None` = never.
    deadline: Option<Instant>,
    cancelled: Arc<AtomicBool>,
    _inflight: InflightGuard,
}

struct Batch {
    adapter: Arc<str>,
    reqs: Vec<Request>,
}

/// Scheduler counters (mean batch size benches report is
/// `rows / batches`; `expired`/`cancelled` count dropped requests;
/// `inflight` is the live queue-depth gauge maintained by
/// [`InflightGuard`]; `by_adapter` counts submissions per adapter name).
#[derive(Default)]
struct ServerStats {
    batches: AtomicU64,
    batched_rows: AtomicU64,
    expired: AtomicU64,
    cancelled: AtomicU64,
    submitted: AtomicU64,
    inflight: AtomicU64,
    by_adapter: Mutex<HashMap<Arc<str>, u64>>,
    /// Submissions not counted in `by_adapter` because the name cap
    /// was reached (see `MAX_TRACKED_ADAPTERS`).
    untracked: AtomicU64,
}

/// Distinct adapter names the per-adapter counter map will track.
/// Submission names are caller-controlled (the wire gateway forwards
/// client strings), so an unbounded map would be a remote
/// memory-exhaustion vector; overflow lands in
/// [`SchedulerStats::per_adapter_untracked`] instead.
const MAX_TRACKED_ADAPTERS: usize = 1024;

/// Cheap point-in-time snapshot of the engine's counters — the surface
/// behind the wire `/v1/stats` endpoint and queue-depth admission
/// control.  `queue_depth` counts requests submitted but not yet
/// answered (queued in the batcher, riding a batch, or mid-compute);
/// `per_adapter` is (name, submitted) sorted by name.
#[derive(Clone, Debug, Default)]
pub struct SchedulerStats {
    pub queue_depth: u64,
    pub submitted: u64,
    pub batches: u64,
    pub batched_rows: u64,
    pub expired: u64,
    pub cancelled: u64,
    pub per_adapter: Vec<(String, u64)>,
    /// Submissions under names beyond the tracked-adapter cap.
    pub per_adapter_untracked: u64,
}

/// The serving engine: adapted model + batcher + worker pool.  See
/// module docs for the data flow; construction spawns the threads,
/// `shutdown` (or drop) drains and joins them.
pub struct Server {
    ingress: Option<Sender<Request>>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    model: Arc<Mutex<AdaptedModel>>,
    stats: Arc<ServerStats>,
    out_pool: Arc<OutputPool>,
    /// Per-site input widths, spec order (submit-time validation).
    site_ns: Vec<usize>,
    worker_count: usize,
}

/// Ceiling on spawned workers, however configured — each worker is a
/// real OS thread and more of them than cores only adds contention.
const MAX_WORKERS: usize = 64;

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl Server {
    /// Spawn the engine over `model`.  `cfg` is used as-is — apply
    /// `ServeConfig::env_overridden()` at the call site (the CLI and
    /// bench drivers do), so tests stay hermetic.
    pub fn new(model: AdaptedModel, cfg: &ServeConfig) -> Server {
        let site_ns: Vec<usize> =
            model.spec().sites.iter().map(|s| s.shape.n).collect();
        let max_batch = cfg.max_batch.max(1);
        let max_wait = Duration::from_micros(cfg.max_wait_us);
        // Same resolution rule as the compute backends: explicit count,
        // or auto (available_parallelism, capped) — the zero-FLOP floor
        // means serving always gets its workers.  Unlike the compute
        // kernels (where plan_threads clamps to actual matrix rows), a
        // server has no natural row bound, so cap explicit requests too
        // instead of attempting an unbounded number of thread spawns.
        let workers = if cfg.workers > MAX_WORKERS {
            eprintln!(
                "warning: serve workers capped at {MAX_WORKERS} \
                 (requested {})",
                cfg.workers
            );
            MAX_WORKERS
        } else {
            cfg.workers
        };
        let worker_count = plan_threads(workers, 0, usize::MAX, usize::MAX);

        let model = Arc::new(Mutex::new(model));
        let stats = Arc::new(ServerStats::default());
        let out_pool = OutputPool::shared();
        let (ingress_tx, ingress_rx) = channel::<Request>();
        let (batch_tx, batch_rx) = channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        let batcher = std::thread::spawn(move || {
            batcher_loop(ingress_rx, batch_tx, max_batch, max_wait);
        });
        let mut workers = Vec::with_capacity(worker_count);
        for _ in 0..worker_count {
            let rx = batch_rx.clone();
            let mdl = model.clone();
            let st = stats.clone();
            let pool = out_pool.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(&rx, &mdl, &st, &pool);
            }));
        }
        Server {
            ingress: Some(ingress_tx),
            batcher: Some(batcher),
            workers,
            model,
            stats,
            out_pool,
            site_ns,
            worker_count,
        }
    }

    /// Workers actually spawned (after auto resolution).
    pub fn worker_count(&self) -> usize {
        self.worker_count
    }

    /// (batches executed, total rows batched) so far.
    pub fn batch_stats(&self) -> (u64, u64) {
        (
            self.stats.batches.load(Ordering::Relaxed),
            self.stats.batched_rows.load(Ordering::Relaxed),
        )
    }

    /// (deadline-expired, cancelled) requests dropped from batches.
    pub fn drop_stats(&self) -> (u64, u64) {
        (
            self.stats.expired.load(Ordering::Relaxed),
            self.stats.cancelled.load(Ordering::Relaxed),
        )
    }

    /// (fresh allocations, reuses) of the shared batch-output pool.
    pub fn output_pool_stats(&self) -> (u64, u64) {
        self.out_pool.stats()
    }

    /// Point-in-time snapshot of every scheduler counter (see
    /// [`SchedulerStats`]).  Cheap: atomic loads plus one brief lock to
    /// copy the per-adapter map — safe to call on every wire request
    /// (queue-depth admission control does).
    pub fn scheduler_stats(&self) -> SchedulerStats {
        let mut per_adapter: Vec<(String, u64)> = lock(&self.stats.by_adapter)
            .iter()
            .map(|(k, v)| (k.to_string(), *v))
            .collect();
        per_adapter.sort();
        SchedulerStats {
            queue_depth: self.stats.inflight.load(Ordering::Relaxed),
            submitted: self.stats.submitted.load(Ordering::Relaxed),
            batches: self.stats.batches.load(Ordering::Relaxed),
            batched_rows: self.stats.batched_rows.load(Ordering::Relaxed),
            expired: self.stats.expired.load(Ordering::Relaxed),
            cancelled: self.stats.cancelled.load(Ordering::Relaxed),
            per_adapter,
            per_adapter_untracked: self
                .stats
                .untracked
                .load(Ordering::Relaxed),
        }
    }

    /// The queue-depth gauge alone (requests submitted but not yet
    /// answered) — the admission-control fast path, no map copy.
    pub fn queue_depth(&self) -> u64 {
        self.stats.inflight.load(Ordering::Relaxed)
    }

    /// The shared adapted model (hot load/evict while serving, cache
    /// stats).
    pub fn model(&self) -> Arc<Mutex<AdaptedModel>> {
        self.model.clone()
    }

    fn submit_inner(
        &self,
        adapter: &str,
        xs: Vec<Vec<f32>>,
        deadline: Option<Duration>,
    ) -> anyhow::Result<Ticket> {
        anyhow::ensure!(
            xs.len() == self.site_ns.len(),
            "request has {} site rows, model has {} sites",
            xs.len(),
            self.site_ns.len()
        );
        for (i, (x, n)) in xs.iter().zip(&self.site_ns).enumerate() {
            anyhow::ensure!(
                x.len() == *n,
                "site {i}: request row has {} values, site expects {n}",
                x.len()
            );
        }
        let ingress = self
            .ingress
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("server is shut down"))?;
        let (tx, rx) = channel::<Reply>();
        let submitted = Instant::now();
        let cancelled = Arc::new(AtomicBool::new(false));
        let key: Arc<str> = Arc::from(adapter);
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        self.stats.inflight.fetch_add(1, Ordering::Relaxed);
        {
            let mut map = lock(&self.stats.by_adapter);
            match map.get_mut(&key) {
                Some(count) => *count += 1,
                None if map.len() < MAX_TRACKED_ADAPTERS => {
                    map.insert(key.clone(), 1);
                }
                None => {
                    self.stats.untracked.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        let req = Request {
            adapter: key,
            xs,
            reply: tx,
            at: submitted,
            deadline: deadline.map(|d| submitted + d),
            cancelled: cancelled.clone(),
            _inflight: InflightGuard(self.stats.clone()),
        };
        ingress
            .send(req)
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        Ok(Ticket { rx, submitted, cancel: CancelHandle(cancelled) })
    }

    /// Enqueue one whole-model request (one row per site, spec order).
    /// Returns immediately; block on the ticket for the answer.
    pub fn submit(
        &self,
        adapter: &str,
        xs: Vec<Vec<f32>>,
    ) -> anyhow::Result<Ticket> {
        self.submit_inner(adapter, xs, None)
    }

    /// [`Server::submit`] with a relative deadline: if the request is
    /// still queued when it expires, it is answered with a timeout
    /// error instead of occupying a batch slot.
    pub fn submit_with_deadline(
        &self,
        adapter: &str,
        xs: Vec<Vec<f32>>,
        deadline: Duration,
    ) -> anyhow::Result<Ticket> {
        self.submit_inner(adapter, xs, Some(deadline))
    }

    /// Single-row sugar for 1-site models (the PR-3 surface).
    pub fn submit_row(
        &self,
        adapter: &str,
        x: Vec<f32>,
    ) -> anyhow::Result<Ticket> {
        anyhow::ensure!(
            self.site_ns.len() == 1,
            "submit_row needs a 1-site model; this one has {} sites",
            self.site_ns.len()
        );
        self.submit_inner(adapter, vec![x], None)
    }

    /// Stop accepting requests, drain everything in flight, join the
    /// threads.  Every request submitted before shutdown is answered.
    pub fn shutdown(&mut self) {
        self.ingress.take(); // batcher sees disconnect, flushes, exits
        if let Some(b) = self.batcher.take() {
            let _ = b.join(); // dropping its batch sender stops workers
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// When a pending request must leave the batcher: its arrival plus the
/// group wait bound, or its own deadline — whichever is sooner (an
/// expired request must be *answered* near its deadline, which means
/// flushing it to a worker that sends the timeout error).
fn effective_flush_at(r: &Request, max_wait: Duration) -> Instant {
    let by_wait = r.at + max_wait;
    match r.deadline {
        Some(d) => d.min(by_wait),
        None => by_wait,
    }
}

/// How often the batcher sweeps pending groups for cancelled members
/// while anything is pending.  Cancellation is an async flag with no
/// wake channel (a `Sender`-holding cancel handle would keep the
/// ingress alive and hang shutdown), so a bounded poll keeps
/// drop-on-cancel prompt even under a multi-second `max_wait`.
const CANCEL_SWEEP: Duration = Duration::from_millis(5);

/// One adapter's pending requests plus the earliest instant any member
/// must leave the batcher.  The cached minimum is exact: members only
/// join (the min is monotone under `min`) and leave wholesale, so the
/// per-arrival scans stay O(groups), not O(total pending requests).
struct Group {
    min_flush: Instant,
    reqs: Vec<Request>,
}

fn batcher_loop(
    rx: Receiver<Request>,
    tx: Sender<Batch>,
    max_batch: usize,
    max_wait: Duration,
) {
    let mut pending: HashMap<Arc<str>, Group> = HashMap::new();
    'run: loop {
        let earliest = pending.values().map(|g| g.min_flush).min();
        let received = match earliest {
            // Nothing pending: block until a request (or shutdown).
            None => match rx.recv() {
                Ok(r) => Some(r),
                Err(_) => break 'run,
            },
            Some(deadline) => {
                let until = deadline
                    .saturating_duration_since(Instant::now())
                    .min(CANCEL_SWEEP);
                match rx.recv_timeout(until) {
                    Ok(r) => Some(r),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => break 'run,
                }
            }
        };
        // Timeout wakeups double as cancellation sweeps; arrivals skip
        // the O(pending) member scan.
        let sweep = received.is_none();
        if let Some(req) = received {
            let eff = effective_flush_at(&req, max_wait);
            let key = req.adapter.clone();
            let group =
                pending.entry(key.clone()).or_insert_with(|| Group {
                    min_flush: eff,
                    reqs: Vec::new(),
                });
            group.min_flush = group.min_flush.min(eff);
            group.reqs.push(req);
            if group.reqs.len() >= max_batch {
                if let Some(g) = pending.remove(&key) {
                    let batch = Batch { adapter: key, reqs: g.reqs };
                    if tx.send(batch).is_err() {
                        return; // workers gone — nothing left to answer
                    }
                }
            }
        }
        // Flush every group at its wait/deadline bound (the worker
        // answers expired members with the timeout error), plus — on
        // sweep ticks — any group holding a cancelled member, so the
        // "cancelled" answer arrives within ~CANCEL_SWEEP rather than
        // at max_wait.
        let now = Instant::now();
        let due: Vec<Arc<str>> = pending
            .iter()
            .filter(|(_, g)| {
                now >= g.min_flush
                    || (sweep
                        && g.reqs.iter().any(|r| {
                            r.cancelled.load(Ordering::Relaxed)
                        }))
            })
            .map(|(k, _)| k.clone())
            .collect();
        for key in due {
            if let Some(g) = pending.remove(&key) {
                if tx.send(Batch { adapter: key, reqs: g.reqs }).is_err() {
                    return;
                }
            }
        }
    }
    // Ingress disconnected (shutdown): flush everything still pending so
    // no submitted request goes unanswered.
    for (adapter, g) in pending.drain() {
        if tx.send(Batch { adapter, reqs: g.reqs }).is_err() {
            return;
        }
    }
}

fn worker_loop(
    rx: &Mutex<Receiver<Batch>>,
    model: &Mutex<AdaptedModel>,
    stats: &ServerStats,
    pool: &Arc<OutputPool>,
) {
    let mut ws = Workspace::new();
    loop {
        // Standard Mutex<Receiver> work queue: one idle worker at a
        // time blocks inside recv() *while holding the lock*; the guard
        // drops at the end of this statement, so the batch itself is
        // always processed lock-free.  Never add work to this statement
        // chain — it would run under the lock and stall the pool.
        let batch = match lock(rx).recv() {
            Ok(b) => b,
            Err(_) => return, // batcher exited and the queue is drained
        };
        let Batch { adapter, reqs } = batch;
        // Dropped requests first: cancelled or past-deadline members
        // are answered with their error and never occupy compute.
        let now = Instant::now();
        let mut live = Vec::with_capacity(reqs.len());
        for req in reqs {
            if req.cancelled.load(Ordering::Relaxed) {
                stats.cancelled.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(Err(format!(
                    "request for `{adapter}` was cancelled"
                )));
            } else if req.deadline.is_some_and(|d| now >= d) {
                stats.expired.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(Err(format!(
                    "request for `{adapter}` timed out: deadline exceeded \
                     after {:?} in queue",
                    now.duration_since(req.at)
                )));
            } else {
                live.push(req);
            }
        }
        if live.is_empty() {
            continue;
        }
        // Two-phase handle lookup so the model lock stays brief even on
        // projection-cache misses: one plan under the lock describes
        // every cold site of the request, all of them regenerate
        // *outside* the lock, then install under a second brief lock.
        // A thrashing cache costs the missing worker regen time, never
        // the whole pool.
        let plan = lock(model).plan(&adapter);
        let plan = match plan {
            Ok(p) => p,
            Err(e) => {
                let msg = format!("{e:#}");
                for req in live {
                    let _ = req.reply.send(Err(msg.clone()));
                }
                continue;
            }
        };
        let regen: Vec<(Option<Matrix>, Option<Matrix>)> = plan
            .sites
            .iter()
            .map(|sp| {
                let l = sp
                    .l
                    .is_none()
                    .then(|| regen_l(sp.seed, &sp.l_name, sp.m, sp.a));
                let r = sp
                    .r
                    .is_none()
                    .then(|| regen_r(sp.seed, &sp.r_name, sp.b, sp.n));
                (l, r)
            })
            .collect();
        let handles = lock(model).install(&plan, regen);
        let rows = live.len();
        // One batch matrix and one pooled output per site: inputs come
        // from the worker's Workspace (allocation-free after warmup),
        // outputs from the shared pool (recycled when the batch's last
        // ticket drops them).
        let mut outs = Vec::with_capacity(handles.sites.len());
        for (s, sh) in handles.sites.iter().enumerate() {
            let n = sh.r.cols;
            let m = sh.l.rows;
            let mut x = ws.take_matrix(rows, n);
            for (i, req) in live.iter().enumerate() {
                x.data[i * n..(i + 1) * n].copy_from_slice(&req.xs[s]);
            }
            let mut out = pool.take(rows, m);
            adapter_forward_into(
                &x,
                &sh.l,
                &sh.r,
                &sh.y,
                handles.alpha,
                &mut ws,
                out.matrix_mut(),
            );
            ws.recycle_matrix(x);
            outs.push(out);
        }
        let outs = Arc::new(outs);
        let done = Instant::now();
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.batched_rows.fetch_add(rows as u64, Ordering::Relaxed);
        for (row, req) in live.into_iter().enumerate() {
            let resp = Response {
                outs: outs.clone(),
                row,
                adapter: adapter.clone(),
                batch_rows: rows,
                done,
            };
            let _ = req.reply.send(Ok(resp));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::cosa::{adapter_forward, regen_l, regen_r};
    use crate::math::rng::Pcg64;
    use crate::model::{CoreInput, ModelSpec, SiteShape};
    use crate::util::prop;

    const M: usize = 12;
    const N: usize = 10;

    fn test_cfg(max_batch: usize, max_wait_us: u64) -> ServeConfig {
        ServeConfig {
            cache_mb: 4.0,
            max_batch,
            max_wait_us,
            workers: 2,
            ..ServeConfig::default()
        }
    }

    /// 1-site model matching the PR-3 test fixtures (site stem
    /// "adp.0.wq", 4x3 cores).
    fn test_model(adapters: &[(&str, u64)]) -> AdaptedModel {
        let mut model = AdaptedModel::single_site(
            "adp.0.wq",
            SiteShape { m: M, n: N },
            4,
            3,
            1 << 20,
        );
        for (name, seed) in adapters {
            let mut rng = Pcg64::derive(*seed, name);
            let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
            model
                .insert(
                    name,
                    *seed,
                    2.0,
                    vec![CoreInput::new("adp.0.wq.l", "adp.0.wq.r", y)],
                )
                .unwrap();
        }
        model
    }

    fn reference_forward(seed: u64, name: &str, x_row: &[f32]) -> Vec<f32> {
        let mut rng = Pcg64::derive(seed, name);
        let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
        let l = regen_l(seed, "adp.0.wq.l", M, 4);
        let r = regen_r(seed, "adp.0.wq.r", 3, N);
        let x = Matrix::from_vec(1, N, x_row.to_vec());
        adapter_forward(&x, &l, &r, &y, 2.0).data
    }

    #[test]
    fn absurd_worker_requests_are_capped() {
        let model = test_model(&[("solo", 7)]);
        let cfg = ServeConfig { workers: 1_000_000, ..test_cfg(4, 200) };
        let server = Server::new(model, &cfg);
        assert!(server.worker_count() <= 64, "{}", server.worker_count());
        let t = server.submit_row("solo", vec![0.0; N]).unwrap();
        assert!(t.wait().is_ok());
    }

    #[test]
    fn every_request_answered_exactly_once_and_unmixed() {
        // Property test: random request mixes over several adapters —
        // every ticket resolves with the right adapter's math, and the
        // scheduler's row accounting matches the request count exactly
        // (each request answered exactly once).
        prop::for_all("serve answers all, batches unmixed", 5, |rng| {
            let adapters =
                [("alpha", 7u64), ("beta", 8u64), ("gamma", 9u64)];
            let model = test_model(&adapters);
            let server = Server::new(model, &test_cfg(4, 500));
            let total = prop::int_in(rng, 5, 40);
            let mut tickets = Vec::new();
            let mut expect = Vec::new();
            for _ in 0..total {
                let which = prop::int_in(rng, 0, adapters.len() - 1);
                let (name, seed) = adapters[which];
                let x: Vec<f32> =
                    (0..N).map(|_| rng.normal() as f32).collect();
                expect.push(reference_forward(seed, name, &x));
                tickets.push((name, server.submit_row(name, x).unwrap()));
            }
            let mut answered = 0usize;
            for ((name, ticket), want) in
                tickets.into_iter().zip(&expect)
            {
                let resp = ticket.wait().expect("request must be answered");
                answered += 1;
                assert_eq!(&*resp.adapter, name, "batch mixed adapters");
                assert!(resp.batch_rows >= 1 && resp.batch_rows <= 4);
                assert_eq!(resp.sites(), 1);
                for (got, exp) in resp.output().iter().zip(want) {
                    assert!(
                        (got - exp).abs() < 1e-4,
                        "{name}: {got} vs {exp}"
                    );
                }
            }
            assert_eq!(answered, total);
            let (batches, rows) = server.batch_stats();
            assert_eq!(rows as usize, total,
                       "every row batched exactly once");
            assert!(batches >= 1);
        });
    }

    #[test]
    fn multi_site_requests_route_every_site_bit_identically() {
        // Serial requests (each waited before the next) pin batch_rows
        // to 1, so the engine's per-site outputs must match the
        // AdaptedModel's own 1-row forward bit for bit.
        let spec =
            ModelSpec::synthetic(3, SiteShape { m: 16, n: 14 }, 4, 3);
        let mut model = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        let mut rng = Pcg64::derive(7, "ms");
        let ys: Vec<Matrix> = spec
            .sites
            .iter()
            .map(|s| Matrix::gaussian(s.a, s.b, 0.5, &mut rng))
            .collect();
        model.insert_synthetic("ms", 7, 2.0, ys.clone()).unwrap();
        // reference copy served outside the engine
        let mut reference = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        reference.insert_synthetic("ms", 7, 2.0, ys).unwrap();

        let server = Server::new(model, &test_cfg(4, 200));
        for round in 0..3 {
            let xs_mat: Vec<Matrix> = spec
                .sites
                .iter()
                .map(|s| {
                    Matrix::gaussian(1, s.shape.n, 1.0, &mut rng)
                })
                .collect();
            let xs_rows: Vec<Vec<f32>> =
                xs_mat.iter().map(|m| m.data.clone()).collect();
            let resp = server
                .submit("ms", xs_rows)
                .unwrap()
                .wait()
                .unwrap();
            assert_eq!(resp.sites(), 3);
            assert_eq!(resp.batch_rows, 1, "serial submits stay 1-row");
            let want = reference.forward("ms", &xs_mat).unwrap();
            for (site, wm) in want.iter().enumerate() {
                let got = resp.site_output(site);
                assert_eq!(got.len(), spec.sites[site].shape.m);
                for (p, q) in got.iter().zip(&wm.data) {
                    assert_eq!(p.to_bits(), q.to_bits(),
                               "round {round} site {site} diverged");
                }
            }
        }
        // wrong per-site row count / width are submit-time errors
        assert!(server.submit("ms", vec![vec![0.0; 14]]).is_err());
        let bad: Vec<Vec<f32>> =
            vec![vec![0.0; 14], vec![0.0; 13], vec![0.0; 14]];
        assert!(server.submit("ms", bad).is_err());
        assert!(server.submit_row("ms", vec![0.0; 14]).is_err(),
                "submit_row must refuse multi-site models");
    }

    #[test]
    fn full_batches_flush_on_size_not_deadline() {
        let model = test_model(&[("solo", 7)]);
        // max_wait far beyond the test budget: only the size trigger can
        // flush, so replies prove the max-batch path works.
        let server = Server::new(model, &test_cfg(4, 30_000_000));
        let x = vec![0.25f32; N];
        let tickets: Vec<Ticket> = (0..8)
            .map(|_| server.submit_row("solo", x.clone()).unwrap())
            .collect();
        for t in tickets {
            let resp = t.wait().unwrap();
            assert_eq!(resp.batch_rows, 4, "size-triggered flush");
        }
    }

    #[test]
    fn max_wait_is_honored_for_partial_batches() {
        let model = test_model(&[("solo", 7)]);
        let wait_us = 50_000; // 50 ms
        let server = Server::new(model, &test_cfg(64, wait_us));
        let t = server.submit_row("solo", vec![1.0; N]).unwrap();
        let submitted = t.submitted;
        let resp = t.wait().unwrap();
        let waited = resp.done.duration_since(submitted);
        // Flushed by the deadline (not by size: batch stayed at 1 row),
        // within a generous service-time margin for slow CI machines.
        assert_eq!(resp.batch_rows, 1);
        assert!(
            waited >= Duration::from_micros(wait_us / 2),
            "flushed way before the wait bound: {waited:?}"
        );
        assert!(
            waited < Duration::from_secs(20),
            "partial batch never flushed: {waited:?}"
        );
    }

    #[test]
    fn expired_requests_get_timeout_errors_without_occupying_batches() {
        let model = test_model(&[("solo", 7)]);
        // max_wait far beyond the test budget: only the deadline can
        // get these answered.
        let server = Server::new(model, &test_cfg(64, 30_000_000));
        let t = server
            .submit_with_deadline(
                "solo",
                vec![vec![1.0; N]],
                Duration::from_millis(20),
            )
            .unwrap();
        let submitted = t.submitted;
        let err = t.wait().expect_err("expired request must error");
        assert!(err.to_string().contains("timed out"), "{err}");
        let waited = submitted.elapsed();
        assert!(
            waited < Duration::from_secs(20),
            "timeout answer must arrive near the deadline, not at \
             max_wait: {waited:?}"
        );
        let (expired, _) = server.drop_stats();
        assert_eq!(expired, 1);
        let (batches, rows) = server.batch_stats();
        assert_eq!((batches, rows), (0, 0),
                   "an expired request must not occupy a batch slot");
        // a deadline that is not hit leaves the request untouched
        let t = server
            .submit_with_deadline(
                "solo",
                vec![vec![1.0; N]],
                Duration::from_secs(600),
            )
            .unwrap();
        // force a flush by filling the batch is impossible here
        // (max_wait is huge), so cancel the noop path via shutdown
        drop(server); // shutdown drains: the request must be answered
        assert!(t.wait().is_ok(), "unexpired request served on drain");
    }

    #[test]
    fn cancelled_requests_are_dropped_from_their_batch() {
        let model = test_model(&[("solo", 7)]);
        // max_wait far beyond the test budget: only the cancel sweep
        // can get this answered — proving cancellation does not wait
        // out the group's max_wait bound.
        let server = Server::new(model, &test_cfg(4, 30_000_000));
        let t = server.submit_row("solo", vec![0.5; N]).unwrap();
        let submitted = t.submitted;
        let handle = t.cancel_handle();
        handle.cancel();
        assert!(handle.is_cancelled());
        let err = t.wait().expect_err("cancelled request must error");
        assert!(err.to_string().contains("cancelled"), "{err}");
        assert!(
            submitted.elapsed() < Duration::from_secs(20),
            "cancel answer must arrive via the sweep, not at max_wait"
        );
        let (_, cancelled) = server.drop_stats();
        assert_eq!(cancelled, 1);
        let (batches, rows) = server.batch_stats();
        assert_eq!((batches, rows), (0, 0),
                   "a cancelled request must not occupy a batch slot");
        // cancellation is per-request: the next one serves normally
        // (shutdown-drained here — max_wait is far beyond the budget)
        let t = server.submit_row("solo", vec![0.5; N]).unwrap();
        drop(server);
        assert!(t.wait().is_ok());
    }

    #[test]
    fn output_buffers_recycle_across_batches() {
        let model = test_model(&[("solo", 7)]);
        let server = Server::new(model, &test_cfg(4, 200));
        for _ in 0..10 {
            // wait + drop each response so its pooled output returns
            // before the next batch takes one
            let resp =
                server.submit_row("solo", vec![0.5; N]).unwrap().wait();
            drop(resp);
        }
        let (allocs, reuses) = server.output_pool_stats();
        assert!(allocs <= 2,
                "steady single-row batches must reuse, not allocate: \
                 {allocs} allocs");
        assert!(reuses >= 8, "pool must actually be reused: {reuses}");
    }

    #[test]
    fn scheduler_stats_track_depth_and_per_adapter_counts() {
        let model = test_model(&[("alpha", 7), ("beta", 8)]);
        let server = Server::new(model, &test_cfg(4, 200));
        assert_eq!(server.queue_depth(), 0, "idle engine has empty queue");
        let mut tickets = Vec::new();
        for _ in 0..3 {
            tickets.push(server.submit_row("alpha", vec![0.1; N]).unwrap());
        }
        tickets.push(server.submit_row("beta", vec![0.2; N]).unwrap());
        for t in tickets {
            t.wait().unwrap();
        }
        // Every answered ticket's Request is dropped by the worker right
        // after the reply lands, so the gauge drains to zero promptly;
        // a bounded spin absorbs the reply-then-drop window.
        let t0 = Instant::now();
        while server.queue_depth() > 0
            && t0.elapsed() < Duration::from_secs(10)
        {
            std::thread::yield_now();
        }
        let stats = server.scheduler_stats();
        assert_eq!(stats.queue_depth, 0, "answered requests must drain");
        assert_eq!(stats.submitted, 4);
        assert_eq!(stats.batched_rows, 4);
        assert!(stats.batches >= 1);
        assert_eq!(
            stats.per_adapter,
            vec![("alpha".to_string(), 3), ("beta".to_string(), 1)],
            "per-adapter counters sorted by name"
        );
        // errors drain the gauge too (the guard rides the Request)
        let t = server.submit_row("ghost", vec![0.0; N]).unwrap();
        assert!(t.wait().is_err());
        let t0 = Instant::now();
        while server.queue_depth() > 0
            && t0.elapsed() < Duration::from_secs(10)
        {
            std::thread::yield_now();
        }
        assert_eq!(server.queue_depth(), 0);
        assert_eq!(server.scheduler_stats().per_adapter.len(), 3,
                   "unknown adapters still count submissions");
    }

    #[test]
    fn unknown_adapter_and_bad_row_are_errors() {
        let model = test_model(&[("solo", 7)]);
        let server = Server::new(model, &test_cfg(4, 200));
        let t = server.submit_row("ghost", vec![0.0; N]).unwrap();
        assert!(t.wait().is_err(), "unknown adapter must error");
        assert!(server.submit_row("solo", vec![0.0; N + 1]).is_err());
    }

    #[test]
    fn shutdown_answers_in_flight_requests() {
        let model = test_model(&[("solo", 7)]);
        // huge wait: only the shutdown drain can flush these
        let mut server = Server::new(model, &test_cfg(64, 30_000_000));
        let tickets: Vec<Ticket> = (0..3)
            .map(|_| server.submit_row("solo", vec![0.5; N]).unwrap())
            .collect();
        server.shutdown();
        for t in tickets {
            assert!(t.wait().is_ok(), "shutdown must drain, not drop");
        }
        assert!(server.submit_row("solo", vec![0.5; N]).is_err());
    }

    #[test]
    fn hot_load_and_evict_while_serving() {
        let model = test_model(&[("old", 7)]);
        let server = Server::new(model, &test_cfg(4, 200));
        let model = server.model();
        {
            let mut mdl = model.lock().unwrap();
            let mut rng = Pcg64::derive(11, "new");
            let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
            mdl.insert(
                "new",
                11,
                2.0,
                vec![CoreInput::new("adp.0.wq.l", "adp.0.wq.r", y)],
            )
            .unwrap();
            mdl.evict("old");
        }
        let t_new = server.submit_row("new", vec![0.1; N]).unwrap();
        assert!(t_new.wait().is_ok(), "hot-loaded adapter must serve");
        let t_old = server.submit_row("old", vec![0.1; N]).unwrap();
        assert!(t_old.wait().is_err(), "evicted adapter must error");
    }
}
