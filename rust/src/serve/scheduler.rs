//! Request scheduler: class-fair queue -> fused cross-adapter batches
//! -> worker pool.
//!
//! ## Data flow
//!
//! ```text
//! submit() --ingress--> WFQ batcher --fused batches--> workers --> Ticket
//! ```
//!
//! * **submit** accepts one whole-model request — one activation row
//!   per site of the served [`AdaptedModel`], in spec order — and
//!   returns a [`Ticket`] the caller blocks on.  Single-site models
//!   keep the PR-3 ergonomics via [`Server::submit_row`].  Requests may
//!   carry a **deadline** ([`Server::submit_with_deadline`]): an
//!   expired request is answered with a timeout error instead of
//!   occupying compute in a batch, and the batcher flushes early so the
//!   timeout answer arrives near the deadline rather than at
//!   `max_wait`.  A [`Ticket::cancel_handle`] drops the request the
//!   same way from any thread; cancelled requests are flushed by a
//!   bounded batcher sweep (`CANCEL_SWEEP`), so the "cancelled" answer
//!   also never waits out a long `max_wait`.  Every submit surface may
//!   also carry a **QoS class** ([`Server::submit_classed`]).
//! * The **batcher** thread groups pending requests **by site shape**,
//!   not by adapter id — and since submit-time validation pins every
//!   accepted request to the served model's site shapes, the whole
//!   pending set is one fusable group: rows bound for *different
//!   adapters* ride one fused batch.  What the class queues decide is
//!   the *boarding order*: deficit-weighted fair queuing over the three
//!   [`RequestClass`] tiers (weights from `[serve.classes]`), so
//!   interactive rows board first in proportion to their weight while a
//!   backlogged background class still boards at least one row per
//!   rotation — between two consecutive background rows at most
//!   `w_interactive + w_batch` rows from the other classes board
//!   (asserted by the starvation test).  A batch flushes when it
//!   reaches `max_batch` rows or when a member reaches its effective
//!   wait bound (`min(arrival + max_wait, deadline)`).
//! * **Workers** (count resolved through the same `plan_threads` helper
//!   the compute backends share) pull whole fused batches, segment them
//!   by (adapter, method) in first-seen order — every adapter is
//!   uniform-method, so adapter segmentation *is* method segmentation —
//!   and resolve **all** adapters of the batch through one
//!   [`AdaptedModel::plan_many`] under a brief model lock: cache misses
//!   for every cold regenerable tensor of every segment are described
//!   by that one call ([`ModelPlan::regen_missing`] materializes them
//!   through each method's declared [`RegenSpec`](crate::adapters::
//!   RegenSpec)s outside the lock), then installed under a second brief
//!   lock ([`AdaptedModel::install_many`]) — so a cold or thrashing
//!   projection cache never serializes the pool, and a K-adapter batch
//!   costs two lock round-trips instead of 2·K.  The worker then
//!   assembles one segment-stacked batch matrix per site in
//!   worker-owned [`Workspace`] buffers and runs one **grouped
//!   block-diagonal** [`forward_grouped_into`] sweep per site — maximal
//!   same-method segment runs dispatch through each method's grouped
//!   kernel (all-CoSA batches take the exact pre-trait grouped path),
//!   bit-identical to composing per-adapter batches.  The matmul hot
//!   path is allocation-free at steady state (the Workspace contract),
//!   and the per-site batch *outputs* come from the shared
//!   [`OutputPool`](super::outpool::OutputPool) — recycled across
//!   workers when the last ticket of a batch drops them — so a batch
//!   allocates nothing after warmup, end to end.  Setting
//!   `[serve] fused = false` keeps the ingress/batcher identical but
//!   computes each adapter segment independently — the pre-fusion
//!   per-adapter path, kept as the serving-tail bench baseline.
//!
//! Fused batching is what buys multi-adapter throughput at heavy-tail
//! adapter popularity: per-adapter grouping leaves most batches at one
//! or two rows once requests spread over hundreds of cold adapters,
//! re-paying per-batch overheads (locks, pool draws, dispatch) per row,
//! while the fused batch amortizes them across every adapter at once
//! (`benches/serve_bench.rs` measures the tail-heavy scenario; CI gates
//! the fused-vs-per-adapter ratio machine-independently).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::adapters::{
    forward_grouped_into_marked, Adapter, GroupedMarks,
};
use crate::config::ServeConfig;
use crate::linalg::tiled::plan_threads;
use crate::linalg::{QuantMat, Workspace};
use crate::math::matrix::Matrix;
use crate::model::{AdaptedModel, ModelHandles, ModelPlan};
use crate::obs::{self, Outcome, Stage, Trace};

use super::outpool::{OutputPool, PooledOut};

/// QoS class of one request — the weighted-fair-queuing tier its row
/// boards fused batches under (see module docs).  `Interactive` is the
/// default on every legacy submit surface.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RequestClass {
    #[default]
    Interactive,
    Batch,
    Background,
}

impl RequestClass {
    /// Every class, scheduling order (index == internal queue index).
    pub const ALL: [RequestClass; 3] = [
        RequestClass::Interactive,
        RequestClass::Batch,
        RequestClass::Background,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            RequestClass::Interactive => "interactive",
            RequestClass::Batch => "batch",
            RequestClass::Background => "background",
        }
    }

    /// Parse a wire-facing class name.  `None` on anything unknown —
    /// the gateway turns that into a 400, never a silent default.
    pub fn parse(s: &str) -> Option<RequestClass> {
        match s {
            "interactive" => Some(RequestClass::Interactive),
            "batch" => Some(RequestClass::Batch),
            "background" => Some(RequestClass::Background),
            _ => None,
        }
    }

    fn idx(self) -> usize {
        self as usize
    }
}

/// One answered request.  `outs` holds the whole batch's per-site
/// output matrices, shared by every ticket of the batch; `row` is this
/// request's row in each of them.
pub struct Response {
    pub outs: Arc<Vec<PooledOut>>,
    pub row: usize,
    /// Adapter id this request's row segment ran under (a fused batch
    /// mixes adapters; `row` always lands inside its own segment).
    pub adapter: Arc<str>,
    /// Rows in the batch this request rode in.
    pub batch_rows: usize,
    /// When the worker finished the batch (latency = `done` - submit).
    pub done: Instant,
}

impl Response {
    /// Adapted sites in this response (the model's site count).
    pub fn sites(&self) -> usize {
        self.outs.len()
    }

    /// This request's output row at `site` (width `m_site`).
    pub fn site_output(&self, site: usize) -> &[f32] {
        self.outs[site].row(self.row)
    }

    /// Site-0 output row — the whole answer for single-site models.
    pub fn output(&self) -> &[f32] {
        self.site_output(0)
    }
}

type Reply = Result<Response, String>;

/// Cancels one in-flight request from any thread (cloneable; survives
/// the ticket moving into `wait`).  A cancelled request is dropped from
/// its batch at flush time and answered with a "cancelled" error.
#[derive(Clone)]
pub struct CancelHandle(Arc<AtomicBool>);

impl CancelHandle {
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Handle for one in-flight request; `wait` blocks for the answer.
pub struct Ticket {
    rx: Receiver<Reply>,
    /// When the request entered the queue (set by `submit`).
    pub submitted: Instant,
    cancel: CancelHandle,
}

impl Ticket {
    pub fn wait(self) -> anyhow::Result<Response> {
        match self.rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(msg)) => Err(anyhow::anyhow!(msg)),
            Err(_) => Err(anyhow::anyhow!(
                "server shut down before answering the request"
            )),
        }
    }

    /// Mark this request cancelled (see [`CancelHandle`]).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// A clonable handle for cancelling after the ticket moves away.
    pub fn cancel_handle(&self) -> CancelHandle {
        self.cancel.clone()
    }
}

/// Decrements the in-flight gauge exactly once, whenever its request
/// leaves the engine — answered by a worker, dropped with a batch on a
/// teardown race, or never sent at all.  Tying the decrement to `Drop`
/// (instead of sprinkling it over every reply path) is what keeps the
/// [`SchedulerStats::queue_depth`] gauge exact: a `Request` is dropped
/// exactly once, no matter which path answered it.
struct InflightGuard(Arc<ServerStats>);

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

struct Request {
    adapter: Arc<str>,
    /// One activation row per site, spec order.
    xs: Vec<Vec<f32>>,
    reply: Sender<Reply>,
    at: Instant,
    /// Absolute expiry; `None` = never.
    deadline: Option<Instant>,
    cancelled: Arc<AtomicBool>,
    class: RequestClass,
    /// Stage-timing span riding the request (`None` when tracing is
    /// off).  The ticket carries it — no thread-locals cross the pool.
    trace: Option<Trace>,
    _inflight: InflightGuard,
}

/// One fused batch: rows for possibly many adapters, in boarding order
/// (the worker segments them by adapter, first-seen order).
struct Batch {
    reqs: Vec<Request>,
}

/// Scheduler counters (mean batch size benches report is
/// `rows / batches`; `expired`/`cancelled` count dropped requests;
/// `inflight` is the live queue-depth gauge maintained by
/// [`InflightGuard`]; `by_adapter` counts submissions per adapter name;
/// the `class_*` triples index by [`RequestClass`]).
#[derive(Default)]
struct ServerStats {
    batches: AtomicU64,
    batched_rows: AtomicU64,
    expired: AtomicU64,
    cancelled: AtomicU64,
    submitted: AtomicU64,
    inflight: AtomicU64,
    by_adapter: Mutex<HashMap<Arc<str>, u64>>,
    /// Submissions not counted in `by_adapter` because the name cap
    /// was reached (see `MAX_TRACKED_ADAPTERS`).
    untracked: AtomicU64,
    class_submitted: [AtomicU64; 3],
    class_answered: [AtomicU64; 3],
    /// Per-class service latency (submit → computed reply), the shared
    /// `obs` log₂-µs histogram (formerly the scheduler-private
    /// `LatencyHist` — identical bucketing and p99 semantics).
    class_latency: [obs::Histogram; 3],
}

/// Distinct adapter names the per-adapter counter map will track.
/// Submission names are caller-controlled (the wire gateway forwards
/// client strings), so an unbounded map would be a remote
/// memory-exhaustion vector; overflow lands in
/// [`SchedulerStats::per_adapter_untracked`] instead.
const MAX_TRACKED_ADAPTERS: usize = 1024;

/// Per-class QoS counters in a [`SchedulerStats`] snapshot.
#[derive(Clone, Debug, Default)]
pub struct ClassStats {
    pub class: String,
    pub submitted: u64,
    /// Requests answered with computed output (errors excluded).
    pub answered: u64,
    /// p50 service latency (submit → computed reply) in µs, as the
    /// log₂-bucket upper edge; 0 until the class answers a request.
    pub p50_us: u64,
    /// p95, same semantics as `p50_us`.
    pub p95_us: u64,
    /// p99, same semantics as `p50_us`.
    pub p99_us: u64,
    /// The full latency histogram snapshot (`/metrics` renders it as
    /// `_bucket`/`_sum`/`_count` series).
    pub hist: obs::Snapshot,
}

/// Cheap point-in-time snapshot of the engine's counters — the surface
/// behind the wire `/v1/stats` endpoint and queue-depth admission
/// control.  `queue_depth` counts requests submitted but not yet
/// answered (queued in the batcher, riding a batch, or mid-compute);
/// `per_adapter` is (name, submitted) sorted by name; `per_class` is
/// always [`RequestClass::ALL`] order.
#[derive(Clone, Debug, Default)]
pub struct SchedulerStats {
    pub queue_depth: u64,
    pub submitted: u64,
    pub batches: u64,
    pub batched_rows: u64,
    pub expired: u64,
    pub cancelled: u64,
    pub per_adapter: Vec<(String, u64)>,
    /// Submissions under names beyond the tracked-adapter cap.
    pub per_adapter_untracked: u64,
    pub per_class: Vec<ClassStats>,
}

/// The serving engine: adapted model + WFQ batcher + worker pool.  See
/// module docs for the data flow; construction spawns the threads,
/// `shutdown` (or drop) drains and joins them.
pub struct Server {
    ingress: Option<Sender<Request>>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    model: Arc<Mutex<AdaptedModel>>,
    stats: Arc<ServerStats>,
    out_pool: Arc<OutputPool>,
    /// Shared telemetry registry (a disabled one for `Server::new`
    /// callers; `Server::with_obs` wires a live one through).
    obs: Arc<obs::Registry>,
    /// Per-site input widths, spec order (submit-time validation).
    site_ns: Vec<usize>,
    worker_count: usize,
}

/// Ceiling on spawned workers, however configured — each worker is a
/// real OS thread and more of them than cores only adds contention.
const MAX_WORKERS: usize = 64;

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl Server {
    /// Spawn the engine over `model`.  `cfg` is used as-is — apply
    /// `ServeConfig::env_overridden()` at the call site (the CLI and
    /// bench drivers do), so tests stay hermetic.  Tracing is off;
    /// callers that want spans use [`Server::with_obs`].
    pub fn new(model: AdaptedModel, cfg: &ServeConfig) -> Server {
        Self::with_obs(model, cfg, obs::Registry::disabled())
    }

    /// [`Server::new`] with a shared telemetry registry: every request
    /// gets a [`Trace`] from `obs` (unless it is disabled), and the
    /// per-stage histograms / slow ring aggregate there.
    pub fn with_obs(
        mut model: AdaptedModel,
        cfg: &ServeConfig,
        obs: Arc<obs::Registry>,
    ) -> Server {
        let site_ns: Vec<usize> =
            model.spec().sites.iter().map(|s| s.shape.n).collect();
        // One funnel for the cache codec: whatever `[serve] cache_quant`
        // resolved to governs every install this server performs.
        // Config load and env override both validated the string, so an
        // unparseable value here (hand-built cfg) keeps the model's
        // current codec rather than guessing.
        match cfg.cache_quant_kind() {
            Ok(kind) => model.set_cache_quant(kind),
            Err(e) => eprintln!("warning: serve.cache_quant: {e}"),
        }
        let max_batch = cfg.max_batch.max(1);
        let max_wait = Duration::from_micros(cfg.max_wait_us);
        // Zero weights would stall a class's queue forever; config
        // validation rejects them at load time, this clamp covers
        // hand-built configs.
        let weights = [
            cfg.classes.interactive.max(1),
            cfg.classes.batch.max(1),
            cfg.classes.background.max(1),
        ];
        let fused = cfg.fused;
        // Same resolution rule as the compute backends: explicit count,
        // or auto (available_parallelism, capped) — the zero-FLOP floor
        // means serving always gets its workers.  Unlike the compute
        // kernels (where plan_threads clamps to actual matrix rows), a
        // server has no natural row bound, so cap explicit requests too
        // instead of attempting an unbounded number of thread spawns.
        let workers = if cfg.workers > MAX_WORKERS {
            eprintln!(
                "warning: serve workers capped at {MAX_WORKERS} \
                 (requested {})",
                cfg.workers
            );
            MAX_WORKERS
        } else {
            cfg.workers
        };
        let worker_count = plan_threads(workers, 0, usize::MAX, usize::MAX);

        let model = Arc::new(Mutex::new(model));
        let stats = Arc::new(ServerStats::default());
        let out_pool = OutputPool::shared();
        let (ingress_tx, ingress_rx) = channel::<Request>();
        let (batch_tx, batch_rx) = channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        let batcher = std::thread::spawn(move || {
            batcher_loop(ingress_rx, batch_tx, max_batch, max_wait, weights);
        });
        let mut workers = Vec::with_capacity(worker_count);
        for _ in 0..worker_count {
            let rx = batch_rx.clone();
            let mdl = model.clone();
            let st = stats.clone();
            let pool = out_pool.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(&rx, &mdl, &st, &pool, fused);
            }));
        }
        Server {
            ingress: Some(ingress_tx),
            batcher: Some(batcher),
            workers,
            model,
            stats,
            out_pool,
            obs,
            site_ns,
            worker_count,
        }
    }

    /// The shared telemetry registry (exposition endpoints render it).
    pub fn obs(&self) -> Arc<obs::Registry> {
        self.obs.clone()
    }

    /// Workers actually spawned (after auto resolution).
    pub fn worker_count(&self) -> usize {
        self.worker_count
    }

    /// (batches executed, total rows batched) so far.
    pub fn batch_stats(&self) -> (u64, u64) {
        (
            self.stats.batches.load(Ordering::Relaxed),
            self.stats.batched_rows.load(Ordering::Relaxed),
        )
    }

    /// (deadline-expired, cancelled) requests dropped from batches.
    pub fn drop_stats(&self) -> (u64, u64) {
        (
            self.stats.expired.load(Ordering::Relaxed),
            self.stats.cancelled.load(Ordering::Relaxed),
        )
    }

    /// (fresh allocations, reuses) of the shared batch-output pool.
    pub fn output_pool_stats(&self) -> (u64, u64) {
        self.out_pool.stats()
    }

    /// Point-in-time snapshot of every scheduler counter (see
    /// [`SchedulerStats`]).  Cheap: atomic loads plus one brief lock to
    /// copy the per-adapter map — safe to call on every wire request
    /// (queue-depth admission control does).
    pub fn scheduler_stats(&self) -> SchedulerStats {
        let mut per_adapter: Vec<(String, u64)> = lock(&self.stats.by_adapter)
            .iter()
            .map(|(k, v)| (k.to_string(), *v))
            .collect();
        per_adapter.sort();
        let per_class = RequestClass::ALL
            .iter()
            .map(|&c| {
                let hist = self.stats.class_latency[c.idx()].snapshot();
                ClassStats {
                    class: c.as_str().to_string(),
                    submitted: self.stats.class_submitted[c.idx()]
                        .load(Ordering::Relaxed),
                    answered: self.stats.class_answered[c.idx()]
                        .load(Ordering::Relaxed),
                    p50_us: hist.p50_us(),
                    p95_us: hist.p95_us(),
                    p99_us: hist.p99_us(),
                    hist,
                }
            })
            .collect();
        SchedulerStats {
            queue_depth: self.stats.inflight.load(Ordering::Relaxed),
            submitted: self.stats.submitted.load(Ordering::Relaxed),
            batches: self.stats.batches.load(Ordering::Relaxed),
            batched_rows: self.stats.batched_rows.load(Ordering::Relaxed),
            expired: self.stats.expired.load(Ordering::Relaxed),
            cancelled: self.stats.cancelled.load(Ordering::Relaxed),
            per_adapter,
            per_adapter_untracked: self
                .stats
                .untracked
                .load(Ordering::Relaxed),
            per_class,
        }
    }

    /// The queue-depth gauge alone (requests submitted but not yet
    /// answered) — the admission-control fast path, no map copy.
    pub fn queue_depth(&self) -> u64 {
        self.stats.inflight.load(Ordering::Relaxed)
    }

    /// The shared adapted model (hot load/evict while serving, cache
    /// stats).
    pub fn model(&self) -> Arc<Mutex<AdaptedModel>> {
        self.model.clone()
    }

    /// Submit-time validation: the request must match the served
    /// model's site count and per-site input widths.
    fn validate_sites(&self, xs: &[Vec<f32>]) -> anyhow::Result<()> {
        anyhow::ensure!(
            xs.len() == self.site_ns.len(),
            "request has {} site rows, model has {} sites",
            xs.len(),
            self.site_ns.len()
        );
        for (i, (x, n)) in xs.iter().zip(&self.site_ns).enumerate() {
            anyhow::ensure!(
                x.len() == *n,
                "site {i}: request row has {} values, site expects {n}",
                x.len()
            );
        }
        Ok(())
    }

    fn submit_inner(
        &self,
        adapter: &str,
        xs: Vec<Vec<f32>>,
        class: RequestClass,
        deadline: Option<Duration>,
        trace: Option<Trace>,
    ) -> anyhow::Result<Ticket> {
        // In-process callers get their span opened here; wire callers
        // hand one in that already carries the parse/admission marks.
        let mut trace = trace.or_else(|| self.obs.begin());
        if let Some(t) = trace.as_mut() {
            t.set_class(class.idx());
            if t.mark_us(Stage::Parse).is_none() {
                t.mark(Stage::Parse);
            }
            if t.mark_us(Stage::Admission).is_none() {
                t.mark(Stage::Admission);
            }
        }
        if let Err(e) = self.validate_sites(&xs) {
            if let Some(t) = trace.take() {
                t.finish(Outcome::Errored);
            }
            return Err(e);
        }
        let Some(ingress) = self.ingress.as_ref() else {
            if let Some(t) = trace.take() {
                t.finish(Outcome::Errored);
            }
            return Err(anyhow::anyhow!("server is shut down"));
        };
        let (tx, rx) = channel::<Reply>();
        let submitted = Instant::now();
        let cancelled = Arc::new(AtomicBool::new(false));
        let key: Arc<str> = Arc::from(adapter);
        if let Some(t) = trace.as_mut() {
            t.set_adapter(&key);
        }
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        self.stats.class_submitted[class.idx()]
            .fetch_add(1, Ordering::Relaxed);
        self.stats.inflight.fetch_add(1, Ordering::Relaxed);
        {
            let mut map = lock(&self.stats.by_adapter);
            match map.get_mut(&key) {
                Some(count) => *count += 1,
                None if map.len() < MAX_TRACKED_ADAPTERS => {
                    map.insert(key.clone(), 1);
                }
                None => {
                    self.stats.untracked.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        let req = Request {
            adapter: key,
            xs,
            reply: tx,
            at: submitted,
            deadline: deadline.map(|d| submitted + d),
            cancelled: cancelled.clone(),
            class,
            trace,
            _inflight: InflightGuard(self.stats.clone()),
        };
        // A send failure drops `req` — its trace records `dropped`,
        // which is exactly what a mid-shutdown teardown is.
        ingress
            .send(req)
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        Ok(Ticket { rx, submitted, cancel: CancelHandle(cancelled) })
    }

    /// Enqueue one whole-model request (one row per site, spec order).
    /// Returns immediately; block on the ticket for the answer.
    pub fn submit(
        &self,
        adapter: &str,
        xs: Vec<Vec<f32>>,
    ) -> anyhow::Result<Ticket> {
        self.submit_inner(adapter, xs, RequestClass::default(), None, None)
    }

    /// [`Server::submit`] with an explicit QoS class and optional
    /// relative deadline.
    pub fn submit_classed(
        &self,
        adapter: &str,
        xs: Vec<Vec<f32>>,
        class: RequestClass,
        deadline: Option<Duration>,
    ) -> anyhow::Result<Ticket> {
        self.submit_inner(adapter, xs, class, deadline, None)
    }

    /// [`Server::submit_classed`] with a caller-opened [`Trace`] (the
    /// wire gateway opens one at HTTP accept so the span covers parse
    /// and admission; `None` falls back to opening one here).
    pub fn submit_traced(
        &self,
        adapter: &str,
        xs: Vec<Vec<f32>>,
        class: RequestClass,
        deadline: Option<Duration>,
        trace: Option<Trace>,
    ) -> anyhow::Result<Ticket> {
        self.submit_inner(adapter, xs, class, deadline, trace)
    }

    /// [`Server::submit`] with a relative deadline: if the request is
    /// still queued when it expires, it is answered with a timeout
    /// error instead of occupying a batch slot.
    pub fn submit_with_deadline(
        &self,
        adapter: &str,
        xs: Vec<Vec<f32>>,
        deadline: Duration,
    ) -> anyhow::Result<Ticket> {
        self.submit_inner(
            adapter,
            xs,
            RequestClass::default(),
            Some(deadline),
            None,
        )
    }

    /// Single-row sugar for 1-site models (the PR-3 surface).
    pub fn submit_row(
        &self,
        adapter: &str,
        x: Vec<f32>,
    ) -> anyhow::Result<Ticket> {
        anyhow::ensure!(
            self.site_ns.len() == 1,
            "submit_row needs a 1-site model; this one has {} sites",
            self.site_ns.len()
        );
        self.submit_inner(
            adapter,
            vec![x],
            RequestClass::default(),
            None,
            None,
        )
    }

    /// Stop accepting requests, drain everything in flight, join the
    /// threads.  Every request submitted before shutdown is answered.
    pub fn shutdown(&mut self) {
        self.ingress.take(); // batcher sees disconnect, flushes, exits
        if let Some(b) = self.batcher.take() {
            let _ = b.join(); // dropping its batch sender stops workers
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// When a pending request must leave the batcher: its arrival plus the
/// group wait bound, or its own deadline — whichever is sooner (an
/// expired request must be *answered* near its deadline, which means
/// flushing it to a worker that sends the timeout error).
fn effective_flush_at(r: &Request, max_wait: Duration) -> Instant {
    let by_wait = r.at + max_wait;
    match r.deadline {
        Some(d) => d.min(by_wait),
        None => by_wait,
    }
}

/// How often the batcher sweeps pending queues for cancelled members
/// while anything is pending.  Cancellation is an async flag with no
/// wake channel (a `Sender`-holding cancel handle would keep the
/// ingress alive and hang shutdown), so a bounded poll keeps
/// drop-on-cancel prompt even under a multi-second `max_wait`.
const CANCEL_SWEEP: Duration = Duration::from_millis(5);

/// The batcher's pending set: one FIFO per QoS class plus the
/// deficit-round-robin state that drains them in weighted fair order.
/// Every request the server accepts shares the served model's site
/// shapes (submit validates the widths), so the whole set is one
/// fusable group — the class queues only decide the order rows *board*
/// a fused batch.
///
/// DRR with quantum = configured class weight: a backlogged class
/// boards up to its weight per rotation before the cursor moves on, so
/// between two consecutive background rows at most
/// `w_interactive + w_batch` rows from the other classes board — the
/// bounded-wait guarantee the starvation test asserts.
struct ClassQueues {
    queues: [VecDeque<(Instant, Request)>; 3],
    /// Cached per-class minimum of the members' flush instants; exact
    /// after every [`ClassQueues::refresh_min`].
    min_flush: [Option<Instant>; 3],
    weights: [u64; 3],
    deficit: [u64; 3],
    cursor: usize,
    len: usize,
}

impl ClassQueues {
    fn new(weights: [u64; 3]) -> ClassQueues {
        ClassQueues {
            queues: Default::default(),
            min_flush: [None; 3],
            weights,
            deficit: [0; 3],
            cursor: 0,
            len: 0,
        }
    }

    fn push(&mut self, req: Request, flush_at: Instant) {
        let c = req.class.idx();
        self.min_flush[c] = Some(match self.min_flush[c] {
            Some(t) => t.min(flush_at),
            None => flush_at,
        });
        self.queues[c].push_back((flush_at, req));
        self.len += 1;
    }

    /// Earliest instant any pending member must leave the batcher.
    fn earliest(&self) -> Option<Instant> {
        self.min_flush.iter().flatten().copied().min()
    }

    /// Must a batch flush now?  True when any member reached its wait
    /// bound, or — on sweep ticks — when any member was cancelled (so
    /// the "cancelled" answer never waits out `max_wait`).
    fn due(&self, now: Instant, sweep: bool) -> bool {
        if self.len == 0 {
            return false;
        }
        if self.min_flush.iter().flatten().any(|&t| now >= t) {
            return true;
        }
        sweep
            && self
                .queues
                .iter()
                .flatten()
                .any(|(_, r)| r.cancelled.load(Ordering::Relaxed))
    }

    /// Next boarding request in weighted fair order (see struct docs).
    fn pop_next(&mut self) -> Option<Request> {
        if self.len == 0 {
            return None;
        }
        loop {
            let c = self.cursor;
            if self.queues[c].is_empty() {
                // standard DRR: an idle class banks no credit
                self.deficit[c] = 0;
                self.cursor = (c + 1) % 3;
                continue;
            }
            if self.deficit[c] == 0 {
                self.deficit[c] = self.weights[c];
            }
            self.deficit[c] -= 1;
            if self.deficit[c] == 0 {
                self.cursor = (c + 1) % 3;
            }
            // Checked-empty above, but degrade to "no request" rather
            // than panicking the batcher if `len` ever drifts from the
            // queue contents.
            let Some((_, req)) = self.queues[c].pop_front() else {
                self.len = self.queues.iter().map(|q| q.len()).sum();
                return None;
            };
            self.len -= 1;
            return Some(req);
        }
    }

    /// Recompute the cached flush minima after a partial drain —
    /// members leave in WFQ order, not FIFO-wholesale, so the
    /// join-monotone cache stops being exact once a batch boards.
    fn refresh_min(&mut self) {
        for (c, q) in self.queues.iter().enumerate() {
            self.min_flush[c] = q.iter().map(|(t, _)| *t).min();
        }
    }
}

fn batcher_loop(
    rx: Receiver<Request>,
    tx: Sender<Batch>,
    max_batch: usize,
    max_wait: Duration,
    weights: [u64; 3],
) {
    let mut pending = ClassQueues::new(weights);
    'run: loop {
        let received = match pending.earliest() {
            // Nothing pending: block until a request (or shutdown).
            None => match rx.recv() {
                Ok(r) => Some(r),
                Err(_) => break 'run,
            },
            Some(deadline) => {
                let until = deadline
                    .saturating_duration_since(Instant::now())
                    .min(CANCEL_SWEEP);
                match rx.recv_timeout(until) {
                    Ok(r) => Some(r),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => break 'run,
                }
            }
        };
        // Timeout wakeups double as cancellation sweeps; arrivals skip
        // the O(pending) member scan.
        let sweep = received.is_none();
        if let Some(req) = received {
            let eff = effective_flush_at(&req, max_wait);
            pending.push(req, eff);
            if pending.len >= max_batch
                && !flush_one(&mut pending, &tx, max_batch)
            {
                return; // workers gone — nothing left to answer
            }
        }
        // Flush at the wait/deadline bound (the worker answers expired
        // members with the timeout error), plus — on sweep ticks —
        // whenever a cancelled member is pending, so the "cancelled"
        // answer arrives within ~CANCEL_SWEEP rather than at max_wait.
        // Each flush boards up to max_batch rows; loop until nothing
        // due remains (a due row beyond one batch boards the next).
        let now = Instant::now();
        while pending.due(now, sweep) {
            if !flush_one(&mut pending, &tx, max_batch) {
                return;
            }
        }
    }
    // Ingress disconnected (shutdown): flush everything still pending so
    // no submitted request goes unanswered.
    while pending.len > 0 {
        if !flush_one(&mut pending, &tx, max_batch) {
            return;
        }
    }
}

/// Board up to `max_batch` rows in WFQ order into one fused batch and
/// ship it; false when the workers are gone.
fn flush_one(
    pending: &mut ClassQueues,
    tx: &Sender<Batch>,
    max_batch: usize,
) -> bool {
    let mut reqs = Vec::with_capacity(max_batch.min(pending.len));
    while reqs.len() < max_batch {
        match pending.pop_next() {
            Some(mut r) => {
                // end of the queue stage: the row just boarded
                if let Some(t) = r.trace.as_mut() {
                    t.mark(Stage::Queue);
                }
                reqs.push(r);
            }
            None => break,
        }
    }
    pending.refresh_min();
    if reqs.is_empty() {
        return true;
    }
    tx.send(Batch { reqs }).is_ok()
}

fn worker_loop(
    rx: &Mutex<Receiver<Batch>>,
    model: &Mutex<AdaptedModel>,
    stats: &ServerStats,
    pool: &Arc<OutputPool>,
    fused: bool,
) {
    let mut ws = Workspace::new();
    loop {
        // Standard Mutex<Receiver> work queue: one idle worker at a
        // time blocks inside recv() *while holding the lock*; the guard
        // drops at the end of this statement, so the batch itself is
        // always processed lock-free.  Never add work to this statement
        // chain — it would run under the lock and stall the pool.
        let batch = match lock(rx).recv() {
            Ok(b) => b,
            Err(_) => return, // batcher exited and the queue is drained
        };
        // Dropped requests first: cancelled or past-deadline members
        // are answered with their error and never occupy a fused slot.
        let now = Instant::now();
        let mut live = Vec::with_capacity(batch.reqs.len());
        for mut req in batch.reqs {
            if req.cancelled.load(Ordering::Relaxed) {
                stats.cancelled.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(Err(format!(
                    "request for `{}` was cancelled",
                    req.adapter
                )));
                if let Some(t) = req.trace.take() {
                    t.finish(Outcome::Cancelled);
                }
            } else if req.deadline.is_some_and(|d| now >= d) {
                stats.expired.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(Err(format!(
                    "request for `{}` timed out: deadline exceeded \
                     after {:?} in queue",
                    req.adapter,
                    now.duration_since(req.at)
                )));
                if let Some(t) = req.trace.take() {
                    t.finish(Outcome::Expired);
                }
            } else {
                live.push(req);
            }
        }
        if live.is_empty() {
            continue;
        }
        // Segment the fused batch by adapter, first-seen order — rows
        // keep their class-fair boarding order within each segment.
        let mut names: Vec<Arc<str>> = Vec::new();
        let mut groups: Vec<Vec<Request>> = Vec::new();
        for req in live {
            match names.iter().position(|n| *n == req.adapter) {
                Some(g) => groups[g].push(req),
                None => {
                    names.push(req.adapter.clone());
                    groups.push(vec![req]);
                }
            }
        }
        for group in groups.iter_mut() {
            for req in group.iter_mut() {
                if let Some(t) = req.trace.as_mut() {
                    t.mark(Stage::BatchAssemble);
                }
            }
        }
        // Two-phase handle lookup, batched across adapters: ONE brief
        // model lock plans every adapter of the fused batch (all cold
        // sites of all segments described at once), regeneration runs
        // outside the lock, then ONE more brief lock installs
        // everything — 2 lock round-trips per batch instead of 2·K.
        let plans = {
            let name_refs: Vec<&str> = names.iter().map(|n| &**n).collect();
            lock(model).plan_many(&name_refs)
        };
        let mut seg_plans = Vec::with_capacity(plans.len());
        let mut seg_groups = Vec::with_capacity(plans.len());
        for (plan, group) in plans.into_iter().zip(groups) {
            match plan {
                Ok(p) => {
                    seg_plans.push(p);
                    seg_groups.push(group);
                }
                Err(e) => {
                    // a bad segment answers its own rows with the error;
                    // its batchmates ride on
                    let msg = format!("{e:#}");
                    for mut req in group {
                        let _ = req.reply.send(Err(msg.clone()));
                        if let Some(t) = req.trace.take() {
                            t.finish(Outcome::Errored);
                        }
                    }
                }
            }
        }
        if seg_plans.is_empty() {
            continue;
        }
        // Each plan carries the RegenSpecs its adapter method declares
        // (CoSA: [L, R] per site; fully-stored methods: none), so this
        // regeneration step is method-agnostic by construction.
        let regens: Vec<Vec<Vec<Option<Matrix>>>> =
            seg_plans.iter().map(ModelPlan::regen_missing).collect();
        let handles = lock(model).install_many(&seg_plans, regens);
        // Cache planning done (plan + regen + install): stamp the
        // cache_plan mark and the plan's method / hit-miss split on
        // every traced member — outside the model lock.
        for (plan, group) in seg_plans.iter().zip(seg_groups.iter_mut())
        {
            let (hits, misses) = plan.cache_hits_misses();
            for req in group.iter_mut() {
                if let Some(t) = req.trace.as_mut() {
                    t.set_method(plan.method.name());
                    t.add_cache(hits, misses);
                    t.mark(Stage::CachePlan);
                }
            }
        }
        if fused {
            run_fused(&handles, seg_groups, stats, pool, &mut ws);
        } else {
            // `[serve] fused = false`: identical ingress and batches,
            // each adapter segment computed independently — the
            // pre-fusion per-adapter path the tail bench baselines on.
            for (h, group) in handles.iter().zip(seg_groups) {
                run_segment(h, group, stats, pool, &mut ws);
            }
        }
    }
}

/// The fused path: one grouped block-diagonal dispatch per site over
/// every adapter segment of the batch (see module docs).
fn run_fused(
    handles: &[ModelHandles],
    mut groups: Vec<Vec<Request>>,
    stats: &ServerStats,
    pool: &Arc<OutputPool>,
    ws: &mut Workspace,
) {
    let segs: Vec<usize> = groups.iter().map(|g| g.len()).collect();
    let rows: usize = segs.iter().sum();
    let alphas: Vec<f32> = handles.iter().map(|h| h.alpha).collect();
    let nsites = handles[0].sites.len();
    let traced =
        groups.iter().flatten().any(|r| r.trace.is_some());
    // Pack phase: every site's batch matrix is assembled before any
    // compute starts, so the pack/gemm trace marks bracket the real
    // phases.  Same row gathers, same kernel calls, same order per
    // site as an interleaved loop — outputs stay bit-identical.
    let mut site_xs = Vec::with_capacity(nsites);
    for s in 0..nsites {
        // every adapter shares the spec's site dims — read them off the
        // first segment's handles
        let n = handles[0].sites[s].adapter.in_dim();
        let mut x = ws.take_matrix(rows, n);
        let mut row = 0usize;
        for group in &groups {
            for req in group {
                x.data[row * n..(row + 1) * n].copy_from_slice(&req.xs[s]);
                row += 1;
            }
        }
        site_xs.push(x);
    }
    if traced {
        mark_all(&mut groups, Stage::Pack);
    }
    let mut marks = traced.then(GroupedMarks::default);
    let mut outs = Vec::with_capacity(nsites);
    for (s, x) in site_xs.into_iter().enumerate() {
        let m = handles[0].sites[s].adapter.out_dim();
        let adapters: Vec<&dyn Adapter> = handles
            .iter()
            .map(|h| h.sites[s].adapter.as_ref())
            .collect();
        let regens: Vec<&[Arc<QuantMat>]> = handles
            .iter()
            .map(|h| h.sites[s].regen.as_slice())
            .collect();
        let mut out = pool.take(rows, m);
        forward_grouped_into_marked(
            &adapters,
            &regens,
            &alphas,
            &x,
            &segs,
            ws,
            out.matrix_mut(),
            marks.as_mut(),
        );
        ws.recycle_matrix(x);
        outs.push(out);
    }
    if traced {
        mark_all(&mut groups, Stage::Gemm);
        if let (Some(mk), Some(reg)) = (
            marks,
            groups
                .iter()
                .flatten()
                .find_map(|r| r.trace.as_ref().map(|t| t.registry().clone())),
        ) {
            reg.record_grouped(mk.copy_us, mk.compute_us);
        }
    }
    let outs = Arc::new(outs);
    let done = Instant::now();
    stats.batches.fetch_add(1, Ordering::Relaxed);
    stats.batched_rows.fetch_add(rows as u64, Ordering::Relaxed);
    let mut row = 0usize;
    for group in groups {
        for req in group {
            reply_ok(req, &outs, row, rows, done, stats);
            row += 1;
        }
    }
}

/// Stamp `stage` on every traced member of a segmented batch.
fn mark_all(groups: &mut [Vec<Request>], stage: Stage) {
    for group in groups.iter_mut() {
        for req in group.iter_mut() {
            if let Some(t) = req.trace.as_mut() {
                t.mark(stage);
            }
        }
    }
}

/// One adapter segment computed on its own batch matrices and pooled
/// outputs — the `[serve] fused = false` per-adapter path.
fn run_segment(
    h: &ModelHandles,
    mut group: Vec<Request>,
    stats: &ServerStats,
    pool: &Arc<OutputPool>,
    ws: &mut Workspace,
) {
    let rows = group.len();
    // Same pack-then-compute phase split as `run_fused`, so the
    // per-adapter baseline path carries the same trace marks.
    let mut site_xs = Vec::with_capacity(h.sites.len());
    for (s, sh) in h.sites.iter().enumerate() {
        let n = sh.adapter.in_dim();
        let mut x = ws.take_matrix(rows, n);
        for (i, req) in group.iter().enumerate() {
            x.data[i * n..(i + 1) * n].copy_from_slice(&req.xs[s]);
        }
        site_xs.push(x);
    }
    for req in group.iter_mut() {
        if let Some(t) = req.trace.as_mut() {
            t.mark(Stage::Pack);
        }
    }
    let mut outs = Vec::with_capacity(h.sites.len());
    for (sh, x) in h.sites.iter().zip(site_xs) {
        let m = sh.adapter.out_dim();
        let mut out = pool.take(rows, m);
        sh.adapter
            .forward_into(&x, &sh.regen, h.alpha, ws, out.matrix_mut());
        ws.recycle_matrix(x);
        outs.push(out);
    }
    for req in group.iter_mut() {
        if let Some(t) = req.trace.as_mut() {
            t.mark(Stage::Gemm);
        }
    }
    let outs = Arc::new(outs);
    let done = Instant::now();
    stats.batches.fetch_add(1, Ordering::Relaxed);
    stats.batched_rows.fetch_add(rows as u64, Ordering::Relaxed);
    for (row, req) in group.into_iter().enumerate() {
        reply_ok(req, &outs, row, rows, done, stats);
    }
}

/// Send one computed answer, recording per-class QoS accounting
/// (exactly one reply per live request — the exactly-once property the
/// tests pin down).
fn reply_ok(
    mut req: Request,
    outs: &Arc<Vec<PooledOut>>,
    row: usize,
    batch_rows: usize,
    done: Instant,
    stats: &ServerStats,
) {
    let c = req.class.idx();
    stats.class_answered[c].fetch_add(1, Ordering::Relaxed);
    stats.class_latency[c].record(done.duration_since(req.at));
    let resp = Response {
        outs: outs.clone(),
        row,
        adapter: req.adapter.clone(),
        batch_rows,
        done,
    };
    let _ = req.reply.send(Ok(resp));
    if let Some(mut t) = req.trace.take() {
        t.set_batch_rows(batch_rows);
        t.finish(Outcome::Answered);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::cosa::{adapter_forward, regen_l, regen_r};
    use crate::math::rng::Pcg64;
    use crate::model::{CoreInput, ModelSpec, SiteShape};
    use crate::util::prop;

    const M: usize = 12;
    const N: usize = 10;

    fn test_cfg(max_batch: usize, max_wait_us: u64) -> ServeConfig {
        ServeConfig {
            cache_mb: 4.0,
            max_batch,
            max_wait_us,
            workers: 2,
            ..ServeConfig::default()
        }
    }

    /// 1-site model matching the PR-3 test fixtures (site stem
    /// "adp.0.wq", 4x3 cores).
    fn test_model(adapters: &[(&str, u64)]) -> AdaptedModel {
        let mut model = AdaptedModel::single_site(
            "adp.0.wq",
            SiteShape { m: M, n: N },
            4,
            3,
            1 << 20,
        );
        for (name, seed) in adapters {
            let mut rng = Pcg64::derive(*seed, name);
            let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
            model
                .insert(
                    name,
                    *seed,
                    2.0,
                    vec![CoreInput::new("adp.0.wq.l", "adp.0.wq.r", y)],
                )
                .unwrap();
        }
        model
    }

    fn reference_forward(seed: u64, name: &str, x_row: &[f32]) -> Vec<f32> {
        let mut rng = Pcg64::derive(seed, name);
        let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
        let l = regen_l(seed, "adp.0.wq.l", M, 4);
        let r = regen_r(seed, "adp.0.wq.r", 3, N);
        let x = Matrix::from_vec(1, N, x_row.to_vec());
        adapter_forward(&x, &l, &r, &y, 2.0).data
    }

    #[test]
    fn absurd_worker_requests_are_capped() {
        let model = test_model(&[("solo", 7)]);
        let cfg = ServeConfig { workers: 1_000_000, ..test_cfg(4, 200) };
        let server = Server::new(model, &cfg);
        assert!(server.worker_count() <= 64, "{}", server.worker_count());
        let t = server.submit_row("solo", vec![0.0; N]).unwrap();
        assert!(t.wait().is_ok());
    }

    #[test]
    fn every_request_answered_exactly_once_with_its_own_adapters_math() {
        // Property test: random request mixes over several adapters —
        // every ticket resolves with the right adapter's math even when
        // a fused batch mixes adapters, and the scheduler's row
        // accounting matches the request count exactly (each request
        // answered exactly once).
        prop::for_all("serve answers all, rows unmixed", 5, |rng| {
            let adapters =
                [("alpha", 7u64), ("beta", 8u64), ("gamma", 9u64)];
            let model = test_model(&adapters);
            let server = Server::new(model, &test_cfg(4, 500));
            let total = prop::int_in(rng, 5, 40);
            let mut tickets = Vec::new();
            let mut expect = Vec::new();
            for _ in 0..total {
                let which = prop::int_in(rng, 0, adapters.len() - 1);
                let (name, seed) = adapters[which];
                let x: Vec<f32> =
                    (0..N).map(|_| rng.normal() as f32).collect();
                expect.push(reference_forward(seed, name, &x));
                tickets.push((name, server.submit_row(name, x).unwrap()));
            }
            let mut answered = 0usize;
            for ((name, ticket), want) in
                tickets.into_iter().zip(&expect)
            {
                let resp = ticket.wait().expect("request must be answered");
                answered += 1;
                assert_eq!(&*resp.adapter, name, "wrong adapter's segment");
                assert!(resp.batch_rows >= 1 && resp.batch_rows <= 4);
                assert_eq!(resp.sites(), 1);
                for (got, exp) in resp.output().iter().zip(want) {
                    assert!(
                        (got - exp).abs() < 1e-4,
                        "{name}: {got} vs {exp}"
                    );
                }
            }
            assert_eq!(answered, total);
            let (batches, rows) = server.batch_stats();
            assert_eq!(rows as usize, total,
                       "every row batched exactly once");
            assert!(batches >= 1);
        });
    }

    #[test]
    fn fused_batches_mix_adapters_with_exact_per_row_outputs() {
        // The tentpole end to end: four requests for four *different*
        // adapters board ONE fused batch (size-triggered — max_wait is
        // far beyond the test budget, so nothing else can flush), and
        // every ticket gets exactly its own adapter's math.
        let adapters = [
            ("alpha", 7u64),
            ("beta", 8u64),
            ("gamma", 9u64),
            ("delta", 10u64),
        ];
        let model = test_model(&adapters);
        let server = Server::new(model, &test_cfg(4, 30_000_000));
        let mut rng = Pcg64::new(3);
        let mut tickets = Vec::new();
        let mut expect = Vec::new();
        for (name, seed) in adapters {
            let x: Vec<f32> = (0..N).map(|_| rng.normal() as f32).collect();
            expect.push(reference_forward(seed, name, &x));
            tickets.push((name, server.submit_row(name, x).unwrap()));
        }
        for ((name, t), want) in tickets.into_iter().zip(&expect) {
            let resp = t.wait().unwrap();
            assert_eq!(&*resp.adapter, name);
            assert_eq!(resp.batch_rows, 4,
                       "four adapters must ride one fused batch");
            for (got, exp) in resp.output().iter().zip(want) {
                assert!((got - exp).abs() < 1e-4, "{name}: {got} vs {exp}");
            }
        }
        let (batches, rows) = server.batch_stats();
        assert_eq!((batches, rows), (1, 4), "one fused batch, all rows");
    }

    #[test]
    fn unfused_mode_serves_per_adapter_segment_batches() {
        // `[serve] fused = false` keeps ingress/batching identical but
        // computes per-adapter segments independently — each segment
        // counts as its own batch (the tail bench's baseline shape).
        let adapters = [("alpha", 7u64), ("beta", 8u64)];
        let model = test_model(&adapters);
        let cfg = ServeConfig { fused: false, ..test_cfg(4, 30_000_000) };
        let server = Server::new(model, &cfg);
        let mut tickets = Vec::new();
        for i in 0..4 {
            let (name, _) = adapters[i % 2];
            tickets
                .push((name, server.submit_row(name, vec![0.5; N]).unwrap()));
        }
        for (name, t) in tickets {
            let resp = t.wait().unwrap();
            assert_eq!(&*resp.adapter, name);
            assert_eq!(resp.batch_rows, 2, "two rows per adapter segment");
        }
        let (batches, rows) = server.batch_stats();
        assert_eq!((batches, rows), (2, 4),
                   "one batch per adapter segment when unfused");
    }

    #[test]
    fn wfq_pop_order_bounds_background_wait() {
        // The non-starvation invariant, deterministically on the DRR
        // queue itself: with background backlogged, at most
        // w_interactive + w_batch rows from the other classes board
        // between two consecutive background rows — sustained
        // interactive load cannot starve background.
        let stats = Arc::new(ServerStats::default());
        let mk = |class: RequestClass| {
            let (tx, _rx) = channel::<Reply>();
            stats.inflight.fetch_add(1, Ordering::Relaxed);
            Request {
                adapter: Arc::from("a"),
                xs: vec![Vec::new()],
                reply: tx,
                at: Instant::now(),
                deadline: None,
                cancelled: Arc::new(AtomicBool::new(false)),
                class,
                trace: None,
                _inflight: InflightGuard(stats.clone()),
            }
        };
        let weights = [8u64, 4, 1];
        let mut q = ClassQueues::new(weights);
        let now = Instant::now();
        for _ in 0..200 {
            q.push(mk(RequestClass::Interactive), now);
        }
        for _ in 0..100 {
            q.push(mk(RequestClass::Batch), now);
        }
        for _ in 0..20 {
            q.push(mk(RequestClass::Background), now);
        }
        let bound = (weights[0] + weights[1]) as usize;
        let (mut popped, mut bg_seen, mut since_bg) = (0usize, 0usize, 0);
        while let Some(r) = q.pop_next() {
            popped += 1;
            if r.class == RequestClass::Background {
                bg_seen += 1;
                since_bg = 0;
            } else {
                since_bg += 1;
                assert!(
                    bg_seen == 20 || since_bg <= bound,
                    "background starved: {since_bg} foreign rows in a \
                     row with backlog present"
                );
            }
        }
        assert_eq!(popped, 320, "every pushed request must pop");
        assert_eq!(bg_seen, 20);
    }

    #[test]
    fn multi_site_requests_route_every_site_bit_identically() {
        // Serial requests (each waited before the next) pin batch_rows
        // to 1, so the engine's per-site outputs must match the
        // AdaptedModel's own 1-row forward bit for bit — through the
        // grouped single-segment compute path.
        let spec =
            ModelSpec::synthetic(3, SiteShape { m: 16, n: 14 }, 4, 3);
        let mut model = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        let mut rng = Pcg64::derive(7, "ms");
        let ys: Vec<Matrix> = spec
            .sites
            .iter()
            .map(|s| Matrix::gaussian(s.a, s.b, 0.5, &mut rng))
            .collect();
        model.insert_synthetic("ms", 7, 2.0, ys.clone()).unwrap();
        // reference copy served outside the engine
        let mut reference = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        reference.insert_synthetic("ms", 7, 2.0, ys).unwrap();

        let server = Server::new(model, &test_cfg(4, 200));
        for round in 0..3 {
            let xs_mat: Vec<Matrix> = spec
                .sites
                .iter()
                .map(|s| {
                    Matrix::gaussian(1, s.shape.n, 1.0, &mut rng)
                })
                .collect();
            let xs_rows: Vec<Vec<f32>> =
                xs_mat.iter().map(|m| m.data.clone()).collect();
            let resp = server
                .submit("ms", xs_rows)
                .unwrap()
                .wait()
                .unwrap();
            assert_eq!(resp.sites(), 3);
            assert_eq!(resp.batch_rows, 1, "serial submits stay 1-row");
            let want = reference.forward("ms", &xs_mat).unwrap();
            for (site, wm) in want.iter().enumerate() {
                let got = resp.site_output(site);
                assert_eq!(got.len(), spec.sites[site].shape.m);
                for (p, q) in got.iter().zip(&wm.data) {
                    assert_eq!(p.to_bits(), q.to_bits(),
                               "round {round} site {site} diverged");
                }
            }
        }
        // wrong per-site row count / width are submit-time errors
        assert!(server.submit("ms", vec![vec![0.0; 14]]).is_err());
        let bad: Vec<Vec<f32>> =
            vec![vec![0.0; 14], vec![0.0; 13], vec![0.0; 14]];
        assert!(server.submit("ms", bad).is_err());
        assert!(server.submit_row("ms", vec![0.0; 14]).is_err(),
                "submit_row must refuse multi-site models");
    }

    #[test]
    fn full_batches_flush_on_size_not_deadline() {
        let model = test_model(&[("solo", 7)]);
        // max_wait far beyond the test budget: only the size trigger can
        // flush, so replies prove the max-batch path works.
        let server = Server::new(model, &test_cfg(4, 30_000_000));
        let x = vec![0.25f32; N];
        let tickets: Vec<Ticket> = (0..8)
            .map(|_| server.submit_row("solo", x.clone()).unwrap())
            .collect();
        for t in tickets {
            let resp = t.wait().unwrap();
            assert_eq!(resp.batch_rows, 4, "size-triggered flush");
        }
    }

    #[test]
    fn max_wait_is_honored_for_partial_batches() {
        let model = test_model(&[("solo", 7)]);
        let wait_us = 50_000; // 50 ms
        let server = Server::new(model, &test_cfg(64, wait_us));
        let t = server.submit_row("solo", vec![1.0; N]).unwrap();
        let submitted = t.submitted;
        let resp = t.wait().unwrap();
        let waited = resp.done.duration_since(submitted);
        // Flushed by the deadline (not by size: batch stayed at 1 row),
        // within a generous service-time margin for slow CI machines.
        assert_eq!(resp.batch_rows, 1);
        assert!(
            waited >= Duration::from_micros(wait_us / 2),
            "flushed way before the wait bound: {waited:?}"
        );
        assert!(
            waited < Duration::from_secs(20),
            "partial batch never flushed: {waited:?}"
        );
    }

    #[test]
    fn expired_requests_get_timeout_errors_without_occupying_batches() {
        let model = test_model(&[("solo", 7)]);
        // max_wait far beyond the test budget: only the deadline can
        // get these answered.
        let server = Server::new(model, &test_cfg(64, 30_000_000));
        let t = server
            .submit_with_deadline(
                "solo",
                vec![vec![1.0; N]],
                Duration::from_millis(20),
            )
            .unwrap();
        let submitted = t.submitted;
        let err = t.wait().expect_err("expired request must error");
        assert!(err.to_string().contains("timed out"), "{err}");
        let waited = submitted.elapsed();
        assert!(
            waited < Duration::from_secs(20),
            "timeout answer must arrive near the deadline, not at \
             max_wait: {waited:?}"
        );
        let (expired, _) = server.drop_stats();
        assert_eq!(expired, 1);
        let (batches, rows) = server.batch_stats();
        assert_eq!((batches, rows), (0, 0),
                   "an expired request must not occupy a batch slot");
        // a deadline that is not hit leaves the request untouched
        let t = server
            .submit_with_deadline(
                "solo",
                vec![vec![1.0; N]],
                Duration::from_secs(600),
            )
            .unwrap();
        // force a flush by filling the batch is impossible here
        // (max_wait is huge), so cancel the noop path via shutdown
        drop(server); // shutdown drains: the request must be answered
        assert!(t.wait().is_ok(), "unexpired request served on drain");
    }

    #[test]
    fn cancelled_requests_are_dropped_from_their_batch() {
        let model = test_model(&[("solo", 7)]);
        // max_wait far beyond the test budget: only the cancel sweep
        // can get this answered — proving cancellation does not wait
        // out the group's max_wait bound.
        let server = Server::new(model, &test_cfg(4, 30_000_000));
        let t = server.submit_row("solo", vec![0.5; N]).unwrap();
        let submitted = t.submitted;
        let handle = t.cancel_handle();
        handle.cancel();
        assert!(handle.is_cancelled());
        let err = t.wait().expect_err("cancelled request must error");
        assert!(err.to_string().contains("cancelled"), "{err}");
        assert!(
            submitted.elapsed() < Duration::from_secs(20),
            "cancel answer must arrive via the sweep, not at max_wait"
        );
        let (_, cancelled) = server.drop_stats();
        assert_eq!(cancelled, 1);
        let (batches, rows) = server.batch_stats();
        assert_eq!((batches, rows), (0, 0),
                   "a cancelled request must not occupy a batch slot");
        // cancellation is per-request: the next one serves normally
        // (shutdown-drained here — max_wait is far beyond the budget)
        let t = server.submit_row("solo", vec![0.5; N]).unwrap();
        drop(server);
        assert!(t.wait().is_ok());
    }

    #[test]
    fn cancelled_rows_never_occupy_fused_slots() {
        // Cancel one member of a pending cross-adapter group: the
        // fused batch that flushes must hold only the live rows.
        let adapters = [("alpha", 7u64), ("beta", 8u64), ("gamma", 9u64)];
        let model = test_model(&adapters);
        // max_wait far beyond the budget: only the cancel sweep flushes.
        let server = Server::new(model, &test_cfg(64, 30_000_000));
        let ta = server.submit_row("alpha", vec![0.1; N]).unwrap();
        let tb = server.submit_row("beta", vec![0.2; N]).unwrap();
        let tc = server.submit_row("gamma", vec![0.3; N]).unwrap();
        tb.cancel();
        let err = tb.wait().expect_err("cancelled request must error");
        assert!(err.to_string().contains("cancelled"), "{err}");
        let ra = ta.wait().unwrap();
        let rc = tc.wait().unwrap();
        assert_eq!(ra.batch_rows, 2,
                   "the fused batch must hold only live rows");
        assert_eq!(rc.batch_rows, 2);
        assert_eq!(&*ra.adapter, "alpha");
        assert_eq!(&*rc.adapter, "gamma");
        let (batches, rows) = server.batch_stats();
        assert_eq!((batches, rows), (1, 2),
                   "cancelled rows must not occupy fused slots");
        let (_, cancelled) = server.drop_stats();
        assert_eq!(cancelled, 1);
    }

    #[test]
    fn per_class_stats_track_submissions_and_latency() {
        let model = test_model(&[("solo", 7)]);
        let server = Server::new(model, &test_cfg(4, 200));
        for (i, &c) in RequestClass::ALL.iter().enumerate() {
            for _ in 0..=i {
                let t = server
                    .submit_classed("solo", vec![vec![0.5; N]], c, None)
                    .unwrap();
                t.wait().unwrap();
            }
        }
        let stats = server.scheduler_stats();
        assert_eq!(stats.per_class.len(), 3);
        for (i, cs) in stats.per_class.iter().enumerate() {
            assert_eq!(cs.class, RequestClass::ALL[i].as_str());
            assert_eq!(cs.submitted, i as u64 + 1);
            assert_eq!(cs.answered, i as u64 + 1);
            assert!(cs.p99_us > 0,
                    "an answered class must show a latency tail");
            assert!(cs.p50_us > 0 && cs.p50_us <= cs.p95_us
                        && cs.p95_us <= cs.p99_us,
                    "percentiles must be ordered: {cs:?}");
            assert_eq!(cs.hist.count(), cs.answered,
                       "histogram counts every answer");
        }
        // legacy surfaces default to interactive
        server.submit_row("solo", vec![0.5; N]).unwrap().wait().unwrap();
        let stats = server.scheduler_stats();
        assert_eq!(stats.per_class[0].submitted, 2);
        assert_eq!(stats.per_class[1].submitted, 2);
        assert_eq!(stats.per_class[2].submitted, 3);
    }

    #[test]
    fn output_buffers_recycle_across_batches() {
        let model = test_model(&[("solo", 7)]);
        let server = Server::new(model, &test_cfg(4, 200));
        for _ in 0..10 {
            // wait + drop each response so its pooled output returns
            // before the next batch takes one
            let resp =
                server.submit_row("solo", vec![0.5; N]).unwrap().wait();
            drop(resp);
        }
        let (allocs, reuses) = server.output_pool_stats();
        assert!(allocs <= 2,
                "steady single-row batches must reuse, not allocate: \
                 {allocs} allocs");
        assert!(reuses >= 8, "pool must actually be reused: {reuses}");
    }

    #[test]
    fn scheduler_stats_track_depth_and_per_adapter_counts() {
        let model = test_model(&[("alpha", 7), ("beta", 8)]);
        let server = Server::new(model, &test_cfg(4, 200));
        assert_eq!(server.queue_depth(), 0, "idle engine has empty queue");
        let mut tickets = Vec::new();
        for _ in 0..3 {
            tickets.push(server.submit_row("alpha", vec![0.1; N]).unwrap());
        }
        tickets.push(server.submit_row("beta", vec![0.2; N]).unwrap());
        for t in tickets {
            t.wait().unwrap();
        }
        // Every answered ticket's Request is dropped by the worker right
        // after the reply lands, so the gauge drains to zero promptly;
        // a bounded spin absorbs the reply-then-drop window.
        let t0 = Instant::now();
        while server.queue_depth() > 0
            && t0.elapsed() < Duration::from_secs(10)
        {
            std::thread::yield_now();
        }
        let stats = server.scheduler_stats();
        assert_eq!(stats.queue_depth, 0, "answered requests must drain");
        assert_eq!(stats.submitted, 4);
        assert_eq!(stats.batched_rows, 4);
        assert!(stats.batches >= 1);
        assert_eq!(
            stats.per_adapter,
            vec![("alpha".to_string(), 3), ("beta".to_string(), 1)],
            "per-adapter counters sorted by name"
        );
        // errors drain the gauge too (the guard rides the Request)
        let t = server.submit_row("ghost", vec![0.0; N]).unwrap();
        assert!(t.wait().is_err());
        let t0 = Instant::now();
        while server.queue_depth() > 0
            && t0.elapsed() < Duration::from_secs(10)
        {
            std::thread::yield_now();
        }
        assert_eq!(server.queue_depth(), 0);
        assert_eq!(server.scheduler_stats().per_adapter.len(), 3,
                   "unknown adapters still count submissions");
    }

    #[test]
    fn unknown_adapter_and_bad_row_are_errors() {
        let model = test_model(&[("solo", 7)]);
        let server = Server::new(model, &test_cfg(4, 200));
        let t = server.submit_row("ghost", vec![0.0; N]).unwrap();
        assert!(t.wait().is_err(), "unknown adapter must error");
        assert!(server.submit_row("solo", vec![0.0; N + 1]).is_err());
    }

    #[test]
    fn unknown_adapter_in_fused_batch_spares_its_batchmates() {
        // A bad segment answers its own rows with the error while the
        // rest of the fused batch computes normally.
        let model = test_model(&[("alpha", 7)]);
        let server = Server::new(model, &test_cfg(2, 30_000_000));
        let good = server.submit_row("alpha", vec![0.5; N]).unwrap();
        let bad = server.submit_row("ghost", vec![0.5; N]).unwrap();
        assert!(bad.wait().is_err(), "unknown adapter must error");
        let resp = good.wait().expect("batchmate must still be served");
        assert_eq!(&*resp.adapter, "alpha");
        assert_eq!(resp.batch_rows, 1,
                   "the failed segment's row must not pad the batch");
    }

    #[test]
    fn shutdown_answers_in_flight_requests() {
        let model = test_model(&[("solo", 7)]);
        // huge wait: only the shutdown drain can flush these
        let mut server = Server::new(model, &test_cfg(64, 30_000_000));
        let tickets: Vec<Ticket> = (0..3)
            .map(|_| server.submit_row("solo", vec![0.5; N]).unwrap())
            .collect();
        server.shutdown();
        for t in tickets {
            assert!(t.wait().is_ok(), "shutdown must drain, not drop");
        }
        assert!(server.submit_row("solo", vec![0.5; N]).is_err());
    }

    #[test]
    fn poisoned_model_lock_still_answers_every_ticket() {
        // A client thread panicking while holding the shared model
        // mutex poisons it.  The workers' poison-recovering `lock()`
        // must keep serving: every ticket submitted afterwards has to
        // resolve (with an answer, not a hang or a dropped channel).
        let model = test_model(&[("solo", 7)]);
        let server = Server::new(model, &test_cfg(4, 200));
        let shared = server.model();
        let poisoner = std::thread::spawn(move || {
            let _guard = shared.lock().unwrap();
            panic!("poisoning the model lock on purpose");
        });
        assert!(poisoner.join().is_err(), "poisoner must panic");
        assert!(
            server.model().lock().is_err(),
            "the model mutex must actually be poisoned"
        );
        let tickets: Vec<Ticket> = (0..8)
            .map(|_| server.submit_row("solo", vec![0.5; N]).unwrap())
            .collect();
        // Wait with a hang guard: a regression here would block recv()
        // forever, so the waits run on the side and are given 10s.
        let waiter = std::thread::spawn(move || {
            tickets.into_iter().map(Ticket::wait).collect::<Vec<_>>()
        });
        let t0 = std::time::Instant::now();
        while !waiter.is_finished()
            && t0.elapsed() < Duration::from_secs(10)
        {
            std::thread::yield_now();
        }
        assert!(
            waiter.is_finished(),
            "tickets hung after the model lock was poisoned"
        );
        for r in waiter.join().expect("waiter thread") {
            let resp =
                r.expect("poisoned lock must not lose the ticket");
            assert_eq!(&*resp.adapter, "solo");
        }
    }

    #[test]
    fn hot_load_and_evict_while_serving() {
        let model = test_model(&[("old", 7)]);
        let server = Server::new(model, &test_cfg(4, 200));
        let model = server.model();
        {
            let mut mdl = model.lock().unwrap();
            let mut rng = Pcg64::derive(11, "new");
            let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
            mdl.insert(
                "new",
                11,
                2.0,
                vec![CoreInput::new("adp.0.wq.l", "adp.0.wq.r", y)],
            )
            .unwrap();
            mdl.evict("old");
        }
        let t_new = server.submit_row("new", vec![0.1; N]).unwrap();
        assert!(t_new.wait().is_ok(), "hot-loaded adapter must serve");
        let t_old = server.submit_row("old", vec![0.1; N]).unwrap();
        assert!(t_old.wait().is_err(), "evicted adapter must error");
    }

    /// A registry with an exemplar ring big enough to retain every
    /// trace a test submits, and a slow threshold nothing reaches.
    fn test_registry() -> Arc<obs::Registry> {
        obs::Registry::with_params(true, u64::MAX / 2000, 64, 256)
    }

    fn assert_stage_ordered(e: &crate::obs::SlowEntry) {
        assert!(
            e.stages[Stage::Reply.idx()].is_some(),
            "trace {:016x} has no terminal reply mark",
            e.id
        );
        let mut prev = 0u64;
        for s in Stage::ALL {
            if let Some(off) = e.stages[s.idx()] {
                assert!(
                    off >= prev,
                    "trace {:016x}: stage {} offset {} < {}",
                    e.id,
                    s.name(),
                    off,
                    prev
                );
                prev = off;
            }
        }
    }

    #[test]
    fn every_request_path_yields_a_complete_stage_ordered_trace() {
        // The trace-lifecycle property: every submitted request —
        // answered, errored, cancelled, expired, or drained on
        // shutdown — terminates exactly one trace, and every finished
        // trace's marks are stage-ordered with a terminal reply mark.
        // Answered + errored ride a fast-flush server; cancelled +
        // expired need the huge-max_wait server (only the cancel sweep
        // / deadline can answer them, as the dedicated tests pin).
        let reg = test_registry();
        {
            let model =
                test_model(&[("alpha", 7u64), ("beta", 8u64)]);
            let server =
                Server::with_obs(model, &test_cfg(4, 500), reg.clone());
            for _ in 0..3 {
                let t =
                    server.submit_row("alpha", vec![0.5; N]).unwrap();
                t.wait().unwrap();
            }
            let t = server.submit_row("ghost", vec![0.0; N]).unwrap();
            assert!(t.wait().is_err(), "unknown adapter errors");
            // validation failures terminate the trace too
            assert!(server.submit_row("alpha", vec![0.0; N + 1]).is_err());
        }
        let reg2 = test_registry();
        {
            let model =
                test_model(&[("alpha", 7u64), ("beta", 8u64)]);
            let server = Server::with_obs(
                model,
                &test_cfg(64, 30_000_000),
                reg2.clone(),
            );
            let t = server.submit_row("alpha", vec![0.5; N]).unwrap();
            t.cancel();
            assert!(t.wait().is_err());
            let t = server
                .submit_with_deadline(
                    "beta",
                    vec![vec![0.5; N]],
                    Duration::from_millis(20),
                )
                .unwrap();
            assert!(t.wait().is_err());
        }
        assert_eq!(reg.finished(Outcome::Answered), 3);
        assert_eq!(reg.finished(Outcome::Errored), 2);
        assert_eq!(reg.finished_total(), 5, "one trace per submit");
        assert_eq!(reg2.finished(Outcome::Cancelled), 1);
        assert_eq!(reg2.finished(Outcome::Expired), 1);
        assert_eq!(reg2.finished_total(), 2);
        let recent = reg.recent_snapshot();
        assert_eq!(recent.len(), 5, "exemplar ring retains every trace");
        for e in recent.iter().chain(reg2.recent_snapshot().iter()) {
            assert_stage_ordered(e);
        }
        // answered traces carry the full pipeline and the plan's
        // method + cache split (CoSA: L and R per site = 2 tensors)
        let answered: Vec<_> = recent
            .iter()
            .filter(|e| e.outcome == "answered")
            .collect();
        assert_eq!(answered.len(), 3);
        for e in &answered {
            for s in Stage::ALL {
                assert!(
                    e.stages[s.idx()].is_some(),
                    "answered trace missing stage {}",
                    s.name()
                );
            }
            assert_eq!(e.method, "cosa");
            assert_eq!(e.adapter, "alpha");
            assert_eq!(e.batch_rows, 1);
            assert_eq!(e.cache_hits + e.cache_misses, 2);
        }
        // the per-stage histograms saw the pipeline: the ghost request
        // boards the queue too (4 samples), but only the answered
        // three reach compute
        assert_eq!(reg.merged_stage_snapshot(Stage::Queue).count(), 4);
        for s in [Stage::Pack, Stage::Gemm] {
            assert_eq!(
                reg.merged_stage_snapshot(s).count(),
                3,
                "stage {} histogram",
                s.name()
            );
        }
        // errored-before-queue traces never mark pipeline stages
        let errored: Vec<_> = recent
            .iter()
            .filter(|e| e.outcome == "errored")
            .collect();
        assert_eq!(errored.len(), 2);
        assert!(
            errored
                .iter()
                .any(|e| e.stages[Stage::Queue.idx()].is_none()),
            "the validation failure never reached the queue"
        );
    }

    #[test]
    fn shutdown_drain_still_terminates_every_trace() {
        let reg = test_registry();
        {
            let model = test_model(&[("solo", 7)]);
            let mut server = Server::with_obs(
                model,
                &test_cfg(64, 30_000_000),
                reg.clone(),
            );
            let tickets: Vec<Ticket> = (0..3)
                .map(|_| server.submit_row("solo", vec![0.5; N]).unwrap())
                .collect();
            server.shutdown();
            for t in tickets {
                assert!(t.wait().is_ok(), "drain answers");
            }
        }
        assert_eq!(reg.finished(Outcome::Answered), 3);
        for e in reg.recent_snapshot() {
            assert_stage_ordered(&e);
        }
    }

    #[test]
    fn disabled_registry_requests_carry_no_traces() {
        // Server::new wires the disabled registry: no trace is ever
        // opened, nothing aggregates.
        let model = test_model(&[("solo", 7)]);
        let server = Server::new(model, &test_cfg(4, 200));
        let reg = server.obs();
        assert!(!reg.enabled());
        server.submit_row("solo", vec![0.5; N]).unwrap().wait().unwrap();
        assert_eq!(reg.finished_total(), 0);
        assert!(reg.recent_snapshot().is_empty());
    }
}
