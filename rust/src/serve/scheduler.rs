//! Request scheduler: queue -> per-adapter batches -> worker pool.
//!
//! ## Data flow
//!
//! ```text
//! submit() --ingress--> batcher --batches--> workers --reply--> Ticket
//! ```
//!
//! * **submit** accepts one activation row per request and returns a
//!   [`Ticket`] the caller blocks on.
//! * The **batcher** thread drains the ingress queue and groups pending
//!   requests **by adapter id** — a batch never mixes adapters.  A group
//!   flushes when it reaches `max_batch` rows or when its oldest request
//!   has waited `max_wait_us` (each request is answered within the wait
//!   bound plus service time, even at trickle load).
//! * **Workers** (count resolved through the same `plan_threads` helper
//!   the compute backends share) pull whole batches, snapshot the
//!   adapter's `L`/`R`/`Y` handles under a brief registry lock — cache
//!   *misses* regenerate outside the lock via the registry's two-phase
//!   `plan`/`install` split, so a cold or thrashing projection cache
//!   never serializes the pool — assemble the batch matrix in a
//!   worker-owned [`Workspace`] buffer and run `adapter_forward_into`.  The matmul hot path — intermediates,
//!   packing scratch, the assembled input — is allocation-free at steady
//!   state (the Workspace contract); the batch *output* is allocated
//!   once per batch and shared zero-copy with every ticket of the batch
//!   via `Arc`, so per-request cost is an `Arc` clone, not a row copy.
//!
//! Batching is what buys multi-adapter throughput: a single-row forward
//! re-reads the whole `L`/`R`/`Y` working set per request, while a
//! k-row batch amortizes that traffic k ways (`benches/serve_bench.rs`
//! measures the speedup; CI gates it at >= 1.5x for 64 Zipf-skewed
//! adapters).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::adapters::cosa::adapter_forward_into;
use crate::config::ServeConfig;
use crate::linalg::tiled::plan_threads;
use crate::linalg::Workspace;
use crate::math::matrix::Matrix;

use super::registry::AdapterRegistry;

/// One answered request.  `out` is the whole batch's output matrix,
/// shared by every ticket of the batch; `row` is this request's row.
pub struct Response {
    pub out: Arc<Matrix>,
    pub row: usize,
    /// Adapter id the batch ran under (every row of `out` used it).
    pub adapter: Arc<str>,
    /// Rows in the batch this request rode in.
    pub batch_rows: usize,
    /// When the worker finished the batch (latency = `done` - submit).
    pub done: Instant,
}

impl Response {
    /// This request's output row (width m).
    pub fn output(&self) -> &[f32] {
        self.out.row(self.row)
    }
}

type Reply = Result<Response, String>;

/// Handle for one in-flight request; `wait` blocks for the answer.
pub struct Ticket {
    rx: Receiver<Reply>,
    /// When the request entered the queue (set by `submit`).
    pub submitted: Instant,
}

impl Ticket {
    pub fn wait(self) -> anyhow::Result<Response> {
        match self.rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(msg)) => Err(anyhow::anyhow!(msg)),
            Err(_) => Err(anyhow::anyhow!(
                "server shut down before answering the request"
            )),
        }
    }
}

struct Request {
    adapter: Arc<str>,
    x: Vec<f32>,
    reply: Sender<Reply>,
    at: Instant,
}

struct Batch {
    adapter: Arc<str>,
    reqs: Vec<Request>,
}

/// Scheduler counters (batch count and total batched rows — the mean
/// batch size benches report is `rows / batches`).
#[derive(Default)]
struct ServerStats {
    batches: AtomicU64,
    batched_rows: AtomicU64,
}

/// The serving engine: registry + batcher + worker pool.  See module
/// docs for the data flow; construction spawns the threads, `shutdown`
/// (or drop) drains and joins them.
pub struct Server {
    ingress: Option<Sender<Request>>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    registry: Arc<Mutex<AdapterRegistry>>,
    stats: Arc<ServerStats>,
    site_n: usize,
    worker_count: usize,
}

/// Ceiling on spawned workers, however configured — each worker is a
/// real OS thread and more of them than cores only adds contention.
const MAX_WORKERS: usize = 64;

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl Server {
    /// Spawn the engine over `registry`.  `cfg` is used as-is — apply
    /// `ServeConfig::env_overridden()` at the call site (the CLI and
    /// bench drivers do), so tests stay hermetic.
    pub fn new(registry: AdapterRegistry, cfg: &ServeConfig) -> Server {
        let site_n = registry.site().n;
        let max_batch = cfg.max_batch.max(1);
        let max_wait = Duration::from_micros(cfg.max_wait_us);
        // Same resolution rule as the compute backends: explicit count,
        // or auto (available_parallelism, capped) — the zero-FLOP floor
        // means serving always gets its workers.  Unlike the compute
        // kernels (where plan_threads clamps to actual matrix rows), a
        // server has no natural row bound, so cap explicit requests too
        // instead of attempting an unbounded number of thread spawns.
        let workers = if cfg.workers > MAX_WORKERS {
            eprintln!(
                "warning: serve workers capped at {MAX_WORKERS} \
                 (requested {})",
                cfg.workers
            );
            MAX_WORKERS
        } else {
            cfg.workers
        };
        let worker_count = plan_threads(workers, 0, usize::MAX, usize::MAX);

        let registry = Arc::new(Mutex::new(registry));
        let stats = Arc::new(ServerStats::default());
        let (ingress_tx, ingress_rx) = channel::<Request>();
        let (batch_tx, batch_rx) = channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        let batcher = std::thread::spawn(move || {
            batcher_loop(ingress_rx, batch_tx, max_batch, max_wait);
        });
        let mut workers = Vec::with_capacity(worker_count);
        for _ in 0..worker_count {
            let rx = batch_rx.clone();
            let reg = registry.clone();
            let st = stats.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(&rx, &reg, &st);
            }));
        }
        Server {
            ingress: Some(ingress_tx),
            batcher: Some(batcher),
            workers,
            registry,
            stats,
            site_n,
            worker_count,
        }
    }

    /// Workers actually spawned (after auto resolution).
    pub fn worker_count(&self) -> usize {
        self.worker_count
    }

    /// (batches executed, total rows batched) so far.
    pub fn batch_stats(&self) -> (u64, u64) {
        (
            self.stats.batches.load(Ordering::Relaxed),
            self.stats.batched_rows.load(Ordering::Relaxed),
        )
    }

    /// The shared registry (hot load/evict while serving, cache stats).
    pub fn registry(&self) -> Arc<Mutex<AdapterRegistry>> {
        self.registry.clone()
    }

    /// Enqueue one activation row for `adapter`.  Returns immediately;
    /// block on the ticket for the answer.
    pub fn submit(&self, adapter: &str, x: Vec<f32>) -> anyhow::Result<Ticket> {
        anyhow::ensure!(
            x.len() == self.site_n,
            "request row has {} values, site expects {}",
            x.len(),
            self.site_n
        );
        let ingress = self
            .ingress
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("server is shut down"))?;
        let (tx, rx) = channel::<Reply>();
        let submitted = Instant::now();
        let req = Request {
            adapter: Arc::from(adapter),
            x,
            reply: tx,
            at: submitted,
        };
        ingress
            .send(req)
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        Ok(Ticket { rx, submitted })
    }

    /// Stop accepting requests, drain everything in flight, join the
    /// threads.  Every request submitted before shutdown is answered.
    pub fn shutdown(&mut self) {
        self.ingress.take(); // batcher sees disconnect, flushes, exits
        if let Some(b) = self.batcher.take() {
            let _ = b.join(); // dropping its batch sender stops workers
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Earliest flush deadline among pending groups (oldest request per
/// group + max_wait).
fn earliest_deadline(
    pending: &HashMap<Arc<str>, Vec<Request>>,
    max_wait: Duration,
) -> Option<Instant> {
    pending
        .values()
        .filter_map(|v| v.first().map(|r| r.at + max_wait))
        .min()
}

fn batcher_loop(
    rx: Receiver<Request>,
    tx: Sender<Batch>,
    max_batch: usize,
    max_wait: Duration,
) {
    let mut pending: HashMap<Arc<str>, Vec<Request>> = HashMap::new();
    'run: loop {
        let received = match earliest_deadline(&pending, max_wait) {
            // Nothing pending: block until a request (or shutdown).
            None => match rx.recv() {
                Ok(r) => Some(r),
                Err(_) => break 'run,
            },
            Some(deadline) => {
                let timeout =
                    deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(timeout) {
                    Ok(r) => Some(r),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => break 'run,
                }
            }
        };
        if let Some(req) = received {
            let key = req.adapter.clone();
            let group = pending.entry(key.clone()).or_default();
            group.push(req);
            if group.len() >= max_batch {
                let reqs = pending.remove(&key).unwrap_or_default();
                if tx.send(Batch { adapter: key, reqs }).is_err() {
                    return; // workers gone — nothing left to answer
                }
            }
        }
        // Flush every group whose oldest request hit the wait bound.
        let now = Instant::now();
        let due: Vec<Arc<str>> = pending
            .iter()
            .filter(|(_, v)| {
                v.first().is_some_and(|r| now >= r.at + max_wait)
            })
            .map(|(k, _)| k.clone())
            .collect();
        for key in due {
            if let Some(reqs) = pending.remove(&key) {
                if tx.send(Batch { adapter: key, reqs }).is_err() {
                    return;
                }
            }
        }
    }
    // Ingress disconnected (shutdown): flush everything still pending so
    // no submitted request goes unanswered.
    for (adapter, reqs) in pending.drain() {
        if tx.send(Batch { adapter, reqs }).is_err() {
            return;
        }
    }
}

fn worker_loop(
    rx: &Mutex<Receiver<Batch>>,
    registry: &Mutex<AdapterRegistry>,
    stats: &ServerStats,
) {
    let mut ws = Workspace::new();
    loop {
        // Standard Mutex<Receiver> work queue: one idle worker at a
        // time blocks inside recv() *while holding the lock*; the guard
        // drops at the end of this statement, so the batch itself is
        // always processed lock-free.  Never add work to this statement
        // chain — it would run under the lock and stall the pool.
        let batch = match lock(rx).recv() {
            Ok(b) => b,
            Err(_) => return, // batcher exited and the queue is drained
        };
        let Batch { adapter, reqs } = batch;
        // Two-phase handle lookup so the registry lock stays brief even
        // on a projection-cache miss: plan under the lock (hits resolve
        // here), regenerate any cold L/R *outside* the lock, install
        // under a second brief lock.  A thrashing cache costs the
        // missing worker regen time, never the whole pool.
        let plan = lock(registry).plan(&adapter);
        let plan = match plan {
            Ok(p) => p,
            Err(e) => {
                let msg = format!("{e:#}");
                for req in reqs {
                    let _ = req.reply.send(Err(msg.clone()));
                }
                continue;
            }
        };
        let l_new = if plan.l.is_none() {
            Some(crate::adapters::cosa::regen_l(
                plan.seed, &plan.l_name, plan.m, plan.a,
            ))
        } else {
            None
        };
        let r_new = if plan.r.is_none() {
            Some(crate::adapters::cosa::regen_r(
                plan.seed, &plan.r_name, plan.b, plan.n,
            ))
        } else {
            None
        };
        let handles = lock(registry).install(&plan, l_new, r_new);
        let rows = reqs.len();
        let n = handles.r.cols;
        let m = handles.l.rows;
        let mut x = ws.take_matrix(rows, n);
        for (i, req) in reqs.iter().enumerate() {
            x.data[i * n..(i + 1) * n].copy_from_slice(&req.x);
        }
        // The output lives beyond this batch (tickets hold it via Arc),
        // so it cannot come from the workspace pool.
        let mut out = Matrix::zeros(rows, m);
        adapter_forward_into(
            &x,
            &handles.l,
            &handles.r,
            &handles.y,
            handles.alpha,
            &mut ws,
            &mut out,
        );
        ws.recycle_matrix(x);
        let out = Arc::new(out);
        let done = Instant::now();
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.batched_rows.fetch_add(rows as u64, Ordering::Relaxed);
        for (row, req) in reqs.into_iter().enumerate() {
            let resp = Response {
                out: out.clone(),
                row,
                adapter: adapter.clone(),
                batch_rows: rows,
                done,
            };
            let _ = req.reply.send(Ok(resp));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::cosa::{adapter_forward, regen_l, regen_r};
    use crate::math::rng::Pcg64;
    use crate::serve::registry::SiteShape;
    use crate::util::prop;

    const M: usize = 12;
    const N: usize = 10;

    fn test_cfg(max_batch: usize, max_wait_us: u64) -> ServeConfig {
        ServeConfig {
            cache_mb: 4.0,
            max_batch,
            max_wait_us,
            workers: 2,
        }
    }

    #[test]
    fn absurd_worker_requests_are_capped() {
        let reg = test_registry(&[("solo", 7)]);
        let cfg = ServeConfig { workers: 1_000_000, ..test_cfg(4, 200) };
        let server = Server::new(reg, &cfg);
        assert!(server.worker_count() <= 64, "{}", server.worker_count());
        let t = server.submit("solo", vec![0.0; N]).unwrap();
        assert!(t.wait().is_ok());
    }

    fn test_registry(adapters: &[(&str, u64)]) -> AdapterRegistry {
        let mut reg =
            AdapterRegistry::new(SiteShape { m: M, n: N }, 1 << 20);
        for (name, seed) in adapters {
            let mut rng = Pcg64::derive(*seed, name);
            let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
            reg.insert(name, *seed, 2.0, "adp.0.wq.l", "adp.0.wq.r", y)
                .unwrap();
        }
        reg
    }

    fn reference_forward(seed: u64, name: &str, x_row: &[f32]) -> Vec<f32> {
        let mut rng = Pcg64::derive(seed, name);
        let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
        let l = regen_l(seed, "adp.0.wq.l", M, 4);
        let r = regen_r(seed, "adp.0.wq.r", 3, N);
        let x = Matrix::from_vec(1, N, x_row.to_vec());
        adapter_forward(&x, &l, &r, &y, 2.0).data
    }

    #[test]
    fn every_request_answered_exactly_once_and_unmixed() {
        // Property test: random request mixes over several adapters —
        // every ticket resolves with the right adapter's math, and the
        // scheduler's row accounting matches the request count exactly
        // (each request answered exactly once).
        prop::for_all("serve answers all, batches unmixed", 5, |rng| {
            let adapters =
                [("alpha", 7u64), ("beta", 8u64), ("gamma", 9u64)];
            let reg = test_registry(&adapters);
            let server = Server::new(reg, &test_cfg(4, 500));
            let total = prop::int_in(rng, 5, 40);
            let mut tickets = Vec::new();
            let mut expect = Vec::new();
            for _ in 0..total {
                let which = prop::int_in(rng, 0, adapters.len() - 1);
                let (name, seed) = adapters[which];
                let x: Vec<f32> =
                    (0..N).map(|_| rng.normal() as f32).collect();
                expect.push(reference_forward(seed, name, &x));
                tickets.push((name, server.submit(name, x).unwrap()));
            }
            let mut answered = 0usize;
            for ((name, ticket), want) in
                tickets.into_iter().zip(&expect)
            {
                let resp = ticket.wait().expect("request must be answered");
                answered += 1;
                assert_eq!(&*resp.adapter, name, "batch mixed adapters");
                assert!(resp.batch_rows >= 1 && resp.batch_rows <= 4);
                for (got, exp) in resp.output().iter().zip(want) {
                    assert!(
                        (got - exp).abs() < 1e-4,
                        "{name}: {got} vs {exp}"
                    );
                }
            }
            assert_eq!(answered, total);
            let (batches, rows) = server.batch_stats();
            assert_eq!(rows as usize, total,
                       "every row batched exactly once");
            assert!(batches >= 1);
        });
    }

    #[test]
    fn full_batches_flush_on_size_not_deadline() {
        let reg = test_registry(&[("solo", 7)]);
        // max_wait far beyond the test budget: only the size trigger can
        // flush, so replies prove the max-batch path works.
        let server = Server::new(reg, &test_cfg(4, 30_000_000));
        let x = vec![0.25f32; N];
        let tickets: Vec<Ticket> = (0..8)
            .map(|_| server.submit("solo", x.clone()).unwrap())
            .collect();
        for t in tickets {
            let resp = t.wait().unwrap();
            assert_eq!(resp.batch_rows, 4, "size-triggered flush");
        }
    }

    #[test]
    fn max_wait_is_honored_for_partial_batches() {
        let reg = test_registry(&[("solo", 7)]);
        let wait_us = 50_000; // 50 ms
        let server = Server::new(reg, &test_cfg(64, wait_us));
        let t = server.submit("solo", vec![1.0; N]).unwrap();
        let submitted = t.submitted;
        let resp = t.wait().unwrap();
        let waited = resp.done.duration_since(submitted);
        // Flushed by the deadline (not by size: batch stayed at 1 row),
        // within a generous service-time margin for slow CI machines.
        assert_eq!(resp.batch_rows, 1);
        assert!(
            waited >= Duration::from_micros(wait_us / 2),
            "flushed way before the wait bound: {waited:?}"
        );
        assert!(
            waited < Duration::from_secs(20),
            "partial batch never flushed: {waited:?}"
        );
    }

    #[test]
    fn unknown_adapter_and_bad_row_are_errors() {
        let reg = test_registry(&[("solo", 7)]);
        let server = Server::new(reg, &test_cfg(4, 200));
        let t = server.submit("ghost", vec![0.0; N]).unwrap();
        assert!(t.wait().is_err(), "unknown adapter must error");
        assert!(server.submit("solo", vec![0.0; N + 1]).is_err());
    }

    #[test]
    fn shutdown_answers_in_flight_requests() {
        let reg = test_registry(&[("solo", 7)]);
        // huge wait: only the shutdown drain can flush these
        let mut server = Server::new(reg, &test_cfg(64, 30_000_000));
        let tickets: Vec<Ticket> = (0..3)
            .map(|_| server.submit("solo", vec![0.5; N]).unwrap())
            .collect();
        server.shutdown();
        for t in tickets {
            assert!(t.wait().is_ok(), "shutdown must drain, not drop");
        }
        assert!(server.submit("solo", vec![0.5; N]).is_err());
    }

    #[test]
    fn hot_load_and_evict_while_serving() {
        let reg = test_registry(&[("old", 7)]);
        let server = Server::new(reg, &test_cfg(4, 200));
        let registry = server.registry();
        {
            let mut reg = registry.lock().unwrap();
            let mut rng = Pcg64::derive(11, "new");
            let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
            reg.insert("new", 11, 2.0, "adp.0.wq.l", "adp.0.wq.r", y)
                .unwrap();
            reg.evict("old");
        }
        let t_new = server.submit("new", vec![0.1; N]).unwrap();
        assert!(t_new.wait().is_ok(), "hot-loaded adapter must serve");
        let t_old = server.submit("old", vec![0.1; N]).unwrap();
        assert!(t_old.wait().is_err(), "evicted adapter must error");
    }
}
