//! Serving registry — the multi-site [`AdaptedModel`] layer fronted at
//! the engine boundary.
//!
//! PR 3's `AdapterRegistry` served exactly one `SiteShape`; the
//! registry is now the [`model`](crate::model) layer's `AdaptedModel`:
//! named adapters are *sets* of cores keyed by site (one per
//! [`ModelSpec`](crate::model::ModelSpec) site), all regenerating their
//! `L`/`R` projections from one seed through **one** shared
//! byte-budgeted [`ProjectionCache`].  Everything registry-shaped —
//! hot load/evict, checkpoint load-by-name (v2 files carry every
//! per-site core under one adapter name), the two-phase `plan` /
//! `install` lookup that resolves **all cold sites of a request at
//! once** outside the lock — lives on `AdaptedModel`; this module
//! keeps the serving-facing name plus the §4.1 determinism tests
//! (evict → reload bit-identity, disk round-trips, raced installs).

pub use crate::model::{
    AdaptedModel, CacheStats, CoreInput, ModelSpec, ProjectionCache,
    SiteShape, SiteSpec,
};

/// The serving registry *is* the adapted-model layer (see module docs).
pub type AdapterRegistry = AdaptedModel;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::cosa::{adapter_forward, regen_l, regen_r};
    use crate::math::matrix::Matrix;
    use crate::math::rng::Pcg64;
    use crate::train::checkpoint::Checkpoint;
    use std::sync::Arc;

    fn test_registry(budget: usize) -> AdapterRegistry {
        AdaptedModel::single_site(
            "adp.0.wq",
            SiteShape { m: 12, n: 10 },
            4,
            3,
            budget,
        )
    }

    fn add_adapter(reg: &mut AdapterRegistry, name: &str, seed: u64) {
        let mut rng = Pcg64::derive(seed, name);
        let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
        reg.insert(
            name,
            seed,
            2.0,
            vec![CoreInput::new("adp.0.wq.l", "adp.0.wq.r", y)],
        )
        .unwrap();
    }

    #[test]
    fn forward_matches_direct_adapter_math() {
        let mut reg = test_registry(1 << 20);
        add_adapter(&mut reg, "a", 7);
        let mut rng = Pcg64::new(1);
        let x = Matrix::gaussian(3, 10, 1.0, &mut rng);
        let got = reg.forward_one("a", &x).unwrap();
        let l = regen_l(7, "adp.0.wq.l", 12, 4);
        let r = regen_r(7, "adp.0.wq.r", 3, 10);
        let h = reg.handles("a").unwrap();
        let want = adapter_forward(&x, &l, &r, &h.sites[0].y, 2.0);
        assert_eq!(got, want, "registry forward must be the canonical math");
    }

    #[test]
    fn unknown_adapter_is_an_error() {
        let mut reg = test_registry(1 << 20);
        let x = Matrix::zeros(1, 10);
        assert!(reg.forward_one("nope", &x).is_err());
        assert!(!reg.evict("nope"));
    }

    #[test]
    fn cache_hits_after_first_touch() {
        let mut reg = test_registry(1 << 20);
        add_adapter(&mut reg, "a", 7);
        let x = Matrix::zeros(1, 10);
        reg.forward_one("a", &x).unwrap();
        let s1 = reg.cache_stats();
        assert_eq!((s1.hits, s1.misses), (0, 2), "first touch: L and R miss");
        reg.forward_one("a", &x).unwrap();
        let s2 = reg.cache_stats();
        assert_eq!((s2.hits, s2.misses), (2, 2), "second touch: both hit");
    }

    #[test]
    fn lru_evicts_by_byte_budget_and_keeps_newest() {
        // Budget fits exactly one adapter's projections: L 12x4 + R 3x10
        // = 78 floats = 312 bytes.  Two adapters must thrash; the newest
        // projections always stay resident.
        let mut reg = test_registry(312);
        add_adapter(&mut reg, "a", 7);
        add_adapter(&mut reg, "b", 8);
        let x = Matrix::zeros(1, 10);
        reg.forward_one("a", &x).unwrap();
        reg.forward_one("b", &x).unwrap();
        let s = reg.cache_stats();
        assert_eq!(s.misses, 4, "all four projections regenerate");
        assert!(s.evictions >= 2, "budget forces evictions: {s:?}");
        reg.forward_one("a", &x).unwrap();
        let s = reg.cache_stats();
        assert_eq!(s.misses, 6, "a's projections were evicted, regen again");
    }

    #[test]
    fn zero_budget_still_serves() {
        let mut reg = test_registry(0);
        add_adapter(&mut reg, "a", 7);
        let mut rng = Pcg64::new(2);
        let x = Matrix::gaussian(2, 10, 1.0, &mut rng);
        let o1 = reg.forward_one("a", &x).unwrap();
        let o2 = reg.forward_one("a", &x).unwrap();
        assert_eq!(o1, o2, "regen-every-time must still be deterministic");
    }

    #[test]
    fn evict_reload_is_bit_identical() {
        // The §4.1 determinism contract end-to-end: load -> forward,
        // evict (adapter AND cached projections via a tiny budget),
        // reload -> forward must agree bit-for-bit.
        let mut reg = test_registry(312);
        add_adapter(&mut reg, "a", 7);
        let mut rng = Pcg64::new(3);
        let x = Matrix::gaussian(5, 10, 1.0, &mut rng);
        let before = reg.forward_one("a", &x).unwrap();
        assert!(reg.evict("a"));
        // churn the projection cache so "a" is fully cold again
        add_adapter(&mut reg, "churn", 9);
        reg.forward_one("churn", &x).unwrap();
        add_adapter(&mut reg, "a", 7);
        let after = reg.forward_one("a", &x).unwrap();
        for (p, q) in before.data.iter().zip(&after.data) {
            assert_eq!(p.to_bits(), q.to_bits(), "evict/reload drifted");
        }
    }

    #[test]
    fn checkpoint_roundtrip_load_by_name_bit_identical() {
        use std::collections::BTreeMap;
        let dir = std::env::temp_dir().join("cosa_serve_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Pcg64::new(4);
        let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
        let mut tensors = BTreeMap::new();
        tensors.insert("adp.0.wq.y".to_string(),
                       (vec![4usize, 3], y.data.clone()));
        let ck = Checkpoint {
            version: 2,
            method: "cosa".into(),
            adapter_seed: 77,
            artifact: "tiny-lm_cosa".into(),
            step: 5,
            sites: Vec::new(),
            tensors,
        };
        ck.save(&dir.join("mathbot.cosa")).unwrap();

        let mut reg = test_registry(1 << 20);
        reg.load_from_dir(&dir, "mathbot", 2.0).unwrap();
        let x = Matrix::gaussian(2, 10, 1.0, &mut rng);
        let first = reg.forward_one("mathbot", &x).unwrap();

        // evict + reload from disk: same bits
        assert!(reg.evict("mathbot"));
        reg.load_from_dir(&dir, "mathbot", 2.0).unwrap();
        let second = reg.forward_one("mathbot", &x).unwrap();
        for (p, q) in first.data.iter().zip(&second.data) {
            assert_eq!(p.to_bits(), q.to_bits(), "disk reload drifted");
        }

        // and the in-memory insert with the same parts agrees too
        let mut reg2 = test_registry(1 << 20);
        reg2.insert(
            "mathbot",
            77,
            2.0,
            vec![CoreInput::new("adp.0.wq.l", "adp.0.wq.r", y)],
        )
        .unwrap();
        let third = reg2.forward_one("mathbot", &x).unwrap();
        assert_eq!(first, third, "checkpoint path vs direct insert");
    }

    #[test]
    fn multi_site_checkpoint_roundtrip_from_disk() {
        // The v2 flow end-to-end through the filesystem: one adapter
        // name carries all per-site cores, load_from_dir reassembles
        // the whole model-adapter bit-identically.
        let dir = std::env::temp_dir().join("cosa_serve_registry_v2_test");
        std::fs::create_dir_all(&dir).unwrap();
        let spec = ModelSpec::synthetic(
            3, SiteShape { m: 12, n: 10 }, 4, 3);
        let mut reg = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        let mut rng = Pcg64::new(8);
        let ys: Vec<Matrix> = spec
            .sites
            .iter()
            .map(|s| Matrix::gaussian(s.a, s.b, 0.5, &mut rng))
            .collect();
        reg.insert_synthetic("fleet", 42, 2.0, ys).unwrap();
        let ck = reg.checkpoint("fleet", "tiny-lm_cosa").unwrap();
        ck.save(&dir.join("fleet.cosa")).unwrap();

        let xs: Vec<Matrix> = spec
            .sites
            .iter()
            .map(|s| Matrix::gaussian(2, s.shape.n, 1.0, &mut rng))
            .collect();
        let want = reg.forward("fleet", &xs).unwrap();

        let mut fresh = AdaptedModel::new(spec, 1 << 20).unwrap();
        fresh.load_from_dir(&dir, "fleet", 2.0).unwrap();
        let got = fresh.forward("fleet", &xs).unwrap();
        for (wm, gm) in want.iter().zip(&got) {
            for (p, q) in wm.data.iter().zip(&gm.data) {
                assert_eq!(p.to_bits(), q.to_bits(),
                           "disk v2 round-trip drifted");
            }
        }
    }

    #[test]
    fn plan_install_split_matches_inline_and_survives_races() {
        let mut reg = test_registry(1 << 20);
        add_adapter(&mut reg, "a", 7);
        // Two cold plans (as two workers would take under the lock).
        let p1 = reg.plan("a").unwrap();
        let p2 = reg.plan("a").unwrap();
        let s1 = &p1.sites[0];
        assert!(s1.l.is_none() && s1.r.is_none(), "cold cache");
        // Both regenerate outside the lock...
        let regen = |p: &crate::model::ModelPlan| {
            p.sites
                .iter()
                .map(|s| {
                    (Some(regen_l(s.seed, &s.l_name, s.m, s.a)),
                     Some(regen_r(s.seed, &s.r_name, s.b, s.n)))
                })
                .collect::<Vec<_>>()
        };
        let (r1, r2) = (regen(&p1), regen(&p2));
        // ...first install wins, second gets the already-resident Arcs.
        let h1 = reg.install(&p1, r1);
        let h2 = reg.install(&p2, r2);
        assert!(Arc::ptr_eq(&h1.sites[0].l, &h2.sites[0].l),
                "raced install must dedupe");
        assert!(Arc::ptr_eq(&h1.sites[0].r, &h2.sites[0].r));
        // and a warm plan resolves without any regeneration step
        let p3 = reg.plan("a").unwrap();
        assert!(p3.sites[0].l.is_some() && p3.sites[0].r.is_some(),
                "warm cache");
        let no = p3.no_regen();
        let h3 = reg.install(&p3, no);
        assert!(Arc::ptr_eq(&h1.sites[0].l, &h3.sites[0].l));
        // inline handles() agrees with the split path
        let h4 = reg.handles("a").unwrap();
        assert!(Arc::ptr_eq(&h1.sites[0].l, &h4.sites[0].l)
            && Arc::ptr_eq(&h1.sites[0].r, &h4.sites[0].r));
    }

    #[test]
    fn load_checkpoint_requires_a_core() {
        let ck = Checkpoint {
            version: 2,
            method: "lora".into(),
            adapter_seed: 1,
            artifact: "tiny-lm_lora".into(),
            step: 0,
            sites: Vec::new(),
            tensors: std::collections::BTreeMap::new(),
        };
        let mut reg = test_registry(1 << 20);
        assert!(reg.load_checkpoint("x", &ck, 2.0).is_err());
    }
}
