//! Adapter registry: named adapters + a byte-budgeted LRU over the
//! regenerated `L`/`R` projections.
//!
//! ## Model
//!
//! A registry fronts **one base-model site** ([`SiteShape`]: the adapted
//! weight is `m × n`).  Each registered adapter contributes its trained
//! core `Y` (`a × b`), its seed and a scale `alpha`; the fixed
//! projections `L` (`m × a`) and `R` (`b × n`) are *never stored* — they
//! regenerate on demand from `(seed, tensor name)` via the canonical
//! `regen_l` / `regen_r` generators, exactly as the checkpoint loader
//! does, so an adapter that is evicted and reloaded produces
//! **bit-identical** forward outputs (asserted by the tests below).
//!
//! ## Projection cache
//!
//! Regeneration is O(m·a + b·n) gaussian draws — cheap enough to redo,
//! expensive enough to cache.  [`ProjectionCache`] is an LRU keyed by
//! `(seed, tensor name, rows, cols)` with a byte budget: hits bump a
//! logical clock, misses regenerate and insert, and inserts evict
//! least-recently-used entries until the budget holds (the newest entry
//! is always kept resident so a single over-budget projection still
//! serves).  Entries are `Arc<Matrix>` so the scheduler's workers can
//! hold a projection across a batch while the cache concurrently evicts
//! it for someone else.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::Arc;

use crate::adapters::cosa::{adapter_forward_into, regen_l, regen_r};
use crate::linalg::Workspace;
use crate::math::matrix::Matrix;
use crate::train::checkpoint::Checkpoint;

/// The base-model site a registry serves: the adapted weight is `m × n`
/// (activations enter as rows of width `n`, leave as rows of width `m`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SiteShape {
    pub m: usize,
    pub n: usize,
}

/// One registered adapter: everything except the regenerable projections.
#[derive(Clone)]
pub struct Adapter {
    pub name: Arc<str>,
    pub seed: u64,
    pub alpha: f32,
    /// Tensor names the projections derive from (e.g. "adp.0.wq.l") —
    /// must match what training used or the regenerated L/R differ.
    pub l_name: String,
    pub r_name: String,
    /// Trained core (a × b).
    pub y: Arc<Matrix>,
}

/// Everything one forward needs, `Arc`-shared so the registry lock can
/// be released before any compute starts.
#[derive(Clone)]
pub struct AdapterHandles {
    pub l: Arc<Matrix>,
    pub r: Arc<Matrix>,
    pub y: Arc<Matrix>,
    pub alpha: f32,
}

/// First phase of a two-phase lookup ([`AdapterRegistry::plan`] /
/// [`AdapterRegistry::install`]): `l`/`r` are `Some` on cache hits;
/// on a miss the remaining fields describe the regeneration to perform
/// outside the registry lock.
pub struct ProjectionPlan {
    pub seed: u64,
    pub l_name: String,
    pub r_name: String,
    pub m: usize,
    pub n: usize,
    pub a: usize,
    pub b: usize,
    pub alpha: f32,
    pub y: Arc<Matrix>,
    pub l: Option<Arc<Matrix>>,
    pub r: Option<Arc<Matrix>>,
}

/// Cache key: (seed, tensor name, rows, cols).  Dims are part of the
/// identity so two adapters sharing a seed but differing in core shape
/// can never collide.
type CacheKey = (u64, String, usize, usize);

struct CacheEntry {
    mat: Arc<Matrix>,
    last_used: u64,
}

/// Counters exposed for benches and tests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// Byte-budgeted LRU over regenerated projections (see module docs).
pub struct ProjectionCache {
    budget_bytes: usize,
    bytes: usize,
    tick: u64,
    entries: HashMap<CacheKey, CacheEntry>,
    stats: CacheStats,
}

fn mat_bytes(m: &Matrix) -> usize {
    m.data.len() * std::mem::size_of::<f32>()
}

impl ProjectionCache {
    pub fn new(budget_bytes: usize) -> ProjectionCache {
        ProjectionCache {
            budget_bytes,
            bytes: 0,
            tick: 0,
            entries: HashMap::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    /// Bytes currently resident (diagnostic).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Entries currently resident (diagnostic).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Hit-only lookup: bumps recency and the hit counter on a hit,
    /// touches nothing on a miss (the caller is expected to regenerate
    /// outside any lock and come back through [`ProjectionCache::get_or`]).
    pub fn peek(&mut self, key: &CacheKey) -> Option<Arc<Matrix>> {
        if let Some(e) = self.entries.get_mut(key) {
            self.tick += 1;
            e.last_used = self.tick;
            self.stats.hits += 1;
            return Some(e.mat.clone());
        }
        None
    }

    /// The cached projection for `key`, regenerating via `regen` on a
    /// miss.  Hits refresh recency; misses insert and then evict
    /// least-recently-used entries until the budget holds (the entry
    /// just inserted is never the victim).
    pub fn get_or(
        &mut self,
        key: CacheKey,
        regen: impl FnOnce() -> Matrix,
    ) -> Arc<Matrix> {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(&key) {
            e.last_used = self.tick;
            self.stats.hits += 1;
            return e.mat.clone();
        }
        self.stats.misses += 1;
        let mat = Arc::new(regen());
        self.bytes += mat_bytes(&mat);
        let entry = CacheEntry { mat: mat.clone(), last_used: self.tick };
        self.entries.insert(key.clone(), entry);
        self.evict_to_budget(&key);
        mat
    }

    fn evict_to_budget(&mut self, keep: &CacheKey) {
        while self.bytes > self.budget_bytes && self.entries.len() > 1 {
            let victim = self
                .entries
                .iter()
                .filter(|(k, _)| *k != keep)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            let Some(k) = victim else { break };
            if let Some(e) = self.entries.remove(&k) {
                self.bytes -= mat_bytes(&e.mat);
                self.stats.evictions += 1;
            }
        }
    }
}

/// Named adapters over one site, with hot load/evict and the projection
/// LRU (see module docs).
pub struct AdapterRegistry {
    site: SiteShape,
    adapters: BTreeMap<Arc<str>, Adapter>,
    cache: ProjectionCache,
}

impl AdapterRegistry {
    pub fn new(site: SiteShape, cache_budget_bytes: usize) -> AdapterRegistry {
        AdapterRegistry {
            site,
            adapters: BTreeMap::new(),
            cache: ProjectionCache::new(cache_budget_bytes),
        }
    }

    pub fn site(&self) -> SiteShape {
        self.site
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    pub fn reset_cache_stats(&mut self) {
        self.cache.reset_stats();
    }

    /// Registered adapter names (sorted — BTreeMap order).
    pub fn names(&self) -> Vec<Arc<str>> {
        self.adapters.keys().cloned().collect()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.adapters.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.adapters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.adapters.is_empty()
    }

    /// Hot-load an adapter from its parts.  Replaces any same-named
    /// adapter.  The core must be consistent with the site (L is
    /// `m × a`, R is `b × n`; a/b come from the core itself).
    pub fn insert(
        &mut self,
        name: &str,
        seed: u64,
        alpha: f32,
        l_name: &str,
        r_name: &str,
        y: Matrix,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            y.rows > 0 && y.cols > 0,
            "adapter `{name}`: empty core ({} x {})",
            y.rows,
            y.cols
        );
        let key: Arc<str> = Arc::from(name);
        let adapter = Adapter {
            name: key.clone(),
            seed,
            alpha,
            l_name: l_name.to_string(),
            r_name: r_name.to_string(),
            y: Arc::new(y),
        };
        self.adapters.insert(key, adapter);
        Ok(())
    }

    /// Hot-load from a checkpoint: takes the first `*.y` tensor (BTreeMap
    /// order) as the served core and derives the projection tensor names
    /// from it ("….y" -> "….l" / "….r" — the training-time convention),
    /// so the regenerated projections match the ones the core was
    /// trained against.
    pub fn load_checkpoint(
        &mut self,
        name: &str,
        ck: &Checkpoint,
        alpha: f32,
    ) -> anyhow::Result<()> {
        let found = ck
            .tensors
            .iter()
            .find(|(n, (shape, _))| n.ends_with(".y") && shape.len() == 2);
        let Some((tname, (shape, vals))) = found else {
            anyhow::bail!(
                "checkpoint for `{name}` has no 2-d `*.y` core tensor"
            );
        };
        let stem = tname.strip_suffix(".y").unwrap_or(tname).to_string();
        let y = Matrix::from_vec(shape[0], shape[1], vals.clone());
        self.insert(
            name,
            ck.adapter_seed,
            alpha,
            &format!("{stem}.l"),
            &format!("{stem}.r"),
            y,
        )
    }

    /// Load-by-name entry point: resolve `name` to a checkpoint file in
    /// `dir` (via [`Checkpoint::load_by_name`]) and hot-load it.
    pub fn load_from_dir(
        &mut self,
        dir: &Path,
        name: &str,
        alpha: f32,
    ) -> anyhow::Result<()> {
        let ck = Checkpoint::load_by_name(dir, name)?;
        self.load_checkpoint(name, &ck, alpha)
    }

    /// Drop an adapter.  Its projections stay in the LRU until the byte
    /// budget pushes them out (another adapter may share the seed); a
    /// later reload regenerates bit-identically either way.
    pub fn evict(&mut self, name: &str) -> bool {
        self.adapters.remove(name).is_some()
    }

    /// Lock-friendly first phase of a lookup: cache hits resolve
    /// immediately into the plan; misses leave `l`/`r` as `None` plus
    /// everything needed to regenerate them **outside** whatever lock
    /// guards this registry.  Hand the regenerated matrices back through
    /// [`AdapterRegistry::install`].  (The scheduler's workers use this
    /// split so a cold or thrashing projection cache never serializes
    /// the worker pool behind one regenerating thread.)
    pub fn plan(&mut self, name: &str) -> anyhow::Result<ProjectionPlan> {
        let (m, n) = (self.site.m, self.site.n);
        let adapter = self
            .adapters
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown adapter `{name}`"))?
            .clone();
        let (a, b) = (adapter.y.rows, adapter.y.cols);
        let l = self.cache.peek(&(adapter.seed, adapter.l_name.clone(), m, a));
        let r = self.cache.peek(&(adapter.seed, adapter.r_name.clone(), b, n));
        Ok(ProjectionPlan {
            seed: adapter.seed,
            l_name: adapter.l_name,
            r_name: adapter.r_name,
            m,
            n,
            a,
            b,
            alpha: adapter.alpha,
            y: adapter.y,
            l,
            r,
        })
    }

    /// Second phase: install projections regenerated outside the lock
    /// (pass `None` for anything the plan already resolved).  If two
    /// workers raced the same cold adapter, the first insert wins and
    /// the loser's regenerated copy is dropped — both see identical
    /// bits either way, regen being deterministic.
    pub fn install(
        &mut self,
        plan: &ProjectionPlan,
        l_new: Option<Matrix>,
        r_new: Option<Matrix>,
    ) -> AdapterHandles {
        let l = match &plan.l {
            Some(hit) => hit.clone(),
            None => {
                let (seed, m, a) = (plan.seed, plan.m, plan.a);
                let lname = plan.l_name.clone();
                self.cache.get_or((seed, lname.clone(), m, a), move || {
                    l_new.unwrap_or_else(|| regen_l(seed, &lname, m, a))
                })
            }
        };
        let r = match &plan.r {
            Some(hit) => hit.clone(),
            None => {
                let (seed, b, n) = (plan.seed, plan.b, plan.n);
                let rname = plan.r_name.clone();
                self.cache.get_or((seed, rname.clone(), b, n), move || {
                    r_new.unwrap_or_else(|| regen_r(seed, &rname, b, n))
                })
            }
        };
        AdapterHandles { l, r, y: plan.y.clone(), alpha: plan.alpha }
    }

    /// Projection handles for one forward, through the LRU.  Cache
    /// misses regenerate inline — single-owner callers (tests, the
    /// sequential bench baseline) hold no lock, so the two-phase split
    /// buys them nothing.
    pub fn handles(&mut self, name: &str) -> anyhow::Result<AdapterHandles> {
        let plan = self.plan(name)?;
        Ok(self.install(&plan, None, None))
    }

    /// Workspace-backed forward for `x` (N × n) into `out` (N × m) —
    /// the per-request kernel the scheduler's workers run.
    pub fn forward_into(
        &mut self,
        name: &str,
        x: &Matrix,
        ws: &mut Workspace,
        out: &mut Matrix,
    ) -> anyhow::Result<()> {
        let h = self.handles(name)?;
        adapter_forward_into(x, &h.l, &h.r, &h.y, h.alpha, ws, out);
        Ok(())
    }

    /// Allocating forward (tests and the sequential bench baseline).
    pub fn forward(&mut self, name: &str, x: &Matrix) -> anyhow::Result<Matrix> {
        let h = self.handles(name)?;
        Ok(crate::adapters::cosa::adapter_forward(
            x, &h.l, &h.r, &h.y, h.alpha,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Pcg64;

    fn test_registry(budget: usize) -> AdapterRegistry {
        AdapterRegistry::new(SiteShape { m: 12, n: 10 }, budget)
    }

    fn add_adapter(reg: &mut AdapterRegistry, name: &str, seed: u64) {
        let mut rng = Pcg64::derive(seed, name);
        let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
        reg.insert(name, seed, 2.0, "adp.0.wq.l", "adp.0.wq.r", y).unwrap();
    }

    #[test]
    fn forward_matches_direct_adapter_math() {
        let mut reg = test_registry(1 << 20);
        add_adapter(&mut reg, "a", 7);
        let mut rng = Pcg64::new(1);
        let x = Matrix::gaussian(3, 10, 1.0, &mut rng);
        let got = reg.forward("a", &x).unwrap();
        let l = regen_l(7, "adp.0.wq.l", 12, 4);
        let r = regen_r(7, "adp.0.wq.r", 3, 10);
        let h = reg.handles("a").unwrap();
        let want =
            crate::adapters::cosa::adapter_forward(&x, &l, &r, &h.y, 2.0);
        assert_eq!(got, want, "registry forward must be the canonical math");
    }

    #[test]
    fn unknown_adapter_is_an_error() {
        let mut reg = test_registry(1 << 20);
        let x = Matrix::zeros(1, 10);
        assert!(reg.forward("nope", &x).is_err());
        assert!(!reg.evict("nope"));
    }

    #[test]
    fn cache_hits_after_first_touch() {
        let mut reg = test_registry(1 << 20);
        add_adapter(&mut reg, "a", 7);
        let x = Matrix::zeros(1, 10);
        reg.forward("a", &x).unwrap();
        let s1 = reg.cache_stats();
        assert_eq!((s1.hits, s1.misses), (0, 2), "first touch: L and R miss");
        reg.forward("a", &x).unwrap();
        let s2 = reg.cache_stats();
        assert_eq!((s2.hits, s2.misses), (2, 2), "second touch: both hit");
    }

    #[test]
    fn lru_evicts_by_byte_budget_and_keeps_newest() {
        // Budget fits exactly one adapter's projections: L 12x4 + R 3x10
        // = 78 floats = 312 bytes.  Two adapters must thrash; the newest
        // projections always stay resident.
        let mut reg = test_registry(312);
        add_adapter(&mut reg, "a", 7);
        add_adapter(&mut reg, "b", 8);
        let x = Matrix::zeros(1, 10);
        reg.forward("a", &x).unwrap();
        reg.forward("b", &x).unwrap();
        let s = reg.cache_stats();
        assert_eq!(s.misses, 4, "all four projections regenerate");
        assert!(s.evictions >= 2, "budget forces evictions: {s:?}");
        reg.forward("a", &x).unwrap();
        let s = reg.cache_stats();
        assert_eq!(s.misses, 6, "a's projections were evicted, regen again");
    }

    #[test]
    fn zero_budget_still_serves() {
        let mut reg = test_registry(0);
        add_adapter(&mut reg, "a", 7);
        let mut rng = Pcg64::new(2);
        let x = Matrix::gaussian(2, 10, 1.0, &mut rng);
        let o1 = reg.forward("a", &x).unwrap();
        let o2 = reg.forward("a", &x).unwrap();
        assert_eq!(o1, o2, "regen-every-time must still be deterministic");
    }

    #[test]
    fn evict_reload_is_bit_identical() {
        // The §4.1 determinism contract end-to-end: load -> forward,
        // evict (adapter AND cached projections via a tiny budget),
        // reload -> forward must agree bit-for-bit.
        let mut reg = test_registry(312);
        add_adapter(&mut reg, "a", 7);
        let mut rng = Pcg64::new(3);
        let x = Matrix::gaussian(5, 10, 1.0, &mut rng);
        let before = reg.forward("a", &x).unwrap();
        assert!(reg.evict("a"));
        // churn the projection cache so "a" is fully cold again
        add_adapter(&mut reg, "churn", 9);
        reg.forward("churn", &x).unwrap();
        add_adapter(&mut reg, "a", 7);
        let after = reg.forward("a", &x).unwrap();
        for (p, q) in before.data.iter().zip(&after.data) {
            assert_eq!(p.to_bits(), q.to_bits(), "evict/reload drifted");
        }
    }

    #[test]
    fn checkpoint_roundtrip_load_by_name_bit_identical() {
        use std::collections::BTreeMap;
        let dir = std::env::temp_dir().join("cosa_serve_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Pcg64::new(4);
        let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
        let mut tensors = BTreeMap::new();
        tensors.insert("adp.0.wq.y".to_string(),
                       (vec![4usize, 3], y.data.clone()));
        let ck = Checkpoint {
            method: "cosa".into(),
            adapter_seed: 77,
            artifact: "tiny-lm_cosa".into(),
            step: 5,
            tensors,
        };
        ck.save(&dir.join("mathbot.cosa")).unwrap();

        let mut reg = test_registry(1 << 20);
        reg.load_from_dir(&dir, "mathbot", 2.0).unwrap();
        let x = Matrix::gaussian(2, 10, 1.0, &mut rng);
        let first = reg.forward("mathbot", &x).unwrap();

        // evict + reload from disk: same bits
        assert!(reg.evict("mathbot"));
        reg.load_from_dir(&dir, "mathbot", 2.0).unwrap();
        let second = reg.forward("mathbot", &x).unwrap();
        for (p, q) in first.data.iter().zip(&second.data) {
            assert_eq!(p.to_bits(), q.to_bits(), "disk reload drifted");
        }

        // and the in-memory insert with the same parts agrees too
        let mut reg2 = test_registry(1 << 20);
        reg2.insert("mathbot", 77, 2.0, "adp.0.wq.l", "adp.0.wq.r", y)
            .unwrap();
        let third = reg2.forward("mathbot", &x).unwrap();
        assert_eq!(first, third, "checkpoint path vs direct insert");
    }

    #[test]
    fn plan_install_split_matches_inline_and_survives_races() {
        let mut reg = test_registry(1 << 20);
        add_adapter(&mut reg, "a", 7);
        // Two cold plans (as two workers would take under the lock).
        let p1 = reg.plan("a").unwrap();
        let p2 = reg.plan("a").unwrap();
        assert!(p1.l.is_none() && p1.r.is_none(), "cold cache");
        // Both regenerate outside the lock...
        let l1 = regen_l(p1.seed, &p1.l_name, p1.m, p1.a);
        let r1 = regen_r(p1.seed, &p1.r_name, p1.b, p1.n);
        let l2 = regen_l(p2.seed, &p2.l_name, p2.m, p2.a);
        let r2 = regen_r(p2.seed, &p2.r_name, p2.b, p2.n);
        // ...first install wins, second gets the already-resident Arcs.
        let h1 = reg.install(&p1, Some(l1), Some(r1));
        let h2 = reg.install(&p2, Some(l2), Some(r2));
        assert!(Arc::ptr_eq(&h1.l, &h2.l), "raced install must dedupe");
        assert!(Arc::ptr_eq(&h1.r, &h2.r));
        // and a warm plan resolves without any regeneration step
        let p3 = reg.plan("a").unwrap();
        assert!(p3.l.is_some() && p3.r.is_some(), "warm cache");
        let h3 = reg.install(&p3, None, None);
        assert!(Arc::ptr_eq(&h1.l, &h3.l));
        // inline handles() agrees with the split path
        let h4 = reg.handles("a").unwrap();
        assert!(Arc::ptr_eq(&h1.l, &h4.l) && Arc::ptr_eq(&h1.r, &h4.r));
    }

    #[test]
    fn load_checkpoint_requires_a_core() {
        let ck = Checkpoint {
            method: "lora".into(),
            adapter_seed: 1,
            artifact: "tiny-lm_lora".into(),
            step: 0,
            tensors: std::collections::BTreeMap::new(),
        };
        let mut reg = test_registry(1 << 20);
        assert!(reg.load_checkpoint("x", &ck, 2.0).is_err());
    }
}
