//! `serve` — multi-adapter, multi-site serving engine (the paper's
//! §4.1 deployment story at production shape).
//!
//! A CoSA adapter artifact is only the compact cores `Y` plus a seed
//! that regenerates the fixed projections `L`/`R` bit-identically
//! (`adapters::cosa::regen_l` / `regen_r`) — and CoSA adapts *every*
//! targeted projection of a model, so the natural serving workload is
//! **many adapters × every adapted site of one base model**.  Per-site
//! state is a few KiB of core; a whole model's adapter set is `Σ a·b`
//! floats plus 8 bytes of seed.  This module turns that property into
//! a serving engine over the [`model`](crate::model) layer:
//!
//! * the serving registry is the
//!   [`model::AdaptedModel`](crate::model::AdaptedModel) layer
//!   directly: adapters are per-site [`Adapter`](crate::adapters::
//!   Adapter) trait-object sets loaded by name (site-aware checkpoints
//!   carry all tensors of one adapter under per-site method tags; hot
//!   load/evict), with seed-regenerable tensors cached in **one**
//!   shared byte-budgeted LRU keyed by `(seed, tensor, dims)`.
//!   Evicting and re-materializing an adapter is bit-identical by
//!   construction, and one engine serves CoSA, RoSA, and LoRA
//!   adapters side by side.
//! * [`scheduler`] — the request scheduler: whole-model requests (one
//!   activation row per site) enter class-tiered queues
//!   ([`RequestClass`]: interactive / batch / background under
//!   weighted fair queuing, so sustained interactive load can delay
//!   but never starve background work) and board **fused cross-adapter
//!   batches** under a max-batch / max-wait policy — all requests of
//!   one server share site shapes, so rows from *different* adapters
//!   ride one batch, segmented by adapter and executed with one
//!   grouped block-diagonal GEMM sweep per site
//!   ([`linalg::gemm_grouped_nt_into`](crate::linalg::gemm_grouped_nt_into)).
//!   Per-request deadlines (expired requests answer with a timeout
//!   error instead of occupying fused-batch slots) and a
//!   drop-on-cancel ticket API are layered on top, and the worker pool
//!   plans/installs all cold adapters of a batch in two model-lock
//!   round-trips (`plan_many` / `install_many`).  Each worker owns a
//!   [`linalg::Workspace`](crate::linalg::Workspace); the matmul hot
//!   path performs no allocations at steady state, and batch outputs
//!   come from the shared [`outpool::OutputPool`], recycled across
//!   workers when the last ticket of a batch drops them.  Per-class
//!   submission/latency accounting (p99) is surfaced in
//!   [`SchedulerStats::per_class`].
//! * [`bench`] — the synthetic open-loop workload drivers behind the
//!   `serve-bench` CLI subcommand and `benches/serve_bench.rs`:
//!   [`bench::run`] (single-site `serving` section: Zipf adapter
//!   popularity, batched-vs-sequential throughput, latency
//!   percentiles) and [`bench::run_model`] (multi-site `serving_model`
//!   section: N sites × M adapters, plus the shared-cache vs
//!   per-site-cache comparison).  Both sections of
//!   `BENCH_linalg.json` are gated in CI by
//!   `tools/bench_regression.py`.
//!
//! Knobs come from the `[serve]` and `[model]` config tables
//! ([`config::ServeConfig`](crate::config::ServeConfig),
//! [`config::ModelConfig`](crate::config::ModelConfig)) with
//! `COSA_SERVE_*` / `COSA_MODEL_*` env overrides; worker count resolves
//! through the same `plan_threads` helper the compute backends share.

pub mod bench;
pub mod outpool;
pub mod scheduler;

pub use crate::model::{AdaptedModel, ModelSpec, SiteShape, SiteSpec};
pub use scheduler::{
    CancelHandle, ClassStats, RequestClass, Response, SchedulerStats,
    Server, Ticket,
};
