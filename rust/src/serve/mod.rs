//! `serve` — multi-adapter serving engine (the paper's §4.1 deployment
//! story at production shape).
//!
//! A CoSA adapter artifact is only the compact core `Y` plus a seed that
//! regenerates the fixed projections `L`/`R` bit-identically
//! (`adapters::cosa::regen_l` / `regen_r`).  That makes *many adapters
//! on one base model* the natural serving workload: per-adapter state is
//! a few KiB of core, and the expensive projections are a pure function
//! of `(seed, tensor name, dims)` — cacheable, evictable and
//! reconstructible at will.  This module turns that property into a
//! serving engine:
//!
//! * [`registry`] — the adapter registry: checkpoints loaded by name
//!   (hot load/evict), with regenerated `L`/`R` projections cached in a
//!   byte-budgeted LRU keyed by `(seed, tensor, dims)`.  Evicting and
//!   re-materializing an adapter is bit-identical by construction.
//! * [`scheduler`] — the request scheduler: single-row requests enter a
//!   queue, are grouped **per adapter id** into batches under a
//!   max-batch / max-wait policy, and run on a worker pool where each
//!   worker owns a [`linalg::Workspace`](crate::linalg::Workspace) and
//!   drives `adapter_forward_into` — the matmul hot path performs no
//!   allocations at steady state (the Workspace/pack-pool contract).
//! * [`bench`] — the synthetic open-loop workload driver behind the
//!   `serve-bench` CLI subcommand and `benches/serve_bench.rs`:
//!   configurable adapter count, Zipf-skewed adapter popularity and
//!   request rate, reporting throughput, p50/p95/p99 latency and the
//!   batched-vs-sequential speedup into the `serving` section of
//!   `BENCH_linalg.json` (gated in CI by `tools/bench_regression.py`).
//!
//! Knobs come from the `[serve]` config table
//! ([`config::ServeConfig`](crate::config::ServeConfig)) with
//! `COSA_SERVE_*` env overrides; worker count resolves through the same
//! `plan_threads` helper the compute backends share.

pub mod bench;
pub mod registry;
pub mod scheduler;

pub use registry::{AdapterRegistry, SiteShape};
pub use scheduler::Server;
