//! Slow-request capture: the N slowest traces over a sliding window.
//!
//! Two fixed-size, lock-striped rings live here.  [`SlowRing`] keeps
//! the slowest finished traces seen in the last
//! [`SLOW_WINDOW`](self::SLOW_WINDOW) and backs `GET /v1/debug/slow`;
//! [`RecentRing`] keeps the most recent `exemplars` traces regardless
//! of speed (useful for spot-checking healthy requests, and the
//! substrate for the trace-lifecycle property test).
//!
//! Every finished trace is *offered* to the slow ring; striping by
//! request id spreads contention across [`STRIPES`] mutexes and a
//! per-stripe atomic floor (the minimum resident total once a stripe
//! is full) lets the common fast-request case bail out without
//! touching a lock at all.  Within a stripe, window-expired entries
//! are evicted first; only then does a candidate displace the fastest
//! resident.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::trace::STAGE_COUNT;

/// Sliding window for slow-request retention.
pub const SLOW_WINDOW: Duration = Duration::from_secs(900);

/// Lock stripes per ring.
const STRIPES: usize = 8;

/// Poison-recovering lock (same contract as the scheduler's helper:
/// a panicked holder leaves counters stale, never corrupt).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// A captured trace: full stage breakdown plus the request metadata
/// an operator needs to read it (adapter, batch size, cache plan).
#[derive(Clone, Debug)]
pub struct SlowEntry {
    pub id: u64,
    /// Wall-clock completion time, ms since the Unix epoch.
    pub unix_ms: u64,
    pub total_us: u64,
    pub class: &'static str,
    pub method: &'static str,
    pub outcome: &'static str,
    pub adapter: String,
    pub batch_rows: u32,
    pub cache_hits: u32,
    pub cache_misses: u32,
    /// µs offsets from request start at which each stage completed
    /// (`None` = the stage never ran), indexed by `Stage::idx()`.
    pub stages: [Option<u64>; STAGE_COUNT],
    /// Monotonic completion instant, used for window eviction.
    pub(crate) at: Instant,
}

struct Stripe {
    slots: Mutex<Vec<SlowEntry>>,
    /// Minimum resident `total_us` while the stripe is full; 0 while
    /// it still has room.  Read before locking to reject fast
    /// requests cheaply.
    floor_us: AtomicU64,
}

pub(crate) struct SlowRing {
    stripes: Box<[Stripe]>,
    cap_per_stripe: usize,
    cap_total: usize,
    window: Duration,
}

impl SlowRing {
    pub(crate) fn new(cap_total: usize, window: Duration) -> Self {
        let cap_per_stripe = if cap_total == 0 {
            0
        } else {
            cap_total.div_ceil(STRIPES)
        };
        let stripes: Vec<Stripe> = (0..STRIPES)
            .map(|_| Stripe {
                slots: Mutex::new(Vec::new()),
                floor_us: AtomicU64::new(0),
            })
            .collect();
        SlowRing {
            stripes: stripes.into_boxed_slice(),
            cap_per_stripe,
            cap_total,
            window,
        }
    }

    pub(crate) fn active(&self) -> bool {
        self.cap_per_stripe > 0
    }

    /// Consider `e` for retention.  Cheap for fast requests once the
    /// ring is warm: one relaxed load, no lock.
    pub(crate) fn offer(&self, e: SlowEntry) {
        if self.cap_per_stripe == 0 {
            return;
        }
        let Some(st) = self.stripes.get(e.id as usize % STRIPES) else {
            return;
        };
        let floor = st.floor_us.load(Ordering::Relaxed);
        if floor > 0 && e.total_us <= floor {
            // The stripe was full of strictly slower entries the last
            // time anyone held its lock.  Entries may have expired
            // since; they get swept on the next accepted offer or
            // snapshot, which is a fine staleness trade for a
            // lock-free reject on every fast request.
            return;
        }
        let now = e.at;
        let mut slots = lock(&st.slots);
        slots.retain(|s| {
            now.saturating_duration_since(s.at) <= self.window
        });
        if slots.len() < self.cap_per_stripe {
            slots.push(e);
        } else {
            let mut min_i = 0usize;
            let mut min_us = u64::MAX;
            for (i, s) in slots.iter().enumerate() {
                if s.total_us < min_us {
                    min_us = s.total_us;
                    min_i = i;
                }
            }
            if e.total_us > min_us {
                if let Some(slot) = slots.get_mut(min_i) {
                    *slot = e;
                }
            }
        }
        let floor = if slots.len() >= self.cap_per_stripe {
            slots.iter().map(|s| s.total_us).min().unwrap_or(0)
        } else {
            0
        };
        st.floor_us.store(floor, Ordering::Relaxed);
    }

    /// All in-window entries, slowest first, capped at the configured
    /// ring size.
    pub(crate) fn snapshot(&self) -> Vec<SlowEntry> {
        let now = Instant::now();
        let mut all: Vec<SlowEntry> = Vec::new();
        for st in self.stripes.iter() {
            let slots = lock(&st.slots);
            for s in slots.iter() {
                if now.saturating_duration_since(s.at) <= self.window {
                    all.push(s.clone());
                }
            }
        }
        all.sort_by(|a, b| b.total_us.cmp(&a.total_us));
        all.truncate(self.cap_total);
        all
    }
}

/// Most-recent-N trace ring (the `exemplars` knob).
pub(crate) struct RecentRing {
    stripes: Box<[Mutex<VecDeque<SlowEntry>>]>,
    cap_per_stripe: usize,
}

impl RecentRing {
    pub(crate) fn new(cap_total: usize) -> Self {
        let cap_per_stripe = if cap_total == 0 {
            0
        } else {
            cap_total.div_ceil(STRIPES)
        };
        let stripes: Vec<Mutex<VecDeque<SlowEntry>>> =
            (0..STRIPES).map(|_| Mutex::new(VecDeque::new())).collect();
        RecentRing {
            stripes: stripes.into_boxed_slice(),
            cap_per_stripe,
        }
    }

    pub(crate) fn active(&self) -> bool {
        self.cap_per_stripe > 0
    }

    pub(crate) fn push(&self, e: SlowEntry) {
        if self.cap_per_stripe == 0 {
            return;
        }
        let Some(stripe) = self.stripes.get(e.id as usize % STRIPES)
        else {
            return;
        };
        let mut q = lock(stripe);
        q.push_back(e);
        while q.len() > self.cap_per_stripe {
            q.pop_front();
        }
    }

    pub(crate) fn snapshot(&self) -> Vec<SlowEntry> {
        let mut all: Vec<SlowEntry> = Vec::new();
        for stripe in self.stripes.iter() {
            let q = lock(stripe);
            all.extend(q.iter().cloned());
        }
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(id: u64, total_us: u64, at: Instant) -> SlowEntry {
        SlowEntry {
            id,
            unix_ms: 0,
            total_us,
            class: "interactive",
            method: "cosa",
            outcome: "answered",
            adapter: format!("adp-{id}"),
            batch_rows: 1,
            cache_hits: 0,
            cache_misses: 0,
            stages: [None; STAGE_COUNT],
            at,
        }
    }

    // ids that are multiples of STRIPES land in stripe 0, making the
    // per-stripe eviction order observable with cap_total = STRIPES
    // (one slot per stripe).
    fn sid(k: u64) -> u64 {
        k * STRIPES as u64
    }

    #[test]
    fn keeps_slowest_and_sorts_desc() {
        let ring = SlowRing::new(16, SLOW_WINDOW);
        let now = Instant::now();
        for (id, us) in [(1u64, 500u64), (2, 9000), (3, 100), (4, 7000)]
        {
            ring.offer(entry(id, us, now));
        }
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 4);
        let totals: Vec<u64> =
            snap.iter().map(|s| s.total_us).collect();
        assert_eq!(totals, vec![9000, 7000, 500, 100]);
    }

    #[test]
    fn eviction_order_expired_first_then_fastest() {
        // One slot per stripe: stripe 0 holds an *expired* slow entry.
        let ring = SlowRing::new(STRIPES, SLOW_WINDOW);
        let now = Instant::now();
        let old = now - (SLOW_WINDOW + Duration::from_secs(60));
        ring.offer(entry(sid(1), 1_000_000, old));
        // A faster but in-window candidate must displace the expired
        // entry (window eviction runs before the slowest-kept rule).
        ring.offer(entry(sid(2), 10_000, now));
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].id, sid(2));

        // Stripe full of in-window entries: the *fastest* resident is
        // the one displaced, and only by a slower candidate.
        ring.offer(entry(sid(3), 5_000, now)); // rejected: 5k < 10k
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].id, sid(2));
        ring.offer(entry(sid(4), 20_000, now)); // displaces 10k
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].id, sid(4));
    }

    #[test]
    fn snapshot_filters_expired_entries() {
        let ring = SlowRing::new(8, SLOW_WINDOW);
        let now = Instant::now();
        let old = now - (SLOW_WINDOW + Duration::from_secs(1));
        ring.offer(entry(sid(1), 100, old));
        assert!(ring.snapshot().is_empty());
    }

    #[test]
    fn zero_capacity_ring_is_inert() {
        let ring = SlowRing::new(0, SLOW_WINDOW);
        ring.offer(entry(1, 1_000_000, Instant::now()));
        assert!(ring.snapshot().is_empty());
    }

    #[test]
    fn recent_ring_keeps_latest() {
        let ring = RecentRing::new(STRIPES); // one slot per stripe
        assert!(ring.active());
        let now = Instant::now();
        for id in 0..=STRIPES as u64 {
            ring.push(entry(id, 10, now));
        }
        let snap = ring.snapshot();
        assert_eq!(snap.len(), STRIPES);
        // Stripe 0 saw id 0 then id STRIPES: the newest survives.
        assert!(snap.iter().any(|s| s.id == STRIPES as u64));
        assert!(!snap.iter().any(|s| s.id == 0));
    }

    #[test]
    fn recent_ring_zero_capacity_is_inert() {
        let ring = RecentRing::new(0);
        assert!(!ring.active());
        ring.push(entry(1, 10, Instant::now()));
        assert!(ring.snapshot().is_empty());
    }
}
