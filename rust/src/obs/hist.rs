//! Shared log₂-µs latency histogram.
//!
//! Promoted out of `serve::scheduler` (where it was `LatencyHist`) so
//! the scheduler's per-class latency stats and the telemetry layer's
//! per-stage spans share one type.  Bucket `b` covers durations in
//! `[2^(b-1), 2^b - 1]` µs (bucket 0 is exactly 0 µs); the final
//! bucket absorbs everything ≥ 2^38 µs.  Recording is two relaxed
//! atomic adds — safe to call from every worker concurrently — and
//! percentile readouts return the *upper edge* of the bucket holding
//! the requested rank, exactly as the scheduler's old `p99_us` did.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of log₂ buckets.  40 buckets reach `2^39 - 1` µs ≈ 6 days.
pub const BUCKETS: usize = 40;

/// Inclusive upper edge of bucket `b`, in µs.
pub fn bucket_upper_us(b: usize) -> u64 {
    (1u64 << b.min(BUCKETS - 1)) - 1
}

/// Lock-free log₂-µs histogram with a running sum.
#[derive(Debug)]
pub struct Histogram {
    counts: Box<[AtomicU64]>,
    sum_us: AtomicU64,
}

impl Histogram {
    pub fn new() -> Self {
        let counts: Vec<AtomicU64> =
            (0..BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            counts: counts.into_boxed_slice(),
            sum_us: AtomicU64::new(0),
        }
    }

    fn bucket(us: u64) -> usize {
        ((64 - us.leading_zeros()) as usize).min(BUCKETS - 1)
    }

    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn record_us(&self, us: u64) {
        let b = Self::bucket(us);
        if let Some(c) = self.counts.get(b) {
            c.fetch_add(1, Ordering::Relaxed);
        }
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Upper bucket edge of the requested percentile (1..=100); 0 for
    /// an empty histogram.
    pub fn percentile_us(&self, pct: u8) -> u64 {
        self.snapshot().percentile_us(pct)
    }

    pub fn p50_us(&self) -> u64 {
        self.percentile_us(50)
    }

    pub fn p95_us(&self) -> u64 {
        self.percentile_us(95)
    }

    pub fn p99_us(&self) -> u64 {
        self.percentile_us(99)
    }

    /// Consistent point-in-time copy for rendering (`/metrics`,
    /// `/v1/stats`).  Per-bucket loads are relaxed; a scrape racing a
    /// record may be off by the in-flight sample, never corrupt.
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            buckets: self
                .counts
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
            sum_us: self.sum_us.load(Ordering::Relaxed),
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Owned copy of a [`Histogram`]'s state.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Snapshot {
    pub buckets: Vec<u64>,
    pub sum_us: u64,
}

impl Snapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn percentile_us(&self, pct: u8) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = (total * u64::from(pct.min(100))).div_ceil(100).max(1);
        let mut seen = 0u64;
        for (b, c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper_us(b);
            }
        }
        bucket_upper_us(BUCKETS - 1)
    }

    pub fn p50_us(&self) -> u64 {
        self.percentile_us(50)
    }

    pub fn p95_us(&self) -> u64 {
        self.percentile_us(95)
    }

    pub fn p99_us(&self) -> u64 {
        self.percentile_us(99)
    }

    /// Accumulate `other` into `self` (used to merge per-class /
    /// per-method stage histograms into one series).
    pub fn merge(&mut self, other: &Snapshot) {
        if self.buckets.len() < other.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (b, c) in other.buckets.iter().enumerate() {
            if let Some(slot) = self.buckets.get_mut(b) {
                *slot += c;
            }
        }
        self.sum_us += other.sum_us;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges() {
        assert_eq!(Histogram::bucket(0), 0);
        assert_eq!(Histogram::bucket(1), 1);
        assert_eq!(Histogram::bucket(2), 2);
        assert_eq!(Histogram::bucket(3), 2);
        assert_eq!(Histogram::bucket(4), 3);
        assert_eq!(Histogram::bucket(1023), 10);
        assert_eq!(Histogram::bucket(1024), 11);
        assert_eq!(Histogram::bucket(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn empty_percentiles_are_zero() {
        let h = Histogram::new();
        assert_eq!(h.p50_us(), 0);
        assert_eq!(h.p99_us(), 0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum_us(), 0);
    }

    #[test]
    fn percentile_matches_scheduler_p99_semantics() {
        // 100 samples at ~1 ms, one at ~1 s: the p99 rank (rank 100
        // of 101) still lands in the 1 ms bucket; p100 in the 1 s
        // bucket.  Mirrors the old scheduler test shape.
        let h = Histogram::new();
        for _ in 0..100 {
            h.record_us(1000);
        }
        h.record_us(1_000_000);
        assert!(h.p99_us() < 2048, "p99={}", h.p99_us());
        assert!(h.percentile_us(100) >= 1_000_000);
        assert_eq!(h.count(), 101);
        assert_eq!(h.sum_us(), 100 * 1000 + 1_000_000);
    }

    #[test]
    fn percentiles_are_monotone_in_pct() {
        let h = Histogram::new();
        for us in [0u64, 3, 10, 100, 1000, 10_000, 100_000] {
            h.record_us(us);
        }
        let mut prev = 0;
        for pct in [1u8, 25, 50, 75, 95, 99, 100] {
            let v = h.percentile_us(pct);
            assert!(v >= prev, "pct {pct}: {v} < {prev}");
            prev = v;
        }
    }

    #[test]
    fn snapshot_merge_adds() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record_us(10);
        b.record_us(10);
        b.record_us(1_000_000);
        let mut s = a.snapshot();
        s.merge(&b.snapshot());
        assert_eq!(s.count(), 3);
        assert_eq!(s.sum_us, 10 + 10 + 1_000_000);
        assert!(s.p99_us() >= 1_000_000 / 2);
    }

    #[test]
    fn record_duration_uses_micros() {
        let h = Histogram::new();
        h.record(Duration::from_millis(2));
        assert_eq!(h.sum_us(), 2000);
        assert_eq!(h.count(), 1);
    }
}
