//! Hand-rolled Prometheus text-format (v0.0.4) writer — std only,
//! like `wire::json`.
//!
//! [`PromWriter`] produces `# HELP` / `# TYPE` headers, counter and
//! gauge samples, and full `_bucket`/`_sum`/`_count` histogram series
//! from [`hist::Snapshot`]s.  Label values are escaped per the
//! exposition spec (`\\`, `\"`, `\n`).  Histogram `le` edges are the
//! log₂ bucket upper bounds in µs, cumulative, with a final `+Inf`
//! bucket equal to the sample count — the layout Prometheus'
//! `histogram_quantile` expects.
//!
//! [`render_registry`] emits the telemetry registry's own series
//! (per-stage histograms keyed by class and method, grouped-forward
//! split timings, outcome counters); `wire::api::metrics` composes it
//! with the scheduler / cache / HTTP counters into `GET /metrics`.

use super::hist::{bucket_upper_us, Snapshot, BUCKETS};
use super::trace::{Outcome, Stage};
use super::{Registry, CLASS_LABELS, METHOD_LABELS};

pub struct PromWriter {
    out: String,
}

impl PromWriter {
    pub fn new() -> Self {
        PromWriter {
            out: String::with_capacity(4096),
        }
    }

    /// Escape a label value per the text-format spec.
    pub fn escape_label(v: &str) -> String {
        let mut s = String::with_capacity(v.len());
        for ch in v.chars() {
            match ch {
                '\\' => s.push_str("\\\\"),
                '"' => s.push_str("\\\""),
                '\n' => s.push_str("\\n"),
                _ => s.push(ch),
            }
        }
        s
    }

    /// `# HELP` + `# TYPE` for one metric family.  `kind` is
    /// `counter`, `gauge`, or `histogram`.
    pub fn header(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(help);
        self.out.push('\n');
        self.out.push_str("# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(kind);
        self.out.push('\n');
    }

    fn labels(&mut self, labels: &[(&str, &str)]) {
        if labels.is_empty() {
            return;
        }
        self.out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                self.out.push(',');
            }
            self.out.push_str(k);
            self.out.push_str("=\"");
            self.out.push_str(&Self::escape_label(v));
            self.out.push('"');
        }
        self.out.push('}');
    }

    pub fn sample(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        value: u64,
    ) {
        self.out.push_str(name);
        self.labels(labels);
        self.out.push(' ');
        self.out.push_str(&value.to_string());
        self.out.push('\n');
    }

    pub fn sample_f64(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        value: f64,
    ) {
        self.out.push_str(name);
        self.labels(labels);
        self.out.push(' ');
        self.out.push_str(&format!("{value}"));
        self.out.push('\n');
    }

    /// Emit one histogram series: cumulative `_bucket` lines over
    /// every log₂ edge, `+Inf`, then `_sum` (µs) and `_count`.
    pub fn histogram(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        snap: &Snapshot,
    ) {
        let bucket_name = format!("{name}_bucket");
        let mut cum = 0u64;
        for b in 0..BUCKETS {
            cum += snap.buckets.get(b).copied().unwrap_or(0);
            let le = if b + 1 == BUCKETS {
                "+Inf".to_string()
            } else {
                bucket_upper_us(b).to_string()
            };
            self.out.push_str(&bucket_name);
            self.out.push('{');
            for (k, v) in labels.iter() {
                self.out.push_str(k);
                self.out.push_str("=\"");
                self.out.push_str(&Self::escape_label(v));
                self.out.push_str("\",");
            }
            self.out.push_str("le=\"");
            self.out.push_str(&le);
            self.out.push_str("\"} ");
            self.out.push_str(&cum.to_string());
            self.out.push('\n');
        }
        self.sample(&format!("{name}_sum"), labels, snap.sum_us);
        self.sample(&format!("{name}_count"), labels, snap.count());
    }

    pub fn finish(self) -> String {
        self.out
    }
}

impl Default for PromWriter {
    fn default() -> Self {
        Self::new()
    }
}

/// Render the telemetry registry's own metric families.
pub fn render_registry(reg: &Registry, w: &mut PromWriter) {
    w.header(
        "cosa_obs_enabled",
        "gauge",
        "1 when request tracing is enabled.",
    );
    w.sample("cosa_obs_enabled", &[], u64::from(reg.enabled()));

    w.header(
        "cosa_requests_finished_total",
        "counter",
        "Finished traces by terminal outcome.",
    );
    for o in Outcome::ALL {
        w.sample(
            "cosa_requests_finished_total",
            &[("outcome", o.name())],
            reg.finished(o),
        );
    }

    w.header(
        "cosa_slow_requests_total",
        "counter",
        "Requests slower than [obs] slow_ms.",
    );
    w.sample("cosa_slow_requests_total", &[], reg.slow_total());

    w.header(
        "cosa_stage_duration_us",
        "histogram",
        "Per-stage request latency, log2-us buckets, by request \
         class and adapter method.",
    );
    for (ci, class) in CLASS_LABELS.iter().enumerate() {
        for (mi, method) in METHOD_LABELS.iter().enumerate() {
            for s in Stage::ALL {
                let snap = reg.stage_snapshot(ci, mi, s.idx());
                if snap.count() == 0 {
                    continue;
                }
                w.histogram(
                    "cosa_stage_duration_us",
                    &[
                        ("stage", s.name()),
                        ("class", class),
                        ("method", method),
                    ],
                    &snap,
                );
            }
        }
    }

    let copy = reg.grouped_copy_snapshot();
    if copy.count() > 0 {
        w.header(
            "cosa_grouped_copy_us",
            "histogram",
            "Mixed-method row copy time inside grouped forward.",
        );
        w.histogram("cosa_grouped_copy_us", &[], &copy);
    }
    let compute = reg.grouped_compute_snapshot();
    if compute.count() > 0 {
        w.header(
            "cosa_grouped_gemm_us",
            "histogram",
            "Adapter compute time inside grouped forward.",
        );
        w.histogram("cosa_grouped_gemm_us", &[], &compute);
    }
}

#[cfg(test)]
mod tests {
    use super::super::hist::Histogram;
    use super::super::trace::{Outcome, Stage};
    use super::*;

    /// Value of the first sample line matching `prefix`.
    fn sample_value(text: &str, prefix: &str) -> Option<f64> {
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix(prefix) {
                if let Some(v) = rest.trim().split(' ').next_back() {
                    return v.parse().ok();
                }
            }
        }
        None
    }

    #[test]
    fn label_escaping() {
        assert_eq!(PromWriter::escape_label("plain"), "plain");
        assert_eq!(
            PromWriter::escape_label("a\"b\\c\nd"),
            "a\\\"b\\\\c\\nd"
        );
        let mut w = PromWriter::new();
        w.sample("m", &[("adapter", "we\"ird\\name")], 1);
        let out = w.finish();
        assert_eq!(out, "m{adapter=\"we\\\"ird\\\\name\"} 1\n");
    }

    #[test]
    fn histogram_buckets_are_cumulative_with_inf() {
        let h = Histogram::new();
        h.record_us(0); // bucket 0
        h.record_us(1); // bucket 1
        h.record_us(1000); // bucket 10 (le=1023)
        let mut w = PromWriter::new();
        w.histogram("lat", &[("class", "batch")], &h.snapshot());
        let out = w.finish();
        assert_eq!(
            sample_value(&out, "lat_bucket{class=\"batch\",le=\"0\"}"),
            Some(1.0)
        );
        assert_eq!(
            sample_value(&out, "lat_bucket{class=\"batch\",le=\"1\"}"),
            Some(2.0)
        );
        assert_eq!(
            sample_value(
                &out,
                "lat_bucket{class=\"batch\",le=\"1023\"}"
            ),
            Some(3.0)
        );
        assert_eq!(
            sample_value(
                &out,
                "lat_bucket{class=\"batch\",le=\"+Inf\"}"
            ),
            Some(3.0)
        );
        assert_eq!(
            sample_value(&out, "lat_sum{class=\"batch\"}"),
            Some(1001.0)
        );
        assert_eq!(
            sample_value(&out, "lat_count{class=\"batch\"}"),
            Some(3.0)
        );
        // One line per bucket + Inf + sum + count.
        assert_eq!(out.lines().count(), BUCKETS + 2);
    }

    #[test]
    fn headers_and_plain_samples() {
        let mut w = PromWriter::new();
        w.header("cosa_x_total", "counter", "X.");
        w.sample("cosa_x_total", &[], 7);
        w.sample_f64("cosa_ratio", &[], 0.5);
        let out = w.finish();
        assert!(out.contains("# HELP cosa_x_total X.\n"));
        assert!(out.contains("# TYPE cosa_x_total counter\n"));
        assert!(out.contains("cosa_x_total 7\n"));
        assert!(out.contains("cosa_ratio 0.5\n"));
    }

    #[test]
    fn registry_counters_are_monotone_across_scrapes() {
        let reg = Registry::with_params(true, 1_000_000, 8, 8);
        let scrape = |reg: &std::sync::Arc<Registry>| {
            let mut w = PromWriter::new();
            render_registry(reg, &mut w);
            w.finish()
        };
        let finish_one = || {
            let mut t = reg.begin().unwrap();
            t.mark(Stage::Parse);
            t.mark(Stage::Queue);
            t.finish(Outcome::Answered);
        };
        finish_one();
        let a = scrape(&reg);
        let ka = "cosa_requests_finished_total{outcome=\"answered\"}";
        let va = sample_value(&a, ka).unwrap();
        assert_eq!(va, 1.0);
        finish_one();
        finish_one();
        let b = scrape(&reg);
        let vb = sample_value(&b, ka).unwrap();
        assert!(vb >= va, "counter went backwards: {va} -> {vb}");
        assert_eq!(vb, 3.0);
        // Stage histogram appeared, keyed by class and method.
        let kq = "cosa_stage_duration_us_count{stage=\"queue\",\
                  class=\"interactive\",method=\"unknown\"}";
        assert_eq!(sample_value(&b, kq), Some(3.0));
    }

    #[test]
    fn disabled_registry_renders_cleanly() {
        let reg = Registry::disabled();
        let mut w = PromWriter::new();
        render_registry(&reg, &mut w);
        let out = w.finish();
        assert!(out.contains("cosa_obs_enabled 0\n"));
        assert!(out.contains(
            "cosa_requests_finished_total{outcome=\"answered\"} 0\n"
        ));
    }
}
