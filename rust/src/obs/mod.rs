//! `obs` — zero-dependency telemetry for the serving stack.
//!
//! Three pieces, all std-only:
//!
//! * **Stage-timing spans** ([`trace`]): a [`Trace`] handle created at
//!   HTTP accept (or `Server::submit*` for in-process callers) rides
//!   the request through the pipeline, stamping monotonic marks for
//!   `parse` → `admission` → `queue` → `batch_assemble` →
//!   `cache_plan` → `pack` → `gemm` → `reply`.  Finished spans fold
//!   into per-stage log₂-µs [`Histogram`]s keyed by request class and
//!   adapter method.
//! * **Exposition** ([`prom`]): a hand-rolled Prometheus text-format
//!   writer; `GET /metrics` renders every serving counter plus these
//!   histograms as `_bucket`/`_sum`/`_count` series.
//! * **Slow-request capture** ([`slow`]): a lock-striped ring of the
//!   N slowest traces over a sliding window behind
//!   `GET /v1/debug/slow`, with a WARN line past `[obs] slow_ms`.
//!
//! The [`Registry`] owns all aggregate state and is shared as an
//! `Arc` between the gateway, the scheduler, and the exposition
//! endpoints.  With `enabled = false`, [`Registry::begin`] returns
//! `None` and the request path pays a single branch — the scenario-8
//! bench gates traced throughput at ≥ 0.95× untraced.

pub mod hist;
pub mod prom;
pub mod slow;
pub mod trace;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, SystemTime, UNIX_EPOCH};

pub use hist::{Histogram, Snapshot, BUCKETS};
pub use slow::{SlowEntry, SLOW_WINDOW};
pub use trace::{Outcome, Stage, Trace, OUTCOME_COUNT, STAGE_COUNT};

use crate::config::ObsConfig;
use crate::util::logging::{self, Level};
use slow::{RecentRing, SlowRing};

/// Request-class labels, in `serve::scheduler::RequestClass` index
/// order (the scheduler passes the class *index* when classifying a
/// trace; these strings only name the series).
pub const CLASS_LABELS: [&str; 3] = ["interactive", "batch", "background"];

/// Adapter-method labels.  The first three mirror
/// `adapters::traits::Method` tags; requests whose method is not yet
/// known (sheds, parse errors, unknown adapters) bucket last.
pub const METHOD_LABELS: [&str; 4] = ["cosa", "rosa", "lora", "unknown"];

/// Index of the `"unknown"` method bucket.
pub const METHOD_UNKNOWN: usize = METHOD_LABELS.len() - 1;

const CLASSES: usize = CLASS_LABELS.len();
const METHODS: usize = METHOD_LABELS.len();

/// Shared telemetry state: per-stage histograms, outcome counters,
/// and the slow/recent trace rings.
pub struct Registry {
    enabled: bool,
    slow_us: u64,
    next_id: AtomicU64,
    /// `[class][method][stage]`, flattened.
    stage_hists: Box<[Histogram]>,
    grouped_copy: Histogram,
    grouped_compute: Histogram,
    finished: [AtomicU64; OUTCOME_COUNT],
    slow_total: AtomicU64,
    slow: SlowRing,
    recent: RecentRing,
}

impl Registry {
    pub fn new(cfg: &ObsConfig) -> Arc<Self> {
        Self::with_params(
            cfg.enabled,
            cfg.slow_ms,
            cfg.slow_ring,
            cfg.exemplars,
        )
    }

    /// A registry that records nothing ([`Registry::begin`] returns
    /// `None`).  `Server::new` defaults to this so in-process callers
    /// opt in explicitly via `Server::with_obs`.
    pub fn disabled() -> Arc<Self> {
        Self::with_params(false, u64::MAX / 2000, 0, 0)
    }

    pub fn with_params(
        enabled: bool,
        slow_ms: u64,
        slow_ring: usize,
        exemplars: usize,
    ) -> Arc<Self> {
        let n = CLASSES * METHODS * STAGE_COUNT;
        let hists: Vec<Histogram> =
            (0..n).map(|_| Histogram::new()).collect();
        Arc::new(Registry {
            enabled,
            slow_us: slow_ms.saturating_mul(1000),
            next_id: AtomicU64::new(1),
            stage_hists: hists.into_boxed_slice(),
            grouped_copy: Histogram::new(),
            grouped_compute: Histogram::new(),
            finished: Default::default(),
            slow_total: AtomicU64::new(0),
            slow: SlowRing::new(slow_ring, SLOW_WINDOW),
            recent: RecentRing::new(exemplars),
        })
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn slow_ms(&self) -> u64 {
        self.slow_us / 1000
    }

    /// Open a new trace, or `None` when tracing is disabled (the
    /// whole request then pays one branch per call site).
    pub fn begin(self: &Arc<Self>) -> Option<Trace> {
        if !self.enabled {
            return None;
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Some(Trace::new(Arc::clone(self), id))
    }

    fn hist_idx(class: usize, method: usize, stage: usize) -> usize {
        let c = class.min(CLASSES - 1);
        let m = method.min(METHODS - 1);
        let s = stage.min(STAGE_COUNT - 1);
        (c * METHODS + m) * STAGE_COUNT + s
    }

    pub fn stage_snapshot(
        &self,
        class: usize,
        method: usize,
        stage: usize,
    ) -> Snapshot {
        let i = Self::hist_idx(class, method, stage);
        self.stage_hists
            .get(i)
            .map(Histogram::snapshot)
            .unwrap_or_default()
    }

    /// One stage's histogram merged across every class and method
    /// (the bench's per-stage p99 readout).
    pub fn merged_stage_snapshot(&self, stage: Stage) -> Snapshot {
        let mut acc = Snapshot::default();
        for c in 0..CLASSES {
            for m in 0..METHODS {
                acc.merge(&self.stage_snapshot(c, m, stage.idx()));
            }
        }
        acc
    }

    pub fn finished(&self, outcome: Outcome) -> u64 {
        self.finished
            .get(outcome.idx())
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    pub fn finished_total(&self) -> u64 {
        self.finished
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .sum()
    }

    pub fn slow_total(&self) -> u64 {
        self.slow_total.load(Ordering::Relaxed)
    }

    pub fn slow_snapshot(&self) -> Vec<SlowEntry> {
        self.slow.snapshot()
    }

    /// The most recent `exemplars` finished traces (any speed).
    pub fn recent_snapshot(&self) -> Vec<SlowEntry> {
        self.recent.snapshot()
    }

    /// Fold the adapters-layer grouped-forward split (mixed-method
    /// row copies vs. compute) into the registry.
    pub fn record_grouped(&self, copy_us: u64, compute_us: u64) {
        self.grouped_copy.record_us(copy_us);
        self.grouped_compute.record_us(compute_us);
    }

    pub fn grouped_copy_snapshot(&self) -> Snapshot {
        self.grouped_copy.snapshot()
    }

    pub fn grouped_compute_snapshot(&self) -> Snapshot {
        self.grouped_compute.snapshot()
    }

    /// Terminal accounting for one trace — called exactly once per
    /// trace by [`Trace::finish`] / its `Drop` guard.
    pub(crate) fn record(&self, t: &Trace, outcome: Outcome) {
        if let Some(c) = self.finished.get(outcome.idx()) {
            c.fetch_add(1, Ordering::Relaxed);
        }
        let mut prev = 0u64;
        for s in Stage::ALL {
            if let Some(off) = t.marks.get(s.idx()).copied().flatten() {
                let i = Self::hist_idx(t.class, t.method, s.idx());
                if let Some(h) = self.stage_hists.get(i) {
                    h.record_us(off.saturating_sub(prev));
                }
                prev = off;
            }
        }
        let total_us = t
            .marks
            .get(Stage::Reply.idx())
            .copied()
            .flatten()
            .unwrap_or(prev);
        let slow = total_us >= self.slow_us;
        if !slow && !self.recent.active() && !self.slow.active() {
            return;
        }
        let entry = SlowEntry {
            id: t.id,
            unix_ms: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_millis() as u64)
                .unwrap_or(0),
            total_us,
            class: CLASS_LABELS
                .get(t.class)
                .copied()
                .unwrap_or("interactive"),
            method: METHOD_LABELS
                .get(t.method)
                .copied()
                .unwrap_or("unknown"),
            outcome: outcome.name(),
            adapter: t
                .adapter
                .as_deref()
                .unwrap_or("")
                .to_string(),
            batch_rows: t.batch_rows,
            cache_hits: t.cache_hits,
            cache_misses: t.cache_misses,
            stages: t.marks,
            at: t.start + Duration::from_micros(total_us),
        };
        if slow {
            self.slow_total.fetch_add(1, Ordering::Relaxed);
            let d = |s: Stage| stage_delta(&t.marks, s);
            logging::log_req(
                Level::Warn,
                Some(t.id),
                &format!(
                    "slow request: {:.1} ms total (queue {:.1}, \
                     cache_plan {:.1}, gemm {:.1}) class={} \
                     method={} adapter={} rows={} cache={}h/{}m \
                     outcome={}",
                    total_us as f64 / 1000.0,
                    d(Stage::Queue) as f64 / 1000.0,
                    d(Stage::CachePlan) as f64 / 1000.0,
                    d(Stage::Gemm) as f64 / 1000.0,
                    entry.class,
                    entry.method,
                    entry.adapter,
                    entry.batch_rows,
                    entry.cache_hits,
                    entry.cache_misses,
                    entry.outcome,
                ),
            );
        }
        self.slow.offer(entry.clone());
        self.recent.push(entry);
    }
}

/// Duration of `stage` within a finished span set: offset delta from
/// the previous *marked* stage (0 when the stage never ran).
pub fn stage_delta(
    marks: &[Option<u64>; STAGE_COUNT],
    stage: Stage,
) -> u64 {
    let Some(off) = marks.get(stage.idx()).copied().flatten() else {
        return 0;
    };
    let mut prev = 0u64;
    for s in Stage::ALL {
        if s.idx() >= stage.idx() {
            break;
        }
        if let Some(p) = marks.get(s.idx()).copied().flatten() {
            prev = p;
        }
    }
    off.saturating_sub(prev)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_hands_out_no_traces() {
        let reg = Registry::disabled();
        assert!(!reg.enabled());
        assert!(reg.begin().is_none());
    }

    #[test]
    fn begin_assigns_unique_ids() {
        let reg = Registry::with_params(true, 1_000_000, 8, 8);
        let a = reg.begin().map(|t| t.id()).unwrap_or(0);
        let b = reg.begin().map(|t| t.id()).unwrap_or(0);
        assert!(a > 0 && b > 0 && a != b);
    }

    #[test]
    fn finish_records_outcome_and_stage_deltas() {
        let reg = Registry::with_params(true, 1_000_000, 8, 8);
        let mut t = reg.begin().expect("enabled");
        t.set_class(1);
        t.set_method("rosa");
        t.mark(Stage::Parse);
        t.mark(Stage::Queue);
        t.finish(Outcome::Answered);
        assert_eq!(reg.finished(Outcome::Answered), 1);
        assert_eq!(reg.finished(Outcome::Expired), 0);
        // class=batch(1), method=rosa(1): parse, queue, reply each
        // recorded one sample.
        for s in [Stage::Parse, Stage::Queue, Stage::Reply] {
            assert_eq!(reg.stage_snapshot(1, 1, s.idx()).count(), 1);
        }
        assert_eq!(
            reg.stage_snapshot(1, 1, Stage::Gemm.idx()).count(),
            0
        );
        assert_eq!(reg.merged_stage_snapshot(Stage::Queue).count(), 1);
    }

    #[test]
    fn dropped_traces_still_record() {
        let reg = Registry::with_params(true, 1_000_000, 8, 8);
        {
            let mut t = reg.begin().expect("enabled");
            t.mark(Stage::Parse);
            // dropped without finish (e.g. scheduler shutdown)
        }
        assert_eq!(reg.finished(Outcome::Dropped), 1);
        let recent = reg.recent_snapshot();
        assert_eq!(recent.len(), 1);
        assert_eq!(recent[0].outcome, "dropped");
        // The Drop guard stamps the terminal reply mark.
        assert!(recent[0].stages[Stage::Reply.idx()].is_some());
    }

    #[test]
    fn slow_requests_count_and_capture() {
        // slow_ms = 0: everything is "slow".
        let reg = Registry::with_params(true, 0, 8, 0);
        let t = reg.begin().expect("enabled");
        t.finish(Outcome::Answered);
        assert_eq!(reg.slow_total(), 1);
        assert_eq!(reg.slow_snapshot().len(), 1);
        // exemplars = 0: recent ring inert.
        assert!(reg.recent_snapshot().is_empty());
    }

    #[test]
    fn stage_delta_skips_unmarked_stages() {
        let mut marks = [None; STAGE_COUNT];
        marks[Stage::Parse.idx()] = Some(10);
        marks[Stage::Queue.idx()] = Some(250);
        marks[Stage::Reply.idx()] = Some(300);
        assert_eq!(stage_delta(&marks, Stage::Parse), 10);
        // queue's previous marked stage is parse (admission unmarked)
        assert_eq!(stage_delta(&marks, Stage::Queue), 240);
        assert_eq!(stage_delta(&marks, Stage::Gemm), 0);
        assert_eq!(stage_delta(&marks, Stage::Reply), 50);
    }
}
