//! Per-request stage-timing spans.
//!
//! A [`Trace`] is created once per request — at HTTP accept in
//! `wire::api`, or inside `Server::submit*` for in-process callers —
//! and travels *with* the request through the scheduler (the ticket /
//! `Request` carries it; no thread-locals cross the worker pool).
//! Each pipeline stage calls [`Trace::mark`] as it completes; marks
//! are monotonic µs offsets from the trace's start, so the per-stage
//! duration is the delta between consecutive marked offsets.
//!
//! Every trace terminates exactly once: explicitly via
//! [`Trace::finish`] on the known exits (answered / expired /
//! cancelled / shed / errored), or via `Drop` with
//! [`Outcome::Dropped`] if a request is torn down without an answer
//! (e.g. scheduler shutdown).  Either way the terminal `reply` mark is
//! stamped, so a finished trace always has a complete, stage-ordered
//! span set — the property the trace-lifecycle test pins.

use std::sync::Arc;
use std::time::Instant;

use super::Registry;

/// Number of pipeline stages a request passes through.
pub const STAGE_COUNT: usize = 8;

/// Request pipeline stages, in execution order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// HTTP read + JSON body parse (wire layer).
    Parse,
    /// Admission control: drain / queue-depth / class shed checks.
    Admission,
    /// Time spent queued in the batcher's class queues.
    Queue,
    /// Batch boarding: WFQ pop, cancel/deadline sweep, segmentation.
    BatchAssemble,
    /// Cache plan + regen of missing projections + install (the
    /// hit/miss counts on the trace say how much was regenerated).
    CachePlan,
    /// Per-site batch-matrix assembly (row gather into the workspace).
    Pack,
    /// Grouped block-diagonal GEMM + adapter compute.
    Gemm,
    /// Reply delivery back to the ticket / connection.
    Reply,
}

impl Stage {
    pub const ALL: [Stage; STAGE_COUNT] = [
        Stage::Parse,
        Stage::Admission,
        Stage::Queue,
        Stage::BatchAssemble,
        Stage::CachePlan,
        Stage::Pack,
        Stage::Gemm,
        Stage::Reply,
    ];

    pub fn idx(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Stage::Parse => "parse",
            Stage::Admission => "admission",
            Stage::Queue => "queue",
            Stage::BatchAssemble => "batch_assemble",
            Stage::CachePlan => "cache_plan",
            Stage::Pack => "pack",
            Stage::Gemm => "gemm",
            Stage::Reply => "reply",
        }
    }
}

/// Number of terminal outcomes.
pub const OUTCOME_COUNT: usize = 6;

/// How a request's trace terminated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    Answered,
    /// Deadline exceeded while queued.
    Expired,
    Cancelled,
    /// Rejected by admission control (429 / 503) before submit.
    Shed,
    /// Answered with an error (bad request, unknown adapter, plan
    /// failure).
    Errored,
    /// Torn down without a reply (scheduler shutdown).
    Dropped,
}

impl Outcome {
    pub const ALL: [Outcome; OUTCOME_COUNT] = [
        Outcome::Answered,
        Outcome::Expired,
        Outcome::Cancelled,
        Outcome::Shed,
        Outcome::Errored,
        Outcome::Dropped,
    ];

    pub fn idx(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Outcome::Answered => "answered",
            Outcome::Expired => "expired",
            Outcome::Cancelled => "cancelled",
            Outcome::Shed => "shed",
            Outcome::Errored => "errored",
            Outcome::Dropped => "dropped",
        }
    }
}

/// The per-request span handle.  Owned by exactly one layer at a time
/// (wire → scheduler request → worker), so marking needs no atomics.
#[derive(Debug)]
pub struct Trace {
    pub(crate) reg: Arc<Registry>,
    pub(crate) id: u64,
    pub(crate) start: Instant,
    pub(crate) class: usize,
    pub(crate) method: usize,
    pub(crate) marks: [Option<u64>; STAGE_COUNT],
    pub(crate) adapter: Option<Arc<str>>,
    pub(crate) batch_rows: u32,
    pub(crate) cache_hits: u32,
    pub(crate) cache_misses: u32,
    pub(crate) finished: bool,
}

impl Trace {
    pub(crate) fn new(reg: Arc<Registry>, id: u64) -> Self {
        Trace {
            reg,
            id,
            start: Instant::now(),
            class: 0,
            method: super::METHOD_UNKNOWN,
            marks: [None; STAGE_COUNT],
            adapter: None,
            batch_rows: 0,
            cache_hits: 0,
            cache_misses: 0,
            finished: false,
        }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// The wire representation of the request id (`x-request-id`).
    pub fn id_hex(&self) -> String {
        format!("{:016x}", self.id)
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.reg
    }

    /// Stamp `stage` as completed now.  Offsets are µs since the
    /// trace started; marking in pipeline order keeps them
    /// non-decreasing by construction.
    pub fn mark(&mut self, stage: Stage) {
        let us = self.start.elapsed().as_micros() as u64;
        if let Some(slot) = self.marks.get_mut(stage.idx()) {
            *slot = Some(us);
        }
    }

    /// µs offset of a completed stage, if it ran.
    pub fn mark_us(&self, stage: Stage) -> Option<u64> {
        self.marks.get(stage.idx()).copied().flatten()
    }

    /// Classify by request class index (scheduler order:
    /// interactive=0, batch=1, background=2).
    pub fn set_class(&mut self, class: usize) {
        self.class = class.min(super::CLASS_LABELS.len() - 1);
    }

    /// Classify by adapter method tag (`"cosa"` / `"rosa"` /
    /// `"lora"`); anything else buckets under `"unknown"`.
    pub fn set_method(&mut self, method: &str) {
        self.method = super::METHOD_LABELS
            .iter()
            .position(|m| *m == method)
            .unwrap_or(super::METHOD_UNKNOWN);
    }

    pub fn set_adapter(&mut self, adapter: &Arc<str>) {
        self.adapter = Some(Arc::clone(adapter));
    }

    pub fn set_batch_rows(&mut self, rows: usize) {
        self.batch_rows = rows.min(u32::MAX as usize) as u32;
    }

    /// Accumulate cache-plan results (hits = resident projections,
    /// misses = seed-regenerated ones).
    pub fn add_cache(&mut self, hits: u32, misses: u32) {
        self.cache_hits = self.cache_hits.saturating_add(hits);
        self.cache_misses = self.cache_misses.saturating_add(misses);
    }

    /// Terminate the trace: stamps the `reply` mark and folds the
    /// span set into the registry's per-stage histograms, the slow
    /// ring, and (when slower than `[obs] slow_ms`) a WARN line.
    pub fn finish(mut self, outcome: Outcome) {
        self.mark(Stage::Reply);
        self.finished = true;
        let reg = Arc::clone(&self.reg);
        reg.record(&self, outcome);
    }
}

impl Drop for Trace {
    fn drop(&mut self) {
        if !self.finished {
            self.finished = true;
            self.mark(Stage::Reply);
            let reg = Arc::clone(&self.reg);
            reg.record(self, Outcome::Dropped);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_order_and_names() {
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(s.idx(), i);
        }
        assert_eq!(Stage::Parse.name(), "parse");
        assert_eq!(Stage::BatchAssemble.name(), "batch_assemble");
        assert_eq!(Stage::Reply.name(), "reply");
    }

    #[test]
    fn outcome_indices_cover_all() {
        for (i, o) in Outcome::ALL.iter().enumerate() {
            assert_eq!(o.idx(), i);
        }
        assert_eq!(Outcome::Dropped.name(), "dropped");
    }

    #[test]
    fn marks_are_monotone() {
        let reg = Registry::disabled();
        let mut t = Trace::new(reg, 1);
        t.mark(Stage::Parse);
        t.mark(Stage::Queue);
        t.mark(Stage::Gemm);
        let a = t.mark_us(Stage::Parse).unwrap_or(u64::MAX);
        let b = t.mark_us(Stage::Queue).unwrap_or(0);
        let c = t.mark_us(Stage::Gemm).unwrap_or(0);
        assert!(a <= b && b <= c);
        assert_eq!(t.mark_us(Stage::Pack), None);
        t.finish(Outcome::Answered);
    }

    #[test]
    fn unknown_method_buckets_as_unknown() {
        let reg = Registry::disabled();
        let mut t = Trace::new(reg, 2);
        t.set_method("cosa");
        assert_eq!(t.method, 0);
        t.set_method("svd-of-the-month");
        assert_eq!(t.method, super::super::METHOD_UNKNOWN);
        t.finish(Outcome::Errored);
    }
}
