//! GLUE hyperparameter presets (paper Table 5, App. C.1) encoded as data —
//! regenerated verbatim by `cosa-repro exp table5`.

/// One Table 5 row: (method+model, task, epochs, lr, batch).
#[derive(Clone, Debug)]
pub struct GlueHp {
    pub method: &'static str,
    pub model: &'static str,
    pub task: &'static str,
    pub epochs: usize,
    pub lr: f64,
    pub batch: usize,
    pub alpha: f64,
}

/// The CoSA rows of Table 5 plus the LoRA reference rows (the full table
/// is in the paper; these are the rows our GLUE-sim runs key off).
pub fn table5() -> Vec<GlueHp> {
    let mut rows = Vec::new();
    let tasks = ["SST-2", "MRPC", "CoLA", "QNLI", "RTE", "STS-B"];
    let cosa_base = [(60, 2e-5, 32), (30, 3e-5, 32), (40, 1e-5, 32),
                     (25, 2e-5, 32), (40, 3e-5, 32), (50, 2.5e-5, 32)];
    let cosa_large = [(20, 2e-5, 32), (40, 3e-5, 32), (40, 1e-5, 32),
                      (20, 2e-5, 32), (100, 3e-5, 32), (40, 2e-5, 32)];
    let lora_base = [(10, 1e-4, 32), (10, 4e-4, 32), (30, 4e-4, 32),
                     (25, 3e-4, 32), (50, 4e-4, 32), (30, 4e-4, 16)];
    for (i, t) in tasks.iter().enumerate() {
        let (e, lr, b) = cosa_base[i];
        rows.push(GlueHp { method: "CoSA", model: "base", task: t,
                           epochs: e, lr, batch: b, alpha: 2.0 });
        let (e, lr, b) = cosa_large[i];
        rows.push(GlueHp { method: "CoSA", model: "large", task: t,
                           epochs: e, lr, batch: b, alpha: 1.0 });
        let (e, lr, b) = lora_base[i];
        rows.push(GlueHp { method: "LoRA", model: "base", task: t,
                           epochs: e, lr, batch: b, alpha: 4.0 });
    }
    rows
}

/// Default compression dims from the paper: GLUE (a,b)=(128,56),
/// NLG (a,b)=(1024,256).
pub const GLUE_AB: (usize, usize) = (128, 56);
pub const NLG_AB: (usize, usize) = (1024, 256);

/// Host `linalg` backend hint per model preset, applied when the run
/// config leaves `[compute]` on "auto": (backend, threads).  Tiny
/// presets (d_model=64) stay serial — their products sit far below the
/// parallelism threshold and thread spawn would only add latency; every
/// larger preset uses the packed micro-kernel backend with auto thread
/// count (pin `backend = "tiled"` / `"reference"` to compare).
pub fn compute_hint(preset: &str) -> (&'static str, usize) {
    if preset.starts_with("tiny") {
        ("packed", 1)
    } else {
        ("packed", 0)
    }
}

/// Serving-engine worker hint per model preset, applied when the run
/// config leaves `[serve] workers` at 0-auto: tiny presets serve
/// single-worker (their per-batch products sit below any useful
/// parallelism), everything larger stays 0 so `serve::Server` resolves
/// the count at spawn time via the shared `plan_threads` cap.
pub fn serve_hint(preset: &str) -> usize {
    if preset.starts_with("tiny") {
        1
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_covers_all_tasks_for_cosa() {
        let rows = table5();
        let cosa_base: Vec<_> = rows.iter()
            .filter(|r| r.method == "CoSA" && r.model == "base").collect();
        assert_eq!(cosa_base.len(), 6);
        // spot-check against the paper
        let mrpc = cosa_base.iter().find(|r| r.task == "MRPC").unwrap();
        assert_eq!(mrpc.epochs, 30);
        assert_eq!(mrpc.lr, 3e-5);
    }

    #[test]
    fn paper_default_dims() {
        assert_eq!(GLUE_AB, (128, 56));
        assert_eq!(NLG_AB, (1024, 256));
    }
}
