//! Config system: run configs (TOML) + the preset registry mirrored from
//! `python/compile/presets.py` via `artifacts/manifest.json`.
//!
//! A run is fully described by (artifact entry, task, train hyperparams,
//! seeds).  `RunConfig::from_toml` loads a config file; every field has a
//! sensible default so tiny configs stay tiny (see `configs/`).

pub mod presets;

use crate::util::toml::TomlDoc;

/// Learning-rate schedule selector (rust-side; the artifact takes lr as a
/// scalar input every step).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    Constant,
    /// Linear warmup then linear decay to zero (GLUE setup, App. C.1).
    LinearWarmup { warmup_frac: f64 },
    /// Linear warmup then cosine decay (NLG setup, App. C.2).
    CosineWarmup { warmup_frac: f64 },
}

/// Training hyperparameters owned by the rust coordinator.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f64,
    pub weight_decay: f64,
    pub clip_norm: f64,
    pub schedule: Schedule,
    pub eval_every: usize,
    pub log_every: usize,
    /// Logical batch = device batch × grad_accum (batcher groups chunks).
    pub grad_accum: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            lr: 2e-3,
            weight_decay: 0.01,
            clip_norm: 1.0,
            schedule: Schedule::CosineWarmup { warmup_frac: 0.03 },
            eval_every: 50,
            log_every: 10,
            grad_accum: 1,
        }
    }
}

/// Host `linalg` backend selection, mirrored into
/// `linalg::configure` by the trainer (TOML table `[compute]`; the
/// `COSA_BACKEND` / `COSA_THREADS` env vars override everything).
#[derive(Clone, Debug, PartialEq)]
pub struct ComputeConfig {
    /// "auto" | "reference" | "tiled" | "packed".
    pub backend: String,
    /// Worker threads for the tiled/packed backends; 0 = auto.
    pub threads: usize,
}

impl Default for ComputeConfig {
    fn default() -> Self {
        ComputeConfig { backend: "auto".into(), threads: 0 }
    }
}

impl ComputeConfig {
    /// Fill unset fields ("auto" / 0) from the preset's hint
    /// (`presets::compute_hint`).
    pub fn resolved(&self, preset: &str) -> ComputeConfig {
        let (hint_backend, hint_threads) = presets::compute_hint(preset);
        ComputeConfig {
            backend: if self.backend == "auto" {
                hint_backend.to_string()
            } else {
                self.backend.clone()
            },
            threads: if self.threads == 0 {
                hint_threads
            } else {
                self.threads
            },
        }
    }
}

/// Shared env-override reader: parse `key` if set, warn and fall back
/// on garbage — the one warn-and-fallback behavior every
/// `env_overridden()` (`COSA_SERVE_*` / `COSA_WIRE_*` /
/// `COSA_MODEL_*`) shares.
fn env_num<T: std::str::FromStr>(key: &str, fallback: T) -> T {
    match std::env::var(key) {
        Ok(s) => match s.parse::<T>() {
            Ok(v) => v,
            Err(_) => {
                eprintln!(
                    "warning: ignoring {key}=`{s}` (not a valid value)"
                );
                fallback
            }
        },
        Err(_) => fallback,
    }
}

/// WFQ weights for the scheduler's three QoS classes (TOML table
/// `[serve.classes]`).  A class with weight `w` boards up to `w` rows
/// per deficit-round-robin rotation while backlogged, so relative
/// weights are relative shares of fused-batch slots under load; every
/// weight must be >= 1 (0 would stall a class's queue forever).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClassWeights {
    pub interactive: u64,
    pub batch: u64,
    pub background: u64,
}

impl Default for ClassWeights {
    fn default() -> Self {
        ClassWeights { interactive: 8, batch: 4, background: 1 }
    }
}

/// Multi-adapter serving engine knobs (TOML table `[serve]`; the
/// `COSA_SERVE_*` env vars override via [`ServeConfig::env_overridden`]).
/// Consumed by `serve::Server` and the `serve-bench` CLI subcommand.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Byte budget for the regenerated-projection LRU, in MiB.
    pub cache_mb: f64,
    /// Max rows per fused batch before a flush.
    pub max_batch: usize,
    /// Max time a partial batch waits before flushing, in microseconds.
    pub max_wait_us: u64,
    /// Worker threads; 0 = auto (same cap as the compute backends).
    pub workers: usize,
    /// Warm pre-loading: every checkpoint in this directory is loaded
    /// into the served `AdaptedModel` at gateway startup (empty =
    /// disabled).  The same directory is the default for the wire
    /// `/v1/adapters/{name}/load` endpoint.
    pub preload_dir: String,
    /// Cross-adapter fused batching: rows for different adapters ride
    /// one grouped block-diagonal dispatch.  `false` computes each
    /// adapter segment independently (the pre-fusion per-adapter path,
    /// kept as the serving-tail bench baseline).
    pub fused: bool,
    /// Per-class WFQ weights (see [`ClassWeights`]).
    pub classes: ClassWeights,
    /// Storage codec for cache-resident regenerated projections:
    /// `"f32"` (bit-identical default), `"bf16"` (half the bytes,
    /// ~1e-2 relative error), or `"int8"` (quarter the bytes plus
    /// per-row scales, ~1e-1 worst-case relative error).  See
    /// `linalg::QuantKind` and the README's "Quantized cache" section.
    pub cache_quant: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            cache_mb: 64.0,
            max_batch: 16,
            max_wait_us: 200,
            workers: 0,
            preload_dir: String::new(),
            fused: true,
            classes: ClassWeights::default(),
            cache_quant: "f32".into(),
        }
    }
}

impl ServeConfig {
    /// The projection-LRU budget in bytes (`cache_mb` is MiB).  The
    /// one conversion every consumer shares — callers must not
    /// hand-roll it, or rounding/clamping will diverge.
    pub fn cache_budget_bytes(&self) -> usize {
        (self.cache_mb.max(0.0) * (1 << 20) as f64) as usize
    }

    /// The parsed cache codec (`cache_quant` is the raw TOML/env
    /// string; the TOML loader and `env_overridden` both validate, so
    /// consumers normally cannot see this fail).
    pub fn cache_quant_kind(&self) -> anyhow::Result<crate::linalg::QuantKind> {
        crate::linalg::QuantKind::parse(&self.cache_quant)
    }

    /// Apply the `COSA_SERVE_*` env overrides (read fresh on every call
    /// so long-lived processes can be steered per-invocation):
    /// `COSA_SERVE_CACHE_MB`, `COSA_SERVE_MAX_BATCH`,
    /// `COSA_SERVE_MAX_WAIT_US`, `COSA_SERVE_WORKERS`,
    /// `COSA_SERVE_PRELOAD_DIR`, `COSA_SERVE_CACHE_QUANT`,
    /// `COSA_SERVE_FUSED`, and the class
    /// weights `COSA_SERVE_CLASS_INTERACTIVE` /
    /// `COSA_SERVE_CLASS_BATCH` / `COSA_SERVE_CLASS_BACKGROUND`.
    /// Unparseable values warn and fall back to the config value,
    /// mirroring the `COSA_BACKEND` / `COSA_THREADS` behavior.
    pub fn env_overridden(&self) -> ServeConfig {
        let mut out = self.clone();
        out.cache_mb = env_num("COSA_SERVE_CACHE_MB", out.cache_mb);
        out.max_batch = env_num("COSA_SERVE_MAX_BATCH", out.max_batch);
        out.max_wait_us = env_num("COSA_SERVE_MAX_WAIT_US", out.max_wait_us);
        out.workers = env_num("COSA_SERVE_WORKERS", out.workers);
        if let Ok(dir) = std::env::var("COSA_SERVE_PRELOAD_DIR") {
            out.preload_dir = dir;
        }
        if let Ok(q) = std::env::var("COSA_SERVE_CACHE_QUANT") {
            match crate::linalg::QuantKind::parse(&q) {
                Ok(_) => out.cache_quant = q,
                Err(e) => eprintln!(
                    "warning: COSA_SERVE_CACHE_QUANT: {e}; using `{}`",
                    out.cache_quant
                ),
            }
        }
        out.fused = env_num("COSA_SERVE_FUSED", out.fused);
        let cw = &mut out.classes;
        cw.interactive =
            env_num("COSA_SERVE_CLASS_INTERACTIVE", cw.interactive);
        cw.batch = env_num("COSA_SERVE_CLASS_BATCH", cw.batch);
        cw.background =
            env_num("COSA_SERVE_CLASS_BACKGROUND", cw.background);
        for (name, w) in [
            ("COSA_SERVE_CLASS_INTERACTIVE", &mut cw.interactive),
            ("COSA_SERVE_CLASS_BATCH", &mut cw.batch),
            ("COSA_SERVE_CLASS_BACKGROUND", &mut cw.background),
        ] {
            if *w == 0 {
                eprintln!(
                    "warning: {name}=0 would stall the class; using 1"
                );
                *w = 1;
            }
        }
        if out.max_batch == 0 {
            eprintln!("warning: COSA_SERVE_MAX_BATCH=0 is invalid; using 1");
            out.max_batch = 1;
        }
        if out.cache_mb.is_nan() || out.cache_mb < 0.0 {
            // Mirror the TOML path's `cache_mb >= 0` validation instead
            // of letting a negative or NaN value silently zero the
            // cache (parsing "NaN" as f64 succeeds, so a plain `< 0.0`
            // test alone would let it through).
            eprintln!(
                "warning: COSA_SERVE_CACHE_MB={} is not a valid budget; \
                 using {}",
                out.cache_mb, self.cache_mb
            );
            out.cache_mb = self.cache_mb;
        }
        out
    }

    /// Fill auto fields from the preset's hint (`presets::serve_hint`),
    /// mirroring [`ComputeConfig::resolved`].  For deployments that
    /// serve a *model preset's own site* — `serve-bench` deliberately
    /// does not call this, because its synthetic site has nothing to do
    /// with any preset's model size (see `cmd_serve_bench`).
    pub fn resolved(&self, preset: &str) -> ServeConfig {
        let hint_workers = presets::serve_hint(preset);
        ServeConfig {
            workers: if self.workers == 0 {
                hint_workers
            } else {
                self.workers
            },
            ..self.clone()
        }
    }
}

/// Network gateway knobs (TOML table `[wire]`; the `COSA_WIRE_*` env
/// vars override via [`WireConfig::env_overridden`]).  Consumed by
/// `wire::Gateway` (the HTTP/1.1 + JSON front-end over the serve
/// scheduler) and the `serve` / `serve-bench --wire` CLI subcommands.
#[derive(Clone, Debug, PartialEq)]
pub struct WireConfig {
    /// Bind address (the listener binds `host:port`).
    pub host: String,
    /// Bind port; 0 = ephemeral (the gateway reports the bound port).
    pub port: u16,
    /// HTTP worker threads draining the accept queue; 0 = auto.
    pub http_workers: usize,
    /// Largest accepted request body; beyond it the request is
    /// answered 413 without reading the remainder.
    pub max_body_bytes: usize,
    /// Socket read timeout per request, in milliseconds (0 = none).
    pub read_timeout_ms: u64,
    /// Socket write timeout per response, in milliseconds (0 = none).
    pub write_timeout_ms: u64,
    /// Honor `keep-alive` (false closes every connection after one
    /// exchange).
    pub keep_alive: bool,
    /// Accepted-connection queue bound; overflow is answered 503 and
    /// closed without occupying a worker.
    pub max_pending_conns: usize,
    /// Admission control: shed forwards with 429 once the scheduler
    /// queue depth reaches this watermark (0 = disabled).
    pub shed_queue_depth: usize,
    /// Admission control: shed forwards with 429 while the projection
    /// LRU evicts faster than this per second (0 = disabled).
    pub shed_evictions_per_s: f64,
    /// `Retry-After` seconds attached to 429 sheds.
    pub retry_after_s: u64,
    /// Default per-request deadline for `/v1/forward` bodies that do
    /// not carry `deadline_ms` (0 = no deadline).
    pub deadline_ms: u64,
}

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig {
            host: "127.0.0.1".into(),
            port: 7080,
            http_workers: 0,
            max_body_bytes: 8 << 20,
            read_timeout_ms: 5_000,
            write_timeout_ms: 5_000,
            keep_alive: true,
            max_pending_conns: 64,
            shed_queue_depth: 1024,
            shed_evictions_per_s: 0.0,
            retry_after_s: 1,
            deadline_ms: 0,
        }
    }
}

impl WireConfig {
    /// Apply the `COSA_WIRE_*` env overrides (read fresh per call,
    /// mirroring `COSA_SERVE_*`): `COSA_WIRE_HOST`, `COSA_WIRE_PORT`,
    /// `COSA_WIRE_HTTP_WORKERS`, `COSA_WIRE_MAX_BODY_BYTES`,
    /// `COSA_WIRE_READ_TIMEOUT_MS`, `COSA_WIRE_WRITE_TIMEOUT_MS`,
    /// `COSA_WIRE_KEEP_ALIVE`, `COSA_WIRE_MAX_PENDING_CONNS`,
    /// `COSA_WIRE_SHED_QUEUE_DEPTH`, `COSA_WIRE_SHED_EVICTIONS_PER_S`,
    /// `COSA_WIRE_RETRY_AFTER_S`, `COSA_WIRE_DEADLINE_MS`.
    /// Unparseable values warn and fall back.
    pub fn env_overridden(&self) -> WireConfig {
        let mut out = self.clone();
        if let Ok(h) = std::env::var("COSA_WIRE_HOST") {
            out.host = h;
        }
        out.port = env_num("COSA_WIRE_PORT", out.port);
        out.http_workers =
            env_num("COSA_WIRE_HTTP_WORKERS", out.http_workers);
        out.max_body_bytes =
            env_num("COSA_WIRE_MAX_BODY_BYTES", out.max_body_bytes);
        out.read_timeout_ms =
            env_num("COSA_WIRE_READ_TIMEOUT_MS", out.read_timeout_ms);
        out.write_timeout_ms =
            env_num("COSA_WIRE_WRITE_TIMEOUT_MS", out.write_timeout_ms);
        out.keep_alive = env_num("COSA_WIRE_KEEP_ALIVE", out.keep_alive);
        out.max_pending_conns =
            env_num("COSA_WIRE_MAX_PENDING_CONNS", out.max_pending_conns);
        out.shed_queue_depth =
            env_num("COSA_WIRE_SHED_QUEUE_DEPTH", out.shed_queue_depth);
        out.shed_evictions_per_s = env_num(
            "COSA_WIRE_SHED_EVICTIONS_PER_S",
            out.shed_evictions_per_s,
        );
        out.retry_after_s =
            env_num("COSA_WIRE_RETRY_AFTER_S", out.retry_after_s);
        out.deadline_ms = env_num("COSA_WIRE_DEADLINE_MS", out.deadline_ms);
        if out.max_body_bytes == 0 {
            eprintln!(
                "warning: COSA_WIRE_MAX_BODY_BYTES=0 is invalid; using {}",
                self.max_body_bytes
            );
            out.max_body_bytes = self.max_body_bytes;
        }
        if out.max_pending_conns == 0 {
            eprintln!(
                "warning: COSA_WIRE_MAX_PENDING_CONNS=0 is invalid; \
                 using {}",
                self.max_pending_conns
            );
            out.max_pending_conns = self.max_pending_conns;
        }
        if out.shed_evictions_per_s.is_nan() || out.shed_evictions_per_s < 0.0
        {
            eprintln!(
                "warning: COSA_WIRE_SHED_EVICTIONS_PER_S={} is not a \
                 valid rate; using {}",
                out.shed_evictions_per_s, self.shed_evictions_per_s
            );
            out.shed_evictions_per_s = self.shed_evictions_per_s;
        }
        out
    }
}

/// Adapted-model shape knobs (TOML table `[model]`; the `COSA_MODEL_*`
/// env vars override via [`ModelConfig::env_overridden`]).  Describes
/// the [`model::ModelSpec`](crate::model::ModelSpec) multi-site serving
/// and benching build: either the synthetic preset (`sites = N` plus
/// per-site dims) or an explicit `sites_spec` list of
/// `"name:MxN:AxB"` strings (which wins when non-empty — that is also
/// where per-site heterogeneous core dims are expressed directly).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Synthetic preset: number of sites.
    pub sites: usize,
    /// Synthetic preset: every site's adapted-weight dims.
    pub site_m: usize,
    pub site_n: usize,
    /// Synthetic preset: base core dims (odd sites get half — see
    /// `ModelSpec::synthetic`).
    pub core_a: usize,
    pub core_b: usize,
    /// Explicit site list (`"name:MxN:AxB"` each); overrides the
    /// synthetic preset when non-empty.
    pub sites_spec: Vec<String>,
    /// Adapter method synthetic/preset adapters are built with:
    /// `"cosa"` (default), `"rosa"`, or `"lora"` — the servable
    /// subset of [`adapters::Method`](crate::adapters::Method).
    pub method: String,
}

impl Default for ModelConfig {
    fn default() -> Self {
        // The serving_model acceptance scenario's shape (24
        // heterogeneous sites of 96x96 with 16x12 base cores).
        ModelConfig {
            sites: 24,
            site_m: 96,
            site_n: 96,
            core_a: 16,
            core_b: 12,
            sites_spec: Vec::new(),
            method: "cosa".to_string(),
        }
    }
}

impl ModelConfig {
    /// Apply the `COSA_MODEL_*` env overrides (read fresh per call,
    /// mirroring `COSA_SERVE_*`): `COSA_MODEL_SITES`,
    /// `COSA_MODEL_SITE_M`, `COSA_MODEL_SITE_N`, `COSA_MODEL_CORE_A`,
    /// `COSA_MODEL_CORE_B`, `COSA_MODEL_METHOD`, and
    /// `COSA_MODEL_SITES_SPEC` (comma-separated `name:MxN:AxB`
    /// entries).  Unparseable values warn and fall back.
    pub fn env_overridden(&self) -> ModelConfig {
        let mut out = self.clone();
        out.sites = env_num("COSA_MODEL_SITES", out.sites);
        out.site_m = env_num("COSA_MODEL_SITE_M", out.site_m);
        out.site_n = env_num("COSA_MODEL_SITE_N", out.site_n);
        out.core_a = env_num("COSA_MODEL_CORE_A", out.core_a);
        out.core_b = env_num("COSA_MODEL_CORE_B", out.core_b);
        if let Ok(s) = std::env::var("COSA_MODEL_METHOD") {
            out.method = s.trim().to_ascii_lowercase();
        }
        if let Ok(s) = std::env::var("COSA_MODEL_SITES_SPEC") {
            out.sites_spec = s
                .split(',')
                .map(|p| p.trim().to_string())
                .filter(|p| !p.is_empty())
                .collect();
        }
        out
    }

    /// Resolve the `[model] method` knob to a servable
    /// [`Method`](crate::adapters::Method) — one of
    /// [`SERVABLE_METHODS`](crate::adapters::SERVABLE_METHODS); other
    /// method tags (trainable baselines like `dora`) are rejected
    /// here because the serving engine cannot decode them.
    pub fn to_method(&self) -> anyhow::Result<crate::adapters::Method> {
        let m = crate::adapters::Method::from_str(&self.method)
            .map_err(|e| anyhow::anyhow!("model.method: {e:#}"))?;
        anyhow::ensure!(
            crate::adapters::SERVABLE_METHODS.contains(&m),
            "model.method `{}` is not servable (expected one of: \
             cosa, rosa, lora)",
            self.method
        );
        Ok(m)
    }

    /// Build the [`ModelSpec`](crate::model::ModelSpec) this config
    /// describes: the explicit `sites_spec` list when non-empty, else
    /// the synthetic preset.
    pub fn to_spec(
        &self,
        name: &str,
    ) -> anyhow::Result<crate::model::ModelSpec> {
        use crate::model::{ModelSpec, SiteShape};
        if !self.sites_spec.is_empty() {
            return ModelSpec::from_site_list(name, &self.sites_spec);
        }
        anyhow::ensure!(
            self.sites >= 1,
            "model.sites must be >= 1 (got {})",
            self.sites
        );
        anyhow::ensure!(
            self.site_m >= 1 && self.site_n >= 1
                && self.core_a >= 1 && self.core_b >= 1,
            "model dims must be >= 1 (site {}x{}, core {}x{})",
            self.site_m,
            self.site_n,
            self.core_a,
            self.core_b
        );
        let spec = ModelSpec::synthetic(
            self.sites,
            SiteShape { m: self.site_m, n: self.site_n },
            self.core_a,
            self.core_b,
        );
        // give the spec the caller's name (synthetic() labels it by
        // site count, which is right for benches but not for configs)
        Ok(ModelSpec { name: name.to_string(), ..spec })
    }
}

/// Telemetry knobs (TOML table `[obs]`; the `COSA_OBS_*` env vars
/// override via [`ObsConfig::env_overridden`]).  Consumed by
/// `obs::Registry` — per-request stage tracing, the `/metrics`
/// exposition, and the `/v1/debug/slow` slow-request ring.
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// Master switch for request tracing.  `false` keeps `/metrics`
    /// serving the aggregate counters but stops per-request spans and
    /// slow-trace capture (one branch per request of overhead).
    pub enabled: bool,
    /// WARN + slow-ring threshold: a request whose end-to-end latency
    /// reaches this many milliseconds is logged with its full stage
    /// breakdown.
    pub slow_ms: u64,
    /// Capacity of the slowest-requests ring behind
    /// `GET /v1/debug/slow` (0 disables capture).
    pub slow_ring: usize,
    /// Most-recent-traces ring capacity (healthy-request exemplars;
    /// 0 disables).
    pub exemplars: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            enabled: true,
            slow_ms: 500,
            slow_ring: 32,
            exemplars: 8,
        }
    }
}

impl ObsConfig {
    /// Apply the `COSA_OBS_*` env overrides (read fresh per call,
    /// mirroring `COSA_SERVE_*`): `COSA_OBS_ENABLED`,
    /// `COSA_OBS_SLOW_MS`, `COSA_OBS_SLOW_RING`, `COSA_OBS_EXEMPLARS`.
    /// Unparseable values warn and fall back.
    pub fn env_overridden(&self) -> ObsConfig {
        let mut out = self.clone();
        out.enabled = env_num("COSA_OBS_ENABLED", out.enabled);
        out.slow_ms = env_num("COSA_OBS_SLOW_MS", out.slow_ms);
        out.slow_ring = env_num("COSA_OBS_SLOW_RING", out.slow_ring);
        out.exemplars = env_num("COSA_OBS_EXEMPLARS", out.exemplars);
        if out.slow_ms == 0 {
            eprintln!(
                "warning: COSA_OBS_SLOW_MS=0 would flag every request \
                 as slow; using {}",
                self.slow_ms
            );
            out.slow_ms = self.slow_ms;
        }
        out
    }
}

/// A full run description.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub name: String,
    /// Artifact entry name, e.g. "small-lm_cosa" (kind suffix added by the
    /// trainer: `_train` / `_eval`).
    pub artifact: String,
    /// Task id from `data::tasks` (e.g. "math", "code", "nlu:mrpc-sim").
    pub task: String,
    pub train: TrainConfig,
    pub compute: ComputeConfig,
    pub serve: ServeConfig,
    pub wire: WireConfig,
    pub model: ModelConfig,
    pub obs: ObsConfig,
    pub base_seed: u64,
    pub adapter_seed: u64,
    pub data_seed: u64,
    pub out_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            name: "run".into(),
            artifact: "tiny-lm_cosa".into(),
            task: "math".into(),
            train: TrainConfig::default(),
            compute: ComputeConfig::default(),
            serve: ServeConfig::default(),
            wire: WireConfig::default(),
            model: ModelConfig::default(),
            obs: ObsConfig::default(),
            base_seed: 42,
            adapter_seed: 1234,
            data_seed: 7,
            out_dir: "runs".into(),
        }
    }
}

impl RunConfig {
    pub fn from_toml(src: &str) -> anyhow::Result<RunConfig> {
        let doc = TomlDoc::parse(src)?;
        let mut cfg = RunConfig::default();
        cfg.name = doc.str_or("name", &cfg.name);
        cfg.artifact = doc.str_or("artifact", &cfg.artifact);
        cfg.task = doc.str_or("task", &cfg.task);
        cfg.base_seed = doc.i64_or("seeds.base", cfg.base_seed as i64) as u64;
        cfg.adapter_seed =
            doc.i64_or("seeds.adapter", cfg.adapter_seed as i64) as u64;
        cfg.data_seed = doc.i64_or("seeds.data", cfg.data_seed as i64) as u64;
        cfg.out_dir = doc.str_or("out_dir", &cfg.out_dir);

        let t = &mut cfg.train;
        t.steps = doc.i64_or("train.steps", t.steps as i64) as usize;
        t.lr = doc.f64_or("train.lr", t.lr);
        t.weight_decay = doc.f64_or("train.weight_decay", t.weight_decay);
        t.clip_norm = doc.f64_or("train.clip_norm", t.clip_norm);
        t.eval_every =
            doc.i64_or("train.eval_every", t.eval_every as i64) as usize;
        t.log_every =
            doc.i64_or("train.log_every", t.log_every as i64) as usize;
        t.grad_accum =
            doc.i64_or("train.grad_accum", t.grad_accum as i64) as usize;
        let warmup = doc.f64_or("train.warmup_frac", 0.03);
        t.schedule = match doc.str_or("train.schedule", "cosine").as_str() {
            "constant" => Schedule::Constant,
            "linear" => Schedule::LinearWarmup { warmup_frac: warmup },
            "cosine" => Schedule::CosineWarmup { warmup_frac: warmup },
            other => anyhow::bail!("unknown schedule `{other}`"),
        };

        let c = &mut cfg.compute;
        c.backend = doc.str_or("compute.backend", &c.backend);
        crate::linalg::Kind::parse(&c.backend)?; // fail fast on typos
        let threads = doc.i64_or("compute.threads", c.threads as i64);
        anyhow::ensure!(threads >= 0,
                        "compute.threads must be >= 0 (got {threads}; \
                         use 0 for auto)");
        c.threads = threads as usize;

        let s = &mut cfg.serve;
        s.cache_mb = doc.f64_or("serve.cache_mb", s.cache_mb);
        anyhow::ensure!(s.cache_mb >= 0.0,
                        "serve.cache_mb must be >= 0 (got {})", s.cache_mb);
        let max_batch = doc.i64_or("serve.max_batch", s.max_batch as i64);
        anyhow::ensure!(max_batch >= 1,
                        "serve.max_batch must be >= 1 (got {max_batch})");
        s.max_batch = max_batch as usize;
        let max_wait = doc.i64_or("serve.max_wait_us", s.max_wait_us as i64);
        anyhow::ensure!(max_wait >= 0,
                        "serve.max_wait_us must be >= 0 (got {max_wait})");
        s.max_wait_us = max_wait as u64;
        let workers = doc.i64_or("serve.workers", s.workers as i64);
        anyhow::ensure!(workers >= 0,
                        "serve.workers must be >= 0 (got {workers}; \
                         use 0 for auto)");
        s.workers = workers as usize;
        s.preload_dir = doc.str_or("serve.preload_dir", &s.preload_dir);
        s.fused = doc.bool_or("serve.fused", s.fused);
        s.cache_quant = doc.str_or("serve.cache_quant", &s.cache_quant);
        crate::linalg::QuantKind::parse(&s.cache_quant)?; // fail fast on typos
        for (key, field) in [
            ("serve.classes.interactive", &mut s.classes.interactive),
            ("serve.classes.batch", &mut s.classes.batch),
            ("serve.classes.background", &mut s.classes.background),
        ] {
            let v = doc.i64_or(key, *field as i64);
            anyhow::ensure!(
                v >= 1,
                "{key} must be >= 1 (got {v}; a zero weight would stall \
                 the class)"
            );
            *field = v as u64;
        }

        let w = &mut cfg.wire;
        w.host = doc.str_or("wire.host", &w.host);
        let port = doc.i64_or("wire.port", w.port as i64);
        anyhow::ensure!((0..=u16::MAX as i64).contains(&port),
                        "wire.port must be in 0..=65535 (got {port}; \
                         use 0 for ephemeral)");
        w.port = port as u16;
        for (key, field, min) in [
            ("wire.http_workers", &mut w.http_workers, 0i64),
            ("wire.max_body_bytes", &mut w.max_body_bytes, 1),
            ("wire.max_pending_conns", &mut w.max_pending_conns, 1),
            ("wire.shed_queue_depth", &mut w.shed_queue_depth, 0),
        ] {
            let v = doc.i64_or(key, *field as i64);
            anyhow::ensure!(v >= min, "{key} must be >= {min} (got {v})");
            *field = v as usize;
        }
        for (key, field) in [
            ("wire.read_timeout_ms", &mut w.read_timeout_ms),
            ("wire.write_timeout_ms", &mut w.write_timeout_ms),
            ("wire.retry_after_s", &mut w.retry_after_s),
            ("wire.deadline_ms", &mut w.deadline_ms),
        ] {
            let v = doc.i64_or(key, *field as i64);
            anyhow::ensure!(v >= 0, "{key} must be >= 0 (got {v})");
            *field = v as u64;
        }
        w.keep_alive = doc.bool_or("wire.keep_alive", w.keep_alive);
        w.shed_evictions_per_s =
            doc.f64_or("wire.shed_evictions_per_s", w.shed_evictions_per_s);
        anyhow::ensure!(
            w.shed_evictions_per_s >= 0.0,
            "wire.shed_evictions_per_s must be >= 0 (got {}; use 0 to \
             disable)",
            w.shed_evictions_per_s
        );

        let m = &mut cfg.model;
        for (key, field) in [
            ("model.sites", &mut m.sites),
            ("model.site_m", &mut m.site_m),
            ("model.site_n", &mut m.site_n),
            ("model.core_a", &mut m.core_a),
            ("model.core_b", &mut m.core_b),
        ] {
            let v = doc.i64_or(key, *field as i64);
            anyhow::ensure!(v >= 1, "{key} must be >= 1 (got {v})");
            *field = v as usize;
        }
        if let Some(val) = doc.get("model.sites_spec") {
            let crate::util::toml::TomlValue::Arr(items) = val else {
                anyhow::bail!("model.sites_spec must be an array of \
                               \"name:MxN:AxB\" strings");
            };
            m.sites_spec = items
                .iter()
                .map(|v| {
                    v.as_str().map(str::to_string).ok_or_else(|| {
                        anyhow::anyhow!(
                            "model.sites_spec entries must be strings"
                        )
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
        }
        m.method = doc.str_or("model.method", &m.method);

        let o = &mut cfg.obs;
        o.enabled = doc.bool_or("obs.enabled", o.enabled);
        let slow_ms = doc.i64_or("obs.slow_ms", o.slow_ms as i64);
        anyhow::ensure!(
            slow_ms >= 1,
            "obs.slow_ms must be >= 1 (got {slow_ms}; disable tracing \
             with obs.enabled = false instead)"
        );
        o.slow_ms = slow_ms as u64;
        for (key, field) in [
            ("obs.slow_ring", &mut o.slow_ring),
            ("obs.exemplars", &mut o.exemplars),
        ] {
            let v = doc.i64_or(key, *field as i64);
            anyhow::ensure!(
                (0..=65536).contains(&v),
                "{key} must be in 0..=65536 (got {v})"
            );
            *field = v as usize;
        }
        // Fail fast on unbuildable model tables (bad site-spec syntax,
        // duplicate site names, unservable method) instead of at
        // first use.
        cfg.model.to_spec(&cfg.name)?;
        cfg.model.to_method()?;
        Ok(cfg)
    }

    pub fn load(path: &str) -> anyhow::Result<RunConfig> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        Self::from_toml(&src)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests that set `COSA_MODEL_*` vars serialize on this lock —
    /// env vars are process-global, and the model env tests read each
    /// other's vars through `env_overridden()`.
    static MODEL_ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn defaults_fill_missing_fields() {
        let cfg = RunConfig::from_toml("artifact = \"small-lm_cosa\"").unwrap();
        assert_eq!(cfg.artifact, "small-lm_cosa");
        assert_eq!(cfg.train.steps, 200);
        assert_eq!(cfg.train.weight_decay, 0.01);
    }

    #[test]
    fn full_config_parses() {
        let cfg = RunConfig::from_toml(
            r#"
name = "e2e-math"
artifact = "e2e-lm_cosa"
task = "math"
out_dir = "runs/e2e"
[train]
steps = 300
lr = 1e-3
schedule = "cosine"
warmup_frac = 0.1
clip_norm = 0.5
[seeds]
base = 1
adapter = 2
data = 3
"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "e2e-math");
        assert_eq!(cfg.train.steps, 300);
        assert_eq!(cfg.train.clip_norm, 0.5);
        assert_eq!(cfg.train.schedule,
                   Schedule::CosineWarmup { warmup_frac: 0.1 });
        assert_eq!((cfg.base_seed, cfg.adapter_seed, cfg.data_seed), (1, 2, 3));
    }

    #[test]
    fn bad_schedule_rejected() {
        assert!(RunConfig::from_toml("[train]\nschedule = \"step\"").is_err());
    }

    #[test]
    fn compute_table_parses_and_validates() {
        let cfg = RunConfig::from_toml(
            "[compute]\nbackend = \"tiled\"\nthreads = 4",
        )
        .unwrap();
        assert_eq!(cfg.compute.backend, "tiled");
        assert_eq!(cfg.compute.threads, 4);
        assert!(RunConfig::from_toml("[compute]\nbackend = \"gpu\"").is_err());
        assert!(RunConfig::from_toml("[compute]\nthreads = -1").is_err());
        // defaults stay "auto"/0
        let d = RunConfig::from_toml("").unwrap();
        assert_eq!(d.compute, ComputeConfig::default());
    }

    #[test]
    fn serve_table_parses_and_validates() {
        let cfg = RunConfig::from_toml(
            "[serve]\ncache_mb = 16.0\nmax_batch = 8\nmax_wait_us = 500\n\
             workers = 3",
        )
        .unwrap();
        assert_eq!(cfg.serve.cache_mb, 16.0);
        assert_eq!(cfg.serve.max_batch, 8);
        assert_eq!(cfg.serve.max_wait_us, 500);
        assert_eq!(cfg.serve.workers, 3);
        assert!(RunConfig::from_toml("[serve]\nmax_batch = 0").is_err());
        assert!(RunConfig::from_toml("[serve]\nworkers = -1").is_err());
        assert!(RunConfig::from_toml("[serve]\ncache_mb = -2.0").is_err());
        // cache codec: aliases accepted, typos fail fast
        let q = RunConfig::from_toml("[serve]\ncache_quant = \"bf16\"")
            .unwrap();
        assert_eq!(q.serve.cache_quant, "bf16");
        assert_eq!(q.serve.cache_quant_kind().unwrap(),
                   crate::linalg::QuantKind::Bf16);
        assert!(RunConfig::from_toml("[serve]\ncache_quant = \"fp8\"")
            .is_err());
        // defaults when the table is absent
        let d = RunConfig::from_toml("").unwrap();
        assert_eq!(d.serve, ServeConfig::default());
    }

    #[test]
    fn serve_fused_and_class_weights_parse_and_validate() {
        let cfg = RunConfig::from_toml(
            "[serve]\nfused = false\n[serve.classes]\ninteractive = 10\n\
             batch = 5\nbackground = 2",
        )
        .unwrap();
        assert!(!cfg.serve.fused);
        assert_eq!(
            cfg.serve.classes,
            ClassWeights { interactive: 10, batch: 5, background: 2 }
        );
        // zero/negative weights would stall a class — rejected
        assert!(RunConfig::from_toml(
            "[serve.classes]\nbackground = 0").is_err());
        assert!(RunConfig::from_toml(
            "[serve.classes]\ninteractive = -2").is_err());
        // defaults when absent: fused on, 8/4/1 weights
        let d = RunConfig::from_toml("").unwrap();
        assert!(d.serve.fused);
        assert_eq!(d.serve.classes, ClassWeights::default());
    }

    #[test]
    fn serve_env_overrides_win_and_warn_on_garbage() {
        // Unique var values so a parallel test reading the same keys is
        // the only possible interference (none does today — this is the
        // only test that mutates COSA_SERVE_*, so the full-equality
        // check at the end cannot race another test's vars).
        std::env::set_var("COSA_SERVE_MAX_BATCH", "9");
        std::env::set_var("COSA_SERVE_MAX_WAIT_US", "not-a-number");
        std::env::set_var("COSA_SERVE_CACHE_MB", "-3.0");
        std::env::set_var("COSA_SERVE_PRELOAD_DIR", "env/dir");
        std::env::set_var("COSA_SERVE_FUSED", "false");
        std::env::set_var("COSA_SERVE_CLASS_BATCH", "6");
        std::env::set_var("COSA_SERVE_CLASS_BACKGROUND", "0");
        std::env::set_var("COSA_SERVE_CACHE_QUANT", "int8");
        let cfg = ServeConfig::default().env_overridden();
        assert_eq!(cfg.cache_quant, "int8", "cache codec env wins");
        std::env::set_var("COSA_SERVE_CACHE_QUANT", "fp8");
        assert_eq!(ServeConfig::default().env_overridden().cache_quant,
                   "f32", "unknown codec warns and falls back");
        assert_eq!(cfg.max_batch, 9, "env wins over the default");
        assert_eq!(cfg.max_wait_us, ServeConfig::default().max_wait_us,
                   "garbage env value falls back");
        assert_eq!(cfg.cache_mb, ServeConfig::default().cache_mb,
                   "negative cache budget falls back like the TOML path");
        assert_eq!(cfg.preload_dir, "env/dir",
                   "preload dir env wins over the (empty) default");
        assert!(!cfg.fused, "COSA_SERVE_FUSED=false disables fusion");
        assert_eq!(cfg.classes.batch, 6);
        assert_eq!(cfg.classes.background, 1,
                   "a zero weight clamps to 1 instead of stalling");
        for key in [
            "COSA_SERVE_MAX_BATCH",
            "COSA_SERVE_MAX_WAIT_US",
            "COSA_SERVE_CACHE_MB",
            "COSA_SERVE_PRELOAD_DIR",
            "COSA_SERVE_FUSED",
            "COSA_SERVE_CLASS_BATCH",
            "COSA_SERVE_CLASS_BACKGROUND",
            "COSA_SERVE_CACHE_QUANT",
        ] {
            std::env::remove_var(key);
        }
        let cfg = ServeConfig::default().env_overridden();
        assert_eq!(cfg, ServeConfig::default());
    }

    #[test]
    fn serve_preload_dir_parses_from_toml() {
        let cfg = RunConfig::from_toml(
            "[serve]\npreload_dir = \"ckpts/fleet\"",
        )
        .unwrap();
        assert_eq!(cfg.serve.preload_dir, "ckpts/fleet");
        // absent -> disabled (empty)
        let d = RunConfig::from_toml("").unwrap();
        assert!(d.serve.preload_dir.is_empty());
    }

    #[test]
    fn wire_table_parses_and_validates() {
        let cfg = RunConfig::from_toml(
            "[wire]\nhost = \"0.0.0.0\"\nport = 9090\nhttp_workers = 2\n\
             max_body_bytes = 1048576\nread_timeout_ms = 250\n\
             keep_alive = false\nshed_queue_depth = 32\n\
             shed_evictions_per_s = 100.0\ndeadline_ms = 50",
        )
        .unwrap();
        assert_eq!(cfg.wire.host, "0.0.0.0");
        assert_eq!(cfg.wire.port, 9090);
        assert_eq!(cfg.wire.http_workers, 2);
        assert_eq!(cfg.wire.max_body_bytes, 1 << 20);
        assert_eq!(cfg.wire.read_timeout_ms, 250);
        assert!(!cfg.wire.keep_alive);
        assert_eq!(cfg.wire.shed_queue_depth, 32);
        assert_eq!(cfg.wire.shed_evictions_per_s, 100.0);
        assert_eq!(cfg.wire.deadline_ms, 50);
        assert!(RunConfig::from_toml("[wire]\nport = 70000").is_err());
        assert!(RunConfig::from_toml("[wire]\nport = -1").is_err());
        assert!(RunConfig::from_toml("[wire]\nmax_body_bytes = 0").is_err());
        assert!(RunConfig::from_toml("[wire]\nmax_pending_conns = 0")
            .is_err());
        assert!(RunConfig::from_toml("[wire]\nread_timeout_ms = -5")
            .is_err());
        assert!(RunConfig::from_toml(
            "[wire]\nshed_evictions_per_s = -1.0").is_err());
        // defaults when the table is absent
        let d = RunConfig::from_toml("").unwrap();
        assert_eq!(d.wire, WireConfig::default());
    }

    #[test]
    fn wire_env_overrides_win_and_warn_on_garbage() {
        std::env::set_var("COSA_WIRE_PORT", "8123");
        std::env::set_var("COSA_WIRE_MAX_BODY_BYTES", "not-a-number");
        std::env::set_var("COSA_WIRE_MAX_PENDING_CONNS", "0");
        std::env::set_var("COSA_WIRE_KEEP_ALIVE", "false");
        let cfg = WireConfig::default().env_overridden();
        assert_eq!(cfg.port, 8123, "env wins over the default");
        assert_eq!(cfg.max_body_bytes, WireConfig::default().max_body_bytes,
                   "garbage env value falls back");
        assert_eq!(cfg.max_pending_conns,
                   WireConfig::default().max_pending_conns,
                   "a zero accept-queue bound falls back");
        assert!(!cfg.keep_alive);
        std::env::remove_var("COSA_WIRE_PORT");
        std::env::remove_var("COSA_WIRE_MAX_BODY_BYTES");
        std::env::remove_var("COSA_WIRE_MAX_PENDING_CONNS");
        std::env::remove_var("COSA_WIRE_KEEP_ALIVE");
        let cfg = WireConfig::default().env_overridden();
        assert_eq!(cfg, WireConfig::default());
    }

    #[test]
    fn model_table_parses_and_validates() {
        let cfg = RunConfig::from_toml(
            "[model]\nsites = 4\nsite_m = 32\nsite_n = 24\ncore_a = 8\n\
             core_b = 6",
        )
        .unwrap();
        assert_eq!(cfg.model.sites, 4);
        assert_eq!((cfg.model.site_m, cfg.model.site_n), (32, 24));
        let spec = cfg.model.to_spec("run").unwrap();
        assert_eq!(spec.len(), 4);
        assert_eq!(spec.name, "run");
        assert!(RunConfig::from_toml("[model]\nsites = 0").is_err());
        assert!(RunConfig::from_toml("[model]\ncore_a = -3").is_err());
        // defaults when the table is absent
        let d = RunConfig::from_toml("").unwrap();
        assert_eq!(d.model, ModelConfig::default());
        assert_eq!(d.model.to_spec("x").unwrap().len(), 24);
    }

    #[test]
    fn model_method_selects_servable_adapter_zoo_members() {
        use crate::adapters::Method;
        let d = RunConfig::from_toml("").unwrap();
        assert_eq!(d.model.method, "cosa", "cosa is the default");
        assert_eq!(d.model.to_method().unwrap(), Method::CoSA);
        for (tag, want) in [
            ("cosa", Method::CoSA),
            ("rosa", Method::RoSA),
            ("lora", Method::LoRA),
        ] {
            let cfg = RunConfig::from_toml(&format!(
                "[model]\nmethod = \"{tag}\""
            ))
            .unwrap();
            assert_eq!(cfg.model.to_method().unwrap(), want);
        }
        // unknown tags and known-but-unservable baselines fail fast
        assert!(RunConfig::from_toml(
            "[model]\nmethod = \"qlora\"").is_err());
        assert!(RunConfig::from_toml(
            "[model]\nmethod = \"dora\"").is_err());
        // env override (normalized to lowercase)
        let _env = MODEL_ENV_LOCK
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        std::env::set_var("COSA_MODEL_METHOD", "RoSA");
        let cfg = ModelConfig::default().env_overridden();
        assert_eq!(cfg.method, "rosa");
        assert_eq!(cfg.to_method().unwrap(), Method::RoSA);
        std::env::remove_var("COSA_MODEL_METHOD");
        assert_eq!(ModelConfig::default().env_overridden().method, "cosa");
    }

    #[test]
    fn model_site_list_overrides_synthetic_preset() {
        let cfg = RunConfig::from_toml(
            "[model]\nsites = 9\nsites_spec = [\"adp.0.wq:16x12:4x3\", \
             \"adp.0.wv:16x12:2x3\"]",
        )
        .unwrap();
        let spec = cfg.model.to_spec("m").unwrap();
        assert_eq!(spec.len(), 2, "explicit list wins over sites = 9");
        assert_eq!(spec.sites[0].name, "adp.0.wq");
        assert_eq!((spec.sites[1].a, spec.sites[1].b), (2, 3),
                   "per-site heterogeneous cores come from the list");
        // config parsing fails fast on malformed or duplicate entries
        assert!(RunConfig::from_toml(
            "[model]\nsites_spec = [\"nodims\"]").is_err());
        assert!(RunConfig::from_toml(
            "[model]\nsites_spec = [\"a:2x2:1x1\", \"a:2x2:1x1\"]")
            .is_err());
        assert!(RunConfig::from_toml(
            "[model]\nsites_spec = 7").is_err());
    }

    #[test]
    fn model_env_overrides_win_and_warn_on_garbage() {
        let _env = MODEL_ENV_LOCK
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        std::env::set_var("COSA_MODEL_SITES", "3");
        std::env::set_var("COSA_MODEL_CORE_A", "not-a-number");
        std::env::set_var("COSA_MODEL_SITES_SPEC", "");
        let cfg = ModelConfig::default().env_overridden();
        assert_eq!(cfg.sites, 3, "env wins over the default");
        assert_eq!(cfg.core_a, ModelConfig::default().core_a,
                   "garbage env value falls back");
        assert!(cfg.sites_spec.is_empty(),
                "empty spec env means no explicit sites");
        std::env::set_var("COSA_MODEL_SITES_SPEC",
                          "adp.0.wq:8x8:2x2, adp.0.wv:8x8:2x2");
        let cfg = ModelConfig::default().env_overridden();
        assert_eq!(cfg.sites_spec.len(), 2);
        assert_eq!(cfg.to_spec("m").unwrap().len(), 2);
        std::env::remove_var("COSA_MODEL_SITES");
        std::env::remove_var("COSA_MODEL_CORE_A");
        std::env::remove_var("COSA_MODEL_SITES_SPEC");
        let cfg = ModelConfig::default().env_overridden();
        assert_eq!(cfg, ModelConfig::default());
    }

    #[test]
    fn obs_table_parses_and_validates() {
        let cfg = RunConfig::from_toml(
            "[obs]\nenabled = false\nslow_ms = 250\nslow_ring = 64\n\
             exemplars = 16",
        )
        .unwrap();
        assert!(!cfg.obs.enabled);
        assert_eq!(cfg.obs.slow_ms, 250);
        assert_eq!(cfg.obs.slow_ring, 64);
        assert_eq!(cfg.obs.exemplars, 16);
        assert!(RunConfig::from_toml("[obs]\nslow_ms = 0").is_err());
        assert!(RunConfig::from_toml("[obs]\nslow_ring = -1").is_err());
        assert!(RunConfig::from_toml("[obs]\nexemplars = 100000")
            .is_err());
        // defaults when the table is absent: tracing on
        let d = RunConfig::from_toml("").unwrap();
        assert_eq!(d.obs, ObsConfig::default());
        assert!(d.obs.enabled);
    }

    #[test]
    fn obs_env_overrides_win_and_warn_on_garbage() {
        std::env::set_var("COSA_OBS_ENABLED", "false");
        std::env::set_var("COSA_OBS_SLOW_MS", "0");
        std::env::set_var("COSA_OBS_SLOW_RING", "not-a-number");
        std::env::set_var("COSA_OBS_EXEMPLARS", "12");
        let cfg = ObsConfig::default().env_overridden();
        assert!(!cfg.enabled, "env wins over the default");
        assert_eq!(cfg.slow_ms, ObsConfig::default().slow_ms,
                   "slow_ms=0 falls back like the TOML path");
        assert_eq!(cfg.slow_ring, ObsConfig::default().slow_ring,
                   "garbage env value falls back");
        assert_eq!(cfg.exemplars, 12);
        for key in [
            "COSA_OBS_ENABLED",
            "COSA_OBS_SLOW_MS",
            "COSA_OBS_SLOW_RING",
            "COSA_OBS_EXEMPLARS",
        ] {
            std::env::remove_var(key);
        }
        let cfg = ObsConfig::default().env_overridden();
        assert_eq!(cfg, ObsConfig::default());
    }

    #[test]
    fn serve_resolution_respects_explicit_settings() {
        let auto = ServeConfig::default();
        assert_eq!(auto.resolved("tiny-lm").workers, 1,
                   "tiny preset hints one worker");
        assert_eq!(auto.resolved("small-lm").workers, 0,
                   "larger presets stay auto");
        let explicit = ServeConfig { workers: 5, ..ServeConfig::default() };
        assert_eq!(explicit.resolved("tiny-lm").workers, 5);
    }

    #[test]
    fn compute_resolution_respects_explicit_settings() {
        let auto = ComputeConfig::default();
        let r = auto.resolved("tiny-lm");
        assert_eq!(r.backend, "packed");
        assert_eq!(r.threads, 1, "tiny preset hints serial");
        let explicit =
            ComputeConfig { backend: "reference".into(), threads: 3 };
        let r = explicit.resolved("tiny-lm");
        assert_eq!(r.backend, "reference");
        assert_eq!(r.threads, 3);
    }
}
